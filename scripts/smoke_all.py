"""Dev script: run a reduced-config forward+train+prefill+decode for every
assigned architecture on CPU. Fast feedback loop while building."""
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.data import make_batch
from repro.models import lm
from repro.train import init_train_state, make_train_step, make_prefill_step, make_decode_step

ONLY = sys.argv[1:] or ARCH_IDS

for arch in ONLY:
    t0 = time.time()
    try:
        cfg = get_config(arch).smoke()
        rng = jax.random.key(0)
        state = init_train_state(cfg, rng)
        n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
        batch = make_batch(cfg, 2, 64)
        step = jax.jit(make_train_step(cfg, telemetry=True))
        state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        assert jnp.isfinite(metrics["loss"]), f"{arch}: loss NaN"
        # prefill + 2 decode steps
        pf = jax.jit(make_prefill_step(cfg, cache_len=96))
        logits, cache = pf(state["params"], batch)
        assert jnp.all(jnp.isfinite(logits)), f"{arch}: prefill NaN"
        dec = jax.jit(make_decode_step(cfg))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(2):
            tok, lg, cache = dec(state["params"], tok, cache)
        assert jnp.all(jnp.isfinite(lg)), f"{arch}: decode NaN"
        print(f"OK   {arch:22s} params={n_params:>9,} loss={loss:8.4f} "
              f"dirty={float(metrics['dirty_fraction']):.2f} "
              f"({time.time()-t0:.1f}s)")
    except Exception as e:
        print(f"FAIL {arch}: {type(e).__name__}: {e}")
        traceback.print_exc()
