#!/usr/bin/env bash
# Tier-1 verification: the full pytest suite plus the benchmark smoke
# (which refreshes and schema-checks BENCH_fig10.json / BENCH_table6.json).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python -m benchmarks.run --quick
echo "verify.sh: OK"
