#!/usr/bin/env bash
# Tier-1 verification: the full pytest suite plus the benchmark smoke
# (which refreshes and schema-checks BENCH_fig10.json / BENCH_table6.json
# / BENCH_scenarios.json, asserts the adaptive concurrency controller
# never moves more bytes than the static share-floor gate on the
# contended grid, runs the controlplane_scaling smoke — stacked defer-k
# sweep bit-equal to the per-k reference and >= 5x at 64 candidates,
# event-skipping FleetSim bit-identical to the per-second loop and
# >= 10x on a sparse plan — the route-aware pod/spine criteria: stacked
# defer-k x route selections bit-equal to the per-pair reference,
# route-aware bytes <= fixed-shortest-path on every cell and strictly
# lower on an oversubscribed one, stacked route-sweep decision latency
# within 2x of the flat-fabric sweep at 64 candidates x 4 routes — the
# receding-horizon admission criteria (ISSUE 9, horizon_sweep): horizon
# contended bytes <= the myopic controller's on every load x fabric
# cell, strictly lower on >= 1 cyclic-load cell, horizon select() <= 2x
# the myopic stacked sweep at 64 candidates, horizon=False
# stacked-vs-reference selections bit-equal — and the fault-injection
# scenario smoke: empty-FaultPlan parity bit-identical, node_failure RTO
# bounded, host_drain deadline met, per-link bytes conserved across
# abort/retry).
#
# Tier-1 pytest includes the ISSUE 8 fabric tests: tests/test_route_sweep.py
# (pod_spine structure, link-id table parity, stacked pair pricing,
# sparse masked solver, controller route parity) and
# tests/test_route_failover.py (correlated uplink outage -> failover),
# plus the ISSUE 9 receding-horizon tests: tests/test_horizon.py
# (ResumeState fresh-init bit-parity and mid-round resume consistency,
# subset-share solves, trough pricing, subset <= queue-prefix scores,
# overtake-aging no-starvation, LMCM trough wakes vs event-skip, and
# horizon=False byte-parity with the myopic controller).
#
# After tier-1, the sharded-decide-plane parity tests are re-run in a
# SEPARATE pytest process with XLA_FLAGS forcing 2 virtual CPU devices
# (the flag only takes effect before jax initializes; tier-1 deliberately
# sees the real single device, so multi-device tests skip there and the
# forced pass is what actually exercises shard_map bit-parity on CI).
#
#   --fast   tier-1 pytest only (skip the benchmark smoke)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

FAST=0
for arg in "$@"; do
    case "$arg" in
        --fast) FAST=1 ;;
        *) echo "verify.sh: unknown argument '$arg'" >&2; exit 2 ;;
    esac
done

python -m pytest -x -q
XLA_FLAGS="--xla_force_host_platform_device_count=2${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m pytest -x -q tests/test_shard.py
if [ "$FAST" -eq 0 ]; then
    python -m benchmarks.run --quick
fi
echo "verify.sh: OK"
