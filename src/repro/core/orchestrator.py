"""LMCM — the Live Migration Control Module (paper §5).

ALMA's central component: it intercepts every migration request coming from
the consolidation planner and decides, per request, to

  * trigger immediately   (workload is in a suitable LM moment),
  * postpone by RemainTime (Algorithm 2) — re-evaluated at the new moment,
  * or cancel              (migration cost exceeds the remaining-work benefit,
                            or a provider/customer constraint is violated).

Policies:
  immediate   — no surveillance (paper Fig. 5a baseline)
  alma-paper  — faithful pipeline: NB -> LM/NLM -> FFT -> Alg.1 -> Alg.2,
                first-cycle-window profile, binary decisions
  alma-plus   — beyond-paper: folded (majority-vote) cycle profile, posterior-
                weighted suitability, Strunk-cost-minimizing window selection
                within the provider's max-wait horizon

Provider knobs (paper §5.1): ``max_wait`` caps postponement (long cycles must
not starve migrations), ``max_concurrent`` rate-limits simultaneous
migrations. Customer knob: ``deadline`` — if the workload is expected to end
before the migration pays off, the request is cancelled.

Scalability: all per-job surveillance (window gather -> NB classification ->
FFT cycle fit -> Algorithm 2) is delegated to the fleet-wide batched engine
in ``core/surveillance.py`` — ONE tick computes every stale job's cycle fit
(staleness epochs: a fit is reused until the window advances period/4
samples) and answers Algorithm 2 for the whole fleet in one vectorized jit
call. ``decide`` reads the engine's cached models; the Fig. 10 benchmark
drives ``SurveillanceEngine.tick`` directly at 10k+ jobs.

Execution feedback: released requests run on the contention-aware
migration plane (``core/plane.py``), and the plane feeds back through
``bandwidth_probe`` — the max-min fair share a request would realize right
now on its src->dst links. The deadline check and the alma-plus cost scan
judge feasibility at that realized bandwidth instead of the nominal link
speed.

Concurrency control at the release boundary (``due``) is pluggable:

  * ``controller`` (preferred) — an adaptive concurrency controller
    (``core/controller.py``) that sweeps candidate in-flight counts per
    migration domain and launches the batch minimizing predicted total
    contended bytes;
  * ``min_share_frac`` (fallback) — the static share-floor gate: a
    candidate whose realized fair share would fall below
    ``min_share_frac`` x its *uncontended path capacity* is deferred one
    sampling period. The gate probes cumulatively within the tick — each
    candidate contends against the actual paths of every same-burst
    co-launch admitted before it, not against same-path clones — so
    co-launches in disjoint domains no longer dilute each other
    spuriously, and a burst that would dilute everyone below the floor is
    deferred as a burst.

Either way, a request that can no longer be deferred without breaching the
provider's ``max_wait`` is released unconditionally.
"""
from __future__ import annotations

import heapq
import inspect
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import characterize, cycles, postpone as pp, strunk
from repro.core.surveillance import SurveillanceEngine, SurveilledJob
from repro.core.telemetry import TelemetryBuffer


@dataclass
class MigrationRequest:
    job_id: str
    created_at: float
    v_bytes: float                      # state size to move
    src: str = ""
    dst: str = ""
    deadline: Optional[float] = None    # customer: expected workload end
    # --- filled by the simulator/plane ---
    path: Tuple[str, ...] = ()          # network links the transfer traverses
    # --- filled by LMCM ---
    # pending|scheduled|running|done|cancelled|failed ("failed" is the
    # terminal state of an aborted request whose retries are exhausted)
    decision: str = "pending"
    scheduled_at: float = 0.0
    outcome: Optional[strunk.MigrationOutcome] = None
    # --- failure/retry state (fault-injecting scenarios) ---
    retries: int = 0                    # re-admissions after aborts so far
    attempt_bytes: float = 0.0          # bytes wasted by aborted attempts
    # admission-time priced prediction (stamped by the simulator from the
    # controller's cost batch at launch); the execute plane's prediction
    # guard (core/guard.py) watches realized progress against these and
    # throttles/aborts diverging lanes. None = lane runs unguarded.
    expected_bytes: Optional[float] = None
    expected_time: Optional[float] = None
    # urgent requests (failure recovery: the workload is gone, there is
    # no cycle left to time against) bypass policy postponement at submit
    # and at the release boundary — concurrency control still applies
    urgent: bool = False
    # consecutive controller deferrals (receding-horizon admission): the
    # controller promotes a request to a forced launch once this reaches
    # its aging bound, so subset selection can never starve a candidate
    defers: int = 0
    # generation of this request's LIVE heap entry: cancel+resubmit leaves
    # the old entry in the heap, and decision alone cannot tell the stale
    # entry from the live one (both say "scheduled") — ``due`` only honors
    # the entry whose sequence number matches
    heap_gen: int = field(default=-1, repr=False, compare=False)


class LMCM:
    def __init__(self, *, policy: str = "alma-paper", max_wait: float = 1e4,
                 max_concurrent: int = 2, bandwidth: float = 50e9,
                 sample_period: float = 1.0,
                 surveillance: Optional[SurveillanceEngine] = None,
                 min_share_frac: float = 0.0,
                 retry_backoff_s: float = 4.0, retry_max: int = 3,
                 retry_jitter: float = 0.0, retry_jitter_seed: int = 0):
        assert policy in ("immediate", "alma-paper", "alma-plus")
        self.policy = policy
        self.max_wait = max_wait
        self.max_concurrent = max_concurrent
        self.bandwidth = bandwidth
        self.sample_period = sample_period     # seconds per telemetry sample
        self.engine = surveillance or SurveillanceEngine(
            folded=(policy == "alma-plus"))
        self.jobs: Dict[str, SurveilledJob] = self.engine.jobs
        self.queue: List = []                  # heap of (fire_time, seq, req)
        self._seq = 0
        self.running: List[MigrationRequest] = []
        self.log: List[MigrationRequest] = []
        # realized-bandwidth feedback from the migration plane: fair-share
        # bandwidth a request would get right now, given what's in flight.
        # Preferred signature (req, extra, pending): ``pending`` carries
        # the actual paths of same-burst co-launches not yet in flight,
        # ``extra`` approximates further ones as same-path clones; legacy
        # two-argument probes are detected once and fed the clone count
        # only. The simulator wires this to ShardedPlane.probe_bandwidth;
        # the deadline check and the alma-plus cost scan use it in place
        # of the nominal link speed, and ``due``'s fallback gate defers
        # launches whose share would fall below ``min_share_frac`` x the
        # request's uncontended path capacity (0 disables the gate).
        self.bandwidth_probe: Optional[Callable[..., float]] = None
        self._probe_pending: Tuple[Optional[Callable], bool] = (None, False)
        self.min_share_frac = min_share_frac
        # uncontended capacity of a request's src->dst path (the gate's
        # floor reference on multi-rack topologies, where the bottleneck
        # is the ToR/core link, not the nominal single-link speed); wired
        # to ShardedPlane.path_capacity by the simulator
        self.path_capacity: Optional[
            Callable[[MigrationRequest], float]] = None
        # adaptive concurrency controller (core/controller.py): when set,
        # it replaces the static share-floor gate at the release boundary
        self.controller = None
        # re-admission of aborted in-flight requests (``fail``):
        # exponential backoff base and the retry cap before a request is
        # failed permanently
        self.retry_backoff_s = retry_backoff_s
        self.retry_max = retry_max
        # deterministic backoff de-collision: a mass abort (host failure,
        # guard storm) re-admits many requests off the SAME event, so pure
        # exponential backoff re-collides them all on the same tick
        # forever. ``retry_jitter`` > 0 stretches each wait by up to that
        # fraction, keyed by a stable per-(job, attempt, seed) hash —
        # de-synchronized across jobs, reproducible across runs, and 0 by
        # default (bit-parity with the un-jittered schedule)
        self.retry_jitter = float(retry_jitter)
        self.retry_jitter_seed = int(retry_jitter_seed)
        # endpoint revalidation hook, wired by the simulator: called on a
        # request before re-admission and again at the release boundary;
        # it may rewrite src/dst/path (e.g. route around dead hosts) and
        # returns False when no valid endpoints exist — the request is
        # then failed/cancelled instead of launched at a dead host
        self.retarget: Optional[
            Callable[[MigrationRequest], bool]] = None
        # receding-horizon admission keeps reading cycle fits even under
        # policy="immediate" (the controller prices launch-at-trough
        # columns from the same fits); the simulator sets this so its
        # event-skip keeps honoring surveillance refresh boundaries
        self.force_surveillance = False

    @property
    def uses_surveillance(self) -> bool:
        """Whether this policy reads cycle fits at all — ``immediate``
        is the paper's no-surveillance baseline (Fig. 5a), so a
        simulator may skip its per-step engine ticks and staleness
        boundaries entirely."""
        return self.policy != "immediate" or self.force_surveillance

    # -- registration --------------------------------------------------------
    def register_job(self, job_id: str, telemetry: TelemetryBuffer,
                     nb: characterize.NaiveBayes, *, window: int = 512,
                     dirty_rate_fn=None) -> None:
        self.engine.register(job_id, telemetry, nb, window=window,
                             dirty_rate_fn=dirty_rate_fn)

    # -- characterization + cycle fit (paper §4) ------------------------------
    def refresh_job(self, job_id: str) -> Optional[cycles.CycleModel]:
        """Current cycle model of one job — recomputed by the surveillance
        engine only when the job's staleness epoch has lapsed."""
        return self.engine.refresh_model(job_id)

    def tick(self, now: float = 0.0) -> int:
        """One fleet surveillance pass (batched; see SurveillanceEngine).
        Staleness is tracked by telemetry step counts, not wall time, so
        ``now`` is accepted only for sim-loop symmetry. Returns the number
        of jobs whose cycle fit was recomputed."""
        return self.engine.refresh()

    # -- the decision (paper §5.2 + Fig. 5c) ----------------------------------
    def decide(self, req: MigrationRequest, now: float) -> float:
        """Returns the wait time (seconds); -1 means cancel."""
        wait = self._policy_wait(req, now)
        # provider constraint: never postpone beyond max_wait
        wait = min(wait, self.max_wait)
        # customer constraint: cancel if workload ends before migration pays
        # (judged at the REALIZED bandwidth the contended link would give us,
        # not the nominal link speed)
        if req.deadline is not None:
            t_mig = strunk.strunk_bounds(req.v_bytes,
                                         self.effective_bandwidth(req))[0]
            if now + wait + t_mig >= req.deadline:
                return -1.0
        return wait

    def _policy_wait(self, req: MigrationRequest, now: float) -> float:
        """The policy's raw postponement, before provider/customer knobs."""
        if self.policy == "immediate":
            return 0.0
        job = self.jobs.get(req.job_id)
        model = self.refresh_job(req.job_id) if job else None
        if model is None or not model.cyclic:
            return 0.0                     # acyclic: nothing to exploit
        m_now = int(now / self.sample_period) - job.origin_step
        if self.policy == "alma-paper":
            return pp.postpone(model, m_now) * self.sample_period
        return self._best_window_wait(job, model, req, now)

    def effective_bandwidth(self, req: MigrationRequest, extra: int = 0,
                            pending: Sequence[Tuple[str, ...]] = ()
                            ) -> float:
        """Bandwidth this request would realize now: the plane's fair-share
        probe when wired, capped by the nominal link speed. ``pending``
        carries the ACTUAL network paths of launches released in the same
        burst but not yet in flight; ``extra`` approximates further such
        launches as clones of this request's path (the legacy form kept
        for two-argument probes)."""
        if self.bandwidth_probe is None:
            return self.bandwidth
        if pending:
            if self._takes_pending():
                probed = self.bandwidth_probe(req, extra, tuple(pending))
            else:
                # legacy two-argument probe: fold the co-launches into the
                # same-path-clone approximation (exact on a single link)
                probed = self.bandwidth_probe(req, extra + len(pending))
        else:
            probed = self.bandwidth_probe(req, extra)
        if not np.isfinite(probed) or probed <= 0:
            return self.bandwidth
        return min(self.bandwidth, probed)

    def _takes_pending(self) -> bool:
        """Whether the wired probe accepts the third ``pending`` argument —
        decided from its signature (cached per probe object) rather than a
        try/except, which would silently mask TypeErrors raised INSIDE a
        modern probe and degrade it to the clone approximation."""
        fn = self.bandwidth_probe
        if self._probe_pending[0] is not fn:
            try:
                params = list(inspect.signature(fn).parameters.values())
                ok = (len(params) >= 3
                      or any(p.kind is p.VAR_POSITIONAL for p in params))
            except (TypeError, ValueError):
                ok = False
            self._probe_pending = (fn, ok)
        return self._probe_pending[1]

    def _floor_reference(self, req: MigrationRequest) -> float:
        """The bandwidth the share floor is a fraction OF: the request's
        uncontended path capacity when the topology is wired (a cross-rack
        transfer through an oversubscribed core can never realize the
        nominal access speed — gating it against ``self.bandwidth`` would
        defer it forever even on an idle fabric), else the nominal link
        speed."""
        if self.path_capacity is not None:
            cap = self.path_capacity(req)
            if np.isfinite(cap) and cap > 0:
                return cap
        return self.bandwidth

    def _best_window_wait(self, job: SurveilledJob, model: cycles.CycleModel,
                          req: MigrationRequest, now: float) -> float:
        """'alma-plus': scan candidate start moments across one full cycle
        (bounded by max_wait) and pick the minimum-Strunk-cost start."""
        m_now = int(now / self.sample_period) - job.origin_step
        remain = pp.postpone(model, m_now) * self.sample_period
        rate = job.dirty_rate_fn
        if rate is None:
            return remain
        # scan one cycle of candidate starts; Alg.2's moment is always a
        # candidate and wins ties (never do worse than alma-paper)
        horizon = min(model.period * self.sample_period, self.max_wait)
        candidates = np.unique(np.concatenate(
            [[min(remain, self.max_wait)],
             np.linspace(0.0, horizon, num=min(32, model.period + 1))]))
        costs = strunk.expected_cost_batch(
            req.v_bytes, self.effective_bandwidth(req), rate,
            now + candidates)
        best = costs.min()
        ok = costs <= best * 1.01
        if ok[candidates == min(remain, self.max_wait)].any():
            return float(min(remain, self.max_wait))
        return float(candidates[ok][0])

    # -- queue machinery -------------------------------------------------------
    def _push(self, req: MigrationRequest, when: float) -> None:
        """(Re)enter the heap: stamps the request with a fresh entry
        generation so any older entry for the same request goes stale."""
        req.scheduled_at = when
        self._seq += 1
        req.heap_gen = self._seq
        heapq.heappush(self.queue, (when, self._seq, req))

    def submit(self, req: MigrationRequest, now: float) -> None:
        # urgent (recovery) requests skip the policy decision: the
        # workload they restart is gone, so there is no LM moment to wait
        # for — only the release boundary's concurrency control applies
        wait = 0.0 if req.urgent else self.decide(req, now)
        if wait < 0:
            req.decision = "cancelled"
            self.log.append(req)
            return
        req.decision = "scheduled"
        self._push(req, now + wait)

    def fail(self, req: MigrationRequest,
             outcome: strunk.MigrationOutcome, now: float) -> bool:
        """Re-admission boundary for a request whose in-flight lane was
        aborted: bill the wasted attempt, then either re-enter the heap
        with exponential backoff (endpoints revalidated through
        ``retarget``, so a retry never aims at a dead host) or fail the
        request permanently. Returns True iff a retry was scheduled.

        Deadline/max-wait semantics survive re-admission: ``created_at``
        is never touched, so a retry already past the provider's
        max-wait wall force-launches through ``_admit``; a retry that
        cannot meet the customer deadline even at the backed-off start
        is failed now rather than launched doomed."""
        req.attempt_bytes += outcome.bytes_sent
        req.outcome = outcome
        if req.retries >= self.retry_max or \
                (self.retarget is not None and not self.retarget(req)):
            req.decision = "failed"
            self.log.append(req)
            return False
        req.retries += 1
        wait = self.retry_backoff_s * (2.0 ** (req.retries - 1))
        if self.retry_jitter > 0.0:
            # crc32 is stable across processes (unlike hash()), so the
            # jittered schedule is reproducible per seed while distinct
            # jobs aborted by one event fan out over [wait, wait*(1+j))
            h = zlib.crc32(f"{self.retry_jitter_seed}:{req.job_id}:"
                           f"{req.retries}".encode())
            wait *= 1.0 + self.retry_jitter * (h / 2.0 ** 32)
        if req.deadline is not None:
            t_mig = strunk.strunk_bounds(req.v_bytes,
                                         self.effective_bandwidth(req))[0]
            if now + wait + t_mig >= req.deadline:
                req.decision = "failed"
                self.log.append(req)
                return False
        req.decision = "scheduled"
        self._push(req, now + wait)
        return True

    def cancel(self, req: MigrationRequest) -> None:
        """Withdraw a request (e.g. the consolidation plan was revised).
        Heap entries are left in place; ``due`` skips non-scheduled pops,
        and the entry generation protects a cancelled-then-resubmitted
        request from its own stale entry (firing early off the old entry,
        or being dropped when the old entry is consumed first)."""
        if req.decision in ("pending", "scheduled"):
            req.decision = "cancelled"
            self.log.append(req)

    def next_due_time(self) -> float:
        """Earliest heap fire time (``inf`` when the queue is idle) — the
        event-skipping simulator's release horizon. Stale entries (from
        cancel/resubmit) are included: they make the bound conservative
        (the skipped window only shrinks), never wrong, and a stale pop
        at the boundary is a cheap no-op."""
        return self.queue[0][0] if self.queue else float("inf")

    def due(self, now: float) -> List[MigrationRequest]:
        """Pop requests whose moment has come, honoring max_concurrent and
        the concurrency policy at the release boundary: the adaptive
        controller when wired, else the cumulative share-floor gate."""
        self.running = [r for r in self.running if r.decision == "running"]
        ready: List[MigrationRequest] = []
        while (self.queue and self.queue[0][0] <= now
               and len(self.running) + len(ready) < self.max_concurrent):
            _, gen, req = heapq.heappop(self.queue)
            if req.decision != "scheduled" or gen != req.heap_gen:
                continue            # cancelled or superseded: stale entry
            # endpoint revalidation at the release boundary: a host that
            # died while the request sat in the heap is routed around
            # BEFORE the controller prices candidate paths (dead hosts
            # never reach the defer-k sweep); no valid endpoints -> cancel
            if self.retarget is not None and not self.retarget(req):
                req.decision = "cancelled"
                self.log.append(req)
                continue
            # re-check suitability at fire time (cycle may have drifted);
            # urgent recovery requests have no workload left to re-time
            if self.policy != "immediate" and not req.urgent:
                wait = self.decide(req, now)
                if wait < 0:
                    req.decision = "cancelled"
                    self.log.append(req)
                    continue
                if wait > self.sample_period and now + wait <= \
                        req.created_at + self.max_wait:
                    self._push(req, now + wait)
                    continue
            ready.append(req)
        out, deferred = self._admit(ready, now)
        for req in deferred:
            self._push(req, self._defer_wake(req, now))
        for req in out:
            req.decision = "running"
        self.running.extend(out)
        return out

    def _defer_wake(self, req: MigrationRequest, now: float) -> float:
        """Fire time for a controller-deferred request. A receding-horizon
        controller prices a specific wake (the predicted cycle trough) and
        publishes it in ``deferred_until``; honoring it here keeps
        ``next_due_time`` exact, so an event-skipping simulator stops at
        the re-admission boundary instead of jumping it. Clamped to
        ``max_wait`` so a far trough can never push a request past its
        urgency wall (``_admit`` only defers requests that can still wait
        at least one sampling period)."""
        wake = now + self.sample_period
        ctl = self.controller
        if ctl is not None:
            w = getattr(ctl, "deferred_until", {}).pop(id(req), None)
            if w is not None:
                wake = max(wake, float(w))
        return min(wake, req.created_at + self.max_wait)

    def _admit(self, ready: List[MigrationRequest], now: float
               ) -> Tuple[List[MigrationRequest], List[MigrationRequest]]:
        """Split the tick's ready burst into (launch, defer). Requests
        that cannot wait another sampling period without breaching
        ``max_wait`` always launch; the rest go through the adaptive
        controller when wired, else the share-floor gate, else all
        launch."""
        if not ready:
            return [], []
        can_defer = [now + self.sample_period <= r.created_at + self.max_wait
                     for r in ready]
        if self.controller is not None:
            forced = [r for r, ok in zip(ready, can_defer) if not ok]
            free = [r for r, ok in zip(ready, can_defer) if ok]
            chosen = {id(r) for r in
                      self.controller.select(free, now, forced=forced)}
            launch = [r for r, ok in zip(ready, can_defer)
                      if not ok or id(r) in chosen]
            return launch, [r for r in free if id(r) not in chosen]
        if self.min_share_frac <= 0.0 or self.bandwidth_probe is None:
            return ready, []
        # static fallback: cumulative share-floor gate. Each candidate is
        # probed against everything in flight PLUS the actual paths of the
        # co-launches admitted earlier in this same burst, and defers when
        # its share would fall below min_share_frac x its uncontended path
        # capacity. An idle fabric always admits the head of the burst.
        launch, defer = [], []
        pending_paths: List[Tuple[str, ...]] = []
        blind = 0               # admitted co-launches with no tagged path:
        for req, ok in zip(ready, can_defer):   # fall back to clone-counting
            gated = ok and (self.running or launch)
            if gated and (self.effective_bandwidth(
                    req, extra=blind, pending=pending_paths)
                    < self.min_share_frac * self._floor_reference(req)):
                defer.append(req)
                continue
            launch.append(req)
            if req.path:
                pending_paths.append(tuple(req.path))
            else:
                blind += 1
        return launch, defer

    def finish(self, req: MigrationRequest,
               outcome: strunk.MigrationOutcome) -> None:
        req.decision = "done"
        req.outcome = outcome
        self.log.append(req)
