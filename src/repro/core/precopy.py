"""Block-level pre-copy live migration of a *live* sharded pytree.

This is the paper's migration algorithm (§3.2) re-targeted at TPU job state
(params + optimizer + caches): while the job keeps stepping, state blocks
that changed since the last round ("dirty pages") are re-copied to the
destination buffer; Xen's three stop conditions end the iterative phase and
a final stop-and-copy (the only pause the job sees) transfers the last dirty
set. The result is bit-exact: the destination pytree equals the source at
the moment of the final copy (tested in tests/test_precopy.py).

Block diffing is the memory-bound hot loop -> Pallas kernel
(``repro.kernels.dirty_delta``), with a jnp fallback on hosts without it.

Time accounting is dual: wall-clock (real copies) and a bandwidth model
(bytes / link-bandwidth) so fleet-scale costs can be projected from smoke
runs — the same separation the paper uses between testbed runs and the
1,000-VM trace analysis.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strunk import (MigrationOutcome, XEN_MAX_ROUNDS,
                               XEN_STOP_DIRTY_PAGES, XEN_STOP_TOTAL_FACTOR)
from repro.kernels import ops as kops


@dataclass(frozen=True)
class PrecopyConfig:
    block_elems: int = 1 << 14                 # "page" size, in elements
    max_rounds: int = XEN_MAX_ROUNDS
    stop_dirty_blocks: int = XEN_STOP_DIRTY_PAGES
    stop_total_factor: float = XEN_STOP_TOTAL_FACTOR
    bandwidth: float = 50e9                    # modeled ICI link, bytes/s
    steps_per_round: int = 1                   # job steps overlapped per round


# ---------------------------------------------------------------------------
# flat block view of a pytree
# ---------------------------------------------------------------------------
def _flatten(state) -> List[jnp.ndarray]:
    return [l.reshape(-1) for l in jax.tree.leaves(state)]


@partial(jax.jit, static_argnums=(2,))
def _leaf_dirty(new: jnp.ndarray, old: jnp.ndarray, block: int) -> jnp.ndarray:
    """(n,) leaf pair -> (nb,) bool dirty mask."""
    nb = -(-new.shape[0] // block)
    pad = nb * block - new.shape[0]
    n2 = jnp.pad(new, (0, pad)).reshape(nb, block)
    o2 = jnp.pad(old, (0, pad)).reshape(nb, block)
    return kops.dirty_blocks(n2, o2)


@partial(jax.jit, static_argnums=(3,))
def _leaf_merge(new: jnp.ndarray, old: jnp.ndarray, dirty: jnp.ndarray,
                block: int) -> jnp.ndarray:
    """Copy dirty blocks of ``new`` over ``old`` (the 'network transfer')."""
    nb = dirty.shape[0]
    pad = nb * block - new.shape[0]
    n2 = jnp.pad(new, (0, pad)).reshape(nb, block)
    o2 = jnp.pad(old, (0, pad)).reshape(nb, block)
    out = jnp.where(dirty[:, None], n2, o2)
    return out.reshape(-1)[: new.shape[0]]


def dirty_scan(live, shadow, block: int) -> Tuple[List[jnp.ndarray], int, int]:
    """Per-leaf dirty masks + (dirty_blocks, dirty_bytes) totals."""
    masks, n_dirty, n_bytes = [], 0, 0
    for new, old in zip(_flatten(live), _flatten(shadow)):
        m = _leaf_dirty(new, old.astype(new.dtype), block)
        masks.append(m)
        d = int(jnp.sum(m))
        n_dirty += d
        n_bytes += d * block * new.dtype.itemsize
    return masks, n_dirty, n_bytes


def merge_dirty(live, shadow, masks: List[jnp.ndarray], block: int):
    flat_live = _flatten(live)
    flat_shadow = _flatten(shadow)

    def align(n, o):
        """The cross-placement transfer: move live data onto the destination
        sharding before merging (this IS the network copy)."""
        if getattr(n, "sharding", None) != getattr(o, "sharding", None):
            n = jax.device_put(n, o.sharding)
        return n

    merged = [_leaf_merge(align(n, o), o.astype(n.dtype), m, block)
              for n, o, m in zip(flat_live, flat_shadow, masks)]
    leaves = jax.tree.leaves(shadow)
    treedef = jax.tree.structure(shadow)
    new_leaves = [m.reshape(l.shape).astype(l.dtype)
                  for m, l in zip(merged, leaves)]
    return jax.tree.unflatten(treedef, new_leaves)


def total_bytes(state) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(state))


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
@dataclass
class PrecopyReport:
    outcome: MigrationOutcome
    wall_time: float
    per_round_dirty_bytes: List[int]
    v_mem: int


def migrate(get_state: Callable[[], Any],
            step_fn: Optional[Callable[[], None]],
            cfg: PrecopyConfig = PrecopyConfig(),
            *, placement: Optional[Callable[[Any], Any]] = None
            ) -> Tuple[Any, PrecopyReport]:
    """Pre-copy migrate the state returned by ``get_state`` while ``step_fn``
    keeps mutating it between rounds (the 'live' in live migration).

    ``placement`` optionally maps the destination pytree onto its new
    sharding/devices (e.g. ``lambda t: jax.device_put(t, dst_sharding)``).
    Returns (destination_state, report).
    """
    t0 = time.monotonic()
    place = placement or (lambda t: t)
    live = get_state()
    v_mem = total_bytes(live)

    # round 0: full copy (iterative-copy stage, first iteration)
    shadow = place(jax.tree.map(jnp.array, live))
    sent = v_mem
    sim_t = v_mem / cfg.bandwidth
    per_round = [v_mem]
    rounds = 1
    reason = "max_rounds"

    while True:
        if step_fn is not None:            # job keeps running during the copy
            for _ in range(cfg.steps_per_round):
                step_fn()
        live = get_state()
        masks, n_dirty, n_bytes = dirty_scan(live, shadow, cfg.block_elems)
        if n_dirty <= cfg.stop_dirty_blocks:
            reason = "dirty_low"
            break
        if rounds >= cfg.max_rounds:
            reason = "max_rounds"
            break
        if sent + n_bytes > cfg.stop_total_factor * v_mem:
            reason = "total_cap"
            break
        shadow = merge_dirty(live, shadow, masks, cfg.block_elems)
        sent += n_bytes
        sim_t += n_bytes / cfg.bandwidth
        per_round.append(n_bytes)
        rounds += 1

    # stop-and-copy: job paused; transfer the final dirty set
    live = get_state()
    masks, n_dirty, n_bytes = dirty_scan(live, shadow, cfg.block_elems)
    shadow = merge_dirty(live, shadow, masks, cfg.block_elems)
    shadow = jax.block_until_ready(shadow)
    downtime = n_bytes / cfg.bandwidth
    sent += n_bytes
    sim_t += downtime
    per_round.append(n_bytes)

    outcome = MigrationOutcome(total_time=sim_t, downtime=downtime,
                               bytes_sent=float(sent), rounds=rounds,
                               stop_reason=reason)
    report = PrecopyReport(outcome=outcome, wall_time=time.monotonic() - t0,
                           per_round_dirty_bytes=per_round, v_mem=v_mem)
    return shadow, report
