"""Naive Bayes workload characterization (paper §4.1, Table 5).

Discretized (binned) NB exactly as the paper sketches: load indexes are
quantile-discretized, per-class likelihood tables are learned with Laplace
smoothing, and prediction is a table lookup + sum of logs — Θ(n + k) per
sample (n = number of classes, k = number of indexes), which is the
linear-cost property the paper leans on for 1,000+ VM scalability.

Classes follow the paper: primary workload kinds (CPU / MEM / IO / IDLE)
that collapse onto the binary LM / NLM suitability signal — memory-dirty
workloads are NLM (pre-copy is dirty-rate bound, §3.2), everything else LM.
The posterior probabilities are kept (the paper highlights NB's quantitative
output as an optimization hook) and drive the 'alma-plus' policy.

The predict path is pure JAX (jit + vmap) so a fleet of series can be
classified in one batched call.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# canonical workload classes (paper §6.2)
CLASSES = ("CPU", "MEM", "IO", "IDLE")
CPU, MEM, IO, IDLE = range(4)
# suitability collapse: pre-copy cost tracks the memory dirty rate
LM_SUITABLE = np.array([True, False, True, True])   # MEM -> NLM


@dataclass
class NaiveBayes:
    """Binned NB model. Arrays are device-ready; predict is jittable."""

    bin_edges: jnp.ndarray      # (F, n_bins-1) quantile edges per feature
    log_likelihood: jnp.ndarray  # (C, F, n_bins)
    log_prior: jnp.ndarray      # (C,)

    @property
    def n_classes(self) -> int:
        return self.log_prior.shape[0]

    def predict_logprob(self, x: jnp.ndarray) -> jnp.ndarray:
        """x: (..., F) -> log-posterior (..., C) (unnormalized)."""
        return _nb_logprob(self.bin_edges, self.log_likelihood,
                           self.log_prior, x)

    def predict(self, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (class (...,) int32, posterior (..., C))."""
        return _nb_predict(self.bin_edges, self.log_likelihood,
                           self.log_prior, x)


def _nb_logprob(edges, ll, prior, x):
    bins = jax.vmap(jnp.searchsorted, in_axes=(0, -1), out_axes=-1)(
        edges, x)                                    # (..., F)
    lp = jnp.take_along_axis(
        ll[None], bins[..., None, :, None], axis=-1)[..., 0]  # (..., C, F)
    return jnp.sum(lp, axis=-1) + prior


@jax.jit
def _nb_predict(edges, ll, prior, x):
    lead = x.shape[:-1]
    lp = _nb_logprob(edges, ll, prior, x.reshape(-1, x.shape[-1]))
    lp = lp.reshape(*lead, -1)
    post = jax.nn.softmax(lp, axis=-1)
    return jnp.argmax(lp, axis=-1).astype(jnp.int32), post


@jax.jit
def _nb_predict_lm(edges, ll, prior, x):
    """LM/NLM signal only: same argmax as ``_nb_predict`` (bit-identical
    class decisions) but skips the softmax posterior — the decide-plane
    tick only consumes the binary suitability series, and this is also the
    shard_map body of the sharded classify (``core/shard.py``): no
    cross-row reduction anywhere, so row-partitioning is exact."""
    lead = x.shape[:-1]
    lp = _nb_logprob(edges, ll, prior, x.reshape(-1, x.shape[-1]))
    cls = jnp.argmax(lp, axis=-1)
    lm = jnp.asarray(LM_SUITABLE, jnp.int8)[
        jnp.clip(cls, 0, len(LM_SUITABLE) - 1)]
    return lm.reshape(lead)


def fit(features: np.ndarray, labels: np.ndarray, *, n_bins: int = 16,
        n_classes: int = len(CLASSES), alpha: float = 1.0) -> NaiveBayes:
    """features: (N, F) f32; labels: (N,) int in [0, n_classes)."""
    N, F = features.shape
    qs = np.linspace(0, 1, n_bins + 1)[1:-1]
    edges = np.quantile(features, qs, axis=0).T.astype(np.float32)  # (F, nb-1)
    # enforce strictly increasing edges (constant features -> tiny ramp)
    edges = np.maximum.accumulate(edges, axis=1)
    bump = np.arange(edges.shape[1], dtype=np.float32) * 1e-9
    edges = edges + bump[None, :]

    bins = np.stack([np.searchsorted(edges[f], features[:, f])
                     for f in range(F)], axis=1)     # (N, F)
    counts = np.zeros((n_classes, F, n_bins), np.float64)
    for c in range(n_classes):
        sel = bins[labels == c]
        for f in range(F):
            counts[c, f] = np.bincount(sel[:, f], minlength=n_bins)
    ll = np.log((counts + alpha)
                / (counts.sum(axis=2, keepdims=True) + alpha * n_bins))
    prior = np.bincount(labels, minlength=n_classes).astype(np.float64)
    log_prior = np.log((prior + alpha) / (prior.sum() + alpha * n_classes))
    return NaiveBayes(jnp.asarray(edges), jnp.asarray(ll, dtype=jnp.float32),
                      jnp.asarray(log_prior, dtype=jnp.float32))


def classify_series(nb: NaiveBayes, window: np.ndarray,
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Classify a telemetry window (T, F) sample-by-sample.

    Returns (classes (T,), lm_binary (T,) {0=NLM,1=LM}, posterior (T, C)).
    """
    cls, post = nb.predict(jnp.asarray(window, jnp.float32))
    cls = np.asarray(cls)
    lm = LM_SUITABLE[np.clip(cls, 0, len(LM_SUITABLE) - 1)].astype(np.int8)
    return cls, lm, np.asarray(post)


def classify_series_batch(nb: NaiveBayes, windows: np.ndarray,
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Classify a fleet of telemetry windows (J, T, F) in ONE jitted call —
    the surveillance-tick entry point (``core/surveillance.py``). Per-row
    results are identical to ``classify_series`` on each window (the jitted
    predict flattens leading axes, so reductions stay per-sample).

    Returns (classes (J, T), lm_binary (J, T) {0=NLM,1=LM},
    posterior (J, T, C)).
    """
    return classify_series(nb, windows)     # predict flattens leading axes


def classify_lm_batch(nb: NaiveBayes, windows: np.ndarray) -> np.ndarray:
    """LM-only fleet classification: (J, T, F) -> (J, T) int8 {0=NLM,1=LM}.

    Bit-identical to ``classify_series_batch``'s lm output (same jitted
    argmax, same suitability table) but never materializes the (J, T, C)
    posterior — the surveillance tick's classify stage.
    """
    return np.asarray(_nb_predict_lm(nb.bin_edges, nb.log_likelihood,
                                     nb.log_prior,
                                     jnp.asarray(windows, jnp.float32)))


def primary_secondary(classes: np.ndarray) -> Tuple[int, Optional[int]]:
    """Paper Table 5 reporting: the dominant and runner-up workload class."""
    counts = np.bincount(classes, minlength=len(CLASSES))
    order = np.argsort(-counts)
    primary = int(order[0])
    secondary = int(order[1]) if counts[order[1]] > 0.1 * counts.sum() else None
    return primary, secondary
