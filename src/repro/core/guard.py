"""Prediction guard — convergence watchdogs for in-flight migrations.

ALMA's admission sweep prices every launch from a model (cycle fit +
what-if cost batch), and until this layer the execute plane trusted that
price unconditionally: a lane whose realized dirty rate exceeds the
estimate grinds toward the Xen ``max_rounds``/``total_cap`` stops at up
to ``stop_total_factor``x the priced bytes, burning shared links the
whole way. Production migration managers treat convergence handling as
table stakes (He & Buyya's taxonomy: auto-converge, timeout/abort); this
module is that handler for ``core/plane.py``.

Mechanics: each launched lane may carry its admission-time expectation
(``expected_bytes``/``expected_time``, priced by the controller's cost
batch at launch). At every round boundary the plane evaluates all lanes
against a vectorized :class:`MigrationGuard`: the divergence ratio is
``max(realized_sent / expected_bytes, elapsed / expected_time)``, and a
two-rung policy ladder fires as it crosses configurable thresholds —

  1. **auto-converge throttling** (QEMU-style): the lane's dirty-rate
     table is replaced by a progressively scaled copy
     (``throttle_factor ** step``, floored at ``throttle_floor``).  The
     throttle is a *composable table transform* (:func:`throttled_spec`)
     — the scaled ``PiecewiseRate`` flows through the same ``RateBank``
     sampling, ``lane_state()`` snapshots, and
     ``simulate_precopy_batch``/``ResumeState`` repricing as the
     original, so the controller's in-flight repricing stays
     bit-consistent with what the plane will actually execute;
  2. **abort-and-retry**: the lane settles early with partial-bytes
     accounting and ``stop_reason == strunk.STOP_GUARD``
     (``"guard_abort"``, distinct from fault aborts) and re-enters
     ``LMCM.fail()``'s backoff path.  FleetSim additionally treats a
     guard abort as misprediction feedback: the job's cycle fit is
     forced stale and its ``trust`` score decays, which gates the
     receding-horizon trough pricing (see :meth:`MigrationGuard.trusts`).

Lanes without expectations (NaN) are never throttled or aborted, and a
plane constructed with ``guard=None`` (the default) takes none of these
code paths — disabled runs are bit-identical to a guard-less build.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.rates import PiecewiseRate, as_rate_table


def expectation_of(req) -> Tuple[float, float]:
    """(expected_bytes, expected_time) stamped on a request at admission,
    NaN where absent — NaN disarms the guard for that lane."""
    b = getattr(req, "expected_bytes", None)
    t = getattr(req, "expected_time", None)
    return (float(b) if b is not None else float("nan"),
            float(t) if t is not None else float("nan"))


def throttled_spec(spec, factor: float):
    """The composable auto-converge transform: ``spec`` with every dirty
    rate scaled by ``factor`` in (0, 1].

    ``PiecewiseRate`` tables (and anything ``as_rate_table`` can
    normalize: constants, objects exposing ``rate_table``) come back as a
    derived ``PiecewiseRate`` — same breakpoints, scaled rates — so every
    consumer (the plane's ``RateBank`` sampling, ``what_if_cost_batch``
    repricing from a ``ResumeState``, the scalar reference loop) prices
    the throttled lane identically. Plain callables are wrapped; None
    (no dirtying) is returned unchanged."""
    if spec is None:
        return None
    factor = float(factor)
    table = spec if isinstance(spec, PiecewiseRate) else (
        None if callable(spec) else as_rate_table(spec))
    if table is not None:
        return PiecewiseRate(table.ends, np.asarray(table.rates) * factor,
                             offset=table.offset)
    return lambda t, _fn=spec, _f=factor: _f * float(_fn(t))


class MigrationGuard:
    """Vectorized convergence watchdog + misprediction-feedback policy.

    One instance is shared by every migration domain of a
    ``fabric.ShardedPlane`` (it is plumbed through ``_plane_kw``), so the
    ``n_throttles``/``n_aborts`` counters aggregate fleet-wide; all
    per-lane state lives in the plane's SoA rows.

    Thresholds are divergence *ratios* (realized / predicted):
    ``throttle_ratio`` arms the auto-converge ladder, ``abort_ratio``
    (must be >= throttle_ratio) cuts the lane loose. ``trust_decay`` /
    ``trust_floor`` shape the per-job trust score a guard abort burns,
    and ``trust_gate`` is the ``confidence x trust`` floor below which
    the receding-horizon controller falls back to myopic pricing instead
    of deferring to a trough the model may have hallucinated."""

    def __init__(self, *, throttle_ratio: float = 1.5,
                 abort_ratio: float = 3.0,
                 throttle_factor: float = 0.5,
                 throttle_floor: float = 0.05,
                 trust_decay: float = 0.5,
                 trust_gate: float = 0.25,
                 trust_floor: float = 0.05):
        if not (1.0 <= throttle_ratio <= abort_ratio):
            raise ValueError("need 1 <= throttle_ratio <= abort_ratio, got "
                             f"{throttle_ratio} / {abort_ratio}")
        if not (0.0 < throttle_factor < 1.0):
            raise ValueError(f"throttle_factor in (0,1): {throttle_factor}")
        if not (0.0 < trust_decay <= 1.0):
            raise ValueError(f"trust_decay in (0,1]: {trust_decay}")
        self.throttle_ratio = float(throttle_ratio)
        self.abort_ratio = float(abort_ratio)
        self.throttle_factor = float(throttle_factor)
        self.throttle_floor = float(throttle_floor)
        self.trust_decay = float(trust_decay)
        self.trust_gate = float(trust_gate)
        self.trust_floor = float(trust_floor)
        self.n_throttles = 0
        self.n_aborts = 0

    def divergence(self, sent: np.ndarray, elapsed: np.ndarray,
                   expected_bytes: np.ndarray,
                   expected_time: np.ndarray) -> np.ndarray:
        """Per-lane divergence ratio, NaN where the lane carries no
        expectation (NaN compares False against every threshold, so
        unguarded lanes are structurally exempt)."""
        with np.errstate(invalid="ignore", divide="ignore"):
            div_b = np.asarray(sent, float) / np.asarray(
                expected_bytes, float)
            div_t = np.asarray(elapsed, float) / np.asarray(
                expected_time, float)
        return np.fmax(div_b, div_t)

    def factor_for(self, step: int) -> Optional[float]:
        """Dirty-rate scale after ``step`` ladder escalations, or None
        once the progressive cap would undercut ``throttle_floor``."""
        f = self.throttle_factor ** step
        return f if f >= self.throttle_floor else None

    def decay_trust(self, trust: float) -> float:
        """Trust after one guard abort (burned fits stay above the floor
        so a long-lived job can re-earn trough pricing after refits)."""
        return max(self.trust_floor, float(trust) * self.trust_decay)

    def trusts(self, confidence: float, trust: float) -> bool:
        """Does ``confidence x trust`` clear the trough-pricing gate?"""
        return float(confidence) * float(trust) >= self.trust_gate
