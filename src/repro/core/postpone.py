"""Algorithm 2 — identification of the live-migration moment (paper §5.2).

``postpone(model, m_current)`` computes the paper's ``RemainTime``: zero when
the workload's current relative moment sits in ArrayLM, otherwise the
distance to the first suitable moment. We also handle the wrap-around case
the paper leaves implicit (current moment past the last LM instant of the
cycle -> wait into the next cycle) and an all-NLM guard (returns ``period``
as a one-full-cycle backoff).

A vectorized jit variant classifies a whole fleet in one call (used by the
Fig. 10 scalability benchmark).

Two consumers, one algorithm: the LMCM's per-request decide path calls
``postpone`` directly (defer the request, re-decide at the trough), and the
receding-horizon admission controller reads the same RemainTime through
``SurveillanceEngine.next_trough`` — there it is a PRICE, not a verdict:
"launch now" and "launch at the trough T+RemainTime" are two columns of one
scored what-if batch, so Alg. 2's timing and the fabric's contention are
weighed in the same currency (predicted bytes) instead of in sequence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cycles import CycleModel


def postpone(model: CycleModel, m_current: int) -> int:
    """RemainTime in samples until the next suitable (LM) moment."""
    if model.period <= 1:
        return 0 if model.profile_lm.any() else int(model.period or 1)
    m_rel = int(m_current) % model.period
    if model.profile_lm[m_rel] == 1:
        return 0                                     # already suitable
    if len(model.array_lm) == 0:
        return model.period                          # acyclically busy: back off
    greater = model.array_lm[model.array_lm > m_rel]
    nxt = int(greater[0]) if len(greater) else int(model.array_lm[0]) + model.period
    return nxt - m_rel


def postpone_batch(profiles: jnp.ndarray, periods: jnp.ndarray,
                   m_current: jnp.ndarray) -> jnp.ndarray:
    """Vectorized Algorithm 2 over a fleet.

    profiles: (J, P_max) int8 (1=LM), padded with -1 beyond each period;
    periods: (J,) int32; m_current: (J,) int32. Returns (J,) RemainTime.
    """
    J, P_max = profiles.shape
    m_rel = m_current % jnp.maximum(periods, 1)

    idx = jnp.arange(P_max)[None, :]
    valid = idx < periods[:, None]
    is_lm = (profiles == 1) & valid
    # distance from m_rel to each LM phase, wrapping within the period
    dist = (idx - m_rel[:, None]) % jnp.maximum(periods, 1)[:, None]
    dist = jnp.where(is_lm, dist, jnp.iinfo(jnp.int32).max)
    remain = jnp.min(dist, axis=1)
    none_lm = ~jnp.any(is_lm, axis=1)
    remain = jnp.where(none_lm, periods, remain)       # all-NLM backoff
    return jnp.where(periods <= 1, 0, remain).astype(jnp.int32)


postpone_batch_jit = jax.jit(postpone_batch)


def pack_fleet(models, *, n_jobs=None, p_max=None) -> tuple:
    """CycleModels -> padded arrays for ``postpone_batch``.

    ``n_jobs``/``p_max`` optionally pad the job/period axes beyond the
    fleet's own extent (the surveillance engine buckets both to powers of
    two so the jit cache stays bounded); padding rows have period 0 and
    all-(-1) profiles, which ``postpone_batch`` maps to RemainTime 0.
    """
    p_req = max((m.period for m in models if m.period > 1), default=1)
    p_max = max(p_max or 1, p_req, 1)
    n_jobs = max(n_jobs or len(models), len(models))
    profiles = np.full((n_jobs, p_max), -1, np.int8)
    periods = np.zeros(n_jobs, np.int32)
    for j, m in enumerate(models):
        periods[j] = m.period
        if m.period > 1:
            profiles[j, : m.period] = m.profile_lm
    return jnp.asarray(profiles), jnp.asarray(periods)
