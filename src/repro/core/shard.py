"""Sharded decide plane — row-partitioning surveillance across devices.

Every stage of the surveillance pipeline (NB classify, matmul-DFT spectrum,
autocorrelation refinement, Algorithm 2 postponement) is embarrassingly
parallel per job row: no stage reduces across jobs. That makes the scaling
story trivial to state and strong to test — partitioning the job axis over
a 1-D device mesh with ``shard_map`` produces BIT-IDENTICAL results to the
single-device path, which stays in the tree as the parity reference.

This module owns the mesh plumbing so the engine and the kernels never
repeat it:

  * ``decide_mesh(shards)`` — build the 1-D ``('shard',)`` mesh over the
    first ``shards`` local devices (``None``/``<=1`` -> no mesh, i.e. the
    single-device reference path). On a CPU host, virtual devices come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before jax
    initializes — see ``scripts/verify.sh`` and the fig10 shard cells).
  * ``classify_lm(nb, W, mesh)`` — NB arrays replicated, window rows
    partitioned; the shard_map body is the same jitted
    ``characterize._nb_predict_lm`` the unsharded path runs.
  * ``postpone_rows(profiles, periods, m_now, mesh)`` — Algorithm 2 with
    all three row-aligned operands partitioned. Returns the DEVICE array
    unmaterialized so overlapped ticks can defer the host sync
    (``surveillance.TickResult``).

The kernel stages (spectrum/autocorr) take the mesh directly via
``kernels.ops`` (``cycles.fit_cycle_batch(..., mesh=...)``); padding there
follows the same rows-to-multiple-of-mesh rule as ``_pad_rows`` here.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import characterize
from repro.core import postpone as pp
from repro.kernels import backend as kb


def device_count() -> int:
    """Visible local device count (virtual CPU devices included)."""
    return len(jax.devices())


def decide_mesh(shards: Optional[int] = None):
    """1-D ``('shard',)`` mesh over the first ``shards`` local devices.

    ``None`` or ``<= 1`` returns ``None`` — callers then take the
    single-device reference path unchanged. Asking for more shards than
    visible devices is an error (forcing virtual devices is an env-level
    decision, not something to guess at here).
    """
    if shards is None or shards <= 1:
        return None
    devs = jax.devices()
    if shards > len(devs):
        raise ValueError(
            f"requested {shards} shards but only {len(devs)} devices are "
            "visible; set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{shards} (before jax initializes) to fake them on CPU")
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs[:shards]), ("shard",))


def _pad_rows(x: jnp.ndarray, n: int) -> Tuple[jnp.ndarray, int]:
    """Pad axis 0 to a multiple of ``n``; returns (padded, original_rows).
    Row stages never mix rows, so zero padding cannot perturb real rows."""
    B = x.shape[0]
    B_p = -(-B // n) * n
    if B_p != B:
        x = jnp.pad(x, ((0, B_p - B),) + ((0, 0),) * (x.ndim - 1))
    return x, B


def classify_lm(nb: characterize.NaiveBayes, windows, mesh=None) -> np.ndarray:
    """(J, T, F) windows -> (J, T) int8 LM series, optionally row-sharded.

    ``mesh=None`` is the single-device reference; with a mesh the NB tables
    are replicated and the job rows partitioned. Bit-identical either way —
    NB decisions are per-sample.
    """
    if mesh is None:
        return characterize.classify_lm_batch(nb, windows)
    from jax.sharding import PartitionSpec as P
    axis = mesh.axis_names[0]
    x, J = _pad_rows(jnp.asarray(windows, jnp.float32),
                     int(mesh.devices.size))
    fn = kb.shard_map_compat(
        characterize._nb_predict_lm, mesh,
        in_specs=(P(), P(), P(), P(axis)), out_specs=P(axis))
    return np.asarray(fn(nb.bin_edges, nb.log_likelihood, nb.log_prior,
                         x))[:J]


def postpone_rows(profiles, periods, m_now, mesh=None) -> jnp.ndarray:
    """Algorithm 2 over the packed fleet, optionally row-sharded.

    Returns the device array WITHOUT a host sync: with jax's async
    dispatch the decide of tick t executes while the caller records/
    gathers tick t+1 (``SurveillanceEngine`` materializes lazily).
    Padding rows carry period 0, which Algorithm 2 maps to RemainTime 0
    independent of ``m_now``.
    """
    m_now = jnp.asarray(m_now)
    if mesh is None:
        return pp.postpone_batch_jit(profiles, periods, m_now)
    from jax.sharding import PartitionSpec as P
    axis = mesh.axis_names[0]
    n = int(mesh.devices.size)
    prof, J = _pad_rows(jnp.asarray(profiles), n)
    per, _ = _pad_rows(jnp.asarray(periods), n)
    m, _ = _pad_rows(m_now, n)
    out = kb.shard_map_compat(
        pp.postpone_batch_jit, mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=P(axis))(prof, per, m)
    return out[:J]
