"""Adaptive concurrency controller — closing the loop from probe to policy.

The paper's LMCM decides *when* each migration fires (Algorithm 2 picks the
next LM moment; ``orchestrator.decide`` is that decision point), but it
treats *how many* may fire together as a static provider knob
(``max_concurrent``, later refined by the ``min_share_frac`` share-floor
gate). PR 2/3 measured why that is the wrong shape: at >= 16 concurrent
lanes the shared link — not the moment — becomes the bound, and a fixed
floor can neither exploit an idle fabric nor recognize that two lanes with
near-zero dirty rates share a link for free.

This controller governs the SAME decision boundary as Algorithm 2 —
``LMCM.due``, the moment a scheduled request is released — but along the
orthogonal axis the paper leaves static: at each boundary it sweeps the
candidate in-flight counts (*defer-k* over the ready queue, per migration
domain) and launches the batch that minimizes **predicted total contended
bytes**, tie-broken by predicted summed migration time, then by launching
more. Where Algorithm 2 asks "is this a suitable LM moment for job j?",
the controller asks "how many of the ready lanes should this moment
carry?" — the concurrency/bandwidth co-scheduling that He & Buyya's
taxonomy (2112.02593) and Wang et al.'s SDN planning (1412.4980) identify
as the biggest traffic lever an orchestrator leaves unused.

Inputs (all shipped by PR 3's fabric):

  * ``plane.domain_links()`` / ``plane.what_if_shares(paths)`` — per-domain
    membership and the max-min fair shares a hypothetical launch batch
    would realize against exactly the domains it intersects;
  * ``strunk.what_if_cost_batch`` — the batched pre-copy cost of a whole
    candidate batch at those shares, rates sampled through the same
    ``RateBank`` tables the execution plane uses;
  * ``plane.path_capacity`` — the uncontended bottleneck a deferred lane
    is priced at.

The model, per migration domain (connected component of "shares a link"
over the candidates' paths plus the live domains):

  * launching ``k`` candidates prices each at its what-if fair share from
    ``now`` (forced co-launches — requests past the provider's max-wait
    wall — are included in the share solve and the bill);
  * deferring the rest prices each at its uncontended path capacity from
    ``now + defer_s`` — deliberately optimistic: a deferred lane re-enters
    the sweep at the next boundary, so the estimate is re-judged every
    tick, and the optimism biases toward deferral, the direction that
    minimizes contended bytes (pricing the tail at its predicted *actual*
    start times was tried and measured worse: long serial horizons make
    deferral look phase-risky and push the sweep back toward concurrency);
  * marginal dilution of already-in-flight lanes is NOT billed (their
    remaining cost is mid-round state the what-if cannot cheaply reprice);
    the omission biases toward deferral, which is the safe direction.

Progress guarantees live with the caller: candidates the LMCM cannot defer
past ``max_wait`` bypass the sweep entirely, and an idle domain always
releases its head-of-line candidate (``select`` never returns an empty
batch for a component with nothing in flight), so the controller can be
strictly lazier than the static gate without ever stalling the fabric.

Cost of a decision (the fleet-scale constraint): the sweep is ONE-SOLVE.
The n+1 nested "launch the first k" batches share one (L, M) incidence,
so their fair shares come from a single stacked progressive filling
(``plane.what_if_shares_sweep`` -> ``network.fair_share_masked``), every
prefix is priced in ONE flattened ``strunk.what_if_cost_batch`` call
(rate tables gathered from one ``RateBank`` — ``bank.take`` — instead of
n+1 re-normalizations), and per-k totals are segment sums over the
flattened outcome. Candidate grouping unions paths through
``network.LinkUnionFind`` — near-linear in candidates + live domains
instead of quadratic pairwise set intersections. The pre-refactor per-k
loop is kept verbatim as ``_sweep_reference`` (``sweep="reference"``):
its per-lane pre-copy recurrences and per-k share solves are the
executable spec the stacked path must match — same selected k, same
(bytes, time, -k) score tuple — asserted by tests/test_controlplane.py
over random topologies and by the controlplane_scaling benchmark.

On hierarchical fabrics (``Topology.pod_spine``) the sweep gains the
*route* axis: a (src, dst) pair exposes k candidate routes (one per spine
plane), and the decision becomes **defer-k x route**. A route stage runs
first per multi-route component: every (lane, route) pair is priced as if
launched alone against the in-flight set — all pairs through ONE stacked
``fair_share_masked`` solve over one tall incidence
(``plane.what_if_pair_shares``) and ONE flattened cost batch — and routes
are assigned greedily in queue order, exact score ties de-conflicted
toward less-claimed links (``_assign_routes``). The defer-k stage then
sweeps prefixes over the ASSIGNED paths exactly as on a flat fabric, and
launching requests get their route stamped on ``req.path``. The per-pair
loop is kept verbatim inside ``sweep="reference"`` — identical (k, route)
selections are asserted by tests/test_route_sweep.py.

``horizon=True`` grows the sweep a third axis: **now vs trough**. The
myopic sweep prices deferral at one fixed re-evaluation delay and only
over queue-order prefixes; the receding-horizon sweep (a) prices each
candidate's deferral at its own predicted workload trough (Algorithm 2's
RemainTime through ``trough_of`` — the paper's postponement becomes a
COLUMN of the admission score instead of an upstream verdict), (b) scores
arbitrary candidate subsets — queue-order prefixes plus benefit-order
prefixes, so a disjoint cheap-now candidate cannot starve behind a
cross-rack-glued head — through ``plane.what_if_subset_shares``, and (c)
bills the marginal dilution of already-running lanes by resuming their
mid-round state (``plane.lane_state`` -> ``strunk.ResumeState``) under
each scenario's shares. Deferred candidates carry their trough wake in
``deferred_until`` (the LMCM turns it into a heap re-admission so
event-skip stops there) and their would-be links in claims that seed
route tie de-confliction. Progress is explicit: a candidate OVERTAKEN
``aging_limit`` times (a later-queued candidate launched past it while it
deferred — the one starvation mode subset reordering introduces; plain
queue-order waiting does not age) is promoted to forced.
``horizon=False`` (default) leaves every myopic code path byte-identical
to PR 8.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import network, strunk


def _default_path_of(plane):
    def path_of(req) -> Tuple[str, ...]:
        if getattr(req, "path", None):
            return tuple(req.path)
        return plane.topology.path(req.src, req.dst)
    return path_of


def _default_routes_of(plane):
    """Candidate routes of a request: the topology's per-pair route set.
    A pre-stamped ``req.path`` that IS one of those routes does not pin
    the choice (FleetSim stamps route 0 on every request at submit; the
    sweep may still re-route it), but a custom path outside the route set
    is honored as a fixed single route."""
    def routes_of(req) -> Tuple[Tuple[str, ...], ...]:
        routes = plane.topology.routes(req.src, req.dst)
        stamped = tuple(getattr(req, "path", None) or ())
        if stamped and stamped not in routes:
            return (stamped,)
        return routes
    return routes_of


class AdaptiveConcurrencyController:
    """Defer-k launch selection over the ready queue, per migration domain.

    ``plane`` is a ``fabric.ShardedPlane`` or ``plane.MigrationPlane``
    (both expose ``domain_links`` / ``what_if_shares_sweep`` /
    ``path_capacity``). ``rate_of(req)`` returns the request's dirty-rate
    spec in the lane-registration form of ``core/rates.py`` (a
    ``PiecewiseRate`` table keeps the whole sweep vectorized); ``defer_s``
    is the re-evaluation delay deferred candidates are priced at (the
    LMCM's sampling period).

    ``sweep`` selects the sweep engine: ``"stacked"`` (default) answers
    all n+1 prefixes with one share solve + one flattened cost batch —
    O(one solve) per component per tick; ``"reference"`` is the original
    per-k loop (one share solve + one pre-copy batch PER prefix), kept as
    the executable spec and as the honest baseline the
    ``controlplane_scaling`` benchmark times the stacked path against.
    Both select the same k with the same score tuple.

    ``horizon=True`` switches to the receding-horizon subset sweep (see
    module docstring): per-candidate trough-priced deferral via
    ``trough_of(req, now) -> seconds-until-trough | None``, subset
    selection over queue- and benefit-order prefixes, in-flight lane
    repricing, trough wakes in ``deferred_until`` (consumed by the LMCM's
    deferral push), and a hard no-starvation bound — a candidate overtaken
    ``aging_limit`` times by later-queued launches is promoted to a
    forced launch.
    """

    def __init__(self, plane, *,
                 rate_of: Optional[Callable[[object], object]] = None,
                 path_of: Optional[Callable[[object], Tuple[str, ...]]] = None,
                 routes_of: Optional[Callable[
                     [object], Tuple[Tuple[str, ...], ...]]] = None,
                 defer_s: float = 1.0, sweep: str = "stacked",
                 horizon: bool = False,
                 trough_of: Optional[Callable[
                     [object, float], Optional[float]]] = None,
                 aging_limit: int = 8):
        assert sweep in ("stacked", "reference")
        self.plane = plane
        self.rate_of = rate_of or (lambda req: None)
        self.path_of = path_of or _default_path_of(plane)
        if routes_of is not None:
            self.routes_of = routes_of
        elif path_of is not None:
            # a custom path resolver pins each request to that one path
            self.routes_of = lambda req: (self.path_of(req),)
        else:
            self.routes_of = _default_routes_of(plane)
        self.defer_s = defer_s
        self.sweep = sweep
        self.horizon = bool(horizon)
        self.trough_of = trough_of
        self.aging_limit = int(aging_limit)
        # id(req) -> absolute wake time of the most recent horizon
        # deferral (rebuilt every select; the LMCM consumes it to push
        # trough-timed re-admissions into its heap)
        self.deferred_until: Dict[int, float] = {}
        # id(req) -> (wake, path): links a horizon-deferred candidate is
        # about to take — counted as live by route tie de-confliction
        # until the wake passes or the request launches. Never populated
        # with horizon=False (bit-parity with the PR 8 assignment).
        self._deferred_claims: Dict[int, Tuple[float, Tuple[str, ...]]] = {}

    # -- selection -----------------------------------------------------------
    def select(self, candidates: Sequence, now: float, *,
               forced: Sequence = ()) -> List:
        """The subset of ``candidates`` to launch at ``now``. ``forced``
        are requests launching regardless (max-wait wall); they are not
        returned but their paths contend in every what-if evaluation.

        On multi-route fabrics this is the defer-k x route sweep: the
        route stage assigns every lane in a multi-route component its
        route first (each (lane, route) pair priced against the in-flight
        set in one stacked solve, greedily de-conflicted on ties), then
        the defer-k stage sweeps prefixes over the assigned paths.
        Launching requests (forced + the chosen prefix) get their
        assigned route stamped on ``req.path`` so the execution plane
        rides it; deferred candidates stay unstamped and are re-routed at
        the next boundary. Single-route components skip the route stage —
        flat fabrics behave exactly as before.

        With ``horizon=True`` the per-component decision is the subset
        sweep (``_sweep_subset``): chosen candidates need not be a queue
        prefix, deferred candidates get trough wakes in
        ``deferred_until`` plus link claims for route de-confliction, and
        candidates overtaken ``aging_limit`` times are promoted to forced
        launches before the sweep (no starvation)."""
        aged: List = []
        if self.horizon:
            self._prune_claims(now)
            self.deferred_until = {}
            aged = [r for r in candidates
                    if getattr(r, "defers", 0) >= self.aging_limit]
            if aged:
                aged_ids = {id(r) for r in aged}
                forced = list(forced) + aged
                candidates = [r for r in candidates
                              if id(r) not in aged_ids]
                for r in aged:
                    self._deferred_claims.pop(id(r), None)
        if not candidates:
            return aged
        cand_routes = [self.routes_of(r) for r in candidates]
        forced_routes = [self.routes_of(r) for r in forced]
        cand_links = [tuple(l for p in rs for l in p) for rs in cand_routes]
        forced_links = [tuple(l for p in rs for l in p)
                        for rs in forced_routes]
        chosen: List = list(aged)    # aged launch regardless, so they must
        # be RETURNED (membership in the chosen set is what the LMCM acts
        # on); they also contend as forced lanes in every what-if above
        for idxs, busy, f_idx in self._components(cand_links, forced_links):
            group = [candidates[i] for i in idxs]
            g_routes = [cand_routes[i] for i in idxs]
            g_forced = [forced[i] for i in f_idx]
            g_froutes = [forced_routes[i] for i in f_idx]
            multi = any(len(rs) != 1 for rs in g_froutes + g_routes)
            if not multi:
                g_fpaths = [rs[0] for rs in g_froutes]
                g_paths = [rs[0] for rs in g_routes]
            else:
                g_fpaths, g_paths = self._route_stage(
                    g_forced, g_froutes, group, g_routes, now)
            if self.horizon:
                sel, delays, troughy = self._sweep_subset(
                    group, g_paths, g_forced, g_fpaths, now)
                if not sel and not busy and not g_forced \
                        and not troughy.all():
                    # idle domain with no predicted trough to wait for:
                    # release the head of line (when EVERY candidate has a
                    # trough wake scheduled, waiting IS the decision — the
                    # aging bound and the max-wait wall still guarantee
                    # progress)
                    sel = [0]
            else:
                k = self._best_k(group, g_paths, g_forced, g_fpaths, now)
                if k == 0 and not busy and not g_forced:
                    k = 1    # idle domain: always release the head of line
                sel = list(range(k))
            if multi:        # stamp assigned routes on what launches NOW
                for r, p in zip(g_forced, g_fpaths):
                    r.path = p
                for j in sel:
                    group[j].path = g_paths[j]
            chosen.extend(group[j] for j in sel)
            if self.horizon:
                sel_set = set(sel)
                # aging counts OVERTAKES, not waiting: a deferred candidate
                # ages only when a later-queued candidate launched past it
                # (the starvation mode subset reordering introduces).
                # Queue-order waiting behind a draining head is the myopic
                # schedule, not starvation, and must not trip the bound.
                overtake = max(sel) if sel else -1
                for j, r in enumerate(group):
                    if j in sel_set:
                        self._deferred_claims.pop(id(r), None)
                    else:
                        if j < overtake:
                            r.defers = getattr(r, "defers", 0) + 1
                        wake = now + float(delays[j])
                        self.deferred_until[id(r)] = wake
                        self._deferred_claims[id(r)] = (wake, g_paths[j])
                for r in g_forced:
                    self._deferred_claims.pop(id(r), None)
        return chosen

    def _prune_claims(self, now: float) -> None:
        """Drop deferred-link claims whose wake has passed — the claim
        either re-enters this very select() as a live candidate or the
        request is gone (launched elsewhere, cancelled, expired)."""
        dead = [k for k, (wake, _) in self._deferred_claims.items()
                if wake <= now]
        for k in dead:
            del self._deferred_claims[k]

    # -- the route stage (stage A of defer-k x route) ------------------------
    def _route_stage(self, forced: Sequence,
                     forced_routes: Sequence[Tuple[Tuple[str, ...], ...]],
                     group: Sequence,
                     group_routes: Sequence[Tuple[Tuple[str, ...], ...]],
                     now: float
                     ) -> Tuple[List[Tuple[str, ...]],
                                List[Tuple[str, ...]]]:
        """Assign every lane of a multi-route component its route.

        Each (lane, route) pair is priced as if it launched ALONE against
        everything in flight — pair j's fair share and pre-copy cost, all
        pairs answered by ONE stacked masked solve
        (``plane.what_if_pair_shares``) and ONE flattened cost batch in
        the default engine, or by the per-pair loop under
        ``sweep="reference"`` (the executable spec: same shares, same
        costs, identical assignments). ``_assign_routes`` then picks
        greedily, de-conflicting exact score ties toward less-claimed
        links. Returns (forced paths, candidate paths) in input order."""
        lanes = list(forced) + list(group)
        routes = list(forced_routes) + list(group_routes)
        pair_lane = [i for i, rs in enumerate(routes) for _ in rs]
        pair_paths = [tuple(p) for rs in routes for p in rs]
        v_lane = np.asarray([r.v_bytes for r in lanes], np.float64)
        specs = [self.rate_of(r) for r in lanes]
        if self.sweep == "stacked":
            from repro.core.rates import RateBank
            shares = self.plane.what_if_pair_shares([], pair_paths)
            idx = np.asarray(pair_lane, np.intp)
            bank = RateBank(specs)
            rate_arg = bank.take(idx) if not bank.fallback \
                else [specs[i] for i in pair_lane]
            priced = strunk.what_if_cost_batch(
                v_lane[idx], shares, rate_arg,
                np.full(len(pair_paths), now), full=True)
            p_bytes, p_time = priced.bytes_sent, priced.total_time
        else:
            p_bytes = np.empty(len(pair_paths))
            p_time = np.empty(len(pair_paths))
            for j, (i, p) in enumerate(zip(pair_lane, pair_paths)):
                share = self.plane.what_if_shares([p])
                out = strunk.what_if_cost_batch(
                    v_lane[i:i + 1], share, [specs[i]],
                    np.asarray([now]), full=True)
                p_bytes[j] = out.bytes_sent[0]
                p_time[j] = out.total_time[0]
        assigned = self._assign_routes(routes, p_bytes, p_time)
        n_f = len(forced)
        return assigned[:n_f], assigned[n_f:]

    def _assign_routes(self, routes: Sequence[Tuple[Tuple[str, ...], ...]],
                       p_bytes: np.ndarray, p_time: np.ndarray
                       ) -> List[Tuple[str, ...]]:
        """Greedy deterministic route assignment over the priced pairs:
        lanes in order (forced first, then queue order), each taking its
        (bytes, time)-minimal route; EXACT score ties break toward the
        route whose links carry fewer claimed lanes — in-flight lanes
        plus earlier assignments — then toward the lowest route index
        (= the fixed-shortest path). Shared by both sweep engines, so
        stacked-vs-reference assignment parity reduces to share/cost
        parity of the pair pricing. Horizon-deferred candidates' claimed
        links count as live too: they will take those links at their
        trough wake, so spreading must not collapse onto them (the claim
        dict is empty with ``horizon=False`` — PR 8 bit-parity)."""
        claimed = dict(self.plane.link_live_counts())
        for _wake, p in self._deferred_claims.values():
            for l in dict.fromkeys(p):
                claimed[l] = claimed.get(l, 0) + 1
        assigned: List[Tuple[str, ...]] = []
        j = 0
        for rs in routes:
            best = None
            for m, p in enumerate(rs):
                load = sum(claimed.get(l, 0) for l in p)
                key = (float(p_bytes[j + m]), float(p_time[j + m]), load, m)
                if best is None or key < best[0]:
                    best = (key, p)
            _, p = best
            for l in p:
                claimed[l] = claimed.get(l, 0) + 1
            assigned.append(p)
            j += len(rs)
        return assigned

    # -- grouping ------------------------------------------------------------
    def _components(self, cand_paths: Sequence[Tuple[str, ...]],
                    forced_paths: Sequence[Tuple[str, ...]]
                    ) -> List[Tuple[List[int], bool, List[int]]]:
        """Connected components of "shares a link" over candidate paths,
        forced-launch paths, and the live migration domains, via one
        ``network.LinkUnionFind`` pass — near-linear in paths + domains
        (the old pairwise set-intersection merge was O(n^2) in candidates
        and re-hashed every domain's frozenset each tick). Yields
        (candidate indexes, has-in-flight-lanes, forced indexes) per
        component, ordered by smallest candidate index; path-less
        candidates are unconstrained singletons."""
        uf = network.LinkUnionFind()
        comps: dict = {}                 # root (or singleton tag) -> state

        def entry(root):
            c = comps.get(root)
            if c is None:
                c = comps[root] = ([], False, [])
            return c

        roots = [uf.union_path(p) for p in cand_paths]
        f_roots = [uf.union_path(p) for p in forced_paths]
        d_roots = [uf.union_path(d) for d in self.plane.domain_links()]
        # a second find per path collapses the unions that happened after
        # the path's own union_path call
        for i, r in enumerate(roots):
            if r is None:                # path-less: its own component
                comps[("solo", i)] = ([i], False, [])
            else:
                entry(uf.find(r))[0].append(i)
        for i, r in enumerate(f_roots):
            if r is not None:
                entry(uf.find(r))[2].append(i)
        for r in d_roots:
            if r is not None:
                root = uf.find(r)
                c = entry(root)
                comps[root] = (c[0], True, c[2])
        out = [(idxs, busy, f_idx) for idxs, busy, f_idx in comps.values()
               if idxs]
        return sorted(out, key=lambda c: c[0][0])

    # -- the sweep -----------------------------------------------------------
    def _best_k(self, group: Sequence, paths: Sequence[Tuple[str, ...]],
                forced: Sequence, forced_paths: Sequence[Tuple[str, ...]],
                now: float) -> int:
        """Candidate in-flight count minimizing predicted total contended
        bytes over this component: launch ``group[:k]`` now at what-if
        fair shares (alongside the forced launches), defer ``group[k:]``
        to ``now + defer_s`` at uncontended path capacity. Tie-break:
        summed predicted migration time, then larger k (never defer for
        free). Dispatches to the one-solve stacked sweep (default) or the
        per-k reference loop (``sweep="reference"``)."""
        fn = self._sweep_stacked if self.sweep == "stacked" \
            else self._sweep_reference
        return fn(group, paths, forced, forced_paths, now)[0]

    def _deferred_tails(self, v: np.ndarray, idle_bw: np.ndarray,
                        specs: Sequence, now: float
                        ) -> Tuple[np.ndarray, np.ndarray]:
        # a lane's deferred cost does not depend on k: price every
        # candidate's deferral ONCE, and read "defer the k..n-1 tail" off
        # suffix sums instead of re-simulating it n+1 times
        deferred = strunk.what_if_cost_batch(
            v, idle_bw, specs, np.full(len(v), now + self.defer_s),
            full=True)
        tail_bytes = np.concatenate(
            [np.cumsum(deferred.bytes_sent[::-1])[::-1], [0.0]])
        tail_time = np.concatenate(
            [np.cumsum(deferred.total_time[::-1])[::-1], [0.0]])
        return tail_bytes, tail_time

    def _sweep_inputs(self, group: Sequence, forced: Sequence, now: float):
        v = np.asarray([r.v_bytes for r in group], np.float64)
        specs = [self.rate_of(r) for r in group]
        v_forced = np.asarray([r.v_bytes for r in forced], np.float64)
        specs_forced = [self.rate_of(r) for r in forced]
        idle_bw = np.asarray(
            [self.plane.path_capacity(r.src, r.dst) for r in group])
        tails = self._deferred_tails(v, idle_bw, specs, now)
        return v, specs, v_forced, specs_forced, tails

    def _sweep_stacked(self, group: Sequence,
                       paths: Sequence[Tuple[str, ...]], forced: Sequence,
                       forced_paths: Sequence[Tuple[str, ...]], now: float
                       ) -> Tuple[int, Tuple[float, float, int]]:
        """One-solve sweep: all n+1 prefix batches share ONE stacked
        fair-share solve and ONE flattened pre-copy cost batch.

        Prefixes are nested, so the F+n distinct (lane, start-time) pairs
        repeat across prefixes with only the SHARE varying — the flattened
        batch lays out prefix k's lanes contiguously (forced first, then
        candidates 0..k-1, identical to the reference's per-k layout), the
        rate tables are gathered from one ``RateBank`` over the F+n unique
        specs, and per-k totals are contiguous-slice segment sums —
        bit-identical to the reference's per-k ``.sum()`` calls (same
        values, same lengths, same pairwise order)."""
        from repro.core.rates import RateBank
        n, n_f = len(group), len(forced)
        v, specs, v_forced, specs_forced, (tail_bytes, tail_time) = \
            self._sweep_inputs(group, forced, now)
        # (n+1, F+n) shares: row k = fair shares of forced + group[:k]
        shares = self.plane.what_if_shares_sweep(forced_paths, paths)
        # flattened layout: segment k holds forced + group[:k]
        counts = n_f + np.arange(n + 1)
        seg = np.concatenate([[0], np.cumsum(counts)])
        within = np.arange(int(seg[-1])) - np.repeat(seg[:-1], counts)
        row = np.repeat(np.arange(n + 1), counts)
        v_all = np.concatenate([v_forced, v])
        specs_all = specs_forced + specs
        bank = RateBank(specs_all)
        # un-tabulatable specs (plain callables) take the reference's
        # per-lane compatibility path; tabular banks gather in one go
        rate_arg = bank.take(within) if not bank.fallback \
            else [specs_all[i] for i in within]
        launched = strunk.what_if_cost_batch(
            v_all[within], shares[row, within], rate_arg,
            np.full(len(within), now), full=True)
        best: Optional[Tuple[Tuple[float, float, int], int]] = None
        for k in range(n + 1):
            lo, hi = int(seg[k]), int(seg[k + 1])
            score = (float(launched.bytes_sent[lo:hi].sum()
                           + tail_bytes[k]),
                     float(launched.total_time[lo:hi].sum() + tail_time[k]),
                     -k)
            if best is None or score < best[0]:
                best = (score, k)
        return best[1], best[0]

    def _sweep_reference(self, group: Sequence,
                         paths: Sequence[Tuple[str, ...]], forced: Sequence,
                         forced_paths: Sequence[Tuple[str, ...]], now: float
                         ) -> Tuple[int, Tuple[float, float, int]]:
        """The pre-refactor per-k loop, kept verbatim as the executable
        spec: one fair-share solve and one pre-copy cost batch PER prefix.
        The stacked sweep must select the same k with the same score
        tuple."""
        n, n_f = len(group), len(forced)
        v, specs, v_forced, specs_forced, (tail_bytes, tail_time) = \
            self._sweep_inputs(group, forced, now)
        best: Optional[Tuple[Tuple[float, float, int], int]] = None
        for k in range(n + 1):
            launch_paths = list(forced_paths) + list(paths[:k])
            shares = self.plane.what_if_shares(launch_paths)
            launched = strunk.what_if_cost_batch(
                np.concatenate([v_forced, v[:k]]), shares,
                specs_forced + specs[:k],
                np.full(n_f + k, now), full=True)
            score = (float(launched.bytes_sent.sum() + tail_bytes[k]),
                     float(launched.total_time.sum() + tail_time[k]),
                     -k)
            if best is None or score < best[0]:
                best = (score, k)
        return best[1], best[0]

    # -- the receding-horizon subset sweep (horizon=True) --------------------
    def _score_subsets(self, group: Sequence,
                       paths: Sequence[Tuple[str, ...]], forced: Sequence,
                       forced_paths: Sequence[Tuple[str, ...]], now: float):
        """Score every scenario subset of the receding-horizon sweep over
        one component. Returns ``(subsets, scores, delays, troughy)``:
        the candidate-index subsets evaluated (queue-order prefixes first,
        then benefit-order prefixes, deduped), their (bytes, time, -count)
        scores, the per-candidate deferral delay (seconds until the
        predicted trough, floored at ``defer_s``), and which candidates
        actually have a trough prediction.

        Scenario i's bill = marginal resume cost of every in-flight lane
        of the component (``plane.lane_state`` -> ``strunk.ResumeState``)
        + the forced launches + the selected candidates, all at row i's
        shares from ONE ``what_if_subset_shares`` solve and ONE flattened
        resumable cost batch, + each deferred candidate priced at its own
        trough ``now + delays[j]`` at uncontended capacity. Queue prefixes
        are always among the scenarios, so the winning score can never
        exceed the myopic defer-k ladder's on the same inputs (the
        subset <= prefix property test reads exactly this invariant)."""
        from repro.core.rates import RateBank
        n, n_f = len(group), len(forced)
        v = np.asarray([r.v_bytes for r in group], np.float64)
        specs = [self.rate_of(r) for r in group]
        v_forced = np.asarray([r.v_bytes for r in forced], np.float64)
        specs_forced = [self.rate_of(r) for r in forced]
        idle_bw = np.asarray(
            [self.plane.path_capacity(r.src, r.dst) for r in group])
        delays = np.full(n, float(self.defer_s))
        troughy = np.zeros(n, bool)
        if self.trough_of is not None:
            for j, r in enumerate(group):
                d = self.trough_of(r, now)
                if d is not None and np.isfinite(d):
                    delays[j] = max(float(self.defer_s), float(d))
                    troughy[j] = True
        bank_c = RateBank(specs)
        two = np.concatenate([np.arange(n), np.arange(n)])
        # one batch prices every candidate twice at uncontended capacity:
        # launched alone NOW (the benefit-ordering key) and deferred to
        # its own trough T+delta (the per-candidate deferred tail —
        # deliberately optimistic, same bias as the myopic sweep)
        both = strunk.what_if_cost_batch(
            np.concatenate([v, v]), np.concatenate([idle_bw, idle_bw]),
            bank_c.take(two) if not bank_c.fallback else specs + specs,
            np.concatenate([np.full(n, now), now + delays]), full=True)
        alone_bytes = both.bytes_sent[:n]
        d_bytes = both.bytes_sent[n:]
        d_time = both.total_time[n:]
        # in-flight lanes of this component, aligned 1:1 with the base
        # columns of the subset solve (same link set -> same domains in
        # the same creation order)
        links = {l for p in list(forced_paths) + list(paths) for l in p}
        lanes = self.plane.lane_state(links) if links else []
        n_b = len(lanes)
        orders = [(list(range(n)), range(n + 1))]
        if n > 1:
            # benefit order: most launch-now gain first (ties: queue
            # order). At large n the ladder is strided — the queue ladder
            # stays complete (the subset <= prefix guarantee needs only
            # it), so thinning the benefit rows trades a little selection
            # resolution for half the scenario rows at 64+ candidates.
            gain = alone_bytes - d_bytes
            bo = sorted(range(n), key=lambda j: (gain[j], j))
            orders.append(
                (bo, range(1, n + 1) if n <= 32 else range(1, n, 2)))
        subsets: List[Tuple[int, ...]] = []
        seen = set()
        for o, ks in orders:
            for k in ks:
                s = tuple(sorted(o[:k]))
                if s not in seen:
                    seen.add(s)
                    subsets.append(s)
        masks = np.zeros((len(subsets), n), bool)
        for i, s in enumerate(subsets):
            masks[i, list(s)] = True
        shares = self.plane.what_if_subset_shares(forced_paths, paths,
                                                  masks)
        # flattened (scenario, entry) cost batch over the unified entry
        # axis [in-flight lanes | forced | candidates]: every row carries
        # all base+forced entries plus its mask's candidates (row order
        # within the flat axis is irrelevant — totals are bincount sums)
        k_n = len(subsets)
        n_bf = n_b + n_f
        rows_c, cols_c = np.nonzero(masks)
        flat_entry = np.concatenate(
            [np.tile(np.arange(n_bf, dtype=np.intp), k_n),
             n_bf + cols_c.astype(np.intp)])
        flat_row = np.concatenate(
            [np.repeat(np.arange(k_n, dtype=np.intp), n_bf),
             rows_c.astype(np.intp)])
        v_all = np.concatenate(
            [np.asarray([s.v for s in lanes], np.float64), v_forced, v])
        specs_all = [s.spec for s in lanes] + specs_forced + specs
        zf = np.zeros(n_f + n)
        init = strunk.ResumeState(
            rem=np.concatenate(
                [np.asarray([s.rem for s in lanes], np.float64),
                 v_forced, v]),
            acc=np.concatenate(
                [np.asarray([s.acc for s in lanes], np.float64), zf]),
            sent=np.concatenate(
                [np.asarray([s.sent for s in lanes], np.float64), zf]),
            rounds=np.concatenate(
                [np.asarray([s.rounds for s in lanes], np.int64),
                 np.zeros(n_f + n, np.int64)]),
            stopped=np.concatenate(
                [np.asarray([s.stopped for s in lanes], bool),
                 np.zeros(n_f + n, bool)]),
            reason=np.concatenate(
                [np.asarray([s.reason for s in lanes], np.int64),
                 np.full(n_f + n, strunk.REASON_MAX_ROUNDS, np.int64)])
        ).take(flat_entry)
        bank = RateBank(specs_all)
        rate_arg = bank.take(flat_entry) if not bank.fallback \
            else [specs_all[i] for i in flat_entry]
        priced = strunk.what_if_cost_batch(
            v_all[flat_entry], shares[flat_row, flat_entry], rate_arg,
            np.full(len(flat_entry), now), init=init, full=True)
        row_bytes = np.bincount(flat_row, weights=priced.bytes_sent,
                                minlength=k_n)
        row_time = np.bincount(flat_row, weights=priced.total_time,
                               minlength=k_n)
        tail_b = d_bytes.sum() - masks @ d_bytes
        tail_t = d_time.sum() - masks @ d_time
        scores = [(float(row_bytes[i] + tail_b[i]),
                   float(row_time[i] + tail_t[i]), -len(s))
                  for i, s in enumerate(subsets)]
        return subsets, scores, delays, troughy

    def _sweep_subset(self, group: Sequence,
                      paths: Sequence[Tuple[str, ...]], forced: Sequence,
                      forced_paths: Sequence[Tuple[str, ...]], now: float
                      ) -> Tuple[List[int], np.ndarray, np.ndarray]:
        """Pick the minimal-score scenario subset (ties resolve to the
        earliest-listed subset — queue-order prefixes come first, so an
        exact tie keeps the myopic choice). Returns (sorted candidate
        indexes to launch now, per-candidate deferral delays, trough
        availability mask)."""
        subsets, scores, delays, troughy = self._score_subsets(
            group, paths, forced, forced_paths, now)
        best = min(range(len(subsets)), key=lambda i: scores[i])
        return list(subsets[best]), delays, troughy
