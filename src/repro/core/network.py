"""Migration-fabric network model — topology, domains, and max-min sharing.

The paper's testbed moves every live migration over one dedicated 1 Gbit/s
migration network (§6.1); its central claim is that *simultaneous*
migrations congest that network and degrade applications (§1, Tables 6-7).
He & Buyya's taxonomy (arXiv:2112.02593) and Wang et al.'s SDN migration
planning (arXiv:1412.4980) both single out bandwidth sharing among
concurrent migrations as the first-order effect an orchestrator must model
— and both argue the model must be topology-aware once the fleet outgrows
a single flat link. This module provides that model:

  * ``Topology`` — hosts mapped to the *access* links their migration
    traffic traverses, plus optional *shared* links (a core uplink) that
    are crossed only when a transfer leaves its access domain.  Factories:
    ``single_link`` (the paper's shared migration network), ``star``
    (per-host access links + core), ``multi_rack`` (per-rack access links
    + core — the sharded-fabric substrate).
  * ``fair_share`` — max-min fair bandwidth allocation across concurrent
    transfers via progressive filling (water-filling): repeatedly find the
    most-contended link, freeze every flow crossing it at that link's equal
    share, and redistribute the slack to the remaining flows.
    ``fair_share_dense`` is the same algorithm over a precomputed link x
    lane incidence matrix — the migration plane's per-event hot path.
    ``fair_share_masked`` batches K *scenarios* (lane subsets of one
    incidence) through one stacked filling — the adaptive controller's
    defer-k prefix sweep solves all n+1 "launch the first k" batches in a
    single call.
  * ``LinkUnionFind`` — path-compressed, size-balanced union-find over
    link ids with a per-root link-membership set. Migration domains are
    connected components of "shares a link"; the fabric and the adaptive
    controller both key them by link through this structure, so a
    launch/merge is O(alpha) instead of a scan over every live domain
    (or, in the controller's old grouping, O(n^2) pairwise set
    intersections).

Migration domains: two transfers interact iff their paths share a link.
Because shared (core) links are only on *cross-domain* paths, transfers
confined to disjoint access links form independent domains — the sharded
execution fabric (``core/fabric.py``) advances each domain's event loop
separately, and a domain's trajectory is bit-equal to running it alone.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, \
    Set, Tuple, Union

import numpy as np


@dataclass(frozen=True)
class Link:
    link_id: str
    capacity: float                     # bytes/s


class Topology:
    """Host -> migration-link mapping with per-link capacities.

    ``host_links`` maps each host to its access links; ``shared_links``
    (e.g. a core uplink) are traversed only when source and destination
    have *different* access links — intra-domain transfers never touch
    the core.  Hosts absent from ``host_links`` fall back to
    ``default_path`` (for the common "one shared migration network" model
    this means every migration, tagged or not, contends on the same link).

    ``path(src, dst)`` returns the tuple of link ids a migration from
    ``src`` to ``dst`` traverses; the plane charges the transfer against
    every link on the path.
    """

    def __init__(self, links: Sequence[Link],
                 host_links: Dict[str, Tuple[str, ...]] | None = None,
                 default_path: Tuple[str, ...] = (),
                 shared_links: Tuple[str, ...] = ()):
        self.links: Dict[str, Link] = {l.link_id: l for l in links}
        self.host_links = dict(host_links or {})
        self.default_path = tuple(default_path)
        self.shared_links = tuple(shared_links)
        for h, ls in self.host_links.items():
            for l in ls:
                if l not in self.links:
                    raise KeyError(f"host {h!r} references unknown link {l!r}")
        for l in self.shared_links:
            if l not in self.links:
                raise KeyError(f"unknown shared link {l!r}")

    @property
    def capacities(self) -> Dict[str, float]:
        return {i: l.capacity for i, l in self.links.items()}

    def set_capacity(self, link_id: str, capacity: float) -> None:
        """Mutate one link's capacity in place (fault injection: a
        degraded or failed link keeps its identity — paths and domain
        membership are unchanged — but fair shares recompute against the
        new value; 0.0 freezes the link's flows at share 0). Live planes
        snapshot ``capacities`` at construction, so callers push the
        change through ``MigrationPlane.set_link_capacity`` /
        ``ShardedPlane.set_link_capacity``, which route here."""
        old = self.links[link_id]          # KeyError on unknown links
        self.links[link_id] = Link(old.link_id, float(capacity))

    def access_of(self, host: str) -> Tuple[str, ...]:
        """The host's access links — its migration-domain signature."""
        return tuple(l for l in self.host_links.get(host, self.default_path)
                     if l not in self.shared_links)

    def path(self, src: str, dst: str) -> Tuple[str, ...]:
        """Links traversed by a src->dst migration (order-stable dedup).
        Shared links are included only when the endpoints live in
        different access domains."""
        a_src, a_dst = self.access_of(src), self.access_of(dst)
        out: List[str] = []
        seq = (a_src + (self.shared_links if a_src != a_dst else ())
               + a_dst)
        for l in seq:
            if l not in out:
                out.append(l)
        if not out:
            out = list(self.default_path)
        return tuple(out)

    # -- factories -----------------------------------------------------------
    @classmethod
    def single_link(cls, capacity: float,
                    link_id: str = "migration-net") -> "Topology":
        """The paper's testbed: one shared migration network for everyone."""
        return cls([Link(link_id, capacity)], default_path=(link_id,))

    @classmethod
    def star(cls, hosts: Sequence[str], access_capacity: float,
             core_capacity: float | None = None) -> "Topology":
        """Per-host access links, optionally through a shared core link.
        Cross-host transfers traverse src access -> core -> dst access;
        same-host transfers stay on the host's access link."""
        links = [Link(f"acc:{h}", access_capacity) for h in hosts]
        host_links = {h: (f"acc:{h}",) for h in hosts}
        shared: Tuple[str, ...] = ()
        if core_capacity is not None:
            links.append(Link("core", core_capacity))
            shared = ("core",)
        return cls(links, host_links, shared_links=shared)

    @classmethod
    def multi_rack(cls, racks: Union[int, Mapping[str, Sequence[str]]],
                   access_capacity: float,
                   core_capacity: float | None = None, *,
                   hosts_per_rack: int = 4) -> "Topology":
        """Rack-level access (ToR) links plus an optional shared core —
        the sharded-fabric substrate. ``racks`` is either a mapping
        ``{rack_id: [host, ...]}`` or an int (auto-named ``r{i}h{j}``).
        Intra-rack migrations contend only on their rack link; cross-rack
        migrations additionally cross the core."""
        if isinstance(racks, int):
            racks = {f"r{i}": [f"r{i}h{j}" for j in range(hosts_per_rack)]
                     for i in range(racks)}
        links = [Link(f"acc:{r}", access_capacity) for r in racks]
        host_links = {h: (f"acc:{r}",)
                      for r, hs in racks.items() for h in hs}
        shared: Tuple[str, ...] = ()
        if core_capacity is not None:
            links.append(Link("core", core_capacity))
            shared = ("core",)
        return cls(links, host_links, shared_links=shared)


def fair_share(paths: Sequence[Sequence[str]],
               capacities: Dict[str, float]) -> np.ndarray:
    """Max-min fair rates (bytes/s) for concurrent flows over shared links.

    Progressive filling: every flow's rate grows uniformly until some link
    saturates; flows crossing the saturated link freeze at that share, the
    rest keep growing on the slack. A flow with an empty path is
    unconstrained and gets ``inf`` (the caller decides what that means).
    """
    n = len(paths)
    rates = np.zeros(n)
    frozen = np.zeros(n, bool)
    members: Dict[str, List[int]] = {}
    for i, p in enumerate(paths):
        for l in dict.fromkeys(p):          # dedup, keep order
            members.setdefault(l, []).append(i)
    while True:
        bottleneck = None
        for l, idxs in members.items():
            live = [i for i in idxs if not frozen[i]]
            if not live:
                continue
            rem = capacities[l] - float(rates[idxs].sum())
            share = max(rem, 0.0) / len(live)
            if bottleneck is None or share < bottleneck[0]:
                bottleneck = (share, l)
        if bottleneck is None:
            break
        share, l = bottleneck
        for i in members[l]:
            if not frozen[i]:
                rates[i] = share
                frozen[i] = True
    rates[~frozen] = np.inf                 # flows crossing no link
    return rates


class DenseFairShare:
    """Reusable max-min fair-share solver over a fixed (L, M) incidence.

    The same progressive-filling algorithm as ``fair_share`` — identical
    bottleneck selection order (first minimum in link order); per-link
    sums run over the dense lane axis, so results can differ from the
    sparse version by float summation order (ULPs) only when three or
    more flows tie. All scratch arrays are preallocated and every step is
    an in-place ufunc or a matmul into a buffer: this sits on the
    migration plane's per-event hot path, where numpy dispatch and
    temporaries dominate at fleet lane counts. The returned rates array
    is a reused buffer — callers consume it before the next call. Lanes
    crossing no link get ``inf``.
    """

    def __init__(self, incidence: np.ndarray, capacities: np.ndarray):
        self.inc = np.ascontiguousarray(incidence, np.float64)
        self.caps = np.asarray(capacities, np.float64)
        n_links, m = self.inc.shape
        self.rates = np.empty(m)
        self._live = np.empty(m)           # 1.0 while unfrozen
        self._unfrozen = np.empty(m, bool)
        self._mask = np.empty(m, bool)
        self._n_live = np.empty(n_links)
        self._used = np.empty(n_links)
        self._share = np.empty(n_links)
        self._empty = np.empty(n_links, bool)
        self._occupied = np.empty(n_links, bool)

    def __call__(self) -> np.ndarray:
        inc, caps, rates, live = self.inc, self.caps, self.rates, self._live
        if inc.shape[0] == 0:           # no links at all: every lane is
            rates.fill(np.inf)          # unconstrained (the caller's
            return rates                # fallback bandwidth applies)
        rates.fill(0.0)
        live.fill(1.0)
        while True:
            np.matmul(inc, live, out=self._n_live)
            np.matmul(inc, rates, out=self._used)
            np.subtract(caps, self._used, out=self._share)
            np.maximum(self._share, 0.0, out=self._share)
            np.less_equal(self._n_live, 0.0, out=self._empty)
            np.logical_not(self._empty, out=self._occupied)
            np.divide(self._share, self._n_live, out=self._share,
                      where=self._occupied)
            np.copyto(self._share, np.inf, where=self._empty)
            l = int(np.argmin(self._share))
            s = float(self._share[l])
            if not np.isfinite(s):
                break
            np.greater(live, 0.0, out=self._unfrozen)
            np.greater(inc[l], 0.0, out=self._mask)
            np.logical_and(self._mask, self._unfrozen, out=self._mask)
            np.copyto(rates, s, where=self._mask)
            np.copyto(live, 0.0, where=self._mask)
        np.greater(live, 0.0, out=self._unfrozen)
        np.copyto(rates, np.inf, where=self._unfrozen)
        return rates


def fair_share_dense(incidence: np.ndarray, capacities: np.ndarray
                     ) -> np.ndarray:
    """One-shot ``DenseFairShare`` (tests / callers without a cached
    incidence); the plane holds a solver instance instead."""
    return DenseFairShare(incidence, capacities)().copy()


def fair_share_masked(incidence: np.ndarray, capacities: np.ndarray,
                      active: np.ndarray) -> np.ndarray:
    """Max-min fair shares for K lane subsets of ONE (L, M) incidence.

    ``active`` is a (K, M) bool mask: row k is an independent progressive-
    filling scenario over the lanes it selects (the other columns are
    absent — zero demand, zero membership). Returns (K, M) rates: inactive
    lanes get 0, active lanes crossing no link get ``inf``.

    This is the stacked solver behind the defer-k prefix sweep: the n+1
    nested "launch the first k candidates" batches differ only in their
    active mask, so every per-scenario quantity — per-link live-lane
    counts, committed bandwidth, the candidate share — is one (K, L) ufunc
    or matmul, and each iteration freezes at least one link per open
    scenario (<= L+1 iterations total, vs K full solves).

    Per scenario the arithmetic is per-link-local, exactly as in
    ``DenseFairShare``: a link's remaining capacity and live count involve
    only its member lanes, so the values a scenario's lanes freeze at do
    not depend on which other scenarios (or which disjoint sub-components)
    share the call.
    """
    inc = np.ascontiguousarray(incidence, np.float64)
    caps = np.asarray(capacities, np.float64)
    active = np.asarray(active, bool)
    k_n, m = active.shape
    n_links = inc.shape[0]
    rates = np.zeros((k_n, m))
    if n_links == 0:                     # no links: every active lane is
        rates[active] = np.inf           # unconstrained
        return rates
    live = active.astype(np.float64)
    inc_t = np.ascontiguousarray(inc.T)              # (M, L)
    n_live = np.empty((k_n, n_links))
    used = np.empty((k_n, n_links))
    share = np.empty((k_n, n_links))
    occupied = np.empty((k_n, n_links), bool)
    mask = np.empty((k_n, m), bool)
    rows = np.arange(k_n)
    while True:
        np.matmul(live, inc_t, out=n_live)
        np.matmul(rates, inc_t, out=used)
        np.subtract(caps, used, out=share)
        np.maximum(share, 0.0, out=share)
        np.greater(n_live, 0.0, out=occupied)
        np.divide(share, n_live, out=share, where=occupied)
        np.copyto(share, np.inf, where=~occupied)
        l_star = np.argmin(share, axis=1)            # (K,) per-scenario
        s = share[rows, l_star]                      # bottleneck share
        open_k = np.isfinite(s)
        if not open_k.any():
            break
        # freeze each open scenario's bottleneck members at its share
        np.greater(inc[l_star], 0.0, out=mask)       # gather rows: (K, M)
        np.logical_and(mask, live > 0.0, out=mask)
        np.logical_and(mask, open_k[:, None], out=mask)
        np.copyto(rates, s[:, None], where=mask)
        np.copyto(live, 0.0, where=mask)
    rates[live > 0.0] = np.inf           # active lanes crossing no link
    return rates


def build_incidence(paths: Sequence[Sequence[str]],
                    capacities: Dict[str, float]
                    ) -> Tuple[np.ndarray, np.ndarray, List[str],
                               Dict[str, int]]:
    """First-appearance link order, (L, M) 0/1 incidence, capacity
    vector, and link->row map for ``paths`` — the ONE construction behind
    both the migration plane's cached banks and the stacked prefix sweep
    (their bit-parity depends on sharing the same dedup/ordering)."""
    order = list(dict.fromkeys(l for p in paths for l in p))
    row = {l: i for i, l in enumerate(order)}
    inc = np.zeros((len(order), len(paths)))
    for j, p in enumerate(paths):
        for l in dict.fromkeys(p):
            inc[row[l], j] = 1.0
    return inc, np.asarray([capacities[l] for l in order]), order, row


def what_if_prefix_shares(base_paths: Sequence[Sequence[str]],
                          fixed_paths: Sequence[Sequence[str]],
                          cand_paths: Sequence[Sequence[str]],
                          capacities: Dict[str, float],
                          fallback_bw: float) -> np.ndarray:
    """Fair shares of all n+1 nested defer-k launch batches in one solve.

    Row k of the returned (n+1, F+n) array holds the max-min shares the F
    ``fixed_paths`` lanes plus the first k ``cand_paths`` lanes would
    realize against the ``base_paths`` lanes already in flight — i.e. the
    answers of n+1 ``fair_share(base + fixed + cand[:k])`` calls, read
    from ONE (L, M) incidence with one ``fair_share_masked`` invocation.
    Active lanes crossing no link get ``fallback_bw``; columns past F+k
    are inactive in row k and read 0.
    """
    paths = [tuple(p) for p in base_paths] + [tuple(p) for p in fixed_paths]
    cand = [tuple(p) for p in cand_paths]
    n_base_fixed, n = len(paths), len(cand)
    paths += cand
    inc, caps_vec, _, _ = build_incidence(paths, capacities)
    active = np.zeros((n + 1, len(paths)), bool)
    active[:, :n_base_fixed] = True
    # row k launches candidates 0..k-1
    active[:, n_base_fixed:] = np.tril(np.ones((n + 1, n), bool), -1)
    shares = fair_share_masked(inc, caps_vec, active)[:, len(base_paths):]
    return np.where(np.isfinite(shares), shares, fallback_bw)


class LinkUnionFind:
    """Path-compressed, size-balanced union-find over link ids, with a
    per-root membership set (the links of each component).

    Migration domains are connected components of the "shares a link"
    relation; keying them by link makes domain lookup/merge O(alpha):
    ``ShardedPlane`` resolves a launch path to its domains with one
    ``find`` per link (instead of scanning every live domain's link set)
    and the adaptive controller's candidate grouping unions paths in
    near-linear time (instead of quadratic pairwise set intersections).
    Components can be deleted wholesale (``pop_component``) — the fabric
    dissolves a domain when its lanes drain.
    """

    __slots__ = ("_parent", "_size", "_links")

    def __init__(self) -> None:
        self._parent: Dict[str, str] = {}
        self._size: Dict[str, int] = {}
        self._links: Dict[str, Set[str]] = {}

    def add(self, link: str) -> str:
        """Register ``link`` as a singleton component (no-op if present);
        returns its root."""
        if link not in self._parent:
            self._parent[link] = link
            self._size[link] = 1
            self._links[link] = {link}
            return link
        return self.find(link)

    def find(self, link: str) -> Optional[str]:
        """Root of ``link``'s component (None if unregistered), with
        path compression."""
        parent = self._parent
        root = parent.get(link)
        if root is None:
            return None
        while parent[root] != root:
            root = parent[root]
        while parent[link] != root:      # compress
            parent[link], link = root, parent[link]
        return root

    def union(self, a: str, b: str) -> str:
        """Join the components of ``a`` and ``b`` (registering either as
        needed); returns the merged root. Size-balanced: the smaller
        root's membership set folds into the larger's."""
        ra, rb = self.add(a), self.add(b)
        if ra == rb:
            return ra
        if self._size[ra] < self._size[rb]:
            ra, rb = rb, ra
        self._parent[rb] = ra
        self._size[ra] += self._size.pop(rb)
        self._links[ra] |= self._links.pop(rb)
        return ra

    def union_path(self, path: Iterable[str]) -> Optional[str]:
        """Union every link of ``path`` into one component; returns its
        root (None for an empty path)."""
        root: Optional[str] = None
        for l in path:
            root = self.add(l) if root is None else self.union(root, l)
        return root

    def pop_component(self, link: str) -> Set[str]:
        """Delete ``link``'s entire component (a drained domain's links
        revert to unregistered); returns the removed membership set."""
        root = self.find(link)
        if root is None:
            return set()
        links = self._links.pop(root)
        for l in links:
            del self._parent[l]
        del self._size[root]
        return links
