"""Migration-fabric network model — topology, domains, and max-min sharing.

The paper's testbed moves every live migration over one dedicated 1 Gbit/s
migration network (§6.1); its central claim is that *simultaneous*
migrations congest that network and degrade applications (§1, Tables 6-7).
He & Buyya's taxonomy (arXiv:2112.02593) and Wang et al.'s SDN migration
planning (arXiv:1412.4980) both single out bandwidth sharing among
concurrent migrations as the first-order effect an orchestrator must model
— and both argue the model must be topology-aware once the fleet outgrows
a single flat link. This module provides that model:

  * ``Topology`` — hosts mapped to the *access* links their migration
    traffic traverses, plus optional *shared* links (a core uplink) that
    are crossed only when a transfer leaves its access domain.  Factories:
    ``single_link`` (the paper's shared migration network), ``star``
    (per-host access links + core), ``multi_rack`` (per-rack access links
    + core — the sharded-fabric substrate), and ``pod_spine`` — the
    3-tier hierarchical fabric::

        spine tier      spine:s0          spine:s1       (one link per
                        /      \\          /      \\        spine plane)
        pod tier   pod:p0s0 pod:p1s0  pod:p0s1 pod:p1s1  (per-pod uplink
                        |        |        |        |      per plane)
        access    acc:p0r0 acc:p0r1  acc:p1r0 acc:p1r1   (ToR per rack)
                    |   |    |   |     |   |    |   |
        hosts     p0r0h*  p0r1h*    p1r0h*   p1r1h*

    Cross-pod traffic picks ONE spine plane m and traverses
    ``acc -> pod:p_src s_m -> spine:s_m -> pod:p_dst s_m -> acc``;
    intra-pod cross-rack traffic crosses one pod uplink; intra-rack
    traffic only its ToR. Every (src, dst) pair therefore exposes
    ``n_spines`` *candidate routes* (``Topology.routes``) — the route
    axis the admission controller sweeps — with ``path()`` pinned to
    route 0 (the fixed-shortest-path baseline). Per-tier
    oversubscription shrinks pod uplinks and spines relative to the
    access capacity below them.
  * ``fair_share`` — max-min fair bandwidth allocation across concurrent
    transfers via progressive filling (water-filling): repeatedly find the
    most-contended link, freeze every flow crossing it at that link's equal
    share, and redistribute the slack to the remaining flows.
    ``fair_share_dense`` is the same algorithm over a precomputed link x
    lane incidence matrix — the migration plane's per-event hot path.
    ``fair_share_masked`` batches K *scenarios* (lane subsets of one
    incidence) through one stacked filling — the adaptive controller's
    defer-k prefix sweep solves all n+1 "launch the first k" batches in a
    single call.
  * ``LinkUnionFind`` — path-compressed, size-balanced union-find over
    link ids with a per-root link-membership set. Migration domains are
    connected components of "shares a link"; the fabric and the adaptive
    controller both key them by link through this structure, so a
    launch/merge is O(alpha) instead of a scan over every live domain
    (or, in the controller's old grouping, O(n^2) pairwise set
    intersections).

Migration domains: two transfers interact iff their paths share a link.
Because shared (core) links are only on *cross-domain* paths, transfers
confined to disjoint access links form independent domains — the sharded
execution fabric (``core/fabric.py``) advances each domain's event loop
separately, and a domain's trajectory is bit-equal to running it alone.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, \
    Set, Tuple, Union

import numpy as np


@dataclass(frozen=True)
class Link:
    link_id: str
    capacity: float                     # bytes/s


class Topology:
    """Host -> migration-link mapping with per-link capacities.

    ``host_links`` maps each host to its access links; ``shared_links``
    (e.g. a core uplink) are traversed only when source and destination
    have *different* access links — intra-domain transfers never touch
    the core.  Hosts absent from ``host_links`` fall back to
    ``default_path`` (for the common "one shared migration network" model
    this means every migration, tagged or not, contends on the same link).

    ``path(src, dst)`` returns the tuple of link ids a migration from
    ``src`` to ``dst`` traverses; the plane charges the transfer against
    every link on the path.
    """

    def __init__(self, links: Sequence[Link],
                 host_links: Dict[str, Tuple[str, ...]] | None = None,
                 default_path: Tuple[str, ...] = (),
                 shared_links: Tuple[str, ...] = (),
                 route_map: Mapping[Tuple[Tuple[str, ...], Tuple[str, ...]],
                                   Sequence[Sequence[str]]] | None = None,
                 link_tiers: Mapping[str, int] | None = None,
                 pods: Mapping[str, str] | None = None):
        self.links: Dict[str, Link] = {l.link_id: l for l in links}
        self.host_links = dict(host_links or {})
        self.default_path = tuple(default_path)
        self.shared_links = tuple(shared_links)
        # (src_access_sig, dst_access_sig) -> candidate routes; route 0 is
        # the canonical fixed-shortest path that ``path()`` returns.
        self.route_map: Dict[Tuple[Tuple[str, ...], Tuple[str, ...]],
                             Tuple[Tuple[str, ...], ...]] = {
            (tuple(ks), tuple(kd)): tuple(tuple(p) for p in v)
            for (ks, kd), v in (route_map or {}).items()}
        self.link_tiers = dict(link_tiers or {})   # link -> 0 acc/1 pod/2 spine
        self._pods = dict(pods or {})              # host -> pod id
        for h, ls in self.host_links.items():
            for l in ls:
                if l not in self.links:
                    raise KeyError(f"host {h!r} references unknown link {l!r}")
        for l in self.shared_links:
            if l not in self.links:
                raise KeyError(f"unknown shared link {l!r}")
        for key, routes in self.route_map.items():
            if not routes:
                raise ValueError(f"route_map entry {key!r} has no routes")
            for p in routes:
                for l in p:
                    if l not in self.links:
                        raise KeyError(
                            f"route_map entry {key!r} references unknown "
                            f"link {l!r}")
        for l in self.link_tiers:
            if l not in self.links:
                raise KeyError(f"link_tiers references unknown link {l!r}")
        # Precomputed lookup tables (the dict walks stay as the parity
        # oracle; hot callers go through integer link ids).
        self.link_ids: Dict[str, int] = {
            l: i for i, l in enumerate(self.links)}
        self._caps_vec = np.asarray(
            [l.capacity for l in self.links.values()], np.float64)
        self._access_cache: Dict[str, Tuple[str, ...]] = {}
        self._routes_cache: Dict[Tuple[str, str],
                                 Tuple[Tuple[str, ...], ...]] = {}
        self._ids_cache: Dict[Tuple[str, ...], Optional[np.ndarray]] = {}

    @property
    def capacities(self) -> Dict[str, float]:
        return {i: l.capacity for i, l in self.links.items()}

    def set_capacity(self, link_id: str, capacity: float) -> None:
        """Mutate one link's capacity in place (fault injection: a
        degraded or failed link keeps its identity — paths and domain
        membership are unchanged — but fair shares recompute against the
        new value; 0.0 freezes the link's flows at share 0). Live planes
        snapshot ``capacities`` at construction, so callers push the
        change through ``MigrationPlane.set_link_capacity`` /
        ``ShardedPlane.set_link_capacity``, which route here."""
        old = self.links[link_id]          # KeyError on unknown links
        self.links[link_id] = Link(old.link_id, float(capacity))
        self._caps_vec[self.link_ids[link_id]] = float(capacity)

    def access_of(self, host: str) -> Tuple[str, ...]:
        """The host's access links — its migration-domain signature."""
        hit = self._access_cache.get(host)
        if hit is None:
            hit = tuple(l for l in self.host_links.get(host,
                                                       self.default_path)
                        if l not in self.shared_links)
            self._access_cache[host] = hit
        return hit

    def path(self, src: str, dst: str) -> Tuple[str, ...]:
        """Links traversed by a src->dst migration (order-stable dedup).
        Shared links are included only when the endpoints live in
        different access domains. On routed topologies this is route 0 of
        ``routes(src, dst)`` — the fixed-shortest-path baseline."""
        a_src, a_dst = self.access_of(src), self.access_of(dst)
        routed = self.route_map.get((a_src, a_dst))
        if routed is not None:
            return routed[0]
        out: List[str] = []
        seq = (a_src + (self.shared_links if a_src != a_dst else ())
               + a_dst)
        for l in seq:
            if l not in out:
                out.append(l)
        if not out:
            out = list(self.default_path)
        return tuple(out)

    def routes(self, src: str, dst: str) -> Tuple[Tuple[str, ...], ...]:
        """All candidate routes for a src->dst migration. Route 0 is the
        canonical ``path()``; unrouted pairs expose exactly one route."""
        key = (src, dst)
        hit = self._routes_cache.get(key)
        if hit is None:
            a_src, a_dst = self.access_of(src), self.access_of(dst)
            hit = self.route_map.get((a_src, a_dst))
            if hit is None:
                hit = (self.path(src, dst),)
            self._routes_cache[key] = hit
        return hit

    def n_routes(self) -> int:
        """Maximum candidate-route count over all pairs (1 when flat)."""
        return max((len(r) for r in self.route_map.values()), default=1)

    def pod_of(self, host: str) -> Optional[str]:
        """Pod id of ``host`` (None on non-hierarchical topologies)."""
        return self._pods.get(host)

    def tier_of(self, link: str) -> int:
        """Fabric tier of ``link``: 0 access/ToR, 1 pod, 2 spine.
        Links without an explicit tier are access."""
        return self.link_tiers.get(link, 0)

    # -- precomputed link-id tables (hot-path mirrors of the dict walks) --
    def caps_vector(self) -> np.ndarray:
        """Capacity per ``link_ids`` index, kept in sync by
        ``set_capacity``. The returned array is live — callers that
        snapshot capacities must copy."""
        return self._caps_vec

    def ids_of(self, path: Sequence[str]) -> Optional[np.ndarray]:
        """``path`` as an integer link-index array (cached), or None when
        any link is unknown — the caller falls back to the dict walk."""
        key = tuple(path)
        hit = self._ids_cache.get(key, False)
        if hit is False:
            try:
                hit = np.asarray([self.link_ids[l] for l in key], np.intp)
            except KeyError:
                hit = None
            self._ids_cache[key] = hit
        return hit

    def path_ids(self, src: str, dst: str) -> Optional[np.ndarray]:
        """Precomputed link-index array of ``path(src, dst)``."""
        return self.ids_of(self.path(src, dst))

    def route_ids(self, src: str, dst: str
                  ) -> Tuple[Optional[np.ndarray], ...]:
        """Per-route link-index arrays of ``routes(src, dst)``."""
        return tuple(self.ids_of(p) for p in self.routes(src, dst))

    # -- factories -----------------------------------------------------------
    @classmethod
    def single_link(cls, capacity: float,
                    link_id: str = "migration-net") -> "Topology":
        """The paper's testbed: one shared migration network for everyone."""
        return cls([Link(link_id, capacity)], default_path=(link_id,))

    @classmethod
    def star(cls, hosts: Sequence[str], access_capacity: float,
             core_capacity: float | None = None) -> "Topology":
        """Per-host access links, optionally through a shared core link.
        Cross-host transfers traverse src access -> core -> dst access;
        same-host transfers stay on the host's access link."""
        links = [Link(f"acc:{h}", access_capacity) for h in hosts]
        host_links = {h: (f"acc:{h}",) for h in hosts}
        shared: Tuple[str, ...] = ()
        if core_capacity is not None:
            links.append(Link("core", core_capacity))
            shared = ("core",)
        return cls(links, host_links, shared_links=shared)

    @classmethod
    def multi_rack(cls, racks: Union[int, Mapping[str, Sequence[str]]],
                   access_capacity: float,
                   core_capacity: float | None = None, *,
                   hosts_per_rack: int = 4) -> "Topology":
        """Rack-level access (ToR) links plus an optional shared core —
        the sharded-fabric substrate. ``racks`` is either a mapping
        ``{rack_id: [host, ...]}`` or an int (auto-named ``r{i}h{j}``).
        Intra-rack migrations contend only on their rack link; cross-rack
        migrations additionally cross the core."""
        if isinstance(racks, int):
            racks = {f"r{i}": [f"r{i}h{j}" for j in range(hosts_per_rack)]
                     for i in range(racks)}
        links = [Link(f"acc:{r}", access_capacity) for r in racks]
        host_links = {h: (f"acc:{r}",)
                      for r, hs in racks.items() for h in hs}
        shared: Tuple[str, ...] = ()
        if core_capacity is not None:
            links.append(Link("core", core_capacity))
            shared = ("core",)
        return cls(links, host_links, shared_links=shared)

    @classmethod
    def pod_spine(cls, pods: int, racks_per_pod: int,
                  hosts_per_rack: int = 2, *,
                  access_capacity: float,
                  pod_oversubscription: float = 1.0,
                  spine_oversubscription: float = 1.0,
                  n_spines: int = 2) -> "Topology":
        """3-tier access -> pod -> spine fabric with per-tier
        oversubscription and multi-path routing (module docstring diagram).

        Hosts ``p{i}r{j}h{k}`` hang off per-rack ToR links
        ``acc:p{i}r{j}`` at ``access_capacity``. Each pod owns one uplink
        per spine plane, ``pod:p{i}s{m}``; a pod's aggregate uplink
        capacity is ``racks_per_pod * access / pod_oversubscription``,
        split evenly across the planes. Each plane's spine link
        ``spine:s{m}`` carries ``pods * uplink / spine_oversubscription``.
        1:1 oversubscription is non-blocking at each tier boundary; 1:4
        means the tier above admits a quarter of the capacity below it.

        Every distinct-rack (src, dst) pair exposes ``n_spines`` candidate
        routes — route m rides plane m end to end (intra-pod: ToR ->
        pod uplink m -> ToR; cross-pod: additionally spine m and the
        destination pod's plane-m uplink). Same-rack pairs have the single
        ToR route. ``path()`` pins route 0 (the fixed-shortest-path
        baseline the route-aware controller is benchmarked against).
        """
        if pods < 1 or racks_per_pod < 1 or n_spines < 1:
            raise ValueError("pods, racks_per_pod, n_spines must be >= 1")
        uplink = racks_per_pod * access_capacity / (
            pod_oversubscription * n_spines)
        spine_cap = pods * uplink / spine_oversubscription
        links = []
        host_links: Dict[str, Tuple[str, ...]] = {}
        tiers: Dict[str, int] = {}
        pod_map: Dict[str, str] = {}
        rack_of: Dict[Tuple[int, int], str] = {}
        for i in range(pods):
            for j in range(racks_per_pod):
                acc = f"acc:p{i}r{j}"
                links.append(Link(acc, access_capacity))
                tiers[acc] = 0
                rack_of[(i, j)] = acc
                for k in range(hosts_per_rack):
                    h = f"p{i}r{j}h{k}"
                    host_links[h] = (acc,)
                    pod_map[h] = f"p{i}"
        for i in range(pods):
            for m in range(n_spines):
                up = f"pod:p{i}s{m}"
                links.append(Link(up, uplink))
                tiers[up] = 1
        for m in range(n_spines):
            sp = f"spine:s{m}"
            links.append(Link(sp, spine_cap))
            tiers[sp] = 2
        route_map: Dict[Tuple[Tuple[str, ...], Tuple[str, ...]],
                        Tuple[Tuple[str, ...], ...]] = {}
        for (pi, ri), a_src in rack_of.items():
            for (pj, rj), a_dst in rack_of.items():
                if a_src == a_dst:
                    continue
                if pi == pj:               # intra-pod, cross-rack
                    routes = tuple(
                        (a_src, f"pod:p{pi}s{m}", a_dst)
                        for m in range(n_spines))
                else:                      # cross-pod: one plane end to end
                    routes = tuple(
                        (a_src, f"pod:p{pi}s{m}", f"spine:s{m}",
                         f"pod:p{pj}s{m}", a_dst)
                        for m in range(n_spines))
                route_map[((a_src,), (a_dst,))] = routes
        return cls(links, host_links, route_map=route_map,
                   link_tiers=tiers, pods=pod_map)


def fair_share(paths: Sequence[Sequence[str]],
               capacities: Dict[str, float]) -> np.ndarray:
    """Max-min fair rates (bytes/s) for concurrent flows over shared links.

    Progressive filling: every flow's rate grows uniformly until some link
    saturates; flows crossing the saturated link freeze at that share, the
    rest keep growing on the slack. A flow with an empty path is
    unconstrained and gets ``inf`` (the caller decides what that means).
    """
    n = len(paths)
    rates = np.zeros(n)
    frozen = np.zeros(n, bool)
    members: Dict[str, List[int]] = {}
    for i, p in enumerate(paths):
        for l in dict.fromkeys(p):          # dedup, keep order
            members.setdefault(l, []).append(i)
    while True:
        bottleneck = None
        for l, idxs in members.items():
            live = [i for i in idxs if not frozen[i]]
            if not live:
                continue
            rem = capacities[l] - float(rates[idxs].sum())
            share = max(rem, 0.0) / len(live)
            if bottleneck is None or share < bottleneck[0]:
                bottleneck = (share, l)
        if bottleneck is None:
            break
        share, l = bottleneck
        for i in members[l]:
            if not frozen[i]:
                rates[i] = share
                frozen[i] = True
    rates[~frozen] = np.inf                 # flows crossing no link
    return rates


def fair_share_ids(path_ids: Sequence[Optional[np.ndarray]],
                   caps_vec: np.ndarray) -> np.ndarray:
    """``fair_share`` over precomputed integer link-index arrays
    (``Topology.ids_of``) instead of link-name tuples.

    Same progressive filling, same member insertion order, same
    summation (``rates[idxs].sum()`` over the identical index lists) —
    bit-parity with the dict oracle is by construction, and the planes'
    probe hot paths skip the per-call name hashing and path dict walks.
    A lane whose ids are ``None`` (or empty) is unconstrained -> ``inf``.
    """
    n = len(path_ids)
    rates = np.zeros(n)
    frozen = np.zeros(n, bool)
    members: Dict[int, List[int]] = {}
    for i, p in enumerate(path_ids):
        if p is None:
            continue
        for l in dict.fromkeys(int(x) for x in p):
            members.setdefault(l, []).append(i)
    while True:
        bottleneck = None
        for l, idxs in members.items():
            live = [i for i in idxs if not frozen[i]]
            if not live:
                continue
            rem = float(caps_vec[l]) - float(rates[idxs].sum())
            share = max(rem, 0.0) / len(live)
            if bottleneck is None or share < bottleneck[0]:
                bottleneck = (share, l)
        if bottleneck is None:
            break
        share, l = bottleneck
        for i in members[l]:
            if not frozen[i]:
                rates[i] = share
                frozen[i] = True
    rates[~frozen] = np.inf
    return rates


class DenseFairShare:
    """Reusable max-min fair-share solver over a fixed (L, M) incidence.

    The same progressive-filling algorithm as ``fair_share`` — identical
    bottleneck selection order (first minimum in link order); per-link
    sums run over the dense lane axis, so results can differ from the
    sparse version by float summation order (ULPs) only when three or
    more flows tie. All scratch arrays are preallocated and every step is
    an in-place ufunc or a matmul into a buffer: this sits on the
    migration plane's per-event hot path, where numpy dispatch and
    temporaries dominate at fleet lane counts. The returned rates array
    is a reused buffer — callers consume it before the next call. Lanes
    crossing no link get ``inf``.
    """

    def __init__(self, incidence: np.ndarray, capacities: np.ndarray):
        self.inc = np.ascontiguousarray(incidence, np.float64)
        self.caps = np.asarray(capacities, np.float64)
        n_links, m = self.inc.shape
        self.rates = np.empty(m)
        self._live = np.empty(m)           # 1.0 while unfrozen
        self._unfrozen = np.empty(m, bool)
        self._mask = np.empty(m, bool)
        self._n_live = np.empty(n_links)
        self._used = np.empty(n_links)
        self._share = np.empty(n_links)
        self._empty = np.empty(n_links, bool)
        self._occupied = np.empty(n_links, bool)

    def __call__(self) -> np.ndarray:
        inc, caps, rates, live = self.inc, self.caps, self.rates, self._live
        if inc.shape[0] == 0:           # no links at all: every lane is
            rates.fill(np.inf)          # unconstrained (the caller's
            return rates                # fallback bandwidth applies)
        rates.fill(0.0)
        live.fill(1.0)
        while True:
            np.matmul(inc, live, out=self._n_live)
            np.matmul(inc, rates, out=self._used)
            np.subtract(caps, self._used, out=self._share)
            np.maximum(self._share, 0.0, out=self._share)
            np.less_equal(self._n_live, 0.0, out=self._empty)
            np.logical_not(self._empty, out=self._occupied)
            np.divide(self._share, self._n_live, out=self._share,
                      where=self._occupied)
            np.copyto(self._share, np.inf, where=self._empty)
            l = int(np.argmin(self._share))
            s = float(self._share[l])
            if not np.isfinite(s):
                break
            np.greater(live, 0.0, out=self._unfrozen)
            np.greater(inc[l], 0.0, out=self._mask)
            np.logical_and(self._mask, self._unfrozen, out=self._mask)
            np.copyto(rates, s, where=self._mask)
            np.copyto(live, 0.0, where=self._mask)
        np.greater(live, 0.0, out=self._unfrozen)
        np.copyto(rates, np.inf, where=self._unfrozen)
        return rates


def fair_share_dense(incidence: np.ndarray, capacities: np.ndarray
                     ) -> np.ndarray:
    """One-shot ``DenseFairShare`` (tests / callers without a cached
    incidence); the plane holds a solver instance instead."""
    return DenseFairShare(incidence, capacities)().copy()


# Auto-switch ``fair_share_masked`` to the CSR-style path once the dense
# (K, M) x (M, L) matmuls touch this many cells per round. High enough
# that every flat-fabric test/benchmark stays on the dense path
# bit-unchanged; tall 3-tier sweeps (pods x racks x spines links, many
# (lane, route) columns) cross it.
_SPARSE_CELLS = 1 << 18


def _fair_share_masked_sparse(inc: np.ndarray, caps: np.ndarray,
                              active: np.ndarray) -> np.ndarray:
    """CSR-style ``fair_share_masked``: per-link member-column index
    arrays replace the dense matmuls, so each filling round touches only
    the columns that actually cross a link — the win once the incidence
    is tall and sparse (a 3-tier fabric's lanes each cross <= 5 of
    hundreds of links). Same per-scenario arithmetic and first-minimum
    bottleneck order as the dense path; results can differ from dense by
    float summation order (ULPs) only, and match the python
    ``fair_share`` summation exactly when a scenario's active columns are
    a prefix (per-link sums run over ascending member columns)."""
    k_n, m = active.shape
    n_links = inc.shape[0]
    cols = [np.flatnonzero(inc[l] > 0.0) for l in range(n_links)]
    rates = np.zeros((k_n, m))
    live = active.astype(np.float64)
    n_live = np.empty((k_n, n_links))
    share = np.empty((k_n, n_links))
    occupied = np.empty((k_n, n_links), bool)
    rows = np.arange(k_n)
    while True:
        for l in range(n_links):
            c = cols[l]
            n_live[:, l] = live[:, c].sum(axis=1)
            share[:, l] = caps[l] - rates[:, c].sum(axis=1)
        np.maximum(share, 0.0, out=share)
        np.greater(n_live, 0.0, out=occupied)
        np.divide(share, n_live, out=share, where=occupied)
        np.copyto(share, np.inf, where=~occupied)
        l_star = np.argmin(share, axis=1)
        s = share[rows, l_star]
        open_k = np.isfinite(s)
        if not open_k.any():
            break
        for k in np.flatnonzero(open_k):
            c = cols[l_star[k]]
            sel = c[live[k, c] > 0.0]
            rates[k, sel] = s[k]
            live[k, sel] = 0.0
    rates[live > 0.0] = np.inf
    return rates


def fair_share_masked(incidence: np.ndarray, capacities: np.ndarray,
                      active: np.ndarray, *,
                      sparse: Optional[bool] = None) -> np.ndarray:
    """Max-min fair shares for K lane subsets of ONE (L, M) incidence.

    ``active`` is a (K, M) bool mask: row k is an independent progressive-
    filling scenario over the lanes it selects (the other columns are
    absent — zero demand, zero membership). Returns (K, M) rates: inactive
    lanes get 0, active lanes crossing no link get ``inf``.

    This is the stacked solver behind the defer-k prefix sweep: the n+1
    nested "launch the first k candidates" batches differ only in their
    active mask, so every per-scenario quantity — per-link live-lane
    counts, committed bandwidth, the candidate share — is one (K, L) ufunc
    or matmul, and each iteration freezes at least one link per open
    scenario (<= L+1 iterations total, vs K full solves).

    Per scenario the arithmetic is per-link-local, exactly as in
    ``DenseFairShare``: a link's remaining capacity and live count involve
    only its member lanes, so the values a scenario's lanes freeze at do
    not depend on which other scenarios (or which disjoint sub-components)
    share the call.

    ``sparse`` switches to the CSR-style per-link member-array path
    (``None`` auto-picks it once the dense matmuls would sweep
    ``_SPARSE_CELLS`` incidence cells per round — tall 3-tier fabrics;
    flat fabrics keep the dense path bit-unchanged).
    """
    inc = np.ascontiguousarray(incidence, np.float64)
    caps = np.asarray(capacities, np.float64)
    active = np.asarray(active, bool)
    k_n, m = active.shape
    n_links = inc.shape[0]
    rates = np.zeros((k_n, m))
    if n_links == 0:                     # no links: every active lane is
        rates[active] = np.inf           # unconstrained
        return rates
    if sparse is None:
        sparse = n_links >= 32 and k_n * m >= _SPARSE_CELLS
    if sparse:
        return _fair_share_masked_sparse(inc, caps, active)
    live = active.astype(np.float64)
    inc_t = np.ascontiguousarray(inc.T)              # (M, L)
    n_live = np.empty((k_n, n_links))
    used = np.empty((k_n, n_links))
    share = np.empty((k_n, n_links))
    occupied = np.empty((k_n, n_links), bool)
    mask = np.empty((k_n, m), bool)
    rows = np.arange(k_n)
    while True:
        np.matmul(live, inc_t, out=n_live)
        np.matmul(rates, inc_t, out=used)
        np.subtract(caps, used, out=share)
        np.maximum(share, 0.0, out=share)
        np.greater(n_live, 0.0, out=occupied)
        np.divide(share, n_live, out=share, where=occupied)
        np.copyto(share, np.inf, where=~occupied)
        l_star = np.argmin(share, axis=1)            # (K,) per-scenario
        s = share[rows, l_star]                      # bottleneck share
        open_k = np.isfinite(s)
        if not open_k.any():
            break
        # freeze each open scenario's bottleneck members at its share
        np.greater(inc[l_star], 0.0, out=mask)       # gather rows: (K, M)
        np.logical_and(mask, live > 0.0, out=mask)
        np.logical_and(mask, open_k[:, None], out=mask)
        np.copyto(rates, s[:, None], where=mask)
        np.copyto(live, 0.0, where=mask)
    rates[live > 0.0] = np.inf           # active lanes crossing no link
    return rates


def build_incidence(paths: Sequence[Sequence[str]],
                    capacities: Dict[str, float]
                    ) -> Tuple[np.ndarray, np.ndarray, List[str],
                               Dict[str, int]]:
    """First-appearance link order, (L, M) 0/1 incidence, capacity
    vector, and link->row map for ``paths`` — the ONE construction behind
    both the migration plane's cached banks and the stacked prefix sweep
    (their bit-parity depends on sharing the same dedup/ordering)."""
    order = list(dict.fromkeys(l for p in paths for l in p))
    row = {l: i for i, l in enumerate(order)}
    inc = np.zeros((len(order), len(paths)))
    for j, p in enumerate(paths):
        for l in dict.fromkeys(p):
            inc[row[l], j] = 1.0
    return inc, np.asarray([capacities[l] for l in order]), order, row


def what_if_prefix_shares(base_paths: Sequence[Sequence[str]],
                          fixed_paths: Sequence[Sequence[str]],
                          cand_paths: Sequence[Sequence[str]],
                          capacities: Dict[str, float],
                          fallback_bw: float) -> np.ndarray:
    """Fair shares of all n+1 nested defer-k launch batches in one solve.

    Row k of the returned (n+1, F+n) array holds the max-min shares the F
    ``fixed_paths`` lanes plus the first k ``cand_paths`` lanes would
    realize against the ``base_paths`` lanes already in flight — i.e. the
    answers of n+1 ``fair_share(base + fixed + cand[:k])`` calls, read
    from ONE (L, M) incidence with one ``fair_share_masked`` invocation.
    Active lanes crossing no link get ``fallback_bw``; columns past F+k
    are inactive in row k and read 0.
    """
    paths = [tuple(p) for p in base_paths] + [tuple(p) for p in fixed_paths]
    cand = [tuple(p) for p in cand_paths]
    n_base_fixed, n = len(paths), len(cand)
    paths += cand
    inc, caps_vec, _, _ = build_incidence(paths, capacities)
    active = np.zeros((n + 1, len(paths)), bool)
    active[:, :n_base_fixed] = True
    # row k launches candidates 0..k-1
    active[:, n_base_fixed:] = np.tril(np.ones((n + 1, n), bool), -1)
    shares = fair_share_masked(inc, caps_vec, active)[:, len(base_paths):]
    return np.where(np.isfinite(shares), shares, fallback_bw)


def what_if_subset_shares(base_paths: Sequence[Sequence[str]],
                          fixed_paths: Sequence[Sequence[str]],
                          cand_paths: Sequence[Sequence[str]],
                          masks, capacities: Dict[str, float],
                          fallback_bw: float) -> np.ndarray:
    """Fair shares of K arbitrary candidate subsets in one stacked solve,
    base columns INCLUDED.

    Row k of the returned (K, B + F + n) array holds the max-min shares of
    every ``base_paths`` lane (already in flight), every ``fixed_paths``
    lane, and the ``cand_paths`` lanes selected by ``masks[k]`` — i.e. the
    answer of ``fair_share(base + fixed + [cand[j] for j in masks[k]])``,
    K scenarios over ONE (L, M) incidence. The receding-horizon admission
    sweep needs both generalizations over ``what_if_prefix_shares``: the
    kept base columns let it reprice mid-flight lanes under each
    hypothetical admission, and arbitrary masks price non-prefix subsets
    (queue-order AND benefit-order prefixes in one call). Active lanes
    crossing no link get ``fallback_bw``; inactive columns read 0.
    """
    masks = np.asarray(masks, bool)
    k_n, n = masks.shape
    paths = ([tuple(p) for p in base_paths]
             + [tuple(p) for p in fixed_paths]
             + [tuple(p) for p in cand_paths])
    if len(cand_paths) != n:
        raise ValueError(f"{n}-wide masks for {len(cand_paths)} candidates")
    n_bf = len(base_paths) + len(fixed_paths)
    if not paths:
        return np.zeros((k_n, 0))
    inc, caps_vec, _, _ = build_incidence(paths, capacities)
    active = np.concatenate([np.ones((k_n, n_bf), bool), masks], axis=1)
    shares = fair_share_masked(inc, caps_vec, active)
    return np.where(np.isfinite(shares), shares, fallback_bw)


def pair_active_mask(n_base: int, n_fixed: int, n_pairs: int) -> np.ndarray:
    """The (n_pairs, n_base + n_fixed + n_pairs) scenario mask of the
    route sweep: row j activates every base/fixed lane plus exactly pair
    column j — one (candidate, route) hypothesis per scenario, so each
    route is priced against the in-flight set without seeing its
    siblings. Exposed so tests can assert one-route-per-lane validity."""
    n_bf = n_base + n_fixed
    active = np.zeros((n_pairs, n_bf + n_pairs), bool)
    active[:, :n_bf] = True
    active[:, n_bf:] = np.eye(n_pairs, dtype=bool)
    return active


def what_if_pair_shares(base_paths: Sequence[Sequence[str]],
                        fixed_paths: Sequence[Sequence[str]],
                        pair_paths: Sequence[Sequence[str]],
                        capacities: Dict[str, float],
                        fallback_bw: float) -> np.ndarray:
    """Fair share each (candidate, route) pair would realize on its own
    against the in-flight + forced lanes — all P pairs in ONE solve.

    ``pair_paths`` flattens the (candidate, route) axis: entry j is one
    candidate lane routed one particular way. Scenario j solves
    ``fair_share(base + fixed + [pair_paths[j]])`` — the same per-pair
    sparse call the reference route sweep makes — but all P scenarios
    share one (L, M) incidence and one ``fair_share_masked`` stacked
    filling (mask from ``pair_active_mask``). Returns the (P,) diagonal:
    pair j's share in scenario j, ``fallback_bw`` where unconstrained.
    """
    n_pairs = len(pair_paths)
    if n_pairs == 0:
        return np.zeros(0)
    paths = ([tuple(p) for p in base_paths]
             + [tuple(p) for p in fixed_paths]
             + [tuple(p) for p in pair_paths])
    n_bf = len(base_paths) + len(fixed_paths)
    inc, caps_vec, _, _ = build_incidence(paths, capacities)
    active = pair_active_mask(len(base_paths), len(fixed_paths), n_pairs)
    shares = fair_share_masked(inc, caps_vec, active)
    diag = shares[np.arange(n_pairs), n_bf + np.arange(n_pairs)]
    return np.where(np.isfinite(diag), diag, fallback_bw)


class LinkUnionFind:
    """Path-compressed, size-balanced union-find over link ids, with a
    per-root membership set (the links of each component).

    Migration domains are connected components of the "shares a link"
    relation; keying them by link makes domain lookup/merge O(alpha):
    ``ShardedPlane`` resolves a launch path to its domains with one
    ``find`` per link (instead of scanning every live domain's link set)
    and the adaptive controller's candidate grouping unions paths in
    near-linear time (instead of quadratic pairwise set intersections).
    Components can be deleted wholesale (``pop_component``) — the fabric
    dissolves a domain when its lanes drain.
    """

    __slots__ = ("_parent", "_size", "_links")

    def __init__(self) -> None:
        self._parent: Dict[str, str] = {}
        self._size: Dict[str, int] = {}
        self._links: Dict[str, Set[str]] = {}

    def add(self, link: str) -> str:
        """Register ``link`` as a singleton component (no-op if present);
        returns its root."""
        if link not in self._parent:
            self._parent[link] = link
            self._size[link] = 1
            self._links[link] = {link}
            return link
        return self.find(link)

    def find(self, link: str) -> Optional[str]:
        """Root of ``link``'s component (None if unregistered), with
        path compression."""
        parent = self._parent
        root = parent.get(link)
        if root is None:
            return None
        while parent[root] != root:
            root = parent[root]
        while parent[link] != root:      # compress
            parent[link], link = root, parent[link]
        return root

    def union(self, a: str, b: str) -> str:
        """Join the components of ``a`` and ``b`` (registering either as
        needed); returns the merged root. Size-balanced: the smaller
        root's membership set folds into the larger's."""
        ra, rb = self.add(a), self.add(b)
        if ra == rb:
            return ra
        if self._size[ra] < self._size[rb]:
            ra, rb = rb, ra
        self._parent[rb] = ra
        self._size[ra] += self._size.pop(rb)
        self._links[ra] |= self._links.pop(rb)
        return ra

    def union_path(self, path: Iterable[str]) -> Optional[str]:
        """Union every link of ``path`` into one component; returns its
        root (None for an empty path)."""
        root: Optional[str] = None
        for l in path:
            root = self.add(l) if root is None else self.union(root, l)
        return root

    def pop_component(self, link: str) -> Set[str]:
        """Delete ``link``'s entire component (a drained domain's links
        revert to unregistered); returns the removed membership set."""
        root = self.find(link)
        if root is None:
            return set()
        links = self._links.pop(root)
        for l in links:
            del self._parent[l]
        del self._size[root]
        return links
