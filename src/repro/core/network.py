"""Migration-fabric network model — topology, domains, and max-min sharing.

The paper's testbed moves every live migration over one dedicated 1 Gbit/s
migration network (§6.1); its central claim is that *simultaneous*
migrations congest that network and degrade applications (§1, Tables 6-7).
He & Buyya's taxonomy (arXiv:2112.02593) and Wang et al.'s SDN migration
planning (arXiv:1412.4980) both single out bandwidth sharing among
concurrent migrations as the first-order effect an orchestrator must model
— and both argue the model must be topology-aware once the fleet outgrows
a single flat link. This module provides that model:

  * ``Topology`` — hosts mapped to the *access* links their migration
    traffic traverses, plus optional *shared* links (a core uplink) that
    are crossed only when a transfer leaves its access domain.  Factories:
    ``single_link`` (the paper's shared migration network), ``star``
    (per-host access links + core), ``multi_rack`` (per-rack access links
    + core — the sharded-fabric substrate).
  * ``fair_share`` — max-min fair bandwidth allocation across concurrent
    transfers via progressive filling (water-filling): repeatedly find the
    most-contended link, freeze every flow crossing it at that link's equal
    share, and redistribute the slack to the remaining flows.
    ``fair_share_dense`` is the same algorithm over a precomputed link x
    lane incidence matrix — the migration plane's per-event hot path.

Migration domains: two transfers interact iff their paths share a link.
Because shared (core) links are only on *cross-domain* paths, transfers
confined to disjoint access links form independent domains — the sharded
execution fabric (``core/fabric.py``) advances each domain's event loop
separately, and a domain's trajectory is bit-equal to running it alone.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Sequence, Tuple, Union

import numpy as np


@dataclass(frozen=True)
class Link:
    link_id: str
    capacity: float                     # bytes/s


class Topology:
    """Host -> migration-link mapping with per-link capacities.

    ``host_links`` maps each host to its access links; ``shared_links``
    (e.g. a core uplink) are traversed only when source and destination
    have *different* access links — intra-domain transfers never touch
    the core.  Hosts absent from ``host_links`` fall back to
    ``default_path`` (for the common "one shared migration network" model
    this means every migration, tagged or not, contends on the same link).

    ``path(src, dst)`` returns the tuple of link ids a migration from
    ``src`` to ``dst`` traverses; the plane charges the transfer against
    every link on the path.
    """

    def __init__(self, links: Sequence[Link],
                 host_links: Dict[str, Tuple[str, ...]] | None = None,
                 default_path: Tuple[str, ...] = (),
                 shared_links: Tuple[str, ...] = ()):
        self.links: Dict[str, Link] = {l.link_id: l for l in links}
        self.host_links = dict(host_links or {})
        self.default_path = tuple(default_path)
        self.shared_links = tuple(shared_links)
        for h, ls in self.host_links.items():
            for l in ls:
                if l not in self.links:
                    raise KeyError(f"host {h!r} references unknown link {l!r}")
        for l in self.shared_links:
            if l not in self.links:
                raise KeyError(f"unknown shared link {l!r}")

    @property
    def capacities(self) -> Dict[str, float]:
        return {i: l.capacity for i, l in self.links.items()}

    def access_of(self, host: str) -> Tuple[str, ...]:
        """The host's access links — its migration-domain signature."""
        return tuple(l for l in self.host_links.get(host, self.default_path)
                     if l not in self.shared_links)

    def path(self, src: str, dst: str) -> Tuple[str, ...]:
        """Links traversed by a src->dst migration (order-stable dedup).
        Shared links are included only when the endpoints live in
        different access domains."""
        a_src, a_dst = self.access_of(src), self.access_of(dst)
        out: List[str] = []
        seq = (a_src + (self.shared_links if a_src != a_dst else ())
               + a_dst)
        for l in seq:
            if l not in out:
                out.append(l)
        if not out:
            out = list(self.default_path)
        return tuple(out)

    # -- factories -----------------------------------------------------------
    @classmethod
    def single_link(cls, capacity: float,
                    link_id: str = "migration-net") -> "Topology":
        """The paper's testbed: one shared migration network for everyone."""
        return cls([Link(link_id, capacity)], default_path=(link_id,))

    @classmethod
    def star(cls, hosts: Sequence[str], access_capacity: float,
             core_capacity: float | None = None) -> "Topology":
        """Per-host access links, optionally through a shared core link.
        Cross-host transfers traverse src access -> core -> dst access;
        same-host transfers stay on the host's access link."""
        links = [Link(f"acc:{h}", access_capacity) for h in hosts]
        host_links = {h: (f"acc:{h}",) for h in hosts}
        shared: Tuple[str, ...] = ()
        if core_capacity is not None:
            links.append(Link("core", core_capacity))
            shared = ("core",)
        return cls(links, host_links, shared_links=shared)

    @classmethod
    def multi_rack(cls, racks: Union[int, Mapping[str, Sequence[str]]],
                   access_capacity: float,
                   core_capacity: float | None = None, *,
                   hosts_per_rack: int = 4) -> "Topology":
        """Rack-level access (ToR) links plus an optional shared core —
        the sharded-fabric substrate. ``racks`` is either a mapping
        ``{rack_id: [host, ...]}`` or an int (auto-named ``r{i}h{j}``).
        Intra-rack migrations contend only on their rack link; cross-rack
        migrations additionally cross the core."""
        if isinstance(racks, int):
            racks = {f"r{i}": [f"r{i}h{j}" for j in range(hosts_per_rack)]
                     for i in range(racks)}
        links = [Link(f"acc:{r}", access_capacity) for r in racks]
        host_links = {h: (f"acc:{r}",)
                      for r, hs in racks.items() for h in hs}
        shared: Tuple[str, ...] = ()
        if core_capacity is not None:
            links.append(Link("core", core_capacity))
            shared = ("core",)
        return cls(links, host_links, shared_links=shared)


def fair_share(paths: Sequence[Sequence[str]],
               capacities: Dict[str, float]) -> np.ndarray:
    """Max-min fair rates (bytes/s) for concurrent flows over shared links.

    Progressive filling: every flow's rate grows uniformly until some link
    saturates; flows crossing the saturated link freeze at that share, the
    rest keep growing on the slack. A flow with an empty path is
    unconstrained and gets ``inf`` (the caller decides what that means).
    """
    n = len(paths)
    rates = np.zeros(n)
    frozen = np.zeros(n, bool)
    members: Dict[str, List[int]] = {}
    for i, p in enumerate(paths):
        for l in dict.fromkeys(p):          # dedup, keep order
            members.setdefault(l, []).append(i)
    while True:
        bottleneck = None
        for l, idxs in members.items():
            live = [i for i in idxs if not frozen[i]]
            if not live:
                continue
            rem = capacities[l] - float(rates[idxs].sum())
            share = max(rem, 0.0) / len(live)
            if bottleneck is None or share < bottleneck[0]:
                bottleneck = (share, l)
        if bottleneck is None:
            break
        share, l = bottleneck
        for i in members[l]:
            if not frozen[i]:
                rates[i] = share
                frozen[i] = True
    rates[~frozen] = np.inf                 # flows crossing no link
    return rates


class DenseFairShare:
    """Reusable max-min fair-share solver over a fixed (L, M) incidence.

    The same progressive-filling algorithm as ``fair_share`` — identical
    bottleneck selection order (first minimum in link order); per-link
    sums run over the dense lane axis, so results can differ from the
    sparse version by float summation order (ULPs) only when three or
    more flows tie. All scratch arrays are preallocated and every step is
    an in-place ufunc or a matmul into a buffer: this sits on the
    migration plane's per-event hot path, where numpy dispatch and
    temporaries dominate at fleet lane counts. The returned rates array
    is a reused buffer — callers consume it before the next call. Lanes
    crossing no link get ``inf``.
    """

    def __init__(self, incidence: np.ndarray, capacities: np.ndarray):
        self.inc = np.ascontiguousarray(incidence, np.float64)
        self.caps = np.asarray(capacities, np.float64)
        n_links, m = self.inc.shape
        self.rates = np.empty(m)
        self._live = np.empty(m)           # 1.0 while unfrozen
        self._unfrozen = np.empty(m, bool)
        self._mask = np.empty(m, bool)
        self._n_live = np.empty(n_links)
        self._used = np.empty(n_links)
        self._share = np.empty(n_links)
        self._empty = np.empty(n_links, bool)
        self._occupied = np.empty(n_links, bool)

    def __call__(self) -> np.ndarray:
        inc, caps, rates, live = self.inc, self.caps, self.rates, self._live
        if inc.shape[0] == 0:           # no links at all: every lane is
            rates.fill(np.inf)          # unconstrained (the caller's
            return rates                # fallback bandwidth applies)
        rates.fill(0.0)
        live.fill(1.0)
        while True:
            np.matmul(inc, live, out=self._n_live)
            np.matmul(inc, rates, out=self._used)
            np.subtract(caps, self._used, out=self._share)
            np.maximum(self._share, 0.0, out=self._share)
            np.less_equal(self._n_live, 0.0, out=self._empty)
            np.logical_not(self._empty, out=self._occupied)
            np.divide(self._share, self._n_live, out=self._share,
                      where=self._occupied)
            np.copyto(self._share, np.inf, where=self._empty)
            l = int(np.argmin(self._share))
            s = float(self._share[l])
            if not np.isfinite(s):
                break
            np.greater(live, 0.0, out=self._unfrozen)
            np.greater(inc[l], 0.0, out=self._mask)
            np.logical_and(self._mask, self._unfrozen, out=self._mask)
            np.copyto(rates, s, where=self._mask)
            np.copyto(live, 0.0, where=self._mask)
        np.greater(live, 0.0, out=self._unfrozen)
        np.copyto(rates, np.inf, where=self._unfrozen)
        return rates


def fair_share_dense(incidence: np.ndarray, capacities: np.ndarray
                     ) -> np.ndarray:
    """One-shot ``DenseFairShare`` (tests / callers without a cached
    incidence); the plane holds a solver instance instead."""
    return DenseFairShare(incidence, capacities)().copy()
