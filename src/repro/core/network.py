"""Shared-link migration network model — the contention side of the plane.

The paper's testbed moves every live migration over one dedicated 1 Gbit/s
migration network (§6.1); its central claim is that *simultaneous*
migrations congest that network and degrade applications (§1, Tables 6-7).
He & Buyya's taxonomy (arXiv:2112.02593) and Wang et al.'s SDN migration
planning (arXiv:1412.4980) both single out bandwidth sharing among
concurrent migrations as the first-order effect an orchestrator must model.
This module provides that model:

  * ``Topology`` — hosts mapped to the links their migration traffic
    traverses (a shared migration network, per-host access links, or a
    star with a core uplink), each link with a fixed capacity in bytes/s.
  * ``fair_share`` — max-min fair bandwidth allocation across concurrent
    transfers via progressive filling (water-filling): repeatedly find the
    most-contended link, freeze every flow crossing it at that link's equal
    share, and redistribute the slack to the remaining flows.

The migration plane (``core/plane.py``) re-runs ``fair_share`` at every
round boundary of every in-flight migration, so a migration's bandwidth is
a function of what else is moving — exactly the coupling the seed's
fire-and-forget executor ignored (every migration ran at full link speed
no matter how many were in flight).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Link:
    link_id: str
    capacity: float                     # bytes/s


class Topology:
    """Host -> migration-link mapping with per-link capacities.

    ``path(src, dst)`` returns the tuple of link ids a migration from
    ``src`` to ``dst`` traverses; the plane charges the transfer against
    every link on the path. Hosts absent from ``host_links`` fall back to
    ``default_path`` (for the common "one shared migration network" model
    this means every migration, tagged or not, contends on the same link).
    """

    def __init__(self, links: Sequence[Link],
                 host_links: Dict[str, Tuple[str, ...]] | None = None,
                 default_path: Tuple[str, ...] = ()):
        self.links: Dict[str, Link] = {l.link_id: l for l in links}
        self.host_links = dict(host_links or {})
        self.default_path = tuple(default_path)
        for h, ls in self.host_links.items():
            for l in ls:
                if l not in self.links:
                    raise KeyError(f"host {h!r} references unknown link {l!r}")

    @property
    def capacities(self) -> Dict[str, float]:
        return {i: l.capacity for i, l in self.links.items()}

    def path(self, src: str, dst: str) -> Tuple[str, ...]:
        """Links traversed by a src->dst migration (order-stable dedup)."""
        out: List[str] = []
        for host in (src, dst):
            for l in self.host_links.get(host, self.default_path):
                if l not in out:
                    out.append(l)
        if not out:
            out = list(self.default_path)
        return tuple(out)

    # -- factories -----------------------------------------------------------
    @classmethod
    def single_link(cls, capacity: float,
                    link_id: str = "migration-net") -> "Topology":
        """The paper's testbed: one shared migration network for everyone."""
        return cls([Link(link_id, capacity)], default_path=(link_id,))

    @classmethod
    def star(cls, hosts: Sequence[str], access_capacity: float,
             core_capacity: float | None = None) -> "Topology":
        """Per-host access links, optionally through a shared core link."""
        links = [Link(f"acc:{h}", access_capacity) for h in hosts]
        host_links = {h: (f"acc:{h}",) for h in hosts}
        if core_capacity is not None:
            links.append(Link("core", core_capacity))
            host_links = {h: (f"acc:{h}", "core") for h in hosts}
        return cls(links, host_links)


def fair_share(paths: Sequence[Sequence[str]],
               capacities: Dict[str, float]) -> np.ndarray:
    """Max-min fair rates (bytes/s) for concurrent flows over shared links.

    Progressive filling: every flow's rate grows uniformly until some link
    saturates; flows crossing the saturated link freeze at that share, the
    rest keep growing on the slack. A flow with an empty path is
    unconstrained and gets ``inf`` (the caller decides what that means).
    """
    n = len(paths)
    rates = np.zeros(n)
    frozen = np.zeros(n, bool)
    members: Dict[str, List[int]] = {}
    for i, p in enumerate(paths):
        for l in dict.fromkeys(p):          # dedup, keep order
            members.setdefault(l, []).append(i)
    while True:
        bottleneck = None
        for l, idxs in members.items():
            live = [i for i in idxs if not frozen[i]]
            if not live:
                continue
            rem = capacities[l] - float(rates[idxs].sum())
            share = max(rem, 0.0) / len(live)
            if bottleneck is None or share < bottleneck[0]:
                bottleneck = (share, l)
        if bottleneck is None:
            break
        share, l = bottleneck
        for i in members[l]:
            if not frozen[i]:
                rates[i] = share
                frozen[i] = True
    rates[~frozen] = np.inf                 # flows crossing no link
    return rates
