"""Piecewise-constant dirty-rate tables — the fleet's vectorizable rate spec.

A live migration's cost is driven by the dirty rate r(t) of the workload
being moved (paper §3.2); the fleet represents every workload's rate as a
``PiecewiseRate`` — a cyclic table of (phase end, rate) pairs.  The table
form is what makes the whole execution stack vectorizable:

  * ``PiecewiseRate.batch`` stacks M tables into one padded lookup, so the
    batched pre-copy simulator (``strunk.simulate_precopy_batch``) samples
    the entire fleet's rates per round in one call;
  * the migration plane (``core/plane.py``) registers each launched lane's
    table into the same padded layout (``RateBank``) and accrues dirty
    bytes for every in-flight lane per event chunk in one lookup — no
    per-lane Python in the event loop.

Scalar calls (``rate(t)``) and every batched path index the same tables
with the same float64 arithmetic, so scalar vs batch agree bit-for-bit —
the parity contract the simulator and the plane's scalar-reference tests
rely on.

Lane-registration API (what the plane accepts per lane):

  =====================  =================================================
  spec                   vectorized handling
  =====================  =================================================
  ``None``               rate 0 (nothing dirties; pre-copy converges in
                         one round)
  ``float``              constant rate — a one-entry table
  ``PiecewiseRate``      table row in the shared padded lookup
  object with a
  ``rate_table``         its ``PiecewiseRate`` is registered (e.g. a
  attribute              ``fleetsim.WorkloadTrace``)
  plain callable         compatibility path: sampled per lane per event
                         (keeps third-party rate functions working, but
                         re-introduces O(lanes) Python — prefer tables)
  =====================  =================================================

``as_rate_table`` performs that normalization; ``RateBank`` is the plane's
stacked-lookup container.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np


class PiecewiseRate:
    """Piecewise-constant cyclic rate r(t) backed by phase-end tables.

    ``ends`` are cumulative phase end times, ``rates`` the per-phase value;
    the pattern repeats every ``ends[-1]`` seconds, shifted by ``offset``.
    Scalar calls and the vectorized ``batch`` path index the same tables
    with the same float64 arithmetic, so they agree bit-for-bit — the
    parity contract ``strunk.simulate_precopy_batch`` relies on.
    """

    def __init__(self, ends: Sequence[float], rates: Sequence[float],
                 offset: float = 0.0):
        self.ends = np.asarray(ends, np.float64)
        self.rates = np.asarray(rates, np.float64)
        self.cycle = float(self.ends[-1])
        self.offset = float(offset)

    def index_at(self, t: float) -> int:
        tc = (t + self.offset) % self.cycle
        i = int(np.searchsorted(self.ends, tc, side="right"))
        return min(i, len(self.rates) - 1)

    def __call__(self, t: float) -> float:
        return float(self.rates[self.index_at(t)])

    @staticmethod
    def stack(lanes: Sequence["PiecewiseRate"]
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Pad M tables into one (ends, rates, cycle, offset) array set —
        the storable form behind ``batch`` and ``RateBank``. Padding rule:
        ``ends`` extend with inf (never matched), ``rates`` replicate each
        row's last value, so any further right-padding of a stacked row is
        idempotent (``RateBank.concat`` re-pads to a common width)."""
        m = len(lanes)
        width = max(len(l.rates) for l in lanes)
        ends = np.full((m, width), np.inf)
        rates = np.zeros((m, width))
        for i, l in enumerate(lanes):
            n = len(l.rates)
            ends[i, :n] = l.ends
            rates[i, :n] = l.rates
            rates[i, n:] = l.rates[-1]
        cyc = np.asarray([l.cycle for l in lanes])
        off = np.asarray([l.offset for l in lanes])
        return ends, rates, cyc, off

    @staticmethod
    def lookup_fn(ends: np.ndarray, rates: np.ndarray, cyc: np.ndarray,
                  off: np.ndarray) -> Callable[[np.ndarray], np.ndarray]:
        """The vectorized (M,) time -> (M,) rate lookup over stacked
        tables (see ``stack``). Per-lane arithmetic is independent of the
        stack's width and row order, so gathered/concatenated stacks
        sample bit-identically to freshly built ones."""
        m, width = rates.shape
        # flat-table lookup with persistent scratch: per-phase column
        # compares (W is tiny) + in-place ufuncs beat a (M, W)
        # broadcast+reduce by ~5x in numpy dispatch overhead — this sits on
        # the batch simulator's per-round hot path. The returned array is a
        # reused buffer: callers consume it before the next call.
        cols = [np.ascontiguousarray(ends[:, k]) for k in range(width)]
        flat = np.ascontiguousarray(rates.ravel())
        row_off = np.arange(m, dtype=np.intp) * width
        tc = np.empty(m)
        idx = np.empty(m, np.intp)
        cmp = np.empty(m, bool)
        out = np.empty(m)

        def fn(t: np.ndarray) -> np.ndarray:
            np.add(t, off, out=tc)
            np.mod(tc, cyc, out=tc)
            np.copyto(idx, row_off)
            for col in cols[:-1]:       # tc < ends[-1] always
                np.greater_equal(tc, col, out=cmp)
                np.add(idx, cmp, out=idx, casting="unsafe")
            return flat.take(idx, out=out)
        fn.vectorized = True
        fn.nonneg = bool(np.all(rates >= 0.0))
        return fn

    @staticmethod
    def batch(lanes: Sequence["PiecewiseRate"]
              ) -> Callable[[np.ndarray], np.ndarray]:
        """One vectorized rate function over (M,) lanes: maps the (M,) time
        array to (M,) rates in a single padded table lookup."""
        return PiecewiseRate.lookup_fn(*PiecewiseRate.stack(lanes))


RateSpec = Union[None, float, PiecewiseRate, Callable[[float], float]]


def as_rate_table(spec: RateSpec) -> Optional[PiecewiseRate]:
    """Normalize a lane's rate spec to a ``PiecewiseRate`` table, or None
    when only per-call sampling is possible (plain callables).

    Constants become one-entry tables (cycle 1.0 — any positive cycle
    yields the same value everywhere); objects exposing a ``rate_table``
    attribute (e.g. ``WorkloadTrace``) contribute their table directly.
    """
    if spec is None:
        return PiecewiseRate([1.0], [0.0])
    if isinstance(spec, PiecewiseRate):
        return spec
    table = getattr(spec, "rate_table", None)
    if isinstance(table, PiecewiseRate):
        return table
    if callable(spec):
        return None
    return PiecewiseRate([1.0], [float(spec)])


class RateBank:
    """Stacked rate tables for the plane's in-flight lanes.

    Holds one padded table row per lane plus a per-lane fallback callable
    slot for specs that cannot be tabulated. ``sample(t, copy_mask)``
    returns the (M,) dirty rates at scalar time ``t`` — one padded lookup
    for every table lane, a scalar call per fallback lane still in its
    copy phase (matching the reference loop's call pattern bit-for-bit).

    The padded tables are stored as plain arrays, so banks compose
    without re-normalizing specs: ``concat`` stitches two banks (the
    fabric merges the banks of two bridged migration domains instead of
    rebuilding from the lane list), ``take`` gathers arbitrary rows into
    a derived bank (the defer-k sweep prices all n+1 nested prefixes
    through ONE bank built from the n unique candidate tables). Both are
    numpy copies — no per-lane Python — and both sample bit-identically
    to a freshly built bank (per-row lookups are width/order agnostic).
    """

    def __init__(self, specs: Sequence[RateSpec]):
        tables: List[PiecewiseRate] = []
        fallback: List[Tuple[int, Callable[[float], float]]] = []
        for i, spec in enumerate(specs):
            table = as_rate_table(spec)
            if table is None:
                fallback.append((i, spec))
                table = PiecewiseRate([1.0], [0.0])   # placeholder row
            tables.append(table)
        self._init_arrays(
            *(PiecewiseRate.stack(tables) if tables
              else (np.full((0, 1), np.inf), np.zeros((0, 1)),
                    np.zeros(0), np.zeros(0))),
            fallback)

    def _init_arrays(self, ends, rates, cyc, off, fallback) -> None:
        self.m = len(cyc)
        self._ends, self._rates = ends, rates
        self._cyc, self._off = cyc, off
        self.fallback = fallback
        self._lookup = PiecewiseRate.lookup_fn(ends, rates, cyc, off) \
            if self.m else None
        # public view of the stacked lookup: an (M,) time array -> (M,)
        # rates callable (``.vectorized``/``.nonneg`` set), valid whenever
        # ``fallback`` is empty — strunk's what-if costing reuses it to
        # price hypothetical lane batches through the same tables
        self.table_fn = self._lookup
        self._t = np.empty(self.m)
        self._out = np.empty(self.m)

    @classmethod
    def _from_arrays(cls, ends, rates, cyc, off, fallback) -> "RateBank":
        bank = cls.__new__(cls)
        bank._init_arrays(ends, rates, cyc, off, fallback)
        return bank

    @staticmethod
    def _pad_to(ends: np.ndarray, rates: np.ndarray, width: int
                ) -> Tuple[np.ndarray, np.ndarray]:
        """Right-pad stacked tables to ``width`` columns under the
        ``PiecewiseRate.stack`` padding rule (idempotent: trailing rate
        columns already replicate each row's last valid value)."""
        m, w = rates.shape
        if w >= width:
            return ends, rates
        e = np.full((m, width), np.inf)
        r = np.empty((m, width))
        e[:, :w] = ends
        r[:, :w] = rates
        r[:, w:] = rates[:, w - 1:w]
        return e, r

    @classmethod
    def concat(cls, a: "RateBank", b: "RateBank") -> "RateBank":
        """Bank holding ``a``'s lanes followed by ``b``'s — array
        concatenation only (no spec re-normalization); rows sample
        bit-identically to a rebuild over the combined lane list."""
        width = max(a._rates.shape[1], b._rates.shape[1])
        ea, ra = cls._pad_to(a._ends, a._rates, width)
        eb, rb = cls._pad_to(b._ends, b._rates, width)
        fallback = list(a.fallback) + [(i + a.m, fn) for i, fn in b.fallback]
        return cls._from_arrays(
            np.concatenate([ea, eb]), np.concatenate([ra, rb]),
            np.concatenate([a._cyc, b._cyc]),
            np.concatenate([a._off, b._off]), fallback)

    def take(self, idx: np.ndarray) -> "RateBank":
        """Bank whose lane ``j`` is this bank's lane ``idx[j]`` (rows may
        repeat) — one fancy-index gather."""
        idx = np.asarray(idx, np.intp)
        if self.fallback:
            by_row = dict(self.fallback)
            fallback = [(j, by_row[int(i)]) for j, i in enumerate(idx)
                        if int(i) in by_row]
        else:
            fallback = []
        return self._from_arrays(
            self._ends[idx], self._rates[idx], self._cyc[idx],
            self._off[idx], fallback)

    def sample(self, t: float, copy_mask: np.ndarray) -> np.ndarray:
        """(M,) rates at time ``t``; fallback lanes are sampled only while
        ``copy_mask`` is set (stopped lanes accrue nothing, and the
        reference loop never calls their rate function either)."""
        if self._lookup is None:
            return self._out
        self._t.fill(t)
        np.copyto(self._out, self._lookup(self._t))
        for i, fn in self.fallback:
            self._out[i] = float(fn(t)) if copy_mask[i] else 0.0
        return self._out
