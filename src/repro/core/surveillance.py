"""Fleet surveillance engine — one batched tick for the whole LMCM fleet.

The paper's LMCM (§5) surveils every VM continuously: classify the latest
telemetry window (NB, §4.1), recognize the workload cycle (FFT, §4.2 +
Alg. 1), and answer migration requests with Alg. 2 postponements. The seed
ran that pipeline one job at a time from ``LMCM.refresh_job`` — a Python
dispatch per job whose cost capped Fig. 10 scalability near 1k jobs at a
1 s sampling period. This module replaces the per-job loop with ONE batched
computation over the registered fleet:

  1. gather     — every job's telemetry window in one SoA ``window_matrix``
                  call (``telemetry.FleetTelemetry`` fast path; generic
                  per-buffer fallback for foreign stores);
  2. classify   — one jitted Naive Bayes call over (J, T, F)
                  (``characterize.classify_series_batch``); classification
                  is *incremental*: NB is stateless per sample, so a slid
                  window only classifies its new tail and splices the
                  cached lm series for the overlap (telemetry steps are
                  assumed dense — one sample per step);
  3. recognize  — one batched power spectrum (Pallas MXU matmul-DFT with a
                  fused mean-removal prologue on TPU) + one vectorized
                  candidate-lag autocorrelation refinement
                  (``cycles.fit_cycle_batch`` / ``kernels/autocorr.py``);
  4. decide     — the already-vectorized Algorithm 2 applied fleet-wide
                  (``postpone.postpone_batch``).

Staleness epochs make the tick incremental: a job's cycle fit is only
recomputed once its window has advanced >= period/4 samples since the last
fit (``acyclic_refit`` samples while no cycle is known), so a steady-state
tick touches only the jobs whose phase estimate could actually have
drifted. ``LMCM`` consumes the engine for both its per-request decisions
and its per-step surveillance; ``FleetSim`` and
``benchmarks/fig10_scalability.py`` drive ``tick`` directly.

Batch shapes are bucketed to powers of two before entering jitted code so
a fleet whose stale subset fluctuates does not retrace XLA programs every
tick.

100k-job extensions (all default-off / bit-identical):

  * sharding   — ``shards=k`` partitions every job-row stage (classify,
                 spectrum, refinement, Alg. 2) across the first k local
                 devices via shard_map (``core/shard.py``). No stage mixes
                 rows, so sharded ticks are BIT-IDENTICAL to the
                 single-device reference path (``shards=None``).
  * overlap    — ``overlap=True`` returns ``TickResult`` while Algorithm 2
                 is still executing under jax's async dispatch; the
                 job->RemainTime dict materializes on first ``.remain``
                 access, so the caller's next record/gather/classify
                 overlaps the decide. ``overlap=False`` restores the
                 synchronous schedule; values are bit-identical either way
                 (the decide's operands are captured at dispatch).
  * decide cache — the packed Alg. 2 operands (profiles/periods/origins/
                 ids) are cached and invalidated only by register/
                 unregister/refit, so a tick over an all-fresh fleet does
                 ZERO per-job Python work beyond the staleness scan:
                 ``m_now`` is one vectorized subtraction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import characterize, cycles, postpone as pp
from repro.core import shard as shardlib
from repro.core.telemetry import TelemetryBuffer


def _pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


@dataclass
class SurveilledJob:
    """Per-job surveillance state (the LMCM's job registry entry)."""
    job_id: str
    telemetry: TelemetryBuffer          # or any buffer with its interface
    nb: characterize.NaiveBayes
    window: int = 512
    dirty_rate_fn: Optional[Callable[[float], float]] = None
    model: Optional[cycles.CycleModel] = None
    lm_series: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int8))
    # step index of the first sample in the characterized window: Alg.1's
    # profile is indexed from here, so Alg.2's M_current must be too
    origin_step: int = 0
    fitted_step: int = -1               # latest step at last fit (-1 = never)
    # misprediction feedback (core/guard.py): decayed by each guard abort
    # of this job's migrations, floor-clamped by the guard's policy. The
    # receding-horizon controller gates trough pricing on
    # confidence x trust, so a burned fit stops deferring launches to
    # troughs the model hallucinated until refits re-earn it.
    trust: float = 1.0


class TickResult:
    """One surveillance tick's outcome: ``remain`` (job -> Alg.2 RemainTime
    in samples), ``refitted`` (cycle fits recomputed), ``fleet`` (jobs with
    a current model), ``confidence`` (job -> spectral confidence of its
    current fit — the guard layer's gating input, shared with the packed
    Alg. 2 cache so surfacing it costs no per-tick Python).

    With ``overlap=True`` the engine constructs this while Algorithm 2 is
    still executing on device (jax async dispatch); the ``remain`` dict is
    built on first access from operands captured at dispatch time, so the
    values are bit-identical to the synchronous schedule — only the host
    sync moves.
    """
    __slots__ = ("_remain", "refitted", "fleet", "confidence", "_thunk")

    def __init__(self, remain: Optional[Dict[str, int]], refitted: int,
                 fleet: int, confidence: Optional[Dict[str, float]] = None,
                 _thunk: Optional[Callable] = None):
        self._remain = remain
        self.refitted = refitted
        self.fleet = fleet
        self.confidence = confidence if confidence is not None else {}
        self._thunk = _thunk

    @property
    def remain(self) -> Dict[str, int]:
        if self._thunk is not None:
            self._remain = self._thunk()
            self._thunk = None
        return self._remain

    @property
    def pending(self) -> bool:
        """True while the decide has not been synced to host yet."""
        return self._thunk is not None

    def __repr__(self) -> str:
        body = "<pending>" if self.pending else repr(self._remain)
        return (f"TickResult(remain={body}, refitted={self.refitted}, "
                f"fleet={self.fleet})")


class SurveillanceEngine:
    """Batched NB -> FFT -> Alg.2 surveillance over a registered fleet."""

    def __init__(self, *, folded: bool = False, min_samples: int = 8,
                 acyclic_refit: int = 8,
                 use_kernel: Optional[bool] = None,
                 shards: Optional[int] = None,
                 overlap: bool = False,
                 min_coverage: float = 0.5):
        self.folded = folded
        self.min_samples = min_samples
        self.acyclic_refit = acyclic_refit
        # degraded-telemetry gate: fraction of a job's gathered window that
        # must be valid (recorded AND finite — NaN samples are sensor
        # dropout) for its cycle fit to be trusted; rows below it demote to
        # an acyclic model instead of fitting a cycle to zero-filled holes.
        # Clean telemetry always has coverage 1.0, so the gate is inert
        # until NaNs appear.
        self.min_coverage = float(min_coverage)
        self.use_kernel = use_kernel
        self.shards = shards
        self.overlap = overlap
        self.mesh = shardlib.decide_mesh(shards)
        self.jobs: Dict[str, SurveilledJob] = {}
        self._decide_cache: Optional[Tuple] = None

    # -- registration -------------------------------------------------------
    def register(self, job_id: str, telemetry, nb: characterize.NaiveBayes,
                 *, window: int = 512, dirty_rate_fn=None) -> SurveilledJob:
        job = SurveilledJob(job_id, telemetry, nb, window=window,
                            dirty_rate_fn=dirty_rate_fn)
        self.jobs[job_id] = job
        self._decide_cache = None
        return job

    def unregister(self, job_id: str) -> None:
        if self.jobs.pop(job_id, None) is not None:
            self._decide_cache = None

    # -- staleness epochs ---------------------------------------------------
    def _latest_steps(self, jobs: List[SurveilledJob]) -> np.ndarray:
        """(J,) latest telemetry step per job; one call on the fleet-SoA
        fast path, per-buffer otherwise."""
        out = np.full(len(jobs), -1, np.int64)
        by_fleet: Dict[int, List[int]] = {}
        for i, job in enumerate(jobs):
            fleet = getattr(job.telemetry, "fleet", None)
            if fleet is not None:
                by_fleet.setdefault(id(fleet), []).append(i)
            else:
                out[i] = job.telemetry.latest_step()
        for idxs in by_fleet.values():
            fleet = jobs[idxs[0]].telemetry.fleet
            latest = fleet.latest_steps()
            for i in idxs:
                out[i] = latest[jobs[i].telemetry.index]
        return out

    def _stale(self, job: SurveilledJob, latest: int) -> bool:
        if latest < 0 or len(job.telemetry) < self.min_samples:
            return False                        # not enough history yet
        if job.fitted_step < 0:
            return True
        advanced = latest - job.fitted_step
        if job.model is not None and job.model.period > 1:
            return advanced >= max(1, job.model.period // 4)
        return advanced >= self.acyclic_refit

    def next_refresh_step(self, now_step: int) -> float:
        """Earliest telemetry step at which ANY registered job's cycle fit
        becomes stale, assuming telemetry stays dense (one sample per
        step) — the event-skipping simulator's surveillance horizon: a
        per-step ``refresh()`` is a pure no-op strictly before this step,
        so the simulator may jump straight to it without changing any
        fit (``inf`` when no job will ever go stale, e.g. an empty
        fleet). Jobs with no samples yet are assumed to record their
        FIRST sample at ``now_step`` (callers pass the step about to be
        recorded), so they reach ``min_samples`` at
        ``now_step + min_samples - 1``."""
        nxt = np.inf
        if not self.jobs:
            return nxt
        jobs = list(self.jobs.values())
        for job, latest in zip(jobs, self._latest_steps(jobs)):
            base = int(latest) if latest >= 0 else now_step - 1
            ready = base + max(0, self.min_samples - len(job.telemetry))
            if job.fitted_step < 0:
                cand = ready                    # stale on first full window
            else:
                if job.model is not None and job.model.period > 1:
                    thresh = max(1, job.model.period // 4)
                else:
                    thresh = self.acyclic_refit
                cand = max(ready, job.fitted_step + thresh)
            nxt = min(nxt, cand)
        return nxt

    # -- the batched pipeline ----------------------------------------------
    def refresh(self, job_ids: Optional[List[str]] = None,
                *, force: bool = False) -> int:
        """Recompute the cycle fit of every stale (or ``force``d) job in
        one batched pipeline per (classifier, window-length) group.
        Returns the number of jobs refit."""
        jobs = ([self.jobs[i] for i in job_ids] if job_ids is not None
                else list(self.jobs.values()))
        if not jobs:
            return 0
        latest = self._latest_steps(jobs)
        todo = [(job, ls) for job, ls in zip(jobs, latest)
                if (force and ls >= 0
                    and len(job.telemetry) >= self.min_samples)
                or (not force and self._stale(job, ls))]
        if not todo:
            return 0
        groups: Dict[tuple, List[tuple]] = {}
        for job, ls in todo:
            m = min(job.window, len(job.telemetry))
            delta = int(ls) - job.fitted_step
            # incremental classification: NB is stateless per sample, so a
            # slid window only needs its NEW tail classified — the cached
            # lm_series supplies the overlap (telemetry steps are assumed
            # dense, one sample per step, as the recorder produces them)
            splice = (job.fitted_step >= 0 and len(job.lm_series) == m
                      and 0 <= delta < m)
            tail = min(m, _pow2(max(delta, 1))) if splice else m
            groups.setdefault((id(job.nb), m, tail), []).append((job, ls))
        for (_, m, tail), entries in groups.items():
            self._refresh_group([j for j, _ in entries],
                                np.asarray([ls for _, ls in entries]),
                                m, tail)
        return len(todo)

    def _refresh_group(self, jobs: List[SurveilledJob],
                       latest: np.ndarray, m: int, tail: int) -> None:
        G = len(jobs)
        # masked gather: NaN dropout samples come back zero-filled (the
        # batched NB/FFT stays finite) with their invalidity recorded, so
        # starved rows can be demoted instead of fit to hole-filled data
        W, counts, valid = TelemetryBuffer.window_matrix(
            [j.telemetry for j in jobs], tail,
            return_mask=True)                               # (G, tail, F)
        coverage = valid.sum(axis=1) / np.maximum(counts, 1)
        # bucket BOTH batch axes so the jitted NB doesn't retrace per stale
        # subset (job axis) or per history length (time axis — zero rows at
        # the front classify to garbage and are sliced off; NB is per-sample)
        G_p, T_p = _pow2(G), _pow2(tail)
        if G_p != G or T_p != tail:
            Wp = np.zeros((G_p, T_p, W.shape[2]))
            Wp[:G, T_p - tail:] = W
            W = Wp
        # lm-only classify: same jitted argmax as classify_series_batch
        # (bit-identical lm), no (G, T, C) posterior — optionally sharded
        lm_tail = shardlib.classify_lm(jobs[0].nb, W, self.mesh)
        lm_tail = lm_tail[:G, T_p - tail:]
        if tail == m:
            LM = lm_tail
        else:
            LM = np.empty((G, m), np.int8)
            for i, (job, ls) in enumerate(zip(jobs, latest)):
                d = int(ls) - job.fitted_step
                LM[i, : m - d] = job.lm_series[d:]
                if d:
                    LM[i, m - d:] = lm_tail[i, tail - d:]
        models = cycles.fit_cycle_batch(LM, folded=self.folded,
                                        use_kernel=self.use_kernel,
                                        mesh=self.mesh)
        for i, (job, model, lm_row, ls) in enumerate(
                zip(jobs, models, LM, latest)):
            if coverage[i] < self.min_coverage:
                # blackout-starved window: a cycle fit over zero-filled
                # holes is noise — demote to acyclic (same shape as the
                # not-found branch of fit_cycle_batch) until telemetry
                # recovers and a later refit sees real samples again
                model = cycles.CycleModel(0, 0.0, np.asarray(
                    [1 if lm_row.mean() >= 0.5 else 0], np.int8))
            job.model = model
            job.lm_series = lm_row
            job.origin_step = int(ls) - m + 1
            job.fitted_step = int(ls)
        self._decide_cache = None       # packed Alg.2 operands went stale

    def refresh_model(self, job_id: str, *, force: bool = False
                      ) -> Optional[cycles.CycleModel]:
        """Single-job view of ``refresh``: recompute if stale, then return
        the (possibly cached) model. None while history is too short."""
        self.refresh([job_id], force=force)
        return self.jobs[job_id].model

    # -- the batched tick ---------------------------------------------------
    def _packed_fleet(self) -> Tuple:
        """(ids, origins, profiles, periods, confidence) for the fitted
        fleet, padded/bucketed for Alg. 2 — cached between ticks and
        invalidated only by register/unregister/refit, so an all-fresh
        tick does no per-job Python work past the staleness scan."""
        if self._decide_cache is None:
            fitted = [j for j in self.jobs.values() if j.model is not None]
            if not fitted:
                self._decide_cache = ((), None, None, None, {})
            else:
                p_max = max((j.model.period for j in fitted
                             if j.model.period > 1), default=1)
                # bucket both axes: jit cache stays O(log J * log P)
                J_p, P_p = _pow2(len(fitted)), _pow2(max(p_max, 1))
                profiles, periods = pp.pack_fleet(
                    [j.model for j in fitted], n_jobs=J_p, p_max=P_p)
                origins = np.zeros(J_p, np.int64)
                origins[: len(fitted)] = [j.origin_step for j in fitted]
                self._decide_cache = (tuple(j.job_id for j in fitted),
                                      origins, profiles, periods,
                                      {j.job_id: float(j.model.confidence)
                                       for j in fitted})
        return self._decide_cache

    def next_trough(self, job_ids: List[str], now_step: int
                    ) -> Dict[str, Optional[int]]:
        """Samples until each job's next predicted LM trough — Algorithm
        2's RemainTime read off the CURRENT cycle fits (no refit: admission
        decisions ride whatever the last tick fitted, so pricing a
        candidate does not perturb the surveillance schedule). ``None``
        for unregistered jobs and for jobs without a cyclic model — there
        is no trough to time against, and the receding-horizon controller
        falls back to its myopic one-period deferral for them."""
        out: Dict[str, Optional[int]] = {}
        for jid in job_ids:
            job = self.jobs.get(jid)
            model = job.model if job is not None else None
            if model is None or not model.cyclic:
                out[jid] = None
            else:
                out[jid] = int(pp.postpone(
                    model, int(now_step) - job.origin_step))
        return out

    def tick(self, now_step: int) -> TickResult:
        """One fleet surveillance tick: refresh every stale cycle fit, then
        answer Algorithm 2 for the whole fleet in one vectorized call.

        With ``overlap=True`` the returned ``TickResult`` is constructed
        before the decide's host sync: Alg. 2 runs under jax async dispatch
        while the caller records/gathers the next tick, and ``.remain``
        materializes on first access (bit-identical values — the operands
        are captured at dispatch). Padding rows (period 0) decide to 0 and
        are sliced off before the dict is built.
        """
        refitted = self.refresh()
        ids, origins, profiles, periods, conf = self._packed_fleet()
        if not ids:
            return TickResult({}, refitted, 0)
        m_now = (now_step - origins).astype(np.int32)   # one vector op
        remain_dev = shardlib.postpone_rows(profiles, periods, m_now,
                                            self.mesh)
        J = len(ids)

        def materialize(ids=ids, dev=remain_dev, J=J) -> Dict[str, int]:
            return dict(zip(ids, np.asarray(dev)[:J].tolist()))

        if self.overlap:
            return TickResult(None, refitted, J, conf, _thunk=materialize)
        return TickResult(materialize(), refitted, J, conf)
