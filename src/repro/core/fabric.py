"""Sharded datacenter fabric — per-domain migration planes.

At datacenter scale the fleet is not one flat migration network: hosts hang
off per-rack access links joined by a core (``network.Topology.star`` /
``multi_rack``), and two migrations interact only if their paths share a
link. ``ShardedPlane`` exploits that: it partitions the in-flight lanes
into *migration domains* — connected components of the "shares a link"
relation — and runs one independent ``MigrationPlane`` event loop per
domain.

Why shard instead of one big plane:

  * **event decoupling** — a round boundary in one rack's domain no longer
    forces an event chunk (fair-share recompute + dirty resample) on every
    other rack's lanes; per-step work scales with the busiest domain, not
    the fleet.
  * **structural isolation** — a domain's event loop sees exactly the
    lanes it would see running alone, so migrations in disjoint domains
    are bit-equal to running each domain by itself (asserted in
    ``tests/test_fabric.py``). Core-link traffic is the only coupling:
    a lane whose path crosses shared (core) links bridges the domains it
    touches, which are then merged (``MigrationPlane._absorb``) and
    advance as one until they drain.

Domains are dynamic: they form at launch, merge when a cross-rack lane
bridges them, and dissolve when their lanes drain (byte accounting is
folded into the fabric's persistent per-link counters). Domain membership
is kept in a link-keyed union-find (``network.LinkUnionFind``: path
compression + union by size, one root per domain): resolving a launch
path to the domains it touches is one ``find`` per path link — O(alpha),
independent of how many domains are live — instead of an intersection
scan over every domain's link set, and a domain merge unions two roots
while ``MigrationPlane._absorb`` stitches the per-root execution state
(SoA lanes, rate bank, incidence) in place rather than rebuilding it from
the merged lane list. A drained domain's component is deleted wholesale
(its links revert to unregistered).

The fabric presents the same surface as a single plane — ``launch`` /
``advance`` / ``probe_bandwidth`` / ``link_bytes`` / ``last_shares`` — so
``FleetSim`` and the LMCM's realized-bandwidth feedback are agnostic to
the sharding; ``probe_bandwidth`` computes the fair share against the
intersecting domains only (disjoint domains cannot affect a new lane's
share), and ``what_if_shares_sweep`` answers the adaptive controller's
whole defer-k prefix ladder in one stacked solve.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import network, strunk
from repro.core.plane import MigrationPlane
from repro.core.rates import RateSpec


class ShardedPlane:
    """Fabric of per-domain ``MigrationPlane`` event loops (same surface
    as a single plane; see module docstring for the domain model)."""

    def __init__(self, topology: network.Topology, *, vectorized: bool = True,
                 **plane_kw):
        self.topology = topology
        self.caps = topology.capacities
        # id-indexed mirror of ``caps`` for the integer probe fast path
        self._caps_all = topology.caps_vector().copy()
        self.vectorized = vectorized
        self._plane_kw = plane_kw
        self._fallback_bw = max(self.caps.values(), default=np.inf)
        self.now = 0.0
        self._domains: List[MigrationPlane] = []
        # link-keyed domain membership: find(link) -> root -> plane.
        # _domains stays the ordered iteration surface (creation order,
        # which fixes lane order inside merged planes and the base-path
        # order of probes — both bit-parity-relevant)
        self._uf = network.LinkUnionFind()
        self._root_domain: Dict[str, MigrationPlane] = {}
        self._domain_root: Dict[int, str] = {}            # id(plane) -> root
        self._unlinked: Optional[MigrationPlane] = None   # path-less lanes
        self._dom_seq = 0
        # the union-find is keyed by link *incarnations*: a link whose
        # last live lane completed detaches from its component (domains
        # are components of the LIVE "shares a link" relation — matching
        # a new launch against a drained link's old domain would couple
        # event chunking across lanes that share nothing), and its next
        # use re-registers a fresh key. Ghost keys are reaped wholesale
        # when their domain drains (``pop_component``).
        self._link_key: Dict[str, str] = {}               # live link -> key
        self._live: Dict[str, int] = {}                   # live lanes per link
        self._lane_links: Dict[int, frozenset] = {}       # id(req) -> links
        self._gen = 0
        self._pending: List[Tuple[object, strunk.MigrationOutcome]] = []
        self._retired_link_bytes: Dict[str, float] = {}
        # final shares of domains that dissolved during the MOST RECENT
        # advance only — mirrors MigrationPlane.last_shares ("shares at
        # the latest event boundary") without retaining every job ever run
        self._dissolved_shares: Dict[str, float] = {}
        self.merges = 0                  # domain-bridging events (telemetry)

    # -- introspection -------------------------------------------------------
    @property
    def in_flight(self) -> int:
        return sum(d.in_flight for d in self._domains)

    @property
    def domain_count(self) -> int:
        return len(self._domains)

    def jobs_in_flight(self) -> List[str]:
        return [j for d in self._domains for j in d.jobs_in_flight()]

    def domain_links(self) -> List[frozenset]:
        """Link sets of the live domains (diagnostics / tests / the
        adaptive controller's candidate grouping)."""
        return [d.link_set for d in self._domains]

    def link_live_counts(self) -> Dict[str, int]:
        """In-flight lane count per link (route de-confliction input for
        ``pick_route`` and the controller's greedy route assignment)."""
        return dict(self._live)

    def domain_paths(self) -> List[List[Tuple[str, ...]]]:
        """Per-domain in-flight lane paths (the controller's what-if
        baseline for each migration domain)."""
        return [d.paths_in_flight() for d in self._domains]

    @property
    def link_bytes(self) -> Dict[str, float]:
        out = dict(self._retired_link_bytes)
        for d in self._domains:
            for l, b in d.link_bytes.items():
                out[l] = out.get(l, 0.0) + b
        return out

    @property
    def last_shares(self) -> Dict[str, float]:
        """Fair shares at each live domain's latest event boundary (plus
        the final shares of domains that drained in the last advance)."""
        shares = dict(self._dissolved_shares)
        for d in self._domains:
            shares.update(d.last_shares)
        return shares

    def _hit_domains(self, links: Iterable[str]) -> List[MigrationPlane]:
        """The live domains whose in-flight lanes touch any of ``links``
        — one union-find lookup per link (O(alpha) each, independent of
        the domain count), returned in domain-creation order (=
        ``self._domains`` order, which probe base-path ordering and merge
        targeting both rely on)."""
        hits: Dict[int, MigrationPlane] = {}
        for l in links:
            key = self._link_key.get(l)
            if key is not None:
                d = self._root_domain[self._uf.find(key)]
                hits[id(d)] = d
        return sorted(hits.values(), key=lambda d: d._fabric_seq)

    def probe_bandwidth(self, src: str, dst: str, extra: int = 0,
                        pending: Sequence[Sequence[str]] = ()) -> float:
        """Fair-share bandwidth a NEW src->dst migration would realize,
        computed against the domains its path intersects — lanes in
        disjoint domains cannot affect the share, so the probe is
        per-domain (the LMCM's ``bandwidth_probe`` wiring lands here).
        ``pending`` carries the actual paths of same-burst co-launches not
        yet on the fabric (they widen the intersecting-domain set: a
        co-launch can couple the probed lane to a domain its own path
        never touches); ``extra`` approximates further committed launches
        as same-path clones (legacy form)."""
        topo = self.topology
        path = topo.path(src, dst)
        pend = [tuple(p) for p in pending]
        pset = frozenset(path).union(*map(frozenset, pend)) if pend \
            else frozenset(path)
        hits = self._hit_domains(pset)
        ids = topo.ids_of(path)
        if ids is not None:
            # integer fast path: all lanes' link-id arrays are precomputed
            # (``fair_share_ids`` is the dict walk's bit-parity mirror)
            base_ids = [pi for d in hits for pi in d.ids_in_flight()]
            pend_ids = [topo.ids_of(p) for p in pend]
            if all(x is not None for x in base_ids) and \
                    all(x is not None for x in pend_ids):
                id_paths = base_ids + pend_ids + [ids] * (extra + 1)
                share = float(network.fair_share_ids(
                    id_paths, self._caps_all)[-1])
                return share if np.isfinite(share) else self._fallback_bw
        paths = [p for d in hits for p in d.paths_in_flight()]
        paths += pend + [path] * (extra + 1)
        share = float(network.fair_share(paths, self.caps)[-1])
        return share if np.isfinite(share) else self._fallback_bw

    def what_if_shares(self, new_paths: Sequence[Sequence[str]]
                       ) -> np.ndarray:
        """Max-min fair shares the hypothetical lanes ``new_paths`` would
        realize if all launched right now — solved against the union of
        the domains any of them intersects (domains are maximal
        components, so no other lane can affect the answer). One share per
        new path; unlinked lanes get the fallback bandwidth."""
        pend = [tuple(p) for p in new_paths]
        if not pend:
            return np.zeros(0)
        base = self._base_paths(l for p in pend for l in p)
        shares = network.fair_share(base + pend, self.caps)[len(base):]
        return np.where(np.isfinite(shares), shares, self._fallback_bw)

    def _base_paths(self, links: Iterable[str]) -> List[Tuple[str, ...]]:
        return [p for d in self._hit_domains(links)
                for p in d.paths_in_flight()]

    def what_if_shares_sweep(self, fixed_paths: Sequence[Sequence[str]],
                             cand_paths: Sequence[Sequence[str]]
                             ) -> np.ndarray:
        """All n+1 nested what-if batches of the defer-k sweep in ONE
        stacked solve: row k holds the fair shares of the F ``fixed_paths``
        lanes plus the first k ``cand_paths`` lanes against the domains the
        sweep intersects (columns past F+k are inactive and read 0).
        Equivalent to n+1 ``what_if_shares`` calls over growing prefixes;
        see ``network.fair_share_masked``."""
        base = self._base_paths(
            l for paths in (fixed_paths, cand_paths) for p in paths
            for l in p)
        return network.what_if_prefix_shares(
            base, fixed_paths, cand_paths, self.caps, self._fallback_bw)

    def what_if_pair_shares(self, fixed_paths: Sequence[Sequence[str]],
                            pair_paths: Sequence[Sequence[str]]
                            ) -> np.ndarray:
        """Fair share each (candidate, route) pair would realize ON ITS
        OWN against the ``fixed_paths`` lanes and the domains any pair or
        fixed lane intersects — the route-selection stage of the defer-k x
        route sweep, all pairs in one stacked solve (see
        ``network.what_if_pair_shares``)."""
        base = self._base_paths(
            l for paths in (fixed_paths, pair_paths) for p in paths
            for l in p)
        return network.what_if_pair_shares(
            base, fixed_paths, pair_paths, self.caps, self._fallback_bw)

    def what_if_subset_shares(self, fixed_paths: Sequence[Sequence[str]],
                              cand_paths: Sequence[Sequence[str]],
                              masks) -> np.ndarray:
        """Fair shares of K arbitrary candidate subsets against the
        domains the sweep intersects, base columns INCLUDED (row k: every
        intersecting in-flight lane + every fixed lane + the candidates
        ``masks[k]`` selects). Base-column order is ``_base_paths`` over
        the fixed+candidate links — the same order ``lane_state`` returns
        snapshots in, so the controller can reprice lane j at column j.
        See ``network.what_if_subset_shares``."""
        base = self._base_paths(
            l for paths in (fixed_paths, cand_paths) for p in paths
            for l in p)
        return network.what_if_subset_shares(
            base, fixed_paths, cand_paths, masks, self.caps,
            self._fallback_bw)

    def lane_state(self, links=None):
        """Mid-round snapshots of every lane in the domains touching
        ``links`` (all domains when None) — aligned one-to-one with
        ``_base_paths(links)``, i.e. with the base columns of
        ``what_if_subset_shares`` over the same link set."""
        if links is None:
            hits = self._domains
        else:
            hits = self._hit_domains(links)
        return [s for d in hits for s in d.lane_state()]

    def path_capacity(self, src: str, dst: str) -> float:
        """Uncontended capacity of the src->dst path (tightest link a lone
        migration would traverse) — the launch gate's floor reference."""
        path = self.topology.path(src, dst)
        if not path:
            return self._fallback_bw
        ids = self.topology.ids_of(path)
        if ids is not None:
            return float(self._caps_all[ids].min())
        return min(self.caps[l] for l in path)

    def pick_route(self, src: str, dst: str,
                   pending: Sequence[Sequence[str]] = ()
                   ) -> Tuple[str, ...]:
        """The candidate route a src->dst launch should ride right now
        (same contract as ``MigrationPlane.pick_route``): best probed
        fair share against the intersecting domains, ties broken toward
        fewer live lanes on the route's links, then the lowest route
        index. Flat pairs return ``path()`` unchanged."""
        routes = self.topology.routes(src, dst)
        if len(routes) == 1:
            return routes[0]
        shares = self.what_if_pair_shares(
            [tuple(p) for p in pending], list(routes))
        best, best_key = 0, None
        for j, r in enumerate(routes):
            load = sum(self._live.get(l, 0) for l in r)
            key = (float(shares[j]), -load, -j)
            if best_key is None or key > best_key:
                best, best_key = j, key
        return routes[best]

    # -- lifecycle -----------------------------------------------------------
    def _new_domain(self) -> MigrationPlane:
        d = MigrationPlane(self.topology, vectorized=self.vectorized,
                           **self._plane_kw)
        d._fabric_seq = self._dom_seq
        self._dom_seq += 1
        self._domains.append(d)
        return d

    def _on_finished(self, done):
        """Completion bookkeeping: a finished lane releases its links —
        a link whose live count reaches zero detaches from the union-find
        (its key is dropped; the ghost node is reaped at domain drain)."""
        for req, _ in done:
            for l in self._lane_links.pop(id(req), ()):
                left = self._live[l] - 1
                if left:
                    self._live[l] = left
                else:
                    del self._live[l]
                    del self._link_key[l]
        return done

    # -- fault injection -----------------------------------------------------
    def set_link_capacity(self, link: str, capacity: float) -> None:
        """Push a capacity change (fault injection) through the topology,
        the fabric's own probe view, and every live domain — future
        domains snapshot the mutated topology at creation."""
        self.topology.set_capacity(link, capacity)
        self.caps[link] = float(capacity)
        self._fallback_bw = max(self.caps.values(), default=np.inf)
        idx = self.topology.link_ids.get(link)
        if idx is not None:
            self._caps_all[idx] = float(capacity)
        for d in self._domains:
            d.set_link_capacity(link, capacity)

    def abort(self, job_id: str
              ) -> List[Tuple[object, strunk.MigrationOutcome]]:
        """Settle ``job_id``'s in-flight lane early across the fabric
        (see ``MigrationPlane.abort``): the lane's links release their
        union-find incarnations exactly as a completion would, and a
        domain fully drained by the abort dissolves immediately."""
        return self._abort_where(lambda d: d.abort(job_id))

    def fail_host(self, host: str
                  ) -> List[Tuple[object, strunk.MigrationOutcome]]:
        """Abort every in-flight lane with ``host`` as an endpoint."""
        return self._abort_where(lambda d: d.fail_host(host))

    def abort_link(self, link: str
                   ) -> List[Tuple[object, strunk.MigrationOutcome]]:
        """Abort every in-flight lane whose path crosses ``link`` — a
        hard ToR/pod-uplink outage (see ``MigrationPlane.abort_link``;
        the capacity change is the caller's move)."""
        return self._abort_where(lambda d: d.abort_link(link))

    def _abort_where(self, abort_fn
                     ) -> List[Tuple[object, strunk.MigrationOutcome]]:
        aborted: List[Tuple[object, strunk.MigrationOutcome]] = []
        for d in list(self._domains):
            out = abort_fn(d)
            if not out:
                continue
            aborted.extend(self._on_finished(out))
            if not d.in_flight:
                self._dissolve(d)
                self._domains.remove(d)
        return aborted

    def launch(self, req, rate: RateSpec, now: float, *,
               path: Optional[Sequence[str]] = None) -> None:
        """Start executing ``req`` at ``now`` in the domain its path
        belongs to — creating it, or merging the domains the path bridges
        (e.g. a cross-rack lane joining two busy racks through the core).
        Domain resolution is one union-find lookup per path link and a
        merge is one union per bridged domain — O(alpha), with
        ``MigrationPlane._absorb`` stitching the bridged domains' live
        execution state in place. ``rate`` follows the lane-registration
        API of ``core/rates.py``."""
        p = tuple(path) if path is not None else \
            self.topology.path(req.src, req.dst)
        pset = frozenset(p)
        if pset:
            hits = self._hit_domains(pset)
        else:
            # unlinked lanes never contend; keep them in one side domain
            hits = [self._unlinked] if self._unlinked is not None else []
        if not hits:
            target = self._new_domain()
            if not pset:
                self._unlinked = target
        else:
            target = hits[0]
            for other in hits[1:]:
                t = max(now, target.now, other.now)
                self._pending.extend(self._on_finished(target.advance(t)))
                self._pending.extend(self._on_finished(other.advance(t)))
                target._absorb(other)
                self._domains.remove(other)
                self.merges += 1
        if pset:
            old_roots = [self._domain_root[id(d)] for d in hits]
            for l in pset:
                if l not in self._link_key:
                    self._gen += 1
                    self._link_key[l] = f"{l}#{self._gen}"
                self._live[l] = self._live.get(l, 0) + 1
            root = self._uf.union_path(self._link_key[l] for l in p)
            for r in old_roots:
                root = self._uf.union(root, r)
                self._root_domain.pop(r, None)
            for d in hits:
                self._domain_root.pop(id(d), None)
            self._root_domain[root] = target
            self._domain_root[id(target)] = root
            self._lane_links[id(req)] = pset
        target.launch(req, rate, now, path=p)
        self.now = max(self.now, now)

    def advance(self, until: float):
        """Advance every domain's event loop to ``until`` (or drain);
        returns completions across all domains (plus any produced by
        launch-time catch-ups and merges). Drained domains dissolve —
        their byte accounting folds into the fabric counters and their
        union-find component is deleted wholesale."""
        finished = self._pending
        self._pending = []
        live: List[MigrationPlane] = []
        self._dissolved_shares = {}
        for d in self._domains:
            finished.extend(self._on_finished(d.advance(until)))
            if not np.isfinite(until):
                self.now = max(self.now, d.now)
            if d.in_flight:
                live.append(d)
            else:
                self._dissolve(d)
        self._domains = live
        if np.isfinite(until):
            self.now = max(self.now, until)
        return finished

    def _dissolve(self, d: MigrationPlane) -> None:
        """Retire a drained domain (drain or mass abort): fold its byte
        accounting into the fabric counters, surface its final shares,
        and delete its union-find component wholesale — ghost link
        incarnations are reaped with it."""
        for l, b in d.link_bytes.items():
            self._retired_link_bytes[l] = \
                self._retired_link_bytes.get(l, 0.0) + b
        self._dissolved_shares.update(d.last_shares)
        root = self._domain_root.pop(id(d), None)
        if root is not None:
            self._root_domain.pop(root, None)
            self._uf.pop_component(root)
        if d is self._unlinked:
            self._unlinked = None
