"""Load-index telemetry — the TPU analogue of the paper's 15-second SNMP
samples (§4, §6.4.1).

Each job (training/serving replica) owns a ``TelemetryBuffer``; the runtime
records one sample per step with exact in-process load indexes (no semantic
gap): dirty-bytes of the last update, collective bytes, step time, tokens/s.
The LMCM reads fixed-length windows for characterization. Gathering overhead
is measured in ``benchmarks/fig11_gathering.py``.

Fleet scale: ``FleetTelemetry`` keeps the whole fleet's samples in one
structure-of-arrays ring buffer — (J, capacity, F) — so the surveillance
engine (``core/surveillance.py``) gathers every job's window in a single
vectorized ``window_matrix`` call instead of J per-buffer copies, and the
simulator records one (J, F) row per step instead of J dict-kwarg calls.
Per-job ``view(j)`` adapters expose the ``TelemetryBuffer`` read/record
surface, so existing consumers (LMCM registration, examples) are agnostic
to which backing store a job uses.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

DEFAULT_FIELDS: Tuple[str, ...] = (
    "step_time", "dirty_bytes", "dirty_fraction", "collective_bytes",
    "compute_util", "hbm_util",
)


class TelemetryBuffer:
    """Fixed-capacity ring buffer of per-step load indexes."""

    def __init__(self, capacity: int = 8192,
                 fields: Sequence[str] = DEFAULT_FIELDS):
        self.fields = tuple(fields)
        self.capacity = capacity
        self._data = np.zeros((capacity, len(self.fields)), np.float64)
        self._steps = np.full(capacity, -1, np.int64)
        self._n = 0
        self._lock = threading.Lock()

    def record(self, step: int, **indexes: float) -> None:
        with self._lock:
            i = self._n % self.capacity
            for j, f in enumerate(self.fields):
                self._data[i, j] = float(indexes.get(f, 0.0))
            self._steps[i] = step
            self._n += 1

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    def latest_step(self) -> int:
        with self._lock:
            if self._n == 0:
                return -1
            return int(self._steps[(self._n - 1) % self.capacity])

    def window(self, n: int) -> np.ndarray:
        """Most recent ``n`` samples, oldest first. Shape (m<=n, F)."""
        with self._lock:
            m = min(n, len(self))
            if m == 0:
                return np.zeros((0, len(self.fields)))
            idx = (np.arange(self._n - m, self._n)) % self.capacity
            return self._data[idx].copy()

    def series(self, field: str, n: Optional[int] = None) -> np.ndarray:
        j = self.fields.index(field)
        return self.window(n if n is not None else len(self))[:, j]

    def snapshot(self) -> Dict[str, np.ndarray]:
        w = self.window(len(self))
        return {f: w[:, j] for j, f in enumerate(self.fields)}

    @staticmethod
    def window_matrix(buffers: Sequence["TelemetryBuffer"], n: int,
                      return_mask: bool = False):
        """Gather the most recent ``n`` samples of many buffers into one
        (J, n, F) SoA batch in a single call.

        Buffers backed by the same ``FleetTelemetry`` are gathered with one
        vectorized fancy-index; foreign buffers fall back to per-buffer
        ``window`` copies into the preallocated output. Short histories are
        left zero at the front; the second return value holds each job's
        valid sample count (callers batch jobs with equal counts).

        ``return_mask=True`` adds a (J, n) bool validity mask (recorded AND
        every field finite); non-finite samples are zero-filled in the
        output so classify/cycle-fit math stays finite while coverage
        gates see the dropout.
        """
        J = len(buffers)
        F = len(buffers[0].fields) if J else 0
        out = np.zeros((J, n, F), np.float64)
        lengths = np.zeros(J, np.int64)
        mask = np.zeros((J, n), bool) if return_mask else None
        # fleet fast path: group contiguous views of a shared SoA store
        by_fleet: Dict[int, List[int]] = {}
        for j, b in enumerate(buffers):
            fleet = getattr(b, "fleet", None)
            if fleet is not None and tuple(b.fields) == (
                    tuple(buffers[0].fields)):
                by_fleet.setdefault(id(fleet), []).append(j)
        done = np.zeros(J, bool)
        for js in by_fleet.values():
            fleet = buffers[js[0]].fleet
            rows = np.asarray([buffers[j].index for j in js])
            if return_mask:
                w, m, fm = fleet.window_matrix(n, rows=rows,
                                               return_mask=True)
                mask[js] = fm
            else:
                w, m = fleet.window_matrix(n, rows=rows)
            out[js] = w
            lengths[js] = m
            done[js] = True
        for j, b in enumerate(buffers):
            if done[j]:
                continue
            w = b.window(n)
            lengths[j] = len(w)
            if len(w):
                if return_mask:
                    finite = np.isfinite(w).all(axis=1)
                    mask[j, n - len(w):] = finite
                    w = np.where(finite[:, None], w, 0.0)
                out[j, n - len(w):] = w
        if return_mask:
            return out, lengths, mask
        return out, lengths


class FleetJobView:
    """One job's ``TelemetryBuffer``-compatible view into a FleetTelemetry
    SoA store (read surface + per-step ``record``)."""

    def __init__(self, fleet: "FleetTelemetry", index: int):
        self.fleet = fleet
        self.index = index
        self.fields = fleet.fields
        self.capacity = fleet.capacity

    def __len__(self) -> int:
        return int(min(self.fleet._n[self.index], self.capacity))

    def record(self, step: int, **indexes: float) -> None:
        self.fleet.record_job(self.index, step, **indexes)

    def latest_step(self) -> int:
        return self.fleet.latest_step(self.index)

    def window(self, n: int) -> np.ndarray:
        w, m = self.fleet.window_matrix(n, rows=np.asarray([self.index]))
        return w[0, n - int(m[0]):]

    def series(self, field: str, n: Optional[int] = None) -> np.ndarray:
        j = self.fields.index(field)
        return self.window(n if n is not None else len(self))[:, j]

    def snapshot(self) -> Dict[str, np.ndarray]:
        w = self.window(len(self))
        return {f: w[:, j] for j, f in enumerate(self.fields)}


class FleetTelemetry:
    """Fleet-wide structure-of-arrays telemetry ring buffer.

    One (J, capacity, F) array holds every job's samples; ``record_fleet``
    appends one (J, F) row per step for the whole fleet and
    ``window_matrix`` gathers all windows with one fancy-index — the O(J)
    Python dispatch of per-job ring buffers disappears from both the record
    and the surveillance-gather path. Jobs may also record independently
    (``record_job`` / per-job views); counts are tracked per job.
    """

    def __init__(self, n_jobs: int, capacity: int = 8192,
                 fields: Sequence[str] = DEFAULT_FIELDS):
        self.fields = tuple(fields)
        self.capacity = capacity
        self.n_jobs = n_jobs
        self._data = np.zeros((n_jobs, capacity, len(self.fields)),
                              np.float64)
        self._steps = np.full((n_jobs, capacity), -1, np.int64)
        self._n = np.zeros(n_jobs, np.int64)
        self._lock = threading.Lock()

    def view(self, index: int) -> FleetJobView:
        return FleetJobView(self, index)

    def views(self) -> List[FleetJobView]:
        return [FleetJobView(self, j) for j in range(self.n_jobs)]

    def record_fleet(self, step: int, values: np.ndarray) -> None:
        """Append one sample row per job. values: (J, F) ordered like
        ``fields``."""
        values = np.asarray(values, np.float64)
        with self._lock:
            i = self._n % self.capacity                     # (J,)
            rows = np.arange(self.n_jobs)
            self._data[rows, i] = values
            self._steps[rows, i] = step
            self._n += 1

    def record_fleet_bulk(self, steps: np.ndarray,
                          values: np.ndarray) -> None:
        """Append S fleet rows in one call — ring contents (slots, step
        stamps, counts) identical to S successive ``record_fleet`` calls.
        ``steps``: (S,), ``values``: (S, J, F). The event-skipping
        simulator uses this to land a whole skipped window's telemetry
        without S Python iterations; appends past a full wrap keep only
        the surviving tail (earlier rows would be overwritten anyway)
        while still advancing every job's sample count by S."""
        steps = np.asarray(steps, np.int64)
        values = np.asarray(values, np.float64)
        s = steps.shape[0]
        if s == 0:
            return
        with self._lock:
            if s > self.capacity:        # only the tail survives the wrap
                drop = s - self.capacity
                steps, values = steps[drop:], values[drop:]
                self._n += drop
                s = self.capacity
            idx = (self._n[:, None] + np.arange(s)) % self.capacity  # (J, S)
            rows = np.arange(self.n_jobs)[:, None]
            self._data[rows, idx] = values.transpose(1, 0, 2)
            self._steps[rows, idx] = steps[None, :]
            self._n += s

    def record_job(self, index: int, step: int, **indexes: float) -> None:
        with self._lock:
            i = int(self._n[index] % self.capacity)
            for j, f in enumerate(self.fields):
                self._data[index, i, j] = float(indexes.get(f, 0.0))
            self._steps[index, i] = step
            self._n[index] += 1

    def latest_step(self, index: int) -> int:
        with self._lock:
            if self._n[index] == 0:
                return -1
            return int(self._steps[index,
                                   (self._n[index] - 1) % self.capacity])

    def latest_steps(self) -> np.ndarray:
        """(J,) latest recorded step per job (-1 when empty) — one call."""
        with self._lock:
            rows = np.arange(self.n_jobs)
            idx = (self._n - 1) % self.capacity
            out = self._steps[rows, idx].copy()
            out[self._n == 0] = -1
            return out

    def window_matrix(self, n: int, rows: Optional[np.ndarray] = None,
                      return_mask: bool = False):
        """Most recent ``n`` samples for ``rows`` (default: all jobs) as one
        (len(rows), n, F) gather, oldest first, zero-padded at the front.
        Returns (matrix, per-job valid counts).

        With ``return_mask=True`` also returns a (R, n) bool validity mask:
        True only for recorded samples whose every field is finite. NaN
        samples (sensor dropout / telemetry blackout) are zero-filled in
        the matrix so downstream batched math stays finite, and masked
        False so coverage gates can demote starved rows; the default call
        leaves NaNs in place (the store accepts them verbatim)."""
        with self._lock:
            if rows is None:
                rows = np.arange(self.n_jobs)
            rows = np.asarray(rows)
            counts = np.minimum(self._n[rows], self.capacity)
            m = np.minimum(counts, n)                       # (R,)
            start = self._n[rows] - m
            # gather index t in [0, n): maps to ring slot of sample
            # (start + t - (n - m)); invalid front entries hit slot 0 and
            # are zeroed after the gather
            t = np.arange(n)[None, :]
            rel = t - (n - m)[:, None]
            idx = (start[:, None] + rel) % self.capacity
            w = self._data[rows[:, None], idx]
            w[rel < 0] = 0.0
            if not return_mask:
                return w, m
            finite = np.isfinite(w).all(axis=2)             # (R, n)
            mask = (rel >= 0) & finite
            w[~finite] = 0.0
            return w, m, mask
