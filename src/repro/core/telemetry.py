"""Load-index telemetry — the TPU analogue of the paper's 15-second SNMP
samples (§4, §6.4.1).

Each job (training/serving replica) owns a ``TelemetryBuffer``; the runtime
records one sample per step with exact in-process load indexes (no semantic
gap): dirty-bytes of the last update, collective bytes, step time, tokens/s.
The LMCM reads fixed-length windows for characterization. Gathering overhead
is measured in ``benchmarks/fig11_gathering.py``.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

DEFAULT_FIELDS: Tuple[str, ...] = (
    "step_time", "dirty_bytes", "dirty_fraction", "collective_bytes",
    "compute_util", "hbm_util",
)


class TelemetryBuffer:
    """Fixed-capacity ring buffer of per-step load indexes."""

    def __init__(self, capacity: int = 8192,
                 fields: Sequence[str] = DEFAULT_FIELDS):
        self.fields = tuple(fields)
        self.capacity = capacity
        self._data = np.zeros((capacity, len(self.fields)), np.float64)
        self._steps = np.full(capacity, -1, np.int64)
        self._n = 0
        self._lock = threading.Lock()

    def record(self, step: int, **indexes: float) -> None:
        with self._lock:
            i = self._n % self.capacity
            for j, f in enumerate(self.fields):
                self._data[i, j] = float(indexes.get(f, 0.0))
            self._steps[i] = step
            self._n += 1

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    def latest_step(self) -> int:
        with self._lock:
            if self._n == 0:
                return -1
            return int(self._steps[(self._n - 1) % self.capacity])

    def window(self, n: int) -> np.ndarray:
        """Most recent ``n`` samples, oldest first. Shape (m<=n, F)."""
        with self._lock:
            m = min(n, len(self))
            if m == 0:
                return np.zeros((0, len(self.fields)))
            end = self._n % self.capacity
            idx = (np.arange(self._n - m, self._n)) % self.capacity
            return self._data[idx].copy()

    def series(self, field: str, n: Optional[int] = None) -> np.ndarray:
        j = self.fields.index(field)
        return self.window(n if n is not None else len(self))[:, j]

    def snapshot(self) -> Dict[str, np.ndarray]:
        w = self.window(len(self))
        return {f: w[:, j] for j, f in enumerate(self.fields)}
