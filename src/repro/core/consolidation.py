"""Server-consolidation planner (paper §3.3) — the upstream policy whose
migration plans ALMA intercepts.

First-fit-decreasing heuristics (the paper notes heuristics dominate in
practice for scalability): given per-job loads and host capacities, pack
jobs onto the fewest hosts; every job that must move becomes a
MigrationRequest tagged with its src/dst hosts, which the migration fabric
resolves to network links. ALMA does not modify this policy — it only
re-times its requests (Fig. 2/5c).

Contention-aware packing: on a sharded fabric (``network.Topology.star`` /
``multi_rack``) two packings with the SAME host count can have wildly
different migration bills — one keeps every move inside its rack, the
other funnels the whole fleet through the core. When a ``topology`` is
passed, ``consolidate_ffd`` generates several candidate packings (classic
FFD, rack-affinity FFD that prefers destinations sharing the job's access
links, and a stay-first variant that avoids moves entirely when the
current host fits) and scores each by

  ``(hosts used,  predicted contended bytes,  predicted summed time)``

lexicographically — consolidation remains the primary objective, but ties
break on the *predicted contended migration cost*: every planned
transfer's max-min fair share over the topology
(``network.fair_share``) feeds ``strunk.expected_cost_batch``, so a plan
that would melt the core loses to one that migrates rack-locally. Without
a topology the classic FFD plan is returned unchanged.

On hierarchical fabrics (``Topology.pod_spine``) the byte term is
*tier-weighted*: a transfer's bytes are scaled by the highest fabric tier
its path climbs to (``TIER_WEIGHTS`` — spine bytes cost 4x ToR bytes,
pod-uplink bytes 2x), because oversubscribed upper tiers are the scarce,
fleet-shared resource. Two extra pod-affinity candidate packings (same
rack first, then same pod, then the rest) join the sweep so a plan that
keeps moves under one pod can actually win that scoring. Flat topologies
have every link at tier 0 — weighted bytes equal raw bytes and the
pre-existing behavior is unchanged.

``Placement.host_of`` is on the per-request path of every consolidation
event; it is backed by a job->host index maintained by ``assign``/``move``
(the FFD packer places through ``assign``), not a linear scan over hosts.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import network, strunk
from repro.core.orchestrator import MigrationRequest


@dataclass
class Host:
    host_id: str
    capacity: float                    # abstract load units (e.g. chips)
    jobs: Dict[str, float] = field(default_factory=dict)

    @property
    def load(self) -> float:
        return sum(self.jobs.values())

    @property
    def free(self) -> float:
        return self.capacity - self.load


@dataclass
class Placement:
    hosts: Dict[str, Host]
    _index: Dict[str, str] = field(default_factory=dict, repr=False,
                                   compare=False)

    def __post_init__(self):
        self._index = {j: h.host_id for h in self.hosts.values()
                       for j in h.jobs}

    def host_of(self, job_id: str) -> Optional[str]:
        return self._index.get(job_id)

    def assign(self, job_id: str, host_id: str, load: float) -> None:
        """Place a job on a host, keeping the job->host index in sync."""
        self.hosts[host_id].jobs[job_id] = load
        self._index[job_id] = host_id

    def move(self, job_id: str, dst: str) -> None:
        """Apply a completed migration: relocate the job to ``dst``."""
        src = self._index.get(job_id)
        if src is None or src == dst:
            return
        load = self.hosts[src].jobs.pop(job_id)
        self.hosts[dst].jobs[job_id] = load
        self._index[job_id] = dst


def _pack(placement: Placement, now: float,
          state_bytes: Dict[str, float],
          host_order_for=None, stay_first: bool = False
          ) -> Tuple[Placement, List[MigrationRequest]]:
    """One FFD pass. ``host_order_for(src)`` returns the candidate-host
    scan order for a job currently on ``src`` (None -> most-loaded-first
    for every job — classic FFD); ``stay_first`` tries the job's current
    host before any other."""
    jobs: List[Tuple[str, float, str]] = []
    for h in placement.hosts.values():
        for j, load in h.jobs.items():
            jobs.append((j, load, h.host_id))
    jobs.sort(key=lambda t: -t[1])

    default_order = [h.host_id for h in
                     sorted(placement.hosts.values(), key=lambda h: -h.load)]
    new_p = Placement({hid: Host(hid, placement.hosts[hid].capacity)
                       for hid in default_order})
    plan: List[MigrationRequest] = []

    for job_id, load, src in jobs:
        order = list(host_order_for(src)) if host_order_for else \
            list(default_order)
        if stay_first and src in order:
            order.remove(src)
            order.insert(0, src)
        for hid in order:
            h = new_p.hosts[hid]
            if h.free >= load:
                new_p.assign(job_id, hid, load)
                if hid != src:
                    plan.append(MigrationRequest(
                        job_id=job_id, created_at=now,
                        v_bytes=state_bytes.get(job_id, 0.0),
                        src=src, dst=hid))
                break
        else:  # no capacity anywhere: keep in place
            new_p.assign(job_id, src, load)

    return new_p, plan


# Byte multiplier per fabric tier (index = Topology.tier_of): access/ToR
# bytes at par, pod-uplink bytes 2x, spine bytes 4x — upper tiers are the
# oversubscribed, fleet-shared resource a consolidation plan should spare.
TIER_WEIGHTS = (1.0, 2.0, 4.0)


def _path_weight(topology: network.Topology,
                 path: Sequence[str]) -> float:
    """Tier weight of a transfer: the multiplier of the HIGHEST tier its
    path climbs to (1.0 for empty paths and flat topologies)."""
    w = 1.0
    for l in path:
        tw = TIER_WEIGHTS[min(topology.tier_of(l), len(TIER_WEIGHTS) - 1)]
        if tw > w:
            w = tw
    return w


def plan_cost(plan: Sequence[MigrationRequest],
              topology: network.Topology, *,
              dirty_rates: Optional[Dict[str, object]] = None,
              bandwidth: Optional[float] = None,
              now: float = 0.0) -> Dict[str, float]:
    """Predicted cost of executing ``plan`` as one simultaneous burst on
    ``topology``: each transfer runs at its max-min fair share of the
    links on its src->dst path (everything else in the plan in flight),
    and the contended pre-copy cost comes from
    ``strunk.expected_cost_batch`` at those shares. Returns predicted
    total ``bytes``, tier-weighted ``weighted_bytes`` (spine bytes priced
    above ToR bytes — equal to ``bytes`` on flat topologies), summed lane
    ``time``, and the share vector."""
    if not plan:
        return {"bytes": 0.0, "weighted_bytes": 0.0, "time": 0.0,
                "shares": np.zeros(0)}
    caps = topology.capacities
    fallback = bandwidth if bandwidth is not None \
        else max(caps.values(), default=np.inf)
    paths = [topology.path(r.src, r.dst) for r in plan]
    shares = network.fair_share(paths, caps)
    shares = np.where(np.isfinite(shares), shares, fallback)
    v = np.asarray([r.v_bytes for r in plan], np.float64)
    rates = [(dirty_rates or {}).get(r.job_id, 0.0) for r in plan]
    sim = strunk.expected_cost_batch(v, shares, rates,
                                     np.full(len(plan), now), full=True)
    weights = np.asarray([_path_weight(topology, p) for p in paths])
    return {"bytes": float(sim.bytes_sent.sum()),
            "weighted_bytes": float((sim.bytes_sent * weights).sum()),
            "time": float(sim.total_time.sum()),
            "shares": shares}


def consolidate_ffd(placement: Placement, *, now: float = 0.0,
                    state_bytes: Optional[Dict[str, float]] = None,
                    topology: Optional[network.Topology] = None,
                    dirty_rates: Optional[Dict[str, object]] = None,
                    bandwidth: Optional[float] = None
                    ) -> Tuple[Placement, List[MigrationRequest]]:
    """First-fit-decreasing repack. Returns (new placement, migration plan).

    Classic behavior (no ``topology``): target hosts are the most-loaded
    first (consolidate into few), jobs are placed largest-first; a job
    that lands on a different host than it occupies now yields a
    MigrationRequest carrying src/dst for the fabric's link resolution.

    With a ``topology``, candidate packings (classic / rack-affinity /
    stay-first, plus pod-affinity variants on hierarchical fabrics; see
    module docstring) are scored by ``(hosts_used, predicted tier-weighted
    contended bytes, predicted summed time)`` and the best plan wins — ``dirty_rates`` (per-job ``PiecewiseRate``
    tables or constants) sharpen the byte prediction; ``bandwidth`` caps
    the share of unconstrained paths.
    """
    state_bytes = state_bytes or {}
    classic = _pack(placement, now, state_bytes)
    if topology is None:
        return classic

    loaded_desc = [h.host_id for h in
                   sorted(placement.hosts.values(), key=lambda h: -h.load)]
    # one ordered host list per access signature, built once: local hosts
    # first (loaded-desc), then the rest — rack_affinity is called per job
    access_order: Dict[Tuple[str, ...], List[str]] = {}
    for hid in loaded_desc:
        acc = topology.access_of(hid)
        if acc not in access_order:
            local = [h for h in loaded_desc
                     if topology.access_of(h) == acc]
            rest = [h for h in loaded_desc
                    if topology.access_of(h) != acc]
            access_order[acc] = local + rest

    def rack_affinity(src: str) -> List[str]:
        return access_order.get(topology.access_of(src), loaded_desc)

    candidates = [
        classic,
        _pack(placement, now, state_bytes, host_order_for=rack_affinity),
        _pack(placement, now, state_bytes, host_order_for=rack_affinity,
              stay_first=True),
    ]

    if any(topology.pod_of(hid) is not None for hid in loaded_desc):
        # hierarchical fabric: pod-affinity scan orders — same rack first,
        # then same pod (cheap pod-uplink hop), then the rest — so a plan
        # that never climbs to the spine can win the tier-weighted scoring
        pod_order: Dict[Tuple, List[str]] = {}
        for hid in loaded_desc:
            key = (topology.access_of(hid), topology.pod_of(hid))
            if key not in pod_order:
                acc, pod = key
                local = [h for h in loaded_desc
                         if topology.access_of(h) == acc]
                same_pod = [h for h in loaded_desc
                            if topology.access_of(h) != acc
                            and topology.pod_of(h) == pod]
                rest = [h for h in loaded_desc
                        if topology.access_of(h) != acc
                        and topology.pod_of(h) != pod]
                pod_order[key] = local + same_pod + rest

        def pod_affinity(src: str) -> List[str]:
            return pod_order.get(
                (topology.access_of(src), topology.pod_of(src)),
                loaded_desc)

        candidates.append(_pack(placement, now, state_bytes,
                                host_order_for=pod_affinity))
        candidates.append(_pack(placement, now, state_bytes,
                                host_order_for=pod_affinity,
                                stay_first=True))

    def score(cand: Tuple[Placement, List[MigrationRequest]]):
        new_p, plan = cand
        cost = plan_cost(plan, topology, dirty_rates=dirty_rates,
                         bandwidth=bandwidth, now=now)
        return (hosts_used(new_p), cost["weighted_bytes"], cost["time"])

    return min(candidates, key=score)


def hosts_used(placement: Placement) -> int:
    return sum(1 for h in placement.hosts.values() if h.jobs)
