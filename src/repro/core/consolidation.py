"""Server-consolidation planner (paper §3.3) — the upstream policy whose
migration plans ALMA intercepts.

First-fit-decreasing heuristic (the paper notes heuristics dominate in
practice for scalability): given per-job loads and host capacities, pack jobs
onto the fewest hosts; every job that must move becomes a MigrationRequest
tagged with its src/dst hosts, which the migration plane resolves to network
links. ALMA does not modify this policy — it only re-times its requests
(Fig. 2/5c).

``Placement.host_of`` is on the per-request path of every consolidation
event; it is backed by a job->host index maintained by ``assign``/``move``
(the FFD packer places through ``assign``), not a linear scan over hosts.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.orchestrator import MigrationRequest


@dataclass
class Host:
    host_id: str
    capacity: float                    # abstract load units (e.g. chips)
    jobs: Dict[str, float] = field(default_factory=dict)

    @property
    def load(self) -> float:
        return sum(self.jobs.values())

    @property
    def free(self) -> float:
        return self.capacity - self.load


@dataclass
class Placement:
    hosts: Dict[str, Host]
    _index: Dict[str, str] = field(default_factory=dict, repr=False,
                                   compare=False)

    def __post_init__(self):
        self._index = {j: h.host_id for h in self.hosts.values()
                       for j in h.jobs}

    def host_of(self, job_id: str) -> Optional[str]:
        return self._index.get(job_id)

    def assign(self, job_id: str, host_id: str, load: float) -> None:
        """Place a job on a host, keeping the job->host index in sync."""
        self.hosts[host_id].jobs[job_id] = load
        self._index[job_id] = host_id

    def move(self, job_id: str, dst: str) -> None:
        """Apply a completed migration: relocate the job to ``dst``."""
        src = self._index.get(job_id)
        if src is None or src == dst:
            return
        load = self.hosts[src].jobs.pop(job_id)
        self.hosts[dst].jobs[job_id] = load
        self._index[job_id] = dst


def consolidate_ffd(placement: Placement, *, now: float = 0.0,
                    state_bytes: Optional[Dict[str, float]] = None
                    ) -> Tuple[Placement, List[MigrationRequest]]:
    """First-fit-decreasing repack. Returns (new placement, migration plan).

    Target hosts are the most-loaded first (consolidate into few), jobs are
    placed largest-first; a job that lands on a different host than it
    occupies now yields a MigrationRequest carrying src/dst for the plane's
    link resolution.
    """
    jobs: List[Tuple[str, float, str]] = []
    for h in placement.hosts.values():
        for j, load in h.jobs.items():
            jobs.append((j, load, h.host_id))
    jobs.sort(key=lambda t: -t[1])

    order = sorted(placement.hosts.values(), key=lambda h: -h.load)
    new_p = Placement({h.host_id: Host(h.host_id, h.capacity) for h in order})
    plan: List[MigrationRequest] = []
    state_bytes = state_bytes or {}

    for job_id, load, src in jobs:
        for h in new_p.hosts.values():
            if h.free >= load:
                new_p.assign(job_id, h.host_id, load)
                if h.host_id != src:
                    plan.append(MigrationRequest(
                        job_id=job_id, created_at=now,
                        v_bytes=state_bytes.get(job_id, 0.0),
                        src=src, dst=h.host_id))
                break
        else:  # no capacity anywhere: keep in place
            new_p.assign(job_id, src, load)

    return new_p, plan


def hosts_used(placement: Placement) -> int:
    return sum(1 for h in placement.hosts.values() if h.jobs)
