"""Migration execution plane — batched, contention-aware pre-copy execution.

The seed executed each migration as an isolated scalar ``simulate_precopy``
call at full link bandwidth: concurrency cost nothing, so the ALMA-vs-
immediate gap the paper measures (Tables 6-7) was understated at fleet
scale. This plane advances ALL in-flight migrations together against the
shared-link network model (``core/network.py``):

  * every in-flight migration is a *lane* running the exact Strunk pre-copy
    round recurrence of ``core/strunk.py`` (round i copies the bytes
    dirtied during round i-1; the three Xen stop conditions; a final
    stop-and-copy transfer whose duration is the downtime);
  * a lane's bandwidth is its max-min fair share of the links on its
    src->dst path, recomputed at every event boundary — another migration
    starting, finishing, or completing a round changes everyone's share;
  * dirty bytes accrue per event chunk (rate sampled mid-chunk), which
    degenerates to the reference's mid-round sampling when a round runs
    uninterrupted — an uncontended single lane is bit-equal to
    ``strunk.simulate_precopy_reference`` (asserted in tests).

``advance(until)`` is the event loop: compute fair shares, find the
earliest round completion, move every lane forward by that chunk, settle
completed rounds, repeat. ``FleetSim`` drives it one sampling period at a
time; benchmarks drive it to drain. Per-link byte counters support the
conservation invariant (bytes through a link <= capacity x elapsed time)
and the link-utilization columns of the table6/7 benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import network, strunk

_COPY, _STOP = 0, 1


@dataclass
class _LaneMeta:
    req: object                          # orchestrator.MigrationRequest
    rate_fn: Optional[Callable[[float], float]]
    path: Tuple[str, ...]
    t_start: float


class MigrationPlane:
    """Event-driven executor for concurrent pre-copy migrations."""

    def __init__(self, topology: network.Topology, *,
                 page: int = strunk.PAGE,
                 max_rounds: int = strunk.XEN_MAX_ROUNDS,
                 stop_dirty_pages: int = strunk.XEN_STOP_DIRTY_PAGES,
                 stop_total_factor: float = strunk.XEN_STOP_TOTAL_FACTOR):
        self.topology = topology
        self.caps = topology.capacities
        self.max_rounds = max_rounds
        self.stop_total_factor = stop_total_factor
        self._thresh = float(stop_dirty_pages) * page
        self._fallback_bw = max(self.caps.values(), default=np.inf)
        self.now = 0.0
        self._meta: List[_LaneMeta] = []
        # completions produced by launch()'s internal catch-up advance are
        # parked here and handed to the caller on the next advance()
        self._backlog: List[Tuple[object, strunk.MigrationOutcome]] = []
        # SoA lane state, one row per in-flight migration
        self._v = np.zeros(0)            # migratable bytes
        self._rem = np.zeros(0)          # bytes left in the current transfer
        self._round = np.zeros(0)        # size of the current transfer
        self._acc = np.zeros(0)          # dirty bytes accrued this round
        self._sent = np.zeros(0)
        self._rounds = np.zeros(0, np.int64)
        self._down = np.zeros(0)
        self._phase = np.zeros(0, np.int8)
        self._reason = np.zeros(0, np.int8)
        self.link_bytes: Dict[str, float] = {}
        self.last_shares: Dict[str, float] = {}

    # -- introspection -------------------------------------------------------
    @property
    def in_flight(self) -> int:
        return len(self._meta)

    def jobs_in_flight(self) -> List[str]:
        return [m.req.job_id for m in self._meta]

    def probe_bandwidth(self, src: str, dst: str, extra: int = 0) -> float:
        """Fair-share bandwidth a NEW src->dst migration would receive right
        now, given everything already in flight — the realized-bandwidth
        signal the LMCM feeds into its deadline/cost decisions. ``extra``
        counts additional same-path launches already committed but not yet
        on the plane (a simultaneous release burst shares with itself)."""
        path = self.topology.path(src, dst)
        paths = [m.path for m in self._meta] + [path] * (extra + 1)
        share = float(network.fair_share(paths, self.caps)[-1])
        return share if np.isfinite(share) else self._fallback_bw

    # -- lifecycle -----------------------------------------------------------
    def launch(self, req, rate_fn: Optional[Callable[[float], float]],
               now: float, *, path: Optional[Sequence[str]] = None) -> None:
        """Start executing ``req`` at time ``now`` (>= plane time)."""
        if now > self.now:
            self._backlog.extend(self.advance(now))
        if rate_fn is not None and not callable(rate_fn):
            const = float(rate_fn)
            rate_fn = lambda _t: const
        p = tuple(path) if path is not None else \
            self.topology.path(req.src, req.dst)
        v = float(req.v_bytes)
        self._meta.append(_LaneMeta(req, rate_fn, p, now))
        self._v = np.append(self._v, v)
        self._rem = np.append(self._rem, v)
        self._round = np.append(self._round, v)
        self._acc = np.append(self._acc, 0.0)
        self._sent = np.append(self._sent, 0.0)
        self._rounds = np.append(self._rounds, 0)
        self._down = np.append(self._down, 0.0)
        self._phase = np.append(self._phase, _COPY)
        self._reason = np.append(self._reason, strunk.REASON_MAX_ROUNDS)

    def advance(self, until: float):
        """Run the event loop to ``until`` (or until drained); returns the
        list of (request, MigrationOutcome) completed in this window, plus
        any completions a launch-time catch-up produced earlier."""
        finished: List[Tuple[object, strunk.MigrationOutcome]] = \
            self._backlog
        self._backlog = []
        while self._meta and self.now < until:
            shares = network.fair_share([m.path for m in self._meta],
                                        self.caps)
            shares = np.where(np.isfinite(shares), shares, self._fallback_bw)
            t_done = np.where(
                self._rem <= 0.0, 0.0,
                np.divide(self._rem, shares,
                          out=np.full_like(self._rem, np.inf),
                          where=shares > 0))
            dt = min(float(t_done.min()), until - self.now)
            complete = t_done <= dt * (1 + 1e-12)
            mid = self.now + 0.5 * dt
            for i, meta in enumerate(self._meta):
                if self._phase[i] == _COPY and meta.rate_fn is not None:
                    self._acc[i] += max(0.0, float(meta.rate_fn(mid))) * dt
                moved = float(self._rem[i]) if complete[i] \
                    else float(shares[i]) * dt
                for l in meta.path:
                    self.link_bytes[l] = self.link_bytes.get(l, 0.0) + moved
            self._down = self._down + np.where(self._phase == _STOP, dt, 0.0)
            self._rem = np.where(complete, 0.0, self._rem - shares * dt)
            self.now += dt
            self.last_shares = {m.req.job_id: float(s)
                                for m, s in zip(self._meta, shares)}
            drop: List[int] = []
            for i in np.flatnonzero(complete):
                out = self._settle(int(i))
                if out is not None:
                    finished.append((self._meta[i].req, out))
                    drop.append(int(i))
            if drop:
                keep = [i for i in range(len(self._meta)) if i not in drop]
                self._meta = [self._meta[i] for i in keep]
                for name in ("_v", "_rem", "_round", "_acc", "_sent",
                             "_rounds", "_down", "_phase", "_reason"):
                    setattr(self, name, getattr(self, name)[keep])
        # an infinite drain must not poison the clock: time only ever
        # fast-forwards to a finite target
        if not self._meta and self.now < until and np.isfinite(until):
            self.now = until
        return finished

    def _settle(self, i: int) -> Optional[strunk.MigrationOutcome]:
        """A lane's current transfer just completed: close the round (apply
        the Xen stop conditions in the reference's priority order) or, if it
        was the stop-and-copy, produce the outcome."""
        if self._phase[i] == _COPY:
            self._sent[i] += self._round[i]
            self._rounds[i] += 1
            dirtied = min(float(self._v[i]), float(self._acc[i]))
            stop: Optional[int] = None
            if dirtied <= self._thresh:
                stop = strunk.REASON_DIRTY_LOW
            elif self._rounds[i] >= self.max_rounds:
                stop = strunk.REASON_MAX_ROUNDS
            elif self._sent[i] + dirtied > self.stop_total_factor * self._v[i]:
                stop = strunk.REASON_TOTAL_CAP
            self._round[i] = dirtied
            self._rem[i] = dirtied
            self._acc[i] = 0.0
            if stop is not None:
                self._phase[i] = _STOP
                self._reason[i] = stop
            return None
        self._sent[i] += self._round[i]
        meta = self._meta[i]
        return strunk.MigrationOutcome(
            total_time=self.now - meta.t_start,
            downtime=float(self._down[i]),
            bytes_sent=float(self._sent[i]),
            rounds=int(self._rounds[i]),
            stop_reason=strunk.STOP_REASONS[int(self._reason[i])])
