"""Migration execution plane — batched, contention-aware pre-copy execution.

The seed executed each migration as an isolated scalar ``simulate_precopy``
call at full link bandwidth: concurrency cost nothing, so the ALMA-vs-
immediate gap the paper measures (Tables 6-7) was understated at fleet
scale. This plane advances ALL in-flight migrations together against the
shared-link network model (``core/network.py``):

  * every in-flight migration is a *lane* running the exact Strunk pre-copy
    round recurrence of ``core/strunk.py`` (round i copies the bytes
    dirtied during round i-1; the three Xen stop conditions; a final
    stop-and-copy transfer whose duration is the downtime);
  * a lane's bandwidth is its max-min fair share of the links on its
    src->dst path, recomputed at every event boundary — another migration
    starting, finishing, or completing a round changes everyone's share;
  * dirty bytes accrue per event chunk (rate sampled mid-chunk), which
    degenerates to the reference's mid-round sampling when a round runs
    uninterrupted — an uncontended single lane is bit-equal to
    ``strunk.simulate_precopy_reference`` (asserted in tests).

``advance(until)`` is the event loop: compute fair shares, find the
earliest round completion, move every lane forward by that chunk, settle
completed rounds, repeat.

Two executions of each event chunk:

  * **vectorized** (default) — lanes register their dirty-rate spec with a
    ``rates.RateBank`` (``PiecewiseRate`` tables, constants, or plain
    callables; see ``core/rates.py`` for the lane-registration API), so
    dirty-byte accrual is ONE padded table lookup per chunk; link shares
    come from ``network.fair_share_dense`` over a cached link x lane
    incidence matrix; per-link byte counters are one matrix-vector
    product. No O(lanes) Python inside the event loop.
  * **scalar reference** (``vectorized=False``) — the original per-lane
    loop, kept as the executable specification. Uncontended lanes are
    bit-equal between the two (and to ``simulate_precopy_reference``);
    contended multi-link cases agree to float summation order.

``FleetSim`` drives the plane one sampling period at a time (through the
sharded fabric, ``core/fabric.py``); benchmarks drive it to drain.
Per-link byte counters support the conservation invariant (bytes through a
link <= capacity x elapsed time) and the link-utilization columns of the
table6/7 benchmarks. ``_absorb`` merges another plane's lanes in — the
fabric uses it when a new lane's path bridges two previously independent
migration domains.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import network, strunk
from repro.core.guard import MigrationGuard, expectation_of, throttled_spec
from repro.core.rates import RateBank, RateSpec, as_rate_table

_COPY, _STOP = 0, 1


@dataclass
class _LaneMeta:
    req: object                          # orchestrator.MigrationRequest
    spec: RateSpec                       # raw rate spec (table/const/callable)
    rate_fn: Optional[object]            # scalar callable view of ``spec``
    path: Tuple[str, ...]
    t_start: float
    path_ids: Optional[np.ndarray] = None  # Topology.ids_of(path) fast view
    spec0: RateSpec = None               # pre-throttle spec (None: unthrottled)


@dataclass
class LaneState:
    """Mid-round snapshot of one in-flight lane — one row of the
    receding-horizon sweep's in-flight repricing input. ``sent`` counts
    the bytes already charged to the lane's links (completed transfers
    plus the progressed part of the current one, the same accounting the
    abort path uses); the remaining fields map 1:1 onto
    ``strunk.ResumeState`` so a what-if batch can resume the lane under
    hypothetical fair shares."""
    job_id: str
    path: Tuple[str, ...]
    spec: RateSpec
    v: float
    rem: float
    acc: float
    sent: float
    rounds: int
    stopped: bool
    reason: int


class MigrationPlane:
    """Event-driven executor for concurrent pre-copy migrations."""

    # SoA lane arrays, resized together on every launch/drop/merge
    _SOA_FIELDS = ("_v", "_rem", "_round", "_acc", "_sent", "_rounds",
                   "_down", "_phase", "_reason", "_exp_b", "_exp_t",
                   "_t0", "_thr", "_thr_round")

    def __init__(self, topology: network.Topology, *,
                 page: int = strunk.PAGE,
                 max_rounds: int = strunk.XEN_MAX_ROUNDS,
                 stop_dirty_pages: int = strunk.XEN_STOP_DIRTY_PAGES,
                 stop_total_factor: float = strunk.XEN_STOP_TOTAL_FACTOR,
                 vectorized: bool = True,
                 guard: Optional[MigrationGuard] = None):
        self.topology = topology
        self._guard = guard
        self.caps = topology.capacities
        # id-indexed snapshot of ``caps`` (aligned with topology.link_ids):
        # the integer fast path of probe_bandwidth/path_capacity reads
        # this; set_link_capacity keeps it in sync with the dict
        self._caps_all = topology.caps_vector().copy()
        self.max_rounds = max_rounds
        self.stop_total_factor = stop_total_factor
        self.vectorized = vectorized
        self._thresh = float(stop_dirty_pages) * page
        self._fallback_bw = max(self.caps.values(), default=np.inf)
        self.now = 0.0
        self._meta: List[_LaneMeta] = []
        # completions produced by launch()'s internal catch-up advance are
        # parked here and handed to the caller on the next advance()
        self._backlog: List[Tuple[object, strunk.MigrationOutcome]] = []
        # SoA lane state, one row per in-flight migration
        self._v = np.zeros(0)            # migratable bytes
        self._rem = np.zeros(0)          # bytes left in the current transfer
        self._round = np.zeros(0)        # size of the current transfer
        self._acc = np.zeros(0)          # dirty bytes accrued this round
        self._sent = np.zeros(0)
        self._rounds = np.zeros(0, np.int64)
        self._down = np.zeros(0)
        self._phase = np.zeros(0, np.int8)
        self._reason = np.zeros(0, np.int8)
        # prediction-guard rows (core/guard.py): admission-time expectation
        # (NaN = unguarded lane), launch clock, throttle-ladder step, and
        # the round count at the last escalation (one step per round)
        self._exp_b = np.zeros(0)
        self._exp_t = np.zeros(0)
        self._t0 = np.zeros(0)
        self._thr = np.zeros(0, np.int64)
        self._thr_round = np.zeros(0, np.int64)
        # vectorized-chunk banks: extended in place on launch/merge,
        # rebuilt lazily only after lane drops. Membership fair shares
        # and scratch sizing are deferred separately (_shares_stale): a
        # release burst extends the banks B times but solves ONCE, at
        # the next advance.
        self._banks_stale = True
        self._shares_stale = False
        self._rates: Optional[RateBank] = None
        self._link_order: List[str] = []
        self._link_row: Dict[str, int] = {}
        self._inc = np.zeros((0, 0))         # (L, M) float incidence
        self._caps_vec = np.zeros(0)
        self._link_vec = np.zeros(0)         # per-chunk byte accumulator
        self._job_ids: List[str] = []
        # persistent accounting
        self._link_bytes: Dict[str, float] = {}
        self._share_jobs: List[str] = []
        self._share_vec = np.zeros(0)
        self._link_set_cache: Optional[frozenset] = frozenset()

    # -- introspection -------------------------------------------------------
    @property
    def in_flight(self) -> int:
        return len(self._meta)

    def jobs_in_flight(self) -> List[str]:
        return [m.req.job_id for m in self._meta]

    def paths_in_flight(self) -> List[Tuple[str, ...]]:
        """Network path of every in-flight lane (the fabric's probe input)."""
        return [m.path for m in self._meta]

    def ids_in_flight(self) -> List[Optional[np.ndarray]]:
        """Precomputed link-id array per in-flight lane (None where a
        lane's path has links unknown to the topology — the probe fast
        path falls back to the dict walk)."""
        return [m.path_ids for m in self._meta]

    @property
    def link_set(self) -> frozenset:
        """Links any in-flight lane touches — the plane's migration domain.
        Cached (the fabric reads it per launch/probe across every domain);
        launches extend it incrementally, drops invalidate it."""
        if self._link_set_cache is None:
            self._link_set_cache = frozenset(
                l for m in self._meta for l in m.path)
        return self._link_set_cache

    @property
    def link_bytes(self) -> Dict[str, float]:
        """Bytes moved per link so far (completed + in-flight chunks)."""
        self._fold_link_vec()
        return dict(self._link_bytes)

    @property
    def last_shares(self) -> Dict[str, float]:
        """Fair-share bandwidth per job at the most recent event boundary."""
        return {j: float(s) for j, s in zip(self._share_jobs,
                                            self._share_vec)}

    def probe_bandwidth(self, src: str, dst: str, extra: int = 0,
                        pending: Sequence[Sequence[str]] = ()) -> float:
        """Fair-share bandwidth a NEW src->dst migration would receive right
        now, given everything already in flight — the realized-bandwidth
        signal the LMCM feeds into its deadline/cost decisions. ``pending``
        carries the ACTUAL paths of co-launches committed in the same
        release burst but not yet on the plane; ``extra`` approximates
        further committed launches as same-path clones (the legacy,
        conservative-on-multilink form).

        Hot path: when every path resolves through the topology's
        precomputed link-id tables, the solve runs over integer arrays
        (``network.fair_share_ids`` — bit-parity mirror of the dict walk);
        any unknown link falls back to the dict oracle wholesale."""
        topo = self.topology
        path = topo.path(src, dst)
        ids = topo.ids_of(path)
        if ids is not None and \
                all(m.path_ids is not None for m in self._meta):
            pend_ids = [topo.ids_of(tuple(p)) for p in pending]
            if all(p is not None for p in pend_ids):
                id_paths = [m.path_ids for m in self._meta]
                id_paths += pend_ids + [ids] * (extra + 1)
                share = float(network.fair_share_ids(
                    id_paths, self._caps_all)[-1])
                return share if np.isfinite(share) else self._fallback_bw
        paths = [m.path for m in self._meta]
        paths += [tuple(p) for p in pending]
        paths += [path] * (extra + 1)
        share = float(network.fair_share(paths, self.caps)[-1])
        return share if np.isfinite(share) else self._fallback_bw

    def what_if_shares(self, new_paths: Sequence[Sequence[str]]
                       ) -> np.ndarray:
        """Max-min fair shares the hypothetical lanes ``new_paths`` would
        realize if all launched right now alongside everything in flight —
        the adaptive concurrency controller's candidate-batch input.
        Returns one share per new path (unlinked lanes get the fallback
        bandwidth)."""
        pend = [tuple(p) for p in new_paths]
        if not pend:
            return np.zeros(0)
        paths = [m.path for m in self._meta] + pend
        shares = network.fair_share(paths, self.caps)[len(self._meta):]
        return np.where(np.isfinite(shares), shares, self._fallback_bw)

    def what_if_shares_sweep(self, fixed_paths: Sequence[Sequence[str]],
                             cand_paths: Sequence[Sequence[str]]
                             ) -> np.ndarray:
        """All n+1 nested what-if batches of the defer-k sweep in ONE
        stacked solve: row k holds the fair shares of the F ``fixed_paths``
        lanes plus the first k ``cand_paths`` lanes, all launched right now
        alongside everything in flight (columns past F+k are inactive and
        read 0). Equivalent to n+1 ``what_if_shares`` calls over growing
        prefixes; see ``network.fair_share_masked``."""
        return network.what_if_prefix_shares(
            [m.path for m in self._meta], fixed_paths, cand_paths,
            self.caps, self._fallback_bw)

    def what_if_pair_shares(self, fixed_paths: Sequence[Sequence[str]],
                            pair_paths: Sequence[Sequence[str]]
                            ) -> np.ndarray:
        """Fair share each (candidate, route) pair would realize ON ITS OWN
        against everything in flight plus the ``fixed_paths`` lanes — the
        route-selection stage of the defer-k x route sweep, all pairs in
        one stacked solve (see ``network.what_if_pair_shares``)."""
        return network.what_if_pair_shares(
            [m.path for m in self._meta], fixed_paths, pair_paths,
            self.caps, self._fallback_bw)

    def what_if_subset_shares(self, fixed_paths: Sequence[Sequence[str]],
                              cand_paths: Sequence[Sequence[str]],
                              masks) -> np.ndarray:
        """Fair shares of K arbitrary candidate subsets in one stacked
        solve, KEEPING the in-flight base columns: row k holds the shares
        of every in-flight lane, every ``fixed_paths`` lane, and the
        ``cand_paths`` lanes selected by ``masks[k]``. The receding-
        horizon generalization of ``what_if_shares_sweep`` — base columns
        let the sweep reprice mid-flight lanes per scenario (see
        ``lane_state``), and arbitrary masks price non-prefix subsets."""
        return network.what_if_subset_shares(
            [m.path for m in self._meta], fixed_paths, cand_paths, masks,
            self.caps, self._fallback_bw)

    def lane_state(self, links=None) -> List[LaneState]:
        """Per-lane mid-round snapshots in ``paths_in_flight`` order (the
        base-column order of the what-if solves). ``links`` is accepted
        for interface parity with ``fabric.ShardedPlane`` — a monolithic
        plane is one domain, so every lane is returned regardless."""
        out: List[LaneState] = []
        for i, m in enumerate(self._meta):
            out.append(LaneState(
                job_id=m.req.job_id, path=m.path, spec=m.spec,
                v=float(self._v[i]), rem=float(self._rem[i]),
                acc=float(self._acc[i]),
                sent=max(0.0, float(self._sent[i]
                                    + (self._round[i] - self._rem[i]))),
                rounds=int(self._rounds[i]),
                stopped=bool(self._phase[i] == _STOP),
                reason=int(self._reason[i])))
        return out

    def path_capacity(self, src: str, dst: str) -> float:
        """Uncontended capacity of the src->dst path: the tightest link a
        lone migration would traverse (the launch gate's floor reference —
        a cross-rack transfer can never exceed its ToR/core bottleneck, so
        gating it against the nominal access speed would starve it)."""
        path = self.topology.path(src, dst)
        if not path:
            return self._fallback_bw
        ids = self.topology.ids_of(path)
        if ids is not None:
            return float(self._caps_all[ids].min())
        return min(self.caps[l] for l in path)

    def link_live_counts(self) -> Dict[str, int]:
        """In-flight lane count per link (route de-confliction input for
        ``pick_route`` and the controller's greedy route assignment)."""
        counts: Dict[str, int] = {}
        for m in self._meta:
            for l in dict.fromkeys(m.path):
                counts[l] = counts.get(l, 0) + 1
        return counts

    def pick_route(self, src: str, dst: str,
                   pending: Sequence[Sequence[str]] = ()
                   ) -> Tuple[str, ...]:
        """The candidate route a src->dst launch should ride right now:
        best probed fair share against everything in flight (plus
        ``pending`` same-burst co-launches), ties broken toward fewer live
        lanes on the route's links, then the lowest route index — i.e. the
        fixed-shortest path. Single-route (flat) pairs return ``path()``
        unchanged. This is the launch-time greedy the benchmarks' "route-
        aware" mode uses when no admission controller is wired in; the
        controller's stacked sweep prices routes through
        ``what_if_pair_shares`` instead."""
        routes = self.topology.routes(src, dst)
        if len(routes) == 1:
            return routes[0]
        shares = self.what_if_pair_shares(
            [tuple(p) for p in pending], list(routes))
        live = self.link_live_counts()
        best, best_key = 0, None
        for j, r in enumerate(routes):
            load = sum(live.get(l, 0) for l in r)
            key = (float(shares[j]), -load, -j)
            if best_key is None or key > best_key:
                best, best_key = j, key
        return routes[best]

    def domain_links(self) -> List[frozenset]:
        """Link sets of the live migration domains — a monolithic plane is
        one domain (interface parity with ``fabric.ShardedPlane``)."""
        return [self.link_set] if self._meta else []

    # -- fault injection -----------------------------------------------------
    def set_link_capacity(self, link: str, capacity: float) -> None:
        """Apply a capacity change (degradation, failure at 0.0, or
        restoration) to this plane's view of ``link``. The link keeps its
        identity — paths, incidence, and domain membership are unchanged —
        and the next event chunk's fair-share solve sees the new value
        (a 0-capacity link freezes its flows at share 0; every solver
        stays finite, the lanes simply stall until restored)."""
        capacity = float(capacity)
        self.caps[link] = capacity
        self._fallback_bw = max(self.caps.values(), default=np.inf)
        idx = self.topology.link_ids.get(link)
        if idx is not None:
            self._caps_all[idx] = capacity
        row = self._link_row.get(link)
        if row is not None and row < len(self._caps_vec):
            self._caps_vec[row] = capacity
            self._shares_stale = True    # banks stay valid; re-solve only

    def abort(self, job_id: str
              ) -> List[Tuple[object, strunk.MigrationOutcome]]:
        """Settle ``job_id``'s in-flight lane early. Returns ``[]`` when
        the job is not in flight, else one ``(request, outcome)`` pair
        whose ``stop_reason`` is ``strunk.STOP_ABORTED``. ``bytes_sent``
        counts exactly the bytes already charged to the lane's links —
        completed transfers plus the partial current one — so per-link
        byte conservation holds across abort -> retry."""
        return self._abort_rows(
            [i for i, m in enumerate(self._meta)
             if m.req.job_id == job_id])

    def fail_host(self, host: str
                  ) -> List[Tuple[object, strunk.MigrationOutcome]]:
        """Abort every in-flight lane with ``host`` as an endpoint (a
        dead source kills the copy at its origin; a dead destination
        loses the state already received)."""
        return self._abort_rows(
            [i for i, m in enumerate(self._meta)
             if m.req.src == host or m.req.dst == host])

    def abort_link(self, link: str
                   ) -> List[Tuple[object, strunk.MigrationOutcome]]:
        """Abort every in-flight lane whose path crosses ``link`` — a
        hard ToR/pod-uplink outage kills the transfers riding it while
        lanes on other routes are untouched (unlike a degradation to 0.0,
        which stalls flows in place until restored). The capacity change
        itself is the caller's move (``set_link_capacity(link, 0.0)``)."""
        return self._abort_rows(
            [i for i, m in enumerate(self._meta) if link in m.path])

    def _abort_rows(self, rows: List[int],
                    stop_reason: str = strunk.STOP_ABORTED
                    ) -> List[Tuple[object, strunk.MigrationOutcome]]:
        """Drop the lanes at ``rows`` through the same keep-index path a
        completion uses (banks rebuild lazily; the link-set cache and
        drained union-find incarnations are the fabric's to release).
        ``stop_reason`` distinguishes fault aborts (``STOP_ABORTED``) from
        convergence-guard aborts (``STOP_GUARD``)."""
        if not rows:
            return []
        aborted: List[Tuple[object, strunk.MigrationOutcome]] = []
        for i in rows:
            m = self._meta[i]
            # bytes already charged to the links: completed transfers
            # (_sent) plus the progressed part of the current one
            partial = float(self._sent[i] + (self._round[i] - self._rem[i]))
            aborted.append((m.req, strunk.MigrationOutcome(
                total_time=self.now - m.t_start,
                downtime=float(self._down[i]),
                bytes_sent=max(0.0, partial),
                rounds=int(self._rounds[i]),
                stop_reason=stop_reason)))
        dead = set(rows)
        keep = [i for i in range(len(self._meta)) if i not in dead]
        self._meta = [self._meta[i] for i in keep]
        for name in self._SOA_FIELDS:
            setattr(self, name, getattr(self, name)[keep])
        self._banks_stale = True
        self._link_set_cache = None
        return aborted

    # -- lifecycle -----------------------------------------------------------
    def launch(self, req, rate: RateSpec, now: float, *,
               path: Optional[Sequence[str]] = None,
               expect: Optional[Tuple[float, float]] = None) -> None:
        """Start executing ``req`` at time ``now`` (>= plane time).

        ``rate`` is the lane's dirty-rate spec — a ``rates.PiecewiseRate``
        table (preferred: the vectorized event loop accrues its dirty bytes
        through one batched lookup), a constant, an object exposing
        ``rate_table``, a plain callable of absolute time (compatibility:
        sampled per lane per event), or None.

        ``expect`` is the lane's admission-time prediction,
        ``(expected_bytes, expected_time)`` as priced by the controller's
        cost batch; defaults to the ``expected_bytes``/``expected_time``
        attributes stamped on ``req`` (NaN when absent). When the plane
        carries a ``MigrationGuard``, lanes whose realized progress
        diverges from this expectation are throttled then aborted (see
        ``core/guard.py``); without an expectation a lane is exempt.
        """
        if now > self.now:
            self._backlog.extend(self.advance(now))
        if rate is None or callable(rate):
            rate_fn = rate               # PiecewiseRate is itself callable
        else:
            # constants and objects exposing ``rate_table`` normalize to a
            # table, which doubles as the scalar-path callable
            rate = rate_fn = as_rate_table(rate)
        p = tuple(path) if path is not None else \
            self.topology.path(req.src, req.dst)
        v = float(req.v_bytes)
        meta = _LaneMeta(req, rate, rate_fn, p, now,
                         path_ids=self.topology.ids_of(p))
        self._meta.append(meta)
        self._v = np.append(self._v, v)
        self._rem = np.append(self._rem, v)
        self._round = np.append(self._round, v)
        self._acc = np.append(self._acc, 0.0)
        self._sent = np.append(self._sent, 0.0)
        self._rounds = np.append(self._rounds, 0)
        self._down = np.append(self._down, 0.0)
        self._phase = np.append(self._phase, _COPY)
        self._reason = np.append(self._reason, strunk.REASON_MAX_ROUNDS)
        exp_b, exp_t = expect if expect is not None else expectation_of(req)
        self._exp_b = np.append(self._exp_b, float(exp_b))
        self._exp_t = np.append(self._exp_t, float(exp_t))
        self._t0 = np.append(self._t0, now)
        self._thr = np.append(self._thr, 0)
        self._thr_round = np.append(self._thr_round, -1)
        if self._banks_fresh:
            self._extend_banks(meta)     # O(1) Python, no membership rescan
        else:
            self._banks_stale = True
        if self._link_set_cache is not None:
            self._link_set_cache = self._link_set_cache | frozenset(p)

    def _fold_link_vec(self) -> None:
        """Flush the vectorized per-chunk link accumulator into the
        persistent per-link byte dict."""
        if self._link_vec.any():
            for l, b in zip(self._link_order, self._link_vec):
                self._link_bytes[l] = self._link_bytes.get(l, 0.0) + float(b)
            self._link_vec[:] = 0.0

    def _rebuild_banks(self) -> None:
        """Re-derive the rate bank, link incidence, caps vector, and the
        event-chunk scratch buffers from the current lane membership
        (lazily, after lane drops — launches and domain merges extend the
        banks in place instead, see ``_extend_banks``/``_merge_banks``)."""
        self._fold_link_vec()
        self._rates = RateBank([m.spec for m in self._meta])
        self._inc, self._caps_vec, self._link_order, self._link_row = \
            network.build_incidence([m.path for m in self._meta],
                                    self.caps)
        self._link_vec = np.zeros(len(self._link_order))
        self._job_ids = [m.req.job_id for m in self._meta]
        self._refresh_shares()
        self._alloc_scratch()
        self._banks_stale = False
        self._shares_stale = False

    def _refresh_shares(self) -> None:
        # fair shares are a function of lane MEMBERSHIP only (paths + link
        # capacities — not of per-round state), so one solve per
        # rebuild/extend/merge covers every chunk until the next
        # launch/drop/merge
        shares = network.DenseFairShare(self._inc, self._caps_vec)()
        np.copyto(shares, self._fallback_bw, where=~np.isfinite(shares))
        self._share_cache = shares

    def _alloc_scratch(self) -> None:
        # per-chunk scratch: the event loop below is all in-place ufuncs
        n = len(self._meta)
        self._b_tdone = np.empty(n)
        self._b_mask = np.empty(n, bool)
        self._b_complete = np.empty(n, bool)
        self._b_copy = np.empty(n, bool)
        self._b_f1 = np.empty(n)
        self._b_f2 = np.empty(n)
        self._b_moved = np.empty(n)
        self._b_ltmp = np.empty(len(self._link_order))

    @property
    def _banks_fresh(self) -> bool:
        return self.vectorized and not self._banks_stale \
            and self._rates is not None

    def _extend_banks(self, meta: _LaneMeta) -> None:
        """Append one freshly launched lane to the live banks in place —
        the launch-time alternative to a full ``_rebuild_banks`` (no
        per-lane Python over the existing membership). Produces exactly
        the state a rebuild would: new links keep first-appearance order
        (the new lane is last), table rows gather/concatenate into the
        identical padded layout, and the membership fair-share solve runs
        over the extended incidence."""
        self._rates = RateBank.concat(self._rates, RateBank([meta.spec]))
        new_links = [l for l in dict.fromkeys(meta.path)
                     if l not in self._link_row]
        for l in new_links:
            self._link_row[l] = len(self._link_order)
            self._link_order.append(l)
        n_links, n = len(self._link_order), len(self._meta)
        inc = np.zeros((n_links, n))
        inc[:self._inc.shape[0], :self._inc.shape[1]] = self._inc
        for l in dict.fromkeys(meta.path):
            inc[self._link_row[l], n - 1] = 1.0
        self._inc = inc
        if new_links:
            self._caps_vec = np.concatenate(
                [self._caps_vec, [self.caps[l] for l in new_links]])
            self._link_vec = np.concatenate(
                [self._link_vec, np.zeros(len(new_links))])
        self._job_ids.append(meta.req.job_id)
        self._shares_stale = True        # ONE solve at the next advance

    def _merge_banks(self, other: "MigrationPlane") -> None:
        """Stitch ``other``'s live banks onto this plane's — the
        domain-merge alternative to a full rebuild. The two domains are
        disjoint by construction (they merge because a NEW lane bridges
        them), so the merged incidence is block-diagonal and the link
        order is this plane's followed by the other's — exactly what a
        rebuild over the concatenated lane list derives."""
        self._rates = RateBank.concat(self._rates, other._rates)
        off = len(self._link_order)
        for l in other._link_order:
            self._link_row[l] = off + other._link_row[l]
        self._link_order = self._link_order + other._link_order
        l1, m1 = self._inc.shape
        l2, m2 = other._inc.shape
        inc = np.zeros((l1 + l2, m1 + m2))
        inc[:l1, :m1] = self._inc
        inc[l1:, m1:] = other._inc
        self._inc = inc
        self._caps_vec = np.concatenate([self._caps_vec, other._caps_vec])
        self._link_vec = np.zeros(l1 + l2)   # both folded by the caller
        self._job_ids = self._job_ids + other._job_ids
        self._shares_stale = True        # ONE solve at the next advance

    def advance(self, until: float):
        """Run the event loop to ``until`` (or until drained); returns the
        list of (request, MigrationOutcome) completed in this window, plus
        any completions a launch-time catch-up produced earlier."""
        finished: List[Tuple[object, strunk.MigrationOutcome]] = \
            self._backlog
        self._backlog = []
        if not self._meta:
            # a mass abort can empty the plane between advances: the
            # clean no-op is a clock fast-forward plus backlog handoff —
            # no bank rebuild or fair-share solve over zero lanes
            if self.now < until and np.isfinite(until):
                self.now = until
            self._fold_link_vec()
            return finished
        while self._meta and self.now < until:
            if self.vectorized:
                if self._banks_stale:
                    self._rebuild_banks()
                elif self._shares_stale:
                    self._refresh_shares()
                    self._alloc_scratch()
                    self._shares_stale = False
                # membership-cached fair shares + time-to-completion
                mask, t_done = self._b_mask, self._b_tdone
                shares = self._share_cache
                t_done.fill(np.inf)
                np.greater(shares, 0.0, out=mask)
                np.divide(self._rem, shares, out=t_done, where=mask)
                np.less_equal(self._rem, 0.0, out=mask)
                np.copyto(t_done, 0.0, where=mask)
            else:
                shares = network.fair_share([m.path for m in self._meta],
                                            self.caps)
                shares = np.where(np.isfinite(shares), shares,
                                  self._fallback_bw)
                t_done = np.where(
                    self._rem <= 0.0, 0.0,
                    np.divide(self._rem, shares,
                              out=np.full_like(self._rem, np.inf),
                              where=shares > 0))
            window = until - self.now
            t_min = float(t_done.min())
            # a chunk truncated by the window must land the clock on
            # ``until`` EXACTLY (now + (until - now) != until in floats):
            # the fabric merges domains only at equal event times
            truncated = not (t_min < window)
            dt = window if truncated else t_min
            mid = self.now + 0.5 * dt
            if self.vectorized:
                complete, copying = self._b_complete, self._b_copy
                np.less_equal(t_done, dt * (1 + 1e-12), out=complete)
                np.equal(self._phase, _COPY, out=copying)
                f1, f2, moved = self._b_f1, self._b_f2, self._b_moved
                # dirty accrual: max(0, r)*dt, exactly zeroed off-copy lanes
                r = self._rates.sample(mid, copying)
                np.maximum(r, 0.0, out=f1)
                np.multiply(f1, dt, out=f1)
                np.multiply(f1, copying, out=f1)
                np.add(self._acc, f1, out=self._acc)
                # per-link byte counters: one matvec over the incidence
                np.multiply(shares, dt, out=moved)
                np.copyto(moved, self._rem, where=complete)
                np.matmul(self._inc, moved, out=self._b_ltmp)
                np.add(self._link_vec, self._b_ltmp, out=self._link_vec)
                # downtime accrues on stop-and-copy lanes (= not copying)
                np.subtract(1.0, copying, out=f2)
                np.multiply(f2, dt, out=f2)
                np.add(self._down, f2, out=self._down)
                np.multiply(shares, dt, out=f1)
                np.subtract(self._rem, f1, out=self._rem)
                np.copyto(self._rem, 0.0, where=complete)
                self._share_jobs = self._job_ids
            else:
                complete = t_done <= dt * (1 + 1e-12)
                for i, meta in enumerate(self._meta):
                    if self._phase[i] == _COPY and meta.rate_fn is not None:
                        self._acc[i] += \
                            max(0.0, float(meta.rate_fn(mid))) * dt
                    moved = float(self._rem[i]) if complete[i] \
                        else float(shares[i]) * dt
                    for l in meta.path:
                        self._link_bytes[l] = \
                            self._link_bytes.get(l, 0.0) + moved
                self._down = self._down + np.where(self._phase == _STOP,
                                                   dt, 0.0)
                self._rem = np.where(complete, 0.0,
                                     self._rem - shares * dt)
                self._share_jobs = [m.req.job_id for m in self._meta]
            # the clock may only land PAST ``until`` through float rounding
            # (now + dt can round up even when dt < until - now): clamp, so
            # domain merges always meet at the advance target
            nxt = self.now + dt
            self.now = until if (truncated or nxt > until) else nxt
            self._share_vec = shares
            drop: List[int] = []
            for i in np.flatnonzero(complete):
                out = self._settle(int(i))
                if out is not None:
                    finished.append((self._meta[i].req, out))
                    drop.append(int(i))
            if drop:
                keep = [i for i in range(len(self._meta)) if i not in drop]
                self._meta = [self._meta[i] for i in keep]
                for name in self._SOA_FIELDS:
                    setattr(self, name, getattr(self, name)[keep])
                self._banks_stale = True
                self._link_set_cache = None
            # convergence watchdog: every settle is a round boundary for
            # some lane — re-check the whole fleet's realized-vs-predicted
            # divergence (one vectorized pass; guard aborts flow out
            # through ``finished`` like any completion, so the fabric's
            # link-release path needs no special casing)
            if self._guard is not None and self._meta and complete.any():
                finished.extend(self._guard_check())
        # window boundary check: catches time divergence on lanes that
        # never settle inside this advance (e.g. stalled at share 0)
        if self._guard is not None and self._meta:
            finished.extend(self._guard_check())
        # an infinite drain must not poison the clock: time only ever
        # fast-forwards to a finite target
        if not self._meta and self.now < until and np.isfinite(until):
            self.now = until
        self._fold_link_vec()
        return finished

    # -- prediction guard ----------------------------------------------------
    def _guard_check(self) -> List[Tuple[object, strunk.MigrationOutcome]]:
        """One vectorized watchdog pass over every in-flight lane: compare
        realized progress (the abort path's exact byte accounting) against
        the admission-time expectation and fire the policy ladder —
        auto-converge throttling at ``throttle_ratio``, abort-and-retry
        with ``stop_reason == strunk.STOP_GUARD`` at ``abort_ratio``.
        Lanes already in stop-and-copy are left to finish (aborting a
        migration during its final downtime burst only wastes it)."""
        g = self._guard
        sent = np.maximum(0.0, self._sent + (self._round - self._rem))
        div = g.divergence(sent, self.now - self._t0,
                           self._exp_b, self._exp_t)
        copying = self._phase == _COPY
        abort = copying & (div >= g.abort_ratio)
        throttle = copying & ~abort & (div >= g.throttle_ratio)
        # escalate the ladder at most once per pre-copy round: a diverged
        # lane mid-round keeps its current cap until the next settle
        throttle &= self._rounds > self._thr_round
        for i in np.flatnonzero(throttle):
            self._throttle_row(int(i))
        if not abort.any():
            return []
        rows = [int(i) for i in np.flatnonzero(abort)]
        g.n_aborts += len(rows)
        return self._abort_rows(rows, stop_reason=strunk.STOP_GUARD)

    def _throttle_row(self, i: int) -> None:
        """Apply the next auto-converge step to lane ``i``: swap its spec
        for a progressively scaled table (``guard.throttled_spec`` — the
        composable transform every repricing consumer shares) and flag the
        banks for a lazy rebuild. Past the throttle floor the ladder stops
        escalating and only the abort rung remains."""
        g = self._guard
        self._thr_round[i] = int(self._rounds[i])
        f = g.factor_for(int(self._thr[i]) + 1)
        if f is None:
            return
        m = self._meta[i]
        if m.spec0 is None:
            m.spec0 = m.spec
        spec = throttled_spec(m.spec0, f)
        m.spec = spec
        m.rate_fn = spec if (spec is None or callable(spec)) \
            else as_rate_table(spec)
        self._thr[i] += 1
        g.n_throttles += 1
        self._banks_stale = True

    def _settle(self, i: int) -> Optional[strunk.MigrationOutcome]:
        """A lane's current transfer just completed: close the round (apply
        the Xen stop conditions in the reference's priority order) or, if it
        was the stop-and-copy, produce the outcome."""
        if self._phase[i] == _COPY:
            self._sent[i] += self._round[i]
            self._rounds[i] += 1
            dirtied = min(float(self._v[i]), float(self._acc[i]))
            stop: Optional[int] = None
            if dirtied <= self._thresh:
                stop = strunk.REASON_DIRTY_LOW
            elif self._rounds[i] >= self.max_rounds:
                stop = strunk.REASON_MAX_ROUNDS
            elif self._sent[i] + dirtied > self.stop_total_factor * self._v[i]:
                stop = strunk.REASON_TOTAL_CAP
            self._round[i] = dirtied
            self._rem[i] = dirtied
            self._acc[i] = 0.0
            if stop is not None:
                self._phase[i] = _STOP
                self._reason[i] = stop
            return None
        self._sent[i] += self._round[i]
        meta = self._meta[i]
        return strunk.MigrationOutcome(
            total_time=self.now - meta.t_start,
            downtime=float(self._down[i]),
            bytes_sent=float(self._sent[i]),
            rounds=int(self._rounds[i]),
            stop_reason=strunk.STOP_REASONS[int(self._reason[i])])

    # relative event-clock tolerance for domain merges: the fabric advances
    # both planes to a common target before bridging, and truncated chunks
    # land on ``until`` exactly — but the vectorized path's in-place ufunc
    # summation can leave a freshly drained/launched domain within a few
    # ULPs of the target (float addition order), so merges accept clocks
    # equal to within this relative epsilon and snap to the host plane's.
    ABSORB_EPS = 1e-9

    def _absorb(self, other: "MigrationPlane") -> None:
        """Merge ``other``'s in-flight lanes into this plane — both planes
        must sit at the same event time (the fabric advances them to a
        common ``now`` before bridging two migration domains), equal to
        within ``ABSORB_EPS`` relative (see above)."""
        tol = self.ABSORB_EPS * max(1.0, abs(self.now), abs(other.now))
        if not (abs(other.now - self.now) <= tol):   # NaN-safe: also rejects
            raise ValueError(f"cannot absorb plane at t={other.now} "
                             f"into plane at t={self.now}")
        other.now = self.now                         # snap within tolerance
        other._fold_link_vec()
        self._fold_link_vec()
        # disjoint domains (the normal fabric merge) stitch their live
        # banks instead of flagging a rebuild; overlapping-link planes
        # (possible when called directly) fall back to the lazy rebuild
        incremental = (self._banks_fresh and other._banks_fresh
                       and not any(l in self._link_row
                                   for l in other._link_order))
        self._meta.extend(other._meta)
        for name in self._SOA_FIELDS:
            setattr(self, name, np.concatenate(
                [getattr(self, name), getattr(other, name)]))
        for l, b in other._link_bytes.items():
            self._link_bytes[l] = self._link_bytes.get(l, 0.0) + b
        self._backlog.extend(other._backlog)
        other._meta, other._backlog = [], []
        if incremental:
            self._merge_banks(other)
        else:
            self._banks_stale = True
        self._link_set_cache = None
        other._link_set_cache = None
