"""Pre-copy live-migration cost model (paper §3.2, Strunk's bounds).

Implements Inequalities 1–2 and an iterative pre-copy simulator with the Xen
stop conditions the paper lists: (i) fewer than ``stop_dirty_pages`` dirty
pages since the last round, (ii) at most ``max_rounds`` rounds, (iii) total
transfer capped at ``stop_total_factor`` x V_mem. Dirty rate may be a
constant or a callable of absolute time, which is how the fleet simulator
injects the *workload-phase-dependent* dirty rate — the whole point of the
paper: the same migration started in an NLM phase costs multiples of one
started in an LM phase.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Tuple, Union

DirtyRate = Union[float, Callable[[float], float]]

PAGE = 4096
XEN_MAX_ROUNDS = 29
XEN_STOP_DIRTY_PAGES = 50
XEN_STOP_TOTAL_FACTOR = 3.0


def strunk_bounds(v_mem: float, bandwidth: float,
                  max_rounds: int = XEN_MAX_ROUNDS) -> Tuple[float, float]:
    """(T_mig lower, upper) per Inequality 1: V/B <= T <= (M+1)V/B."""
    return v_mem / bandwidth, (max_rounds + 1) * v_mem / bandwidth


@dataclass
class MigrationOutcome:
    total_time: float          # paper's 'live migration total time'
    downtime: float            # stop-and-copy duration
    bytes_sent: float          # 'network data transfer'
    rounds: int
    stop_reason: str


def simulate_precopy(v_mem: float, bandwidth: float, dirty_rate: DirtyRate,
                     *, start_time: float = 0.0, page: int = PAGE,
                     max_rounds: int = XEN_MAX_ROUNDS,
                     stop_dirty_pages: int = XEN_STOP_DIRTY_PAGES,
                     stop_total_factor: float = XEN_STOP_TOTAL_FACTOR,
                     ) -> MigrationOutcome:
    """Iterative pre-copy (paper §3.2 five-stage algorithm, stages 2–3).

    Round 0 copies all of V_mem; round i copies the bytes dirtied during
    round i-1. ``dirty_rate(t)`` is sampled at absolute time ``t`` so cyclic
    workloads produce cyclic migration costs.
    """
    rate = dirty_rate if callable(dirty_rate) else (lambda _t: float(dirty_rate))
    t = start_time
    sent = 0.0
    to_copy = v_mem
    rounds = 0
    reason = "max_rounds"
    while True:
        dt = to_copy / bandwidth
        # dirty bytes accrued while this round's copy is in flight (sample the
        # rate midway through the round — adequate for piecewise traces)
        dirtied = min(v_mem, max(0.0, rate(t + 0.5 * dt)) * dt)
        sent += to_copy
        t += dt
        rounds += 1
        if dirtied <= stop_dirty_pages * page:
            reason = "dirty_low"
            to_copy = dirtied
            break
        if rounds >= max_rounds:
            reason = "max_rounds"
            to_copy = dirtied
            break
        if sent + dirtied > stop_total_factor * v_mem:
            reason = "total_cap"
            to_copy = dirtied
            break
        to_copy = dirtied

    downtime = to_copy / bandwidth                   # stop-and-copy
    sent += to_copy
    t += downtime
    return MigrationOutcome(total_time=t - start_time, downtime=downtime,
                            bytes_sent=sent, rounds=rounds, stop_reason=reason)


def expected_cost(v_mem: float, bandwidth: float, dirty_rate: DirtyRate,
                  start_time: float = 0.0) -> float:
    """Scalar cost used by the 'alma-plus' window chooser: total bytes sent."""
    return simulate_precopy(v_mem, bandwidth, dirty_rate,
                            start_time=start_time).bytes_sent
