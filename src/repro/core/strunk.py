"""Pre-copy live-migration cost model (paper §3.2, Strunk's bounds).

Implements Inequalities 1–2 and an iterative pre-copy simulator with the Xen
stop conditions the paper lists: (i) fewer than ``stop_dirty_pages`` dirty
pages since the last round, (ii) at most ``max_rounds`` rounds, (iii) total
transfer capped at ``stop_total_factor`` x V_mem. Dirty rate may be a
constant or a callable of absolute time, which is how the fleet simulator
injects the *workload-phase-dependent* dirty rate — the whole point of the
paper: the same migration started in an NLM phase costs multiples of one
started in an LM phase.

Two executions of the same model:

  * ``simulate_precopy_reference`` — the original scalar Python loop, kept
    as the executable specification (and as the honest per-request baseline
    for the concurrency-sweep benchmark).
  * ``simulate_precopy_batch`` — one vectorized simulation over (M,)
    in-flight migrations: per-round dirty-rate sampling across all lanes,
    the three Xen stop conditions evaluated as masked lanes, per-lane
    start times and bandwidths. Bit-equal to the reference lane-for-lane
    (same float64 operation order), which ``tests/test_precopy.py``
    asserts across all three stop reasons and callable rates.

``simulate_precopy`` is the M=1 view of the batch path — the same
structural-parity pattern as ``cycles.fit_cycle`` vs ``fit_cycle_batch``.
The contention-aware execution plane (``core/plane.py``) re-implements the
identical round recurrence with bandwidth recomputed at round boundaries
from the shared-link network model; its uncontended single-lane output is
bit-equal to this module's.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence, Tuple, Union

import numpy as np

DirtyRate = Union[float, Callable[[float], float]]
# batch rates: one spec per lane, or a single spec broadcast to every lane,
# or a vectorized callable (marked ``.vectorized = True``) mapping an (M,)
# time array to an (M,) rate array in one call.
BatchDirtyRate = Union[DirtyRate, Sequence[DirtyRate]]

PAGE = 4096
XEN_MAX_ROUNDS = 29
XEN_STOP_DIRTY_PAGES = 50
XEN_STOP_TOTAL_FACTOR = 3.0

# stop-reason lane codes (batch) <-> names (scalar outcomes)
REASON_DIRTY_LOW, REASON_MAX_ROUNDS, REASON_TOTAL_CAP = 0, 1, 2
STOP_REASONS = ("dirty_low", "max_rounds", "total_cap")
# a lane settled early by fault injection (MigrationPlane.abort /
# fail_host) — deliberately NOT in STOP_REASONS: the pre-copy recurrence
# never produces it, only the abort path does, so completion and abort
# outcomes stay distinguishable by stop_reason alone
STOP_ABORTED = "aborted"
# a lane settled early by the prediction guard (core/guard.py): realized
# progress diverged past the abort ratio of its admission-time priced
# expectation. Like STOP_ABORTED it is NOT in STOP_REASONS (only the
# watchdog produces it), and it is distinct from fault aborts so the
# simulator can route misprediction feedback (forced refit, trust decay)
# without confusing it with infrastructure failure
STOP_GUARD = "guard_abort"


def strunk_bounds(v_mem: float, bandwidth: float,
                  max_rounds: int = XEN_MAX_ROUNDS) -> Tuple[float, float]:
    """(T_mig lower, upper) per Inequality 1: V/B <= T <= (M+1)V/B."""
    return v_mem / bandwidth, (max_rounds + 1) * v_mem / bandwidth


@dataclass
class MigrationOutcome:
    total_time: float          # paper's 'live migration total time'
    downtime: float            # stop-and-copy duration
    bytes_sent: float          # 'network data transfer'
    rounds: int
    stop_reason: str


@dataclass
class BatchMigrationOutcome:
    """(M,) pre-copy outcomes — SoA arrays plus a scalar accessor."""
    total_time: np.ndarray
    downtime: np.ndarray
    bytes_sent: np.ndarray
    rounds: np.ndarray
    stop_reason: np.ndarray    # int codes, see STOP_REASONS

    def __len__(self) -> int:
        return len(self.total_time)

    def item(self, i: int) -> MigrationOutcome:
        return MigrationOutcome(
            total_time=float(self.total_time[i]),
            downtime=float(self.downtime[i]),
            bytes_sent=float(self.bytes_sent[i]),
            rounds=int(self.rounds[i]),
            stop_reason=STOP_REASONS[int(self.stop_reason[i])])


@dataclass
class ResumeState:
    """Mid-round initial state for (M,) lanes — the execution plane's
    ``lane_state()`` snapshot in array form, or fresh rows (``fresh``)
    for lanes not yet launched.

    ``rem``      bytes left in the lane's current transfer: the in-flight
                 round's remainder, or the stop-and-copy remnant when
                 ``stopped``;
    ``acc``      dirty bytes already accrued during the current round;
    ``sent``     bytes already charged to the lane's links (completed
                 rounds plus the progressed part of the current one) —
                 feeds the 3xV total-transfer cap but NOT the returned
                 bill;
    ``rounds``   pre-copy rounds already completed;
    ``stopped``  True once the lane has entered stop-and-copy;
    ``reason``   stop-reason code carried through for already-stopped
                 lanes (ignored for running ones).

    Resumed outcomes are MARGINAL: ``bytes_sent`` / ``total_time`` cover
    only the remaining work from ``start_time`` on, so a what-if sweep
    bills the dilution a candidate batch inflicts on already-running
    lanes as the resumed bill under the hypothetical shares.
    """
    rem: np.ndarray
    acc: np.ndarray
    sent: np.ndarray
    rounds: np.ndarray
    stopped: np.ndarray
    reason: Optional[np.ndarray] = None

    @staticmethod
    def fresh(v_mem) -> "ResumeState":
        """Launch-time state: round 0 copies all of V_mem, nothing accrued."""
        v = np.atleast_1d(np.asarray(v_mem, np.float64))
        m = v.shape[0]
        return ResumeState(rem=v.copy(), acc=np.zeros(m), sent=np.zeros(m),
                           rounds=np.zeros(m, np.int64),
                           stopped=np.zeros(m, bool),
                           reason=np.full(m, REASON_MAX_ROUNDS, np.int64))

    def take(self, idx) -> "ResumeState":
        """Gather rows ``idx`` (with repeats) — the flattened-sweep layout."""
        idx = np.asarray(idx, np.intp)
        return ResumeState(
            rem=self.rem[idx], acc=self.acc[idx], sent=self.sent[idx],
            rounds=self.rounds[idx], stopped=self.stopped[idx],
            reason=None if self.reason is None else self.reason[idx])


def _resume_precopy_batch(v, bw, rate, nonneg, t0, init: ResumeState,
                          thresh, cap, max_rounds) -> BatchMigrationOutcome:
    """Generalized pre-copy recurrence from arbitrary mid-round state.

    Same math as the fresh-start hot loop, but per-lane round counters
    (lanes resume at different depths) and a first-iteration dirty carry:
    the first resumed round dirties ``acc + rate*dt`` because ``acc``
    bytes accrued before the snapshot. For ``ResumeState.fresh`` inputs
    this is value-identical to ``simulate_precopy_batch``'s own loop
    (``0.0 + x == x`` and the op order matches), which
    ``tests/test_horizon.py`` asserts bit-for-bit.
    """
    m = v.shape[0]
    t = t0.astype(np.float64).copy()
    sent = np.zeros(m)                       # marginal: future bytes only
    charged = np.asarray(init.sent, np.float64) + np.zeros(m)
    rounds = np.asarray(init.rounds, np.int64).copy()
    if init.reason is not None:
        reason = np.asarray(init.reason, np.int64).astype(np.int8).copy()
    else:
        reason = np.full(m, REASON_MAX_ROUNDS, np.int8)
    stopped0 = np.asarray(init.stopped, bool)
    rem0 = np.asarray(init.rem, np.float64) + np.zeros(m)
    final = np.where(stopped0, rem0, 0.0)    # stop-and-copy payload
    active = ~stopped0
    work = np.where(active, rem0, 0.0)
    carry = np.where(active, np.asarray(init.acc, np.float64), 0.0)
    while active.any():
        dt = work / bw
        mid = t + 0.5 * dt
        r = rate(mid, active)
        grown = (np.asarray(r, np.float64) if nonneg
                 else np.maximum(r, 0.0)) * dt
        dirtied = np.minimum(carry + grown, v)
        sent = sent + work
        t = t + dt
        rounds = rounds + active
        # stop conditions, priority-ordered as the reference loop (the
        # last copyto wins): dirty_low, then max_rounds, then total_cap
        c_dirty = dirtied <= thresh
        c_rounds = rounds >= max_rounds
        c_total = (charged + sent) + dirtied > cap
        stop = (c_dirty | c_rounds | c_total) & active
        if stop.any():
            np.copyto(reason, REASON_TOTAL_CAP, where=stop)
            np.copyto(reason, REASON_MAX_ROUNDS, where=stop & c_rounds)
            np.copyto(reason, REASON_DIRTY_LOW, where=stop & c_dirty)
            np.copyto(final, dirtied, where=stop)
            active = active & ~stop
        work = dirtied * active              # zero stopped lanes exactly
        carry = np.zeros(m)                  # the carry is spent in round 1
    downtime = final / bw                    # stop-and-copy
    sent = sent + final
    t = t + downtime
    return BatchMigrationOutcome(total_time=t - t0, downtime=downtime,
                                 bytes_sent=sent, rounds=rounds,
                                 stop_reason=reason.astype(np.int64))


def batch_rate_fn(dirty_rate: BatchDirtyRate, m: int
                  ) -> Callable[[np.ndarray, np.ndarray], np.ndarray]:
    """Normalize a batch dirty-rate spec to ``f(t, active) -> rates``.

    ``t`` is the (M,) absolute sample time per lane; only lanes with
    ``active`` True need a valid rate. Scalars broadcast; a callable with
    ``.vectorized`` set is called once on the whole time array; plain
    callables are sampled per active lane (the compatibility path for the
    fleet's per-job ``trace.dirty_rate`` functions).
    """
    if callable(dirty_rate) and getattr(dirty_rate, "vectorized", False):
        return lambda t, active: np.asarray(dirty_rate(t), np.float64)
    if callable(dirty_rate):
        def one_fn(t: np.ndarray, active: np.ndarray) -> np.ndarray:
            out = np.zeros(m)
            for i in np.flatnonzero(active):
                out[i] = float(dirty_rate(float(t[i])))
            return out
        return one_fn
    if np.isscalar(dirty_rate):
        const = np.full(m, float(dirty_rate))
        return lambda t, active: const
    specs = list(dirty_rate)
    if len(specs) != m:
        raise ValueError(f"{len(specs)} rate specs for {m} lanes")
    call_idx = [i for i, s in enumerate(specs) if callable(s)]
    base = np.asarray([0.0 if callable(s) else float(s) for s in specs])
    if not call_idx:
        return lambda t, active: base

    def mixed_fn(t: np.ndarray, active: np.ndarray) -> np.ndarray:
        out = base.copy()
        for i in call_idx:
            if active[i]:
                out[i] = float(specs[i](float(t[i])))
        return out
    return mixed_fn


def simulate_precopy_batch(v_mem, bandwidth, dirty_rate: BatchDirtyRate,
                           *, start_time=0.0, page: int = PAGE,
                           max_rounds: int = XEN_MAX_ROUNDS,
                           stop_dirty_pages: int = XEN_STOP_DIRTY_PAGES,
                           stop_total_factor: float = XEN_STOP_TOTAL_FACTOR,
                           init: Optional[ResumeState] = None,
                           ) -> BatchMigrationOutcome:
    """Vectorized pre-copy over (M,) lanes (paper §3.2 stages 2–3).

    Every lane runs the reference recurrence — round 0 copies all of V_mem,
    round i copies the bytes dirtied during round i-1, the dirty rate is
    sampled mid-round at each lane's own absolute time — with the three Xen
    stop conditions applied as masks. Finished lanes freeze while the rest
    keep iterating, so one call simulates M migrations of arbitrary length
    in max(rounds) vector steps.

    ``init`` resumes lanes from arbitrary mid-round state (the execution
    plane's ``lane_state()`` snapshots) instead of launch: outcomes then
    bill only the MARGINAL remaining bytes/time, which is how the
    receding-horizon controller reprices in-flight lanes under
    hypothetical candidate admissions.
    """
    v = np.atleast_1d(np.asarray(v_mem, np.float64))
    m = v.shape[0]
    if m == 0:
        # the round loop below terminates on ``stop.any()``, which an empty
        # lane set can never satisfy — answer the empty batch directly
        # (what-if sweeps legitimately evaluate "launch nothing")
        z = np.zeros(0)
        return BatchMigrationOutcome(
            total_time=z, downtime=np.zeros(0), bytes_sent=np.zeros(0),
            rounds=np.zeros(0, np.int64), stop_reason=np.zeros(0, np.int64))
    bw = np.broadcast_to(np.asarray(bandwidth, np.float64), (m,))
    t0 = np.broadcast_to(np.asarray(start_time, np.float64), (m,))
    rate = batch_rate_fn(dirty_rate, m)

    nonneg = bool(getattr(dirty_rate, "nonneg", False)) or (
        np.isscalar(dirty_rate) and not callable(dirty_rate)
        and float(dirty_rate) >= 0.0)
    if init is not None:
        return _resume_precopy_batch(
            v, bw, rate, nonneg, t0, init,
            float(stop_dirty_pages) * page, stop_total_factor * v,
            max_rounds)
    t = t0.astype(np.float64).copy()
    sent = np.zeros(m)
    rounds = np.zeros(m, np.int64)
    reason = np.full(m, REASON_MAX_ROUNDS, np.int8)
    active = np.ones(m, bool)
    thresh = float(stop_dirty_pages) * page
    cap = stop_total_factor * v
    # ``work`` holds the current round's bytes for active lanes and 0 for
    # stopped ones, so every accumulator update below is unconditional —
    # stopped lanes add exactly 0.0, which keeps them bit-frozen without a
    # mask per update. All round-local arrays are preallocated buffers
    # updated with in-place ufuncs: this loop is the fleet's hot path and
    # numpy dispatch + temporaries dominate at fleet lane counts.
    work = v.copy()
    final = np.zeros(m)                  # dirtied bytes at stop -> stop&copy
    dt = np.empty(m)
    mid = np.empty(m)
    dirtied = np.empty(m)
    tmp = np.empty(m)
    c_dirty = np.empty(m, bool)
    c_total = np.empty(m, bool)
    stop = np.empty(m, bool)
    k = 0                                # a lane active at iteration k has
    while True:                          # rounds == k+1, so the max_rounds
        k += 1                           # test is a Python scalar compare
        np.divide(work, bw, out=dt)
        np.multiply(dt, 0.5, out=mid)
        np.add(mid, t, out=mid)          # == t + 0.5*dt (exact: commutative)
        r = rate(mid, active)
        if nonneg:                       # max(0, r) == r exactly for r >= 0
            np.multiply(r, dt, out=tmp)
        else:
            np.maximum(r, 0.0, out=tmp)
            np.multiply(tmp, dt, out=tmp)
        np.minimum(tmp, v, out=dirtied)  # == min(v, max(0, r)*dt)
        sent += work
        t += dt
        # stop conditions, priority-ordered exactly as the reference loop:
        # dirty_low, then max_rounds, then total_cap
        np.less_equal(dirtied, thresh, out=c_dirty)
        np.add(sent, dirtied, out=tmp)
        np.greater(tmp, cap, out=c_total)
        if k >= max_rounds:
            np.copyto(stop, active)
        else:
            np.logical_or(c_dirty, c_total, out=stop)
            np.logical_and(stop, active, out=stop)
        if stop.any():
            later = REASON_MAX_ROUNDS if k >= max_rounds else REASON_TOTAL_CAP
            np.copyto(reason, later, where=stop & ~c_dirty)
            np.copyto(reason, REASON_DIRTY_LOW, where=stop & c_dirty)
            np.copyto(rounds, k, where=stop)
            np.copyto(final, dirtied, where=stop)
            np.logical_and(active, ~stop, out=active)
            if not active.any():
                break
        np.multiply(dirtied, active, out=work)   # zero stopped lanes exactly
    downtime = final / bw                            # stop-and-copy
    sent = sent + final
    t = t + downtime
    return BatchMigrationOutcome(total_time=t - t0, downtime=downtime,
                                 bytes_sent=sent, rounds=rounds,
                                 stop_reason=reason.astype(np.int64))


def simulate_precopy(v_mem: float, bandwidth: float, dirty_rate: DirtyRate,
                     *, start_time: float = 0.0, page: int = PAGE,
                     max_rounds: int = XEN_MAX_ROUNDS,
                     stop_dirty_pages: int = XEN_STOP_DIRTY_PAGES,
                     stop_total_factor: float = XEN_STOP_TOTAL_FACTOR,
                     ) -> MigrationOutcome:
    """Scalar pre-copy simulation — the M=1 view of the batch path."""
    batch = simulate_precopy_batch(
        [v_mem], bandwidth, dirty_rate, start_time=start_time, page=page,
        max_rounds=max_rounds, stop_dirty_pages=stop_dirty_pages,
        stop_total_factor=stop_total_factor)
    return batch.item(0)


def simulate_precopy_reference(v_mem: float, bandwidth: float,
                               dirty_rate: DirtyRate,
                               *, start_time: float = 0.0, page: int = PAGE,
                               max_rounds: int = XEN_MAX_ROUNDS,
                               stop_dirty_pages: int = XEN_STOP_DIRTY_PAGES,
                               stop_total_factor: float = XEN_STOP_TOTAL_FACTOR,
                               ) -> MigrationOutcome:
    """The original scalar loop — executable spec the batch path must match
    bit-for-bit, and the per-request baseline the concurrency sweep times.

    Round 0 copies all of V_mem; round i copies the bytes dirtied during
    round i-1. ``dirty_rate(t)`` is sampled at absolute time ``t`` so cyclic
    workloads produce cyclic migration costs.
    """
    rate = dirty_rate if callable(dirty_rate) else (lambda _t: float(dirty_rate))
    t = start_time
    sent = 0.0
    to_copy = v_mem
    rounds = 0
    reason = "max_rounds"
    while True:
        dt = to_copy / bandwidth
        # dirty bytes accrued while this round's copy is in flight (sample the
        # rate midway through the round — adequate for piecewise traces)
        dirtied = min(v_mem, max(0.0, rate(t + 0.5 * dt)) * dt)
        sent += to_copy
        t += dt
        rounds += 1
        if dirtied <= stop_dirty_pages * page:
            reason = "dirty_low"
            to_copy = dirtied
            break
        if rounds >= max_rounds:
            reason = "max_rounds"
            to_copy = dirtied
            break
        if sent + dirtied > stop_total_factor * v_mem:
            reason = "total_cap"
            to_copy = dirtied
            break
        to_copy = dirtied

    downtime = to_copy / bandwidth                   # stop-and-copy
    sent += to_copy
    t += downtime
    return MigrationOutcome(total_time=t - start_time, downtime=downtime,
                            bytes_sent=sent, rounds=rounds, stop_reason=reason)


def expected_cost(v_mem: float, bandwidth: float, dirty_rate: DirtyRate,
                  start_time: float = 0.0) -> float:
    """Scalar cost used by the 'alma-plus' window chooser: total bytes sent."""
    return simulate_precopy(v_mem, bandwidth, dirty_rate,
                            start_time=start_time).bytes_sent


def expected_cost_batch(v_mem, bandwidth, dirty_rate: BatchDirtyRate,
                        start_times, *, full: bool = False,
                        init: Optional[ResumeState] = None):
    """Vectorized expected migration cost (total bytes sent) over (M,)
    hypothetical lanes. Two callers, same math:

      * the 'alma-plus' window scan — ONE migration (scalar ``v_mem`` /
        ``bandwidth``) started at each of (M,) candidate moments;
      * the consolidation planner's packing score — (M,) planned
        migrations with per-lane sizes and per-lane *contended fair-share*
        bandwidths, all started at the consolidation event time (pass
        ``full=True`` for the whole ``BatchMigrationOutcome`` — predicted
        times tie-break packings whose byte bills are equal).
    """
    start = np.atleast_1d(np.asarray(start_times, np.float64))
    m = max(start.shape[0], np.atleast_1d(np.asarray(v_mem)).shape[0])
    out = simulate_precopy_batch(
        np.broadcast_to(np.asarray(v_mem, np.float64), (m,)), bandwidth,
        dirty_rate, start_time=np.broadcast_to(start, (m,)), init=init)
    return out if full else out.bytes_sent


def what_if_cost_batch(v_mem, bandwidth, rate_specs, start_times,
                       *, full: bool = False,
                       init: Optional[ResumeState] = None):
    """``expected_cost_batch`` over (M,) *hypothetical* lanes whose dirty
    rates are given as lane-registration specs (``core/rates.py``: tables,
    constants, ``rate_table`` objects, plain callables, None) — or as an
    already-built ``RateBank`` whose row ``i`` is lane ``i``'s table.

    Spec sequences are normalized through the same ``RateBank`` the
    execution plane registers its lanes with, so an all-tabular candidate
    batch samples every lane's rate in ONE padded lookup per round — the
    entry point the adaptive concurrency controller
    (``core/controller.py``) uses to price a whole defer-k sweep without
    per-lane Python. Passing a ``RateBank`` directly skips even that
    normalization: the stacked defer-k sweep builds one bank over its
    unique candidate tables and ``take``-gathers the flattened prefix
    layout, so pricing all n+1 prefixes re-normalizes nothing. Lanes
    whose spec cannot be tabulated fall back to per-lane sampling.
    """
    from repro.core.rates import RateBank, as_rate_table
    if isinstance(rate_specs, RateBank):
        bank = rate_specs
        if bank.m == 0:
            return expected_cost_batch(np.zeros(0), bandwidth, 0.0,
                                       np.zeros(0), full=full)
        if bank.fallback:
            raise ValueError("RateBank inputs must be fully tabular "
                             "(fallback callables need per-lane specs)")
        return expected_cost_batch(v_mem, bandwidth, bank.table_fn,
                                   start_times, full=full, init=init)
    specs = list(rate_specs)
    if not specs:
        return expected_cost_batch(np.zeros(0), bandwidth, 0.0,
                                   np.zeros(0), full=full)
    bank = RateBank(specs)
    if not bank.fallback:
        rate: BatchDirtyRate = bank.table_fn
    else:
        # mixed tables + callables: hand the normalized per-lane specs to
        # the compatibility path (callables are sampled per lane)
        rate = [as_rate_table(s) or s for s in specs]
    return expected_cost_batch(v_mem, bandwidth, rate, start_times,
                               full=full, init=init)
