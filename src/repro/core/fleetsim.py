"""Deterministic fleet simulator — the paper's testbed, scaled.

Hosts with shared migration links, jobs with phase-labeled workload traces
(dirty-rate over time), a consolidation event that emits migration requests,
and the LMCM deciding when each fires. Migration costs come from the Strunk
pre-copy model sampled against the *time-varying* dirty rate, so a migration
launched in an NLM phase genuinely costs more — which is what Tables 6/7
measure.

Workload traces: phase sequences in the style of the paper's Table 3
artificial cycles (CPU/MEM/IO/IDLE), each phase with characteristic load
indexes (the NB features) and a dirty rate; plus "application" traces
recorded from real training runs of this repo's substrate.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import characterize, strunk
from repro.core.orchestrator import LMCM, MigrationRequest
from repro.core.telemetry import FleetTelemetry, TelemetryBuffer

# phase archetypes: load-index means (step_time, dirty_bytes, dirty_fraction,
# collective_bytes, compute_util, hbm_util) + dirty rate in bytes/s.
# MEM-type phases dirty memory fast (pre-copy hostile); CPU/IO/IDLE barely.
# Constants are calibrated to the paper's testbed scale (1 Gbit/s migration
# network, 0.75-2 GB VMs -> 12-90 s migrations, Tables 6-7); the TPU-fleet
# scale (50 GB/s ICI, 100 GB job state) is the same ratios x ~400 and is
# exercised by the beyond-paper examples.
PAPER_BANDWIDTH = 125e6            # 1 Gbit/s
PHASES = {
    "CPU": dict(compute_util=0.95, hbm_util=0.30, dirty_rate=3e6,
                label=characterize.CPU),
    "MEM": dict(compute_util=0.55, hbm_util=0.95, dirty_rate=150e6,
                label=characterize.MEM),
    "IO": dict(compute_util=0.25, hbm_util=0.45, dirty_rate=12e6,
               label=characterize.IO),
    "IDLE": dict(compute_util=0.03, hbm_util=0.05, dirty_rate=0.3e6,
                 label=characterize.IDLE),
}


@dataclass
class WorkloadTrace:
    """Piecewise-constant phase trace. phases: [(name, duration_s), ...]
    repeated cyclically for ``total_s`` seconds."""
    phases: Sequence[Tuple[str, float]]
    total_s: float
    jitter: float = 0.05
    seed: int = 0

    def __post_init__(self):
        self.cycle_s = sum(d for _, d in self.phases)

    def phase_at(self, t: float) -> str:
        tc = t % self.cycle_s
        for name, d in self.phases:
            if tc < d:
                return name
            tc -= d
        return self.phases[-1][0]

    def dirty_rate(self, t: float) -> float:
        return PHASES[self.phase_at(t)]["dirty_rate"]

    def sample_indexes(self, t: float, rng: np.random.Generator) -> dict:
        ph = PHASES[self.phase_at(t)]
        j = lambda v: float(max(0.0, v * (1 + self.jitter * rng.standard_normal())))
        return dict(
            step_time=j(0.5 / max(ph["compute_util"], 0.02)),
            dirty_bytes=j(ph["dirty_rate"]),
            dirty_fraction=j(min(1.0, ph["dirty_rate"] / 200e6)),
            collective_bytes=j(ph["compute_util"] * 1e9),
            compute_util=j(ph["compute_util"]),
            hbm_util=j(ph["hbm_util"]),
        )

    def label_at(self, t: float) -> int:
        return PHASES[self.phase_at(t)]["label"]


def make_training_nb(rng_seed: int = 0, n: int = 4000) -> characterize.NaiveBayes:
    """Train the NB classifier on labeled synthetic phase samples — the
    paper's training-data step (it trains NB on benchmark runs)."""
    rng = np.random.default_rng(rng_seed)
    feats, labels = [], []
    trace = WorkloadTrace([("CPU", 1), ("MEM", 1), ("IO", 1), ("IDLE", 1)], 4)
    for i in range(n):
        t = rng.uniform(0, trace.cycle_s)
        s = trace.sample_indexes(t, rng)
        feats.append([s[f] for f in TelemetryBuffer().fields])
        labels.append(trace.label_at(t))
    return characterize.fit(np.asarray(feats, np.float32),
                            np.asarray(labels))


@dataclass
class SimJob:
    job_id: str
    trace: WorkloadTrace
    v_bytes: float                      # migratable state size
    telemetry: TelemetryBuffer = field(
        default_factory=lambda: TelemetryBuffer(capacity=16384))


@dataclass
class SimResult:
    migrations: List[MigrationRequest]
    total_bytes: float
    total_time: float
    mean_migration_time: float
    mean_downtime: float
    per_job: Dict[str, strunk.MigrationOutcome]
    lm_hit_rate: float                 # fraction fired inside a true LM phase


class FleetSim:
    """Time-stepped simulation: telemetry sampling + LMCM ticks + migrations.

    Telemetry is backed by one fleet-wide SoA ring buffer (``FleetTelemetry``)
    — one (J, F) record per step, one gather per surveillance tick — and the
    LMCM's batched surveillance engine refreshes every stale cycle fit in a
    single pipeline per step (see ``core/surveillance.py``).
    """

    def __init__(self, jobs: Sequence[SimJob], *, policy: str,
                 bandwidth: float = PAPER_BANDWIDTH, sample_period: float = 1.0,
                 max_wait: float = 600.0, max_concurrent: int = 2,
                 warmup_s: float = 0.0, seed: int = 0):
        self.jobs = {j.job_id: j for j in jobs}
        self.rng = np.random.default_rng(seed)
        self.lmcm = LMCM(policy=policy, max_wait=max_wait,
                         max_concurrent=max_concurrent, bandwidth=bandwidth,
                         sample_period=sample_period)
        self.bandwidth = bandwidth
        self.dt = sample_period
        self.now = 0.0
        # adopt jobs constructed with a default (empty) buffer into the
        # fleet SoA store; pre-filled custom buffers are kept as-is
        self.telemetry = FleetTelemetry(len(jobs), capacity=16384)
        self._job_list = list(jobs)
        for idx, j in enumerate(self._job_list):
            if (len(j.telemetry) == 0
                    and tuple(j.telemetry.fields) == self.telemetry.fields):
                j.telemetry = self.telemetry.view(idx)
        self._soa_record = all(
            getattr(j.telemetry, "fleet", None) is self.telemetry
            and j.telemetry.index == i
            for i, j in enumerate(self._job_list))
        nb = make_training_nb()
        for j in jobs:
            # surveillance window: >=4 observed cycles, else the FFT cannot
            # resolve the period (max detectable period is window/2)
            window = int(min(4096, max(512, 4 * j.trace.cycle_s / self.dt)))
            self.lmcm.register_job(
                j.job_id, j.telemetry, nb, window=window,
                dirty_rate_fn=j.trace.dirty_rate)
        if warmup_s:
            self.run_idle(warmup_s)

    def _record_all(self) -> None:
        """One telemetry sample per job — a single (J, F) SoA append when
        every job lives in the fleet store."""
        step = int(self.now / self.dt)
        if self._soa_record:
            vals = np.empty((len(self._job_list), len(self.telemetry.fields)))
            for i, j in enumerate(self._job_list):
                s = j.trace.sample_indexes(self.now, self.rng)
                vals[i] = [s[f] for f in self.telemetry.fields]
            self.telemetry.record_fleet(step, vals)
        else:
            for j in self._job_list:
                j.telemetry.record(step,
                                   **j.trace.sample_indexes(self.now, self.rng))

    def run_idle(self, seconds: float) -> None:
        steps = int(seconds / self.dt)
        for _ in range(steps):
            self._record_all()
            self.now += self.dt

    def run_with_plan(self, plan: Sequence[MigrationRequest],
                      horizon_s: float = 3600.0) -> SimResult:
        pending = sorted(plan, key=lambda r: r.created_at)
        per_job: Dict[str, strunk.MigrationOutcome] = {}
        done: List[MigrationRequest] = []
        lm_hits = 0
        t_end = self.now + horizon_s
        while self.now < t_end and (pending or self.lmcm.queue
                                    or self.lmcm.running):
            while pending and pending[0].created_at <= self.now:
                self.lmcm.submit(pending.pop(0), self.now)
            self._record_all()
            self.lmcm.tick(self.now)           # batched fleet surveillance
            for req in self.lmcm.due(self.now):
                job = self.jobs[req.job_id]
                outcome = strunk.simulate_precopy(
                    req.v_bytes, self.bandwidth, job.trace.dirty_rate,
                    start_time=self.now)
                self.lmcm.finish(req, outcome)
                per_job[req.job_id] = outcome
                done.append(req)
                # accuracy metric (Figs. 8-9): did we fire in a non-MEM phase?
                if job.trace.phase_at(self.now) != "MEM":
                    lm_hits += 1
            self.now += self.dt
        total_bytes = sum(o.bytes_sent for o in per_job.values())
        times = [o.total_time for o in per_job.values()]
        downs = [o.downtime for o in per_job.values()]
        return SimResult(
            migrations=done,
            total_bytes=total_bytes,
            total_time=float(np.sum(times)) if times else 0.0,
            mean_migration_time=float(np.mean(times)) if times else 0.0,
            mean_downtime=float(np.mean(downs)) if downs else 0.0,
            per_job=per_job,
            lm_hit_rate=lm_hits / max(1, len(done)),
        )


# ---------------------------------------------------------------------------
# the paper's Table 3 artificial cycles + application-like traces
# ---------------------------------------------------------------------------
def table3_traces(phase_s: float = 60.0) -> Dict[str, WorkloadTrace]:
    t = lambda names: WorkloadTrace([(n, phase_s) for n in names],
                                    total_s=3600)
    return {
        "vm03_A": t(["IO", "CPU", "CPU", "IO", "CPU", "CPU", "IO", "CPU",
                     "CPU"]),
        "vm02_C": t(["MEM", "IDLE", "CPU", "MEM", "IDLE", "CPU", "MEM",
                     "IDLE", "CPU"]),
        "vm02_A": t(["MEM", "CPU", "CPU", "MEM", "CPU", "CPU", "MEM", "CPU",
                     "CPU", "MEM", "CPU", "CPU"]),
        "vm01_C": t(["MEM", "IDLE", "CPU", "MEM", "IDLE", "CPU"]),
    }


def application_traces(phase_s: float = 45.0) -> Dict[str, WorkloadTrace]:
    """Application analogues (paper §6.3.2): long irregular phases.
    OpenModeller ~ CPU-dominant with IO bursts; BRAMS ~ complex cycle;
    Hadoop/TeraSort ~ shuffle-heavy (MEM/IO alternation)."""
    t = lambda spec: WorkloadTrace(spec, total_s=7200)
    return {
        "vm03_A_openmodeller": t([("IO", phase_s), ("CPU", 4 * phase_s),
                                  ("MEM", phase_s), ("CPU", 3 * phase_s)]),
        "vm02_C_brams": t([("MEM", phase_s), ("CPU", 2 * phase_s),
                           ("MEM", 2 * phase_s), ("IO", phase_s),
                           ("CPU", 2 * phase_s), ("IDLE", phase_s)]),
        "vm01_C_hadoop": t([("IO", phase_s), ("MEM", 2 * phase_s),
                            ("CPU", phase_s), ("IO", 2 * phase_s)]),
        "vm02_A_hadoop": t([("MEM", 2 * phase_s), ("IO", phase_s),
                            ("CPU", phase_s), ("MEM", phase_s),
                            ("IO", phase_s)]),
    }
