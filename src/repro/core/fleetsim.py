"""Deterministic fleet simulator — the paper's testbed, scaled.

Hosts with shared migration links, jobs with phase-labeled workload traces
(dirty-rate over time), a consolidation event that emits migration requests,
and the LMCM deciding when each fires. Migration costs come from the Strunk
pre-copy model sampled against the *time-varying* dirty rate, so a migration
launched in an NLM phase genuinely costs more — which is what Tables 6/7
measure.

Execution is contention-aware and sharded: every migration the LMCM
releases is handed to the fabric (``core/fabric.py``), which partitions
in-flight transfers into per-access-link migration domains and advances
each domain's event loop (``core/plane.py``) independently, re-sharing
each network link max-min fairly at every round boundary
(``core/network.py``). Simultaneous migrations on shared links therefore
slow each other down — longer rounds, more dirtying per round, more bytes —
which is exactly the congestion effect the paper's orchestrator exists to
avoid, while disjoint domains advance without touching each other. The
LMCM's deadline/cost decisions read the fabric's realized per-domain
bandwidth through ``bandwidth_probe``.

The fleet substrate defaults to ``Topology.star`` when a host ``Placement``
is given (per-host access links joined through a core sized by
``core_oversubscription``); without a placement it falls back to the
paper's single shared migration link.

Time advances event-skipped: when nothing is in flight, ``run_with_plan``
jumps the clock straight to the next pending arrival / LMCM release /
surveillance staleness boundary (and ``run_idle`` to its end),
bulk-appending the skipped telemetry — ring contents, rng stream, fits,
and outcomes are bit-identical to ticking one second at a time
(``event_skip=False`` restores the pure per-second loop; the fast path
also needs the fleet SoA store and stock ``WorkloadTrace`` samplers).

Workload traces: phase sequences in the style of the paper's Table 3
artificial cycles (CPU/MEM/IO/IDLE), each phase with characteristic load
indexes (the NB features) and a dirty rate; plus "application" traces
recorded from real training runs of this repo's substrate. Traces carry a
``PiecewiseRate`` table, so a whole fleet's dirty rates can be sampled in
one vectorized call (``PiecewiseRate.batch``) — the fast path of
``strunk.simulate_precopy_batch``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import characterize, network, strunk
from repro.core.consolidation import Placement
from repro.core.fabric import ShardedPlane
from repro.core.guard import MigrationGuard
from repro.core.orchestrator import LMCM, MigrationRequest
from repro.core.rates import PiecewiseRate  # noqa: F401  (re-export)
from repro.core.telemetry import DEFAULT_FIELDS, FleetTelemetry, \
    TelemetryBuffer

# phase archetypes: load-index means (step_time, dirty_bytes, dirty_fraction,
# collective_bytes, compute_util, hbm_util) + dirty rate in bytes/s.
# MEM-type phases dirty memory fast (pre-copy hostile); CPU/IO/IDLE barely.
# Constants are calibrated to the paper's testbed scale (1 Gbit/s migration
# network, 0.75-2 GB VMs -> 12-90 s migrations, Tables 6-7); the TPU-fleet
# scale (50 GB/s ICI, 100 GB job state) is the same ratios x ~400 and is
# exercised by the beyond-paper examples.
PAPER_BANDWIDTH = 125e6            # 1 Gbit/s
PHASES = {
    "CPU": dict(compute_util=0.95, hbm_util=0.30, dirty_rate=3e6,
                label=characterize.CPU),
    "MEM": dict(compute_util=0.55, hbm_util=0.95, dirty_rate=150e6,
                label=characterize.MEM),
    "IO": dict(compute_util=0.25, hbm_util=0.45, dirty_rate=12e6,
               label=characterize.IO),
    "IDLE": dict(compute_util=0.03, hbm_util=0.05, dirty_rate=0.3e6,
                 label=characterize.IDLE),
}


def phase_means(name: str) -> Tuple[float, ...]:
    """A phase's load-index means in telemetry field order
    (``DEFAULT_FIELDS``) — the ONE place the per-field formulas live, so
    the scalar sampler (``WorkloadTrace.sample_indexes``) and the bulk
    recorder's precomputed tables cannot drift apart."""
    ph = PHASES[name]
    return (0.5 / max(ph["compute_util"], 0.02),        # step_time
            ph["dirty_rate"],                           # dirty_bytes
            min(1.0, ph["dirty_rate"] / 200e6),         # dirty_fraction
            ph["compute_util"] * 1e9,                   # collective_bytes
            ph["compute_util"], ph["hbm_util"])


@dataclass
class WorkloadTrace:
    """Piecewise-constant phase trace. phases: [(name, duration_s), ...]
    repeated cyclically for ``total_s`` seconds, shifted by ``offset``
    (replicas of one application de-phased across the fleet)."""
    phases: Sequence[Tuple[str, float]]
    total_s: float
    jitter: float = 0.05
    seed: int = 0
    offset: float = 0.0

    def __post_init__(self):
        ends = np.cumsum([d for _, d in self.phases]).astype(np.float64)
        self.cycle_s = float(ends[-1])
        self._names = [n for n, _ in self.phases]
        self._rate = PiecewiseRate(
            ends, [PHASES[n]["dirty_rate"] for n in self._names],
            offset=self.offset)

    def phase_at(self, t: float) -> str:
        return self._names[self._rate.index_at(t)]

    def dirty_rate(self, t: float) -> float:
        return self._rate(t)

    @property
    def rate_table(self) -> PiecewiseRate:
        return self._rate

    def sample_indexes(self, t: float, rng: np.random.Generator) -> dict:
        means = phase_means(self.phase_at(t))
        j = lambda v: float(max(0.0, v * (1 + self.jitter * rng.standard_normal())))
        # dict(zip(...)) draws one normal per field IN FIELD ORDER — the
        # rng-stream contract the bulk recorder reproduces as one array
        return dict(zip(DEFAULT_FIELDS, (j(v) for v in means)))

    def label_at(self, t: float) -> int:
        return PHASES[self.phase_at(t)]["label"]


def make_training_nb(rng_seed: int = 0, n: int = 4000) -> characterize.NaiveBayes:
    """Train the NB classifier on labeled synthetic phase samples — the
    paper's training-data step (it trains NB on benchmark runs)."""
    rng = np.random.default_rng(rng_seed)
    feats, labels = [], []
    trace = WorkloadTrace([("CPU", 1), ("MEM", 1), ("IO", 1), ("IDLE", 1)], 4)
    for i in range(n):
        t = rng.uniform(0, trace.cycle_s)
        s = trace.sample_indexes(t, rng)
        feats.append([s[f] for f in TelemetryBuffer().fields])
        labels.append(trace.label_at(t))
    return characterize.fit(np.asarray(feats, np.float32),
                            np.asarray(labels))


@dataclass
class SimJob:
    job_id: str
    trace: WorkloadTrace
    v_bytes: float                      # migratable state size
    telemetry: TelemetryBuffer = field(
        default_factory=lambda: TelemetryBuffer(capacity=16384))


@dataclass
class SimResult:
    migrations: List[MigrationRequest]
    total_bytes: float
    total_time: float
    mean_migration_time: float
    mean_downtime: float
    per_job: Dict[str, strunk.MigrationOutcome]
    lm_hit_rate: float                 # fraction fired inside a true LM phase
    makespan: float = 0.0              # first launch -> last completion
    link_bytes: Dict[str, float] = field(default_factory=dict)
    # --- fault-injection accounting (all zero/empty without a FaultPlan) ---
    aborted_bytes: float = 0.0         # partial bytes wasted by aborted lanes
    n_aborts: int = 0
    n_retries: int = 0                 # aborted requests re-admitted
    failed_jobs: List[str] = field(default_factory=list)   # retries exhausted
    completed_at: Dict[str, float] = field(default_factory=dict)
    # (job_id, t_abort, partial_bytes, path at abort) per aborted lane —
    # the conservation tests bill these bytes against the abort-time path
    abort_log: List[Tuple[str, float, float, Tuple[str, ...]]] = \
        field(default_factory=list)


class FleetSim:
    """Time-stepped simulation: telemetry sampling + LMCM ticks + the
    contention-aware migration plane.

    Telemetry is backed by one fleet-wide SoA ring buffer (``FleetTelemetry``)
    — one (J, F) record per step, one gather per surveillance tick — and the
    LMCM's batched surveillance engine refreshes every stale cycle fit in a
    single pipeline per step (see ``core/surveillance.py``). Migrations the
    LMCM releases run on the sharded fabric (``ShardedPlane``): each
    sampling period every migration domain's event loop advances its
    in-flight pre-copies together, re-sharing link bandwidth max-min
    fairly at round boundaries; disjoint domains advance independently.
    The substrate is a ``Topology.star`` over the placement's hosts when a
    placement is given (access links at ``bandwidth``, core sized by
    ``core_oversubscription``), else the paper's single dedicated
    1 Gbit/s migration network.
    """

    def __init__(self, jobs: Sequence[SimJob], *, policy: str,
                 bandwidth: float = PAPER_BANDWIDTH, sample_period: float = 1.0,
                 max_wait: float = 600.0, max_concurrent: int = 2,
                 warmup_s: float = 0.0, seed: int = 0,
                 topology: Optional[network.Topology] = None,
                 placement: Optional[Placement] = None,
                 min_share_frac: float = 0.0,
                 core_oversubscription: float = 1.0,
                 adaptive_concurrency: bool = False,
                 horizon: bool = False,
                 event_skip: bool = True,
                 route_aware: bool = False,
                 fault_plan=None, evacuate_on_fail: bool = True,
                 retry_backoff_s: float = 4.0, retry_max: int = 3,
                 retry_jitter: float = 0.0, retry_jitter_seed: int = 0,
                 guard: Optional[MigrationGuard] = None):
        self.jobs = {j.job_id: j for j in jobs}
        self.rng = np.random.default_rng(seed)
        self.lmcm = LMCM(policy=policy, max_wait=max_wait,
                         max_concurrent=max_concurrent, bandwidth=bandwidth,
                         sample_period=sample_period,
                         min_share_frac=min_share_frac,
                         retry_backoff_s=retry_backoff_s,
                         retry_max=retry_max,
                         retry_jitter=retry_jitter,
                         retry_jitter_seed=retry_jitter_seed)
        # fault injection (scenarios/faults.py): events fire at the first
        # sampling boundary >= their t, as event boundaries the skip
        # paths never jump over. An EMPTY plan normalizes to None — by
        # construction identical to no plan at all, which is the
        # empty-FaultPlan parity contract every existing benchmark and
        # bit-identity check relies on.
        self._fault_plan = fault_plan if fault_plan else None
        self._fault_idx = 0
        self._down_hosts: set = set()
        self._evacuate_on_fail = evacuate_on_fail
        # (job_id, t, partial_bytes, path) per aborted lane, cumulative;
        # run_with_plan slices its window out for SimResult
        self._abort_log: List[Tuple[str, float, float, Tuple[str, ...]]] = []
        self._failed_jobs: List[str] = []
        self._retry_count = 0
        self._restart_count = 0
        self.bandwidth = bandwidth
        if topology is None:
            if placement is not None:
                # the default fleet substrate: a star fabric — one access
                # link per host at the migration-network speed, joined by a
                # core sized at (n_hosts x access) / oversubscription (1:1
                # leaves the core non-binding; raise the ratio to study an
                # oversubscribed spine)
                hosts = list(placement.hosts)
                topology = network.Topology.star(
                    hosts, bandwidth,
                    core_capacity=len(hosts) * bandwidth
                    / max(core_oversubscription, 1e-9))
            else:
                topology = network.Topology.single_link(bandwidth)
        self.topology = topology
        self.placement = placement
        # prediction guard (core/guard.py): one shared watchdog instance
        # plumbed into every migration domain's plane; None (the default)
        # takes no guard code path anywhere — bit-identical to a
        # guard-less build
        self._guard = guard
        self.plane = ShardedPlane(self.topology, guard=guard)
        # multi-route fabrics (Topology.pod_spine): re-pick each launch's
        # route greedily at its release boundary (best probed share, see
        # ShardedPlane.pick_route). Requests are still stamped with route
        # 0 at submit (probe input); with the adaptive controller wired
        # in, the controller's defer-k x route sweep stamps routes itself
        # and this knob is moot. No-op on single-route topologies.
        self._route_aware = route_aware
        self.lmcm.bandwidth_probe = lambda req, extra=0, pending=(): \
            self.plane.probe_bandwidth(req.src, req.dst, extra,
                                       pending=pending)
        # the launch gate's floor reference: the request's uncontended
        # path capacity (on multi-rack substrates the ToR/core bottleneck,
        # NOT the nominal access speed)
        self.lmcm.path_capacity = lambda req: \
            self.plane.path_capacity(req.src, req.dst)
        # endpoint revalidation around dead hosts — a pure no-op (True)
        # while nothing is down, so wiring it unconditionally preserves
        # the no-fault paths bit-for-bit
        self.lmcm.retarget = self._retarget_request
        if adaptive_concurrency or horizon:
            # replace the static share-floor gate with the adaptive
            # concurrency controller: defer-k sweeps per migration domain
            # over the fabric's what-if probes (min_share_frac remains the
            # fallback policy when the controller is off). ``horizon``
            # upgrades the sweep to receding-horizon admission: the
            # controller also prices "launch at the predicted cycle
            # trough" columns read from the surveillance engine's fits,
            # reprices already-in-flight lanes, and publishes per-request
            # wake times that LMCM._defer_wake turns into exact heap
            # boundaries (so event-skip never jumps a re-admission).
            from repro.core.controller import AdaptiveConcurrencyController
            self.lmcm.controller = AdaptiveConcurrencyController(
                self.plane,
                rate_of=lambda req: (
                    self.jobs[req.job_id].trace.rate_table
                    if req.job_id in self.jobs else None),
                defer_s=sample_period,
                horizon=horizon,
                trough_of=self._trough_of if horizon else None)
            if horizon:
                # horizon admission reads cycle fits even under
                # policy="immediate" — keep the engine ticking and its
                # refresh boundaries visible to the event-skip paths
                self.lmcm.force_surveillance = True
        self.dt = sample_period
        self.now = 0.0
        # adopt jobs constructed with a default (empty) buffer into the
        # fleet SoA store; pre-filled custom buffers are kept as-is
        self.telemetry = FleetTelemetry(len(jobs), capacity=16384)
        self._job_list = list(jobs)
        self._job_row = {j.job_id: i for i, j in enumerate(self._job_list)}
        # job rows currently under a telemetry_blackout fault: their
        # samples are overwritten with NaN AFTER the rng draw, so the
        # stream (and every non-blacked-out value) is unchanged and the
        # scalar/bulk recording paths stay bit-identical
        self._blackout_rows: set = set()
        for idx, j in enumerate(self._job_list):
            if (len(j.telemetry) == 0
                    and tuple(j.telemetry.fields) == self.telemetry.fields):
                j.telemetry = self.telemetry.view(idx)
        self._soa_record = all(
            getattr(j.telemetry, "fleet", None) is self.telemetry
            and j.telemetry.index == i
            for i, j in enumerate(self._job_list))
        # bulk (vectorized, bit-identical) telemetry recording is possible
        # when every job records into the fleet SoA store through the
        # stock WorkloadTrace sampler — the precondition for both the
        # run_idle fast path and run_with_plan's event skipping
        self._bulk_ok = bool(self._job_list) and self._soa_record and all(
            isinstance(j.trace, WorkloadTrace)
            and type(j.trace).sample_indexes is WorkloadTrace.sample_indexes
            and type(j.trace).phase_at is WorkloadTrace.phase_at
            and "sample_indexes" not in vars(j.trace)
            and "phase_at" not in vars(j.trace)
            for j in self._job_list)
        self._event_skip = event_skip
        # earliest step any cycle fit can go stale, cached: fits only
        # change at/after this boundary, so it is recomputed (O(J)) only
        # when the clock reaches it — not on every idle tick
        self._refresh_boundary: Optional[float] = None
        if self._bulk_ok:
            self._bulk_tables = self._build_bulk_tables()
        nb = make_training_nb()
        for j in jobs:
            # surveillance window: >=4 observed cycles, else the FFT cannot
            # resolve the period (max detectable period is window/2)
            window = int(min(4096, max(512, 4 * j.trace.cycle_s / self.dt)))
            self.lmcm.register_job(
                j.job_id, j.telemetry, nb, window=window,
                dirty_rate_fn=j.trace.dirty_rate)
        if warmup_s:
            self.run_idle(warmup_s)

    def _record_all(self) -> None:
        """One telemetry sample per job — a single (J, F) SoA append when
        every job lives in the fleet store."""
        step = int(self.now / self.dt)
        if self._soa_record:
            vals = np.empty((len(self._job_list), len(self.telemetry.fields)))
            for i, j in enumerate(self._job_list):
                s = j.trace.sample_indexes(self.now, self.rng)
                vals[i] = [s[f] for f in self.telemetry.fields]
            if self._blackout_rows:
                vals[sorted(self._blackout_rows)] = np.nan
            self.telemetry.record_fleet(step, vals)
        else:
            for i, j in enumerate(self._job_list):
                s = j.trace.sample_indexes(self.now, self.rng)
                if i in self._blackout_rows:
                    s = dict.fromkeys(s, float("nan"))
                j.telemetry.record(step, **s)

    def _step_times(self, steps: int) -> np.ndarray:
        """The next ``steps``+1 clock values under the per-second loop's
        ``now += dt`` accumulation — cumsum reproduces the float rounding
        of the sequential loop bit-for-bit ([0] is the current clock,
        [:-1] are the iteration clocks, [-1] is the clock after the last
        iteration)."""
        return np.cumsum(np.concatenate([[self.now],
                                         np.full(steps, self.dt)]))

    def _build_bulk_tables(self):
        """Per-job phase tables stacked for the bulk recorder: padded
        phase-end matrix (J, W), per-job last-phase index, cycle, offset,
        jitter, and the (J, P, F) per-phase load-index means in telemetry
        field order (the exact scalars ``sample_indexes`` derives per
        call)."""
        traces = [j.trace for j in self._job_list]
        # the rate tables already carry one (end, rate) entry per phase:
        # reuse their padded stacking (ends inf-padded, one row per job)
        ends, _, cyc, off = PiecewiseRate.stack(
            [t.rate_table for t in traces])
        base = np.zeros((len(traces), ends.shape[1],
                         len(self.telemetry.fields)))
        for i, tr in enumerate(traces):
            for p, n in enumerate(tr._names):
                base[i, p] = phase_means(n)
        return (ends, np.asarray([len(t._names) - 1 for t in traces]),
                cyc, off, np.asarray([t.jitter for t in traces]), base)

    def _record_bulk(self, times: np.ndarray) -> None:
        """One (S, J, F) telemetry append for the per-step samples at
        ``times`` — ring contents and rng stream identical to S
        ``_record_all`` calls (the Generator draws the same normal
        sequence whether sampled scalar-by-scalar or as one array, and
        every per-element op mirrors ``WorkloadTrace.sample_indexes``:
        same modulo/compare phase lookup, same ``v * (1 + jitter * z)``
        float order). No per-step or per-job Python — phase indices come
        from one padded compare against the precomputed tables. Callers
        must have checked ``self._bulk_ok``. Long windows append in
        bounded step chunks (the rng stream is sequential, so chunked
        draws equal one big draw): peak scratch stays O(chunk x J x F)
        instead of O(window x J x F) at 10k-job fleets."""
        n_jobs, n_fields = len(self._job_list), len(self.telemetry.fields)
        chunk = max(1, int(4e6 // max(1, n_jobs * n_fields)))
        for lo in range(0, len(times), chunk):
            self._record_bulk_chunk(times[lo:lo + chunk], n_jobs,
                                    n_fields)

    def _record_bulk_chunk(self, times: np.ndarray, n_jobs: int,
                           n_fields: int) -> None:
        s = len(times)
        if s == 0:
            return
        ends, last, cyc, off, jitter, base = self._bulk_tables
        z = self.rng.standard_normal((s, n_jobs, n_fields))
        tc = np.mod(times[:, None] + off, cyc)             # (S, J)
        # phase index: count of phase ends <= tc (== searchsorted
        # side="right"), clamped like PiecewiseRate.index_at
        idx = np.minimum((tc[:, :, None] >= ends).sum(axis=2), last)
        vals = np.multiply(z, jitter[None, :, None])
        vals += 1.0
        vals *= base[np.arange(n_jobs)[None, :], idx]
        np.maximum(vals, 0.0, out=vals)
        if self._blackout_rows:
            # blackout membership is constant within a chunk: telemetry
            # fault events are skip/bulk boundaries like any other fault
            vals[:, sorted(self._blackout_rows), :] = np.nan
        self.telemetry.record_fleet_bulk(
            (times / self.dt).astype(np.int64), vals)

    def run_idle(self, seconds: float) -> None:
        """Advance the clock recording telemetry only (warmup / idle
        stretches). With the fleet SoA store and stock traces this is one
        bulk append instead of O(seconds) Python iterations, with
        bit-identical ring contents, rng stream, and clock."""
        steps = int(seconds / self.dt)
        if steps <= 0:
            return
        if self._event_skip and self._bulk_ok:
            nows = self._step_times(steps)
            if self._fault_plan is None:
                self._record_bulk(nows[:-1])
                self.now = float(nows[-1])
                return
            # fault events are boundaries the bulk append may not cross:
            # record in segments, firing the due faults at each segment
            # head — chunked rng draws equal one big draw, so ring
            # contents, stream, and clock stay bit-identical to the
            # per-second loop below
            cand = nows[:-1]
            lo = 0
            while lo < steps:
                self._apply_faults(float(cand[lo]))
                t_f = self._next_fault_time()
                hi = steps if not np.isfinite(t_f) else \
                    max(lo + 1, int(np.searchsorted(cand, t_f,
                                                    side="left")))
                self._record_bulk(cand[lo:hi])
                lo = hi
            self.now = float(nows[-1])
            return
        for _ in range(steps):
            if self._fault_plan is not None:
                self._apply_faults(self.now)
            self._record_all()
            self.now += self.dt

    # -- fault injection -----------------------------------------------------
    def _next_fault_time(self) -> float:
        """Sim time of the next unapplied fault event (inf when the plan
        is exhausted or absent) — a hard skip/bulk boundary."""
        if self._fault_plan is None or \
                self._fault_idx >= len(self._fault_plan.events):
            return float("inf")
        return self._fault_plan.events[self._fault_idx].t

    def _apply_faults(self, now: float, launch_info=None) -> None:
        """Fire every fault event due at or before ``now`` (events are
        quantized to the first sampling boundary >= their t). A host
        failure aborts the in-flight lanes touching the host, re-admits
        them through the LMCM's backoff path, and (with
        ``evacuate_on_fail``) cold-restarts the VMs resident on the dead
        host; link events push the new capacity through the fabric."""
        while self._next_fault_time() <= now:
            ev = self._fault_plan.events[self._fault_idx]
            self._fault_idx += 1
            if ev.kind == "host_fail":
                self._down_hosts.add(ev.target)
                for req, outcome in self.plane.fail_host(ev.target):
                    self._handle_abort(req, outcome, now, launch_info)
                if self._evacuate_on_fail:
                    self._submit_restarts(ev.target, now)
            elif ev.kind == "host_recover":
                self._down_hosts.discard(ev.target)
            elif ev.kind == "link_fail":
                # correlated ToR/pod-uplink outage: capacity drops AND the
                # lanes riding the link abort into the retry path (which
                # re-routes around the outage on multi-route fabrics) —
                # unlike a 0.0 link_degrade, which stalls them in place
                self.plane.set_link_capacity(ev.target, ev.capacity)
                for req, outcome in self.plane.abort_link(ev.target):
                    self._handle_abort(req, outcome, now, launch_info)
            elif ev.kind == "telemetry_blackout":
                self._blackout_rows.update(
                    self._job_row[j] for j in ev.jobs if j in self._job_row)
            elif ev.kind == "telemetry_restore":
                self._blackout_rows.difference_update(
                    self._job_row[j] for j in ev.jobs if j in self._job_row)
            else:                        # link_degrade / link_restore
                self.plane.set_link_capacity(ev.target, ev.capacity)

    def _handle_abort(self, req: MigrationRequest,
                      outcome: strunk.MigrationOutcome, now: float,
                      launch_info=None) -> None:
        """Bookkeeping for one aborted lane: log the wasted partial bytes
        against the abort-time path (retries may re-route), drop the
        stale launch record, and hand the request to ``LMCM.fail`` for
        backoff re-admission or permanent failure."""
        self._abort_log.append((req.job_id, now, outcome.bytes_sent,
                                tuple(req.path)))
        if launch_info is not None:
            launch_info.pop(id(req), None)
        if self.lmcm.fail(req, outcome, now):
            self._retry_count += 1
        else:
            self._failed_jobs.append(req.job_id)

    def _handle_guard_abort(self, req: MigrationRequest,
                            outcome: strunk.MigrationOutcome, now: float,
                            launch_info=None) -> None:
        """A guard abort is misprediction feedback, not just a failed
        lane: the fit that priced the launch was wrong, so force it
        stale (refit at the next surveillance tick instead of waiting
        out the staleness epoch) and decay the job's ``trust`` — which
        gates the receding-horizon trough pricing through
        ``MigrationGuard.trusts``. The lane itself then takes the normal
        abort path (wasted-bytes log + ``LMCM.fail`` backoff)."""
        sj = self.lmcm.engine.jobs.get(req.job_id)
        if sj is not None:
            sj.trust = self._guard.decay_trust(sj.trust)
            if sj.fitted_step >= 0:
                sj.fitted_step = -1
                self.lmcm.engine._decide_cache = None
                # the cached stale boundary assumed no forced refits
                self._refresh_boundary = None
        self._handle_abort(req, outcome, now, launch_info)

    def _stamp_expectation(self, req: MigrationRequest,
                           job: SimJob) -> None:
        """Price the launch the guard will hold the lane to: the Strunk
        cost at the fair share the fabric probes for one more lane on
        the request's path, against the job's registered rate table.
        This is the plane's own cost model under the launch-time state
        of the world — divergence beyond it means contention, faults, or
        throttle-resistant dirtying the admission price did not see."""
        bw = self.plane.probe_bandwidth(req.src, req.dst, 1)
        out = strunk.what_if_cost_batch(
            [req.v_bytes], bw, [job.trace.rate_table], [self.now],
            full=True)
        req.expected_bytes = float(out.bytes_sent[0])
        req.expected_time = float(out.total_time[0])

    def _live_hosts(self) -> List[str]:
        return [h for h in self.placement.hosts
                if h not in self._down_hosts]

    def _submit_restarts(self, host: str, now: float) -> None:
        """Cold-restart the VMs resident on a dead host: their memory
        state is lost, so recovery re-sources each image from a live
        host and flows through the normal LMCM pipeline as an urgent
        request (no policy postponement — there is no workload left to
        time against; concurrency control still applies). VMs already
        covered by a live request (in flight and just re-admitted, or
        queued) are skipped — the retry path owns them."""
        if self.placement is None or host not in self.placement.hosts:
            return
        in_play = {r.job_id for r in self.lmcm.running
                   if r.decision == "running"}
        in_play |= {entry[2].job_id for entry in self.lmcm.queue
                    if entry[2].decision == "scheduled"}
        for job_id in sorted(self.placement.hosts[host].jobs):
            if job_id in in_play or job_id not in self.jobs:
                continue
            req = self._restart_request(job_id, now)
            if req is None:
                self._failed_jobs.append(job_id)
                continue
            req.urgent = True
            self._restart_count += 1
            self.lmcm.submit(req, now)

    def _restart_request(self, job_id: str, now: float
                         ) -> Optional[MigrationRequest]:
        """An urgent recovery request for a VM lost with its host: dst is
        the least-loaded live host, src a live image source (the cold
        restart streams the image, not the dead RAM). None when no live
        host remains."""
        live = self._live_hosts()
        if not live:
            return None
        dst = min(live, key=lambda h: (self.placement.hosts[h].load, h))
        src = next((h for h in live if h != dst), dst)
        req = MigrationRequest(job_id, created_at=now,
                               v_bytes=self.jobs[job_id].v_bytes,
                               src=src, dst=dst)
        req.path = self.topology.path(src, dst)
        return req

    def _retarget_request(self, req: MigrationRequest) -> bool:
        """LMCM ``retarget`` hook: keep a request's endpoints off dead
        hosts. A pure no-op (True) while nothing is down — the wiring
        itself changes no fault-free behavior. A dead destination is
        replaced by the least-loaded live host; a dead source means the
        VM's transferable state is gone, so recovery re-sources from a
        live host (cold restart from the image store). Returns False
        when no live host can serve the request."""
        if not self._down_hosts:
            return True
        if self.placement is None:
            return req.src not in self._down_hosts \
                and req.dst not in self._down_hosts
        changed = False
        if req.dst in self._down_hosts:
            live = [h for h in self._live_hosts() if h != req.src]
            if not live:
                return False
            req.dst = min(live,
                          key=lambda h: (self.placement.hosts[h].load, h))
            changed = True
        if req.src in self._down_hosts:
            live = [h for h in self._live_hosts() if h != req.dst]
            if not live:
                return False
            req.src = live[0]
            changed = True
        if changed:
            req.path = self.topology.path(req.src, req.dst)
        return True

    def _tag_request(self, req: MigrationRequest) -> None:
        """Resolve src (via the placement's O(1) job->host index) and the
        network links the transfer will traverse."""
        if self.placement is not None and not req.src:
            req.src = self.placement.host_of(req.job_id) or ""
        req.path = self.topology.path(req.src, req.dst)

    def _trough_of(self, req: MigrationRequest,
                   now: float) -> Optional[float]:
        """Controller ``trough_of`` hook: Alg. 2 RemainTime to the job's
        next predicted cycle trough, in seconds (None when the job has no
        cyclic fit — the controller then prices the plain one-period
        defer instead). With a guard wired, a fit whose
        ``confidence x trust`` falls below the guard's gate is treated
        as no fit at all: guard aborts burned the model's credibility,
        so the controller falls back to myopic pricing until refits
        re-earn it."""
        if self._guard is not None:
            sj = self.lmcm.engine.jobs.get(req.job_id)
            if (sj is not None and sj.model is not None
                    and not self._guard.trusts(sj.model.confidence,
                                               sj.trust)):
                return None
        remain = self.lmcm.engine.next_trough(
            [req.job_id], int(now / self.dt)).get(req.job_id)
        return None if remain is None else float(remain) * self.dt

    def _skip_idle_steps(self, pending: Sequence[MigrationRequest],
                         t_end: float) -> None:
        """Fast-forward over per-second iterations that would be pure
        telemetry: nothing in flight, no arrival due, no heap release,
        and no surveillance epoch going stale. The clock jumps straight
        to the next pending arrival / LMCM due / refresh boundary (or the
        horizon), bulk-appending the skipped samples — ring contents, rng
        stream, clock accumulation, and every fit/decision are
        bit-identical to ticking one second at a time (skipped iterations
        are provably no-ops: ``refresh()`` touches nothing before the
        stale boundary and ``due()`` pops nothing before the heap head).
        """
        nxt_arr = pending[0].created_at if pending else np.inf
        nxt_due = self.lmcm.next_due_time()
        # fault events are first-class boundaries the skip may NEVER
        # jump over: a crash must abort lanes / submit restarts at its
        # own quantized boundary, not at the next arrival (inf when no
        # plan — the mask below degenerates to all-True)
        nxt_fault = self._next_fault_time()
        now_step = int(self.now / self.dt)
        if not self.lmcm.uses_surveillance:
            # no-surveillance policies never tick the engine (no fits to
            # keep on schedule): only arrivals and the heap bound skips
            nxt_refresh = np.inf
        else:
            # a fit can only change at/after the cached boundary (a job
            # is stale no earlier than it), so the O(J) engine scan runs
            # once per boundary, not once per idle tick
            if (self._refresh_boundary is None
                    or now_step >= self._refresh_boundary):
                self._refresh_boundary = \
                    self.lmcm.engine.next_refresh_step(now_step)
            nxt_refresh = self._refresh_boundary
        # candidate iteration count (slack-padded estimate; the exact
        # prefix is re-checked on the generated clocks below)
        bound = min(t_end, nxt_arr, nxt_due, nxt_fault,
                    self.now + (nxt_refresh - now_step) * self.dt)
        cap = int(max(0.0, (bound - self.now) / self.dt)) + 1
        if cap <= 1:
            return
        nows = self._step_times(cap)
        cand = nows[:-1]                       # per-iteration clocks
        safe = ((cand < t_end) & (cand < nxt_arr) & (cand < nxt_due)
                & (cand < nxt_fault)
                & ((cand / self.dt).astype(np.int64) < nxt_refresh))
        stop = int(np.argmin(safe)) if not safe.all() else cap
        if stop <= 0:
            return
        self._record_bulk(cand[:stop])
        self.now = float(nows[stop])

    def run_with_plan(self, plan: Sequence[MigrationRequest],
                      horizon_s: float = 3600.0) -> SimResult:
        pending = sorted(plan, key=lambda r: r.created_at)
        per_job: Dict[str, strunk.MigrationOutcome] = {}
        done: List[MigrationRequest] = []
        completed_at: Dict[str, float] = {}
        lm_hits = 0
        # lm-hit (launched in a non-MEM phase) and launch time, recorded at
        # release but only counted for migrations that actually complete
        launch_info: Dict[int, Tuple[bool, float]] = {}
        first_launch, last_finish = np.inf, 0.0
        # window markers into the cumulative fault accounting
        n_abort0, n_fail0 = len(self._abort_log), len(self._failed_jobs)
        n_retry0 = self._retry_count
        faults_live = self._fault_plan is not None
        t_end = self.now + horizon_s
        while self.now < t_end and (pending or self.lmcm.queue
                                    or self.lmcm.running
                                    or self.plane.in_flight
                                    or (faults_live and
                                        self._next_fault_time() < t_end)):
            if faults_live:
                # fault boundary first: aborts/restarts/capacity changes
                # take effect before this iteration's releases and
                # execution (the skip path stops exactly here)
                self._apply_faults(self.now, launch_info)
            if (self._event_skip and self._bulk_ok
                    and self.plane.in_flight == 0
                    and not self.plane._pending
                    and (pending or self.lmcm.queue
                         or (faults_live and
                             np.isfinite(self._next_fault_time())))):
                self._skip_idle_steps(pending, t_end)
                if self.now >= t_end:
                    break
                if faults_live:
                    # the skip stops exactly ON a fault boundary: fire it
                    # before this iteration's telemetry/releases, matching
                    # the per-second loop's apply-then-record order
                    self._apply_faults(self.now, launch_info)
            while pending and pending[0].created_at <= self.now:
                req = pending.pop(0)
                self._tag_request(req)
                self.lmcm.submit(req, self.now)
            self._record_all()
            if self.lmcm.uses_surveillance:
                # batched fleet surveillance (the immediate baseline is
                # the paper's no-surveillance policy: it never reads a
                # cycle fit, so refreshing fits for it would be pure
                # waste at fleet scale)
                self.lmcm.tick(self.now)
            for req in self.lmcm.due(self.now):
                job = self.jobs[req.job_id]
                # accuracy metric (Figs. 8-9): did we fire in a non-MEM phase?
                launch_info[id(req)] = (job.trace.phase_at(self.now) != "MEM",
                                        self.now)
                first_launch = min(first_launch, self.now)
                if self._route_aware and self.lmcm.controller is None:
                    # greedy launch-time route choice (the controller, when
                    # wired, stamps sweep-assigned routes on req.path)
                    req.path = self.plane.pick_route(req.src, req.dst)
                if self._guard is not None:
                    # stamp the admission-time price the guard holds the
                    # lane to (NaN-free only when a guard is wired — the
                    # stamping itself must not perturb guardless runs)
                    self._stamp_expectation(req, job)
                # register the lane with its PiecewiseRate table so the
                # plane's vectorized event loop accrues its dirty bytes
                # through the batched lookup (see core/rates.py)
                self.plane.launch(req, job.trace.rate_table, self.now,
                                  path=req.path or None)
            self.now += self.dt
            # one sampling period of contended execution: every in-flight
            # migration advances together, link shares recomputed at events
            for req, outcome in self.plane.advance(self.now):
                if outcome.stop_reason == strunk.STOP_GUARD:
                    # convergence watchdog cut the lane: misprediction
                    # feedback + backoff re-admission, not a completion
                    self._handle_guard_abort(req, outcome, self.now,
                                             launch_info)
                    continue
                self.lmcm.finish(req, outcome)
                per_job[req.job_id] = outcome
                done.append(req)
                completed_at[req.job_id] = self.now
                hit, launched_at = launch_info.pop(id(req))
                lm_hits += hit
                last_finish = max(last_finish,
                                  launched_at + outcome.total_time)
                if self.placement is not None and req.dst:
                    self.placement.move(req.job_id, req.dst)
        total_bytes = sum(o.bytes_sent for o in per_job.values())
        times = [o.total_time for o in per_job.values()]
        downs = [o.downtime for o in per_job.values()]
        abort_log = list(self._abort_log[n_abort0:])
        return SimResult(
            migrations=done,
            total_bytes=total_bytes,
            total_time=float(np.sum(times)) if times else 0.0,
            mean_migration_time=float(np.mean(times)) if times else 0.0,
            mean_downtime=float(np.mean(downs)) if downs else 0.0,
            per_job=per_job,
            lm_hit_rate=lm_hits / max(1, len(done)),
            makespan=(last_finish - first_launch) if done else 0.0,
            link_bytes=dict(self.plane.link_bytes),
            aborted_bytes=float(sum(b for _, _, b, _ in abort_log)),
            n_aborts=len(abort_log),
            n_retries=self._retry_count - n_retry0,
            failed_jobs=list(self._failed_jobs[n_fail0:]),
            completed_at=completed_at,
            abort_log=abort_log,
        )


# ---------------------------------------------------------------------------
# the paper's Table 3 artificial cycles + application-like traces
# ---------------------------------------------------------------------------
def table3_traces(phase_s: float = 60.0, *, replicas: int = 1
                  ) -> Dict[str, WorkloadTrace]:
    """The paper's four Table 3 cycles; ``replicas`` > 1 instantiates each
    cycle multiple times with staggered phase offsets (the contended-fleet
    scenario: many VMs of the same applications, out of phase)."""
    def t(names, off):
        return WorkloadTrace([(n, phase_s) for n in names], total_s=3600,
                             offset=off)
    base = {
        "vm03_A": ["IO", "CPU", "CPU", "IO", "CPU", "CPU", "IO", "CPU",
                   "CPU"],
        "vm02_C": ["MEM", "IDLE", "CPU", "MEM", "IDLE", "CPU", "MEM",
                   "IDLE", "CPU"],
        "vm02_A": ["MEM", "CPU", "CPU", "MEM", "CPU", "CPU", "MEM", "CPU",
                   "CPU", "MEM", "CPU", "CPU"],
        "vm01_C": ["MEM", "IDLE", "CPU", "MEM", "IDLE", "CPU"],
    }
    if replicas == 1:
        return {name: t(names, 0.0) for name, names in base.items()}
    out: Dict[str, WorkloadTrace] = {}
    for name, names in base.items():
        cycle = phase_s * len(names)
        for r in range(replicas):
            out[f"{name}.{r}"] = t(names, r * cycle / replicas)
    return out


def application_traces(phase_s: float = 45.0, *, replicas: int = 1
                       ) -> Dict[str, WorkloadTrace]:
    """Application analogues (paper §6.3.2): long irregular phases.
    OpenModeller ~ CPU-dominant with IO bursts; BRAMS ~ complex cycle;
    Hadoop/TeraSort ~ shuffle-heavy (MEM/IO alternation). ``replicas`` > 1
    de-phases multiple instances of each application (contended fleets)."""
    base = {
        "vm03_A_openmodeller": [("IO", phase_s), ("CPU", 4 * phase_s),
                                ("MEM", phase_s), ("CPU", 3 * phase_s)],
        "vm02_C_brams": [("MEM", phase_s), ("CPU", 2 * phase_s),
                         ("MEM", 2 * phase_s), ("IO", phase_s),
                         ("CPU", 2 * phase_s), ("IDLE", phase_s)],
        "vm01_C_hadoop": [("IO", phase_s), ("MEM", 2 * phase_s),
                          ("CPU", phase_s), ("IO", 2 * phase_s)],
        "vm02_A_hadoop": [("MEM", 2 * phase_s), ("IO", phase_s),
                          ("CPU", phase_s), ("MEM", phase_s),
                          ("IO", phase_s)],
    }
    if replicas == 1:
        return {n: WorkloadTrace(spec, total_s=7200)
                for n, spec in base.items()}
    out: Dict[str, WorkloadTrace] = {}
    for n, spec in base.items():
        cycle = sum(d for _, d in spec)
        for r in range(replicas):
            out[f"{n}.{r}"] = WorkloadTrace(spec, total_s=7200,
                                            offset=r * cycle / replicas)
    return out
