"""FFT cycle recognition and cycle decomposition (paper §4.2, Algorithm 1).

Input is the chronologically ordered LM/NLM classification series from the
characterizer. ``cycle_length`` extracts the dominant period via the power
spectrum (O(n log n), exactly the paper's tool); ``decompose`` is Algorithm 1:
one cycle window is split into the suitable (ArrayLM) and unsuitable
(ArrayNLM) moment sets. Simple and complex (multi-interval) cycles both fall
out of the same machinery.

Beyond the paper ('alma-plus'): ``fold_profile`` replaces the first-window
slice with a phase-folded majority vote over *all* observed cycles (more
robust to classifier noise), and a confidence score (peak power / total
power) gates orchestration decisions.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

from repro.kernels import ops as kops


@dataclass
class CycleModel:
    period: int                    # samples per cycle (0 = acyclic)
    confidence: float              # spectral peak share in (0, 1]
    profile_lm: np.ndarray         # (period,) int8: 1 = LM at this phase
    array_lm: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    array_nlm: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))

    @property
    def cyclic(self) -> bool:
        return self.period > 1 and 0 < self.profile_lm.sum() < self.period


def power_spectrum(series: np.ndarray, use_kernel: bool = True) -> np.ndarray:
    """|FFT|^2 of the mean-removed series. Uses the Pallas MXU matmul-DFT
    kernel (interpret mode on CPU) for the sizes it tiles well; falls back to
    numpy's pocketfft otherwise."""
    x = np.asarray(series, np.float32)
    x = x - x.mean()
    if use_kernel and kops.dft_supported(x.shape[-1]):
        return np.asarray(kops.power_spectrum(x[None]))[0]
    f = np.fft.rfft(x)
    return (f.real ** 2 + f.imag ** 2).astype(np.float32)


def cycle_length(series: np.ndarray, *, min_period: int = 2,
                 max_period: Optional[int] = None,
                 use_kernel: bool = True) -> Tuple[int, float]:
    """Dominant cycle length of a series. Returns (period, confidence).

    period = round(N / k*) with k* the argmax power bin whose implied period
    lies in [min_period, max_period]; confidence is that bin's share of total
    (DC-removed) spectral mass.
    """
    n = len(series)
    if n < 2 * min_period:
        return 0, 0.0
    max_period = min(max_period or n // 2, n // 2)
    p = power_spectrum(series, use_kernel=use_kernel)
    p = p[: n // 2 + 1].copy()
    p[0] = 0.0                                     # drop DC
    ks = np.arange(len(p))
    with np.errstate(divide="ignore"):
        periods = np.where(ks > 0, n / np.maximum(ks, 1), np.inf)
    valid = (periods >= min_period) & (periods <= max_period)
    if not valid.any() or p[valid].max() <= 0:
        return 0, 0.0
    k_star = int(np.argmax(np.where(valid, p, -1.0)))
    conf = float(p[k_star] / max(p.sum(), 1e-12))
    p0 = int(round(n / k_star))
    return _refine_period(np.asarray(series, np.float64), p0,
                          min_period, max_period), conf


def _refine_period(x: np.ndarray, p0: int, min_period: int,
                   max_period: int) -> int:
    """Sharpen the FFT bin estimate with a local autocorrelation search.

    FFT periods are quantized to n/k (a 512-sample window puts a true
    120-sample cycle into the 128 bin — enough drift to break Algorithm 2's
    modular indexing four cycles out). The spectral peak still *finds* the
    cycle (the paper's tool); the lag search just de-quantizes it within
    +/- one bin width.
    """
    n = len(x)
    x = x - x.mean()
    denom = float(x @ x) or 1.0
    span = max(2, int(np.ceil(p0 * p0 / n)) + 1)
    lo = max(min_period, p0 - span)
    hi = min(max_period, n - 1, p0 + span)
    best_p, best_r = p0, -np.inf
    for p in range(lo, hi + 1):
        r = float(x[:-p] @ x[p:]) / denom
        if r > best_r:
            best_p, best_r = p, r
    return best_p


def decompose(classes: np.ndarray, period: int
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Algorithm 1 (verbatim): split the first cycle window of the LM/NLM
    series into (ArrayLM, ArrayNLM) moment-index arrays; also returns the
    (period,) LM profile used by Algorithm 2."""
    c = np.asarray(classes[:period], np.int8)
    idx = np.arange(len(c))
    array_lm = idx[c == 1]
    array_nlm = idx[c != 1]
    return array_lm, array_nlm, c


def fold_profile(classes: np.ndarray, period: int) -> np.ndarray:
    """'alma-plus': phase-folded majority vote across all observed cycles."""
    n = (len(classes) // period) * period
    if n == 0:
        return np.asarray(classes[:period], np.int8)
    folded = np.asarray(classes[:n]).reshape(-1, period)
    return (folded.mean(axis=0) >= 0.5).astype(np.int8)


def fit_cycle_batch(classes_batch: np.ndarray, *, min_period: int = 2,
                    max_period: Optional[int] = None,
                    folded: bool = False,
                    use_kernel: Optional[bool] = None) -> list:
    """Fleet-scale cycle recognition: one batched (Pallas MXU-DFT) power
    spectrum for all jobs, then per-job peak pick + refinement. This is the
    path the Fig. 10 scalability benchmark exercises — the per-job python
    dispatch of calling ``fit_cycle`` in a loop dominates beyond ~100 jobs.
    """
    X = np.asarray(classes_batch, np.float32)
    J, n = X.shape
    max_p = min(max_period or n // 2, n // 2)
    if use_kernel is None:
        use_kernel = kops.on_tpu()     # interpret-mode DFT is for validation,
                                       # not CPU throughput
    if use_kernel and kops.dft_supported(n):
        P = np.asarray(kops.power_spectrum(X - X.mean(axis=1, keepdims=True)))
    else:
        F = np.fft.rfft(X - X.mean(axis=1, keepdims=True), axis=1)
        P = (F.real ** 2 + F.imag ** 2).astype(np.float32)
    ks = np.arange(P.shape[1])
    with np.errstate(divide="ignore"):
        periods = np.where(ks > 0, n / np.maximum(ks, 1), np.inf)
    valid = (periods >= min_period) & (periods <= max_p)
    Pv = np.where(valid[None, :], P, -1.0)
    Pv[:, 0] = -1.0
    k_star = np.argmax(Pv, axis=1)
    conf = P[np.arange(J), k_star] / np.maximum(P[:, 1:].sum(axis=1), 1e-12)
    out = []
    for j in range(J):
        if Pv[j, k_star[j]] <= 0:
            out.append(CycleModel(0, 0.0, np.asarray(
                [1 if X[j].mean() >= 0.5 else 0], np.int8)))
            continue
        p0 = int(round(n / k_star[j]))
        period = _refine_period(X[j].astype(np.float64), p0, min_period,
                                max_p)
        cls = np.asarray(classes_batch[j], np.int8)
        array_lm, array_nlm, profile = decompose(cls, period)
        if folded:
            profile = fold_profile(cls, period)
            idx = np.arange(period)
            array_lm, array_nlm = idx[profile == 1], idx[profile != 1]
        out.append(CycleModel(period, float(conf[j]), profile, array_lm,
                              array_nlm))
    return out


def fit_cycle(classes: np.ndarray, *, min_period: int = 2,
              max_period: Optional[int] = None, folded: bool = False,
              use_kernel: bool = True) -> CycleModel:
    """Characterized series -> CycleModel (the paper pipeline in one call)."""
    period, conf = cycle_length(classes.astype(np.float32),
                                min_period=min_period, max_period=max_period,
                                use_kernel=use_kernel)
    if period <= 1:
        profile = np.asarray([1 if np.mean(classes) >= 0.5 else 0], np.int8)
        return CycleModel(0, conf, profile)
    array_lm, array_nlm, profile = decompose(classes, period)
    if folded:
        profile = fold_profile(classes, period)
        idx = np.arange(period)
        array_lm, array_nlm = idx[profile == 1], idx[profile != 1]
    return CycleModel(period, conf, profile, array_lm, array_nlm)
