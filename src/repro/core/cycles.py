"""FFT cycle recognition and cycle decomposition (paper §4.2, Algorithm 1).

Input is the chronologically ordered LM/NLM classification series from the
characterizer. ``cycle_length`` extracts the dominant period via the power
spectrum (O(n log n), exactly the paper's tool); ``decompose`` is Algorithm 1:
one cycle window is split into the suitable (ArrayLM) and unsuitable
(ArrayNLM) moment sets. Simple and complex (multi-interval) cycles both fall
out of the same machinery.

Beyond the paper ('alma-plus'): ``fold_profile`` replaces the first-window
slice with a phase-folded majority vote over *all* observed cycles (more
robust to classifier noise), and a confidence score (peak power / DC-removed
spectral mass) gates orchestration decisions.

Fleet scale: the scalar path (``fit_cycle``) is a J=1 view of the batched
path (``fit_cycle_batch``) — one shared spectrum routine, one shared peak
pick, one shared autocorrelation refinement — so both produce bit-identical
periods/profiles and confidences for the same series by construction. The
batched refinement scores the whole fleet against a shared candidate-lag
grid in one vectorized pass (Pallas ``autocorr_score`` on TPU, f64 einsum
off-TPU) instead of the per-job Python lag loop that used to dominate
surveillance ticks beyond ~100 jobs (see ``core/surveillance.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.kernels import ops as kops


@dataclass
class CycleModel:
    period: int                    # samples per cycle (0 = acyclic)
    confidence: float              # spectral peak share in (0, 1]
    profile_lm: np.ndarray         # (period,) int8: 1 = LM at this phase
    array_lm: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    array_nlm: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))

    @property
    def cyclic(self) -> bool:
        return self.period > 1 and 0 < self.profile_lm.sum() < self.period


def _resolve_kernel(use_kernel: Optional[bool]) -> bool:
    # interpret-mode Pallas is for lowering validation, not CPU throughput:
    # off-accelerator (no TPU or GPU kernel row) the default is the
    # pocketfft/numpy path.
    return kops.has_accelerator() if use_kernel is None else use_kernel


def _spectra(X: np.ndarray, use_kernel: Optional[bool],
             mesh=None) -> np.ndarray:
    """(J, n) f32 -> (J, n//2+1) one-sided power of the mean-removed rows.

    ``mesh`` row-shards the kernel path across devices (bit-identical: the
    spectrum is per-row). The numpy fallback ignores it — pocketfft rows
    are already independent and host-resident.
    """
    n = X.shape[1]
    if _resolve_kernel(use_kernel) and kops.dft_supported(n):
        return np.asarray(kops.power_spectrum(X, center=True, mesh=mesh))
    F = np.fft.rfft(X - X.mean(axis=1, keepdims=True), axis=1)
    return (F.real ** 2 + F.imag ** 2).astype(np.float32)


def power_spectrum(series: np.ndarray, use_kernel: Optional[bool] = None
                   ) -> np.ndarray:
    """One-sided |FFT|^2 of the mean-removed series. Uses the Pallas MXU
    matmul-DFT kernel (fused mean removal) for the sizes it tiles well;
    falls back to numpy's pocketfft otherwise."""
    return _spectra(np.asarray(series, np.float32)[None], use_kernel)[0]


# A near-constant window leaves only float rounding residue after mean
# removal; relative to the raw signal power that residue is ~eps(f32)^2
# (~1e-14). Real 0/1 classification series with any structure carry
# DC-removed mass >= ~1e-2 of total power, so 1e-9 cleanly separates
# "all noise floor" from "has a cycle to score".
_DEGENERATE_MASS_FRAC = 1e-9


def _total_power(X: np.ndarray) -> np.ndarray:
    """(J, n) -> (J,) raw per-row signal power (DC included), the
    reference scale for the degenerate-window confidence clamp."""
    X = np.asarray(X, np.float64)
    return (X * X).sum(axis=1)


def _peak_pick(P: np.ndarray, n: int, min_period: int, max_period: int,
               total_power: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Fleet peak pick. P: (J, n//2+1) one-sided power. Returns
    (k_star (J,), confidence (J,), found (J,) bool)."""
    ks = np.arange(P.shape[1])
    with np.errstate(divide="ignore"):
        periods = np.where(ks > 0, n / np.maximum(ks, 1), np.inf)
    valid = (periods >= min_period) & (periods <= max_period)
    Pv = np.where(valid[None, :], P, -1.0)
    Pv[:, 0] = -1.0                                # drop DC
    k_star = np.argmax(Pv, axis=1)
    rows = np.arange(P.shape[0])
    found = Pv[rows, k_star] > 0
    # confidence: peak bin's share of the DC-removed one-sided spectral
    # mass — the single normalization shared by the scalar and batch paths
    mass = P[:, 1:].sum(axis=1)
    conf = P[rows, k_star] / np.maximum(mass, 1e-12)
    if total_power is not None:
        # degenerate-window clamp: when the whole DC-removed mass is float
        # noise (mass hits the 1e-12 floor relative to raw power), the
        # "peak share" is 1.0-of-nothing — report confidence 0 so gates on
        # confidence fall back instead of trusting pure noise.
        degen = mass <= _DEGENERATE_MASS_FRAC * np.asarray(total_power)
        conf = np.where(degen, 0.0, conf)
    return k_star, conf, found


def _refine_period_batch(X: np.ndarray, p0: np.ndarray, min_period: int,
                         max_period: int, mesh=None) -> np.ndarray:
    """Sharpen FFT bin estimates with a local autocorrelation search, for
    the whole fleet at once.

    FFT periods are quantized to n/k (a 512-sample window puts a true
    120-sample cycle into the 128 bin — enough drift to break Algorithm 2's
    modular indexing four cycles out). The spectral peak still *finds* the
    cycle (the paper's tool); the lag search just de-quantizes it within
    +/- one bin width. All jobs score one shared candidate-lag grid (the
    union of their per-job windows) in a single vectorized pass; each job's
    argmax is masked to its own window.
    """
    J, n = X.shape
    X = np.asarray(X, np.float64)
    Xc = X - X.mean(axis=1, keepdims=True)
    p0 = np.asarray(p0, np.int64)
    span = np.maximum(2, np.ceil(p0 * p0 / n).astype(np.int64) + 1)
    lo = np.maximum(min_period, p0 - span)
    hi = np.minimum(np.minimum(max_period, n - 1), p0 + span)
    ok = hi >= lo
    if not ok.any():
        return p0.copy()
    if kops.has_accelerator() and n <= 2048:
        # Pallas kernel (TPU or GPU row of the dispatch table): fleet x
        # shared candidate-lag grid in one call, optionally row-sharded
        import jax.numpy as jnp
        lags = np.arange(int(lo[ok].min()), int(hi[ok].max()) + 1)
        R = np.asarray(kops.autocorr_score(
            jnp.asarray(Xc, jnp.float32),
            jnp.asarray(lags, jnp.int32), mesh=mesh)).astype(np.float64)
    else:
        # off-accelerator: Wiener-Khinchin on the zero-padded rows gives the
        # exact linear autocorrelation R[j, p] = sum_t x[t] x[t+p] at EVERY
        # lag in one vectorized pocketfft pass (interpret-mode Pallas is not
        # a CPU hot path)
        F = np.fft.rfft(Xc, 2 * n, axis=1)
        R = np.fft.irfft(F.real ** 2 + F.imag ** 2, 2 * n, axis=1)[:, :n]
        lags = np.arange(n)
    valid = (lags[None, :] >= lo[:, None]) & (lags[None, :] <= hi[:, None])
    best = lags[np.argmax(np.where(valid, R, -np.inf), axis=1)]
    return np.where(ok, best, p0)


def _refine_period(x: np.ndarray, p0: int, min_period: int,
                   max_period: int) -> int:
    """Scalar view of ``_refine_period_batch`` (kept for API compat)."""
    return int(_refine_period_batch(np.asarray(x, np.float64)[None],
                                    np.asarray([p0]), min_period,
                                    max_period)[0])


def cycle_length(series: np.ndarray, *, min_period: int = 2,
                 max_period: Optional[int] = None,
                 use_kernel: Optional[bool] = None) -> Tuple[int, float]:
    """Dominant cycle length of a series. Returns (period, confidence).

    period = round(N / k*) with k* the argmax power bin whose implied period
    lies in [min_period, max_period], de-quantized by the autocorrelation
    refinement; confidence is that bin's share of the DC-removed spectral
    mass.
    """
    x = np.asarray(series, np.float32)
    n = len(x)
    if n < 2 * min_period:
        return 0, 0.0
    max_p = min(max_period or n // 2, n // 2)
    P = _spectra(x[None], use_kernel)
    k_star, conf, found = _peak_pick(P, n, min_period, max_p,
                                     total_power=_total_power(x[None]))
    if not found[0]:
        return 0, 0.0
    p0 = int(round(n / k_star[0]))
    return _refine_period(np.asarray(series, np.float64), p0,
                          min_period, max_p), float(conf[0])


def decompose(classes: np.ndarray, period: int
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Algorithm 1 (verbatim): split the first cycle window of the LM/NLM
    series into (ArrayLM, ArrayNLM) moment-index arrays; also returns the
    (period,) LM profile used by Algorithm 2."""
    c = np.asarray(classes[:period], np.int8)
    idx = np.arange(len(c))
    array_lm = idx[c == 1]
    array_nlm = idx[c != 1]
    return array_lm, array_nlm, c


def fold_profile(classes: np.ndarray, period: int) -> np.ndarray:
    """'alma-plus': phase-folded majority vote across all observed cycles."""
    n = (len(classes) // period) * period
    if n == 0:
        return np.asarray(classes[:period], np.int8)
    folded = np.asarray(classes[:n]).reshape(-1, period)
    return (folded.mean(axis=0) >= 0.5).astype(np.int8)


def fit_cycle_batch(classes_batch: np.ndarray, *, min_period: int = 2,
                    max_period: Optional[int] = None,
                    folded: bool = False,
                    use_kernel: Optional[bool] = None,
                    mesh=None) -> List[CycleModel]:
    """Fleet-scale cycle recognition: one batched (Pallas MXU-DFT) power
    spectrum, one batched peak pick, one batched autocorrelation refinement
    for all jobs. This is the surveillance-tick hot path (Fig. 10) — the
    seed's per-job Python dispatch dominated beyond ~100 jobs.

    ``mesh`` row-shards the kernel-path stages across devices; every stage
    is per-row, so sharded output is bit-identical to unsharded.
    """
    X = np.asarray(classes_batch, np.float32)
    J, n = X.shape
    if J == 0:
        return []
    max_p = min(max_period or n // 2, n // 2)
    if n < 2 * min_period:
        return [CycleModel(0, 0.0, np.asarray(
            [1 if X[j].mean() >= 0.5 else 0], np.int8)) for j in range(J)]
    P = _spectra(X, use_kernel, mesh=mesh)
    k_star, conf, found = _peak_pick(P, n, min_period, max_p,
                                     total_power=_total_power(X))
    p0 = np.round(n / np.maximum(k_star, 1)).astype(np.int64)
    periods = np.where(found, p0, 1)
    if found.any():
        refined = _refine_period_batch(X[found].astype(np.float64),
                                       p0[found], min_period, max_p,
                                       mesh=mesh)
        periods = periods.copy()
        periods[found] = refined
    out: List[CycleModel] = []
    for j in range(J):
        if not found[j]:
            out.append(CycleModel(0, 0.0, np.asarray(
                [1 if X[j].mean() >= 0.5 else 0], np.int8)))
            continue
        period = int(periods[j])
        cls = np.asarray(classes_batch[j], np.int8)
        array_lm, array_nlm, profile = decompose(cls, period)
        if folded:
            profile = fold_profile(cls, period)
            idx = np.arange(period)
            array_lm, array_nlm = idx[profile == 1], idx[profile != 1]
        out.append(CycleModel(period, float(conf[j]), profile, array_lm,
                              array_nlm))
    return out


def fit_cycle(classes: np.ndarray, *, min_period: int = 2,
              max_period: Optional[int] = None, folded: bool = False,
              use_kernel: Optional[bool] = None) -> CycleModel:
    """Characterized series -> CycleModel (the paper pipeline in one call).

    A J=1 view of ``fit_cycle_batch`` — scalar/batch parity is structural,
    not coincidental.
    """
    return fit_cycle_batch(np.asarray(classes)[None], min_period=min_period,
                           max_period=max_period, folded=folded,
                           use_kernel=use_kernel)[0]
