"""Kernel backend detection — the dispatch axis for ``kernels/ops.py``.

Every accelerated op in this repo has up to three lowerings:

  * ``tpu`` — the Pallas MXU/VPU kernels (``dft.py``, ``autocorr.py``, ...),
    compiled on TPU, interpret-executed elsewhere for validation;
  * ``gpu`` — the Pallas Triton lowerings (``gpu.py``): plain-Pallas kernel
    bodies with no TPU-specific memory spaces or scratch, compiled via the
    Triton backend on GPU, interpret-executed elsewhere;
  * ``xla`` — pure-jnp fallbacks (``ref.py``) that run on any backend.

``kernel_backend()`` names the lowering the dispatch table should pick for
the running process; ``force_backend`` overrides it (tests use this to
exercise the gpu/xla rows of the table on a CPU host). ``resolve_interpret``
implements the auto-detection contract for the ``interpret=None`` kernel
default: a kernel compiles only when the *physical* platform matches its
target — the override never makes Pallas try to compile a Triton kernel on
a CPU host, it only routes dispatch.
"""
from __future__ import annotations

import contextlib
from typing import Iterator, Optional

import jax

#: physical jax platforms each kernel target compiles on
_PLATFORMS = {"tpu": ("tpu",), "gpu": ("gpu", "cuda", "rocm")}

_OVERRIDE: Optional[str] = None


def kernel_backend() -> str:
    """The dispatch-table row for this process: 'tpu', 'gpu' or 'xla'."""
    if _OVERRIDE is not None:
        return _OVERRIDE
    b = jax.default_backend()
    if b in _PLATFORMS["tpu"]:
        return "tpu"
    if b in _PLATFORMS["gpu"]:
        return "gpu"
    return "xla"


def on_tpu() -> bool:
    return kernel_backend() == "tpu"


def on_gpu() -> bool:
    return kernel_backend() == "gpu"


def has_accelerator() -> bool:
    """True when a compiled kernel lowering (TPU or GPU) is the hot path.
    The pure-XLA row of the dispatch table serves every other backend."""
    return kernel_backend() in ("tpu", "gpu")


@contextlib.contextmanager
def force_backend(name: Optional[str]) -> Iterator[None]:
    """Force ``kernel_backend()`` for the dynamic extent (tests: exercise a
    foreign dispatch row; kernels then run in interpret mode — see
    ``resolve_interpret``). ``None`` restores auto-detection."""
    global _OVERRIDE
    if name is not None and name not in ("tpu", "gpu", "xla"):
        raise ValueError(f"unknown kernel backend {name!r}")
    prev, _OVERRIDE = _OVERRIDE, name
    try:
        yield
    finally:
        _OVERRIDE = prev


def shard_map_compat(fn, mesh, in_specs, out_specs):
    """jax >= 0.6 promotes shard_map to ``jax.shard_map`` (check_vma kwarg);
    older releases ship it under jax.experimental with the check_rep
    spelling. One shim for every row-sharded kernel/decide-plane wrapper."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def resolve_interpret(target: str, interpret: Optional[bool]) -> bool:
    """Auto-detect the ``interpret`` flag for a kernel aimed at ``target``:
    compiled when the running (physical) platform is the target, interpret
    mode everywhere else. An explicit True/False always wins."""
    if interpret is not None:
        return interpret
    return jax.default_backend() not in _PLATFORMS[target]
