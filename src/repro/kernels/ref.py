"""Pure-jnp oracles for every Pallas kernel (per-kernel allclose tests sweep
shapes/dtypes against these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gla import gla_chunked  # noqa: F401  (oracle for ssm_scan)


def max_abs_delta_ref(new: jnp.ndarray, old: jnp.ndarray) -> jnp.ndarray:
    """(n_blocks, block) x2 -> (n_blocks, 1) f32."""
    d = jnp.abs(new.astype(jnp.float32) - old.astype(jnp.float32))
    return jnp.max(d, axis=1, keepdims=True)


def dft_power_ref(x: jnp.ndarray) -> jnp.ndarray:
    """(B, N) f32 -> (B, N) full power spectrum via complex FFT."""
    f = jnp.fft.fft(x.astype(jnp.float32), axis=-1)
    return (f.real ** 2 + f.imag ** 2).astype(jnp.float32)


def autocorr_score_ref_xla(x: jnp.ndarray, lags: jnp.ndarray) -> jnp.ndarray:
    """(J, N) rows x (L,) lags -> (J, L) f32 unnormalized autocorrelation —
    pure-jnp mirror of ``autocorr.autocorr_score`` (zero-tail masking via
    the padded rows, lags clamped to [0, N]); the XLA row of the ops
    dispatch table and the portable oracle for both Pallas lowerings."""
    x = x.astype(jnp.float32)
    J, N = x.shape
    xp = jnp.pad(x, ((0, 0), (0, N)))

    def one(lag):
        p = jnp.clip(lag, 0, N)
        sh = jax.lax.dynamic_slice_in_dim(xp, p, N, axis=1)
        return jnp.sum(x * sh, axis=1)

    return jax.vmap(one, out_axes=1)(lags.astype(jnp.int32))


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  window: int = 0) -> jnp.ndarray:
    """Naive causal GQA attention. q: (B,H,S,D); k,v: (B,Hkv,S,D)."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    kx = jnp.repeat(k, G, axis=1)
    vx = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * D ** -0.5
    pos = np.arange(S)
    mask = pos[:, None] >= pos[None, :]
    if window > 0:
        mask &= (pos[:, None] - pos[None, :]) < window
    s = jnp.where(jnp.asarray(mask)[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32)
                      ).astype(q.dtype)


def ssm_scan_ref(q, k, v, log_decay, *, bonus=None, ssd: bool = True):
    """Step-by-step exact recurrence — the strongest oracle for ssm_scan
    (independent of the chunked decomposition)."""
    from repro.models.gla import clamp_log_decay
    B, H, S, Dk = q.shape
    Dv = v.shape[-1]
    f32 = jnp.float32
    w = jnp.exp(clamp_log_decay(log_decay.astype(f32)))
    qf, kf, vf = q.astype(f32), k.astype(f32), v.astype(f32)

    def step(state, xs):
        qt, kt, vt, wt = xs                      # (B,H,Dk/Dv)
        kv = kt[..., :, None] * vt[..., None, :]
        if ssd:
            state = wt[..., None] * state + kv
            y = jnp.einsum("bhd,bhdv->bhv", qt, state)
        else:
            y = jnp.einsum("bhd,bhdv->bhv", qt, state)
            y = y + jnp.einsum("bhd,hd,bhd->bh", qt,
                               bonus.astype(f32), kt)[..., None] * vt
            state = wt[..., None] * state + kv
        return state, y

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (qf, kf, vf, w))
    state0 = jnp.zeros((B, H, Dk, Dv), f32)
    state, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 2).astype(v.dtype), state
