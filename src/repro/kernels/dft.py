"""MXU matmul-DFT power spectrum — the cycle-recognition hot spot.

The paper's FFT runs per VM over short classification series; a fleet of
1,000+ jobs classifies thousands of series at once. On TPU a radix-2
butterfly wastes the MXU, so we *adapt* (DESIGN.md §5): the DFT of a batch
of length-N real series is two N x N matmuls against precomputed cos/sin
weight matrices with a fused square-add epilogue:

    P[b, f] = (x_b . cos_f)^2 + (x_b . sin_f)^2

O(N^2) per series instead of O(N log N), but N <= 2048 here and the MXU
turns the batch into dense 128-aligned tiles — for series batches this beats
a scalar butterfly on TPU by a wide margin (the classic FFT-vs-matmul
crossover argument). Grid: (batch_tiles, freq_tiles, time_tiles), time
innermost with two f32 accumulators in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

B_TILE = 8
F_TILE = 128
T_TILE = 128
MAX_N = 2048


@functools.lru_cache(maxsize=8)
def dft_weights(n: int):
    # cache NUMPY arrays: caching jnp arrays created inside a jit trace
    # would leak tracers into later traces
    t = np.arange(n)[:, None] * np.arange(n)[None, :]
    ang = 2.0 * np.pi * t / n
    return (np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32))


def _kernel(x_ref, cos_ref, sin_ref, out_ref, acc_re, acc_im):
    ti = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        acc_re[...] = jnp.zeros_like(acc_re)
        acc_im[...] = jnp.zeros_like(acc_im)

    x = x_ref[...]
    acc_re[...] += jax.lax.dot(x, cos_ref[...],
                               preferred_element_type=jnp.float32)
    acc_im[...] += jax.lax.dot(x, sin_ref[...],
                               preferred_element_type=jnp.float32)

    @pl.when(ti == nt - 1)
    def _emit():
        out_ref[...] = acc_re[...] ** 2 + acc_im[...] ** 2


@functools.partial(jax.jit, static_argnames=("interpret",))
def dft_power(x: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """x: (B, N) f32, N % 128 == 0 -> (B, N) power spectrum (all N bins)."""
    B, N = x.shape
    cos_np, sin_np = dft_weights(N)
    cos_w, sin_w = jnp.asarray(cos_np), jnp.asarray(sin_np)
    bt = min(B_TILE, B)
    B_p = -(-B // bt) * bt
    if B_p != B:
        x = jnp.pad(x, ((0, B_p - B), (0, 0)))
    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((B_p, N), jnp.float32),
        grid=(B_p // bt, N // F_TILE, N // T_TILE),
        in_specs=[
            pl.BlockSpec((bt, T_TILE), lambda bi, fi, ti: (bi, ti)),
            pl.BlockSpec((T_TILE, F_TILE), lambda bi, fi, ti: (ti, fi)),
            pl.BlockSpec((T_TILE, F_TILE), lambda bi, fi, ti: (ti, fi)),
        ],
        out_specs=pl.BlockSpec((bt, F_TILE), lambda bi, fi, ti: (bi, fi)),
        scratch_shapes=[pltpu.VMEM((bt, F_TILE), jnp.float32),
                        pltpu.VMEM((bt, F_TILE), jnp.float32)],
        interpret=interpret,
    )(x, cos_w, sin_w)
    return out[:B]
