"""MXU matmul-DFT power spectrum — the cycle-recognition hot spot.

The paper's FFT runs per VM over short classification series; a fleet of
1,000+ jobs classifies thousands of series at once. On TPU a radix-2
butterfly wastes the MXU, so we *adapt* (DESIGN.md §5): the DFT of a batch
of length-N real series is two N x N matmuls against precomputed cos/sin
weight matrices with a fused square-add epilogue:

    P[b, f] = (x_b . cos_f)^2 + (x_b . sin_f)^2

O(N^2) per series instead of O(N log N), but N <= 2048 here and the MXU
turns the batch into dense 128-aligned tiles — for series batches this beats
a scalar butterfly on TPU by a wide margin (the classic FFT-vs-matmul
crossover argument). Grid: (batch_tiles, freq_tiles, time_tiles), time
innermost with two f32 accumulators in VMEM scratch.

Mean removal is fused (``center=True``): a third running accumulator holds
the per-row sum, and the epilogue applies the exact rank-1 correction

    (x - m 1) . W_f = x . W_f - m (1 . W_f)

against the precomputed column sums of the weight matrices, so the host
never materializes the ``X - X.mean()`` copy the surveillance tick used to
pay per fleet scan.

Weight memory: instead of pinning two N x N f32 matrices per cached N
(268 MB worst case at the old ``lru_cache(maxsize=8)``), the cache holds one
length-N cosine table plus an int16 phase-index matrix per N (capacity 2);
``sin`` is the same table read a quarter period earlier. Matrices are
materialized only transiently at trace time (they live on as jit-cache
constants, not host arrays).
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend as kb

B_TILE = 8
F_TILE = 128
T_TILE = 128
MAX_N = 2048

_TABLE_CACHE_MAX = 2
_TABLE_CACHE: "OrderedDict[int, Tuple[np.ndarray, np.ndarray]]" = OrderedDict()


def _dft_tables(n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Cached (cos table (n,) f32, phase-index matrix (n, n) int16).

    ``idx[t, f] = (t * f) % n`` indexes the shared cosine table; int16 is
    exact because the kernel caps n at ``MAX_N`` = 2048 < 2**15. Footprint
    per entry is 2 n^2 + 4 n bytes — a quarter of one f32 weight matrix.
    """
    if n in _TABLE_CACHE:
        _TABLE_CACHE.move_to_end(n)
        return _TABLE_CACHE[n]
    k = np.arange(n, dtype=np.int64)
    table = np.cos(2.0 * np.pi * k / n).astype(np.float32)
    idx = (np.outer(k, k) % n).astype(np.int16)
    _TABLE_CACHE[n] = (table, idx)
    while len(_TABLE_CACHE) > _TABLE_CACHE_MAX:
        _TABLE_CACHE.popitem(last=False)
    return table, idx


def dft_cache_nbytes() -> int:
    """Resident bytes pinned by the DFT weight cache (regression-tested)."""
    return sum(t.nbytes + i.nbytes for t, i in _TABLE_CACHE.values())


def dft_weights(n: int) -> Tuple[np.ndarray, np.ndarray]:
    """(cos, sin) n x n f32 DFT weight matrices.

    Materialized on demand from the cached tables: sin(2 pi t f / n) is the
    cosine table read a quarter period back (n % 4 == 0 on every kernel-
    supported n; other n fall back to direct evaluation, uncached).
    """
    if n > MAX_N or n % 4:
        t = np.arange(n)[:, None] * np.arange(n)[None, :]
        ang = 2.0 * np.pi * t / n
        return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)
    table, idx = _dft_tables(n)
    cos = table[idx]
    sin = table[(idx.astype(np.int32) - n // 4) % n]
    return cos, sin


def _kernel(x_ref, cos_ref, sin_ref, csum_ref, ssum_ref, out_ref,
            acc_re, acc_im, acc_sum, *, n: int, center: bool):
    ti = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        acc_re[...] = jnp.zeros_like(acc_re)
        acc_im[...] = jnp.zeros_like(acc_im)
        acc_sum[...] = jnp.zeros_like(acc_sum)

    x = x_ref[...]
    acc_re[...] += jax.lax.dot(x, cos_ref[...],
                               preferred_element_type=jnp.float32)
    acc_im[...] += jax.lax.dot(x, sin_ref[...],
                               preferred_element_type=jnp.float32)
    if center:
        acc_sum[...] += jnp.sum(x, axis=1, keepdims=True)

    @pl.when(ti == nt - 1)
    def _emit():
        re, im = acc_re[...], acc_im[...]
        if center:
            mean = acc_sum[...] * (1.0 / n)            # (bt, 1)
            re = re - mean * csum_ref[...]
            im = im - mean * ssum_ref[...]
        out_ref[...] = re ** 2 + im ** 2


@functools.partial(jax.jit, static_argnames=("center", "interpret"))
def _dft_power(x: jnp.ndarray, *, center: bool,
               interpret: bool) -> jnp.ndarray:
    B, N = x.shape
    cos_np, sin_np = dft_weights(N)
    cos_w, sin_w = jnp.asarray(cos_np), jnp.asarray(sin_np)
    # column sums of the weights for the mean-removal rank-1 correction
    csum = jnp.asarray(cos_np.sum(axis=0, dtype=np.float64)
                       .astype(np.float32)[None, :])
    ssum = jnp.asarray(sin_np.sum(axis=0, dtype=np.float64)
                       .astype(np.float32)[None, :])
    bt = min(B_TILE, B)
    B_p = -(-B // bt) * bt
    if B_p != B:
        x = jnp.pad(x, ((0, B_p - B), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, n=N, center=center),
        out_shape=jax.ShapeDtypeStruct((B_p, N), jnp.float32),
        grid=(B_p // bt, N // F_TILE, N // T_TILE),
        in_specs=[
            pl.BlockSpec((bt, T_TILE), lambda bi, fi, ti: (bi, ti)),
            pl.BlockSpec((T_TILE, F_TILE), lambda bi, fi, ti: (ti, fi)),
            pl.BlockSpec((T_TILE, F_TILE), lambda bi, fi, ti: (ti, fi)),
            pl.BlockSpec((1, F_TILE), lambda bi, fi, ti: (0, fi)),
            pl.BlockSpec((1, F_TILE), lambda bi, fi, ti: (0, fi)),
        ],
        out_specs=pl.BlockSpec((bt, F_TILE), lambda bi, fi, ti: (bi, fi)),
        scratch_shapes=[pltpu.VMEM((bt, F_TILE), jnp.float32),
                        pltpu.VMEM((bt, F_TILE), jnp.float32),
                        pltpu.VMEM((bt, 1), jnp.float32)],
        interpret=interpret,
    )(x, cos_w, sin_w, csum, ssum)
    return out[:B]


def dft_power(x: jnp.ndarray, *, center: bool = False,
              interpret=None) -> jnp.ndarray:
    """x: (B, N) f32, N % 128 == 0 -> (B, N) power spectrum (all N bins).

    ``center=True`` removes each row's mean inside the kernel (fused
    prologue/epilogue) — equivalent to ``dft_power(x - x.mean(-1, kd))``.
    ``interpret=None`` auto-detects: compiled on TPU, interpret mode
    (lowering validation) everywhere else — callers no longer pay
    interpret-mode dispatch by default on the platform the kernel targets.
    """
    return _dft_power(x, center=center,
                      interpret=kb.resolve_interpret("tpu", interpret))
