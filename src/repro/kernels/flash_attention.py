"""Fused causal GQA flash attention (forward) with optional sliding window.

The substrate's compute hot spot for 32k-token prefill. Online-softmax over
KV tiles with running (m, l, acc) in VMEM scratch; GQA folds the query-head
-> kv-head mapping into the K/V BlockSpec index maps so kv tiles are
fetched once per query-head group member without a gather. Fully-masked
future KV tiles are skipped with ``pl.when`` (the triangular saving).

Grid: (B, H, q_tiles, kv_tiles), kv innermost. Tiles are 128-aligned for the
MXU; the (BQ, BK) logits tile plus q/k/v tiles stay well under VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *, scale, window,
            bq, bk):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    # causal tile skip: this kv tile starts after the last query row
    @pl.when(ki * bk <= qi * bq + bq - 1)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (BK, D)
        s = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = rows >= cols
        if window > 0:
            mask &= rows - cols < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_s[...], jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_s[...] - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_s[...] = acc_s[...] * corr + jax.lax.dot(
            p.astype(v_ref.dtype), v_ref[0, 0],
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[0, 0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "bq", "bk", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    window: int = 0, bq: int = DEFAULT_BQ,
                    bk: int = DEFAULT_BK, interpret: bool = True
                    ) -> jnp.ndarray:
    """q: (B, H, S, D); k, v: (B, Hkv, S, D). Causal; window > 0 adds SWA.

    Returns (B, H, S, D) in q's dtype. S must divide by the tile sizes
    (prefill shapes are powers of two; ops.py falls back otherwise).
    """
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    scale = D ** -0.5
    kern = functools.partial(_kernel, scale=scale, window=window, bq=bq, bk=bk)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        grid=(B, H, S // bq, S // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
