"""Chunked gated-linear-attention scan kernel (Mamba2 SSD / RWKV6 WKV).

Identical math to ``repro.models.gla.gla_chunked`` (the jnp oracle), with the
chunk loop as the innermost sequential grid dimension and the (Dk, Dv) state
carried in VMEM scratch across chunks — the canonical TPU pattern for linear
recurrences. Intra-chunk work is all matmuls: the cumulative log-decay is a
lower-triangular-ones matmul, the masked (Q, Q) score tile and both readout
products hit the MXU.

Grid: (B, H, n_chunks). Static ``ssd`` flag selects SSD semantics
(mask j<=t) vs RWKV (strict past + diagonal bonus ``u``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.models.gla import LOG_DECAY_CLAMP

DEFAULT_CHUNK = 32


def _kernel(q_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, state_ref, st_s, *,
            ssd: bool, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)
    Q = chunk

    @pl.when(ci == 0)
    def _init():
        st_s[...] = jnp.zeros_like(st_s)

    q = q_ref[0, 0].astype(jnp.float32)                 # (Q, Dk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)                 # (Q, Dv)
    lw = jnp.clip(lw_ref[0, 0].astype(jnp.float32), -LOG_DECAY_CLAMP, 0.0)

    rows = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tril = (rows >= cols).astype(jnp.float32)
    L = jax.lax.dot(tril, lw, preferred_element_type=jnp.float32)  # incl. cumsum
    Lq = L if ssd else L - lw
    shift = L[Q // 2: Q // 2 + 1, :]                    # (1, Dk)

    q_in = q * jnp.exp(Lq - shift)
    k_in = k * jnp.exp(shift - L)
    s = jax.lax.dot(q_in, k_in.T, preferred_element_type=jnp.float32)
    mask = (rows >= cols) if ssd else (rows > cols)
    s = jnp.where(mask, s, 0.0)
    if not ssd:
        u = u_ref[0].astype(jnp.float32)                # (Dk,)
        diag = jnp.sum(q * u[None, :] * k, axis=1)      # (Q,)
        s = s + jnp.where(rows == cols, diag[:, None], 0.0)

    y = jax.lax.dot(s, v, preferred_element_type=jnp.float32)
    y += jax.lax.dot(q * jnp.exp(Lq), st_s[...],
                     preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    L_tot = L[Q - 1: Q, :]                              # (1, Dk)
    k_out = k * jnp.exp(L_tot - L)                      # (Q, Dk)
    st_s[...] = (jnp.exp(L_tot).T * st_s[...]
                 + jax.lax.dot(k_out.T, v, preferred_element_type=jnp.float32))

    @pl.when(ci == nc - 1)
    def _emit_state():
        state_ref[0, 0] = st_s[...]


@functools.partial(jax.jit,
                   static_argnames=("ssd", "chunk", "interpret"))
def ssm_scan(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
             log_decay: jnp.ndarray, *, bonus=None, ssd: bool = True,
             chunk: int = DEFAULT_CHUNK, interpret: bool = True):
    """q/k/log_decay: (B, H, S, Dk); v: (B, H, S, Dv).

    Returns (y (B,H,S,Dv) in v.dtype, final_state (B,H,Dk,Dv) f32).
    ``ssd=False`` selects RWKV semantics and requires ``bonus`` (H, Dk).
    """
    B, H, S, Dk = q.shape
    Dv = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    if bonus is None:
        bonus = jnp.zeros((H, Dk), jnp.float32)
    kern = functools.partial(_kernel, ssd=ssd, chunk=chunk)
    y, state = pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((B, H, S, Dv), v.dtype),
                   jax.ShapeDtypeStruct((B, H, Dk, Dv), jnp.float32)),
        grid=(B, H, S // chunk),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, Dk), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, Dk), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, Dv), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, Dk), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, Dk), lambda b, h, ci: (h, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, chunk, Dv), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, Dk, Dv), lambda b, h, ci: (b, h, 0, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((Dk, Dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, log_decay, bonus)
    return y, state
