"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels are *targeted* at TPU and validated in interpret mode — see the
system-level note in DESIGN.md). Wrappers fall back to the jnp reference
when a shape doesn't meet the kernel's tiling contract, so callers never
have to care.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import autocorr as _ac
from repro.kernels import dirty_delta as _dd
from repro.kernels import dft as _dft
from repro.kernels import flash_attention as _fa
from repro.kernels import ssm_scan as _ssm
from repro.kernels import ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


# ---------------------------------------------------------------------------
# dirty blocks (pre-copy)
# ---------------------------------------------------------------------------
def dirty_blocks(new: jnp.ndarray, old: jnp.ndarray,
                 threshold: float = 0.0) -> jnp.ndarray:
    """(n_blocks, block) x2 -> (n_blocks,) bool dirty mask.

    Float dtypes go through the Pallas max-|delta| kernel; integer dtypes use
    an exact != reduction (f32 casting could alias distinct int32 values).
    """
    if not jnp.issubdtype(new.dtype, jnp.floating):
        return jnp.any(new != old, axis=1)
    d = _dd.max_abs_delta(new, old, interpret=_interpret())
    return d[:, 0] > threshold


# ---------------------------------------------------------------------------
# DFT power spectrum (cycle recognition)
# ---------------------------------------------------------------------------
def dft_supported(n: int) -> bool:
    return n % _dft.T_TILE == 0 and 0 < n <= _dft.MAX_N


def power_spectrum(x: jnp.ndarray, *, center: bool = False) -> jnp.ndarray:
    """x: (B, N) -> (B, N//2+1) one-sided power spectrum.

    ``center=True`` fuses per-row mean removal into the kernel prologue
    (no host-side ``x - x.mean()`` copy).
    """
    B, N = x.shape
    if dft_supported(N):
        p = _dft.dft_power(x.astype(jnp.float32), center=center,
                           interpret=_interpret())
    else:
        if center:
            x = x - jnp.mean(x, axis=-1, keepdims=True)
        p = ref.dft_power_ref(x)
    return p[:, : N // 2 + 1]


# ---------------------------------------------------------------------------
# autocorrelation scoring (period refinement)
# ---------------------------------------------------------------------------
def autocorr_score(x: jnp.ndarray, lags: jnp.ndarray) -> jnp.ndarray:
    """(J, N) rows x (L,) shared candidate lags -> (J, L) scores.

    Pallas kernel on TPU (and for interpret-mode validation); the numpy
    oracle is the off-TPU fallback — interpret-mode dispatch is far slower
    than the f64 einsum on CPU and is excluded from the surveillance hot
    path (see cycles._refine_period_batch).
    """
    if on_tpu() and x.shape[1] <= _ac.MAX_N:
        return _ac.autocorr_score(x, lags, interpret=False)
    return jnp.asarray(_ac.autocorr_score_ref(x, lags))


# ---------------------------------------------------------------------------
# flash attention (prefill hot path)
# ---------------------------------------------------------------------------
def flash_attention(q, k, v, *, window: int = 0) -> jnp.ndarray:
    S = q.shape[2]
    if S % _fa.DEFAULT_BQ == 0:
        return _fa.flash_attention(q, k, v, window=window,
                                   interpret=_interpret())
    return ref.attention_ref(q, k, v, window=window)


# ---------------------------------------------------------------------------
# ssm scan (Mamba2/RWKV6)
# ---------------------------------------------------------------------------
def ssm_scan(q, k, v, log_decay, *, bonus=None, ssd: bool = True):
    S = q.shape[2]
    if S % _ssm.DEFAULT_CHUNK == 0:
        return _ssm.ssm_scan(q, k, v, log_decay, bonus=bonus, ssd=ssd,
                             interpret=_interpret())
    return ref.gla_chunked(q, k, v, log_decay,
                           bonus=bonus if not ssd else None)
