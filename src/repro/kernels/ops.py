"""Public wrappers for the accelerated ops — one backend-dispatch table.

Each cycle-recognition op has up to three lowerings, selected per process by
``backend.kernel_backend()`` (overridable with ``backend.force_backend`` so
tests can exercise a foreign row on any host):

  ==================  =======================  ======================  =====================
  op                  tpu                      gpu                     xla (fallback)
  ==================  =======================  ======================  =====================
  power_spectrum      dft.dft_power            gpu.dft_power           ref.dft_power_ref
                      (Pallas MXU matmul-DFT,  (Pallas Triton,         (jnp complex FFT)
                      fused mean removal)      dot per weight tile)
  autocorr_score      autocorr.autocorr_score  gpu.autocorr_score      ref.autocorr_score_
                      (VMEM rows, SMEM lags)   (plain-Pallas body)     ref_xla (vmap slices)
  ==================  =======================  ======================  =====================

Pallas rows auto-detect ``interpret``: compiled on their physical target
platform, interpret mode elsewhere (validation). Shapes outside a kernel's
tiling contract always fall back to the xla row, so callers never care.

Both table ops accept an optional ``mesh``: rows are then partitioned across
the mesh devices with ``shard_map`` (every lowering is embarrassingly
parallel per row, so sharded results are bit-identical to unsharded) — the
kernel half of the sharded surveillance plane (``core/shard.py``).

The training-side kernels (flash attention, ssm scan, dirty blocks) keep
their TPU-or-reference dispatch: they are not on the decide-plane hot path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import autocorr as _ac
from repro.kernels import backend as kb
from repro.kernels import dft as _dft
from repro.kernels import dirty_delta as _dd
from repro.kernels import flash_attention as _fa
from repro.kernels import gpu as _gpu
from repro.kernels import ref
from repro.kernels import ssm_scan as _ssm
from repro.kernels.backend import (  # noqa: F401  (re-exported API)
    force_backend, has_accelerator, kernel_backend, on_gpu, on_tpu)


def _interpret() -> bool:
    """Interpret flag for the TPU-only training kernels."""
    return kb.resolve_interpret("tpu", None)


def _row_sharded(fn, mesh, x: jnp.ndarray) -> jnp.ndarray:
    """Run ``fn`` with the rows of ``x`` partitioned across ``mesh`` via
    shard_map (1-D mesh, axis name taken from the mesh). Rows are padded to
    a multiple of the device count and sliced back; since every lowering is
    per-row, the result is bit-identical to ``fn(x)``."""
    from jax.sharding import PartitionSpec as P
    n = int(mesh.devices.size)
    axis = mesh.axis_names[0]
    B = x.shape[0]
    B_p = -(-B // n) * n
    if B_p != B:
        x = jnp.pad(x, ((0, B_p - B),) + ((0, 0),) * (x.ndim - 1))
    out = kb.shard_map_compat(fn, mesh, in_specs=(P(axis),),
                              out_specs=P(axis))(x)
    return out[:B]


# ---------------------------------------------------------------------------
# dirty blocks (pre-copy)
# ---------------------------------------------------------------------------
def dirty_blocks(new: jnp.ndarray, old: jnp.ndarray,
                 threshold: float = 0.0) -> jnp.ndarray:
    """(n_blocks, block) x2 -> (n_blocks,) bool dirty mask.

    Float dtypes go through the Pallas max-|delta| kernel; integer dtypes use
    an exact != reduction (f32 casting could alias distinct int32 values).
    """
    if not jnp.issubdtype(new.dtype, jnp.floating):
        return jnp.any(new != old, axis=1)
    d = _dd.max_abs_delta(new, old, interpret=_interpret())
    return d[:, 0] > threshold


# ---------------------------------------------------------------------------
# DFT power spectrum (cycle recognition)
# ---------------------------------------------------------------------------
def _power_tpu(x: jnp.ndarray, *, center: bool) -> jnp.ndarray:
    return _dft.dft_power(x.astype(jnp.float32), center=center)


def _power_gpu(x: jnp.ndarray, *, center: bool) -> jnp.ndarray:
    return _gpu.dft_power(x.astype(jnp.float32), center=center)


def _power_xla(x: jnp.ndarray, *, center: bool) -> jnp.ndarray:
    if center:
        x = x - jnp.mean(x, axis=-1, keepdims=True)
    return ref.dft_power_ref(x)


POWER_SPECTRUM = {"tpu": _power_tpu, "gpu": _power_gpu, "xla": _power_xla}


def dft_supported(n: int) -> bool:
    return n % _dft.T_TILE == 0 and 0 < n <= _dft.MAX_N


def power_spectrum(x: jnp.ndarray, *, center: bool = False,
                   mesh=None) -> jnp.ndarray:
    """x: (B, N) -> (B, N//2+1) one-sided power spectrum.

    ``center=True`` removes each row's mean (fused into the kernel prologue
    on the Pallas rows). ``mesh`` partitions the batch rows across devices.
    """
    B, N = x.shape
    row = kernel_backend() if dft_supported(N) else "xla"
    fn = functools.partial(POWER_SPECTRUM[row], center=center)
    p = _row_sharded(fn, mesh, x) if mesh is not None else fn(x)
    return p[:, : N // 2 + 1]


# ---------------------------------------------------------------------------
# autocorrelation scoring (period refinement)
# ---------------------------------------------------------------------------
def _autocorr_tpu(x, lags):
    return _ac.autocorr_score(x, lags)


def _autocorr_gpu(x, lags):
    return _gpu.autocorr_score(x, lags)


def _autocorr_xla(x, lags):
    return ref.autocorr_score_ref_xla(x, lags)


AUTOCORR_SCORE = {"tpu": _autocorr_tpu, "gpu": _autocorr_gpu,
                  "xla": _autocorr_xla}


def autocorr_score(x: jnp.ndarray, lags: jnp.ndarray, *,
                   mesh=None) -> jnp.ndarray:
    """(J, N) rows x (L,) shared candidate lags -> (J, L) scores.

    Pallas kernels on their target accelerators, jnp fallback elsewhere.
    Note the decide plane's CPU hot path does not come through here at all
    — off-accelerator ``cycles._refine_period_batch`` uses a Wiener-
    Khinchin pocketfft pass, which beats any per-lag scoring on host.
    ``mesh`` partitions the job rows across devices.
    """
    row = kernel_backend() if x.shape[1] <= _ac.MAX_N else "xla"
    fn = AUTOCORR_SCORE[row]
    if mesh is not None:
        return _row_sharded(lambda v: fn(v, lags), mesh, x)
    return fn(x, lags)


def kernel_table() -> dict:
    """Introspection: op -> {backend row -> implementing callable}. The
    README's dispatch table and the per-backend parity tests iterate this
    so a silently added/renamed row cannot escape coverage."""
    return {"power_spectrum": dict(POWER_SPECTRUM),
            "autocorr_score": dict(AUTOCORR_SCORE)}


# ---------------------------------------------------------------------------
# flash attention (prefill hot path)
# ---------------------------------------------------------------------------
def flash_attention(q, k, v, *, window: int = 0) -> jnp.ndarray:
    S = q.shape[2]
    if S % _fa.DEFAULT_BQ == 0:
        return _fa.flash_attention(q, k, v, window=window,
                                   interpret=_interpret())
    return ref.attention_ref(q, k, v, window=window)


# ---------------------------------------------------------------------------
# ssm scan (Mamba2/RWKV6)
# ---------------------------------------------------------------------------
def ssm_scan(q, k, v, log_decay, *, bonus=None, ssd: bool = True):
    S = q.shape[2]
    if S % _ssm.DEFAULT_CHUNK == 0:
        return _ssm.ssm_scan(q, k, v, log_decay, bonus=bonus, ssd=ssd,
                             interpret=_interpret())
    return ref.gla_chunked(q, k, v, log_decay,
                           bonus=bonus if not ssd else None)
