"""Dirty-block scan kernel — the pre-copy inner loop (DESIGN.md §5).

Given the live view and the shadow (last-copied) view of a state shard as
(n_blocks, block) tiles, emit the per-block max |delta| so the migration
engine can mark dirty "pages". Purely memory-bound (2 streaming reads, tiny
write): the Pallas value is the explicit HBM->VMEM pipeline; block tiles are
sized so two input tiles + accumulator fit comfortably in VMEM.

Grid: (row_tiles, col_tiles); col dim innermost so the row accumulator lives
in VMEM scratch across the column sweep and the (n_blocks, 1) result is
written once per row tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROW_TILE = 8          # blocks per program
COL_TILE = 2048       # elements of the block dim per program (lane-aligned)


def _kernel(new_ref, old_ref, out_ref, acc):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    d = jnp.abs(new_ref[...].astype(jnp.float32)
                - old_ref[...].astype(jnp.float32))
    acc[...] = jnp.maximum(acc[...], jnp.max(d, axis=1, keepdims=True))

    @pl.when(ci == nc - 1)
    def _emit():
        out_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def max_abs_delta(new: jnp.ndarray, old: jnp.ndarray, *,
                  interpret: bool = True) -> jnp.ndarray:
    """(n_blocks, block) x2 -> (n_blocks, 1) f32 max |new - old| per block."""
    nb, blk = new.shape
    rt = min(ROW_TILE, nb)
    ct = min(COL_TILE, blk)
    # pad to tile multiples (padding contributes |0-0| = 0)
    nb_p = -(-nb // rt) * rt
    blk_p = -(-blk // ct) * ct
    if (nb_p, blk_p) != (nb, blk):
        new = jnp.pad(new, ((0, nb_p - nb), (0, blk_p - blk)))
        old = jnp.pad(old, ((0, nb_p - nb), (0, blk_p - blk)))
    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((nb_p, 1), jnp.float32),
        grid=(nb_p // rt, blk_p // ct),
        in_specs=[pl.BlockSpec((rt, ct), lambda ri, ci: (ri, ci)),
                  pl.BlockSpec((rt, ct), lambda ri, ci: (ri, ci))],
        out_specs=pl.BlockSpec((rt, 1), lambda ri, ci: (ri, 0)),
        scratch_shapes=[pltpu.VMEM((rt, 1), jnp.float32)],
        interpret=interpret,
    )(new, old)
    return out[:nb]
