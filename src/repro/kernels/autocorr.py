"""Batched autocorrelation scoring — the period-refinement hot spot.

FFT bin periods are quantized to N/k; `cycles` de-quantizes them with a
local lag search maximizing the (mean-removed) autocorrelation. At fleet
scale the seed ran that search as a scalar Python loop per job — the single
largest CPU cost of a surveillance tick beyond ~100 jobs. Here the whole
fleet scores one shared grid of candidate lags in a single Pallas call:

    R[j, l] = sum_t x[j, t] * x[j, t + lag_l]        (t + lag_l < N)

Grid: (job_tiles, lag_tiles). Each kernel instance keeps its block's full
rows resident in VMEM (bt x N f32, <= 64 KB at N=2048), reads a tile of
candidate lags from SMEM, and walks them with a fori_loop of dynamic-slice
multiplies on the zero-extended rows (the zero tail implements the
``t + lag < N`` mask for free). The products are VPU work — no MXU — but one
kernel launch replaces J Python-dispatched dot-product loops, and rows are
streamed once per lag *tile* instead of once per lag.

Callers (``cycles._refine_period_batch``) pick each job's argmax over its
own valid lag window; invalid/padding lags are masked host-side.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend as kb

B_TILE = 8
L_TILE = 8
MAX_N = 2048


def _kernel(x_ref, lags_ref, out_ref):
    x = x_ref[...]                                         # (bt, N)
    xp = jnp.concatenate([x, jnp.zeros_like(x)], axis=1)   # zero tail = mask

    def body(l, acc):
        p = jnp.clip(lags_ref[l], 0, x.shape[1])
        sh = jax.lax.dynamic_slice(xp, (0, p), x.shape)    # x[:, p:], padded
        return acc.at[:, l].set(jnp.sum(x * sh, axis=1))

    out_ref[...] = jax.lax.fori_loop(
        0, lags_ref.shape[0], body,
        jnp.zeros(out_ref.shape, jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _autocorr_score(x: jnp.ndarray, lags: jnp.ndarray, *,
                    interpret: bool) -> jnp.ndarray:
    J, N = x.shape
    L = lags.shape[0]
    bt = min(B_TILE, J)
    J_p = -(-J // bt) * bt
    L_p = -(-L // L_TILE) * L_TILE
    if J_p != J:
        x = jnp.pad(x, ((0, J_p - J), (0, 0)))
    if L_p != L:
        lags = jnp.pad(lags, (0, L_p - L))
    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((J_p, L_p), jnp.float32),
        grid=(J_p // bt, L_p // L_TILE),
        in_specs=[
            pl.BlockSpec((bt, N), lambda ji, li: (ji, 0)),
            pl.BlockSpec((L_TILE,), lambda ji, li: (li,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bt, L_TILE), lambda ji, li: (ji, li)),
        interpret=interpret,
    )(x.astype(jnp.float32), lags.astype(jnp.int32))
    return out[:J, :L]


def autocorr_score(x: jnp.ndarray, lags: jnp.ndarray, *,
                   interpret=None) -> jnp.ndarray:
    """x: (J, N) f32 mean-removed rows; lags: (L,) int32 shared candidates.

    Returns (J, L) f32 unnormalized autocorrelation scores. Lags outside
    [0, N) are clamped (callers mask their scores out). ``interpret=None``
    auto-detects: compiled on TPU, interpret mode (lowering validation)
    everywhere else.
    """
    return _autocorr_score(x, lags,
                           interpret=kb.resolve_interpret("tpu", interpret))


def autocorr_score_ref(x: np.ndarray, lags: np.ndarray) -> np.ndarray:
    """Numpy oracle: same contract as ``autocorr_score`` (f64 accumulate)."""
    x = np.asarray(x, np.float64)
    J, N = x.shape
    out = np.zeros((J, len(lags)), np.float64)
    for li, p in enumerate(np.clip(lags, 0, N)):
        if p < N:
            out[:, li] = np.einsum("jt,jt->j", x[:, : N - p], x[:, p:])
    return out.astype(np.float32)
