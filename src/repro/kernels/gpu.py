"""Pallas Triton lowerings of the cycle-recognition kernels (GPU row of the
``kernels/ops.py`` dispatch table).

The TPU kernels in ``dft.py`` / ``autocorr.py`` lean on TPU-specific Pallas
features (VMEM scratch accumulators across an inner grid axis, SMEM scalar
blocks) that the Triton backend does not provide. These lowerings keep the
same math and the same tiling *contract* (callers pad/slice identically) but
restructure for a GPU:

  * ``dft_power``: grid (batch_tiles, freq_tiles); each program keeps its
    block's full rows resident ((bt, N) f32, N <= 2048 -> 64 KB) and runs the
    whole time reduction as one dot per weight tile — no cross-program
    accumulator, so no scratch. Mean removal uses the same rank-1
    column-sum correction as the TPU epilogue.
  * ``autocorr_score``: identical body to the TPU kernel minus the SMEM
    placement of the candidate-lag tile (Triton reads it from regular
    memory).

Both share the TPU module's weight/table caches and numerics, and both run
under interpret mode on non-GPU hosts — parity against ``kernels/ref.py``
is tested per backend in ``tests/test_kernels.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import backend as kb
from repro.kernels.dft import dft_weights

B_TILE = 8
F_TILE = 128
L_TILE = 8
MAX_N = 2048


# ---------------------------------------------------------------------------
# matmul-DFT power spectrum
# ---------------------------------------------------------------------------
def _dft_kernel(x_ref, cos_ref, sin_ref, csum_ref, ssum_ref, out_ref,
                *, n: int, center: bool):
    x = x_ref[...]                                          # (bt, N)
    re = jnp.dot(x, cos_ref[...], preferred_element_type=jnp.float32)
    im = jnp.dot(x, sin_ref[...], preferred_element_type=jnp.float32)
    if center:
        mean = jnp.sum(x, axis=1, keepdims=True) * (1.0 / n)
        re = re - mean * csum_ref[...]
        im = im - mean * ssum_ref[...]
    out_ref[...] = re ** 2 + im ** 2


@functools.partial(jax.jit, static_argnames=("center", "interpret"))
def _dft_power(x: jnp.ndarray, *, center: bool, interpret: bool
               ) -> jnp.ndarray:
    B, N = x.shape
    cos_np, sin_np = dft_weights(N)
    csum = jnp.asarray(cos_np.sum(axis=0, dtype=np.float64)
                       .astype(np.float32)[None, :])
    ssum = jnp.asarray(sin_np.sum(axis=0, dtype=np.float64)
                       .astype(np.float32)[None, :])
    bt = min(B_TILE, B)
    B_p = -(-B // bt) * bt
    if B_p != B:
        x = jnp.pad(x, ((0, B_p - B), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_dft_kernel, n=N, center=center),
        out_shape=jax.ShapeDtypeStruct((B_p, N), jnp.float32),
        grid=(B_p // bt, N // F_TILE),
        in_specs=[
            pl.BlockSpec((bt, N), lambda bi, fi: (bi, 0)),
            pl.BlockSpec((N, F_TILE), lambda bi, fi: (0, fi)),
            pl.BlockSpec((N, F_TILE), lambda bi, fi: (0, fi)),
            pl.BlockSpec((1, F_TILE), lambda bi, fi: (0, fi)),
            pl.BlockSpec((1, F_TILE), lambda bi, fi: (0, fi)),
        ],
        out_specs=pl.BlockSpec((bt, F_TILE), lambda bi, fi: (bi, fi)),
        interpret=interpret,
    )(x, jnp.asarray(cos_np), jnp.asarray(sin_np), csum, ssum)
    return out[:B]


def dft_power(x: jnp.ndarray, *, center: bool = False,
              interpret=None) -> jnp.ndarray:
    """x: (B, N) f32, N % 128 == 0, N <= 2048 -> (B, N) power spectrum.

    Same contract as ``dft.dft_power``; ``interpret=None`` auto-detects
    (compiled on GPU, interpret elsewhere).
    """
    return _dft_power(x, center=center,
                      interpret=kb.resolve_interpret("gpu", interpret))


# ---------------------------------------------------------------------------
# autocorrelation scoring
# ---------------------------------------------------------------------------
def _ac_kernel(x_ref, lags_ref, out_ref):
    x = x_ref[...]                                          # (bt, N)
    xp = jnp.concatenate([x, jnp.zeros_like(x)], axis=1)    # zero tail = mask

    def body(l, acc):
        p = jnp.clip(lags_ref[l], 0, x.shape[1])
        sh = jax.lax.dynamic_slice(xp, (0, p), x.shape)
        return acc.at[:, l].set(jnp.sum(x * sh, axis=1))

    out_ref[...] = jax.lax.fori_loop(
        0, lags_ref.shape[0], body,
        jnp.zeros(out_ref.shape, jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _autocorr_score(x: jnp.ndarray, lags: jnp.ndarray, *,
                    interpret: bool) -> jnp.ndarray:
    J, N = x.shape
    L = lags.shape[0]
    bt = min(B_TILE, J)
    J_p = -(-J // bt) * bt
    L_p = -(-L // L_TILE) * L_TILE
    if J_p != J:
        x = jnp.pad(x, ((0, J_p - J), (0, 0)))
    if L_p != L:
        lags = jnp.pad(lags, (0, L_p - L))
    out = pl.pallas_call(
        _ac_kernel,
        out_shape=jax.ShapeDtypeStruct((J_p, L_p), jnp.float32),
        grid=(J_p // bt, L_p // L_TILE),
        in_specs=[
            pl.BlockSpec((bt, N), lambda ji, li: (ji, 0)),
            pl.BlockSpec((L_TILE,), lambda ji, li: (li,)),
        ],
        out_specs=pl.BlockSpec((bt, L_TILE), lambda ji, li: (ji, li)),
        interpret=interpret,
    )(x.astype(jnp.float32), lags.astype(jnp.int32))
    return out[:J, :L]


def autocorr_score(x: jnp.ndarray, lags: jnp.ndarray, *,
                   interpret=None) -> jnp.ndarray:
    """x: (J, N) f32 rows x (L,) int32 shared lags -> (J, L) f32 scores.

    Same contract as ``autocorr.autocorr_score``; ``interpret=None``
    auto-detects (compiled on GPU, interpret elsewhere).
    """
    return _autocorr_score(x, lags,
                           interpret=kb.resolve_interpret("gpu", interpret))
