"""Fault-tolerant training driver.

Production-loop shape: deterministic step-indexed data, async + incremental
checkpointing, NaN/heartbeat failure detection with restore-and-replay,
ALMA telemetry per step (the load indexes of DESIGN.md §2), and the LMCM
consulted before every disruptive state operation (checkpoint flush,
migration, elastic rescale) so they land in LM windows.

On real fleets the failure signal comes from the cluster manager; here
failures are injectable (tests / examples) via ``failure_hook``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.telemetry import TelemetryBuffer
from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.data import SyntheticCorpus
from repro.train import init_train_state, make_train_step


@dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    telemetry: bool = True
    max_nan_restarts: int = 3
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainerConfig, *,
                 batch: int = 8, seq: int = 128,
                 failure_hook: Optional[Callable[[int], bool]] = None):
        self.cfg, self.tcfg = cfg, tcfg
        self.batch, self.seq = batch, seq
        self.corpus = SyntheticCorpus(cfg, batch, seq, seed=tcfg.seed)
        self.telemetry = TelemetryBuffer()
        self.ckpt = AsyncCheckpointer(tcfg.ckpt_dir)
        self.failure_hook = failure_hook
        self.step_fn = jax.jit(make_train_step(cfg, telemetry=tcfg.telemetry))
        self.state = None
        self.history: List[Dict[str, float]] = []
        self.restarts = 0

    # -- lifecycle -----------------------------------------------------------
    def init_or_restore(self) -> int:
        last = latest_step(self.tcfg.ckpt_dir)
        like = jax.eval_shape(
            lambda: init_train_state(self.cfg, jax.random.key(self.tcfg.seed)))
        if last is not None:
            self.state = restore_checkpoint(self.tcfg.ckpt_dir, last, like)
            return int(self.state["step"])
        self.state = init_train_state(self.cfg,
                                      jax.random.key(self.tcfg.seed))
        return 0

    def _record(self, step: int, metrics, dt: float) -> None:
        m = {k: float(v) for k, v in metrics.items()
             if jnp.ndim(v) == 0}
        m["step_time"] = dt
        self.telemetry.record(
            step, step_time=dt,
            dirty_bytes=m.get("dirty_bytes", 0.0),
            dirty_fraction=m.get("dirty_fraction", 0.0),
            compute_util=min(1.0, 0.05 / max(dt, 1e-6)),
        )
        self.history.append(m)

    # -- the loop --------------------------------------------------------------
    def run(self, num_steps: int) -> Dict[str, Any]:
        step = self.init_or_restore()
        target = step + num_steps
        while step < target:
            if self.failure_hook is not None and self.failure_hook(step):
                # simulated node failure: drop state, restore from checkpoint
                self.ckpt.wait()
                self.state = None
                step = self.init_or_restore()
                self.restarts += 1
                continue
            batch = {k: jnp.asarray(v)
                     for k, v in self.corpus.batch_at(step).items()}
            t0 = time.monotonic()
            self.state, metrics = self.step_fn(self.state, batch)
            metrics = jax.block_until_ready(metrics)
            dt = time.monotonic() - t0
            if not np.isfinite(float(metrics["loss"])):
                if self.restarts >= self.tcfg.max_nan_restarts:
                    raise FloatingPointError(f"NaN loss at step {step}")
                self.state = None
                step = self.init_or_restore()
                self.restarts += 1
                continue
            step += 1
            self._record(step, metrics, dt)
            if step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(step, self.state)
        self.ckpt.wait()
        return {"final_step": step, "restarts": self.restarts,
                "loss": self.history[-1]["loss"] if self.history else None,
                "history": self.history}
