"""Elastic rescaling: live-migrate a training job onto a different mesh.

The sequence (examples/elastic_rescale.py exercises it end-to-end):

  1. keep training on the source mesh while the pre-copy engine snapshots
     state rounds into the destination placement (dirty-block transfers);
  2. at the stop-and-copy point, pause (that's the downtime), final delta;
  3. re-jit the train step for the destination mesh and resume at the same
     step index — the data pipeline is step-indexed so not a token is lost.

ALMA's role: the LMCM picks the stop-and-copy moment (an LM window) so the
final delta — the only blocking transfer — is minimal.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax

from repro.configs.base import ArchConfig
from repro.core import precopy
from repro.launch import sharding as shardlib


@dataclass
class RescaleReport:
    precopy: precopy.PrecopyReport
    src_devices: int
    dst_devices: int


def rescale(cfg: ArchConfig, state, step_once: Callable[[Any], Any],
            dst_mesh, *, pcfg: Optional[precopy.PrecopyConfig] = None
            ) -> Tuple[Any, RescaleReport]:
    """Move ``state`` onto ``dst_mesh`` with pre-copy semantics.

    ``step_once(state) -> state`` advances training on the source placement
    (keeps the job live during iterative copy rounds).
    """
    pcfg = pcfg or precopy.PrecopyConfig()
    dst_sh = shardlib.state_shardings(dst_mesh, jax.eval_shape(lambda: state))

    box = {"state": state}

    def get_state():
        return box["state"]

    def do_step():
        box["state"] = step_once(box["state"])

    def placement(tree):
        return jax.tree.map(
            lambda l, s: jax.device_put(l, s), tree, dst_sh)

    migrated, report = precopy.migrate(get_state, do_step, pcfg,
                                       placement=placement)
    src_n = len(set(jax.tree.leaves(state)[0].devices())) \
        if hasattr(jax.tree.leaves(state)[0], "devices") else 1
    return migrated, RescaleReport(report, src_n, dst_mesh.devices.size)
