"""Deterministic scenario fleets for the fault-injection suite.

One seeded builder (``build_fleet``) produces the substrate every
scenario shares: a ``Topology.multi_rack`` fabric (per-rack ToR links
through a shared core), a ``Placement`` with per-host headroom so
evacuations have somewhere to go, and a de-phased VM population on the
``SCENARIO_PHASES`` cycle — replicas of one application shifted by
``k * cycle / n_vms`` so the fleet is never phase-synchronized (the
paper's contended-fleet setup, Table 3 style).

The helpers below it are the suite's shared vocabulary: a warmup long
enough for the surveillance FFT to lock the cycle (``default_warmup``),
a greedy projected-load evacuation planner (``evacuation_plan``), and
the recovery/SLA report every scenario emits (``scenario_report``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import network
from repro.core.consolidation import Host, Placement
from repro.core.fleetsim import FleetSim, PAPER_BANDWIDTH, SimJob, \
    WorkloadTrace
from repro.core.orchestrator import MigrationRequest

# the suite's common workload cycle: a long cyclic-LM window (CPU), a
# pre-copy-hostile stretch (MEM), and an IO tail — 240 s per cycle, so
# ALMA has a real window to aim for and a real window to avoid
SCENARIO_PHASES = [("CPU", 120.0), ("MEM", 60.0), ("IO", 60.0)]
SCENARIO_CYCLE_S = float(sum(d for _, d in SCENARIO_PHASES))


@dataclass
class ScenarioFleet:
    """A built scenario substrate: jobs + fabric + placement, plus the
    derived indices the scenarios key off (rack membership, VM homes)."""
    jobs: List[SimJob]
    topology: network.Topology
    placement: Placement
    hosts: List[str]
    rack_of: Dict[str, str]
    cycle_s: float = SCENARIO_CYCLE_S
    bandwidth: float = PAPER_BANDWIDTH
    seed: int = 0
    v_bytes: Dict[str, float] = field(default_factory=dict)

    def jobs_on(self, host: str) -> List[str]:
        return sorted(self.placement.hosts[host].jobs)

    def host_of(self, job_id: str) -> Optional[str]:
        return self.placement.host_of(job_id)

    def rack_peers(self, host: str) -> List[str]:
        """Live-in-the-same-rack candidates, the preferred evacuation
        targets (intra-rack moves never cross the core)."""
        r = self.rack_of[host]
        return [h for h in self.hosts if self.rack_of[h] == r and h != host]

    def sim(self, policy: str, **kw) -> FleetSim:
        """A FleetSim over this fleet; scenario kwargs (fault_plan,
        warmup_s, retry knobs, ...) pass straight through."""
        kw.setdefault("bandwidth", self.bandwidth)
        kw.setdefault("seed", self.seed)
        return FleetSim(self.jobs, policy=policy, topology=self.topology,
                        placement=self.placement, **kw)


def build_fleet(*, n_racks: int = 2, hosts_per_rack: int = 3,
                vms_per_host: int = 2, seed: int = 0,
                bandwidth: float = PAPER_BANDWIDTH,
                core_oversubscription: float = 1.0,
                headroom: float = 2.0) -> ScenarioFleet:
    """The suite's seeded substrate.

    ``n_racks`` ToR links (auto-named hosts ``r{i}h{j}``) through a core
    sized at ``n_racks * bandwidth / core_oversubscription``; every host
    gets ``vms_per_host`` unit-load VMs and ``headroom`` spare capacity
    (evacuating one host must be *feasible*, or drain scenarios measure
    nothing). VM k runs the common cycle shifted by ``k * cycle / n_vms``
    and carries ``v_bytes ~ U(0.75, 2.0) GB`` — the paper's VM scale, so
    migrations take tens of seconds and faults genuinely land mid-flight.
    Deterministic in ``seed``.
    """
    topology = network.Topology.multi_rack(
        n_racks, bandwidth,
        core_capacity=n_racks * bandwidth / max(core_oversubscription, 1e-9),
        hosts_per_rack=hosts_per_rack)
    hosts = [f"r{i}h{j}" for i in range(n_racks)
             for j in range(hosts_per_rack)]
    rack_of = {h: h.split("h")[0] for h in hosts}
    rng = np.random.default_rng(seed)
    n_vms = len(hosts) * vms_per_host
    placement = Placement({h: Host(h, float(vms_per_host) + headroom)
                           for h in hosts})
    jobs: List[SimJob] = []
    v_bytes: Dict[str, float] = {}
    for k in range(n_vms):
        host = hosts[k % len(hosts)]
        job_id = f"vm{k:03d}"
        trace = WorkloadTrace(SCENARIO_PHASES, total_s=7200,
                              offset=k * SCENARIO_CYCLE_S / n_vms)
        vb = float(rng.uniform(0.75e9, 2.0e9))
        jobs.append(SimJob(job_id, trace, vb))
        placement.assign(job_id, host, 1.0)
        v_bytes[job_id] = vb
    return ScenarioFleet(jobs=jobs, topology=topology, placement=placement,
                         hosts=hosts, rack_of=rack_of,
                         bandwidth=bandwidth, seed=seed, v_bytes=v_bytes)


def default_warmup(policy: str, cycle_s: float = SCENARIO_CYCLE_S) -> float:
    """Warmup before the scenario clock starts: the surveillance window
    needs >= 4 observed cycles to resolve the period, plus one cycle of
    slack. The immediate baseline reads no fits, so it skips warmup —
    and keeps boot_storm's cold-ring premise literal."""
    return 0.0 if policy == "immediate" else 5.0 * cycle_s


def evacuation_plan(fleet: ScenarioFleet, host: str, t: float, *,
                    deadline: Optional[float] = None,
                    exclude: Sequence[str] = ()) -> List[MigrationRequest]:
    """Drain ``host``: one request per resident VM, targets chosen
    greedily by *projected* free capacity (actual free minus what this
    plan has already routed there), preferring rack-local destinations
    so the drain stays off the core. ``exclude`` removes hosts that are
    (or are about to be) unavailable."""
    banned = {host, *exclude}
    projected = {h: fleet.placement.hosts[h].free
                 for h in fleet.hosts if h not in banned}
    if not projected:
        return []
    local = set(fleet.rack_peers(host))
    plan: List[MigrationRequest] = []
    for job_id in fleet.jobs_on(host):
        load = fleet.placement.hosts[host].jobs[job_id]
        fits = [h for h, free in projected.items() if free >= load]
        pool = fits or list(projected)
        # rack-local first, then most projected headroom, then name
        dst = min(pool, key=lambda h: (h not in local, -projected[h], h))
        projected[dst] -= load
        plan.append(MigrationRequest(
            job_id=job_id, created_at=t, v_bytes=fleet.v_bytes[job_id],
            src=host, dst=dst, deadline=deadline))
    return plan


# -- reporting ---------------------------------------------------------------
def percentiles(values: Sequence[float]) -> Dict[str, float]:
    """p50/p95/max of a recovery-time sample (NaNs when empty — a
    scenario with nothing recovered reports that, not zeros)."""
    if not len(values):
        return {"p50": float("nan"), "p95": float("nan"),
                "max": float("nan")}
    a = np.asarray(values, dtype=np.float64)
    return {"p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "max": float(a.max())}


def sla_violations(plan: Sequence[MigrationRequest],
                   completed_at: Dict[str, float]) -> int:
    """SLA accounting over a scenario's requests: permanently failed,
    cancelled (unroutable), or completed past their own deadline."""
    bad = 0
    for req in plan:
        if req.decision in ("failed", "cancelled"):
            bad += 1
        elif req.deadline is not None:
            done = completed_at.get(req.job_id)
            if done is None or done > req.deadline:
                bad += 1
    return bad


def scenario_report(result, plan: Sequence[MigrationRequest],
                    t0: float) -> Dict:
    """The per-scenario summary every suite entry emits: makespan,
    per-VM recovery time (scenario start -> completion) percentiles,
    bytes (useful + wasted-by-abort), and SLA violations."""
    recovery = [done - t0 for done in result.completed_at.values()]
    return {
        "makespan_s": float(result.makespan),
        "recovery_s": percentiles(recovery),
        "completed": len(result.completed_at),
        "requested": len(plan),
        "total_bytes": float(result.total_bytes),
        "aborted_bytes": float(result.aborted_bytes),
        "n_aborts": int(result.n_aborts),
        "n_retries": int(result.n_retries),
        "failed_jobs": sorted(set(result.failed_jobs)),
        "sla_violations": sla_violations(plan, result.completed_at),
        "lm_hit_rate": float(result.lm_hit_rate),
    }
