"""Fault-injecting datacenter scenarios for the fleet simulator.

``faults`` — seeded deterministic :class:`FaultPlan` schedules (host
crash/recover, link degrade/restore) that ``FleetSim`` drives as
first-class event boundaries; ``fleet`` — the shared seeded scenario
substrate and report helpers; ``suite`` — the four kubevirt-style
scenarios (host_drain, node_failure, boot_storm, rolling_upgrade) and
their CLI.
"""
from repro.scenarios.faults import FaultEvent, FaultPlan
from repro.scenarios.fleet import ScenarioFleet, build_fleet, \
    default_warmup, evacuation_plan, percentiles, scenario_report
from repro.scenarios.suite import SCENARIOS, boot_storm, host_drain, \
    node_failure, rolling_upgrade

__all__ = [
    "FaultEvent", "FaultPlan", "ScenarioFleet", "build_fleet",
    "default_warmup", "evacuation_plan", "percentiles", "scenario_report",
    "SCENARIOS", "boot_storm", "host_drain", "node_failure",
    "rolling_upgrade",
]
