"""Four kubevirt-style datacenter scenarios on the fault-injecting fleet.

Each scenario builds the shared seeded substrate (``fleet.build_fleet``),
drives ``FleetSim`` — with a ``FaultPlan`` where the scenario calls for
real failures — and emits one ``scenario_report`` dict: makespan, per-VM
recovery-time percentiles (p50/p95/max), bytes moved and bytes wasted by
aborts, and SLA violations. All four are deterministic in ``seed``.

``host_drain``
    Planned maintenance: evacuate one host under a deadline. No faults —
    this measures the orchestrator's ability to honor an SLA while still
    timing launches against the workload cycle.
``node_failure``
    The host dies 20 s into an urgent drain, mid-flight: lanes abort
    with partial bytes billed, retries re-route around the corpse, and
    the scenario reports RTO — the worst time-to-recovered over the
    victim's VMs, measured from the crash.
``boot_storm``
    J VMs re-register with cold telemetry rings (warmup 0) and request
    migrations in a staggered burst — the cold-start stress on the
    surveillance path: no fits exist, max-wait alone forces progress.
``rolling_upgrade``
    A wave of drains under the concurrency budget: hosts are drained one
    at a time on one live simulator (sequential ``run_with_plan`` calls,
    placement carrying over), the kubevirt node-upgrade loop.

CLI:  python -m repro.scenarios.suite --scenario node_failure \
          --policy alma-paper --seed 0
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, Optional

from repro.scenarios.faults import FaultPlan
from repro.scenarios.fleet import ScenarioFleet, build_fleet, \
    default_warmup, evacuation_plan, percentiles, scenario_report


def _fleet(seed: int, fleet_kw: Optional[Dict]) -> ScenarioFleet:
    return build_fleet(seed=seed, **(fleet_kw or {}))


def host_drain(*, policy: str = "alma-paper", seed: int = 0,
               deadline_s: float = 480.0, victim: Optional[str] = None,
               horizon_s: float = 4000.0,
               fleet_kw: Optional[Dict] = None) -> Dict:
    """Planned evacuation of one host under a deadline (maintenance
    drain). The deadline rides on every request, so the LMCM may
    postpone into a cyclic-LM window only as far as the SLA allows."""
    fleet = _fleet(seed, fleet_kw)
    victim = victim or fleet.hosts[0]
    sim = fleet.sim(policy, warmup_s=default_warmup(policy))
    t0 = sim.now
    plan = evacuation_plan(fleet, victim, t0, deadline=t0 + deadline_s)
    res = sim.run_with_plan(plan, horizon_s=horizon_s)
    rep = scenario_report(res, plan, t0)
    rep.update({
        "scenario": "host_drain", "policy": policy, "seed": seed,
        "victim": victim, "deadline_s": deadline_s,
        "drained": not fleet.placement.hosts[victim].jobs,
        "deadline_met": (rep["sla_violations"] == 0
                         and rep["completed"] == rep["requested"]),
    })
    return rep


def node_failure(*, policy: str = "alma-paper", seed: int = 0,
                 t_fail_s: float = 20.0, mttr_s: float = 600.0,
                 victim: Optional[str] = None, horizon_s: float = 4000.0,
                 fleet_kw: Optional[Dict] = None) -> Dict:
    """Unplanned host death mid-drain. An urgent evacuation starts at
    t0 (hardware alert: no postponement), the host crashes ``t_fail_s``
    later with lanes in flight — partial bytes are settled and wasted,
    aborted requests back off and re-route (dead source => cold restart
    from a live image host), and any VM still resident is restarted
    urgently. RTO is the worst victim-VM recovery measured from the
    crash; infinite if any victim VM never recovers."""
    fleet = _fleet(seed, fleet_kw)
    victim = victim or fleet.hosts[0]
    victims = set(fleet.jobs_on(victim))
    warm = default_warmup(policy)
    t_fail = warm + t_fail_s
    sim = fleet.sim(policy, warmup_s=warm,
                    fault_plan=FaultPlan.host_failure(
                        t_fail, victim, recover_at=t_fail + mttr_s))
    t0 = sim.now
    plan = evacuation_plan(fleet, victim, t0)
    for req in plan:
        req.urgent = True              # failure-imminent drain: fire now
    res = sim.run_with_plan(plan, horizon_s=horizon_s)
    rep = scenario_report(res, plan, t0)
    victim_rec = [res.completed_at[j] - t_fail for j in victims
                  if j in res.completed_at and res.completed_at[j] > t_fail]
    lost = victims - set(res.completed_at)
    rep.update({
        "scenario": "node_failure", "policy": policy, "seed": seed,
        "victim": victim, "t_fail": t_fail, "mttr_s": mttr_s,
        "victim_vms": len(victims),
        "victim_recovery_s": percentiles(victim_rec),
        "rto_s": (float("inf") if lost
                  else max(victim_rec, default=0.0)),
    })
    return rep


def boot_storm(*, policy: str = "alma-paper", seed: int = 0,
               stagger_s: float = 2.0, max_wait: float = 300.0,
               horizon_s: float = 4000.0,
               fleet_kw: Optional[Dict] = None) -> Dict:
    """Every VM re-registers with a COLD telemetry ring (warmup 0 for
    all policies — that premise is the scenario) and requests a
    migration in a staggered burst: a one-host round-robin shift, so
    each host sheds and receives the same load. With no cycle fits the
    surveillance policies must make progress on max-wait alone."""
    fleet = _fleet(seed, fleet_kw)
    sim = fleet.sim(policy, warmup_s=0.0, max_wait=max_wait)
    t0 = sim.now
    plan = []
    from repro.core.orchestrator import MigrationRequest
    for k, job in enumerate(fleet.jobs):
        src = fleet.host_of(job.job_id)
        dst = fleet.hosts[(fleet.hosts.index(src) + 1) % len(fleet.hosts)]
        plan.append(MigrationRequest(
            job_id=job.job_id, created_at=t0 + k * stagger_s,
            v_bytes=job.v_bytes, src=src, dst=dst))
    res = sim.run_with_plan(plan, horizon_s=horizon_s)
    rep = scenario_report(res, plan, t0)
    rep.update({
        "scenario": "boot_storm", "policy": policy, "seed": seed,
        "n_jobs": len(plan), "stagger_s": stagger_s,
        "max_wait": max_wait,
    })
    return rep


def rolling_upgrade(*, policy: str = "alma-paper", seed: int = 0,
                    rack: str = "r0", max_concurrent: int = 2,
                    wave_horizon_s: float = 4000.0,
                    fleet_kw: Optional[Dict] = None) -> Dict:
    """Drain one rack's hosts in sequence under the concurrency budget
    (the kubevirt node-upgrade loop). One live simulator carries the
    placement across waves, so each wave evacuates onto the fleet the
    previous waves produced; a wave must fully drain before the next
    host goes down for upgrade."""
    fleet = _fleet(seed, fleet_kw)
    targets = [h for h in fleet.hosts if fleet.rack_of[h] == rack]
    sim = fleet.sim(policy, warmup_s=default_warmup(policy),
                    max_concurrent=max_concurrent)
    t_start = sim.now
    waves = []
    all_plan = []
    recovery = []
    for i, host in enumerate(targets):
        t0 = sim.now
        # the NEXT host to be upgraded is about to go down — do not
        # evacuate onto it
        nxt = targets[i + 1:i + 2]
        plan = evacuation_plan(fleet, host, t0, exclude=nxt)
        res = sim.run_with_plan(plan, horizon_s=wave_horizon_s)
        all_plan.extend(plan)
        recovery.extend(done - t0 for done in res.completed_at.values())
        waves.append({
            "host": host,
            "drained": not fleet.placement.hosts[host].jobs,
            "wave_makespan_s": float(res.makespan),
            "completed": len(res.completed_at),
            "requested": len(plan),
            "total_bytes": float(res.total_bytes),
        })
    total_bytes = sum(w["total_bytes"] for w in waves)
    completed = sum(w["completed"] for w in waves)
    requested = sum(w["requested"] for w in waves)
    return {
        "scenario": "rolling_upgrade", "policy": policy, "seed": seed,
        "rack": rack, "max_concurrent": max_concurrent,
        "makespan_s": float(sim.now - t_start),
        "recovery_s": percentiles(recovery),
        "completed": completed, "requested": requested,
        "total_bytes": float(total_bytes),
        "aborted_bytes": 0.0, "n_aborts": 0, "n_retries": 0,
        "failed_jobs": [],
        "sla_violations": requested - completed,
        "all_drained": all(w["drained"] for w in waves),
        "waves": waves,
    }


SCENARIOS = {
    "host_drain": host_drain,
    "node_failure": node_failure,
    "boot_storm": boot_storm,
    "rolling_upgrade": rolling_upgrade,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", choices=sorted(SCENARIOS), required=True)
    ap.add_argument("--policy", default="alma-paper")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    rep = SCENARIOS[args.scenario](policy=args.policy, seed=args.seed)
    print(json.dumps(rep, indent=2, default=str))


if __name__ == "__main__":
    main()
