"""Seeded, deterministic fault plans for the fleet simulator.

A ``FaultPlan`` is an ordered schedule of :class:`FaultEvent` — host
crashes/recoveries and link capacity changes — that ``FleetSim`` drives as
first-class event boundaries: every event fires at the first sampling
boundary at or after its ``t``, and the event-skipping fast paths
(``run_idle`` bulk appends, ``_skip_idle_steps``) never jump over one, so
a faulted run is bit-identical between ``event_skip`` on and off.

Event kinds
-----------
``host_fail``
    The host dies at ``t``: every in-flight lane with it as an endpoint
    is aborted (partial bytes settled, see ``MigrationPlane.fail_host``),
    aborted requests re-enter the LMCM with exponential backoff, and —
    when the simulator's ``evacuate_on_fail`` is set — the VMs resident
    on the host are cold-restarted onto live hosts via urgent requests.
``host_recover``
    The host rejoins at ``t``: it becomes a valid endpoint again.
``link_degrade`` / ``link_restore``
    The link's capacity becomes ``capacity`` at ``t`` (identity, paths,
    and domains are unchanged; 0.0 stalls its flows until restored).
    The two kinds are synonyms mechanically — the split keeps plans
    readable and lets reports tell brownouts from repairs.
``link_fail``
    Correlated outage: the link's capacity drops to ``capacity``
    (typically 0.0) AND every in-flight lane whose path crosses it is
    aborted (``abort_link`` — partial bytes settled exactly as a host
    failure would). Aborted requests re-enter the LMCM with backoff; on a
    multi-route fabric the retry re-routes around the dead link, so a
    ToR/pod-uplink loss fails the lanes over to a surviving spine plane
    instead of stalling them in place the way a 0.0 ``link_degrade``
    does. ``link_restore`` brings the link back.
``telemetry_blackout`` / ``telemetry_restore``
    Sensor dropout: from ``t`` until the matching restore, the listed
    ``jobs`` record NaN telemetry samples instead of real load indexes.
    The simulator injects the NaNs identically on its scalar and bulk
    recording paths (and the rng draws are unchanged — values are
    overwritten after sampling), so blacked-out runs stay bit-identical
    between ``event_skip`` on/off. Downstream, the surveillance gather
    masks the NaNs and demotes under-covered rows to acyclic
    (``SurveillanceEngine.min_coverage``).

An empty plan is falsy; ``FleetSim`` treats it exactly like no plan at
all, which is what keeps every existing benchmark and bit-identity
contract unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Mapping, Optional, Sequence

import numpy as np

HOST_FAIL = "host_fail"
HOST_RECOVER = "host_recover"
LINK_DEGRADE = "link_degrade"
LINK_RESTORE = "link_restore"
LINK_FAIL = "link_fail"
TELEMETRY_BLACKOUT = "telemetry_blackout"
TELEMETRY_RESTORE = "telemetry_restore"
KINDS = (HOST_FAIL, HOST_RECOVER, LINK_DEGRADE, LINK_RESTORE, LINK_FAIL,
         TELEMETRY_BLACKOUT, TELEMETRY_RESTORE)


@dataclass(frozen=True)
class FaultEvent:
    t: float                 # sim-clock seconds (absolute, incl. warmup)
    kind: str                # one of KINDS
    target: str              # host id (host_*) or link id (link_*)
    capacity: float = 0.0    # link events: the new capacity, bytes/s
    # telemetry events: the affected job ids (sensor dropout is per
    # monitoring agent, not per link). Empty on other kinds.
    jobs: tuple = ()

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")


class FaultPlan:
    """Deterministic fault schedule: events sorted by time (stable, so
    same-instant events keep their authored order)."""

    def __init__(self, events: Iterable[FaultEvent] = ()):
        self.events: List[FaultEvent] = sorted(events, key=lambda e: e.t)

    def __bool__(self) -> bool:
        return bool(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def __repr__(self) -> str:
        return f"FaultPlan({self.events!r})"

    # -- builders ------------------------------------------------------------
    @classmethod
    def host_failure(cls, t: float, host: str, *,
                     recover_at: Optional[float] = None) -> "FaultPlan":
        """One host crash at ``t``, optionally rejoining at
        ``recover_at``."""
        events = [FaultEvent(t, HOST_FAIL, host)]
        if recover_at is not None:
            events.append(FaultEvent(recover_at, HOST_RECOVER, host))
        return cls(events)

    @classmethod
    def link_brownout(cls, t: float, link: str, capacity: float, *,
                      restore_at: Optional[float] = None,
                      restore_capacity: Optional[float] = None
                      ) -> "FaultPlan":
        """Degrade ``link`` to ``capacity`` at ``t``, optionally restoring
        ``restore_capacity`` at ``restore_at``."""
        events = [FaultEvent(t, LINK_DEGRADE, link, capacity=capacity)]
        if restore_at is not None:
            if restore_capacity is None:
                raise ValueError("restore_at needs restore_capacity "
                                 "(the original link speed)")
            events.append(FaultEvent(restore_at, LINK_RESTORE, link,
                                     capacity=restore_capacity))
        return cls(events)

    @classmethod
    def access_outage(cls, t: float, link: str, *,
                      restore_at: Optional[float] = None,
                      restore_capacity: Optional[float] = None
                      ) -> "FaultPlan":
        """Correlated rack/ToR (or pod-uplink) loss: ``link`` goes to
        capacity 0 at ``t`` and every lane riding it aborts
        (``link_fail`` — the retries re-route around the outage on
        multi-route fabrics), optionally restoring ``restore_capacity``
        at ``restore_at``."""
        events = [FaultEvent(t, LINK_FAIL, link, capacity=0.0)]
        if restore_at is not None:
            if restore_capacity is None:
                raise ValueError("restore_at needs restore_capacity "
                                 "(the original link speed)")
            events.append(FaultEvent(restore_at, LINK_RESTORE, link,
                                     capacity=restore_capacity))
        return cls(events)

    @classmethod
    def random(cls, hosts: Sequence[str], link_caps: Mapping[str, float],
               *, horizon_s: float, seed: int = 0,
               n_host_faults: int = 1, n_link_faults: int = 1,
               mttr_s: float = 300.0, degrade_frac: float = 0.1
               ) -> "FaultPlan":
        """Seeded random plan: ``n_host_faults`` crashes (each recovering
        after ``mttr_s``) and ``n_link_faults`` brownouts to
        ``degrade_frac`` of nominal capacity (restored after ``mttr_s``),
        uniformly placed over ``[0, horizon_s)``. Deterministic in
        ``seed``."""
        rng = np.random.default_rng(seed)
        hosts = list(hosts)
        links = list(link_caps)
        events: List[FaultEvent] = []
        for _ in range(n_host_faults):
            h = hosts[int(rng.integers(len(hosts)))]
            t = float(rng.uniform(0.0, horizon_s))
            events.append(FaultEvent(t, HOST_FAIL, h))
            events.append(FaultEvent(t + mttr_s, HOST_RECOVER, h))
        for _ in range(n_link_faults):
            l = links[int(rng.integers(len(links)))]
            t = float(rng.uniform(0.0, horizon_s))
            events.append(FaultEvent(
                t, LINK_DEGRADE, l, capacity=degrade_frac * link_caps[l]))
            events.append(FaultEvent(
                t + mttr_s, LINK_RESTORE, l, capacity=link_caps[l]))
        return cls(events)

    @classmethod
    def telemetry_blackout(cls, t: float, jobs: Sequence[str], *,
                           duration_s: float, frac: float = 1.0,
                           seed: int = 0) -> "FaultPlan":
        """Seeded sensor dropout: a ``frac`` subset of ``jobs`` (chosen
        deterministically by ``seed``) records NaN samples over
        ``[t, t + duration_s)``. ``frac=1.0`` blacks out every listed
        job (no rng draw — independent of seed)."""
        jobs = list(jobs)
        if frac >= 1.0:
            picked = tuple(jobs)
        else:
            rng = np.random.default_rng(seed)
            k = max(1, int(round(frac * len(jobs))))
            picked = tuple(sorted(
                np.asarray(jobs)[rng.permutation(len(jobs))[:k]].tolist()))
        return cls([
            FaultEvent(t, TELEMETRY_BLACKOUT, "", jobs=picked),
            FaultEvent(t + duration_s, TELEMETRY_RESTORE, "", jobs=picked),
        ])

    def shifted(self, dt: float) -> "FaultPlan":
        """The same plan with every event time shifted by ``dt`` —
        scenarios author relative times, then shift past warmup."""
        return FaultPlan(FaultEvent(e.t + dt, e.kind, e.target, e.capacity,
                                    e.jobs)
                         for e in self.events)
