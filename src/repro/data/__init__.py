from repro.data.synthetic import (  # noqa: F401
    make_batch, token_stream, SyntheticCorpus,
    correlated_tenant_load, heavy_tail_load,
)
