"""Deterministic synthetic data pipeline.

A real run would stream tokenized documents; here the corpus is a seeded
zipf-ish token process with document boundaries, which is enough to (a) drive
hundreds of real optimization steps, (b) give MoE routers non-degenerate
token statistics, and (c) be exactly resumable from a step index after
restart/migration (fault-tolerance requirement: data state is (seed, step)).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


def _zipf_tokens(rng: np.random.Generator, n: int, vocab: int) -> np.ndarray:
    """Zipf-distributed token ids (cheap inverse-CDF approximation)."""
    u = rng.random(n)
    ids = ((vocab ** u - 1.0) / (vocab - 1.0) * vocab).astype(np.int64)
    return np.clip(ids, 0, vocab - 1)


class SyntheticCorpus:
    """Step-indexed corpus: ``batch_at(step)`` is a pure function of
    (seed, step, shard), so any worker can resume anywhere."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, *,
                 seed: int = 0, shard: int = 0, num_shards: int = 1):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.seed, self.shard, self.num_shards = seed, shard, num_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        toks = _zipf_tokens(rng, self.batch * (self.seq + 1), cfg.vocab_size)
        toks = toks.reshape(self.batch, self.seq + 1)
        # document boundaries every ~1k tokens: token 0 acts as separator
        doc_mask = rng.random((self.batch, self.seq + 1)) < 1e-3
        toks = np.where(doc_mask, 0, toks)
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
        }
        if cfg.frontend_prefix:
            batch["prefix_embeds"] = rng.standard_normal(
                (self.batch, min(cfg.frontend_prefix, self.seq), cfg.d_model)
            ).astype(np.float32) * 0.02
        if cfg.mrope:
            pos = np.broadcast_to(np.arange(self.seq, dtype=np.int32),
                                  (self.batch, self.seq))
            batch["positions"] = np.broadcast_to(pos, (3, self.batch, self.seq))
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch(cfg: ArchConfig, batch: int, seq: int, *,
               seed: int = 0, step: int = 0) -> Dict[str, jnp.ndarray]:
    """One device-ready batch (tests / examples)."""
    np_batch = SyntheticCorpus(cfg, batch, seq, seed=seed).batch_at(step)
    return {k: jnp.asarray(v) for k, v in np_batch.items()}


def token_stream(cfg: ArchConfig, batch: int, seq: int, *, seed: int = 0,
                 start_step: int = 0, prefetch: int = 2,
                 num_shards: int = 1, shard: int = 0):
    """Prefetching host-side iterator (background thread pipeline)."""
    import queue
    import threading

    corpus = SyntheticCorpus(cfg, batch, seq, seed=seed, shard=shard,
                             num_shards=num_shards)
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            try:
                q.put(corpus.batch_at(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=producer, daemon=True)
    t.start()

    class Stream:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return Stream()
