"""Deterministic synthetic data pipeline.

A real run would stream tokenized documents; here the corpus is a seeded
zipf-ish token process with document boundaries, which is enough to (a) drive
hundreds of real optimization steps, (b) give MoE routers non-degenerate
token statistics, and (c) be exactly resumable from a step index after
restart/migration (fault-tolerance requirement: data state is (seed, step)).

Fleet-telemetry load generators live here too: seeded, fully vectorized
(n_jobs, steps, 6) load-index tensors ordered like
``telemetry.DEFAULT_FIELDS``, used by the Fig. 10 scalability benchmark to
stress the decide plane with workload mixes beyond the paper's Table 3
traces — ``heavy_tail_load`` (Pareto dirty-rate bursts over a square-wave
cycle) and ``correlated_tenant_load`` (jobs share their tenant's cycle plus
idiosyncratic drift, the "everyone's nightly build at 2am" pattern that
makes whole shards go stale at once).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


# field order of telemetry.DEFAULT_FIELDS (kept literal so this module does
# not depend on the core package)
LOAD_FIELDS = ("step_time", "dirty_bytes", "dirty_fraction",
               "collective_bytes", "compute_util", "hbm_util")


def _load_indexes(cu: np.ndarray, hb: np.ndarray, dr: np.ndarray
                  ) -> np.ndarray:
    """Map (compute_util, hbm_util, dirty_rate) primitives of any common
    shape to (..., 6) load-index rows ordered like ``LOAD_FIELDS`` — the
    same mapping the fleet simulator's trace sampler uses."""
    return np.stack([0.5 / np.maximum(cu, 0.02), dr,
                     np.minimum(1.0, dr / 200e6), cu * 1e9, cu, hb],
                    axis=-1)


def _square_wave(rng: np.random.Generator, n: int, steps: int,
                 cycle_range: tuple, duty: float) -> np.ndarray:
    """(n, steps) in {0,1}: per-row square wave with a seeded random period
    from ``cycle_range`` and a random phase offset."""
    lo, hi = cycle_range
    periods = rng.integers(lo, hi + 1, n)
    phases = rng.integers(0, periods)
    t = np.arange(steps, dtype=np.int64)
    frac = ((t[None, :] + phases[:, None]) % periods[:, None]) \
        / periods[:, None]
    return (frac < duty).astype(np.float64)


def heavy_tail_load(n_jobs: int, steps: int, *, seed: int = 0,
                    alpha: float = 1.6, burst_rate: float = 0.02,
                    cycle_range: tuple = (64, 256), duty: float = 0.5,
                    jitter: float = 0.05) -> np.ndarray:
    """Heavy-tailed fleet load: (n_jobs, steps, 6) load indexes.

    Each job runs a square-wave busy/idle cycle (seeded period and phase
    from ``cycle_range``); on top, dirty-rate bursts arrive at rate
    ``burst_rate`` per step with Pareto(``alpha``) magnitudes — a few bursts
    dwarf everything else (alpha < 2 means infinite variance), which is the
    regime where a mean-based classifier would misjudge suitability but the
    per-sample NB + cycle decomposition should not. Pure function of the
    arguments (``SeedSequence([seed, n_jobs, steps])``).
    """
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, n_jobs, steps]))
    busy = _square_wave(rng, n_jobs, steps, cycle_range, duty)
    cu = 0.15 + 0.75 * busy
    hb = 0.30 + 0.50 * busy
    dr = 5e6 + 395e6 * busy
    burst = rng.random((n_jobs, steps)) < burst_rate
    mag = (1.0 + rng.pareto(alpha, (n_jobs, steps))) * burst
    dr = dr * (1.0 + mag)               # the heavy tail rides the dirty rate
    cu = np.minimum(1.0, cu * (1.0 + 0.2 * mag))
    noise = 1.0 + jitter * rng.standard_normal((n_jobs, steps, 1))
    return np.maximum(0.0, _load_indexes(cu, hb, dr) * noise)


def correlated_tenant_load(n_jobs: int, steps: int, *, n_tenants: int = 8,
                           rho: float = 0.8, seed: int = 0,
                           cycle_range: tuple = (64, 256),
                           jitter: float = 0.05) -> np.ndarray:
    """Tenant-correlated fleet load: (n_jobs, steps, 6) load indexes.

    Every job belongs to one of ``n_tenants`` tenants; its busy signal is
    ``rho`` parts the tenant's shared cycle plus ``1 - rho`` parts an
    idiosyncratic cycle of its own. High ``rho`` makes whole tenant cohorts
    go stale in the same surveillance tick (the worst case for staleness-
    epoch load spreading), which is exactly what the scalability benchmark
    wants to stress. Pure function of the arguments.
    """
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, n_jobs, n_tenants]))
    tenant = rng.integers(0, n_tenants, n_jobs)
    shared = _square_wave(rng, n_tenants, steps, cycle_range, 0.5)[tenant]
    idio = _square_wave(rng, n_jobs, steps, cycle_range, 0.5)
    busy = rho * shared + (1.0 - rho) * idio
    cu = 0.15 + 0.75 * busy
    hb = 0.25 + 0.55 * busy
    dr = 5e6 + 395e6 * busy
    noise = 1.0 + jitter * rng.standard_normal((n_jobs, steps, 1))
    return np.maximum(0.0, _load_indexes(cu, hb, dr) * noise)


def _zipf_tokens(rng: np.random.Generator, n: int, vocab: int) -> np.ndarray:
    """Zipf-distributed token ids (cheap inverse-CDF approximation)."""
    u = rng.random(n)
    ids = ((vocab ** u - 1.0) / (vocab - 1.0) * vocab).astype(np.int64)
    return np.clip(ids, 0, vocab - 1)


class SyntheticCorpus:
    """Step-indexed corpus: ``batch_at(step)`` is a pure function of
    (seed, step, shard), so any worker can resume anywhere."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, *,
                 seed: int = 0, shard: int = 0, num_shards: int = 1):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.seed, self.shard, self.num_shards = seed, shard, num_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        toks = _zipf_tokens(rng, self.batch * (self.seq + 1), cfg.vocab_size)
        toks = toks.reshape(self.batch, self.seq + 1)
        # document boundaries every ~1k tokens: token 0 acts as separator
        doc_mask = rng.random((self.batch, self.seq + 1)) < 1e-3
        toks = np.where(doc_mask, 0, toks)
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
        }
        if cfg.frontend_prefix:
            batch["prefix_embeds"] = rng.standard_normal(
                (self.batch, min(cfg.frontend_prefix, self.seq), cfg.d_model)
            ).astype(np.float32) * 0.02
        if cfg.mrope:
            pos = np.broadcast_to(np.arange(self.seq, dtype=np.int32),
                                  (self.batch, self.seq))
            batch["positions"] = np.broadcast_to(pos, (3, self.batch, self.seq))
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch(cfg: ArchConfig, batch: int, seq: int, *,
               seed: int = 0, step: int = 0) -> Dict[str, jnp.ndarray]:
    """One device-ready batch (tests / examples)."""
    np_batch = SyntheticCorpus(cfg, batch, seq, seed=seed).batch_at(step)
    return {k: jnp.asarray(v) for k, v in np_batch.items()}


def token_stream(cfg: ArchConfig, batch: int, seq: int, *, seed: int = 0,
                 start_step: int = 0, prefetch: int = 2,
                 num_shards: int = 1, shard: int = 0):
    """Prefetching host-side iterator (background thread pipeline)."""
    import queue
    import threading

    corpus = SyntheticCorpus(cfg, batch, seq, seed=seed, shard=shard,
                             num_shards=num_shards)
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            try:
                q.put(corpus.batch_at(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=producer, daemon=True)
    t.start()

    class Stream:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return Stream()
