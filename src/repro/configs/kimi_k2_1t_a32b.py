"""kimi-k2-1t-a32b — trillion-parameter 384-expert top-8 MoE (paper-table).

[arXiv:2501.kimi2; unverified]  61L d_model=7168 64H (GQA kv=8) expert
d_ff=2048 vocab=163840, MoE 384e top-8 + 1 shared expert, first layer dense.
~1.03T total / ~32B active. Trained with Adafactor + bf16 state and
sequence-sharded activations so the 512-chip dry-run fits HBM (see
EXPERIMENTS.md §Dry-run).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    source="[arXiv:2501.kimi2; unverified]",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_head=112,
    d_ff=18432,                      # the single leading dense layer
    vocab_size=163840,
    rope_theta=5e4,
    block_pattern=("moe",),
    first_k_dense=1,
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                  capacity_factor=1.25, num_shared_experts=1),
    optimizer="adafactor",
    remat="full",
    accum_steps=1,        # batch shards 32-way; accum would cost an f32
                          # grad buffer (4TB/256 ~= 16 GiB/chip) for nothing
    seq_shard=True,
)
