"""musicgen-medium — decoder-only LM over EnCodec tokens.

[arXiv:2306.05284; hf]  48L d_model=1536 24H (GQA kv=24 == MHA) d_ff=6144
vocab=2048. The EnCodec/conditioning frontend is a stub: ``input_specs()``
supplies 256 precomputed conditioning-frame embeddings as a prefix.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    source="[arXiv:2306.05284; hf]",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    block_pattern=("attn",),
    frontend_prefix=256,
    rope_theta=1e4,
    remat="block",
    accum_steps=1,
)
