"""qwen3-8b — dense GQA transformer with per-head q/k RMSNorm.

[hf:Qwen/Qwen3-8B; hf]  36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936, qk_norm, head_dim=128.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b",
    family="dense",
    source="[hf:Qwen/Qwen3-8B; hf]",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_head=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    remat="block",
)
