"""qwen2-vl-2b — VLM backbone with M-RoPE (3-axis rotary over t/h/w).

[arXiv:2409.12191; hf]  28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936. The ViT frontend is a stub: ``input_specs()`` supplies 1024
precomputed patch embeddings as a prefix plus 3-axis M-RoPE position ids.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    source="[arXiv:2409.12191; hf]",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_head=128,
    d_ff=8960,
    vocab_size=151936,
    mrope=True,
    mrope_sections=(16, 24, 24),
    frontend_prefix=1024,
    rope_theta=1e6,
    remat="block",
)
