"""Architecture registry.

``get_config(name)`` returns the full-size assigned config; every module
``repro.configs.<id>`` exports ``CONFIG``. ``REGISTRY`` maps arch id -> config.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ArchConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES, shapes_for,
)

ARCH_IDS = (
    "musicgen_medium",
    "zamba2_2p7b",
    "internlm2_1p8b",
    "qwen3_8b",
    "h2o_danube3_4b",
    "starcoder2_7b",
    "qwen2_vl_2b",
    "rwkv6_1p6b",
    "qwen3_moe_30b_a3b",
    "kimi_k2_1t_a32b",
)

_ALIASES = {
    "musicgen-medium": "musicgen_medium",
    "zamba2-2.7b": "zamba2_2p7b",
    "internlm2-1.8b": "internlm2_1p8b",
    "qwen3-8b": "qwen3_8b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "starcoder2-7b": "starcoder2_7b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
}


def get_config(name: str) -> ArchConfig:
    key = _ALIASES.get(name, name).replace("-", "_")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
