"""zamba2-2.7b — Mamba2 backbone with shared attention blocks.

[arXiv:2411.15242; hf]  54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64. Wiring: five Mamba2 blocks then one
*shared-weight* attention block, repeated (the zamba2 shared-block scheme).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="[arXiv:2411.15242; hf]",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    block_pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "shared_attn"),
    ssm=SSMConfig(kind="mamba2", state_dim=64, head_dim=64, expand=2,
                  conv_width=4),
    remat="block",
)
