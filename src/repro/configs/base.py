"""Architecture configuration system.

Every assigned architecture is an ``ArchConfig`` instance. The config fully
determines the model pytree, the block wiring (dense / MoE / SSM / hybrid),
the sharding rules chosen by ``launch.sharding`` and the train/serve step
builders in ``train.steps``.

Shapes follow the assignment sheet verbatim; reduced "smoke" variants are
derived mechanically via :meth:`ArchConfig.smoke` so that every family is
exercised on CPU.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts sub-config (Switch/Mesh-TF style capacity dispatch)."""

    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    num_shared_experts: int = 0        # always-on shared expert(s) (kimi-k2 style)
    router_jitter: float = 0.0
    aux_loss_weight: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    """State-space / linear-attention sub-config (Mamba2 SSD or RWKV6)."""

    kind: str                          # 'mamba2' | 'rwkv6'
    state_dim: int = 64                # N: SSM state per head
    head_dim: int = 64                 # P: channels per head
    conv_width: int = 4                # depthwise conv (mamba2)
    expand: int = 2                    # d_inner = expand * d_model (mamba2)
    dt_rank: int = 0                   # 0 -> heads (mamba2 uses per-head dt)
    decay_lora: int = 64               # rank of data-dependent decay (rwkv6)


@dataclass(frozen=True)
class ArchConfig:
    # -- identity ------------------------------------------------------------
    name: str
    family: str                        # dense|moe|ssm|hybrid|audio|vlm
    source: str = ""                   # provenance note "[arXiv:...; tier]"

    # -- trunk ---------------------------------------------------------------
    num_layers: int = 12
    d_model: int = 512
    num_heads: int = 8
    num_kv_heads: int = 8
    d_head: int = 0                    # 0 -> d_model // num_heads
    d_ff: int = 2048
    vocab_size: int = 32000
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # -- attention options ---------------------------------------------------
    qk_norm: bool = False              # qwen3: RMSNorm on q/k heads
    gated_mlp: bool = True             # False -> GPT-style 2-matrix MLP
    attn_chunk: int = 512              # online-softmax tile (perf knob)
    rope_theta: float = 1e4
    mrope: bool = False                # qwen2-vl 3-axis M-RoPE
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    sliding_window: int = 0            # 0 -> full attention (h2o-danube SWA)

    # -- block wiring ---------------------------------------------------------
    # Repeating pattern of block kinds over depth. 'attn' = attention+MLP
    # block, 'moe' = attention+MoE block, 'mamba' = Mamba2 block,
    # 'rwkv' = RWKV6 block, 'shared_attn' = zamba2 shared-weight attn block.
    block_pattern: Tuple[str, ...] = ("attn",)
    first_k_dense: int = 0             # kimi-k2: leading dense layers before MoE

    # -- sub-configs ----------------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # -- modality stub ---------------------------------------------------------
    # audio/vlm: number of prefix positions whose embeddings are supplied by a
    # (stubbed) frontend instead of the token table. 0 disables.
    frontend_prefix: int = 0

    # -- numerics / training --------------------------------------------------
    param_dtype: str = "bfloat16"
    optimizer: str = "adamw"           # 'adamw' | 'adafactor'
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    remat: str = "block"               # 'none' | 'block' | 'full'
    accum_steps: int = 1               # gradient-accumulation microbatches
    seq_shard: bool = False            # Megatron-style sequence sharding of the
                                       # residual stream over the model axis
    z_loss: float = 1e-4

    # ------------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.num_heads

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def attention_free(self) -> bool:
        return all(k in ("mamba", "rwkv") for k in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if a 500k-token decode state does not require O(S) KV per head.

        SSM archs keep O(1) state; hybrids keep O(1) + a small shared-attn KV;
        SWA archs keep an O(window) ring. Pure full-attention archs are not
        sub-quadratic and skip the long_500k shape (see DESIGN.md §4).
        """
        if self.attention_free:
            return True
        if self.ssm is not None:       # hybrid: attention is periodic/shared
            return True
        return self.sliding_window > 0

    def pattern_for_depth(self) -> Tuple[str, ...]:
        """Full per-layer kind list of length num_layers."""
        kinds = []
        i = 0
        while len(kinds) < self.num_layers:
            kind = self.block_pattern[i % len(self.block_pattern)]
            if len(kinds) < self.first_k_dense and kind == "moe":
                kind = "attn"
            kinds.append(kind)
            i += 1
        return tuple(kinds[: self.num_layers])

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                                   # embedding
        if not self.tie_embeddings:
            total += v * d                              # lm head
        hd = self.head_dim
        for kind in self.pattern_for_depth():
            if kind in ("attn", "moe", "shared_attn"):
                attn = d * (self.num_heads * hd) * 2          # q, o
                attn += d * (self.num_kv_heads * hd) * 2      # k, v
                total += attn + 2 * d                          # + 2 norms
                if kind == "moe" and self.moe is not None:
                    m = self.moe
                    total += m.num_experts * 3 * d * m.d_ff_expert
                    total += d * m.num_experts                 # router
                    total += m.num_shared_experts * 3 * d * m.d_ff_expert
                else:
                    total += 3 * d * self.d_ff                 # swiglu
            elif kind == "mamba":
                s = self.ssm
                d_in = s.expand * d
                nheads = d_in // s.head_dim
                total += d * (2 * d_in + 2 * s.state_dim + nheads)   # in_proj
                total += s.conv_width * (d_in + 2 * s.state_dim)     # conv
                total += d_in * d + 2 * nheads + d                   # out, A, D, norm
            elif kind == "rwkv":
                total += 4 * d * d + 2 * d * s_lora(self.ssm)        # time-mix
                total += d * self.d_ff + self.d_ff * d + d           # channel-mix
                total += 2 * d                                       # norms
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts count)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dense_expert_cost = m.num_experts * 3 * self.d_model * m.d_ff_expert
        active_expert_cost = (m.top_k + m.num_shared_experts) * 3 * self.d_model * m.d_ff_expert
        n_moe = sum(1 for k in self.pattern_for_depth() if k == "moe")
        return self.param_count() - n_moe * (dense_expert_cost +
                                             m.num_shared_experts * 3 * self.d_model * m.d_ff_expert
                                             - active_expert_cost)

    # ------------------------------------------------------------------------
    def smoke(self) -> "ArchConfig":
        """Mechanically reduced config of the same family for CPU tests."""
        changes = dict(
            num_layers=min(self.num_layers, 2 * len(self.block_pattern)),
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            d_head=32,
            d_ff=256,
            vocab_size=512,
            accum_steps=1,
            remat="none",
            seq_shard=False,
            frontend_prefix=min(self.frontend_prefix, 4),
            first_k_dense=min(self.first_k_dense, 1),
        )
        if self.moe is not None:
            # generous capacity so smoke tests are drop-free (deterministic
            # prefill/decode equivalence); full configs keep the real factor
            changes["moe"] = dataclasses.replace(
                self.moe, num_experts=8, top_k=2, d_ff_expert=64,
                capacity_factor=4.0)
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, state_dim=16, head_dim=32, decay_lora=8)
        if self.mrope:
            changes["mrope_sections"] = (4, 6, 6)     # sums to smoke d_head/2
        return dataclasses.replace(self, **changes)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


def s_lora(ssm: Optional[SSMConfig]) -> int:
    return ssm.decay_lora if ssm is not None else 0


# ---------------------------------------------------------------------------
# Input shapes assigned to the LM family (seq_len, global_batch, mode).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                          # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shapes_for(cfg: ArchConfig) -> Tuple[ShapeConfig, ...]:
    """The shape cells that are well-defined for this architecture."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.sub_quadratic:
        out.append(SHAPES["long_500k"])
    return tuple(out)
