"""h2o-danube-3-4b — llama/mistral-mix dense transformer with sliding-window attention.

[arXiv:2401.16818; unverified]  24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000, SWA window 4096. The SWA ring buffer makes 500k-token decode
sub-quadratic (O(window) KV state), so this arch runs long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    source="[arXiv:2401.16818; unverified]",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_head=120,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=1e4,
    remat="block",
)
