"""rwkv6-1.6b ("Finch") — attention-free RNN with data-dependent decay.

[arXiv:2404.05892; unverified]  24L d_model=2048 d_ff=7168 vocab=65536.
Head dim 64 -> 32 heads; decay is data-dependent through a rank-64 LoRA.
O(1) decode state makes long_500k natural for this arch.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    source="[arXiv:2404.05892; unverified]",
    num_layers=24,
    d_model=2048,
    num_heads=32,           # wkv heads (d_model / head_dim)
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    block_pattern=("rwkv",),
    ssm=SSMConfig(kind="rwkv6", head_dim=64, decay_lora=64),
    remat="block",
)
