"""starcoder2-7b — dense GQA code model.

[arXiv:2402.19173; hf]  32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152, RoPE.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    source="[arXiv:2402.19173; hf]",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    gated_mlp=False,       # GPT-style 2-matrix MLP (gelu), per the paper
    rope_theta=1e5,
    remat="block",
)
