"""qwen3-moe-30b-a3b — 128-expert top-8 MoE, every layer.

[hf:Qwen/Qwen3-30B-A3B; hf]  48L d_model=2048 32H (GQA kv=4) expert d_ff=768
vocab=151936, qk_norm, head_dim=128. ~30B total / ~3B active.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    source="[hf:Qwen/Qwen3-30B-A3B; hf]",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_head=128,
    d_ff=6144,                       # unused: every layer is MoE
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    block_pattern=("moe",),
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768,
                  capacity_factor=1.25),
    remat="block",
    accum_steps=1,
)
