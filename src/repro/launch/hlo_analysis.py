"""Roofline-term analysis from compiled (optimized, partitioned) HLO text.

Why parse text at all: ``compiled.cost_analysis()`` counts a while-loop body
ONCE — a 61-layer ``lax.scan`` under-reports FLOPs by ~61x — and it has no
collective term. This analyzer walks the computation call graph, recovers
loop trip counts from each while condition's comparison constant (exact for
lax.scan loops), and accumulates three per-device terms:

  flops       — 2*M*N*K per dot (MXU work; elementwise ops are noise for LMs)
  hbm_bytes   — operands+results of top-level instructions per computation,
                fusion bodies excluded (their interiors live in VMEM/registers)
                and their traffic counted at the fusion call site
  collectives — per-kind link bytes with ring conventions:
                  all-gather ~ result; all-reduce ~ 2x result;
                  reduce-scatter ~ operands; all-to-all / permute ~ result

All quantities are per device: the partitioned module is the per-device
program.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u4": 1, "s4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _parse_shape(s: str) -> Tuple[Optional[str], List[int]]:
    m = _SHAPE_RE.match(s)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _shape_bytes(dt: Optional[str], dims: List[int]) -> int:
    if dt is None or dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES[dt]


def _all_shapes(text: str) -> List[Tuple[str, List[int]]]:
    return [(m.group(1), [int(d) for d in m.group(2).split(",") if d])
            for m in _SHAPE_RE.finditer(text)]


class HloAnalysis:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self._split(hlo_text)
        self.fusion_bodies = set()
        self.per_comp: Dict[str, Dict[str, float]] = {}
        self.calls: Dict[str, List[Tuple[str, Optional[str], str]]] = \
            defaultdict(list)
        for name, lines in self.comps.items():
            self._scan_comp(name, lines)
        self._memo: Dict[str, Dict[str, float]] = {}

    # -- parsing -----------------------------------------------------------
    def _split(self, text: str) -> None:
        cur = None
        for raw in text.splitlines():
            line = raw.strip()
            if line.endswith("{") and (" -> " in line
                                       or line.startswith("ENTRY")):
                m = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
                if m:
                    cur = m.group(2)
                    self.comps[cur] = []
                    if m.group(1):
                        self.entry = cur
                    continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is not None and "=" in line:
                self.comps[cur].append(line)

    _DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
    _OPERAND_RE = re.compile(r"%([\w\.\-]+)")

    def _scan_comp(self, name: str, lines: List[str]) -> None:
        acc: Dict[str, float] = defaultdict(float)
        bookkeeping = ("parameter(", " constant(", "get-tuple-element(",
                       " tuple(", "bitcast(", " iota(", "after-all(")
        # pass 1: symbol table %name -> (dtype, dims); optimized HLO omits
        # operand shapes inline, so resolve them by definition
        symbols: Dict[str, Tuple[Optional[str], List[int]]] = {}
        for ln in lines:
            dm = self._DEF_RE.match(ln)
            if dm:
                symbols[dm.group(1)] = _parse_shape(dm.group(2))

        def operand_shapes(arglist: str):
            out = []
            for m in self._OPERAND_RE.finditer(arglist):
                if m.group(1) in symbols:
                    out.append(symbols[m.group(1)])
            return out

        for ln in lines:
            dm = self._DEF_RE.match(ln)
            if not dm:
                continue
            rhs = dm.group(2)
            res_dt, res_dims = _parse_shape(rhs)
            res_bytes = _shape_bytes(res_dt, res_dims)
            # operands: names inside the top-level parens of the op
            pm = re.search(r"\b[\w\-\$]+\(([^)]*)\)", rhs)
            ops = operand_shapes(pm.group(1)) if pm else []
            op_bytes = sum(_shape_bytes(dt, d) for dt, d in ops)
            if not any(b in rhs for b in bookkeeping):
                acc["hbm_bytes"] += res_bytes + op_bytes
                acc["hbm_write_bytes"] += res_bytes

            if re.search(r"\bdot\(", rhs):
                k = 1
                lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                if lc and ops:
                    _, lhs_dims = ops[0]
                    for i in lc.group(1).split(","):
                        if i and int(i) < len(lhs_dims):
                            k *= lhs_dims[int(i)]
                res_elems = 1
                for d in res_dims:
                    res_elems *= d
                acc["flops"] += 2.0 * res_elems * k

            for ck in _COLL_KINDS:
                if re.search(rf"\b{ck}(?:-start)?\(", rhs):
                    if ck == "all-reduce":
                        acc["coll_" + ck] += 2 * res_bytes
                    elif ck == "reduce-scatter":
                        acc["coll_" + ck] += op_bytes or res_bytes
                    else:
                        acc["coll_" + ck] += res_bytes
                    break

            if re.search(r"\bwhile\(", rhs):
                cm = re.search(r"condition=%?([\w\.\-]+)", rhs)
                bm = re.search(r"body=%?([\w\.\-]+)", rhs)
                tm = re.search(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"', rhs)
                if bm:
                    trip = (int(tm.group(1)) if tm
                            else cm.group(1) if cm else None)
                    self.calls[name].append(("while", trip, bm.group(1)))
            elif "fusion(" in rhs:
                for cm in re.finditer(r"calls=%?([\w\.\-]+)", rhs):
                    self.fusion_bodies.add(cm.group(1))
                    self.calls[name].append(("fusion", None, cm.group(1)))
            else:
                for cm in re.finditer(r"(?:to_apply|calls)=%?([\w\.\-]+)", rhs):
                    self.calls[name].append(("call", None, cm.group(1)))
        self.per_comp[name] = dict(acc)

    # -- aggregation ---------------------------------------------------------
    def _trip_count(self, cond: Optional[str]) -> int:
        best = 1
        for ln in self.comps.get(cond or "", []):
            if "constant(" in ln and ("compare" in ln or "constant" in ln):
                for m in re.finditer(r"constant\((\d+)\)", ln):
                    best = max(best, int(m.group(1)))
        return best

    def _total(self, name: str, seen=()) -> Dict[str, float]:
        if name in self._memo:
            return self._memo[name]
        if name in seen or name not in self.comps:
            return {}
        out: Dict[str, float] = defaultdict(float)
        mine = self.per_comp.get(name, {})
        is_fusion = name in self.fusion_bodies
        for k, v in mine.items():
            if is_fusion and k in ("hbm_bytes", "hbm_write_bytes"):
                continue           # interior traffic stays in VMEM/registers
            out[k] += v
        for kind, trip, callee in self.calls.get(name, []):
            sub = self._total(callee, seen + (name,))
            if kind != "while":
                mult = 1
            elif isinstance(trip, int):
                mult = trip
            else:
                mult = self._trip_count(trip)
            for k, v in sub.items():
                out[k] += v * mult
        self._memo[name] = dict(out)
        return self._memo[name]

    def totals(self) -> Dict[str, float]:
        t = dict(self._total(self.entry)) if self.entry else {}
        t["coll_total"] = sum(v for k, v in t.items() if k.startswith("coll_"))
        t.setdefault("flops", 0.0)
        t.setdefault("hbm_bytes", 0.0)
        t.setdefault("hbm_write_bytes", 0.0)
        return t


def analyze(hlo_text: str) -> Dict[str, float]:
    return HloAnalysis(hlo_text).totals()


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    t = analyze(hlo_text)
    out = {k[5:]: v for k, v in t.items() if k.startswith("coll_")
           and k != "coll_total"}
    out["total"] = t.get("coll_total", 0.0)
    return out
