"""ShapeDtypeStruct input specs for every (arch x shape) cell — the dry-run's
stand-ins (weak-type-correct, shardable, zero allocation).

``input_specs(cfg, shape)`` returns (mode, args) where args are the exact
pytrees the corresponding step function is lowered with:

  train   -> (train_state, batch)
  prefill -> (params, batch)
  decode  -> (params, token, cache)     # serve_step, KV/state cache at seq_len
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import lm
from repro.train import init_train_state


def _sds(tree) -> Any:
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def batch_specs(cfg: ArchConfig, batch: int, seq: int) -> Dict[str, Any]:
    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "targets": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.frontend_prefix:
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (batch, min(cfg.frontend_prefix, seq), cfg.d_model), jnp.float32)
    if cfg.mrope:
        specs["positions"] = jax.ShapeDtypeStruct((3, batch, seq), jnp.int32)
    return specs


def state_specs(cfg: ArchConfig) -> Any:
    return jax.eval_shape(lambda: init_train_state(cfg, jax.random.key(0)))


def params_specs(cfg: ArchConfig) -> Any:
    return jax.eval_shape(lambda: lm.init_params(cfg, jax.random.key(0)))


def cache_specs(cfg: ArchConfig, batch: int, cache_len: int) -> Any:
    return jax.eval_shape(
        lambda: lm.init_cache(cfg, batch, cache_len))


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[str, Tuple]:
    if shape.mode == "train":
        return "train", (state_specs(cfg),
                         batch_specs(cfg, shape.global_batch, shape.seq_len))
    if shape.mode == "prefill":
        # prefill lowers without targets
        b = batch_specs(cfg, shape.global_batch, shape.seq_len)
        b.pop("targets")
        return "prefill", (params_specs(cfg), b)
    if shape.mode == "decode":
        token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        cache = cache_specs(cfg, shape.global_batch, shape.seq_len)
        return "decode", (params_specs(cfg), token, cache)
    raise ValueError(shape.mode)
