import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and record memory / cost / collective analyses.

This is deliverable (e): it proves the distribution config is coherent
without hardware. The two mesh targets are the single-pod 16x16 (256 chips,
('data','model')) and the 2-pod 2x16x16 (512 chips, ('pod','data','model')).

Usage:
  python -m repro.launch.dryrun --arch internlm2_1p8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all          # every cell, one subprocess each
  python -m repro.launch.dryrun --all --mesh multi

Results append to experiments/dryrun/<arch>_<shape>_<mesh>.json; the roofline
benchmark (benchmarks/roofline.py) consumes these files.
"""
import argparse
import json
import pathlib
import subprocess
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config, shapes_for, SHAPES
from repro.launch import sharding
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.launch.specs import input_specs
from repro.models import dist, lm
from repro.train import make_train_step, make_prefill_step, make_decode_step

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _coerce(v: str):
    for conv in (int, float):
        try:
            return conv(v)
        except ValueError:
            pass
    return {"true": True, "false": False}.get(v.lower(), v)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict = None, inner_shard: bool = False,
               free_cache_out: bool = False):
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    if inner_shard:
        sharding.EXPERT_INNER_SHARD = True
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mode, args = input_specs(cfg, shape)
    constrain = sharding.make_constrain(mesh, cfg)
    constrain_logits = sharding.make_constrain_logits(mesh)
    ctx = dist.DistContext(
        mesh=mesh, batch_axes=batch_axes(mesh), tp_axis="model",
        seq_shard=cfg.seq_shard,
        expert_inner_shard=sharding.EXPERT_INNER_SHARD)

    with mesh, dist.use(ctx):
        if mode == "train":
            fn = make_train_step(cfg, constrain=constrain,
                                 constrain_logits=constrain_logits)
            in_sh = (sharding.state_shardings(mesh, args[0]),
                     sharding.batch_shardings(mesh, args[1]))
            out_sh = (in_sh[0], None)
        elif mode == "prefill":
            fn = make_prefill_step(cfg, cache_len=shape.seq_len,
                                   constrain=constrain)
            in_sh = (sharding.param_shardings(mesh, args[0]),
                     sharding.batch_shardings(mesh, args[1]))
            if free_cache_out:
                # §Perf iteration: let XLA keep the cache in the layout the
                # compute produced; the prefill->decode reshard happens once
                # at hand-off instead of per layer inside prefill
                out_sh = None
            else:
                cache_spec = jax.eval_shape(fn, *args)[1]
                out_sh = (None, sharding.cache_shardings(mesh, cfg,
                                                         cache_spec))
        else:  # decode
            fn = make_decode_step(cfg, constrain=constrain)
            in_sh = (sharding.param_shardings(mesh, args[0]),
                     sharding.batch_shardings(mesh, {"tokens": args[1]})["tokens"],
                     sharding.cache_shardings(mesh, cfg, args[2]))
            out_sh = (in_sh[1], None, in_sh[2])

        donate = {"train": (0,), "prefill": (), "decode": (2,)}[mode]
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        return lowered, mode, mesh


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict = None, inner_shard: bool = False,
             free_cache_out: bool = False) -> dict:
    t0 = time.time()
    lowered, mode, mesh = lower_cell(arch, shape_name, multi_pod,
                                     overrides, inner_shard, free_cache_out)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    mem_d = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        mem_d[f] = int(getattr(mem, f, 0) or 0)
    flops_xla = float(cost.get("flops", 0.0)) if cost else 0.0
    bytes_xla = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
    hlo = analyze(compiled.as_text())          # loop-trip-count aware

    n_dev = mesh.devices.size
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "devices": int(n_dev),
        "mode": mode,
        "memory": mem_d,
        "flops": hlo["flops"],
        "hbm_bytes": hlo["hbm_bytes"],
        "hbm_write_bytes": hlo["hbm_write_bytes"],
        "collectives": {k[5:]: v for k, v in hlo.items()
                        if k.startswith("coll_")},
        "xla_cost_analysis": {"flops": flops_xla,
                              "bytes_accessed": bytes_xla},
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "ok": True,
    }
    print(f"[dryrun] {arch} {shape_name} {'multi' if multi_pod else 'single'}"
          f" OK flops={hlo['flops']:.3e} hbm={hlo['hbm_bytes']:.3e}"
          f" coll={hlo.get('coll_total', 0):.3e}"
          f" temp={mem_d['temp_size_in_bytes']/2**30:.2f}GiB"
          f" args={mem_d['argument_size_in_bytes']/2**30:.2f}GiB"
          f" lower={t_lower:.0f}s compile={t_compile:.0f}s")
    return rec


def cells(mesh_sel: str):
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            for m in (["single", "multi"] if mesh_sel == "both" else [mesh_sel]):
                yield arch, shape.name, m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells that already have results")
    ap.add_argument("--set", action="append", default=[], dest="overrides",
                    help="perf-iteration config override key=value")
    ap.add_argument("--inner-shard", action="store_true",
                    help="expert FFN inner-dim sharding instead of ZeRO-3")
    ap.add_argument("--free-cache-out", action="store_true",
                    help="prefill: let XLA pick the cache output layout")
    ap.add_argument("--tag", default="",
                    help="suffix for the result file (perf iterations)")
    args = ap.parse_args()
    OUT_DIR.mkdir(parents=True, exist_ok=True)

    if args.all:
        failures = []
        for arch, shape, m in cells(args.mesh):
            out = OUT_DIR / f"{arch}_{shape}_{m}.json"
            if out.exists() and not args.force:
                print(f"[dryrun] skip {out.name} (exists)")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", m]
            r = subprocess.run(cmd, cwd=str(OUT_DIR.parents[1]))
            if r.returncode != 0:
                failures.append((arch, shape, m))
        if failures:
            print("FAILURES:", failures)
            sys.exit(1)
        print("[dryrun] all cells OK")
        return

    assert args.arch and args.shape and args.mesh in ("single", "multi")
    tag = f"_{args.tag}" if args.tag else ""
    out = OUT_DIR / f"{args.arch}_{args.shape}_{args.mesh}{tag}.json"
    overrides = dict(kv.split("=", 1) for kv in args.overrides)
    overrides = {k: _coerce(v) for k, v in overrides.items()}
    try:
        rec = run_cell(args.arch, args.shape, args.mesh == "multi",
                       overrides or None, args.inner_shard,
                       args.free_cache_out)
        rec["tag"] = args.tag
        rec["overrides"] = overrides
    except Exception as e:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "ok": False, "error": f"{type(e).__name__}: {e}"}
        out.write_text(json.dumps(rec, indent=2))
        traceback.print_exc()
        sys.exit(1)
    out.write_text(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
