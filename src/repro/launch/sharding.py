"""Sharding rules: parameter/optimizer/batch/cache PartitionSpecs per
(architecture x shape x mesh).

Scheme (DESIGN.md §6): ``data`` carries DP + FSDP (params and optimizer
state ZeRO-sharded over it), ``model`` carries TP (attention heads / FFN
columns), EP (expert axis) and — when ``cfg.seq_shard`` — sequence sharding
of the residual stream. ``pod`` is pure DP: params replicated across pods,
gradients all-reduced over the inter-pod links.

Every rule degrades to replication when a dimension doesn't divide the mesh
axis, so any (arch x mesh) combination lowers; the roofline report then
shows what that costs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch.mesh import axis_size, batch_axes

FSDP, TP = "data", "model"

# §Perf knob, REFUTED BY ANALYSIS (kept for the record): sharding the expert
# FFN inner dim over 'data' is invalid on this mesh because 'data' is also
# the token axis — the partial-output psum would mix tokens. See
# models/blocks._moe_ffn_sharded and EXPERIMENTS.md §Perf.
EXPERT_INNER_SHARD = False

# trailing-dims rules keyed by leaf name; names match the model param dicts.
# 3D entries are the (E, d, f) expert tensors. The embedding table is
# d-sharded only: a vocab-sharded gather forces GSPMD into full
# rematerialization (measured on kimi-k2; see EXPERIMENTS.md §Perf).
_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "embed": (None, TP),
    "head": (None, TP),
    "wq": (FSDP, TP), "wk": (FSDP, TP), "wv": (FSDP, TP),
    "wo": (TP, FSDP),
    "w_gate": (FSDP, TP), "w_up": (FSDP, TP), "w_down": (TP, FSDP),
    "w_gate3": (TP, FSDP, None), "w_up3": (TP, FSDP, None),
    "w_down3": (TP, None, FSDP),
    "w_gate3i": (TP, None, FSDP), "w_up3i": (TP, None, FSDP),
    "w_down3i": (TP, FSDP, None),
    "router": (None, TP),        # expert-sharded; EP gathers the tiny logits
    "in_proj": (FSDP, None), "out_proj": (None, FSDP),
    "wr": (FSDP, TP), "wg": (FSDP, TP),
    "cm_wk": (FSDP, TP), "cm_wv": (TP, FSDP), "cm_wr": (FSDP, TP),
    "maa_w1": (FSDP, None), "decay_w1": (FSDP, None),
}


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return tuple(out)


def _rule_for(names: Tuple[str, ...], shape: Tuple[int, ...]
              ) -> Tuple[Optional[str], ...]:
    leaf = names[-1] if names else ""
    # optimizer-state leaves mirror the param tree: the param name is the
    # nearest enclosing named key
    param_name = leaf
    if leaf in ("vr", "vc", "m", "v", "master"):
        for n in reversed(names[:-1]):
            if n in _RULES or n in ("embed", "head"):
                param_name = n
                break
        else:
            param_name = names[-2] if len(names) >= 2 else leaf
    rule = _RULES.get(param_name)
    if rule is None:
        return ()
    # expert tensors: same names, one extra leading dim -> 3D rule
    if param_name in ("w_gate", "w_up", "w_down"):
        if len(shape) >= 3 and shape[-1] != 1 and _looks_expert(names):
            rule = _RULES[param_name + ("3i" if EXPERT_INNER_SHARD else "3")]
    if leaf == "vr":            # adafactor row stats: param shape minus last
        rule = rule[:-1]
    elif leaf == "vc":          # col stats: minus second-to-last
        rule = rule[:-2] + rule[-1:]
    return rule


def _looks_expert(names: Tuple[str, ...]) -> bool:
    return any(n == "moe" for n in names) and "shared" not in names


def _fits(mesh: Mesh, axes: Optional[str], dim: int) -> bool:
    return axes is not None and dim % axis_size(mesh, axes) == 0


def param_pspec(mesh: Mesh, path, leaf) -> P:
    names = _path_names(path)
    shape = leaf.shape
    rule = _rule_for(names, shape)
    if not rule:
        return P()
    spec: list = [None] * len(shape)
    # align rule to trailing dims (leading dims are layer-stack axes)
    for i, ax in enumerate(rule):
        d = len(shape) - len(rule) + i
        if d >= 0 and _fits(mesh, ax, shape[d]):
            spec[d] = ax
    return P(*spec)


def param_shardings(mesh: Mesh, tree) -> Any:
    """Shape tree (eval_shape output or real params) -> NamedSharding tree."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, param_pspec(mesh, p, l)), tree)


def state_shardings(mesh: Mesh, state_tree) -> Any:
    return param_shardings(mesh, state_tree)   # opt state mirrors params


# ---------------------------------------------------------------------------
# batch / cache
# ---------------------------------------------------------------------------
def batch_shardings(mesh: Mesh, batch_tree) -> Any:
    bd = batch_axes(mesh)

    def spec(path, leaf):
        names = _path_names(path)
        nb = int(np.prod([axis_size(mesh, a) for a in bd]))
        if names and names[-1] == "positions":      # (3, B, S)
            p = P(None, bd, None) if leaf.shape[1] % nb == 0 else P()
        else:                                       # (B, ...) leaves
            p = (P(bd, *([None] * (leaf.ndim - 1)))
                 if leaf.shape[0] % nb == 0 else P())
        return NamedSharding(mesh, p)

    return jax.tree_util.tree_map_with_path(spec, batch_tree)


def cache_shardings(mesh: Mesh, cfg: ArchConfig, cache_tree) -> Any:
    """Decode caches. KV tensors are (L, B, W, Hkv, hd): shard B over the
    batch axes when divisible; shard Hkv over model if divisible, else shard
    the window W over model (long-context, small-batch decode)."""
    bd = batch_axes(mesh)
    nb = int(np.prod([axis_size(mesh, a) for a in bd]))
    tp = axis_size(mesh, TP)

    def spec(path, leaf):
        names = _path_names(path)
        if names and names[-1] == "pos":
            return NamedSharding(mesh, P())
        s: list = [None] * leaf.ndim
        if leaf.ndim >= 2 and leaf.shape[1] % nb == 0 and leaf.shape[1] > 1:
            s[1] = bd                                # batch
        if names and names[-1] in ("k", "v") and leaf.ndim == 5:
            if leaf.shape[3] % tp == 0:
                s[3] = TP                            # kv heads
            elif leaf.shape[2] % tp == 0:
                s[2] = TP                            # ring window
        elif leaf.ndim >= 3:
            # ssm states (L, B, H, ...) / conv states: shard heads if possible
            if leaf.shape[2] % tp == 0 and leaf.shape[2] >= tp:
                s[2] = TP
        return NamedSharding(mesh, P(*s))

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


# ---------------------------------------------------------------------------
# residual-stream constraint hooks (sequence sharding / logits sharding)
# ---------------------------------------------------------------------------
def _guarded_wsc(mesh: Mesh, x, wanted):
    """with_sharding_constraint, dropping axes that don't divide the shape."""
    spec = []
    for d, ax in enumerate(wanted):
        if ax is None:
            spec.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = int(np.prod([axis_size(mesh, a) for a in axes]))
        spec.append(ax if x.shape[d] % n == 0 and x.shape[d] >= n else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def make_constrain(mesh: Mesh, cfg: ArchConfig):
    bd = batch_axes(mesh)
    seq_ax = TP if cfg.seq_shard else None

    def constrain(x):
        return _guarded_wsc(mesh, x, (bd, seq_ax, None))

    return constrain


def make_constrain_logits(mesh: Mesh):
    bd = batch_axes(mesh)

    def constrain(x):
        return _guarded_wsc(mesh, x, (bd, None, TP))

    return constrain
