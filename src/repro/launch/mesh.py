"""Production mesh definitions.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state — the dry-run must set
XLA_FLAGS before any jax initialization.

Axis semantics:
  pod   — outer data-parallel axis across pods (params replicated across it;
          gradient all-reduce crosses the inter-pod links)
  data  — in-pod data parallelism; also the FSDP/ZeRO shard axis for params
          and optimizer state
  model — tensor/expert parallelism (attention heads, FFN, expert axis);
          also the sequence-sharding axis when cfg.seq_shard is on
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Smoke-scale mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: jax.sharding.Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
