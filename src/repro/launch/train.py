"""Training launcher.

CPU smoke scale by default (real optimization steps on a reduced config);
on a TPU fleet the same entry point takes ``--mesh production``. Integrates
the fault-tolerant Trainer (checkpoint/restart, telemetry) and registers the
job with an LMCM instance so migrations/checkpoint flushes land in LM
windows.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_8b --steps 100
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1p8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    trainer = Trainer(cfg, tcfg, batch=args.batch, seq=args.seq)
    out = trainer.run(args.steps)
    for h in out["history"][:: args.log_every]:
        print(f"step={int(h.get('step', 0))} loss={h['loss']:.4f} "
              f"t={h['step_time']*1e3:.1f}ms")
    print(json.dumps({k: v for k, v in out.items() if k != "history"},
                     default=float))


if __name__ == "__main__":
    main()
