"""Serving launcher: batched prefill + decode with the KV/state cache.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6_1p6b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import make_batch
from repro.models import lm
from repro.train import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1p8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = lm.init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, args.batch, args.prompt_len)
    batch.pop("targets")

    prefill = jax.jit(make_prefill_step(
        cfg, cache_len=args.prompt_len + args.tokens))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.monotonic()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.monotonic() - t0

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.monotonic()
    for _ in range(args.tokens - 1):
        tok, logits, cache = decode(params, tok, cache)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.monotonic() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f}ms")
    print(f"decode:  {args.tokens-1} steps in {t_decode*1e3:.1f}ms "
          f"({(args.tokens-1)*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
