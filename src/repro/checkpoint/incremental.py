"""Incremental (dirty-block) checkpoints — the pre-copy engine applied to
fault tolerance.

Between full checkpoints, only state blocks that changed since the last
(full or incremental) snapshot are written — exactly the paper's dirty-page
tracking, reused: for MoE/embedding-heavy models most optimizer blocks are
untouched between adjacent steps, so deltas are small. Restore replays the
base full checkpoint plus deltas in order. This gives checkpoint-frequency
at delta cost, which is what makes tight-RPO fault tolerance affordable at
1000+ nodes.
"""
from __future__ import annotations

import json
import pathlib
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (CODEC, compress_bytes, decompress_bytes,
                                    restore_checkpoint, save_checkpoint,
                                    _flatten_with_paths)
from repro.core.precopy import _leaf_dirty


class IncrementalCheckpointer:
    def __init__(self, directory: str, block_elems: int = 1 << 14,
                 full_every: int = 10):
        self.directory = pathlib.Path(directory)
        self.block = block_elems
        self.full_every = full_every
        self._shadow = None            # host copy of last snapshot
        self._since_full = 0

    def save(self, step: int, state) -> dict:
        """Full or delta save; returns stats {kind, bytes}."""
        host = jax.tree.map(np.asarray, state)
        if self._shadow is None or self._since_full >= self.full_every:
            save_checkpoint(str(self.directory), step, host)
            self._shadow = host
            self._since_full = 0
            n = sum(a.nbytes for a in jax.tree.leaves(host))
            return {"kind": "full", "bytes": n}

        d = self.directory / f"delta_{step:08d}"
        d.mkdir(parents=True, exist_ok=True)
        manifest = {}
        total = 0
        flat_new = _flatten_with_paths(host)
        flat_old = _flatten_with_paths(self._shadow)
        for i, (key, new) in enumerate(flat_new.items()):
            old = flat_old[key]
            nv = new.reshape(-1)
            ov = old.reshape(-1).astype(nv.dtype)
            nb = -(-nv.size // self.block)
            if np.issubdtype(nv.dtype, np.floating):
                dirty = np.asarray(_leaf_dirty(jnp.asarray(nv),
                                               jnp.asarray(ov), self.block))
            else:
                pad = nb * self.block - nv.size
                dirty = np.any(np.pad(nv, (0, pad)).reshape(nb, self.block)
                               != np.pad(ov, (0, pad)).reshape(nb, self.block),
                               axis=1)
            idx = np.flatnonzero(dirty)
            if idx.size == 0:
                continue
            pad = nb * self.block - nv.size
            blocks = np.pad(nv, (0, pad)).reshape(nb, self.block)[idx]
            fname = f"delta_{i:05d}.bin.zst"
            with open(d / fname, "wb") as f:
                f.write(compress_bytes(blocks.tobytes()))
            manifest[key] = {"file": fname, "blocks": idx.tolist(),
                             "dtype": str(nv.dtype)}
            total += blocks.nbytes
        (d / "manifest.json").write_text(json.dumps(
            {"step": step, "block": self.block, "codec": CODEC,
             "leaves": manifest}))
        self._shadow = host
        self._since_full += 1
        return {"kind": "delta", "bytes": total}

    # -- restore -------------------------------------------------------------
    def restore(self, step: int, like, shardings=None) -> Any:
        """Restore state at ``step``: base full checkpoint + ordered deltas."""
        fulls = sorted(int(p.name.split("_")[1])
                       for p in self.directory.glob("step_*") if p.is_dir())
        base = max(s for s in fulls if s <= step)
        state = restore_checkpoint(str(self.directory), base, like)
        deltas = sorted(int(p.name.split("_")[1])
                        for p in self.directory.glob("delta_*") if p.is_dir())
        flat = _flatten_with_paths(jax.tree.map(np.array, state))
        for s in deltas:
            if not (base < s <= step):
                continue
            d = self.directory / f"delta_{s:08d}"
            man = json.loads((d / "manifest.json").read_text())
            blk = man["block"]
            for key, meta in man["leaves"].items():
                raw = decompress_bytes((d / meta["file"]).read_bytes(),
                                       man.get("codec", "zstd"))
                blocks = np.frombuffer(raw, np.dtype(meta["dtype"])
                                       ).reshape(len(meta["blocks"]), blk)
                leaf = flat[key]
                nv = leaf.reshape(-1)
                nb = -(-nv.size // blk)
                padded = np.pad(nv, (0, nb * blk - nv.size)).reshape(nb, blk)
                padded[np.asarray(meta["blocks"])] = blocks
                flat[key] = padded.reshape(-1)[: nv.size].reshape(leaf.shape)
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        keys = list(_flatten_with_paths(like).keys())
        out = [flat[k] for k in keys]
        if shardings is not None:
            sh = jax.tree_util.tree_leaves(shardings)
            out = [jax.device_put(a, s) for a, s in zip(out, sh)]
        return jax.tree_util.tree_unflatten(treedef, out)
