"""Checkpoint store: full pytree save/restore with resharding on restore.

Format: one directory per step containing a zstd-compressed npz-like blob
per leaf-shard plus a JSON manifest (treedef paths, shapes, dtypes). Restore
takes an optional sharding tree and ``jax.device_put``s each leaf onto it —
this is what makes elastic restart (different mesh than at save time) a
one-liner, and what the pre-copy migration engine uses as its destination
materializer.

``AsyncCheckpointer`` snapshots to host memory synchronously (cheap) and
writes in a background thread — the training loop never blocks on disk.
"""
from __future__ import annotations

import json
import pathlib
import threading
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
import zlib

try:
    import zstandard
    CODEC = "zstd"
except ImportError:          # clean containers fall back to stdlib zlib;
    zstandard = None         # the manifest records which codec wrote the
    CODEC = "zlib"           # blobs so a mismatch fails loud at restore


def compress_bytes(data: bytes) -> bytes:
    if CODEC == "zstd":
        return zstandard.ZstdCompressor(level=3).compress(data)
    return zlib.compress(data, 3)


def decompress_bytes(data: bytes, codec: str = "zstd") -> bytes:
    if codec == "zlib":          # stdlib: readable everywhere
        return zlib.decompress(data)
    if zstandard is None:
        raise RuntimeError(
            "checkpoint was written with zstd but zstandard is not "
            "installed in this environment")
    return zstandard.ZstdDecompressor().decompress(data)


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = leaf
    return flat


def save_checkpoint(directory: str, step: int, state) -> pathlib.Path:
    d = pathlib.Path(directory) / f"step_{step:08d}"
    tmp = d.with_suffix(".tmp")
    tmp.mkdir(parents=True, exist_ok=True)
    flat = _flatten_with_paths(state)
    manifest = {}
    for i, (key, leaf) in enumerate(flat.items()):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.bin.zst"
        with open(tmp / fname, "wb") as f:
            f.write(compress_bytes(arr.tobytes()))
        manifest[key] = {"file": fname, "shape": list(arr.shape),
                         "dtype": str(arr.dtype)}
    (tmp / "manifest.json").write_text(json.dumps(
        {"step": step, "codec": CODEC, "leaves": manifest}))
    if d.exists():  # atomic replace
        import shutil
        shutil.rmtree(d)
    tmp.rename(d)
    return d


def latest_step(directory: str) -> Optional[int]:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")
             if p.is_dir()]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like,
                       shardings=None) -> Any:
    """Restore into the structure of ``like`` (a pytree or eval_shape tree).
    ``shardings``: optional matching tree of NamedShardings — leaves are
    placed directly onto the (possibly different) target mesh."""
    d = pathlib.Path(directory) / f"step_{step:08d}"
    top = json.loads((d / "manifest.json").read_text())
    manifest = top["leaves"]
    codec = top.get("codec", "zstd")
    flat_like = _flatten_with_paths(like)
    flat_sh = _flatten_with_paths(shardings) if shardings is not None else {}
    out = {}
    for key, spec in flat_like.items():
        meta = manifest[key]
        raw = decompress_bytes((d / meta["file"]).read_bytes(), codec)
        arr = np.frombuffer(raw, dtype=np.dtype(meta["dtype"])
                            ).reshape(meta["shape"])
        if flat_sh:
            out[key] = jax.device_put(arr, flat_sh[key])
        else:
            out[key] = jnp.asarray(arr)
    # rebuild tree in `like`'s structure
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten_with_paths(like).keys())
    return jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk asynchronously."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def save(self, step: int, state) -> None:
        self.wait()
        host_state = jax.tree.map(np.asarray, state)   # device -> host copy

        def _write():
            save_checkpoint(str(self.directory), step, host_state)
            self.last_saved = step
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.directory.glob("step_*") if p.is_dir())
        for s in steps[: -self.keep]:
            import shutil
            shutil.rmtree(self.directory / f"step_{s:08d}", ignore_errors=True)
