from repro.optim.optimizers import (  # noqa: F401
    OptState, init_opt_state, apply_updates, make_schedule, global_norm,
)
