"""Optimizers: AdamW (fp32 master + moments) and Adafactor (factored second
moment, no momentum — the memory-lean choice for the trillion-param configs).

States are plain pytrees so they shard exactly like the params they mirror
(ZeRO-style over the ``data`` axis — see ``launch.sharding``).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

OptState = Dict[str, Any]


def make_schedule(cfg: ArchConfig, warmup: int = 200,
                  total: int = 10_000) -> Callable[[jnp.ndarray], jnp.ndarray]:
    peak = cfg.learning_rate

    def schedule(step):
        step = step.astype(jnp.float32) + 1.0
        warm = peak * step / max(1, warmup)
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = 0.1 * peak + 0.9 * peak * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return schedule


def global_norm(tree) -> jnp.ndarray:
    # f32 accumulation *inside* the reduce — materializing f32 copies of the
    # stacked expert leaves costs ~15 GiB/device on the 1T config
    leaves = [jnp.sum(jnp.square(l), dtype=jnp.float32)
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def _adamw_init(params) -> OptState:
    f32 = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
    }


def _adamw_update(cfg: ArchConfig, params, grads, state: OptState, lr,
                  scale=1.0, b1=0.9, b2=0.95, eps=1e-8) -> Tuple[Any, OptState]:
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1 - b1 ** c
    bc2 = 1 - b2 ** c

    def upd(g, m, v, master):
        # clip scale applied here, per (scanned) slice: casting the whole
        # grad tree to f32 up front costs multi-GiB temporaries per device
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        master = master - lr * (step + cfg.weight_decay * master)
        return m, v, master

    flat = jax.tree.map(lambda *a: _maybe_scanned(upd, *a),
                        grads, state["m"], state["v"], state["master"])
    m = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype), master, params)
    return new_params, {"m": m, "v": v, "master": master, "count": count}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; rank-1 for matrices, dense for vectors)
# ---------------------------------------------------------------------------
def _adafactor_init(params) -> OptState:
    def factored(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

    return {"v": jax.tree.map(factored, params,
                              is_leaf=lambda x: isinstance(x, jnp.ndarray))}


def _maybe_scanned(upd, g, *rest):
    """Hook for stacked-leaf updates. Measured on the CPU-backend SPMD
    compile: lax.scan over the layer axis *doubles* buffer residency
    (loop double-buffering beats the per-slice temp saving), so updates
    stay flat; the memory battle is won by keeping elementwise math in the
    param dtype instead (see _adafactor_update)."""
    return upd(g, *rest)


def _adafactor_update(cfg: ArchConfig, params, grads, state: OptState, lr,
                      scale=1.0, decay=0.99, eps=1e-30, clip_thresh=1.0):
    count = state["count"] + 1

    def upd(g, v, p):
        # Elementwise math stays in the param dtype (bf16): params are stored
        # bf16, so sub-ulp precision in the step is rounded away regardless,
        # and full-shape f32 temporaries cost ~2x param bytes per device at
        # the 1T scale. Reductions (vr/vc/rms) accumulate in f32.
        dt = g.dtype
        if g.ndim >= 2:
            g2m_r = jnp.mean(jnp.square(g), axis=-1, dtype=jnp.float32)
            g2m_c = jnp.mean(jnp.square(g), axis=-2, dtype=jnp.float32)
            s2 = jnp.asarray(scale, jnp.float32) ** 2
            vr = decay * v["vr"] + (1 - decay) * (g2m_r * s2 + eps)
            vc = decay * v["vc"] + (1 - decay) * (g2m_c * s2 + eps)
            r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            denom = (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                     + 1e-8)
            step = (g * jnp.asarray(scale, dt)) / denom.astype(dt)
            new_v = {"vr": vr, "vc": vc}
        else:
            gf = g.astype(jnp.float32) * scale
            nv = decay * v["v"] + (1 - decay) * (gf * gf + eps)
            step = (gf / (jnp.sqrt(nv) + 1e-8)).astype(dt)
            new_v = {"v": nv}
        rms = jnp.sqrt(jnp.mean(jnp.square(step), dtype=jnp.float32) + 1e-30)
        limit = jnp.maximum(1.0, rms / clip_thresh).astype(dt)
        upd_term = step / limit + jnp.asarray(cfg.weight_decay, dt) * p
        return (p - jnp.asarray(lr, dt) * upd_term).astype(p.dtype), new_v

    pairs = jax.tree.map(lambda *a: _maybe_scanned(upd, *a),
                         grads, state["v"], params)
    is_pair = lambda x: isinstance(x, tuple)
    new_params = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    new_v = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    return new_params, {"v": new_v, "count": count}


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def init_opt_state(cfg: ArchConfig, params) -> OptState:
    state = (_adafactor_init(params) if cfg.optimizer == "adafactor"
             else _adamw_init(params))
    state["count"] = jnp.zeros((), jnp.int32)
    return state


def apply_updates(cfg: ArchConfig, params, grads, state: OptState,
                  lr) -> Tuple[Any, OptState, jnp.ndarray]:
    """Clip-by-global-norm then optimizer update. Returns (params, state, gnorm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-6))
    if cfg.optimizer == "adafactor":
        params, state = _adafactor_update(cfg, params, grads, state, lr,
                                          scale=scale)
    else:
        params, state = _adamw_update(cfg, params, grads, state, lr,
                                      scale=scale)
    return params, state, gnorm
