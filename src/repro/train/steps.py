"""train_step / prefill_step / serve_step builders.

These are the functions the launcher jits (and the multi-pod dry-run
lowers). They are mesh-agnostic: sharding enters only through the
``in_shardings``/``out_shardings`` the launcher attaches and through the
optional residual-stream ``constrain`` hook (sequence sharding).

Telemetry for ALMA is produced here: every train step reports the
dirty-block profile of the update (fraction of parameter blocks touched
beyond a threshold) plus step-level load indexes — the TPU analogue of the
paper's 15-second SNMP samples (DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import lm
from repro import optim

TrainState = Dict[str, Any]


def init_train_state(cfg: ArchConfig, rng) -> TrainState:
    params = lm.init_params(cfg, rng)
    return {
        "params": params,
        "opt": optim.init_opt_state(cfg, params),
        "step": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# dirty-block telemetry (ALMA load index: the pre-copy 'dirty page rate')
# ---------------------------------------------------------------------------
DIRTY_BLOCK = 1 << 14          # 16k-element blocks ~= 32 KiB bf16 "pages"


def dirty_block_stats(old_params, new_params,
                      block: int = DIRTY_BLOCK) -> Dict[str, jnp.ndarray]:
    """Per-update dirty profile: fraction of `block`-sized chunks that changed
    and total bytes changed. This is what the paper measures as MEM dirty rate
    through SNMP; here it is exact, computed from the update itself."""
    dirty_blocks = jnp.zeros((), jnp.float32)
    total_blocks = jnp.zeros((), jnp.float32)
    dirty_bytes = jnp.zeros((), jnp.float32)
    for o, n in zip(jax.tree.leaves(old_params), jax.tree.leaves(new_params)):
        of = o.reshape(-1).astype(jnp.float32)
        nf = n.reshape(-1).astype(jnp.float32)
        nb = -(-of.shape[0] // block)
        pad = nb * block - of.shape[0]
        diff = jnp.pad(jnp.abs(nf - of), (0, pad)).reshape(nb, block)
        changed = jnp.any(diff > 0, axis=1)
        dirty_blocks += jnp.sum(changed.astype(jnp.float32))
        total_blocks += nb
        dirty_bytes += jnp.sum(changed) * block * o.dtype.itemsize
    return {"dirty_fraction": dirty_blocks / jnp.maximum(total_blocks, 1),
            "dirty_bytes": dirty_bytes}


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------
def make_train_step(cfg: ArchConfig, *, constrain: Callable = lm.Identity,
                    constrain_logits: Callable = lm.Identity,
                    telemetry: bool = False,
                    schedule: Optional[Callable] = None):
    """Returns fn(state, batch) -> (state, metrics). Gradient accumulation
    over ``cfg.accum_steps`` microbatches (scan; grads accumulated in f32)."""
    schedule = schedule or optim.make_schedule(cfg)

    def loss_fn(params, microbatch):
        return lm.lm_loss(params, cfg, microbatch, constrain=constrain,
                          constrain_logits=constrain_logits)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        params = state["params"]
        A = cfg.accum_steps
        if A == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape(A, x.shape[0] // A, *x.shape[1:])

            micro = {k: split(v) for k, v in batch.items()}

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), ms = lax.scan(acc_body, (g0, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / A, grads)
            loss = loss / A
            metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0)
                                   if x.ndim else x, ms)

        lr = schedule(state["step"])
        new_params, new_opt, gnorm = optim.apply_updates(
            cfg, params, grads, state["opt"], lr)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        if telemetry:
            metrics.update(dirty_block_stats(params, new_params))
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ArchConfig, cache_len: int, *,
                      constrain: Callable = lm.Identity):
    """fn(params, batch) -> (last_logits (B, V), cache)."""

    def prefill_step(params, batch):
        x, _, cache = lm.forward(params, cfg, batch, constrain=constrain,
                                 want_cache=True, cache_len=cache_len)
        logits = lm._head(cfg, params, x[:, -1:, :])[:, 0]
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ArchConfig, *, constrain: Callable = lm.Identity,
                     greedy: bool = True):
    """serve_step: fn(params, token (B,1), cache) -> (next_token, logits, cache)."""

    def serve_step(params, token, cache):
        logits, cache = lm.decode_step(params, cfg, token, cache,
                                       constrain=constrain)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, cache

    return serve_step
