"""Chunked gated linear attention — the shared scan core for Mamba2 (SSD)
and RWKV6 (Finch).

Both architectures are linear recurrences over an outer-product state::

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          # S: (Dk, Dv) per head
    y_t = q_t S_t            (+ bonus (q_t . u . k_t) v_t   for RWKV)

We evaluate them chunk-parallel (chunk Q tokens): the intra-chunk term is a
masked (Q, Q) matmul — MXU-shaped — and the inter-chunk term is a short scan
carrying S. This is the standard SSD/GLA decomposition; the Pallas kernel in
``repro.kernels.ssm_scan`` implements the identical algorithm with explicit
VMEM tiling, and ``repro.kernels.ref`` re-exports this function as its oracle.

Numerics: all decay math in f32 log-space. Per-step log-decay is clamped to
[-LOG_DECAY_CLAMP, 0]; within a chunk, exponents are shifted by the mid-chunk
cumulative decay so both factors of the factored pairwise term stay inside
f32 range (documented trade-off in DESIGN.md §5 — a per-step decay below
exp(-4) zeroes state within a couple of tokens anyway).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

LOG_DECAY_CLAMP = 4.0
CHUNK = 32


def clamp_log_decay(logw: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(logw, -LOG_DECAY_CLAMP, 0.0)


def gla_chunked(
    q: jnp.ndarray,            # (B, H, S, Dk)
    k: jnp.ndarray,            # (B, H, S, Dk)
    v: jnp.ndarray,            # (B, H, S, Dv)
    log_decay: jnp.ndarray,    # (B, H, S, Dk) per-channel log decay (<= 0)
    *,
    bonus: Optional[jnp.ndarray] = None,   # (H, Dk): RWKV 'u'; None -> SSD mode
    initial_state: Optional[jnp.ndarray] = None,   # (B, H, Dk, Dv)
    chunk: int = CHUNK,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y: (B,H,S,Dv), final_state: (B,H,Dk,Dv)).

    ``bonus is None`` selects SSD semantics (current token enters the state
    *before* readout: mask j<=t, no bonus). Otherwise RWKV semantics (readout
    sees only the past: mask j<t, current token contributes via ``bonus``).
    """
    from repro.models import dist
    q, k, v, log_decay = (dist.constrain_heads(a)
                          for a in (q, k, v, log_decay))
    B, H, S, Dk = q.shape
    Dv = v.shape[-1]
    S_orig = S
    if S % chunk:
        # zero-pad to a chunk multiple: k=v=0 adds nothing to the state and
        # log_decay=0 leaves it untouched, so padding is exact.
        pad = chunk - S % chunk
        padw = ((0, 0), (0, 0), (0, pad), (0, 0))
        q, k, v = (jnp.pad(a, padw) for a in (q, k, v))
        log_decay = jnp.pad(log_decay, padw)
        S += pad
    nc, Q = S // chunk, chunk
    f32 = jnp.float32

    qc = q.reshape(B, H, nc, Q, Dk).astype(f32)
    kc = k.reshape(B, H, nc, Q, Dk).astype(f32)
    vc = v.reshape(B, H, nc, Q, Dv).astype(f32)
    lw = clamp_log_decay(log_decay.reshape(B, H, nc, Q, Dk).astype(f32))

    ssd = bonus is None
    L = jnp.cumsum(lw, axis=3)                       # inclusive cumsum
    L_q = L if ssd else L - lw                       # RWKV reads pre-decay
    L_total = L[:, :, :, -1, :]                      # (B,H,nc,Dk)
    shift = L[:, :, :, Q // 2, :][:, :, :, None, :]  # mid-chunk exponent shift

    q_in = qc * jnp.exp(L_q - shift)                 # (B,H,nc,Q,Dk)
    k_in = kc * jnp.exp(shift - L)
    scores = jnp.einsum("bhcqd,bhckd->bhcqk", q_in, k_in)
    pos = jnp.arange(Q)
    mask = pos[:, None] >= pos[None, :] if ssd else pos[:, None] > pos[None, :]
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    if not ssd:
        diag = jnp.einsum("bhcqd,hd,bhcqd->bhcq", qc, bonus.astype(f32), kc)
        scores = scores + diag[..., None] * jnp.eye(Q)[None, None, None]
    y_intra = jnp.einsum("bhcqk,bhckv->bhcqv", scores, vc)

    # ---- inter-chunk: scan the per-chunk state summaries --------------------
    k_out = kc * jnp.exp(L_total[:, :, :, None, :] - L)   # weight to chunk end
    chunk_states = jnp.einsum("bhcqd,bhcqv->bhcdv", k_out, vc)
    decay_c = jnp.exp(L_total)                             # (B,H,nc,Dk)

    def step(S_prev, xs):
        d_c, st_c = xs                                     # (B,H,Dk), (B,H,Dk,Dv)
        S_new = d_c[..., None] * S_prev + st_c
        return S_new, S_prev                               # emit state *entering* chunk

    S0 = (jnp.zeros((B, H, Dk, Dv), f32) if initial_state is None
          else initial_state.astype(f32))
    d_sc = jnp.moveaxis(decay_c, 2, 0)                     # (nc,B,H,Dk)
    st_sc = jnp.moveaxis(chunk_states, 2, 0)               # (nc,B,H,Dk,Dv)
    final_state, entering = jax.lax.scan(step, S0, (d_sc, st_sc))
    entering = jnp.moveaxis(entering, 0, 2)                # (B,H,nc,Dk,Dv)

    q_inter = qc * jnp.exp(L_q)
    y_inter = jnp.einsum("bhcqd,bhcdv->bhcqv", q_inter, entering)

    y = (y_intra + y_inter).reshape(B, H, S, Dv)[:, :, :S_orig]
    return y, final_state


def gla_decode_step(
    q: jnp.ndarray,            # (B, H, Dk)
    k: jnp.ndarray,            # (B, H, Dk)
    v: jnp.ndarray,            # (B, H, Dv)
    log_decay: jnp.ndarray,    # (B, H, Dk)
    state: jnp.ndarray,        # (B, H, Dk, Dv)
    *,
    bonus: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token exact recurrence (decode path). Matches gla_chunked."""
    f32 = jnp.float32
    qf, kf, vf = q.astype(f32), k.astype(f32), v.astype(f32)
    w = jnp.exp(clamp_log_decay(log_decay.astype(f32)))
    kv = kf[..., :, None] * vf[..., None, :]               # (B,H,Dk,Dv)
    if bonus is None:                                      # SSD: state first
        state = w[..., None] * state + kv
        y = jnp.einsum("bhd,bhdv->bhv", qf, state)
    else:                                                  # RWKV: read, bonus, then update
        y = jnp.einsum("bhd,bhdv->bhv", qf, state)
        y = y + jnp.einsum("bhd,hd,bhd->bh", qf, bonus.astype(f32), kf)[..., None] * vf
        state = w[..., None] * state + kv
    return y, state
