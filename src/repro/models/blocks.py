"""Core transformer building blocks: norms, rotary (incl. M-RoPE), GQA
attention (full / sliding-window / cached decode), SwiGLU MLP and the
sort-based MoE layer.

All blocks are pure functions over parameter pytrees (nested dicts of
``jnp.ndarray``). Matmuls run in the config dtype (bf16 on TPU, MXU f32
accumulation); softmax/norm statistics and the router always run in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ArchConfig, MoEConfig

# jax >= 0.6 promotes shard_map to jax.shard_map (check_vma kwarg); older
# releases ship it under jax.experimental with the check_rep spelling.
if hasattr(jax, "shard_map"):
    _shard_map, _SHARD_MAP_KW = jax.shard_map, {"check_vma": False}
else:
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initialization helpers
# ---------------------------------------------------------------------------
def dense_init(rng, shape, dtype, scale: float = 1.0) -> jnp.ndarray:
    """Truncated-normal fan-in init (the LM-standard 1/sqrt(fan_in))."""
    fan_in = shape[0] if len(shape) <= 2 else shape[-2]
    std = scale / max(1.0, fan_in) ** 0.5
    return (jax.random.truncated_normal(rng, -3.0, 3.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + 3-axis M-RoPE)
# ---------------------------------------------------------------------------
def rope_angles(cfg: ArchConfig, positions: jnp.ndarray) -> jnp.ndarray:
    """Rotation angles per (batch, seq, d_head/2).

    ``positions``: (B, S) int32 for standard RoPE, or (3, B, S) for M-RoPE
    where axis 0 indexes the temporal/height/width position streams and
    ``cfg.mrope_sections`` partitions the frequency bands between them.
    """
    half = cfg.head_dim // 2
    inv_freq = 1.0 / (cfg.rope_theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if cfg.mrope:
        sections = cfg.mrope_sections
        assert sum(sections) == half, (sections, half)
        # frequency band i takes its position stream from axis sec(i)
        axis_of_band = jnp.repeat(jnp.arange(3), jnp.array(sections),
                                  total_repeat_length=half)
        pos = positions.astype(jnp.float32)              # (3, B, S)
        pos_per_band = jnp.take(pos, axis_of_band, axis=0)   # (half, B, S)
        return jnp.einsum("hbs,h->bsh", pos_per_band, inv_freq)
    pos = positions.astype(jnp.float32)                  # (B, S)
    return pos[..., None] * inv_freq                     # (B, S, half)


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, D); angles: (B, S, D/2). Rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------
def attention_init(rng, cfg: ArchConfig) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.num_heads * hd), cfg.dtype),
        "wk": dense_init(ks[1], (d, cfg.num_kv_heads * hd), cfg.dtype),
        "wv": dense_init(ks[2], (d, cfg.num_kv_heads * hd), cfg.dtype),
        "wo": dense_init(ks[3], (cfg.num_heads * hd, d), cfg.dtype,
                         scale=1.0 / (2 * cfg.num_layers) ** 0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, cfg.dtype)
        p["k_norm"] = rmsnorm_init(hd, cfg.dtype)
    return p


def _attn_scores_mask(q_pos, k_pos, window: int):
    """Causal (+ optional sliding window) mask. q_pos/k_pos: (Sq,), (Sk,)."""
    causal = q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        causal &= q_pos[:, None] - k_pos[None, :] < window
    return causal


ATTN_CHUNK = 512


def _chunked_causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                              window: int, chunk: int = ATTN_CHUNK) -> jnp.ndarray:
    """Memory-O(S·chunk) causal attention (online softmax over KV chunks).

    This is the XLA-path equivalent of the Pallas flash-attention kernel
    (``repro.kernels.flash_attention``): outer python loop over query chunks
    (static triangular structure — no wasted masked-out FLOPs), inner
    ``lax.scan`` over the causal KV range with running (m, l, acc). Each query
    chunk is rematerialized on backward so the S² probabilities never coexist.

    q: (B, S, Hkv, G, hd); k, v: (B, S, Hkv, hd) -> (B, S, Hkv, G, hd)
    """
    B, S, Hkv, G, hd = q.shape
    scale = hd ** -0.5
    if S <= chunk:
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) * scale
        pos = jnp.arange(S)
        mask = _attn_scores_mask(pos, pos, window)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)

    assert S % chunk == 0, (S, chunk)
    nq = S // chunk
    kc = k.reshape(B, nq, chunk, Hkv, hd)
    vc = v.reshape(B, nq, chunk, Hkv, hd)
    pos = jnp.arange(chunk)

    def one_q_chunk(qi: int, q_blk: jnp.ndarray) -> jnp.ndarray:
        # causal range: kv chunks [lo, qi]; SWA trims lo to the window
        lo = 0 if window <= 0 else max(0, qi - (window + chunk - 1) // chunk)
        q_pos = qi * chunk + pos

        def kv_step(carry, xs):
            m, l, acc = carry
            k_blk, v_blk, kj = xs
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk
                           ).astype(jnp.float32) * scale
            k_pos = kj * chunk + pos
            mask = _attn_scores_mask(q_pos, k_pos, window)
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, chunk, hd), q.dtype)
        ks_ = jnp.moveaxis(kc[:, lo: qi + 1], 1, 0)
        vs_ = jnp.moveaxis(vc[:, lo: qi + 1], 1, 0)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                  (ks_, vs_, jnp.arange(lo, qi + 1)))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return jnp.moveaxis(out, 3, 1)                 # (B, chunk, Hkv, G, hd)

    qcs = q.reshape(B, nq, chunk, Hkv, G, hd)
    blocks = [jax.checkpoint(one_q_chunk, static_argnums=0)(i, qcs[:, i])
              for i in range(nq)]
    return jnp.concatenate(blocks, axis=1)


def multihead_attention(
    params: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,                         # (B, S, d)
    angles: jnp.ndarray,                    # (B, S, hd/2)
    *,
    kv_cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    cache_pos: Optional[jnp.ndarray] = None,     # scalar: tokens already cached
) -> Tuple[jnp.ndarray, Optional[Tuple[jnp.ndarray, jnp.ndarray]]]:
    """Full-sequence (train/prefill) or single-token cached (decode) attention.

    Decode: ``x`` is (B, 1, d); ``kv_cache`` = (k, v) each (B, W, Hkv, hd)
    where W is the cache window (ring-indexed when SWA is on). Returns the
    updated cache.
    """
    B, S, _ = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, H, hd)
    k = (x @ params["wk"]).reshape(B, S, Hkv, hd)
    v = (x @ params["wv"]).reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, angles)
    k = apply_rope(k, angles)
    scale = hd ** -0.5

    if kv_cache is None:
        # ---- train / prefill: chunked causal (+SWA) attention ---------------
        g = H // Hkv
        qh = q.reshape(B, S, Hkv, g, hd)
        out = _chunked_causal_attention(qh, k, v, cfg.sliding_window,
                                        chunk=min(cfg.attn_chunk, S))
        out = out.reshape(B, S, H * hd)
        new_cache = (k, v)
    else:
        # ---- decode: append one token to the (ring) cache ------------------
        ck, cv = kv_cache
        W = ck.shape[1]
        slot = (cache_pos % W).astype(jnp.int32)
        ck = lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
        cv = lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
        g = H // Hkv
        qh = q.reshape(B, 1, Hkv, g, hd)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qh, ck).astype(jnp.float32) * scale
        # valid cache entries: absolute position of slot i in the ring
        idx = jnp.arange(W)
        n_seen = cache_pos + 1                       # tokens seen incl. current
        if cfg.sliding_window > 0:
            abs_pos = jnp.where(idx <= slot, cache_pos - slot + idx,
                                cache_pos - slot + idx - W)
            valid = (abs_pos >= 0) & (abs_pos > cache_pos - cfg.sliding_window)
        else:
            valid = idx < n_seen
        logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, cv).reshape(B, 1, H * hd)
        new_cache = (ck, cv)

    return out @ params["wo"], new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def mlp_init(rng, cfg: ArchConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    p = {
        "w_up": dense_init(ks[1], (d, f), cfg.dtype),
        "w_down": dense_init(ks[2], (f, d), cfg.dtype,
                             scale=1.0 / (2 * cfg.num_layers) ** 0.5),
    }
    if cfg.gated_mlp:
        p["w_gate"] = dense_init(ks[0], (d, f), cfg.dtype)
    return p


def mlp(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    if "w_gate" in params:             # SwiGLU
        return (jax.nn.silu(x @ params["w_gate"])
                * (x @ params["w_up"])) @ params["w_down"]
    return jax.nn.gelu(x @ params["w_up"]) @ params["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based dispatch; EP-shardable over the expert axis)
# ---------------------------------------------------------------------------
def moe_init(rng, cfg: ArchConfig) -> Params:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(rng, 5)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, f), cfg.dtype),
        "w_up": dense_init(ks[2], (E, d, f), cfg.dtype),
        "w_down": dense_init(ks[3], (E, f, d), cfg.dtype,
                             scale=1.0 / (2 * cfg.num_layers) ** 0.5),
    }
    if m.num_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, d_ff=m.num_shared_experts * f)
    return p


def moe_capacity(m: MoEConfig, num_tokens: int) -> int:
    cap = int(num_tokens * m.top_k * m.capacity_factor / m.num_experts) + 1
    return max(8, -(-cap // 8) * 8)          # round up to 8 for TPU tiling


def _route(params: Params, m: MoEConfig, xt: jnp.ndarray,
           logits: Optional[jnp.ndarray] = None):
    """Router: (T, d) -> (gate (T,K) f32, expert (T,K) i32, aux loss terms).
    ``logits`` may be precomputed (EP path: expert-sharded router matmul +
    logit all-gather)."""
    T, E = xt.shape[0], m.num_experts
    if logits is None:
        logits = xt.astype(jnp.float32) @ params["router"]        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = lax.top_k(probs, m.top_k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)           # renormalize
    # load-balance terms (Switch): sums so they psum across shards cleanly
    p_sum = jnp.sum(probs, axis=0)                                # (E,)
    c_sum = jnp.zeros((E,), jnp.float32).at[expert.reshape(-1)].add(1.0)
    return gate, expert.astype(jnp.int32), p_sum, c_sum


def _fill_buffer(xt: jnp.ndarray, expert: jnp.ndarray, E: int, C: int):
    """Sort-based dispatch: rank tokens within their expert (stable argsort),
    scatter into an (E, C, d) capacity buffer (overflow drops, Switch-style).
    O(Tk log Tk) with no (T, E, C) one-hot. Returns (buffer, slot (T*K,))."""
    TK = expert.size
    d = xt.shape[-1]
    K = TK // xt.shape[0]
    flat_e = expert.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(TK, dtype=jnp.int32) - starts[flat_e[order]]
    pos = jnp.zeros((TK,), jnp.int32).at[order].set(pos_sorted)
    slot = jnp.where(pos < C, flat_e * C + pos, E * C)            # OOB -> drop
    x_rep = jnp.repeat(xt, K, axis=0)
    buf = jnp.zeros((E * C + 1, d), xt.dtype).at[slot].set(x_rep, mode="drop")
    return buf[: E * C].reshape(E, C, d), slot


def _expert_swiglu(h: jnp.ndarray, wg, wu, wd) -> jnp.ndarray:
    a = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, wg))
    b = jnp.einsum("ecd,edf->ecf", h, wu)
    return jnp.einsum("ecf,efd->ecd", a * b, wd)


def _combine(y: jnp.ndarray, slot: jnp.ndarray, gate: jnp.ndarray,
             T: int) -> jnp.ndarray:
    E_C, d = y.shape[0] * y.shape[1], y.shape[-1]
    K = slot.size // T
    y_flat = jnp.concatenate([y.reshape(E_C, d),
                              jnp.zeros((1, d), y.dtype)], axis=0)
    gathered = y_flat[jnp.minimum(slot, E_C)]                     # (T*K, d)
    weighted = gathered * gate.reshape(-1, 1).astype(y.dtype)
    return jnp.sum(weighted.reshape(T, K, d), axis=1)


def moe_ffn(params: Params, cfg: ArchConfig, x: jnp.ndarray
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k expert layer. x: (B, S, d) -> (out, aux_loss).

    With an active distribution context this is the explicit expert-parallel
    path (shard_map + all-to-all; see ``_moe_ffn_sharded``) — GSPMD cannot
    partition the data-dependent dispatch gathers without replicating them
    (measured: 51 TB/step collectives on kimi-k2, EXPERIMENTS.md §Perf).
    Without a mesh it is the same math locally.
    """
    from repro.models import dist
    ctx = dist.current()
    if ctx is not None:
        return _moe_ffn_sharded(params, cfg, x, ctx)

    m = cfg.moe
    B, S, d = x.shape
    T, E = B * S, m.num_experts
    xt = x.reshape(T, d)
    gate, expert, p_sum, c_sum = _route(params, m, xt)
    aux = (E * jnp.sum((p_sum / T) * (c_sum / (T * m.top_k)))
           * m.aux_loss_weight)
    C = moe_capacity(m, T)
    buf, slot = _fill_buffer(xt, expert, E, C)
    y = _expert_swiglu(buf, params["w_gate"], params["w_up"],
                       params["w_down"])
    out = _combine(y, slot, gate, T)
    if m.num_shared_experts:
        out = out + mlp(params["shared"], xt)
    return out.reshape(B, S, d), aux


def _moe_ffn_sharded(params: Params, cfg: ArchConfig, x: jnp.ndarray,
                     ctx) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE: tokens sharded over (batch x model) axes, experts
    over 'model', FSDP ZeRO-3 expert weights over 'data'.

    Per shard: route local tokens -> capacity buffer (E, C, d) -> all-to-all
    over 'model' (tokens travel to their experts' owners) -> expert SwiGLU ->
    all-to-all back -> weighted combine. With ``expert_inner_shard`` the
    expert FFN inner dim is 'data'-sharded (Megatron row/col) and the ZeRO-3
    all-gather is replaced by a psum of the expert outputs (§Perf iteration).
    """
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    mesh, bd, tp = ctx.mesh, ctx.batch_axes, ctx.tp_axis
    tp_n = mesh.shape[tp]
    nb = int(np.prod([mesh.shape[a] for a in bd]))
    B, S, d = x.shape
    E, K = m.num_experts, m.top_k

    B_loc = B // nb if B % nb == 0 else B
    x_bspec = bd if B % nb == 0 else None
    seq_sharded = ctx.seq_shard and S % tp_n == 0 and S // tp_n > 0
    S_loc = S // tp_n if seq_sharded else S
    T_loc = B_loc * S_loc
    if seq_sharded:
        sp_mode = "seq"
        T_tp = T_loc
    elif T_loc % tp_n == 0:
        sp_mode = "slice"
        T_tp = T_loc // tp_n
    else:
        sp_mode = "dup"                 # tiny-token decode: dup work, exact
        T_tp = T_loc
    C = moe_capacity(m, T_tp)
    E_loc = E // tp_n

    # NOTE (§Perf, refuted): 'expert_inner_shard' (Megatron row/col inside
    # each expert, f over 'data') is INVALID on this mesh — 'data' is also
    # the token-shard axis, so the output psum over 'data' would mix
    # different tokens' partial results. A correct version needs either a
    # dedicated mesh axis for the f-split or a token all-gather whose
    # traffic exceeds the ZeRO-3 weight gather it replaces. ZeRO-3 it is.
    zero3 = True
    w_specs = (P(tp, "data", None), P(tp, "data", None),
               P(tp, None, "data"))

    def body(xl, router, wg, wu, wd):
        Bq, Sq, _ = xl.shape
        xt = xl.reshape(Bq * Sq, d)
        if sp_mode == "slice":
            r = lax.axis_index(tp)
            xt = lax.dynamic_slice_in_dim(xt, r * T_tp, T_tp, axis=0)
        # router is expert-sharded (d, E/tp): local matmul, tiny logit gather
        loc_logits = xt.astype(jnp.float32) @ router          # (T_tp, E/tp)
        logits = lax.all_gather(loc_logits, tp, axis=1, tiled=True)
        gate, expert, p_sum, c_sum = _route({}, m, xt, logits=logits)
        T_tot = T_tp * (1 if sp_mode == "dup" else tp_n) * nb
        p_tot = lax.psum(lax.psum(p_sum, bd), tp) if sp_mode != "dup" \
            else lax.psum(p_sum, bd)
        c_tot = lax.psum(lax.psum(c_sum, bd), tp) if sp_mode != "dup" \
            else lax.psum(c_sum, bd)
        aux = (E * jnp.sum((p_tot / T_tot) * (c_tot / (T_tot * K)))
               * m.aux_loss_weight)

        buf, slot = _fill_buffer(xt, expert, E, C)        # (E, C, d)
        recv = lax.all_to_all(buf, tp, split_axis=0, concat_axis=1,
                              tiled=True)                 # (E_loc, C*tp, d)
        if zero3:
            wg_f = lax.all_gather(wg, "data", axis=1, tiled=True)
            wu_f = lax.all_gather(wu, "data", axis=1, tiled=True)
            wd_f = lax.all_gather(wd, "data", axis=2, tiled=True)
            h = _expert_swiglu(recv, wg_f, wu_f, wd_f)
        else:
            # inner-sharded: contraction over local f-slice, psum outputs
            h = _expert_swiglu(recv, wg, wu, wd)
            h = lax.psum(h, "data")
        back = lax.all_to_all(h, tp, split_axis=1, concat_axis=0,
                              tiled=True)                 # (E, C, d)
        y = _combine(back, slot, gate, T_tp)              # (T_tp, d)
        if sp_mode == "slice":
            y = lax.all_gather(y, tp, axis=0, tiled=True)
        return y.reshape(Bq, Sq, d).astype(xl.dtype), aux

    x_spec = P(x_bspec, tp if seq_sharded else None, None)
    out, aux = _shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, tp)) + w_specs,
        out_specs=(x_spec, P()),
        **_SHARD_MAP_KW,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])

    if m.num_shared_experts:
        out = out + mlp(params["shared"], x.reshape(B * S, d)
                        ).reshape(B, S, d)
    return out, aux
