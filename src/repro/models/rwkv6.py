"""RWKV6 ("Finch") block — attention-free mixer with data-dependent decay.

Structure per layer: time-mix (token-shift DDLerp -> r/k/v/g projections,
LoRA data-dependent per-channel decay, WKV outer-product recurrence with
bonus ``u``, per-head norm, gate, out-proj) then channel-mix (token-shift
squared-ReLU FFN with receptance gate). The WKV recurrence runs through the
shared chunked GLA core (``models.gla``) in RWKV semantics (strict-past mask
+ diagonal bonus).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import gla
from repro.models.blocks import dense_init, rmsnorm, rmsnorm_init

Params = Dict[str, jnp.ndarray]

_STREAMS = ("w", "k", "v", "r", "g")


def _hdims(cfg: ArchConfig) -> Tuple[int, int]:
    P = cfg.ssm.head_dim
    return cfg.d_model // P, P


def rwkv6_init(rng, cfg: ArchConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    H, P = _hdims(cfg)
    r = cfg.ssm.decay_lora
    ks = jax.random.split(rng, 10)
    p = {
        # --- time-mix ---------------------------------------------------------
        "maa_x": jnp.zeros((d,), cfg.dtype),
        "maa_base": jnp.zeros((5, d), cfg.dtype),
        "maa_w1": dense_init(ks[0], (d, 5 * r), cfg.dtype),
        "maa_w2": dense_init(ks[1], (5, r, d), cfg.dtype),
        "decay_base": jnp.asarray(                      # per-channel, in (-6,-1)
            -6.0 + 5.0 * (jnp.arange(d) / max(1, d - 1)) ** 0.7,
            jnp.float32),
        "decay_w1": dense_init(ks[2], (d, r), cfg.dtype),
        "decay_w2": dense_init(ks[3], (r, d), cfg.dtype),
        "faaaa": jnp.zeros((H, P), jnp.float32),        # bonus 'u'
        "wr": dense_init(ks[4], (d, d), cfg.dtype),
        "wk": dense_init(ks[5], (d, d), cfg.dtype),
        "wv": dense_init(ks[6], (d, d), cfg.dtype),
        "wg": dense_init(ks[7], (d, d), cfg.dtype),
        "wo": dense_init(ks[8], (d, d), cfg.dtype,
                         scale=1.0 / (2 * cfg.num_layers) ** 0.5),
        "ln_x": rmsnorm_init(d, cfg.dtype),             # per-head norm scale
        # --- channel-mix ------------------------------------------------------
        "cm_maa_k": jnp.zeros((d,), cfg.dtype),
        "cm_maa_r": jnp.zeros((d,), cfg.dtype),
        "cm_wk": dense_init(ks[9], (d, f), cfg.dtype),
        "cm_wv": dense_init(jax.random.fold_in(ks[9], 1), (f, d), cfg.dtype,
                            scale=1.0 / (2 * cfg.num_layers) ** 0.5),
        "cm_wr": dense_init(jax.random.fold_in(ks[9], 2), (d, d), cfg.dtype),
        # --- layer norms ------------------------------------------------------
        "ln1": rmsnorm_init(d, cfg.dtype),
        "ln2": rmsnorm_init(d, cfg.dtype),
    }
    return p


def _shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Token shift: value of the previous position. prev: (B, d) carry."""
    if x.shape[1] == 1 and prev is not None:
        return prev[:, None, :]
    shifted = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev.astype(x.dtype))
    return shifted


def _ddlerp(p: Params, x: jnp.ndarray, xs: jnp.ndarray):
    """Data-dependent interpolation of the five r/k/v/g/w input streams."""
    dx = xs - x
    base = x + dx * p["maa_x"]
    lora = jnp.tanh(base @ p["maa_w1"])                     # (B,S,5r)
    B, S, _ = x.shape
    lora = lora.reshape(B, S, 5, -1).transpose(2, 0, 1, 3)  # (5,B,S,r)
    mix = jnp.einsum("nbsr,nrd->nbsd", lora, p["maa_w2"]) + p["maa_base"][:, None, None]
    return tuple(x + dx * mix[i] for i in range(5))         # order: w,k,v,r,g


def _wkv_inputs(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                shift_prev: Optional[jnp.ndarray]):
    H, P = _hdims(cfg)
    B, S, d = x.shape
    xs = _shift(x, shift_prev)
    xw, xk, xv, xr, xg = _ddlerp(p, x, xs)
    r = (xr @ p["wr"]).reshape(B, S, H, P).transpose(0, 2, 1, 3)
    k = (xk @ p["wk"]).reshape(B, S, H, P).transpose(0, 2, 1, 3)
    v = (xv @ p["wv"]).reshape(B, S, H, P).transpose(0, 2, 1, 3)
    g = jax.nn.silu(xg @ p["wg"])
    logw = -jnp.exp(p["decay_base"]
                    + (jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]).astype(jnp.float32))
    logw = logw.reshape(B, S, H, P).transpose(0, 2, 1, 3)   # (B,H,S,P)
    return r, k, v, g, logw, x[:, -1, :]


def _time_mix_out(p: Params, cfg: ArchConfig, y: jnp.ndarray, g: jnp.ndarray,
                  B: int, S: int) -> jnp.ndarray:
    """Per-head normalization, gate, output projection. y: (B,H,S,P)."""
    H, P = _hdims(cfg)
    d = H * P
    y = y.transpose(0, 2, 1, 3).astype(jnp.float32)          # (B,S,H,P)
    mean2 = jnp.mean(y * y, axis=-1, keepdims=True)          # per-head RMS
    y = (y * jax.lax.rsqrt(mean2 + 64e-5)).reshape(B, S, d)
    y = (y * p["ln_x"]["scale"].astype(jnp.float32)).astype(g.dtype) * g
    return y @ p["wo"]


def _channel_mix(p: Params, x: jnp.ndarray, shift_prev: Optional[jnp.ndarray]):
    xs = _shift(x, shift_prev)
    dx = xs - x
    xk = x + dx * p["cm_maa_k"]
    xr = x + dx * p["cm_maa_r"]
    h = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    return jax.nn.sigmoid(xr @ p["cm_wr"]) * (h @ p["cm_wv"]), x[:, -1, :]


RwkvCache = Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]   # (shift_tm, shift_cm, state)


def rwkv6_block(params: Params, cfg: ArchConfig, x: jnp.ndarray,
                cache: Optional[RwkvCache] = None
                ) -> Tuple[jnp.ndarray, RwkvCache]:
    """Full RWKV6 layer (time-mix + channel-mix residual branches).

    Train/prefill: cache=None (or a carry when continuing). Decode: x is
    (B, 1, d) and cache is the (shift_tm, shift_cm, wkv_state) triple.
    """
    B, S, d = x.shape
    st_tm, st_cm, wkv = cache if cache is not None else (None, None, None)

    xn = rmsnorm(params["ln1"], x, cfg.norm_eps)
    r, k, v, g, logw, last_tm = _wkv_inputs(params, cfg, xn, st_tm)
    if S == 1 and wkv is not None:
        y, new_wkv = gla.gla_decode_step(
            r[:, :, 0], k[:, :, 0], v[:, :, 0], logw[:, :, 0], wkv,
            bonus=params["faaaa"])
        y = y[:, :, None, :]                                 # (B,H,1,P)
    else:
        y, new_wkv = gla.gla_chunked(r, k, v, logw, bonus=params["faaaa"],
                                     initial_state=wkv)
    x = x + _time_mix_out(params, cfg, y, g, B, S)

    xn2 = rmsnorm(params["ln2"], x, cfg.norm_eps)
    cm_out, last_cm = _channel_mix(params, xn2, st_cm)
    x = x + cm_out
    return x, (last_tm, last_cm, new_wkv)


def init_cache(cfg: ArchConfig, batch: int, dtype) -> RwkvCache:
    H, P = _hdims(cfg)
    d = cfg.d_model
    return (jnp.zeros((batch, d), dtype),
            jnp.zeros((batch, d), dtype),
            jnp.zeros((batch, H, P, P), jnp.float32))
