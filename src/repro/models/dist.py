"""Distribution context for model code.

Model functions are mesh-agnostic except where a layer *needs* an explicit
collective schedule (the expert-parallel MoE dispatch — GSPMD's handling of
data-dependent gathers across shardings degrades to full rematerialization,
which the kimi-k2 dry-run exposed at 51 TB/step of collective traffic).
The launcher installs a ``DistContext`` under ``with use(ctx):``; blocks
query ``current()`` and fall back to local math when inactive (smoke tests).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Optional, Tuple

import jax


@dataclass(frozen=True)
class DistContext:
    mesh: jax.sharding.Mesh
    batch_axes: Tuple[str, ...]        # ('pod','data') / ('data',)
    tp_axis: str = "model"
    seq_shard: bool = False
    # beyond-paper perf knob (§Perf): shard the expert FFN inner dim over
    # 'data' instead of ZeRO-3 all-gathering full expert weights
    expert_inner_shard: bool = False


_state = threading.local()


def current() -> Optional[DistContext]:
    return getattr(_state, "ctx", None)


def constrain_heads(x: "jax.Array") -> "jax.Array":
    """Shard a (B, H, S, D) head-major tensor P(batch, tp, None, None).

    Mamba2/RWKV6 parameters are FSDP-only, so without this hint GSPMD
    replicates their head-parallel intermediates over the model axis
    (measured: 258 GiB/device on zamba2 train_4k)."""
    ctx = current()
    if ctx is None or x.ndim < 2:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    nb = 1
    for a in ctx.batch_axes:
        nb *= ctx.mesh.shape[a]
    tp_n = ctx.mesh.shape[ctx.tp_axis]
    spec = [None] * x.ndim
    if x.shape[0] % nb == 0 and x.shape[0] >= nb:
        spec[0] = ctx.batch_axes
    if x.shape[1] % tp_n == 0 and x.shape[1] >= tp_n:
        spec[1] = ctx.tp_axis
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, jax.sharding.PartitionSpec(*spec)))


@contextlib.contextmanager
def use(ctx: Optional[DistContext]):
    prev = current()
    _state.ctx = ctx
    try:
        yield
    finally:
        _state.ctx = prev
