"""Full language model: embedding -> scanned block stack -> head, for every
assigned architecture family.

Wiring modes (chosen from the config's block pattern):

* ``uniform``       — all layers one kind; single ``lax.scan`` over stacked params.
* ``hybrid_shared`` — zamba2: groups of Mamba2 layers with a *shared-weight*
                      attention block applied after each group.
* ``prefix_dense``  — kimi-k2: a leading dense layer, then a scanned MoE stack.

Params are nested dicts; layer stacks are stacked pytrees scanned with
``jax.lax.scan`` so HLO size is O(1) in depth. ``remat='block'`` checkpoints
each scanned body. ``constrain`` is an optional residual-stream sharding hook
installed by the train-step builder (Megatron-style sequence sharding).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import blocks as B
from repro.models import mamba2, rwkv6

Params = Dict[str, Any]
Batch = Dict[str, jnp.ndarray]
Identity = lambda x: x  # noqa: E731


# ---------------------------------------------------------------------------
# wiring
# ---------------------------------------------------------------------------
def wiring_mode(cfg: ArchConfig) -> str:
    if "shared_attn" in cfg.block_pattern:
        return "hybrid_shared"
    if cfg.first_k_dense > 0:
        return "prefix_dense"
    assert len(set(cfg.block_pattern)) == 1, cfg.block_pattern
    return "uniform"


def _group_shape(cfg: ArchConfig) -> Tuple[int, int]:
    """hybrid_shared: (n_groups, mamba_per_group)."""
    per = sum(1 for k in cfg.block_pattern if k == "mamba")
    n_groups = cfg.num_layers // len(cfg.block_pattern)
    return n_groups, per


# ---------------------------------------------------------------------------
# per-kind block init / apply
# ---------------------------------------------------------------------------
def _attn_block_init(rng, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": B.rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": B.attention_init(k1, cfg),
        "ln2": B.rmsnorm_init(cfg.d_model, cfg.dtype),
        "mlp": B.mlp_init(k2, cfg),
    }


def _moe_block_init(rng, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": B.rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": B.attention_init(k1, cfg),
        "ln2": B.rmsnorm_init(cfg.d_model, cfg.dtype),
        "moe": B.moe_init(k2, cfg),
    }


def _mamba_block_init(rng, cfg: ArchConfig) -> Params:
    return {
        "ln": B.rmsnorm_init(cfg.d_model, cfg.dtype),
        "mixer": mamba2.mamba2_init(rng, cfg),
    }


BLOCK_INIT = {
    "attn": _attn_block_init,
    "shared_attn": _attn_block_init,
    "moe": _moe_block_init,
    "mamba": _mamba_block_init,
    "rwkv": rwkv6.rwkv6_init,
}


def apply_block(kind: str, params: Params, cfg: ArchConfig, x: jnp.ndarray,
                angles: jnp.ndarray, cache: Any, cache_pos,
                constrain: Callable = Identity):
    """Returns (x, new_cache, aux_loss). cache=None -> train path (no cache out
    is consumed); still returns prefill-style cache pieces."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "shared_attn", "moe"):
        h, new_kv = B.multihead_attention(
            params["attn"], cfg, B.rmsnorm(params["ln1"], x, cfg.norm_eps),
            angles, kv_cache=cache, cache_pos=cache_pos)
        x = constrain(x + h)
        h2 = B.rmsnorm(params["ln2"], x, cfg.norm_eps)
        if kind == "moe":
            mo, aux = B.moe_ffn(params["moe"], cfg, h2)
            x = constrain(x + mo)
        else:
            x = constrain(x + B.mlp(params["mlp"], h2))
        return x, new_kv, aux
    if kind == "mamba":
        xn = B.rmsnorm(params["ln"], x, cfg.norm_eps)
        if cache is None:
            h, new_c = mamba2.mamba2_forward(params["mixer"], cfg, xn)
        else:
            h, new_c = mamba2.mamba2_decode(params["mixer"], cfg, xn, cache)
        return constrain(x + h), new_c, aux
    if kind == "rwkv":
        x, new_c = rwkv6.rwkv6_block(params, cfg, x, cache)
        return constrain(x), new_c, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------
def init_params(cfg: ArchConfig, rng) -> Params:
    mode = wiring_mode(cfg)
    k_embed, k_head, k_blocks, k_extra = jax.random.split(rng, 4)
    p: Params = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model),
                                    jnp.float32)
                  * cfg.d_model ** -0.5).astype(cfg.dtype),
        "final_ln": B.rmsnorm_init(cfg.d_model, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = B.dense_init(k_head, (cfg.d_model, cfg.vocab_size), cfg.dtype)

    def stacked(kind: str, n: int, key) -> Params:
        return jax.vmap(lambda k: BLOCK_INIT[kind](k, cfg))(jax.random.split(key, n))

    if mode == "uniform":
        kind = cfg.block_pattern[0]
        p["blocks"] = stacked(kind, cfg.num_layers, k_blocks)
    elif mode == "prefix_dense":
        p["dense0"] = _attn_block_init(k_extra, cfg)
        p["blocks"] = stacked("moe", cfg.num_layers - cfg.first_k_dense, k_blocks)
    else:  # hybrid_shared
        n_groups, per = _group_shape(cfg)
        flat = stacked("mamba", n_groups * per, k_blocks)
        p["mamba"] = jax.tree.map(
            lambda a: a.reshape(n_groups, per, *a.shape[1:]), flat)
        p["shared_attn"] = _attn_block_init(k_extra, cfg)
    return p


def param_count(cfg: ArchConfig) -> int:
    """Exact parameter count via shape-only tracing (no allocation)."""
    import math
    spec = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    return sum(math.prod(l.shape) for l in jax.tree.leaves(spec))


# ---------------------------------------------------------------------------
# embedding / positions
# ---------------------------------------------------------------------------
def _positions(cfg: ArchConfig, batch: Batch, Bsz: int, S: int,
               offset=0) -> jnp.ndarray:
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.arange(S)[None, :] + offset                 # (B, S) broadcastable
    pos = jnp.broadcast_to(pos, (Bsz, S))
    if cfg.mrope:
        return jnp.broadcast_to(pos[None], (3, Bsz, S))   # stub: t=h=w stream
    return pos


def _embed(cfg: ArchConfig, params: Params, batch: Batch) -> jnp.ndarray:
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.frontend_prefix and "prefix_embeds" in batch:
        pe = batch["prefix_embeds"].astype(x.dtype)       # (B, P, d) stub frontend
        x = lax.dynamic_update_slice(x, pe, (0, 0, 0))
    return x


def _head(cfg: ArchConfig, params: Params, x: jnp.ndarray,
          constrain_logits: Callable = Identity) -> jnp.ndarray:
    x = B.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return constrain_logits(x @ w)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def _maybe_remat(cfg: ArchConfig, fn: Callable) -> Callable:
    return jax.checkpoint(fn) if cfg.remat in ("block", "full") else fn


def forward(params: Params, cfg: ArchConfig, batch: Batch, *,
            constrain: Callable = Identity, want_cache: bool = False,
            cache_len: int = 0):
    """Full-sequence forward. Returns (hidden, aux_loss, cache-or-None).

    ``want_cache`` (prefill): also build the decode cache with capacity
    ``cache_len`` (>= S; SWA archs use min(cache_len, window))."""
    mode = wiring_mode(cfg)
    Bsz, S = batch["tokens"].shape
    x = constrain(_embed(cfg, params, batch))
    angles = (B.rope_angles(cfg, _positions(cfg, batch, Bsz, S))
              if not cfg.attention_free else jnp.zeros((Bsz, S, 1)))
    aux_total = jnp.zeros((), jnp.float32)
    cache = {"pos": jnp.asarray(S, jnp.int32)} if want_cache else None

    def ring_kv(kv: jnp.ndarray, W: int) -> jnp.ndarray:
        """Arrange prefill K/V (B,S,...) into the decode ring layout (B,W,...)."""
        if S <= W:
            pad = [(0, 0)] * kv.ndim
            pad[1] = (0, W - S)
            return jnp.pad(kv, pad)
        s_idx = jnp.arange(W)
        src = S - 1 - ((S - 1 - s_idx) % W)
        return jnp.take(kv, src, axis=1)

    kv_W = (min(cache_len, cfg.sliding_window) if cfg.sliding_window > 0
            else cache_len)

    if mode == "uniform":
        kind = cfg.block_pattern[0]

        def body(carry, layer_params):
            x, aux = carry
            x, c, a = apply_block(kind, layer_params, cfg, x, angles, None,
                                  None, constrain)
            return (x, aux + a), (c if want_cache else 0)

        (x, aux_total), caches = lax.scan(
            _maybe_remat(cfg, body), (x, aux_total), params["blocks"])
        if want_cache:
            cache[kind] = _pack_cache(kind, caches, ring_kv, kv_W)
    elif mode == "prefix_dense":
        x, c0, a0 = apply_block("attn", params["dense0"], cfg, x, angles,
                                None, None, constrain)
        aux_total += a0

        def body(carry, layer_params):
            x, aux = carry
            x, c, a = apply_block("moe", layer_params, cfg, x, angles, None,
                                  None, constrain)
            return (x, aux + a), (c if want_cache else 0)

        (x, aux_total), caches = lax.scan(
            _maybe_remat(cfg, body), (x, aux_total), params["blocks"])
        if want_cache:
            cache["dense0"] = _pack_cache(
                "attn", jax.tree.map(lambda a: a[None], c0), ring_kv, kv_W)
            cache["moe"] = _pack_cache("moe", caches, ring_kv, kv_W)
    else:  # hybrid_shared
        n_groups, per = _group_shape(cfg)

        def group_body(carry, group_params):
            x, aux = carry

            def inner(carry2, lp):
                x2, aux2 = carry2
                x2, c, a = apply_block("mamba", lp, cfg, x2, angles, None,
                                       None, constrain)
                return (x2, aux2 + a), (c if want_cache else 0)

            (x, aux), m_caches = lax.scan(inner, (x, aux), group_params)
            x, a_cache, a = apply_block("shared_attn", params["shared_attn"],
                                        cfg, x, angles, None, None, constrain)
            return (x, aux + a), ((m_caches, a_cache) if want_cache else 0)

        (x, aux_total), caches = lax.scan(
            _maybe_remat(cfg, group_body), (x, aux_total), params["mamba"])
        if want_cache:
            m_caches, a_caches = caches
            # mamba caches come out (n_groups, per, ...) -> flatten layer axes
            m_flat = jax.tree.map(
                lambda a: a.reshape(n_groups * per, *a.shape[2:]), m_caches)
            cache["mamba"] = m_flat
            cache["shared_attn"] = _pack_cache("shared_attn", a_caches,
                                               ring_kv, kv_W)
    return x, aux_total, cache


def _pack_cache(kind: str, caches, ring_kv: Callable, kv_W: int):
    if kind in ("attn", "shared_attn", "moe"):
        k, v = caches
        return {"k": jax.vmap(lambda a: ring_kv(a, kv_W))(k)
                if k.ndim == 5 else ring_kv(k, kv_W),
                "v": jax.vmap(lambda a: ring_kv(a, kv_W))(v)
                if v.ndim == 5 else ring_kv(v, kv_W)}
    return caches


# ---------------------------------------------------------------------------
# decode (one token)
# ---------------------------------------------------------------------------
def decode_step(params: Params, cfg: ArchConfig, token: jnp.ndarray,
                cache: Dict[str, Any], *, constrain: Callable = Identity):
    """token: (B, 1) int32. Returns (logits (B, V), new_cache)."""
    mode = wiring_mode(cfg)
    Bsz = token.shape[0]
    pos = cache["pos"]
    x = jnp.take(params["embed"], token, axis=0)
    if not cfg.attention_free:
        positions = jnp.broadcast_to(jnp.asarray(pos)[None, None], (Bsz, 1))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[None], (3, Bsz, 1))
        angles = B.rope_angles(cfg, positions)
    else:
        angles = jnp.zeros((Bsz, 1, 1))
    new_cache = {"pos": pos + 1}

    if mode == "uniform":
        kind = cfg.block_pattern[0]

        def body(x, xs):
            layer_params, layer_cache = xs
            x, c, _ = apply_block(kind, layer_params, cfg, x, angles,
                                  _unpack(kind, layer_cache), pos, constrain)
            return x, _repack(kind, c)

        x, new_lc = lax.scan(body, x, (params["blocks"], cache[kind]))
        new_cache[kind] = new_lc
    elif mode == "prefix_dense":
        x, c0, _ = apply_block("attn", params["dense0"], cfg, x, angles,
                               _unpack("attn", jax.tree.map(lambda a: a[0],
                                                            cache["dense0"])),
                               pos, constrain)
        new_cache["dense0"] = jax.tree.map(lambda a: a[None], _repack("attn", c0))

        def body(x, xs):
            layer_params, layer_cache = xs
            x, c, _ = apply_block("moe", layer_params, cfg, x, angles,
                                  _unpack("moe", layer_cache), pos, constrain)
            return x, _repack("moe", c)

        x, new_lc = lax.scan(body, x, (params["blocks"], cache["moe"]))
        new_cache["moe"] = new_lc
    else:  # hybrid_shared
        n_groups, per = _group_shape(cfg)
        m_cache = jax.tree.map(
            lambda a: a.reshape(n_groups, per, *a.shape[1:]), cache["mamba"])

        def group_body(x, xs):
            group_params, g_mcache, g_acache = xs

            def inner(x2, xs2):
                lp, lc = xs2
                x2, c, _ = apply_block("mamba", lp, cfg, x2, angles, lc, pos,
                                       constrain)
                return x2, c

            x, new_mc = lax.scan(inner, x, (group_params, g_mcache))
            x, ac, _ = apply_block("shared_attn", params["shared_attn"], cfg,
                                   x, angles, _unpack("attn", g_acache), pos,
                                   constrain)
            return x, (new_mc, _repack("attn", ac))

        x, (new_mc, new_ac) = lax.scan(
            group_body, x, (params["mamba"], m_cache, cache["shared_attn"]))
        new_cache["mamba"] = jax.tree.map(
            lambda a: a.reshape(n_groups * per, *a.shape[2:]), new_mc)
        new_cache["shared_attn"] = new_ac

    logits = _head(cfg, params, x)[:, 0]                  # (B, V)
    return logits, new_cache


def _unpack(kind: str, layer_cache):
    if kind in ("attn", "shared_attn", "moe"):
        return (layer_cache["k"], layer_cache["v"])
    return layer_cache


def _repack(kind: str, c):
    if kind in ("attn", "shared_attn", "moe"):
        return {"k": c[0], "v": c[1]}
    return c


# ---------------------------------------------------------------------------
# cache init (decode from scratch, e.g. dry-run serve_step input specs)
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    mode = wiring_mode(cfg)
    W = (min(cache_len, cfg.sliding_window) if cfg.sliding_window > 0
         else cache_len)
    hd, Hkv = cfg.head_dim, cfg.num_kv_heads
    kv = lambda n: {"k": jnp.zeros((n, batch, W, Hkv, hd), cfg.dtype),
                    "v": jnp.zeros((n, batch, W, Hkv, hd), cfg.dtype)}
    cache: Dict[str, Any] = {"pos": jnp.asarray(0, jnp.int32)}
    if mode == "uniform":
        kind = cfg.block_pattern[0]
        if kind in ("attn", "moe"):
            cache[kind] = kv(cfg.num_layers)
        elif kind == "mamba":
            cache["mamba"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)),
                mamba2.init_cache(cfg, batch, cfg.dtype))
        else:
            cache["rwkv"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)),
                rwkv6.init_cache(cfg, batch, cfg.dtype))
    elif mode == "prefix_dense":
        cache["dense0"] = kv(1)
        cache["moe"] = kv(cfg.num_layers - cfg.first_k_dense)
    else:
        n_groups, per = _group_shape(cfg)
        cache["mamba"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups * per, *a.shape)),
            mamba2.init_cache(cfg, batch, cfg.dtype))
        cache["shared_attn"] = kv(n_groups)
    return jax.tree.map(jnp.asarray, cache)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def lm_loss(params: Params, cfg: ArchConfig, batch: Batch, *,
            constrain: Callable = Identity,
            constrain_logits: Callable = Identity):
    """Next-token cross entropy (+ z-loss + MoE aux). Returns (loss, metrics)."""
    x, aux, _ = forward(params, cfg, batch, constrain=constrain)
    logits = _head(cfg, params, x, constrain_logits)      # (B, S, V)
    targets = batch["targets"]
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)

    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll) / denom
    zl = cfg.z_loss * jnp.sum(jnp.square(logz) * mask) / denom
    loss = ce + zl + aux
    return loss, {"ce": ce, "z_loss": zl, "aux_loss": aux,
                  "tokens": jnp.sum(mask)}
