"""Mamba2 (SSD) block — zamba2's backbone mixer.

Faithful to the Mamba2 structure: fused in-projection -> short causal
depthwise conv over (x, B, C) -> SSD scan (chunked via ``models.gla``) ->
gated RMSNorm -> out-projection. Per-head scalar decay a_t = exp(dt_t * A_h).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import gla
from repro.models.blocks import dense_init, rmsnorm, rmsnorm_init

Params = Dict[str, jnp.ndarray]


def dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.state_dim
    return d_in, nheads, s.state_dim, conv_ch


def mamba2_init(rng, cfg: ArchConfig) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    d_in, H, N, conv_ch = dims(cfg)
    ks = jax.random.split(rng, 4)
    proj_out = 2 * d_in + 2 * N + H          # [z, xBC..., dt]
    return {
        "in_proj": dense_init(ks[0], (d, proj_out), cfg.dtype),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_ch), cfg.dtype, scale=2.0),
        "conv_b": jnp.zeros((conv_ch,), cfg.dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(                       # softplus^-1 of dt
            jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "norm": rmsnorm_init(d_in, cfg.dtype),
        "out_proj": dense_init(ks[3], (d_in, d), cfg.dtype,
                               scale=1.0 / (2 * cfg.num_layers) ** 0.5),
    }


def _split_proj(cfg: ArchConfig, proj: jnp.ndarray):
    d_in, H, N, _ = dims(cfg)
    z = proj[..., :d_in]
    xBC = proj[..., d_in: 2 * d_in + 2 * N]
    dt = proj[..., 2 * d_in + 2 * N:]
    return z, xBC, dt


def _causal_depthwise_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                           prev: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Width-W causal depthwise conv via shifted adds (width is 4: cheaper and
    simpler than lax.conv at these widths). ``prev``: (B, W-1, C) carry for
    decode continuation."""
    W = w.shape[0]
    if prev is not None:
        xBC = jnp.concatenate([prev.astype(xBC.dtype), xBC], axis=1)
    pad = W - 1 if prev is None else 0
    xp = jnp.pad(xBC, ((0, 0), (pad, 0), (0, 0)))
    S_out = xBC.shape[1] - (0 if prev is None else W - 1)
    out = sum(xp[:, i: i + S_out] * w[i] for i in range(W))
    return out + b


def _ssd_inputs(params: Params, cfg: ArchConfig, xBC: jnp.ndarray,
                dt_raw: jnp.ndarray):
    """Conv'd xBC + raw dt -> (q, k, v, log_decay, x_heads, dt) for the GLA core."""
    d_in, H, N, _ = dims(cfg)
    P = cfg.ssm.head_dim
    xBC = jax.nn.silu(xBC)
    x = xBC[..., :d_in]
    Bm = xBC[..., d_in: d_in + N]
    Cm = xBC[..., d_in + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (..., H)
    A = -jnp.exp(params["A_log"])                                          # (H,)

    # heads: x (..., H, P); B/C shared across heads (n_groups=1)
    xh = x.reshape(*x.shape[:-1], H, P)
    v = xh * dt[..., None].astype(xh.dtype)
    log_decay = dt * A                                                     # (..., H)
    return Cm, Bm, v, log_decay, xh, dt


def mamba2_forward(params: Params, cfg: ArchConfig, x: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence forward. Returns (y, (conv_state, ssd_state)) so prefill
    can hand off to decode."""
    B, S, _ = x.shape
    d_in, H, N, _ = dims(cfg)
    Wc = cfg.ssm.conv_width
    z, xBC_raw, dt_raw = _split_proj(cfg, x @ params["in_proj"])
    xBC = _causal_depthwise_conv(xBC_raw, params["conv_w"], params["conv_b"])
    q, k, v, logw, xh, _ = _ssd_inputs(params, cfg, xBC, dt_raw)

    # GLA layout: (B, H, S, D*). B/C shared across heads -> broadcast.
    qh = jnp.broadcast_to(q[:, None], (B, H, S, N))
    kh = jnp.broadcast_to(k[:, None], (B, H, S, N))
    vh = v.transpose(0, 2, 1, 3)                       # (B,H,S,P)
    lw = jnp.broadcast_to(logw.transpose(0, 2, 1)[..., None], (B, H, S, N))
    y, state = gla.gla_chunked(qh, kh, vh, lw)
    y = y + params["D"][None, :, None, None] * xh.transpose(0, 2, 1, 3)  # D*x skip
    y = y.transpose(0, 2, 1, 3).reshape(B, S, d_in).astype(x.dtype)

    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    conv_state = xBC_raw[:, -(Wc - 1):, :]             # pre-activation carry
    return y @ params["out_proj"], (conv_state, state.astype(jnp.float32))


def mamba2_decode(params: Params, cfg: ArchConfig, x: jnp.ndarray,
                  cache: Tuple[jnp.ndarray, jnp.ndarray]
                  ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Single-token step. x: (B, 1, d); cache = (conv_state, ssd_state)."""
    conv_state, ssd_state = cache
    B = x.shape[0]
    d_in, H, N, _ = dims(cfg)
    z, xBC_raw, dt_raw = _split_proj(cfg, x @ params["in_proj"])
    xBC = _causal_depthwise_conv(xBC_raw, params["conv_w"], params["conv_b"],
                                 prev=conv_state)
    new_conv = jnp.concatenate([conv_state[:, 1:], xBC_raw], axis=1)
    q, k, v, logw, xh, _ = _ssd_inputs(params, cfg, xBC, dt_raw)

    qh = jnp.broadcast_to(q[:, 0, None, :], (B, H, N))
    kh = jnp.broadcast_to(k[:, 0, None, :], (B, H, N))
    vh = v[:, 0]                                       # (B,H,P)
    lw = jnp.broadcast_to(logw[:, 0, :, None], (B, H, N))
    y, new_state = gla.gla_decode_step(qh, kh, vh, lw, ssd_state)
    y = y + params["D"][None, :, None] * xh[:, 0]
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ params["out_proj"], (new_conv, new_state)


def init_cache(cfg: ArchConfig, batch: int, dtype) -> Tuple[jnp.ndarray, jnp.ndarray]:
    d_in, H, N, conv_ch = dims(cfg)
    P = cfg.ssm.head_dim
    return (jnp.zeros((batch, cfg.ssm.conv_width - 1, conv_ch), dtype),
            jnp.zeros((batch, H, N, P), jnp.float32))
