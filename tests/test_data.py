"""Data pipeline: determinism, resumability, shape/domain invariants."""
import numpy as np
from _hypothesis_compat import given, st

from repro.configs import get_config
from repro.data import SyntheticCorpus


def _corpus(seed=0):
    cfg = get_config("internlm2_1p8b").smoke()
    return SyntheticCorpus(cfg, batch=4, seq=32, seed=seed)


@given(step=st.integers(0, 10_000))
def test_batch_pure_function_of_step(step):
    a = _corpus().batch_at(step)
    b = _corpus().batch_at(step)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


@given(s1=st.integers(0, 500), s2=st.integers(0, 500))
def test_distinct_steps_differ(s1, s2):
    if s1 == s2:
        return
    a = _corpus().batch_at(s1)
    b = _corpus().batch_at(s2)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_targets_are_next_tokens_domain():
    cfg = get_config("internlm2_1p8b").smoke()
    b = _corpus().batch_at(3)
    assert b["tokens"].shape == (4, 32)
    assert b["targets"].shape == (4, 32)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < cfg.vocab_size


def test_shards_are_disjoint_streams():
    cfg = get_config("internlm2_1p8b").smoke()
    a = SyntheticCorpus(cfg, 2, 32, seed=0, shard=0, num_shards=2).batch_at(5)
    b = SyntheticCorpus(cfg, 2, 32, seed=0, shard=1, num_shards=2).batch_at(5)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_stream_resume_matches_fresh():
    """Restart-at-step-k (fault tolerance) yields the same batches."""
    c = _corpus()
    fresh = [c.batch_at(k) for k in range(8)]
    resumed = [c.batch_at(k) for k in range(4, 8)]
    for a, b in zip(fresh[4:], resumed):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
