"""Data pipeline: determinism, resumability, shape/domain invariants."""
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.configs import get_config
from repro.data import SyntheticCorpus, correlated_tenant_load, \
    heavy_tail_load


def _corpus(seed=0):
    cfg = get_config("internlm2_1p8b").smoke()
    return SyntheticCorpus(cfg, batch=4, seq=32, seed=seed)


@given(step=st.integers(0, 10_000))
def test_batch_pure_function_of_step(step):
    a = _corpus().batch_at(step)
    b = _corpus().batch_at(step)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


@given(s1=st.integers(0, 500), s2=st.integers(0, 500))
def test_distinct_steps_differ(s1, s2):
    if s1 == s2:
        return
    a = _corpus().batch_at(s1)
    b = _corpus().batch_at(s2)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_targets_are_next_tokens_domain():
    cfg = get_config("internlm2_1p8b").smoke()
    b = _corpus().batch_at(3)
    assert b["tokens"].shape == (4, 32)
    assert b["targets"].shape == (4, 32)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < cfg.vocab_size


def test_shards_are_disjoint_streams():
    cfg = get_config("internlm2_1p8b").smoke()
    a = SyntheticCorpus(cfg, 2, 32, seed=0, shard=0, num_shards=2).batch_at(5)
    b = SyntheticCorpus(cfg, 2, 32, seed=0, shard=1, num_shards=2).batch_at(5)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_stream_resume_matches_fresh():
    """Restart-at-step-k (fault tolerance) yields the same batches."""
    c = _corpus()
    fresh = [c.batch_at(k) for k in range(8)]
    resumed = [c.batch_at(k) for k in range(4, 8)]
    for a, b in zip(fresh[4:], resumed):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


# -- fleet-telemetry load generators ---------------------------------------
@pytest.mark.parametrize("gen", [heavy_tail_load, correlated_tenant_load])
def test_load_generators_deterministic_and_bounded(gen):
    a = gen(23, 100, seed=5)
    b = gen(23, 100, seed=5)
    np.testing.assert_array_equal(a, b)         # pure function of the args
    assert a.shape == (23, 100, 6)              # DEFAULT_FIELDS order
    assert np.isfinite(a).all() and (a >= 0).all()
    assert not np.array_equal(a, gen(23, 100, seed=6))


def test_heavy_tail_bursts_dominate():
    """Pareto bursts must produce dirty-rate spikes far beyond the cyclic
    base signal (the heavy tail is the point of the generator)."""
    a = heavy_tail_load(64, 512, seed=0)
    dr = a[..., 1]                              # dirty_bytes column
    assert dr.max() > 4 * np.quantile(dr, 0.99)
    # the un-burst majority still looks like the plain square wave
    assert np.quantile(dr, 0.5) < 1e9


def test_correlated_tenants_share_cycles():
    """With rho=1 and tiny noise, same-tenant jobs are near-identical while
    cross-tenant pairs decorrelate — the load is genuinely cohorted."""
    a = correlated_tenant_load(16, 256, n_tenants=2, rho=1.0, seed=1,
                               jitter=0.01)
    C = np.corrcoef(a[..., 4])                  # compute_util rows
    off = C[np.triu_indices(16, 1)]
    assert (off > 0.9).sum() >= 30              # within-tenant pairs
    assert (off < 0.5).sum() >= 30              # cross-tenant pairs


def test_correlated_rho_zero_is_idiosyncratic():
    a = correlated_tenant_load(12, 256, n_tenants=2, rho=0.0, seed=2,
                               jitter=0.0)
    C = np.corrcoef(a[..., 4])
    off = C[np.triu_indices(12, 1)]
    assert (off > 0.95).sum() <= 2              # no cohort structure
