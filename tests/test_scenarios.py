"""Fault-injection scenario layer: abort/retry conservation, FaultPlan
semantics, event-skip bit-identity under faults, and the guards around
an emptied plane.

The load-bearing contracts:

* partial bytes of an aborted lane are billed exactly once — per-link
  byte counters equal (abort partials @ abort-time path) + (completed
  bytes @ final path), even when retries re-route;
* a non-empty FaultPlan run is bit-identical between ``event_skip=True``
  and ``False`` (faults are first-class event boundaries);
* an EMPTY FaultPlan is indistinguishable from no plan at all;
* mass abort leaves a consistent, advanceable (no-op) plane and keeps
  every solver finite at zero capacity.
"""
from __future__ import annotations

from collections import defaultdict

import numpy as np
import pytest

from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

from repro.core import network, strunk
from repro.core.fabric import ShardedPlane
from repro.core.orchestrator import LMCM, MigrationRequest
from repro.core.rates import PiecewiseRate
from repro.scenarios.faults import FaultEvent, FaultPlan
from repro.scenarios.fleet import build_fleet, evacuation_plan, \
    percentiles, sla_violations
from repro.scenarios.suite import SCENARIOS


# ---------------------------------------------------------------------------
# FaultPlan semantics
# ---------------------------------------------------------------------------
def test_fault_plan_sorted_stable_and_falsy():
    assert not FaultPlan()
    assert len(FaultPlan()) == 0
    p = FaultPlan([FaultEvent(5.0, "host_fail", "b"),
                   FaultEvent(1.0, "host_fail", "a"),
                   FaultEvent(5.0, "host_recover", "a")])
    assert [e.t for e in p] == [1.0, 5.0, 5.0]
    # stable: same-instant events keep authored order
    assert [e.target for e in p if e.t == 5.0] == ["b", "a"]
    assert p


def test_fault_event_rejects_unknown_kind():
    with pytest.raises(ValueError):
        FaultEvent(0.0, "meteor_strike", "h0")


def test_fault_plan_builders_and_shift():
    p = FaultPlan.host_failure(10.0, "h0", recover_at=60.0)
    assert [(e.t, e.kind) for e in p] == [(10.0, "host_fail"),
                                          (60.0, "host_recover")]
    b = FaultPlan.link_brownout(5.0, "core", 1e6, restore_at=9.0,
                                restore_capacity=1e9)
    assert [(e.kind, e.capacity) for e in b] == [("link_degrade", 1e6),
                                                 ("link_restore", 1e9)]
    with pytest.raises(ValueError):
        FaultPlan.link_brownout(5.0, "core", 1e6, restore_at=9.0)
    s = p.shifted(100.0)
    assert [e.t for e in s] == [110.0, 160.0]
    r1 = FaultPlan.random(["a", "b"], {"l": 1e9}, horizon_s=100.0, seed=3)
    r2 = FaultPlan.random(["a", "b"], {"l": 1e9}, horizon_s=100.0, seed=3)
    assert [(e.t, e.kind, e.target) for e in r1] \
        == [(e.t, e.kind, e.target) for e in r2]


# ---------------------------------------------------------------------------
# plane abort: partial-bytes accounting + emptied-plane guards
# ---------------------------------------------------------------------------
def _flat_rate(v: float) -> PiecewiseRate:
    return PiecewiseRate(np.array([1e12]), np.array([v]))


def _launch(plane, job_id, src, dst, v_bytes=1e9, t=0.0, rate=1e6):
    req = MigrationRequest(job_id, created_at=t, v_bytes=v_bytes,
                           src=src, dst=dst)
    req.path = plane.topology.path(src, dst)
    plane.launch(req, _flat_rate(rate), t, path=req.path)
    return req


def test_abort_partial_bytes_match_link_charges():
    topo = network.Topology.star(["a", "b", "c"], 100e6,
                                 core_capacity=300e6)
    plane = ShardedPlane(topo)
    _launch(plane, "j0", "a", "b")
    _launch(plane, "j1", "c", "b")
    plane.advance(5.0)
    assert plane.in_flight == 2
    before = dict(plane.link_bytes)
    aborted = plane.fail_host("a")
    assert [r.job_id for r, _ in aborted] == ["j0"]
    _, out = aborted[0]
    assert out.stop_reason == strunk.STOP_ABORTED
    assert out.stop_reason not in strunk.STOP_REASONS
    assert out.bytes_sent > 0.0
    # settled partial bytes == exactly what j0's private access link was
    # charged chunk-by-chunk before the crash; the abort itself settles,
    # it never re-bills a link
    assert out.bytes_sent == pytest.approx(plane.link_bytes["acc:a"])
    assert plane.link_bytes == before
    # the survivor keeps running and completes
    assert plane.in_flight == 1
    done = []
    t = 5.0
    while plane.in_flight and t < 500.0:
        t += 1.0
        done += plane.advance(t)
    assert [r.job_id for r, _ in done] == ["j1"]


def test_mass_abort_leaves_clean_noop_plane():
    topo = network.Topology.star(["a", "b", "c"], 100e6)
    plane = ShardedPlane(topo)
    _launch(plane, "j0", "a", "b")
    _launch(plane, "j1", "b", "c")
    plane.advance(2.0)
    out = plane.fail_host("b")          # endpoint of BOTH lanes
    assert len(out) == 2
    assert plane.in_flight == 0
    assert plane.domain_count == 0
    assert plane.advance(100.0) == []   # emptied plane: clean no-op
    # probes still answer after the wipeout
    assert plane.probe_bandwidth("a", "c", 0) > 0


def test_zero_capacity_stays_finite_and_recovers():
    topo = network.Topology.star(["a", "b"], 100e6)
    plane = ShardedPlane(topo)
    _launch(plane, "j0", "a", "b", v_bytes=5e8, rate=0.0)
    plane.advance(1.0)
    plane.set_link_capacity("acc:a", 0.0)
    done = plane.advance(10.0)          # stalled, not NaN/crashed
    assert done == []
    assert plane.in_flight == 1
    plane.set_link_capacity("acc:a", 100e6)
    t, done = 10.0, []
    while plane.in_flight and t < 200.0:
        t += 1.0
        done += plane.advance(t)
    assert [r.job_id for r, _ in done] == ["j0"]


def test_what_if_cost_batch_empty_bank():
    from repro.core.rates import RateBank
    bank = RateBank([])
    assert bank.m == 0
    b = strunk.what_if_cost_batch(np.zeros(0), np.zeros(0), bank,
                                  np.zeros(0))
    assert b.shape == (0,)
    out = strunk.what_if_cost_batch(np.zeros(0), np.zeros(0), bank,
                                    np.zeros(0), full=True)
    assert out.bytes_sent.shape == (0,)
    # empty spec list takes the same guard
    assert strunk.what_if_cost_batch(np.zeros(0), np.zeros(0), [],
                                     np.zeros(0)).shape == (0,)


# ---------------------------------------------------------------------------
# LMCM retry/backoff
# ---------------------------------------------------------------------------
def _aborted_outcome(bytes_sent=1e8):
    return strunk.MigrationOutcome(
        total_time=5.0, downtime=0.0, bytes_sent=bytes_sent, rounds=1,
        stop_reason=strunk.STOP_ABORTED)


def test_lmcm_fail_backoff_doubles_and_caps():
    lm = LMCM(policy="immediate", retry_backoff_s=4.0, retry_max=3)
    req = MigrationRequest("j", created_at=0.0, v_bytes=1e9)
    waits = []
    now = 0.0
    for k in range(3):
        assert lm.fail(req, _aborted_outcome(), now)
        assert req.decision == "scheduled"
        waits.append(req.scheduled_at - now)
        now = req.scheduled_at
    assert waits == [4.0, 8.0, 16.0]
    assert req.attempt_bytes == pytest.approx(3e8)
    # 4th abort exhausts the cap -> terminal failure
    assert not lm.fail(req, _aborted_outcome(), now)
    assert req.decision == "failed"
    assert req.created_at == 0.0        # never touched by retries


def test_lmcm_fail_respects_deadline():
    lm = LMCM(policy="immediate", retry_backoff_s=1e4, retry_max=3)
    req = MigrationRequest("j", created_at=0.0, v_bytes=1e9, deadline=60.0)
    assert not lm.fail(req, _aborted_outcome(), 10.0)
    assert req.decision == "failed"


def test_lmcm_retarget_cancels_unroutable():
    lm = LMCM(policy="immediate")
    lm.retarget = lambda req: False
    req = MigrationRequest("j", created_at=0.0, v_bytes=1e9,
                           src="a", dst="b")
    lm.submit(req, 0.0)
    assert lm.due(0.0) == []
    assert req.decision == "cancelled"


# ---------------------------------------------------------------------------
# FleetSim: conservation, parity, bit-identity under faults
# ---------------------------------------------------------------------------
def _faulted_run(policy, seed, *, event_skip=True, cross_core=True):
    fleet = build_fleet(seed=seed)
    victim = fleet.hosts[0]
    t_fail = 20.0
    sim = fleet.sim(policy, warmup_s=0.0, event_skip=event_skip,
                    fault_plan=FaultPlan.host_failure(
                        t_fail, victim, recover_at=t_fail + 300.0))
    excl = fleet.rack_peers(victim) if cross_core else ()
    plan = evacuation_plan(fleet, victim, sim.now, exclude=excl)
    for req in plan:
        req.urgent = True
    res = sim.run_with_plan(plan, horizon_s=2500.0)
    return sim, res, plan


def _check_link_conservation(res, rtol=1e-6):
    expected = defaultdict(float)
    for _, _, partial, path in res.abort_log:
        for link in path:
            expected[link] += partial
    for req in res.migrations:
        for link in req.path:
            expected[link] += res.per_job[req.job_id].bytes_sent
    links = set(expected) | {l for l, b in res.link_bytes.items() if b}
    assert links
    for link in links:
        assert res.link_bytes.get(link, 0.0) == pytest.approx(
            expected.get(link, 0.0), rel=rtol), link


def test_abort_retry_byte_conservation_seeded():
    sim, res, plan = _faulted_run("immediate", seed=0)
    assert res.n_aborts > 0 and res.n_retries > 0
    assert len(res.per_job) == len(plan) and not res.failed_jobs
    assert res.aborted_bytes == pytest.approx(
        sum(b for _, _, b, _ in res.abort_log))
    _check_link_conservation(res)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_abort_retry_byte_conservation_property(seed):
    _, res, _ = _faulted_run("immediate", seed=seed)
    _check_link_conservation(res)


def test_event_skip_bit_identity_under_faults():
    for policy in ("immediate", "alma-paper"):
        s1, r1, _ = _faulted_run(policy, seed=0, event_skip=True)
        s0, r0, _ = _faulted_run(policy, seed=0, event_skip=False)
        assert r1.n_aborts == r0.n_aborts > 0
        assert r1.total_bytes == r0.total_bytes
        assert r1.total_time == r0.total_time
        assert r1.aborted_bytes == r0.aborted_bytes
        assert r1.link_bytes == r0.link_bytes
        assert r1.completed_at == r0.completed_at
        assert r1.abort_log == r0.abort_log
        assert s1.now == s0.now
        assert np.array_equal(s1.telemetry._data, s0.telemetry._data)
        assert np.array_equal(s1.telemetry._steps, s0.telemetry._steps)
        assert s1.rng.bit_generator.state == s0.rng.bit_generator.state


def test_empty_fault_plan_is_no_plan():
    results = []
    for fp in (None, FaultPlan()):
        fleet = build_fleet(seed=1)
        sim = fleet.sim("immediate", warmup_s=0.0, fault_plan=fp)
        plan = evacuation_plan(fleet, fleet.hosts[0], sim.now)
        res = sim.run_with_plan(plan, horizon_s=2000.0)
        results.append((sim, res))
    (s0, r0), (s1, r1) = results
    assert s1._fault_plan is None       # empty normalizes to None
    assert r1.n_aborts == 0 and r1.aborted_bytes == 0.0
    assert r0.total_bytes == r1.total_bytes
    assert r0.link_bytes == r1.link_bytes
    assert r0.completed_at == r1.completed_at
    assert np.array_equal(s0.telemetry._data, s1.telemetry._data)
    assert s0.rng.bit_generator.state == s1.rng.bit_generator.state


def test_retries_reroute_around_dead_source():
    # the victim dies mid-drain: retried lanes must not keep the corpse
    # as an endpoint, and every VM still completes somewhere live
    sim, res, plan = _faulted_run("immediate", seed=0)
    victim = "r0h0"
    for req in res.migrations:
        # a completed lane may only name the corpse as src if it finished
        # before the crash
        assert req.src != victim or res.completed_at[req.job_id] <= 20.0
    for job_id in (r.job_id for r in plan):
        host = sim.placement.host_of(job_id)
        assert host is not None and host != victim


# ---------------------------------------------------------------------------
# scenario layer
# ---------------------------------------------------------------------------
def test_scenario_helpers():
    assert np.isnan(percentiles([])["p50"])
    p = percentiles([1.0, 2.0, 3.0, 4.0])
    assert p["p50"] == 2.5 and p["max"] == 4.0
    done = MigrationRequest("a", 0.0, 1e9, deadline=10.0)
    late = MigrationRequest("b", 0.0, 1e9, deadline=10.0)
    dead = MigrationRequest("c", 0.0, 1e9)
    dead.decision = "failed"
    assert sla_violations([done, late, dead],
                          {"a": 5.0, "b": 50.0}) == 2


def test_evacuation_plan_projected_load():
    fleet = build_fleet(seed=0)
    victim = fleet.hosts[0]
    plan = evacuation_plan(fleet, victim, 0.0)
    assert {r.job_id for r in plan} == set(fleet.jobs_on(victim))
    assert all(r.src == victim and r.dst != victim for r in plan)
    # projected-load tracking: no destination oversubscribed
    incoming = defaultdict(float)
    for r in plan:
        incoming[r.dst] += fleet.placement.hosts[victim].jobs[r.job_id]
    for h, extra in incoming.items():
        assert fleet.placement.hosts[h].free >= extra
    # rack-local preference: peers have headroom, so the drain stays
    # inside the rack
    assert all(fleet.rack_of[r.dst] == fleet.rack_of[victim] for r in plan)


def test_scenarios_smoke_deterministic():
    a = SCENARIOS["node_failure"](policy="immediate", seed=0)
    b = SCENARIOS["node_failure"](policy="immediate", seed=0)
    assert a == b
    assert np.isfinite(a["rto_s"]) and a["rto_s"] > 0
    assert a["n_aborts"] > 0 and not a["failed_jobs"]
    d = SCENARIOS["host_drain"](policy="immediate", seed=0)
    assert d["drained"] and d["deadline_met"]
