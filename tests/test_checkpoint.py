"""Checkpoint store: full/async/incremental roundtrips and restore-time
resharding hooks (elastic restart)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, IncrementalCheckpointer,
                              latest_step, restore_checkpoint,
                              save_checkpoint)


def _state(seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((32, 48)) * scale,
                                    jnp.bfloat16),
                   "b": jnp.asarray(rng.standard_normal((48,)), jnp.float32)},
        "step": jnp.asarray(int(scale * 10), jnp.int32),
    }


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert jnp.array_equal(x, y)


def test_roundtrip(tmp_path):
    s = _state()
    save_checkpoint(str(tmp_path), 5, s)
    assert latest_step(str(tmp_path)) == 5
    like = jax.eval_shape(lambda: s)
    r = restore_checkpoint(str(tmp_path), 5, like)
    _assert_tree_equal(s, r)


def test_async_checkpointer_keeps_latest(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for step in (10, 20, 30):
        ck.save(step, _state(scale=step))
    ck.wait()
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [20, 30]
    like = jax.eval_shape(lambda: _state())
    r = restore_checkpoint(str(tmp_path), 30, like)
    _assert_tree_equal(_state(scale=30), r)


def test_incremental_delta_then_restore(tmp_path):
    inc = IncrementalCheckpointer(str(tmp_path), block_elems=32,
                                  full_every=100)
    s = _state()
    stats0 = inc.save(0, s)
    assert stats0["kind"] == "full"
    # touch a single block's worth of params
    s2 = jax.tree.map(lambda x: x, s)
    s2["params"]["w"] = s["params"]["w"].at[0, 0].add(jnp.bfloat16(1.0))
    s2["step"] = s["step"] + 1
    stats1 = inc.save(1, s2)
    assert stats1["kind"] == "delta"
    full_bytes = sum(np.asarray(l).nbytes for l in jax.tree.leaves(s))
    assert stats1["bytes"] < full_bytes / 4        # delta is actually small
    like = jax.eval_shape(lambda: s)
    r = inc.restore(1, like)
    _assert_tree_equal(s2, r)
    r0 = inc.restore(0, like)
    _assert_tree_equal(s, r0)


def test_trainer_restores_after_failure(tmp_path):
    """Integration: kill the job at a step, trainer resumes from checkpoint
    and reaches the target step with identical final state semantics."""
    from repro.configs import get_config
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config("internlm2_1p8b").smoke().replace(num_layers=2)
    failed = {"done": False}

    def failure_hook(step):
        if step == 7 and not failed["done"]:
            failed["done"] = True
            return True
        return False

    tcfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                         telemetry=False)
    tr = Trainer(cfg, tcfg, batch=2, seq=32, failure_hook=failure_hook)
    out = tr.run(12)
    assert out["restarts"] == 1
    assert out["final_step"] == 12
    assert np.isfinite(out["loss"])
