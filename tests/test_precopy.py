"""Pre-copy live-migration engine invariants.

The central correctness property: after stop-and-copy the destination pytree
equals the source **exactly**, no matter how the job mutated state between
rounds. Plus the Xen stop conditions and the Strunk analytic bounds
(hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core import precopy, strunk


def _tree(rng, scale=1.0):
    return {
        "w1": jnp.asarray(rng.standard_normal((64, 128)) * scale, jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((300,)) * scale, jnp.bfloat16),
        "step": jnp.asarray(7, jnp.int32),
    }


def test_migration_is_exact_with_live_updates():
    rng = np.random.default_rng(0)
    state = {"v": _tree(rng)}
    calls = {"n": 0}

    def step():
        calls["n"] += 1
        state["v"]["w1"] = state["v"]["w1"] + 0.01 * calls["n"]
        state["v"]["step"] = state["v"]["step"] + 1

    cfg = precopy.PrecopyConfig(block_elems=64, max_rounds=6,
                                stop_dirty_blocks=0)
    dest, report = precopy.migrate(lambda: state["v"], step, cfg)
    # exactness: destination == final source state bit-for-bit
    for a, b in zip(jax.tree.leaves(dest), jax.tree.leaves(state["v"])):
        assert jnp.array_equal(a, b), report
    assert calls["n"] >= 1                       # the job really ran
    assert report.outcome.rounds <= cfg.max_rounds
    assert report.outcome.bytes_sent >= report.v_mem


def test_idle_job_single_round():
    rng = np.random.default_rng(1)
    state = _tree(rng)
    cfg = precopy.PrecopyConfig(block_elems=128)
    dest, report = precopy.migrate(lambda: state, None, cfg)
    assert report.outcome.stop_reason == "dirty_low"
    assert report.outcome.bytes_sent == report.v_mem  # V_mem, no dirty resend
    # Strunk lower bound: T >= V/B
    lo, hi = strunk.strunk_bounds(report.v_mem, cfg.bandwidth)
    assert lo <= report.outcome.total_time <= hi


def test_total_cap_stop_condition():
    rng = np.random.default_rng(2)
    state = {"w": jnp.asarray(rng.standard_normal((4096,)), jnp.float32)}

    def hot_step():  # dirty everything every round
        state["w"] = state["w"] + 1.0

    cfg = precopy.PrecopyConfig(block_elems=64, max_rounds=29,
                                stop_dirty_blocks=0, stop_total_factor=3.0)
    dest, report = precopy.migrate(lambda: state["w"], hot_step, cfg)
    assert report.outcome.stop_reason in ("total_cap", "max_rounds")
    assert report.outcome.bytes_sent <= (3.0 + 2) * report.v_mem


@given(v_mem=st.floats(1e6, 1e10), bw=st.floats(1e7, 1e11),
       rate_frac=st.floats(0.0, 0.95))
def test_strunk_simulation_within_bounds(v_mem, bw, rate_frac):
    """Property: simulated pre-copy obeys Inequality 1 (both bounds)."""
    out = strunk.simulate_precopy(v_mem, bw, rate_frac * bw)
    lo, hi = strunk.strunk_bounds(v_mem, bw)
    assert lo <= out.total_time <= hi * 1.001
    assert 0 <= out.downtime <= out.total_time
    assert out.bytes_sent >= v_mem


@given(rate1=st.floats(0.0, 0.2), rate2=st.floats(0.5, 0.95))
def test_dirty_rate_monotonicity(rate1, rate2):
    """A dirtier workload never migrates cheaper — the paper's core premise."""
    v, bw = 1e9, 125e6
    a = strunk.simulate_precopy(v, bw, rate1 * bw)
    b = strunk.simulate_precopy(v, bw, rate2 * bw)
    assert a.bytes_sent <= b.bytes_sent
    assert a.total_time <= b.total_time * 1.001


def test_phase_dependent_migration_cost():
    """Migrating in an LM phase beats an NLM phase (Fig. 2 scenario)."""
    from repro.core.fleetsim import WorkloadTrace
    tr = WorkloadTrace([("MEM", 100), ("CPU", 100)], 200)
    in_mem = strunk.simulate_precopy(1e9, 125e6, tr.dirty_rate, start_time=10)
    in_cpu = strunk.simulate_precopy(1e9, 125e6, tr.dirty_rate, start_time=110)
    assert in_cpu.bytes_sent < in_mem.bytes_sent
    assert in_cpu.total_time < in_mem.total_time


# ---------------------------------------------------------------------------
# batched simulator: lane-for-lane bit-equality with the scalar reference
# ---------------------------------------------------------------------------
def _as_tuple(o: strunk.MigrationOutcome):
    return (o.total_time, o.downtime, o.bytes_sent, o.rounds, o.stop_reason)


def test_batch_bit_equals_reference_all_stop_reasons():
    """(M,) lanes covering all three Xen stop conditions, constant and
    callable (cyclic-trace) dirty rates, per-lane start times — every lane
    of the batch must equal the scalar reference EXACTLY (same float64
    operation order, not just approximately)."""
    from repro.core.fleetsim import WorkloadTrace
    tr = WorkloadTrace([("MEM", 100), ("CPU", 100)], 200)
    lanes = [
        (1.5e9, 125e6, 2e6, 0.0),            # dirty_low
        (1e9, 125e6, 150e6, 0.0),            # total_cap
        (1e9, 250e6, 0.55 * 250e6, 3.5),     # dirty_low after many rounds
        (2e9, 125e6, tr.dirty_rate, 10.0),   # NLM-phase start, trace rate
        (2e9, 125e6, tr.dirty_rate, 110.0),  # LM-phase start, trace rate
        (0.75e9, 100e6, 0.0, 42.0),          # idle lane, single round
    ]
    batch = strunk.simulate_precopy_batch(
        [l[0] for l in lanes], [l[1] for l in lanes],
        [l[2] for l in lanes], start_time=[l[3] for l in lanes])
    reasons = set()
    for i, (v, bw, rate, t0) in enumerate(lanes):
        ref = strunk.simulate_precopy_reference(v, bw, rate, start_time=t0)
        assert _as_tuple(batch.item(i)) == _as_tuple(ref), (i, ref)
        reasons.add(ref.stop_reason)
    assert {"dirty_low", "total_cap"} <= reasons


def test_batch_bit_equals_reference_max_rounds():
    # max_rounds needs a custom cap: at the Xen default the geometric dirty
    # tail either dips under the dirty_low threshold or trips total_cap first
    batch = strunk.simulate_precopy_batch(
        [1e9, 1e9], 125e6, [0.6 * 125e6, 2e6], max_rounds=5)
    for i, rate in enumerate((0.6 * 125e6, 2e6)):
        ref = strunk.simulate_precopy_reference(1e9, 125e6, rate,
                                                max_rounds=5)
        assert _as_tuple(batch.item(i)) == _as_tuple(ref)
    assert batch.item(0).stop_reason == "max_rounds"
    assert batch.item(1).stop_reason == "dirty_low"


def test_scalar_is_m1_view_of_batch():
    """simulate_precopy is the M=1 view of the batch path and matches the
    reference loop bit-for-bit."""
    from repro.core.fleetsim import WorkloadTrace
    tr = WorkloadTrace([("MEM", 30), ("CPU", 60), ("IDLE", 30)], 120)
    for t0 in (0.0, 17.0, 35.0, 95.0):
        a = strunk.simulate_precopy(1.2e9, 125e6, tr.dirty_rate,
                                    start_time=t0)
        b = strunk.simulate_precopy_reference(1.2e9, 125e6, tr.dirty_rate,
                                              start_time=t0)
        assert _as_tuple(a) == _as_tuple(b)


def test_batch_vectorized_rate_matches_per_lane_callables():
    """PiecewiseRate.batch (the fleet fast path) must sample identically to
    each lane's scalar callable."""
    from repro.core.fleetsim import PiecewiseRate, WorkloadTrace
    traces = [WorkloadTrace([("MEM", 100), ("CPU", 100)], 200, offset=o)
              for o in (0.0, 37.0, 121.0, 180.0)]
    v = np.full(4, 1.6e9)
    starts = np.array([0.0, 11.0, 63.0, 150.0])
    fast = strunk.simulate_precopy_batch(
        v, 125e6, PiecewiseRate.batch([t.rate_table for t in traces]),
        start_time=starts)
    slow = strunk.simulate_precopy_batch(
        v, 125e6, [t.dirty_rate for t in traces], start_time=starts)
    np.testing.assert_array_equal(fast.total_time, slow.total_time)
    np.testing.assert_array_equal(fast.bytes_sent, slow.bytes_sent)
    np.testing.assert_array_equal(fast.rounds, slow.rounds)
    np.testing.assert_array_equal(fast.stop_reason, slow.stop_reason)


def test_expected_cost_batch_matches_scalar_scan():
    from repro.core.fleetsim import WorkloadTrace
    tr = WorkloadTrace([("MEM", 50), ("CPU", 70)], 120)
    starts = np.linspace(0.0, 120.0, 13)
    batch = strunk.expected_cost_batch(1e9, 125e6, tr.dirty_rate, starts)
    scalar = [strunk.expected_cost(1e9, 125e6, tr.dirty_rate, start_time=s)
              for s in starts]
    np.testing.assert_array_equal(batch, scalar)
