"""Pre-copy live-migration engine invariants.

The central correctness property: after stop-and-copy the destination pytree
equals the source **exactly**, no matter how the job mutated state between
rounds. Plus the Xen stop conditions and the Strunk analytic bounds
(hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core import precopy, strunk


def _tree(rng, scale=1.0):
    return {
        "w1": jnp.asarray(rng.standard_normal((64, 128)) * scale, jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((300,)) * scale, jnp.bfloat16),
        "step": jnp.asarray(7, jnp.int32),
    }


def test_migration_is_exact_with_live_updates():
    rng = np.random.default_rng(0)
    state = {"v": _tree(rng)}
    calls = {"n": 0}

    def step():
        calls["n"] += 1
        state["v"]["w1"] = state["v"]["w1"] + 0.01 * calls["n"]
        state["v"]["step"] = state["v"]["step"] + 1

    cfg = precopy.PrecopyConfig(block_elems=64, max_rounds=6,
                                stop_dirty_blocks=0)
    dest, report = precopy.migrate(lambda: state["v"], step, cfg)
    # exactness: destination == final source state bit-for-bit
    for a, b in zip(jax.tree.leaves(dest), jax.tree.leaves(state["v"])):
        assert jnp.array_equal(a, b), report
    assert calls["n"] >= 1                       # the job really ran
    assert report.outcome.rounds <= cfg.max_rounds
    assert report.outcome.bytes_sent >= report.v_mem


def test_idle_job_single_round():
    rng = np.random.default_rng(1)
    state = _tree(rng)
    cfg = precopy.PrecopyConfig(block_elems=128)
    dest, report = precopy.migrate(lambda: state, None, cfg)
    assert report.outcome.stop_reason == "dirty_low"
    assert report.outcome.bytes_sent == report.v_mem  # V_mem, no dirty resend
    # Strunk lower bound: T >= V/B
    lo, hi = strunk.strunk_bounds(report.v_mem, cfg.bandwidth)
    assert lo <= report.outcome.total_time <= hi


def test_total_cap_stop_condition():
    rng = np.random.default_rng(2)
    state = {"w": jnp.asarray(rng.standard_normal((4096,)), jnp.float32)}

    def hot_step():  # dirty everything every round
        state["w"] = state["w"] + 1.0

    cfg = precopy.PrecopyConfig(block_elems=64, max_rounds=29,
                                stop_dirty_blocks=0, stop_total_factor=3.0)
    dest, report = precopy.migrate(lambda: state["w"], hot_step, cfg)
    assert report.outcome.stop_reason in ("total_cap", "max_rounds")
    assert report.outcome.bytes_sent <= (3.0 + 2) * report.v_mem


@given(v_mem=st.floats(1e6, 1e10), bw=st.floats(1e7, 1e11),
       rate_frac=st.floats(0.0, 0.95))
def test_strunk_simulation_within_bounds(v_mem, bw, rate_frac):
    """Property: simulated pre-copy obeys Inequality 1 (both bounds)."""
    out = strunk.simulate_precopy(v_mem, bw, rate_frac * bw)
    lo, hi = strunk.strunk_bounds(v_mem, bw)
    assert lo <= out.total_time <= hi * 1.001
    assert 0 <= out.downtime <= out.total_time
    assert out.bytes_sent >= v_mem


@given(rate1=st.floats(0.0, 0.2), rate2=st.floats(0.5, 0.95))
def test_dirty_rate_monotonicity(rate1, rate2):
    """A dirtier workload never migrates cheaper — the paper's core premise."""
    v, bw = 1e9, 125e6
    a = strunk.simulate_precopy(v, bw, rate1 * bw)
    b = strunk.simulate_precopy(v, bw, rate2 * bw)
    assert a.bytes_sent <= b.bytes_sent
    assert a.total_time <= b.total_time * 1.001


def test_phase_dependent_migration_cost():
    """Migrating in an LM phase beats an NLM phase (Fig. 2 scenario)."""
    from repro.core.fleetsim import WorkloadTrace
    tr = WorkloadTrace([("MEM", 100), ("CPU", 100)], 200)
    in_mem = strunk.simulate_precopy(1e9, 125e6, tr.dirty_rate, start_time=10)
    in_cpu = strunk.simulate_precopy(1e9, 125e6, tr.dirty_rate, start_time=110)
    assert in_cpu.bytes_sent < in_mem.bytes_sent
    assert in_cpu.total_time < in_mem.total_time
