"""Property tests: ``strunk.ResumeState`` under time-varying (and
guard-throttled) rate tables.

The resumable pre-copy recurrence must stay exact when the dirty-rate
table is NOT constant — including tables the prediction guard has
rescaled mid-flight (``guard.throttled_spec``):

* fresh-init bit-parity: ``init=ResumeState.fresh(v)`` equals the
  no-init hot loop bit-for-bit on every outcome field, for randomized
  multi-segment tables at randomized throttle factors;
* conservation: snapshot a lane mid-round off the executing plane
  (``lane_state``) — including AFTER an auto-converge throttle swapped
  its table — and the marginal repriced bill plus bytes/time already
  charged equals the plane's realized outcome.

Hypothesis drives the randomized forms when installed
(``_hypothesis_compat``); the seeded loops below always run, so the
properties are exercised in clean containers too.
"""
from __future__ import annotations

import numpy as np
import pytest

from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st
from repro.core import network, strunk
from repro.core.guard import MigrationGuard, throttled_spec
from repro.core.orchestrator import MigrationRequest
from repro.core.plane import MigrationPlane
from repro.core.rates import PiecewiseRate

CAP = 125e6


def _rand_table(rng) -> PiecewiseRate:
    n = int(rng.integers(2, 6))
    ends = np.cumsum(rng.uniform(5.0, 60.0, n))
    rates = rng.uniform(0.0, 2.5e8, n)
    return PiecewiseRate(ends, rates, offset=float(rng.uniform(0.0, 30.0)))


def _assert_fresh_parity(seed: int) -> None:
    """Fresh-init == no-init, bit-for-bit, with every lane's table run
    through the guard's throttle transform at a random factor (factor
    1.0 rows keep the original table object)."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 10))
    v = rng.uniform(1e8, 3e9, m)
    bw = rng.uniform(5e6, 2e8, m)
    t0 = rng.uniform(0.0, 400.0, m)
    specs = []
    for _ in range(m):
        tbl = _rand_table(rng)
        f = float(rng.choice([1.0, 0.5, 0.25, 0.1]))
        specs.append(tbl if f == 1.0 else throttled_spec(tbl, f))
    base = strunk.what_if_cost_batch(v, bw, specs, t0, full=True)
    resumed = strunk.what_if_cost_batch(
        v, bw, specs, t0, init=strunk.ResumeState.fresh(v), full=True)
    for f in ("total_time", "downtime", "bytes_sent", "rounds",
              "stop_reason"):
        assert np.array_equal(getattr(base, f), getattr(resumed, f)), f


@pytest.mark.parametrize("seed", range(10))
def test_fresh_init_parity_throttled_tables_seeded(seed):
    _assert_fresh_parity(seed)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=100_000))
def test_fresh_init_parity_throttled_tables_property(seed):
    _assert_fresh_parity(seed)


def _snapshot_init(ls) -> strunk.ResumeState:
    return strunk.ResumeState(
        rem=np.asarray([ls.rem]), acc=np.asarray([ls.acc]),
        sent=np.asarray([ls.sent]), rounds=np.asarray([ls.rounds]),
        stopped=np.asarray([ls.stopped]), reason=np.asarray([ls.reason]))


def _assert_conservation(seed: int, *, guard: bool) -> None:
    """Step a lane on the plane, snapshot it mid-flight, run the rest
    uninterrupted (one ``advance`` to the horizon — uninterrupted rounds
    keep the plane on the reference recurrence), and check that the
    snapshot's repriced marginal bill plus bytes/time already charged
    equals the realized outcome. With ``guard`` the lane is hostile and
    the throttle ladder swaps its table BEFORE the snapshot, so the
    repriced spec is the THROTTLED PiecewiseRate."""
    rng = np.random.default_rng(seed)
    if guard:
        g = MigrationGuard(throttle_ratio=1.1, abort_ratio=100.0,
                           throttle_factor=0.3, throttle_floor=0.3)
        rate = PiecewiseRate([1e9], [float(rng.uniform(2e8, 4e8))])
        v = float(rng.uniform(1e9, 2e9))
    else:
        g, rate, v = None, _rand_table(rng), float(rng.uniform(5e8, 3e9))
    plane = MigrationPlane(network.Topology.single_link(CAP), guard=g)
    req = MigrationRequest("j", 0.0, v, src="h0", dst="h1")
    if guard:
        req.expected_bytes, req.expected_time = 1.02 * v, 1.02 * v / CAP
    plane.launch(req, rate, 0.0)
    t, done = 0.0, []
    wait = float(rng.uniform(2.0, 20.0))
    while plane.in_flight and (t < wait or
                               (guard and g.n_throttles == 0)) \
            and t < 200.0:
        t += 1.0
        done.extend(plane.advance(t))
    if not plane.in_flight:
        return                       # lane finished before the snapshot
    ls = plane.lane_state()[0]
    if ls.stopped:
        return                       # already in stop-and-copy: no resume
    done.extend(plane.advance(900.0))
    assert len(done) == 1
    out = done[0][1]
    if guard:
        assert g.n_throttles >= 1
        assert isinstance(ls.spec, PiecewiseRate)
        assert float(np.asarray(ls.spec.rates)[0]) < \
            float(np.asarray(rate.rates)[0])
    marg = strunk.what_if_cost_batch(
        [ls.v], [CAP], [ls.spec], [t], init=_snapshot_init(ls),
        full=True)
    tight = lambda x: pytest.approx(x, rel=1e-12)
    assert ls.sent + marg.bytes_sent[0] == tight(out.bytes_sent)
    assert t + marg.total_time[0] == tight(out.total_time)
    assert marg.downtime[0] == tight(out.downtime)


@pytest.mark.parametrize("seed", range(8))
def test_resume_conservation_time_varying_seeded(seed):
    _assert_conservation(seed, guard=False)


@pytest.mark.parametrize("seed", range(8))
def test_resume_conservation_after_throttle_seeded(seed):
    _assert_conservation(seed, guard=True)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=100_000))
def test_resume_conservation_time_varying_property(seed):
    _assert_conservation(seed, guard=False)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=100_000))
def test_resume_conservation_after_throttle_property(seed):
    _assert_conservation(seed, guard=True)


def test_resume_take_preserves_throttled_rows():
    """``ResumeState.take`` gathers rows intact (the flattened-sweep
    layout the controller reprices throttled in-flight lanes through)."""
    st0 = strunk.ResumeState(
        rem=np.asarray([1e8, 2e8]), acc=np.asarray([3e6, 4e6]),
        sent=np.asarray([5e8, 6e8]), rounds=np.asarray([2, 3]),
        stopped=np.asarray([False, True]),
        reason=np.asarray([strunk.REASON_MAX_ROUNDS,
                           strunk.REASON_DIRTY_LOW]))
    g = st0.take([1, 0, 1])
    assert np.array_equal(g.rem, [2e8, 1e8, 2e8])
    assert np.array_equal(g.stopped, [True, False, True])
    assert np.array_equal(g.reason, [strunk.REASON_DIRTY_LOW,
                                     strunk.REASON_MAX_ROUNDS,
                                     strunk.REASON_DIRTY_LOW])
