"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py).

All kernels run in interpret mode on CPU (the TPU lowering is the target;
interpret executes the same kernel body)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.dirty_delta import max_abs_delta
from repro.kernels.dft import dft_power
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssm_scan import ssm_scan
from repro.models.gla import gla_chunked

RNG = np.random.default_rng(42)


def randn(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# dirty_delta
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("nb,blk", [(1, 64), (7, 129), (32, 2048), (65, 300)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dirty_delta_sweep(nb, blk, dtype):
    new = randn(nb, blk, dtype=dtype)
    old = randn(nb, blk, dtype=dtype)
    got = max_abs_delta(new, old)
    want = ref.max_abs_delta_ref(new, old)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_dirty_blocks_exact_detection():
    new = randn(16, 512, dtype=jnp.bfloat16)
    old = jnp.array(new)
    old = old.at[3, 100].add(jnp.bfloat16(0.5)).at[12, 0].add(jnp.bfloat16(-1))
    d = ops.dirty_blocks(new, old)
    assert set(np.flatnonzero(np.asarray(d))) == {3, 12}


def test_dirty_blocks_int_dtype():
    new = jnp.arange(4 * 64, dtype=jnp.int32).reshape(4, 64)
    old = new.at[2, 5].add(1)
    d = ops.dirty_blocks(new, old)
    assert set(np.flatnonzero(np.asarray(d))) == {2}


# ---------------------------------------------------------------------------
# dft
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,n", [(1, 128), (3, 256), (9, 512), (2, 1024)])
def test_dft_power_sweep(b, n):
    x = randn(b, n)
    got = dft_power(x)
    want = ref.dft_power_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-2)


@pytest.mark.parametrize("b,n", [(3, 128), (5, 512)])
def test_dft_fused_mean_removal(b, n):
    """center=True (in-kernel prologue) == host-side x - x.mean()."""
    x = randn(b, n) + 3.0                      # big DC so the fusion matters
    got = dft_power(x, center=True)
    want = dft_power(x - jnp.mean(x, axis=-1, keepdims=True))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-2)


def test_dft_weights_quarter_shift_exact():
    """sin derived from the shared cosine table == direct evaluation."""
    from repro.kernels.dft import dft_weights
    for n in (128, 256):
        cos_w, sin_w = dft_weights(n)
        t = np.arange(n)[:, None] * np.arange(n)[None, :]
        ang = 2.0 * np.pi * t / n
        np.testing.assert_allclose(cos_w, np.cos(ang), atol=1e-6)
        np.testing.assert_allclose(sin_w, np.sin(ang), atol=1e-6)


def test_dft_weight_cache_capped():
    """Regression: the weight cache must stay bounded (the seed pinned up
    to 8 pairs of N x N f32 matrices — 268 MB at N=2048)."""
    from repro.kernels.dft import (MAX_N, _TABLE_CACHE_MAX, dft_cache_nbytes,
                                   dft_weights)
    for n in (128, 256, 512, 1024, 2048, 512, 128):
        dft_weights(n)
    # capacity entries of (int16 phase matrix + f32 table) at worst-case N
    bound = _TABLE_CACHE_MAX * (2 * MAX_N * MAX_N + 4 * MAX_N)
    assert dft_cache_nbytes() <= bound
    assert dft_cache_nbytes() < 268e6 / 10


# ---------------------------------------------------------------------------
# autocorr (period refinement)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("j,n,nl", [(1, 128, 3), (7, 256, 8), (12, 512, 17)])
def test_autocorr_score_sweep(j, n, nl):
    from repro.kernels.autocorr import autocorr_score, autocorr_score_ref
    x = randn(j, n)
    x = x - jnp.mean(x, axis=1, keepdims=True)
    lags = jnp.asarray(RNG.integers(0, n + 10, nl), jnp.int32)
    got = autocorr_score(x, lags)
    want = autocorr_score_ref(np.asarray(x), np.asarray(lags))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-3)


def test_dft_finds_planted_period():
    n = 512
    t = np.arange(n)
    x = jnp.asarray(np.sin(2 * np.pi * t / 32)[None, :], jnp.float32)
    p = np.asarray(ops.power_spectrum(x))[0]
    assert int(np.argmax(p[1:])) + 1 == n // 32


# ---------------------------------------------------------------------------
# backend dispatch table (tpu / gpu / xla rows)
# ---------------------------------------------------------------------------
def test_kernel_backend_detection_and_override():
    assert ops.kernel_backend() == "xla"        # CPU container
    assert not ops.has_accelerator()
    with ops.force_backend("gpu"):
        assert ops.kernel_backend() == "gpu"
        assert ops.on_gpu() and ops.has_accelerator() and not ops.on_tpu()
    with ops.force_backend("tpu"):
        assert ops.on_tpu() and ops.has_accelerator()
    assert ops.kernel_backend() == "xla"        # override scoped
    with pytest.raises(ValueError):
        with ops.force_backend("cuda"):
            pass


def test_interpret_autodetect_off_target():
    """interpret=None must resolve to interpret mode on a foreign host
    (CPU here) for both kernel targets, and an explicit flag must win."""
    from repro.kernels import backend as kb
    assert kb.resolve_interpret("tpu", None) is True
    assert kb.resolve_interpret("gpu", None) is True
    assert kb.resolve_interpret("tpu", False) is False
    # force_backend routes DISPATCH only — the physical platform still
    # decides interpret, so a forced row never tries to compile on CPU
    with ops.force_backend("gpu"):
        assert kb.resolve_interpret("gpu", None) is True


def test_kernel_table_covers_every_row():
    table = ops.kernel_table()
    assert set(table) == {"power_spectrum", "autocorr_score"}
    for op, rows in table.items():
        assert set(rows) == {"tpu", "gpu", "xla"}, op


@pytest.mark.parametrize("row", ["tpu", "gpu", "xla"])
def test_power_spectrum_rows_parity(row):
    """Every dispatch row against the f64 numpy oracle (the Pallas rows run
    in interpret mode on this host — same kernel bodies as on-target)."""
    x = randn(5, 256) + 1.5                     # DC offset: center matters
    with ops.force_backend(row):
        got = np.asarray(ops.power_spectrum(x, center=True))
    xc = np.asarray(x, np.float64)
    xc -= xc.mean(axis=1, keepdims=True)
    F = np.fft.fft(xc, axis=1)[:, : 129]
    want = F.real ** 2 + F.imag ** 2
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-2)


@pytest.mark.parametrize("row", ["tpu", "gpu", "xla"])
def test_autocorr_rows_parity(row):
    from repro.kernels.autocorr import autocorr_score_ref
    x = randn(6, 256)
    x = x - jnp.mean(x, axis=1, keepdims=True)
    lags = jnp.asarray(RNG.integers(0, 270, 13), jnp.int32)
    with ops.force_backend(row):
        got = np.asarray(ops.autocorr_score(x, lags))
    want = autocorr_score_ref(np.asarray(x), np.asarray(lags))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


def test_dispatch_falls_back_off_tile_shapes():
    """Shapes outside the Pallas tiling contract must take the xla row even
    when an accelerator row is forced — callers never see a tiling error."""
    x = randn(3, 200)                           # 200 not a T_TILE multiple
    assert not ops.dft_supported(200)
    with ops.force_backend("tpu"):
        got = np.asarray(ops.power_spectrum(x, center=True))
    xc = np.asarray(x, np.float64)
    xc -= xc.mean(axis=1, keepdims=True)
    F = np.fft.fft(xc, axis=1)[:, : 101]
    np.testing.assert_allclose(got, F.real ** 2 + F.imag ** 2,
                               rtol=2e-4, atol=2e-2)


def test_gpu_lowerings_direct_parity():
    """The Triton-lowered kernel bodies themselves (interpret mode here)
    against the shared oracles, without going through dispatch."""
    from repro.kernels import gpu
    from repro.kernels.autocorr import autocorr_score_ref
    x = randn(4, 512) + 0.7
    got = np.asarray(gpu.dft_power(x, center=True))
    want = np.asarray(ref.dft_power_ref(
        x - jnp.mean(x, axis=-1, keepdims=True)))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-2)
    xc = x - jnp.mean(x, axis=1, keepdims=True)
    lags = jnp.asarray([0, 3, 17, 200, 511, 600], jnp.int32)
    got = np.asarray(gpu.autocorr_score(xc, lags))
    want = autocorr_score_ref(np.asarray(xc), np.asarray(lags))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,hkv,g,d", [(128, 1, 1, 64), (256, 2, 2, 64),
                                       (384, 2, 4, 128), (256, 4, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, hkv, g, d, dtype):
    q = randn(2, hkv * g, s, d, dtype=dtype)
    k = randn(2, hkv, s, d, dtype=dtype)
    v = randn(2, hkv, s, d, dtype=dtype)
    got = flash_attention(q, k, v, bq=128, bk=128)
    want = ref.attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [64, 128, 500])
def test_flash_attention_swa(window):
    q = randn(1, 4, 256, 64)
    k = randn(1, 2, 256, 64)
    v = randn(1, 2, 256, 64)
    got = flash_attention(q, k, v, window=window)
    want = ref.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,dk,dv", [(64, 16, 16), (128, 64, 32),
                                     (96, 32, 64)])
@pytest.mark.parametrize("ssd", [True, False])
def test_ssm_scan_sweep(s, dk, dv, ssd):
    B, H = 2, 3
    q = randn(B, H, s, dk)
    k = randn(B, H, s, dk)
    v = randn(B, H, s, dv)
    lw = -jnp.abs(randn(B, H, s, dk)) * 0.3
    u = randn(H, dk) if not ssd else None
    y_k, st_k = ssm_scan(q, k, v, lw, bonus=u if not ssd else None, ssd=ssd)
    y_r, st_r = ref.ssm_scan_ref(q, k, v, lw, bonus=u, ssd=ssd)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                               rtol=2e-4, atol=2e-4)


def test_ssm_scan_matches_gla_chunked():
    """Kernel and the model's XLA path share the algorithm bit-for-bit-ish."""
    B, H, S, Dk, Dv = 1, 2, 256, 32, 48
    q, k = randn(B, H, S, Dk), randn(B, H, S, Dk)
    v = randn(B, H, S, Dv)
    lw = -jnp.abs(randn(B, H, S, Dk))
    y_k, st_k = ssm_scan(q, k, v, lw, ssd=True, chunk=32)
    y_c, st_c = gla_chunked(q, k, v, lw, chunk=32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_c),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_c),
                               rtol=5e-4, atol=5e-4)
