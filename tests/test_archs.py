"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward/train step on CPU, output shapes + no NaNs; plus a
prefill -> decode consistency check (the decode path must continue exactly
where prefill's cache left off)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, shapes_for
from repro.data import make_batch
from repro.models import lm
from repro.train import (init_train_state, make_decode_step,
                         make_prefill_step, make_train_step)

BATCH, SEQ = 2, 64


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).smoke()
            state = init_train_state(cfg, jax.random.key(0))
            cache[arch] = (cfg, state)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch, arch_state):
    cfg, state = arch_state(arch)
    batch = make_batch(cfg, BATCH, SEQ)
    step = jax.jit(make_train_step(cfg))
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    assert float(metrics["loss"]) > 0
    assert int(new_state["step"]) == int(state["step"]) + 1
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new_state["params"])))
    assert moved, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch, arch_state):
    """Decode logits after prefill(S) must match prefill(S+1)'s last logits."""
    cfg, state = arch_state(arch)
    params = state["params"]
    batch = make_batch(cfg, BATCH, SEQ)
    toks = batch["tokens"]

    short = dict(batch, tokens=toks[:, :-1])
    if "positions" in short:
        short["positions"] = batch["positions"][..., :-1]

    pf = make_prefill_step(cfg, cache_len=SEQ + 8)
    logits_a, cache = jax.jit(pf)(params, short)
    dec = make_decode_step(cfg)
    nxt, logits_dec, cache = jax.jit(dec)(params, toks[:, -1:], cache)

    logits_b, _ = jax.jit(pf)(params, batch)
    assert jnp.all(jnp.isfinite(logits_dec)), arch
    err = jnp.max(jnp.abs(logits_dec.astype(jnp.float32)
                          - logits_b.astype(jnp.float32)))
    # bf16 params + different compute paths (chunked vs cached attention)
    assert float(err) < 0.35, f"{arch}: decode/prefill mismatch {err}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_shape_assignment(arch):
    cfg = get_config(arch)
    names = {s.name for s in shapes_for(cfg)}
    assert {"train_4k", "prefill_32k", "decode_32k"} <= names
    assert ("long_500k" in names) == cfg.sub_quadratic


def test_param_counts_match_billing():
    """Config-level sanity: param counts land near the advertised sizes."""
    expected = {
        "internlm2_1p8b": (1.5e9, 2.3e9),
        "qwen3_8b": (6.5e9, 9.5e9),
        "starcoder2_7b": (6.0e9, 8.5e9),
        "qwen3_moe_30b_a3b": (26e9, 34e9),
        "kimi_k2_1t_a32b": (0.9e12, 1.2e12),
        "rwkv6_1p6b": (1.2e9, 2.0e9),
        "zamba2_2p7b": (2.0e9, 3.4e9),
        "h2o_danube3_4b": (3.0e9, 4.6e9),
    }
    for arch, (lo, hi) in expected.items():
        n = lm.param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n:,}"
