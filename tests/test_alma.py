"""ALMA core invariants: Naive Bayes characterization, FFT cycle recognition
(Alg. 1) and the POSTPONE moment computation (Alg. 2) — unit + property
tests (hypothesis)."""
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core import characterize, cycles, postpone as pp
from repro.core.fleetsim import WorkloadTrace, make_training_nb

# ---------------------------------------------------------------------------
# Naive Bayes
# ---------------------------------------------------------------------------
def test_nb_learns_separable_phases():
    nb = make_training_nb()
    rng = np.random.default_rng(0)
    trace = WorkloadTrace([("CPU", 10), ("MEM", 10), ("IO", 10),
                           ("IDLE", 10)], 40)
    feats, labels = [], []
    for t in np.arange(0.5, 40.0, 0.25):
        s = trace.sample_indexes(t, rng)
        feats.append([s[f] for f in ("step_time", "dirty_bytes",
                                     "dirty_fraction", "collective_bytes",
                                     "compute_util", "hbm_util")])
        labels.append(trace.label_at(t))
    cls, lm, post = characterize.classify_series(
        nb, np.asarray(feats, np.float32))
    acc = np.mean(cls == np.asarray(labels))
    assert acc > 0.9, acc
    # MEM phases must be NLM, the rest LM
    assert np.all(lm[np.asarray(labels) == characterize.MEM] == 0)


def test_nb_posterior_normalized():
    nb = make_training_nb()
    x = np.random.default_rng(1).random((17, 6)).astype(np.float32)
    _, post = characterize.classify_series(nb, x)[0], \
        characterize.classify_series(nb, x)[2]
    np.testing.assert_allclose(post.sum(axis=1), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# cycle recognition (FFT / Alg. 1)
# ---------------------------------------------------------------------------
@given(period=st.integers(4, 48), reps=st.integers(4, 12),
       duty=st.floats(0.2, 0.8))
def test_fft_recovers_planted_period(period, reps, duty):
    lm_len = max(1, int(period * duty))
    pattern = np.array([1] * lm_len + [0] * (period - lm_len), np.int8)
    series = np.tile(pattern, reps)
    got, conf = cycles.cycle_length(series.astype(np.float32),
                                    max_period=period * 2, use_kernel=False)
    # FFT bin quantization: accept the true period within one bin's width
    n = len(series)
    k_true = round(n / period)
    assert abs(got - period) <= max(1, period // k_true), (got, period)


def test_decompose_is_algorithm1():
    classes = np.array([1, 1, 0, 0, 0, 1, 1, 1, 0, 0], np.int8)
    lm, nlm, profile = cycles.decompose(classes, 5)
    assert lm.tolist() == [0, 1]
    assert nlm.tolist() == [2, 3, 4]
    assert profile.tolist() == [1, 1, 0, 0, 0]


@pytest.mark.parametrize("use_kernel", [False, True])
def test_confidence_parity_single_vs_batch(use_kernel):
    """The same series must get the same (period, confidence) on the scalar
    and the batched path — one shared spectrum + peak-share normalization
    (the seed normalized the two paths differently)."""
    rng = np.random.default_rng(7)
    rows = []
    for period in (6, 12, 24, 40):
        patt = (np.arange(period) < period * 0.6).astype(np.int8)
        s = np.tile(patt, 128 // period + 1)[:128]
        flip = rng.random(128) < 0.05               # classifier noise
        rows.append(np.where(flip, 1 - s, s).astype(np.int8))
    X = np.stack(rows)
    batch = cycles.fit_cycle_batch(X, use_kernel=use_kernel)
    for j, row in enumerate(X):
        single = cycles.fit_cycle(row, use_kernel=use_kernel)
        assert single.period == batch[j].period
        np.testing.assert_array_equal(single.profile_lm, batch[j].profile_lm)
        np.testing.assert_allclose(single.confidence, batch[j].confidence,
                                   atol=1e-7)
        p, conf = cycles.cycle_length(row.astype(np.float32),
                                      use_kernel=use_kernel)
        assert p == single.period
        np.testing.assert_allclose(conf, single.confidence, atol=1e-7)


def test_complex_cycle_detected():
    # two NLM intervals per cycle (paper Fig. 4)
    pattern = [1, 1, 0, 1, 1, 1, 0, 0]
    series = np.tile(pattern, 10).astype(np.float32)
    period, conf = cycles.cycle_length(series, use_kernel=False)
    assert period in (8, 4), period  # 4 is the half-harmonic of the comb
    model = cycles.fit_cycle(np.tile(pattern, 10).astype(np.int8))
    assert model.cyclic


# ---------------------------------------------------------------------------
# POSTPONE (Alg. 2)
# ---------------------------------------------------------------------------
@given(period=st.integers(3, 60), m=st.integers(0, 10_000),
       data=st.data())
def test_postpone_properties(period, m, data):
    profile = np.asarray(
        data.draw(st.lists(st.integers(0, 1), min_size=period,
                           max_size=period)), np.int8)
    idx = np.arange(period)
    model = cycles.CycleModel(period, 1.0, profile,
                              idx[profile == 1], idx[profile != 1])
    remain = pp.postpone(model, m)
    m_rel = m % period
    if profile[m_rel] == 1:
        assert remain == 0                      # already suitable: fire now
    else:
        assert 0 < remain <= period             # bounded wait
        if profile.any():
            # after waiting, the workload is at a suitable moment
            assert profile[(m_rel + remain) % period] == 1


@given(period=st.integers(3, 40), m=st.integers(0, 1000), data=st.data())
def test_postpone_batch_matches_scalar(period, m, data):
    profile = np.asarray(
        data.draw(st.lists(st.integers(0, 1), min_size=period,
                           max_size=period)), np.int8)
    idx = np.arange(period)
    model = cycles.CycleModel(period, 1.0, profile,
                              idx[profile == 1], idx[profile != 1])
    profiles, periods = pp.pack_fleet([model])
    import jax.numpy as jnp
    batch = np.asarray(pp.postpone_batch(profiles, periods,
                                         jnp.asarray([m], jnp.int32)))[0]
    scalar = pp.postpone(model, m)
    if profile.any() and not profile.all():
        assert batch == scalar % period or batch == scalar, (batch, scalar)


def test_postpone_all_nlm_backs_off_one_cycle():
    profile = np.zeros(10, np.int8)
    model = cycles.CycleModel(10, 1.0, profile, np.zeros(0, np.int64),
                              np.arange(10))
    assert pp.postpone(model, 3) == 10
