"""Optional-hypothesis shim: property tests degrade to skips when the
package is absent (clean containers), instead of failing collection.

Usage in test modules::

    from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

When hypothesis is installed these are the real objects; otherwise ``given``
returns a decorator that skip-marks the test and ``st``/``settings`` are
inert stand-ins whose attribute lookups all succeed (strategy expressions in
decorator arguments must still evaluate at import time).
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAS_HYPOTHESIS = True
except ImportError:                                           # pragma: no cover
    HAS_HYPOTHESIS = False

    class _InertStrategies:
        """st.integers(...), st.lists(...), ... -> None placeholders."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _InertStrategies()

    class settings:                                           # noqa: N801
        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*args, **kwargs):
            pass

        @staticmethod
        def load_profile(*args, **kwargs):
            pass

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")
