"""End-to-end system behaviour: real training convergence, the ALMA pipeline
over *measured* (not synthetic) telemetry, and elastic rescaling via live
pre-copy migration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import cycles, precopy
from repro.data import make_batch
from repro.train import init_train_state, make_train_step


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_config("internlm2_1p8b").smoke().replace(
        num_layers=2, d_model=64, d_ff=128, num_heads=2, num_kv_heads=1,
        d_head=32, vocab_size=128, learning_rate=1e-3)


def test_loss_decreases(tiny_cfg):
    cfg = tiny_cfg
    state = init_train_state(cfg, jax.random.key(0))
    step = jax.jit(make_train_step(cfg))
    batch = make_batch(cfg, 4, 48)       # overfit one batch
    losses = []
    for _ in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[:3] + losses[-3:]


def test_measured_telemetry_yields_cycles(tiny_cfg):
    """Drive a training loop whose grad-accum phases create a real dirty-rate
    cycle; ALMA must recover a cycle from *measured* dirty stats."""
    cfg = tiny_cfg
    state = init_train_state(cfg, jax.random.key(1))
    step = jax.jit(make_train_step(cfg, telemetry=True))
    period = 8
    series = []
    for i in range(96):
        batch = make_batch(cfg, 2, 32, step=i)
        state, m = step(state, batch)
        heavy = (i % period) < 3
        series.append(1 if (float(m["dirty_fraction"]) > 0.5) == heavy else 0)
    got, conf = cycles.cycle_length(np.asarray(series, np.float32),
                                    use_kernel=False)
    assert got > 1  # some cycle detected on real measurements


def test_elastic_rescale_preserves_training(tiny_cfg, tmp_path):
    """Live-migrate mid-training (pre-copy) and keep stepping: the migrated
    state must bit-match the source at handoff and train on."""
    cfg = tiny_cfg
    state_box = {"s": init_train_state(cfg, jax.random.key(2))}
    step = jax.jit(make_train_step(cfg))

    def do_step():
        batch = make_batch(cfg, 2, 32, step=int(state_box["s"]["step"]))
        state_box["s"], _ = step(state_box["s"], batch)

    pcfg = precopy.PrecopyConfig(block_elems=1 << 12, max_rounds=4,
                                 stop_dirty_blocks=0)
    migrated, report = precopy.migrate(lambda: state_box["s"], do_step, pcfg)
    for a, b in zip(jax.tree.leaves(migrated),
                    jax.tree.leaves(state_box["s"])):
        assert jnp.array_equal(a, b)
    # destination keeps training
    batch = make_batch(cfg, 2, 32, step=int(migrated["step"]))
    new_state, m = step(migrated, batch)
    assert np.isfinite(float(m["loss"]))
    assert report.outcome.rounds >= 1
