"""Surveillance engine invariants: the batched fleet tick must equal the
per-job scalar pipeline exactly (periods/profiles) and to float tolerance
(confidences); staleness epochs must cache and invalidate correctly; empty
fleets and short windows must be graceful; the fleet SoA telemetry must
agree with per-job ring buffers."""
import numpy as np
import pytest

from repro.core import characterize, cycles, postpone as pp
from repro.core.fleetsim import WorkloadTrace, make_training_nb, table3_traces
from repro.core.surveillance import SurveillanceEngine
from repro.core.telemetry import FleetTelemetry, TelemetryBuffer

WINDOW = 128


def _record_steps(fleet, traces, t0s, rng, start, count):
    fields = fleet.fields
    for s in range(start, start + count):
        vals = np.asarray([[tr.sample_indexes(t0 + s, rng)[f] for f in fields]
                           for tr, t0 in zip(traces, t0s)])
        fleet.record_fleet(s, vals)


def _fill_fleet(seed=0):
    """Fleet of table3-style traces (phase_s=4 -> short cycles that fit a
    128-sample window) in one FleetTelemetry store."""
    rng = np.random.default_rng(seed)
    traces = list(table3_traces(phase_s=4.0).values())
    traces.append(WorkloadTrace([("CPU", 4)], 3600))        # acyclic job
    fleet = FleetTelemetry(len(traces), capacity=4 * WINDOW)
    t0s = [rng.uniform(0, tr.cycle_s) for tr in traces]
    _record_steps(fleet, traces, t0s, rng, 0, WINDOW)
    return fleet, traces, t0s, rng


def _scalar_pipeline(nb, buf, window, now_step, folded=False):
    """The seed per-job path: classify -> fit_cycle -> postpone."""
    w = buf.window(window)
    _, lm, _ = characterize.classify_series(nb, w)
    model = cycles.fit_cycle(lm, folded=folded)
    origin = buf.latest_step() - len(w) + 1
    return model, pp.postpone(model, now_step - origin)


def _register_all(engine, nb, fleet):
    for i, v in enumerate(fleet.views()):
        engine.register(f"j{i}", v, nb, window=WINDOW)


@pytest.fixture(scope="module")
def nb():
    return make_training_nb()


def test_tick_matches_scalar_pipeline(nb):
    fleet, traces, _, _ = _fill_fleet()
    eng = SurveillanceEngine()
    _register_all(eng, nb, fleet)
    now_step = WINDOW - 1
    res = eng.tick(now_step)
    assert res.fleet == len(traces)
    for i in range(len(traces)):
        job = eng.jobs[f"j{i}"]
        model, remain = _scalar_pipeline(nb, fleet.view(i), WINDOW, now_step)
        assert job.model.period == model.period, i
        np.testing.assert_array_equal(job.model.profile_lm, model.profile_lm)
        np.testing.assert_array_equal(job.model.array_lm, model.array_lm)
        np.testing.assert_allclose(job.model.confidence, model.confidence,
                                   atol=1e-6)
        if model.cyclic:
            assert res.remain[f"j{i}"] == remain, i


def test_incremental_refresh_matches_full_reclassify(nb):
    """Sliding the window and refitting through the staleness-epoch splice
    path must equal classifying the full window from scratch."""
    fleet, traces, t0s, rng = _fill_fleet()
    eng = SurveillanceEngine()
    _register_all(eng, nb, fleet)
    eng.refresh(force=True)                    # full-window first fit
    _record_steps(fleet, traces, t0s, rng, WINDOW, 37)
    eng.refresh(force=True)                    # delta=37 -> incremental path
    for i in range(len(traces)):
        job = eng.jobs[f"j{i}"]
        w = fleet.view(i).window(WINDOW)
        _, lm_full, _ = characterize.classify_series(nb, w)
        np.testing.assert_array_equal(job.lm_series, lm_full)
        model = cycles.fit_cycle(lm_full)
        assert job.model.period == model.period
        np.testing.assert_array_equal(job.model.profile_lm, model.profile_lm)
        np.testing.assert_allclose(job.model.confidence, model.confidence,
                                   atol=1e-6)
        assert job.origin_step == fleet.latest_step(i) - WINDOW + 1


def test_staleness_epochs(nb):
    fleet, traces, _, _ = _fill_fleet()
    eng = SurveillanceEngine()
    _register_all(eng, nb, fleet)
    assert eng.refresh() == len(traces)        # first fit: everything stale
    assert eng.refresh() == 0                  # nothing moved: all cached
    job = eng.jobs["j1"]                       # vm02_C: MEM phases -> cyclic
    model0 = job.model
    period = model0.period
    assert period > 1
    rng = np.random.default_rng(5)
    fields = fleet.fields
    # advance fewer than period//4 samples: fit must stay cached
    few = max(1, period // 4 - 2)
    for s in range(few):
        fleet.record_fleet(WINDOW + s, rng.random((len(traces), len(fields))))
    eng.refresh()
    assert eng.jobs["j1"].model is model0
    # cross the epoch boundary: fit must be recomputed
    for s in range(few, period // 4 + 1):
        fleet.record_fleet(WINDOW + s, rng.random((len(traces), len(fields))))
    eng.refresh()
    assert eng.jobs["j1"].model is not model0
    assert eng.jobs["j1"].fitted_step == fleet.latest_step(1)


def test_empty_fleet_and_short_window(nb):
    eng = SurveillanceEngine()
    res = eng.tick(0)                          # no jobs registered at all
    assert res.remain == {} and res.fleet == 0 and res.refitted == 0
    buf = TelemetryBuffer(capacity=64)
    eng.register("tiny", buf, nb, window=WINDOW)
    assert eng.tick(0).fleet == 0              # no samples yet
    for s in range(4):                         # below min_samples
        buf.record(s, compute_util=0.5)
    assert eng.refresh() == 0
    assert eng.refresh_model("tiny") is None
    for s in range(4, 16):                     # crosses min_samples
        buf.record(s, compute_util=0.5)
    assert eng.refresh_model("tiny") is not None
    assert eng.tick(15).fleet == 1


def test_fresh_tick_zero_perjob_work(nb, monkeypatch):
    """Decide-plane cache regression guard: a tick over an all-fresh fleet
    (nothing stale) must not repack Algorithm 2's operands — no pack_fleet
    call, i.e. zero per-job Python work beyond the staleness scan — and
    must still return the correct remains via the cached operands."""
    fleet, traces, _, _ = _fill_fleet()
    eng = SurveillanceEngine()
    _register_all(eng, nb, fleet)
    want = eng.tick(WINDOW - 1).remain          # first tick builds the cache

    calls = []
    orig = pp.pack_fleet

    def counting(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    monkeypatch.setattr(pp, "pack_fleet", counting)
    res = eng.tick(WINDOW - 1)                  # all fresh: cache hit
    assert not calls, "fresh tick repacked the fleet (per-job Python work)"
    assert res.remain == want and res.refitted == 0

    # registration invalidates: the new job must repack on the next tick
    lone = TelemetryBuffer(capacity=WINDOW)
    for s in range(WINDOW):
        lone.record(s, compute_util=0.5)
    eng.register("late", lone, nb, window=WINDOW)
    res = eng.tick(WINDOW - 1)
    assert calls and "late" in res.remain

    # unregister invalidates too: the job must vanish from the decide
    calls.clear()
    eng.unregister("late")
    res = eng.tick(WINDOW - 1)
    assert calls and "late" not in res.remain
    assert res.remain == want


def test_mixed_backing_stores_one_gather(nb):
    """window_matrix must agree across fleet views and foreign buffers."""
    fleet, traces, _, _ = _fill_fleet()
    lone = TelemetryBuffer(capacity=256)
    rng = np.random.default_rng(9)
    tr = traces[0]
    for s in range(WINDOW):
        lone.record(s, **tr.sample_indexes(s * 1.0, rng))
    bufs = [fleet.view(0), lone, fleet.view(2)]
    W, lens = TelemetryBuffer.window_matrix(bufs, WINDOW)
    assert W.shape == (3, WINDOW, len(fleet.fields))
    assert lens.tolist() == [WINDOW] * 3
    for k, b in enumerate(bufs):
        np.testing.assert_allclose(W[k], b.window(WINDOW))


def test_fleet_telemetry_wraps_like_scalar_buffers():
    J, cap, steps = 3, 16, 41
    fleet = FleetTelemetry(J, capacity=cap)
    bufs = [TelemetryBuffer(capacity=cap) for _ in range(J)]
    rng = np.random.default_rng(0)
    for s in range(steps):
        vals = rng.random((J, len(fleet.fields)))
        fleet.record_fleet(s, vals)
        for j, b in enumerate(bufs):
            b.record(s, **dict(zip(fleet.fields, vals[j])))
    for n in (4, cap, cap + 5):
        W, m = fleet.window_matrix(n)
        for j in range(J):
            w = bufs[j].window(n)
            assert m[j] == len(w)
            np.testing.assert_allclose(W[j, n - len(w):], w)
    assert fleet.latest_steps().tolist() == [steps - 1] * J
