"""Adaptive concurrency controller: defer-k selection per migration
domain, LMCM integration (forced launches, deferral bookkeeping), and the
adaptive-vs-static-gate byte contract on a contended burst.

The load-bearing contracts:

  * the controller launches the batch minimizing predicted total
    contended bytes — it serializes lanes whose dirty rates make
    concurrency expensive, and launches disjoint-domain lanes in
    parallel;
  * an idle domain always releases its head-of-line candidate (no
    livelock), and a request that cannot wait past ``max_wait`` launches
    unconditionally;
  * with the controller OFF nothing changes (the static gate remains the
    fallback policy);
  * end-to-end on a contended burst the controller's measured bytes are
    <= the static gate's.
"""
import numpy as np
import pytest

from repro.core import network, strunk
from repro.core.controller import AdaptiveConcurrencyController
from repro.core.fabric import ShardedPlane
from repro.core.fleetsim import FleetSim, SimJob, WorkloadTrace
from repro.core.orchestrator import LMCM, MigrationRequest
from repro.core.plane import MigrationPlane
from repro.core.rates import PiecewiseRate

CAP = 125e6


def _rack_topo(racks=2, access=CAP, core=CAP):
    return network.Topology.multi_rack(racks, access, core_capacity=core,
                                       hosts_per_rack=2)


def _reqs(n, rack="r0", v=1e9):
    out = [MigrationRequest(f"{rack}j{i}", 0.0, v,
                            src=f"{rack}h0", dst=f"{rack}h1")
           for i in range(n)]
    return out


def _ctl(plane, rate):
    return AdaptiveConcurrencyController(plane, rate_of=lambda r: rate)


# ---------------------------------------------------------------------------
# selection
# ---------------------------------------------------------------------------
def test_serializes_when_contention_costs_bytes():
    """Two same-link candidates with a dirty rate that makes halved
    bandwidth expensive: predicted bytes are minimized by launching one
    and deferring the other."""
    plane = ShardedPlane(_rack_topo())
    sel = _ctl(plane, 30e6).select(_reqs(2), 0.0)
    assert [r.job_id for r in sel] == ["r0j0"]
    # the controller's choice matches the explicit cost comparison
    solo = 2 * strunk.expected_cost(1e9, CAP, 30e6)
    both = 2 * strunk.expected_cost(1e9, CAP / 2, 30e6)
    assert solo < both


def test_disjoint_domains_launch_in_parallel():
    """Candidates in different racks share no link: one launches per
    (independent) domain in the same tick."""
    plane = ShardedPlane(_rack_topo())
    cands = _reqs(2, "r0") + _reqs(2, "r1")
    sel = _ctl(plane, 30e6).select(cands, 0.0)
    assert [r.job_id for r in sel] == ["r0j0", "r1j0"]


def test_zero_rate_singleton_launches_not_defers():
    """A lone candidate on an idle domain ties launch-vs-defer on bytes
    and time — the tie-break must prefer launching (never defer for
    free)."""
    plane = ShardedPlane(_rack_topo())
    assert len(_ctl(plane, 0.0).select(_reqs(1), 0.0)) == 1


def test_busy_domain_defers_until_drained():
    """With a lane in flight on the candidate's only link, launching now
    is predicted more expensive than waiting; once the lane drains the
    candidate is released."""
    plane = ShardedPlane(_rack_topo())
    ctl = _ctl(plane, 30e6)
    plane.launch(MigrationRequest("busy", 0.0, 2e9,
                                  src="r0h0", dst="r0h1"), 30e6, 0.0)
    cand = _reqs(1)
    assert ctl.select(cand, 0.0) == []
    plane.advance(np.inf)
    assert len(ctl.select(cand, plane.now)) == 1


def test_forced_launches_contend_in_the_sweep():
    """Forced (max-wait-wall) launches are not swept, but their paths must
    dilute the what-if shares of the swept candidates: with a forced lane
    on the same link, the candidate defers."""
    plane = ShardedPlane(_rack_topo())
    ctl = _ctl(plane, 30e6)
    forced = _reqs(1)
    cand = [MigrationRequest("r0cand", 0.0, 1e9, src="r0h0", dst="r0h1")]
    assert ctl.select(cand, 0.0, forced=forced) == []
    # same candidate with no forced competition launches
    assert len(ctl.select(cand, 0.0)) == 1


def test_select_works_on_monolithic_plane():
    """The controller duck-types over MigrationPlane too (one domain)."""
    plane = MigrationPlane(network.Topology.single_link(CAP))
    sel = _ctl(plane, 30e6).select(_reqs(2), 0.0)
    assert len(sel) == 1


# ---------------------------------------------------------------------------
# LMCM integration
# ---------------------------------------------------------------------------
def _wired_lmcm(plane, rate, **kw):
    lmcm = LMCM(policy="immediate", bandwidth=CAP, sample_period=1.0, **kw)
    lmcm.bandwidth_probe = lambda req, extra=0, pending=(): \
        plane.probe_bandwidth(req.src, req.dst, extra, pending=pending)
    lmcm.path_capacity = lambda req: plane.path_capacity(req.src, req.dst)
    lmcm.controller = _ctl(plane, rate)
    return lmcm


def test_due_defers_and_relaunches_through_controller():
    """due() launches the controller's pick, re-queues the rest one
    sampling period out, and releases them as the fabric drains."""
    plane = ShardedPlane(_rack_topo())
    lmcm = _wired_lmcm(plane, 30e6, max_concurrent=8, max_wait=600.0)
    reqs = _reqs(3)
    for r in reqs:
        r.path = plane.topology.path(r.src, r.dst)
        lmcm.submit(r, 0.0)
    fired = lmcm.due(0.0)
    assert [r.job_id for r in fired] == ["r0j0"]
    assert all(r.decision == "scheduled" for r in reqs[1:])
    for r in fired:
        plane.launch(r, 30e6, 0.0)
    assert lmcm.due(1.0) == []          # still busy: everything defers
    plane.advance(np.inf)
    for r in fired:
        lmcm.finish(r, None)
    assert len(lmcm.due(plane.now + 1.0)) == 1   # next in line releases


def test_max_wait_wall_forces_launch_despite_controller():
    """A request that cannot defer another period launches even when the
    controller would hold it back."""
    plane = ShardedPlane(_rack_topo())
    lmcm = _wired_lmcm(plane, 30e6, max_concurrent=8, max_wait=5.0)
    plane.launch(MigrationRequest("busy", 0.0, 1e12,
                                  src="r0h0", dst="r0h1"), 30e6, 0.0)
    req = _reqs(1)[0]
    req.path = plane.topology.path(req.src, req.dst)
    lmcm.submit(req, 0.0)
    assert lmcm.due(0.0) == []          # busy link: deferred
    assert lmcm.due(4.5) == [req]       # 5.5 > created+max_wait: forced
    assert req.decision == "running"


# ---------------------------------------------------------------------------
# end-to-end
# ---------------------------------------------------------------------------
def test_fleetsim_adaptive_knob_beats_static_gate_bytes():
    """FleetSim(adaptive_concurrency=True) completes the same contended
    burst as the static gate with no more total bytes moved. The traces
    are IO/CPU cycles (dirty rates below link capacity), where bytes are
    driven by concurrency — the axis the controller owns — rather than by
    the phase lottery of link-saturating MEM bursts (Algorithm 2's axis,
    disabled here under policy='immediate')."""
    results = {}
    for adaptive in (False, True):
        jobs = [SimJob(f"j{i}",
                       WorkloadTrace([("IO", 60), ("CPU", 60)], 3600,
                                     offset=15.0 * i),
                       1e9)
                for i in range(8)]
        sim = FleetSim(jobs, policy="immediate", warmup_s=60.0,
                       max_concurrent=8, seed=5,
                       min_share_frac=0.0 if adaptive else 0.5,
                       adaptive_concurrency=adaptive)
        plan = [MigrationRequest(j.job_id, sim.now + 5.0, j.v_bytes)
                for j in jobs]
        results[adaptive] = sim.run_with_plan(plan, horizon_s=4000.0)
    assert len(results[True].per_job) == 8
    assert len(results[False].per_job) == 8
    assert results[True].total_bytes <= results[False].total_bytes
    assert results[True].total_time <= results[False].total_time


def test_admit_is_passthrough_without_controller_or_gate():
    """With no controller wired and the share floor disabled (the default
    FleetSim configuration), the release boundary must be a pure
    pass-through: every ready request launches, none defer, in ready
    order — the structural guarantee that this PR's hook leaves all
    existing non-adaptive paths untouched."""
    lmcm = LMCM(policy="immediate", max_concurrent=8, bandwidth=CAP)
    # even with a probe wired, min_share_frac == 0 must not gate
    lmcm.bandwidth_probe = lambda req, extra=0, pending=(): 1.0
    ready = [MigrationRequest(f"j{i}", 0.0, 1e9) for i in range(5)]
    launch, defer = lmcm._admit(list(ready), 0.0)
    assert launch == ready and defer == []
    # and end-to-end through due(): all fire in one tick
    for r in ready:
        lmcm.submit(r, 0.0)
    assert [r.job_id for r in lmcm.due(0.0)] == [r.job_id for r in ready]
