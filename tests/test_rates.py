"""RateBank / as_rate_table edge cases — the padded-lookup shape
contracts the plane's vectorized event loop stands on: empty lane sets,
single-entry tables, and lane membership churn (a callable-rate lane
dropped mid-flight forces a bank rebuild with different padding)."""
import numpy as np
import pytest

from repro.core import network, strunk
from repro.core.orchestrator import MigrationRequest
from repro.core.plane import MigrationPlane
from repro.core.rates import PiecewiseRate, RateBank, as_rate_table


# ---------------------------------------------------------------------------
# as_rate_table normalization
# ---------------------------------------------------------------------------
def test_as_rate_table_forms():
    assert as_rate_table(None)(123.4) == 0.0
    assert as_rate_table(3e6)(77.7) == 3e6
    table = PiecewiseRate([10.0, 20.0], [1.0, 2.0])
    assert as_rate_table(table) is table

    class Carrier:
        rate_table = table
    assert as_rate_table(Carrier()) is table
    assert as_rate_table(lambda t: 5.0) is None     # only per-call sampling


def test_single_entry_table_constant_everywhere():
    """A one-entry table (the constant-rate normalization) is constant at
    every time, scalar and batched — including the degenerate width-1
    padded lookup (no column compares at all)."""
    one = PiecewiseRate([1.0], [7e6])
    for t in (0.0, 0.5, 1.0, 123.456, 1e6):
        assert one(t) == 7e6
    fn = PiecewiseRate.batch([one])
    out = fn(np.asarray([0.0]))
    assert out.shape == (1,) and out[0] == 7e6
    np.testing.assert_array_equal(
        PiecewiseRate.batch([one, one])(np.asarray([3.0, 9e9])),
        [7e6, 7e6])


# ---------------------------------------------------------------------------
# RateBank shapes
# ---------------------------------------------------------------------------
def test_rate_bank_empty_lane_set():
    bank = RateBank([])
    assert bank.m == 0 and bank.fallback == []
    out = bank.sample(0.0, np.zeros(0, bool))
    assert out.shape == (0,)


def test_rate_bank_single_lane():
    bank = RateBank([PiecewiseRate([2.0, 4.0], [1e6, 9e6])])
    assert bank.sample(1.0, np.ones(1, bool))[0] == 1e6
    assert bank.sample(3.0, np.ones(1, bool))[0] == 9e6


def test_rate_bank_mixed_widths_and_callable():
    """Tables of different widths pad into one lookup; callable lanes
    live in the fallback slot and are sampled only while copying."""
    calls = []

    def cb(t):
        calls.append(t)
        return 4e6
    bank = RateBank([PiecewiseRate([1.0], [2e6]),
                     PiecewiseRate([5.0, 6.0, 9.0], [1.0, 2.0, 3.0]),
                     cb])
    assert [i for i, _ in bank.fallback] == [2]
    mask = np.asarray([True, True, False])
    out = bank.sample(5.5, mask)
    assert out[0] == 2e6 and out[1] == 2.0 and out[2] == 0.0
    assert calls == []                     # stopped lane never sampled
    out = bank.sample(5.5, np.ones(3, bool))
    assert out[2] == 4e6 and calls == [5.5]


def test_table_fn_matches_scalar_lookup():
    """The public stacked lookup indexes the same tables as scalar calls,
    bit-for-bit (the parity contract what_if_cost_batch relies on)."""
    tables = [PiecewiseRate([3.0, 7.0, 11.0], [5.0, 6.0, 7.0], offset=1.5),
              PiecewiseRate([1.0], [2e6]),
              PiecewiseRate([2.0, 60.0], [1e6, 8e6], offset=0.25)]
    bank = RateBank(tables)
    t = np.asarray([0.9, 55.5, 123.75])
    got = bank.table_fn(t).copy()          # reused buffer: copy to keep
    np.testing.assert_array_equal(
        got, [tables[0](0.9), tables[1](55.5), tables[2](123.75)])


# ---------------------------------------------------------------------------
# membership churn on the plane
# ---------------------------------------------------------------------------
def test_callable_lane_dropped_mid_flight_rebuilds_bank():
    """Regression: a lane registered with a plain CALLABLE rate completes
    and is dropped while table lanes stay in flight — the rebuilt bank
    must shrink its padded lookup consistently, and the survivors'
    outcomes must be unchanged vs running without the callable lane ever
    present (it shares no contention once drained)."""
    topo = network.Topology.single_link(125e6)
    table = PiecewiseRate([60.0, 120.0], [2e6, 1e6])

    def run(with_callable):
        plane = MigrationPlane(topo)
        if with_callable:
            # tiny state: drains long before the table lanes
            plane.launch(MigrationRequest("cb", 0.0, 1e6),
                         lambda t: 0.5e6, 0.0)
        for j in range(3):
            plane.launch(MigrationRequest(f"t{j}", 0.0, 1e9 + j * 1e8),
                         table, 0.0)
        done = {}
        t = 0.0
        while plane.in_flight:
            t += 1.0
            for req, out in plane.advance(t):
                done[req.job_id] = (out.total_time, out.bytes_sent,
                                    out.rounds, out.stop_reason)
        return done

    with_cb = run(True)
    assert "cb" in with_cb and len(with_cb) == 4
    # callable lane's own outcome is sane
    assert with_cb["cb"][3] == "dirty_low"
    without = run(False)
    # survivors: the callable lane contended while present, so compare
    # against a reference run where it also ran — instead assert the
    # rebuilt bank kept every table lane bit-consistent between the
    # vectorized and scalar-reference planes
    plane_ref = MigrationPlane(topo, vectorized=False)
    plane_ref.launch(MigrationRequest("cb", 0.0, 1e6),
                     lambda t: 0.5e6, 0.0)
    for j in range(3):
        plane_ref.launch(MigrationRequest(f"t{j}", 0.0, 1e9 + j * 1e8),
                         table, 0.0)
    done_ref = {}
    t = 0.0
    while plane_ref.in_flight:
        t += 1.0
        for req, out in plane_ref.advance(t):
            done_ref[req.job_id] = (out.total_time, out.bytes_sent,
                                    out.rounds, out.stop_reason)
    assert with_cb == done_ref
    assert set(without) == {"t0", "t1", "t2"}


def test_what_if_cost_batch_empty_and_parity():
    """strunk.what_if_cost_batch: the empty candidate set is answered
    directly, and tabular specs match per-spec scalar simulation."""
    out = strunk.what_if_cost_batch(np.zeros(0), np.zeros(0), [],
                                    np.zeros(0), full=True)
    assert len(out) == 0 and out.bytes_sent.shape == (0,)
    table = PiecewiseRate([60.0, 120.0], [30e6, 1e6])
    v = np.asarray([1e9, 2e9])
    bw = np.asarray([125e6, 62.5e6])
    got = strunk.what_if_cost_batch(v, bw, [table, 4e6],
                                    np.asarray([0.0, 30.0]), full=True)
    ref0 = strunk.simulate_precopy_reference(1e9, 125e6, table,
                                             start_time=0.0)
    ref1 = strunk.simulate_precopy_reference(2e9, 62.5e6, 4e6,
                                             start_time=30.0)
    assert got.bytes_sent[0] == ref0.bytes_sent
    assert got.bytes_sent[1] == ref1.bytes_sent
    assert got.total_time[0] == ref0.total_time
    assert got.total_time[1] == ref1.total_time


def test_rate_bank_concat_and_take_sample_parity():
    """Composed banks (concat of mixed widths, row gathers with repeats)
    sample bit-identically to freshly built banks over the same specs —
    the contract the plane's incremental merges and the stacked defer-k
    sweep rely on."""
    a = PiecewiseRate([10.0, 25.0, 40.0], [1e6, 7e6, 3e6], offset=4.0)
    b = PiecewiseRate([60.0], [5e6])
    specs = [a, 2e6, None, b]
    bank = RateBank(specs)
    joined = RateBank.concat(RateBank(specs[:2]), RateBank(specs[2:]))
    idx = np.asarray([3, 0, 0, 2, 1])
    taken = bank.take(idx)
    fresh = RateBank([specs[i] for i in idx])
    t = np.linspace(0.0, 123.0, 7)
    copy_all = np.ones(len(specs), bool)
    for tt in t:
        assert np.array_equal(bank.sample(tt, copy_all).copy(),
                              joined.sample(tt, copy_all).copy())
        assert np.array_equal(taken.sample(tt, np.ones(5, bool)).copy(),
                              fresh.sample(tt, np.ones(5, bool)).copy())
    assert taken.table_fn.nonneg and joined.table_fn.nonneg


def test_rate_bank_take_remaps_fallback_rows():
    """Gathering rows that hold un-tabulatable callables keeps the
    fallback wiring on the gathered positions."""
    fn = lambda t: 9e6
    bank = RateBank([1e6, fn])
    taken = bank.take(np.asarray([1, 0, 1]))
    assert [i for i, _ in taken.fallback] == [0, 2]
    got = taken.sample(5.0, np.ones(3, bool))
    assert list(got) == [9e6, 1e6, 9e6]


def test_what_if_cost_batch_accepts_rate_bank():
    """Passing a prebuilt (tabular) RateBank prices identically to the
    spec list; fallback-bearing banks are rejected loudly."""
    table = PiecewiseRate([60.0, 120.0], [30e6, 1e6])
    v = np.asarray([1e9, 2e9])
    bw = np.asarray([125e6, 62.5e6])
    start = np.asarray([0.0, 30.0])
    via_specs = strunk.what_if_cost_batch(v, bw, [table, 4e6], start,
                                          full=True)
    via_bank = strunk.what_if_cost_batch(v, bw, RateBank([table, 4e6]),
                                         start, full=True)
    assert np.array_equal(via_specs.bytes_sent, via_bank.bytes_sent)
    assert np.array_equal(via_specs.total_time, via_bank.total_time)
    with pytest.raises(ValueError):
        strunk.what_if_cost_batch(v, bw, RateBank([table, lambda t: 1e6]),
                                  start)
