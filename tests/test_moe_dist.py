"""Expert-parallel MoE (shard_map + all-to-all) vs the local reference path.

Runs in a subprocess with 8 forced host devices (the test process itself
must keep the default single device — see conftest note)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import blocks, dist

    cfg = get_config("qwen3_moe_30b_a3b").smoke().replace(
        moe=get_config("qwen3_moe_30b_a3b").smoke().moe.__class__(
            num_experts=8, top_k=2, d_ff_expert=64, capacity_factor=8.0))
    # huge capacity factor -> no drops -> sharded == local exactly
    rng = np.random.default_rng(0)
    params = blocks.moe_init(jax.random.key(1), cfg)
    x = jnp.asarray(rng.standard_normal((4, 32, cfg.d_model)) * 0.1,
                    jnp.bfloat16)

    out_local, aux_local = blocks.moe_ffn(params, cfg, x)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = dist.DistContext(mesh=mesh, batch_axes=("data",),
                           tp_axis="model", seq_shard=False)
    with mesh, dist.use(ctx):
        out_sh, aux_sh = jax.jit(
            lambda p, x: blocks.moe_ffn(p, cfg, x))(params, x)

    err = float(jnp.max(jnp.abs(out_sh.astype(jnp.float32)
                                - out_local.astype(jnp.float32))))
    aerr = abs(float(aux_sh) - float(aux_local))
    assert err < 5e-2, f"out mismatch {err}"
    assert aerr < 1e-3, f"aux mismatch {aerr}"

    # gradients flow through the a2a dispatch
    def loss(p):
        with dist.use(ctx):
            o, a = blocks.moe_ffn(p, cfg, x)
        return jnp.sum(o.astype(jnp.float32)) + a
    with mesh:
        g = jax.jit(jax.grad(loss))(params)
    gn = sum(float(jnp.sum(jnp.abs(l.astype(jnp.float32))))
             for l in jax.tree.leaves(g))
    assert gn > 0 and np.isfinite(gn), gn
    print("MOE_DIST_OK", err, aerr)
""")


def test_sharded_moe_matches_local():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=480)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MOE_DIST_OK" in r.stdout
