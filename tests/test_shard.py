"""Sharded decide plane: bit-parity across shard counts, overlapped-tick
bit-identity, and the mesh plumbing.

Multi-device tests skip unless the process started with
``XLA_FLAGS=--xla_force_host_platform_device_count>=2`` — tier-1 pytest
deliberately sees the real single CPU device (tests/conftest.py), and
``scripts/verify.sh`` runs this file again in a forced 2-device pass, which
is where the shard_map paths actually execute."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import characterize, postpone as pp, shard
from repro.core.fleetsim import make_training_nb, table3_traces
from repro.core.surveillance import SurveillanceEngine
from repro.core.telemetry import FleetTelemetry
from repro.kernels import ops

WINDOW = 128

multi = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >= 2 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count); scripts/verify.sh runs the forced pass")


@pytest.fixture(scope="module")
def nb():
    return make_training_nb()


def _build(nb, *, shards=None, overlap=False, n_jobs=13, extra=0):
    """Deterministic fleet + engine: same args -> identical telemetry, so
    engines built with different shard/overlap knobs are comparable."""
    rng = np.random.default_rng(0)
    traces = list(table3_traces(phase_s=4.0).values())
    fleet = FleetTelemetry(n_jobs, capacity=WINDOW)
    eng = SurveillanceEngine(shards=shards, overlap=overlap)
    for i in range(n_jobs):
        eng.register(f"j{i}", fleet.view(i), nb, window=WINDOW)
    t0s = [rng.uniform(0, traces[i % len(traces)].cycle_s)
           for i in range(n_jobs)]
    fields = fleet.fields
    for s in range(WINDOW + extra):
        vals = np.asarray(
            [[traces[i % len(traces)].sample_indexes(t0s[i] + s, rng)[f]
              for f in fields] for i in range(n_jobs)])
        fleet.record_fleet(s, vals)
    return eng, fleet


# -- mesh plumbing ----------------------------------------------------------
def test_decide_mesh_single_device_path():
    assert shard.decide_mesh(None) is None
    assert shard.decide_mesh(1) is None
    with pytest.raises(ValueError):
        shard.decide_mesh(jax.device_count() + 1)


@multi
def test_decide_mesh_shape():
    mesh = shard.decide_mesh(2)
    assert mesh.axis_names == ("shard",) and mesh.devices.size == 2


# -- overlapped ticks (runs on any device count) ----------------------------
def test_overlap_tick_bit_identity(nb):
    sync, _ = _build(nb, overlap=False)
    lazy, _ = _build(nb, overlap=True)
    now = WINDOW - 1
    r_sync = sync.tick(now)
    r_lazy = lazy.tick(now)
    assert not r_sync.pending
    assert r_lazy.pending                  # decide still in flight
    assert r_lazy.remain == r_sync.remain  # first access materializes
    assert not r_lazy.pending
    assert (r_lazy.refitted, r_lazy.fleet) == (r_sync.refitted, r_sync.fleet)


def test_overlap_values_survive_later_refits(nb):
    """The lazy dict must reflect the fleet AT DISPATCH: a refit between
    tick and first .remain access must not leak into the old result."""
    eng, fleet = _build(nb, overlap=True, extra=0)
    ref, _ = _build(nb, overlap=False)
    now = WINDOW - 1
    res = eng.tick(now)
    want = ref.tick(now).remain
    # mutate the engine before materializing: new samples + forced refit
    rng = np.random.default_rng(99)
    for s in range(WINDOW, WINDOW + 40):
        fleet.record_fleet(s, rng.random((13, len(fleet.fields))))
    eng.refresh(force=True)
    assert res.remain == want


# -- bit-parity across shard counts -----------------------------------------
@multi
@pytest.mark.parametrize("overlap", [False, True])
def test_tick_bit_parity_across_shard_counts(nb, overlap):
    ref, _ = _build(nb, shards=None, overlap=False)
    want = ref.tick(WINDOW - 1)
    counts = [2] + ([jax.device_count()] if jax.device_count() > 2 else [])
    for k in counts:
        got_eng, _ = _build(nb, shards=k, overlap=overlap)
        got = got_eng.tick(WINDOW - 1)
        assert got.remain == want.remain, k
        assert (got.refitted, got.fleet) == (want.refitted, want.fleet)
        for jid, job in ref.jobs.items():
            other = got_eng.jobs[jid]
            assert other.model.period == job.model.period, (k, jid)
            np.testing.assert_array_equal(other.model.profile_lm,
                                          job.model.profile_lm)
            np.testing.assert_array_equal(other.lm_series, job.lm_series)


@multi
def test_next_refresh_step_sharded(nb):
    """Staleness horizons are derived from the fitted models, so sharded
    and single-device engines must agree step-for-step."""
    ref, _ = _build(nb, shards=None, extra=9)
    got, _ = _build(nb, shards=2, extra=9)
    ref.refresh()
    got.refresh()
    for now in (WINDOW, WINDOW + 3, WINDOW + 50):
        assert got.next_refresh_step(now) == ref.next_refresh_step(now)


# -- sharded stage wrappers -------------------------------------------------
@multi
@pytest.mark.parametrize("J", [4, 7])          # multiple and non-multiple
def test_classify_lm_sharded_parity(nb, J):
    rng = np.random.default_rng(3)
    W = rng.random((J, 64, 6))
    mesh = shard.decide_mesh(2)
    got = shard.classify_lm(nb, W, mesh)
    want = characterize.classify_lm_batch(nb, W)
    np.testing.assert_array_equal(got, want)


def test_classify_lm_matches_full_classifier(nb):
    """The lm fast path must be bit-identical to the full classifier's lm
    output (same argmax, same suitability table)."""
    rng = np.random.default_rng(4)
    W = rng.random((5, 96, 6))
    _, lm_full, _ = characterize.classify_series_batch(nb, W)
    np.testing.assert_array_equal(characterize.classify_lm_batch(nb, W),
                                  lm_full)


@multi
@pytest.mark.parametrize("J", [6, 9])
def test_postpone_rows_sharded_parity(J):
    rng = np.random.default_rng(7)
    P_max = 16
    profiles = jnp.asarray(rng.integers(-1, 2, (J, P_max)), jnp.int8)
    periods = jnp.asarray(rng.integers(0, P_max + 1, J), jnp.int32)
    m_now = jnp.asarray(rng.integers(0, 500, J), jnp.int32)
    mesh = shard.decide_mesh(2)
    got = np.asarray(shard.postpone_rows(profiles, periods, m_now, mesh))
    want = np.asarray(pp.postpone_batch_jit(profiles, periods, m_now))
    np.testing.assert_array_equal(got, want)


@multi
def test_kernel_ops_mesh_row_sharding():
    rng = np.random.default_rng(11)
    mesh = shard.decide_mesh(2)
    x = jnp.asarray(rng.standard_normal((5, 256)), jnp.float32)
    got = np.asarray(ops.power_spectrum(x, center=True, mesh=mesh))
    want = np.asarray(ops.power_spectrum(x, center=True))
    np.testing.assert_array_equal(got, want)
    lags = jnp.arange(3, 40, dtype=jnp.int32)
    got = np.asarray(ops.autocorr_score(x, lags, mesh=mesh))
    want = np.asarray(ops.autocorr_score(x, lags))
    np.testing.assert_array_equal(got, want)
