"""LMCM decision tests: postpone into LM windows, provider max-wait,
customer-deadline cancellation, and end-to-end fleet results (ALMA beats
immediate on cyclic workloads)."""
import numpy as np
import pytest

from repro.core.fleetsim import (FleetSim, SimJob, WorkloadTrace,
                                 table3_traces)
from repro.core.orchestrator import LMCM, MigrationRequest


def _sim(policy, *, max_wait=600.0, seed=0, trace=None, warm=1200.0):
    trace = trace or WorkloadTrace([("MEM", 60), ("CPU", 60)], 3600)
    jobs = [SimJob("j0", trace, 1e9)]
    return FleetSim(jobs, policy=policy, warmup_s=warm, max_wait=max_wait,
                    seed=seed), jobs


def test_alma_fires_in_lm_phase():
    sim, jobs = _sim("alma-paper")
    # submit right at the start of a MEM (NLM) phase
    t_mem = (int(sim.now / 120) + 1) * 120 + 1.0
    sim.run_idle(t_mem - sim.now)
    res = sim.run_with_plan([MigrationRequest("j0", sim.now, 1e9)],
                            horizon_s=1200.0)
    assert len(res.per_job) == 1
    assert res.lm_hit_rate == 1.0


def test_immediate_fires_immediately():
    sim, jobs = _sim("immediate")
    t0 = sim.now
    res = sim.run_with_plan([MigrationRequest("j0", t0, 1e9)],
                            horizon_s=600.0)
    req = res.migrations[0]
    assert req.scheduled_at - t0 <= sim.dt * 2


def test_max_wait_cap():
    lmcm = LMCM(policy="alma-paper", max_wait=30.0)
    # no registered job -> decide returns 0/immediate; registered acyclic too
    req = MigrationRequest("nojob", 0.0, 1e9)
    assert lmcm.decide(req, 0.0) == 0.0


def test_deadline_cancellation():
    sim, jobs = _sim("alma-paper")
    t_mem = (int(sim.now / 120) + 1) * 120 + 1.0
    sim.run_idle(t_mem - sim.now)
    # workload "ends" before any LM window could be reached
    req = MigrationRequest("j0", sim.now, 1e9, deadline=sim.now + 2.0)
    sim.lmcm.submit(req, sim.now)
    assert req.decision == "cancelled"


def test_alma_beats_immediate_on_cyclic_fleet():
    traces = table3_traces(phase_s=60.0)
    results = {}
    for policy in ("immediate", "alma-paper"):
        jobs = [SimJob(j, tr, 1e9) for j, tr in traces.items()]
        sim = FleetSim(jobs, policy=policy, warmup_s=1200.0, seed=3)
        plan = [MigrationRequest(j.job_id, sim.now + 5.0, j.v_bytes)
                for j in jobs]
        results[policy] = sim.run_with_plan(plan, horizon_s=4000.0)
    assert (results["alma-paper"].total_bytes
            <= results["immediate"].total_bytes)
    assert (results["alma-paper"].mean_migration_time
            <= results["immediate"].mean_migration_time)
    assert results["alma-paper"].lm_hit_rate >= 0.75


def test_concurrency_limit_respected():
    traces = table3_traces()
    jobs = [SimJob(j, tr, 1e9) for j, tr in traces.items()]
    sim = FleetSim(jobs, policy="immediate", warmup_s=60.0,
                   max_concurrent=1, seed=0)
    for j in jobs:
        sim.lmcm.submit(MigrationRequest(j.job_id, sim.now, j.v_bytes),
                        sim.now)
    due = sim.lmcm.due(sim.now + 1)
    assert len(due) <= 1
