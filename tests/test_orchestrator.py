"""LMCM decision tests: postpone into LM windows, provider max-wait,
customer-deadline cancellation, and end-to-end fleet results (ALMA beats
immediate on cyclic workloads)."""
import numpy as np
import pytest

from repro.core.fleetsim import (FleetSim, SimJob, WorkloadTrace,
                                 table3_traces)
from repro.core.orchestrator import LMCM, MigrationRequest


def _sim(policy, *, max_wait=600.0, seed=0, trace=None, warm=1200.0):
    trace = trace or WorkloadTrace([("MEM", 60), ("CPU", 60)], 3600)
    jobs = [SimJob("j0", trace, 1e9)]
    return FleetSim(jobs, policy=policy, warmup_s=warm, max_wait=max_wait,
                    seed=seed), jobs


def test_alma_fires_in_lm_phase():
    sim, jobs = _sim("alma-paper")
    # submit right at the start of a MEM (NLM) phase
    t_mem = (int(sim.now / 120) + 1) * 120 + 1.0
    sim.run_idle(t_mem - sim.now)
    res = sim.run_with_plan([MigrationRequest("j0", sim.now, 1e9)],
                            horizon_s=1200.0)
    assert len(res.per_job) == 1
    assert res.lm_hit_rate == 1.0


def test_immediate_fires_immediately():
    sim, jobs = _sim("immediate")
    t0 = sim.now
    res = sim.run_with_plan([MigrationRequest("j0", t0, 1e9)],
                            horizon_s=600.0)
    req = res.migrations[0]
    assert req.scheduled_at - t0 <= sim.dt * 2


def test_max_wait_cap():
    lmcm = LMCM(policy="alma-paper", max_wait=30.0)
    # no registered job -> decide returns 0/immediate; registered acyclic too
    req = MigrationRequest("nojob", 0.0, 1e9)
    assert lmcm.decide(req, 0.0) == 0.0


def test_deadline_cancellation():
    sim, jobs = _sim("alma-paper")
    t_mem = (int(sim.now / 120) + 1) * 120 + 1.0
    sim.run_idle(t_mem - sim.now)
    # workload "ends" before any LM window could be reached
    req = MigrationRequest("j0", sim.now, 1e9, deadline=sim.now + 2.0)
    sim.lmcm.submit(req, sim.now)
    assert req.decision == "cancelled"


def test_alma_beats_immediate_on_cyclic_fleet():
    traces = table3_traces(phase_s=60.0)
    results = {}
    for policy in ("immediate", "alma-paper"):
        jobs = [SimJob(j, tr, 1e9) for j, tr in traces.items()]
        sim = FleetSim(jobs, policy=policy, warmup_s=1200.0, seed=3)
        plan = [MigrationRequest(j.job_id, sim.now + 5.0, j.v_bytes)
                for j in jobs]
        results[policy] = sim.run_with_plan(plan, horizon_s=4000.0)
    assert (results["alma-paper"].total_bytes
            <= results["immediate"].total_bytes)
    assert (results["alma-paper"].mean_migration_time
            <= results["immediate"].mean_migration_time)
    assert results["alma-paper"].lm_hit_rate >= 0.75


def test_concurrency_limit_respected():
    traces = table3_traces()
    jobs = [SimJob(j, tr, 1e9) for j, tr in traces.items()]
    sim = FleetSim(jobs, policy="immediate", warmup_s=60.0,
                   max_concurrent=1, seed=0)
    for j in jobs:
        sim.lmcm.submit(MigrationRequest(j.job_id, sim.now, j.v_bytes),
                        sim.now)
    due = sim.lmcm.due(sim.now + 1)
    assert len(due) <= 1


def test_cancelled_after_scheduling_never_fires():
    """Regression: a request cancelled after being heap-scheduled must not
    be returned by due() — the stale heap entry is skipped on pop."""
    lmcm = LMCM(policy="immediate", max_concurrent=8)
    keep = MigrationRequest("keep", 0.0, 1e9)
    drop = MigrationRequest("drop", 0.0, 1e9)
    lmcm.submit(drop, 0.0)
    lmcm.submit(keep, 0.0)
    assert drop.decision == "scheduled"
    lmcm.cancel(drop)
    assert drop.decision == "cancelled"
    fired = lmcm.due(10.0)
    assert [r.job_id for r in fired] == ["keep"]
    assert all(r.decision == "running" for r in fired)
    assert drop in lmcm.log and drop.decision == "cancelled"
    # cancelling a running/done request is a no-op
    lmcm.cancel(keep)
    assert keep.decision == "running"


def test_cancel_then_resubmit_same_burst():
    """Regression: a request cancelled and re-requested within the same
    burst must fire exactly once, at the RE-REQUESTED time — the stale
    heap entry from the first submit must neither fire it early nor
    consume/drop the live entry."""
    lmcm = LMCM(policy="immediate", max_concurrent=8)
    req = MigrationRequest("flip", 0.0, 1e9)
    lmcm.submit(req, 0.0)               # entry A at t=0
    lmcm.cancel(req)
    req.decision = "pending"            # plan revised again: re-request
    lmcm.submit(req, 5.0)               # entry B at t=5
    assert req.decision == "scheduled" and req.scheduled_at == 5.0
    # entry A (t=0) is due now, but it is stale: nothing may fire early
    assert lmcm.due(1.0) == []
    assert req.decision == "scheduled"
    # at t=5 the live entry fires — exactly once
    fired = lmcm.due(5.0)
    assert [r.job_id for r in fired] == ["flip"]
    assert req.decision == "running"
    lmcm.finish(req, None)
    assert lmcm.due(10.0) == []         # no duplicate from the stale entry


def test_cancel_resubmit_later_entry_not_dropped():
    """The mirror ordering: first submit schedules LATE, the resubmit
    schedules EARLY — popping the early live entry must not be confused
    by the late stale one remaining in the heap."""
    lmcm = LMCM(policy="immediate", max_concurrent=8)
    req = MigrationRequest("flip", 0.0, 1e9)
    lmcm.submit(req, 0.0)
    # force the first entry far into the future, as a postponement would
    lmcm.queue.clear()
    lmcm._push(req, 100.0)
    lmcm.cancel(req)
    req.decision = "pending"
    lmcm.submit(req, 2.0)               # live entry at t=2
    fired = lmcm.due(3.0)
    assert [r.job_id for r in fired] == ["flip"]
    lmcm.finish(req, None)
    assert lmcm.due(200.0) == []        # stale late entry is inert


def test_contended_fleet_alma_beats_immediate():
    """>=8 simultaneous requests over one shared 1 Gbit/s link: ALMA's
    postponement de-correlates both the dirty phases AND the link
    contention, so it wins on bytes and on summed migration time."""
    results = {}
    for policy in ("immediate", "alma-paper"):
        traces = table3_traces(phase_s=60.0, replicas=2)    # 8 jobs
        jobs = [SimJob(j, tr, 1e9) for j, tr in traces.items()]
        sim = FleetSim(jobs, policy=policy, warmup_s=1200.0,
                       max_concurrent=8, seed=5)
        plan = [MigrationRequest(j.job_id, sim.now + 5.0, j.v_bytes)
                for j in jobs]
        results[policy] = sim.run_with_plan(plan, horizon_s=4000.0)
    alma, trad = results["alma-paper"], results["immediate"]
    assert len(trad.per_job) == 8 and len(alma.per_job) == 8
    assert alma.total_bytes < trad.total_bytes
    assert alma.total_time < trad.total_time
    # conservation on the shared link for both policies
    for res in results.values():
        assert res.link_bytes["migration-net"] <= 125e6 * res.makespan * (1 + 1e-9)


def test_min_share_launch_gate():
    """With min_share_frac set, due() defers launches whose realized share
    would be too small — including a simultaneous release burst, where
    requests freed in the SAME call must be counted against each other."""
    from repro.core.network import Topology
    from repro.core.plane import MigrationPlane
    lmcm = LMCM(policy="immediate", max_concurrent=8, bandwidth=125e6,
                min_share_frac=0.5, max_wait=60.0, sample_period=1.0)
    plane = MigrationPlane(Topology.single_link(125e6))
    lmcm.bandwidth_probe = lambda req, extra=0: \
        plane.probe_bandwidth(req.src, req.dst, extra)
    reqs = [MigrationRequest(f"j{i}", 0.0, 1e9) for i in range(8)]
    for r in reqs:
        lmcm.submit(r, 0.0)
    fired = lmcm.due(0.0)
    # floor = cap/2: exactly two fit (first is ungated, the second probes at
    # cap/2 == floor), the other six defer rather than dilute the burst
    assert len(fired) == 2
    for r in fired:
        plane.launch(r, 2e6, 0.0)
    assert lmcm.due(1.0) == []                       # still at the floor
    assert all(r.decision == "scheduled" for r in reqs[2:])
    # drain the plane -> deferred requests launch on the idle link
    plane.advance(np.inf)
    for r in fired:
        lmcm.finish(r, None)
    assert len(lmcm.due(2.0)) == 2


def test_gate_floor_uses_path_capacity_not_nominal_bandwidth():
    """Regression (multi-rack): the share floor must be a fraction of the
    request's UNCONTENDED PATH CAPACITY, not of the nominal single-link
    speed — a cross-rack transfer through a 1:4-oversubscribed core can
    never realize the access speed, and the old nominal-referenced floor
    deferred it forever even with the fabric nearly idle."""
    from repro.core.fabric import ShardedPlane
    from repro.core.network import Topology
    cap = 125e6
    # cross-rack bottleneck: the core at cap/2
    topo = Topology.multi_rack(2, cap, core_capacity=cap / 2,
                               hosts_per_rack=2)
    plane = ShardedPlane(topo)
    lmcm = LMCM(policy="immediate", max_concurrent=8, bandwidth=cap,
                min_share_frac=0.6, max_wait=600.0, sample_period=1.0)
    lmcm.bandwidth_probe = lambda req, extra=0, pending=(): \
        plane.probe_bandwidth(req.src, req.dst, extra, pending=pending)
    lmcm.path_capacity = lambda req: plane.path_capacity(req.src, req.dst)
    # something in flight elsewhere so the gate is active (not idle)
    plane.launch(MigrationRequest("bg", 0.0, 1e12,
                                  src="r1h0", dst="r1h1"), 1e6, 0.0)
    req = MigrationRequest("x", 0.0, 1e9, src="r0h0", dst="r1h0")
    req.path = topo.path(req.src, req.dst)
    lmcm.running.append(MigrationRequest("bg", 0.0, 1e12,
                                         src="r1h0", dst="r1h1"))
    lmcm.running[0].decision = "running"
    lmcm.submit(req, 0.0)
    # realized share: the cross path shares acc:r1 with bg -> cap/2 = the
    # core bottleneck = its full uncontended capacity. New floor: 0.6 x
    # cap/2 -> passes. Old floor 0.6 x cap -> deferred forever.
    fired = lmcm.due(0.0)
    assert [r.job_id for r in fired] == ["x"]
    # sanity: without the wired path_capacity the old behavior deferred
    lmcm2 = LMCM(policy="immediate", max_concurrent=8, bandwidth=cap,
                 min_share_frac=0.6, max_wait=600.0, sample_period=1.0)
    lmcm2.bandwidth_probe = lmcm.bandwidth_probe
    lmcm2.running = lmcm.running
    req2 = MigrationRequest("x2", 0.0, 1e9, src="r0h0", dst="r1h0")
    req2.path = topo.path(req2.src, req2.dst)
    lmcm2.submit(req2, 0.0)
    assert lmcm2.due(0.0) == []


def test_same_tick_burst_diluted_below_floor_defers_both():
    """Regression: two same-tick launches that would each dilute below
    the share floor must BOTH defer — the gate probes cumulatively within
    the tick instead of admitting each as if alone."""
    from repro.core.network import Topology
    from repro.core.plane import MigrationPlane
    cap = 125e6
    lmcm = LMCM(policy="immediate", max_concurrent=8, bandwidth=cap,
                min_share_frac=0.4, max_wait=60.0, sample_period=1.0)
    plane = MigrationPlane(Topology.single_link(cap))
    lmcm.bandwidth_probe = lambda req, extra=0, pending=(): \
        plane.probe_bandwidth(req.src, req.dst, extra, pending=pending)
    lmcm.path_capacity = lambda req: plane.path_capacity(req.src, req.dst)
    # two lanes already in flight: a third would get cap/3 > floor, a
    # third AND fourth would each get cap/4 < floor = 0.4 x cap
    for i in range(2):
        bg = MigrationRequest(f"bg{i}", 0.0, 1e12)
        plane.launch(bg, 1e6, 0.0)
        bg.decision = "running"
        lmcm.running.append(bg)
    reqs = [MigrationRequest(f"j{i}", 0.0, 1e9) for i in range(2)]
    for r in reqs:
        r.path = plane.topology.path(r.src, r.dst)
        lmcm.submit(r, 0.0)
    assert lmcm.due(0.0) == []
    assert all(r.decision == "scheduled" for r in reqs)


def test_same_tick_disjoint_domains_not_spuriously_deferred():
    """Regression: same-tick co-launches in DISJOINT migration domains
    must not dilute each other. The old gate approximated co-launches as
    clones of the probed request's own path, so an intra-r1 launch halved
    the probed share of an intra-r0 candidate that shares no link with
    it; probing with the actual paths admits both."""
    from repro.core.fabric import ShardedPlane
    from repro.core.network import Topology
    cap = 125e6
    topo = Topology.multi_rack(3, cap, core_capacity=3 * cap,
                               hosts_per_rack=2)
    plane = ShardedPlane(topo)
    lmcm = LMCM(policy="immediate", max_concurrent=8, bandwidth=cap,
                min_share_frac=0.6, max_wait=60.0, sample_period=1.0)
    lmcm.bandwidth_probe = lambda req, extra=0, pending=(): \
        plane.probe_bandwidth(req.src, req.dst, extra, pending=pending)
    lmcm.path_capacity = lambda req: plane.path_capacity(req.src, req.dst)
    # background lane in r2 so the gate is active for the whole burst
    bg = MigrationRequest("bg", 0.0, 1e12, src="r2h0", dst="r2h1")
    plane.launch(bg, 1e6, 0.0)
    bg.decision = "running"
    lmcm.running.append(bg)
    # same-tick candidates in two OTHER disjoint racks: neither shares a
    # link with bg or with each other
    a = MigrationRequest("a", 0.0, 1e9, src="r0h0", dst="r0h1")
    b = MigrationRequest("b", 0.0, 1e9, src="r1h0", dst="r1h1")
    for r in (a, b):
        r.path = topo.path(r.src, r.dst)
        lmcm.submit(r, 0.0)
    # legacy clone counting probed b as "a's launch = a clone of b's own
    # path": acc:r1 at cap/2 < 0.6 x cap -> spurious deferral. Actual-path
    # probing sees a's path is disjoint: both launch at full share.
    fired = lmcm.due(0.0)
    assert [r.job_id for r in fired] == ["a", "b"]


def test_realized_bandwidth_reaches_decisions():
    """With lanes in flight, the LMCM's deadline check uses the plane's
    fair-share probe: a migration that would fit at full link speed is
    cancelled when the contended share makes it miss its deadline."""
    trace = WorkloadTrace([("CPU", 60), ("IO", 60)], 3600)
    jobs = [SimJob(f"j{i}", trace, 1e9) for i in range(4)]
    sim = FleetSim(jobs, policy="immediate", warmup_s=60.0,
                   max_concurrent=4, seed=0)
    # saturate the link with three other transfers
    for i in range(3):
        sim.plane.launch(MigrationRequest(f"j{i}", sim.now, 4e9), 1e6,
                         sim.now)
    # V/B = 8 s uncontended, 32 s at a quarter share
    req = MigrationRequest("j3", sim.now, 1e9, deadline=sim.now + 16.0)
    assert sim.lmcm.effective_bandwidth(req) == 125e6 / 4
    sim.lmcm.submit(req, sim.now)
    assert req.decision == "cancelled"
    # the same deadline is feasible on an idle link
    idle = FleetSim(jobs, policy="immediate", warmup_s=60.0, seed=0)
    req2 = MigrationRequest("j3", idle.now, 1e9, deadline=idle.now + 16.0)
    idle.lmcm.submit(req2, idle.now)
    assert req2.decision == "scheduled"
