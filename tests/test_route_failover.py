"""Correlated rack/ToR outage on a pod/spine fabric (ISSUE 8 satellite):
``FaultPlan.access_outage`` takes a pod uplink to capacity 0 AND aborts
every lane riding it (``link_fail``); with ``route_aware=True`` the
LMCM retries re-route onto a surviving spine plane instead of stalling,
and per-link byte conservation holds across abort -> retry -> reroute.
"""
from collections import defaultdict

import numpy as np
import pytest

from repro.core import network
from repro.core.consolidation import Host, Placement
from repro.core.fleetsim import FleetSim, SimJob, WorkloadTrace
from repro.core.orchestrator import MigrationRequest
from repro.scenarios.faults import FaultPlan

CAP = 125e6
DEAD = "pod:p0s0"


def _fabric_sim(*, route_aware, fault_plan=None, n_jobs=4, seed=0):
    topo = network.Topology.pod_spine(
        2, 2, access_capacity=CAP,
        pod_oversubscription=1.0, spine_oversubscription=1.0, n_spines=2)
    trace = WorkloadTrace([("MEM", 60.0), ("CPU", 60.0)], 120.0)
    jobs = [SimJob(f"j{i}", trace, 2e9) for i in range(n_jobs)]
    # jobs live on pod-0 hosts; every host is a valid endpoint
    hosts = {h: Host(h, float(n_jobs)) for h in sorted(topo.host_links)}
    placement = Placement(hosts)
    for i, j in enumerate(jobs):
        placement.assign(j.job_id, f"p0r{i % 2}h{(i // 2) % 2}", 1.0)
    sim = FleetSim(jobs, policy="immediate", warmup_s=0.0, seed=seed,
                   max_concurrent=8, topology=topo, placement=placement,
                   route_aware=route_aware, fault_plan=fault_plan)
    plan = [MigrationRequest(j.job_id, sim.now + 2.0, j.v_bytes,
                             src=placement.host_of(j.job_id),
                             dst=f"p1r{i % 2}h{(i // 2) % 2}")
            for i, j in enumerate(jobs)]
    return sim, plan


def _check_link_conservation(res, rtol=1e-6):
    """Every byte the plane billed to a link is accounted for by either
    an aborted lane's settled partial or a completed migration."""
    expected = defaultdict(float)
    for _, _, partial, path in res.abort_log:
        for link in path:
            expected[link] += partial
    for req in res.migrations:
        for link in req.path:
            expected[link] += res.per_job[req.job_id].bytes_sent
    links = set(expected) | {l for l, b in res.link_bytes.items() if b}
    assert links
    for link in links:
        assert res.link_bytes.get(link, 0.0) == pytest.approx(
            expected.get(link, 0.0), rel=rtol), link


def test_route_aware_spreads_across_planes():
    """Healthy fabric: pick_route puts concurrent cross-pod lanes on
    more than one spine plane."""
    sim, plan = _fabric_sim(route_aware=True)
    res = sim.run_with_plan(plan, horizon_s=3000.0)
    assert len(res.per_job) == len(plan) and not res.failed_jobs
    planes = {l for r in res.migrations for l in r.path
              if l.startswith("pod:p0")}
    assert len(planes) > 1, planes


def test_access_outage_fails_over_to_surviving_route():
    fp = FaultPlan.access_outage(10.0, DEAD)
    sim, plan = _fabric_sim(route_aware=True, fault_plan=fp)
    res = sim.run_with_plan(plan, horizon_s=3000.0)
    # lanes were riding the dead uplink and aborted when it failed
    assert res.n_aborts > 0
    assert all(DEAD in path for _, _, _, path in res.abort_log)
    # every job still completed — the retries re-routed around the loss
    assert len(res.per_job) == len(plan) and not res.failed_jobs
    aborted = {j for j, _, _, _ in res.abort_log}
    assert aborted
    for req in res.migrations:
        if req.job_id in aborted:
            assert DEAD not in req.path, req.job_id
    # the dead link froze: only pre-outage partials are billed to it
    partials = sum(p for _, _, p, path in res.abort_log if DEAD in path)
    assert res.link_bytes.get(DEAD, 0.0) == pytest.approx(partials)
    _check_link_conservation(res)


def test_access_outage_conservation_seeded():
    for seed in range(3):
        fp = FaultPlan.access_outage(10.0, DEAD, restore_at=400.0,
                                     restore_capacity=CAP)
        sim, plan = _fabric_sim(route_aware=True, fault_plan=fp,
                                seed=seed)
        res = sim.run_with_plan(plan, horizon_s=3000.0)
        assert not res.failed_jobs
        _check_link_conservation(res)


def test_link_fail_vs_degrade_semantics():
    """``link_fail`` aborts the lanes; a 0.0 ``link_degrade`` stalls them
    in place — same capacity change, different lane fate."""
    res = {}
    for kind, fp in [
            ("fail", FaultPlan.access_outage(10.0, DEAD,
                                             restore_at=200.0,
                                             restore_capacity=CAP)),
            ("degrade", FaultPlan.link_brownout(10.0, DEAD, 0.0,
                                                restore_at=200.0,
                                                restore_capacity=CAP))]:
        sim, plan = _fabric_sim(route_aware=False, fault_plan=fp)
        res[kind] = sim.run_with_plan(plan, horizon_s=3000.0)
    assert res["fail"].n_aborts > 0
    assert res["degrade"].n_aborts == 0
    assert not res["fail"].failed_jobs and not res["degrade"].failed_jobs


def test_route_aware_noop_on_flat_topology():
    """On a single-route fabric the knob changes nothing: identical
    outcomes bit for bit."""
    out = {}
    for ra in (False, True):
        topo = network.Topology.multi_rack(2, CAP, core_capacity=CAP,
                                           hosts_per_rack=2)
        trace = WorkloadTrace([("MEM", 60.0), ("CPU", 60.0)], 120.0)
        jobs = [SimJob(f"j{i}", trace, 1e9) for i in range(3)]
        hosts = {h: Host(h, 4.0) for h in sorted(topo.host_links)}
        placement = Placement(hosts)
        for i, j in enumerate(jobs):
            placement.assign(j.job_id, f"r0h{i % 2}", 1.0)
        sim = FleetSim(jobs, policy="immediate", warmup_s=0.0, seed=0,
                       max_concurrent=8, topology=topo,
                       placement=placement, route_aware=ra)
        plan = [MigrationRequest(j.job_id, sim.now + 2.0, j.v_bytes,
                                 src=placement.host_of(j.job_id),
                                 dst=f"r1h{i % 2}")
                for i, j in enumerate(jobs)]
        r = sim.run_with_plan(plan, horizon_s=3000.0)
        out[ra] = (r.total_bytes, r.total_time, r.link_bytes,
                   r.completed_at, sim.now)
    assert out[False] == out[True]
