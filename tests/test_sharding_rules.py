"""Sharding rules: for every architecture, every parameter/optimizer leaf
gets a PartitionSpec whose axes divide the leaf dims on the production mesh
— the static half of what the dry-run proves end-to-end."""
import jax
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch import sharding
from repro.launch.specs import state_specs


class FakeMesh:
    """Duck-typed mesh: rule functions only read .shape / .axis_names."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)
        import numpy as np
        self.devices = np.empty(tuple(shape.values()))


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_param_specs_divide(arch, mesh):
    cfg = get_config(arch)
    tree = state_specs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    n_sharded = 0
    for path, leaf in flat:
        spec = sharding.param_pspec(mesh, path, leaf)
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert leaf.shape[d] % n == 0, (path, spec, leaf.shape)
            n_sharded += 1
    assert n_sharded > 0, f"{arch}: nothing sharded at all"


@pytest.mark.parametrize("arch", ["qwen3_8b", "kimi_k2_1t_a32b",
                                  "rwkv6_1p6b", "zamba2_2p7b"])
def test_big_leaves_are_sharded(arch):
    """Memory safety: every leaf above 64 MiB must shard on >=1 axis."""
    cfg = get_config(arch)
    tree = state_specs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    import math
    for path, leaf in flat:
        nbytes = math.prod(leaf.shape) * leaf.dtype.itemsize
        if nbytes < (64 << 20):
            continue
        spec = sharding.param_pspec(SINGLE, path, leaf)
        assert any(ax is not None for ax in spec), (
            f"{arch}: unsharded {nbytes/2**20:.0f}MiB leaf at "
            + "/".join(str(getattr(k, 'key', '?')) for k in path))
