"""Shared-link network model + migration plane invariants.

Max-min fairness properties of ``network.fair_share``, topology path
resolution, and the execution plane's two core contracts: an uncontended
lane is bit-equal to the scalar Strunk reference, and a contended link
never carries more than capacity x time (conservation)."""
import numpy as np
import pytest

from repro.core import network, strunk
from repro.core.fleetsim import WorkloadTrace
from repro.core.orchestrator import MigrationRequest
from repro.core.plane import MigrationPlane


# ---------------------------------------------------------------------------
# fair share
# ---------------------------------------------------------------------------
def test_single_link_equal_split():
    caps = {"L": 100.0}
    for m in (1, 2, 5, 64):
        r = network.fair_share([("L",)] * m, caps)
        np.testing.assert_allclose(r, 100.0 / m)


def test_bottleneck_flow_frees_slack():
    # B is capped by L2 (4); A picks up the slack on L1 (10 - 4 = 6)
    caps = {"L1": 10.0, "L2": 4.0}
    r = network.fair_share([("L1",), ("L1", "L2")], caps)
    np.testing.assert_allclose(r, [6.0, 4.0])


def test_fair_share_respects_all_capacities():
    rng = np.random.default_rng(0)
    links = [f"L{i}" for i in range(6)]
    caps = {l: float(rng.uniform(1, 20)) for l in links}
    for _ in range(20):
        paths = [tuple(rng.choice(links, size=rng.integers(1, 4),
                                  replace=False))
                 for _ in range(rng.integers(1, 10))]
        rates = network.fair_share(paths, caps)
        assert np.all(rates > 0)
        for l in links:
            used = sum(r for r, p in zip(rates, paths) if l in p)
            assert used <= caps[l] * (1 + 1e-9)
        # max-min: every flow is bottlenecked at some saturated link
        for r, p in zip(rates, paths):
            saturated = any(
                sum(q for q, pp in zip(rates, paths) if l in pp)
                >= caps[l] * (1 - 1e-9) for l in p)
            assert saturated, (r, p)


def test_unconstrained_flow_is_inf():
    r = network.fair_share([(), ("L",)], {"L": 5.0})
    assert np.isinf(r[0]) and r[1] == 5.0


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------
def test_single_link_topology_paths():
    topo = network.Topology.single_link(125e6)
    assert topo.path("h0", "h1") == ("migration-net",)
    assert topo.path("", "") == ("migration-net",)


def test_star_topology_paths():
    topo = network.Topology.star(["h0", "h1", "h2"], 10.0, core_capacity=15.0)
    assert topo.path("h0", "h1") == ("acc:h0", "core", "acc:h1")
    # an intra-domain migration never touches the shared core, and doesn't
    # double-charge its access link
    assert topo.path("h0", "h0") == ("acc:h0",)
    # a coreless star still shares nothing between distinct hosts
    flat = network.Topology.star(["h0", "h1"], 10.0)
    assert flat.path("h0", "h1") == ("acc:h0", "acc:h1")


def test_multi_rack_topology_paths():
    topo = network.Topology.multi_rack(2, 10.0, core_capacity=15.0,
                                       hosts_per_rack=2)
    # intra-rack: only the rack's ToR link
    assert topo.path("r0h0", "r0h1") == ("acc:r0",)
    # cross-rack: src ToR -> core -> dst ToR
    assert topo.path("r0h0", "r1h1") == ("acc:r0", "core", "acc:r1")
    assert topo.access_of("r1h0") == ("acc:r1",)
    named = network.Topology.multi_rack({"a": ["x"], "b": ["y"]}, 5.0,
                                        core_capacity=3.0)
    assert named.path("x", "y") == ("acc:a", "core", "acc:b")


def test_fair_share_dense_matches_sparse():
    rng = np.random.default_rng(7)
    links = [f"L{i}" for i in range(5)]
    caps = {l: float(rng.uniform(1, 20)) for l in links}
    for _ in range(30):
        paths = [tuple(rng.choice(links, size=rng.integers(1, 4),
                                  replace=False))
                 for _ in range(rng.integers(1, 12))]
        sparse = network.fair_share(paths, caps)
        order: list = []
        for p in paths:
            for l in p:
                if l not in order:
                    order.append(l)
        inc = np.zeros((len(order), len(paths)))
        for i, p in enumerate(paths):
            for l in p:
                inc[order.index(l), i] = 1.0
        dense = network.fair_share_dense(
            inc, np.asarray([caps[l] for l in order]))
        np.testing.assert_allclose(dense, sparse, rtol=1e-12)


def test_topology_rejects_unknown_link():
    with pytest.raises(KeyError):
        network.Topology([network.Link("a", 1.0)], {"h": ("a", "b")})


# ---------------------------------------------------------------------------
# the migration plane
# ---------------------------------------------------------------------------
def _outcome_tuple(o):
    return (o.total_time, o.downtime, o.bytes_sent, o.rounds, o.stop_reason)


@pytest.mark.parametrize("v,rate,kw", [
    (1.5e9, 2e6, {}),                       # dirty_low
    (1e9, 0.6 * 125e6, {"max_rounds": 5}),  # max_rounds
    (1e9, 150e6, {}),                       # total_cap
])
def test_uncontended_lane_bit_equals_reference(v, rate, kw):
    plane = MigrationPlane(network.Topology.single_link(125e6), **kw)
    plane.launch(MigrationRequest("j", 0.0, v), rate, 0.0)
    (req, out), = plane.advance(np.inf)
    ref = strunk.simulate_precopy_reference(v, 125e6, rate, **kw)
    assert _outcome_tuple(out) == _outcome_tuple(ref)


def test_uncontended_lane_with_cyclic_trace():
    tr = WorkloadTrace([("MEM", 100), ("CPU", 100)], 200)
    plane = MigrationPlane(network.Topology.single_link(125e6))
    plane.launch(MigrationRequest("j", 0.0, 2e9), tr.dirty_rate, 110.0)
    (req, out), = plane.advance(np.inf)
    ref = strunk.simulate_precopy_reference(2e9, 125e6, tr.dirty_rate,
                                            start_time=110.0)
    assert _outcome_tuple(out) == _outcome_tuple(ref)


def test_contention_slows_both_lanes():
    plane = MigrationPlane(network.Topology.single_link(125e6))
    for j in ("a", "b"):
        plane.launch(MigrationRequest(j, 0.0, 1e9), 3e6, 0.0)
    outs = dict((r.job_id, o) for r, o in plane.advance(np.inf))
    alone = strunk.simulate_precopy_reference(1e9, 125e6, 3e6)
    for o in outs.values():
        assert o.total_time > alone.total_time * 1.5
        # halved bandwidth -> longer rounds -> more dirtying -> more bytes
        assert o.bytes_sent >= alone.bytes_sent


def test_conservation_on_contended_link():
    """Total bytes across a shared 1 Gbit/s link <= capacity x elapsed."""
    cap = 125e6
    plane = MigrationPlane(network.Topology.single_link(cap))
    tr = WorkloadTrace([("MEM", 60), ("CPU", 60)], 120)
    rng = np.random.default_rng(3)
    for j in range(8):
        plane.launch(MigrationRequest(f"j{j}", 0.0,
                                      float(rng.uniform(0.5e9, 2e9))),
                     tr.dirty_rate, 0.0)
    outs = [o for _, o in plane.advance(np.inf)]
    assert len(outs) == 8
    elapsed = plane.now          # all launched at t=0
    moved = plane.link_bytes["migration-net"]
    assert moved <= cap * elapsed * (1 + 1e-9)
    assert moved == pytest.approx(sum(o.bytes_sent for o in outs), rel=1e-9)


def test_staggered_launch_and_stepped_advance():
    """Lanes joining mid-flight shrink everyone's share; stepping the plane
    in 1 s chunks reaches the same completion set as one big advance."""
    plane = MigrationPlane(network.Topology.single_link(125e6))
    plane.launch(MigrationRequest("a", 0.0, 1e9), 2e6, 0.0)
    plane.launch(MigrationRequest("b", 0.0, 1e9), 2e6, 3.0)  # advances to t=3
    assert plane.now == 3.0
    assert plane.last_shares["a"] == 125e6   # a ran alone until b arrived
    done = {}
    t = 3.0
    while plane.in_flight:
        t += 1.0
        done.update((r.job_id, o) for r, o in plane.advance(t))
    assert set(done) == {"a", "b"}
    # a had a 3 s head start at full bandwidth, so it finishes first
    assert done["a"].total_time < done["b"].total_time


def test_probe_bandwidth_feedback():
    plane = MigrationPlane(network.Topology.single_link(100.0))
    assert plane.probe_bandwidth("h0", "h1") == 100.0
    plane.launch(MigrationRequest("x", 0.0, 1e9), 0.0, 0.0)
    assert plane.probe_bandwidth("h0", "h1") == 50.0
    plane.launch(MigrationRequest("y", 0.0, 1e9), 0.0, 0.0)
    assert plane.probe_bandwidth("h0", "h1") == pytest.approx(100.0 / 3)
