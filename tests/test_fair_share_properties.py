"""Property-based max-min invariants for ``network.fair_share``.

Over random topologies (random link capacities, random multi-link paths):

  * feasibility — no link carries more than its capacity;
  * max-min optimality — every flow crosses a saturated link on which its
    rate is maximal (so no flow can be increased without decreasing some
    flow of smaller-or-equal rate on that link);
  * permutation invariance — shuffling the flow order permutes the rates
    identically (the allocation is a function of the multiset of paths).

Hypothesis drives the search when installed (``_hypothesis_compat``
degrades the ``@given`` tests to skips otherwise); the ``_seeded``
variants run the same invariants over a fixed random sweep so clean
containers still execute them.
"""
import numpy as np
import pytest

from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

from repro.core import network

LINKS = [f"L{i}" for i in range(6)]


def _check_feasible(paths, caps, rates):
    for l, cap in caps.items():
        used = sum(r for r, p in zip(rates, paths) if l in p)
        assert used <= cap * (1 + 1e-9), (l, used, cap)


def _check_max_min(paths, caps, rates):
    """Max-min optimality: each flow has a bottleneck — a saturated link
    it crosses where no other flow gets a strictly larger rate."""
    for r, p in zip(rates, paths):
        if not p:
            assert np.isinf(r)
            continue
        bottlenecked = False
        for l in p:
            used = sum(q for q, pp in zip(rates, paths) if l in pp)
            saturated = used >= caps[l] * (1 - 1e-9)
            is_max = all(q <= r * (1 + 1e-9)
                         for q, pp in zip(rates, paths) if l in pp)
            if saturated and is_max:
                bottlenecked = True
                break
        assert bottlenecked, (r, p, rates)


def _check_permutation(paths, caps, rates, rng):
    perm = rng.permutation(len(paths))
    permuted = network.fair_share([paths[i] for i in perm], caps)
    np.testing.assert_allclose(permuted, rates[perm], rtol=1e-9)


def _random_case(rng):
    caps = {l: float(rng.uniform(0.5, 50.0)) for l in LINKS}
    n_flows = int(rng.integers(1, 12))
    paths = [tuple(rng.choice(LINKS, size=rng.integers(1, 4),
                              replace=False))
             for _ in range(n_flows)]
    if rng.random() < 0.2:
        paths.append(())                # an unconstrained flow
    return paths, caps


# ---------------------------------------------------------------------------
# seeded sweep — always runs, hypothesis or not
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(8))
def test_max_min_invariants_seeded(seed):
    rng = np.random.default_rng(seed)
    for _ in range(25):
        paths, caps = _random_case(rng)
        rates = network.fair_share(paths, caps)
        finite = [r for r, p in zip(rates, paths) if p]
        assert all(r > 0 and np.isfinite(r) for r in finite)
        _check_feasible(paths, caps, rates)
        _check_max_min(paths, caps, rates)
        _check_permutation(paths, caps, rates, rng)


def test_dense_solver_same_invariants_seeded():
    rng = np.random.default_rng(99)
    for _ in range(25):
        paths, caps = _random_case(rng)
        order = sorted({l for p in paths for l in p})
        inc = np.zeros((len(order), len(paths)))
        for i, p in enumerate(paths):
            for l in p:
                inc[order.index(l), i] = 1.0
        rates = network.fair_share_dense(
            inc, np.asarray([caps[l] for l in order]))
        _check_feasible(paths, caps, rates)
        _check_max_min(paths, caps, rates)


# ---------------------------------------------------------------------------
# hypothesis search (skipped cleanly when the package is absent)
# ---------------------------------------------------------------------------
if HAS_HYPOTHESIS:
    path_strategy = st.lists(
        st.lists(st.sampled_from(LINKS), min_size=0, max_size=4,
                 unique=True).map(tuple),
        min_size=1, max_size=14)
    caps_strategy = st.fixed_dictionaries(
        {l: st.floats(min_value=0.5, max_value=50.0) for l in LINKS})
else:                                    # inert placeholders for @given args
    path_strategy = caps_strategy = None


@settings(max_examples=200, deadline=None)
@given(paths=path_strategy, caps=caps_strategy)
def test_no_link_over_capacity(paths, caps):
    rates = network.fair_share(paths, caps)
    _check_feasible(paths, caps, rates)


@settings(max_examples=200, deadline=None)
@given(paths=path_strategy, caps=caps_strategy)
def test_every_flow_bottlenecked(paths, caps):
    rates = network.fair_share(paths, caps)
    _check_max_min(paths, caps, rates)


@settings(max_examples=100, deadline=None)
@given(paths=path_strategy, caps=caps_strategy,
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_permutation_invariance(paths, caps, seed):
    rates = network.fair_share(paths, caps)
    _check_permutation(paths, caps, rates, np.random.default_rng(seed))
