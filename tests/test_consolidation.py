"""Consolidation planner: FFD packing, the O(1) job->host index behind
``Placement.host_of``, and src/dst tagging of the migration plan."""
import numpy as np

from repro.core import consolidation as cs


def _placement(n_hosts=4, jobs_per_host=2, cap=4.0, load=1.0):
    hosts = {}
    for h in range(n_hosts):
        hid = f"h{h}"
        hosts[hid] = cs.Host(hid, cap, {f"j{h}_{k}": load
                                        for k in range(jobs_per_host)})
    return cs.Placement(hosts)


def test_host_of_index_matches_hosts():
    p = _placement()
    for h in p.hosts.values():
        for j in h.jobs:
            assert p.host_of(j) == h.host_id
    assert p.host_of("nope") is None


def test_ffd_consolidates_and_tags_requests():
    p = _placement(n_hosts=4, jobs_per_host=2, cap=4.0, load=1.0)
    new_p, plan = cs.consolidate_ffd(p, now=7.0,
                                     state_bytes={"j0_0": 5e8})
    # 8 unit jobs fit on 2 hosts of capacity 4
    assert cs.hosts_used(new_p) == 2
    # index in the repacked placement is in sync with the host dicts
    for h in new_p.hosts.values():
        for j in h.jobs:
            assert new_p.host_of(j) == h.host_id
    for req in plan:
        assert req.src and req.dst and req.src != req.dst
        assert new_p.host_of(req.job_id) == req.dst
        assert p.host_of(req.job_id) == req.src
        assert req.created_at == 7.0
    moved = {r.job_id for r in plan}
    assert "j0_0" not in moved or next(
        r for r in plan if r.job_id == "j0_0").v_bytes == 5e8


def test_assign_and_move_keep_index_in_sync():
    p = _placement(n_hosts=3, jobs_per_host=1, cap=4.0)
    p.assign("new_job", "h2", 2.0)
    assert p.host_of("new_job") == "h2"
    assert p.hosts["h2"].jobs["new_job"] == 2.0
    p.move("new_job", "h0")
    assert p.host_of("new_job") == "h0"
    assert "new_job" not in p.hosts["h2"].jobs
    assert p.hosts["h0"].jobs["new_job"] == 2.0
    p.move("new_job", "h0")              # no-op move keeps state coherent
    assert p.hosts["h0"].jobs["new_job"] == 2.0


def test_overfull_placement_keeps_jobs_in_place():
    hosts = {"a": cs.Host("a", 1.0, {"big": 1.0}),
             "b": cs.Host("b", 1.0, {"huge": 1.0})}
    new_p, plan = cs.consolidate_ffd(cs.Placement(hosts))
    assert plan == []
    assert new_p.host_of("big") == "a" and new_p.host_of("huge") == "b"


def test_host_of_scales_constant_time():
    """The index makes host_of independent of fleet size (regression for
    the O(hosts x jobs) linear scan on the per-request path)."""
    p = _placement(n_hosts=200, jobs_per_host=50, cap=100.0)
    import time
    t0 = time.perf_counter()
    for _ in range(1000):
        p.host_of("h199_49")
    dt = time.perf_counter() - t0
    assert dt < 0.05, dt                 # 10k scans would take far longer
