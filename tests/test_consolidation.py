"""Consolidation planner: FFD packing, the O(1) job->host index behind
``Placement.host_of``, and src/dst tagging of the migration plan."""
import numpy as np
import pytest

from repro.core import consolidation as cs


def _placement(n_hosts=4, jobs_per_host=2, cap=4.0, load=1.0):
    hosts = {}
    for h in range(n_hosts):
        hid = f"h{h}"
        hosts[hid] = cs.Host(hid, cap, {f"j{h}_{k}": load
                                        for k in range(jobs_per_host)})
    return cs.Placement(hosts)


def test_host_of_index_matches_hosts():
    p = _placement()
    for h in p.hosts.values():
        for j in h.jobs:
            assert p.host_of(j) == h.host_id
    assert p.host_of("nope") is None


def test_ffd_consolidates_and_tags_requests():
    p = _placement(n_hosts=4, jobs_per_host=2, cap=4.0, load=1.0)
    new_p, plan = cs.consolidate_ffd(p, now=7.0,
                                     state_bytes={"j0_0": 5e8})
    # 8 unit jobs fit on 2 hosts of capacity 4
    assert cs.hosts_used(new_p) == 2
    # index in the repacked placement is in sync with the host dicts
    for h in new_p.hosts.values():
        for j in h.jobs:
            assert new_p.host_of(j) == h.host_id
    for req in plan:
        assert req.src and req.dst and req.src != req.dst
        assert new_p.host_of(req.job_id) == req.dst
        assert p.host_of(req.job_id) == req.src
        assert req.created_at == 7.0
    moved = {r.job_id for r in plan}
    assert "j0_0" not in moved or next(
        r for r in plan if r.job_id == "j0_0").v_bytes == 5e8


def test_assign_and_move_keep_index_in_sync():
    p = _placement(n_hosts=3, jobs_per_host=1, cap=4.0)
    p.assign("new_job", "h2", 2.0)
    assert p.host_of("new_job") == "h2"
    assert p.hosts["h2"].jobs["new_job"] == 2.0
    p.move("new_job", "h0")
    assert p.host_of("new_job") == "h0"
    assert "new_job" not in p.hosts["h2"].jobs
    assert p.hosts["h0"].jobs["new_job"] == 2.0
    p.move("new_job", "h0")              # no-op move keeps state coherent
    assert p.hosts["h0"].jobs["new_job"] == 2.0


def test_overfull_placement_keeps_jobs_in_place():
    hosts = {"a": cs.Host("a", 1.0, {"big": 1.0}),
             "b": cs.Host("b", 1.0, {"huge": 1.0})}
    new_p, plan = cs.consolidate_ffd(cs.Placement(hosts))
    assert plan == []
    assert new_p.host_of("big") == "a" and new_p.host_of("huge") == "b"


def test_contention_aware_packing_prefers_rack_local_moves():
    """Two packings tie at 2 hosts, but classic FFD funnels four
    cross-rack transfers through the core while the rack-affinity
    candidate consolidates with ONE intra-rack move — the topology-scored
    planner must pick the cheap plan."""
    from repro.core import network
    from repro.core.rates import PiecewiseRate
    hosts = {
        "r0h0": cs.Host("r0h0", 2.0, {"j1": 1.0}),
        "r0h1": cs.Host("r0h1", 2.0, {"j2": 1.0}),
        "r1h0": cs.Host("r1h0", 2.0, {"j3": 1.0, "j4": 1.0}),
        "r1h1": cs.Host("r1h1", 2.0),
    }
    topo = network.Topology.multi_rack(
        {"r0": ["r0h0", "r0h1"], "r1": ["r1h0", "r1h1"]},
        125e6, core_capacity=125e6)
    sb = {j: 1e9 for j in ("j1", "j2", "j3", "j4")}
    rates = {j: PiecewiseRate([60.0], [50e6]) for j in sb}

    classic_p, classic_plan = cs.consolidate_ffd(
        cs.Placement({k: cs.Host(h.host_id, h.capacity, dict(h.jobs))
                      for k, h in hosts.items()}), state_bytes=sb)
    best_p, best_plan = cs.consolidate_ffd(
        cs.Placement(hosts), state_bytes=sb, topology=topo,
        dirty_rates=rates)

    assert cs.hosts_used(best_p) == cs.hosts_used(classic_p) == 2
    assert len(classic_plan) == 4       # the blind plan crosses the core
    assert len(best_plan) == 1
    (req,) = best_plan
    assert topo.access_of(req.src) == topo.access_of(req.dst)
    blind = cs.plan_cost(classic_plan, topo, dirty_rates=rates)
    smart = cs.plan_cost(best_plan, topo, dirty_rates=rates)
    assert smart["bytes"] < blind["bytes"] / 4
    # index integrity of the winning placement
    for h in best_p.hosts.values():
        for j in h.jobs:
            assert best_p.host_of(j) == h.host_id


def test_plan_cost_empty_and_uncontended():
    from repro.core import network
    topo = network.Topology.single_link(125e6)
    assert cs.plan_cost([], topo)["bytes"] == 0.0
    from repro.core.orchestrator import MigrationRequest
    one = [MigrationRequest("j", 0.0, 1e9, src="a", dst="b")]
    cost = cs.plan_cost(one, topo)
    # zero dirty rate: exactly V bytes at the full link share
    assert cost["bytes"] == pytest.approx(1e9)
    assert cost["shares"][0] == 125e6


def test_topology_scoring_never_worsens_host_count():
    """The contended score is lexicographic: host count stays primary, so
    the topology-aware planner consolidates exactly as well as classic
    FFD on every seed."""
    from repro.core import network
    rng = np.random.default_rng(0)
    for _ in range(10):
        n_racks = int(rng.integers(2, 4))
        racks = {f"r{r}": [f"r{r}h{k}" for k in range(3)]
                 for r in range(n_racks)}
        topo = network.Topology.multi_rack(racks, 125e6,
                                           core_capacity=250e6)
        hosts = {}
        for r, hs in racks.items():
            for h in hs:
                jobs = {f"{h}_j{i}": 1.0
                        for i in range(int(rng.integers(0, 3)))}
                hosts[h] = cs.Host(h, 4.0, jobs)
        sb = {j: 5e8 for h in hosts.values() for j in h.jobs}
        p1, _ = cs.consolidate_ffd(
            cs.Placement({k: cs.Host(h.host_id, h.capacity, dict(h.jobs))
                          for k, h in hosts.items()}), state_bytes=sb)
        p2, plan2 = cs.consolidate_ffd(cs.Placement(hosts), state_bytes=sb,
                                       topology=topo)
        assert cs.hosts_used(p2) == cs.hosts_used(p1)
        for req in plan2:
            assert req.src and req.dst and req.src != req.dst


def test_host_of_scales_constant_time():
    """The index makes host_of independent of fleet size (regression for
    the O(hosts x jobs) linear scan on the per-request path)."""
    p = _placement(n_hosts=200, jobs_per_host=50, cap=100.0)
    import time
    t0 = time.perf_counter()
    for _ in range(1000):
        p.host_of("h199_49")
    dt = time.perf_counter() - t0
    assert dt < 0.05, dt                 # 10k scans would take far longer


def test_tier_weighted_cost_prices_spine_above_tor():
    """On a pod/spine fabric a cross-pod transfer's bytes are weighted by
    the spine multiplier; flat topologies keep weighted == raw."""
    from repro.core import network
    from repro.core.orchestrator import MigrationRequest
    topo = network.Topology.pod_spine(2, 2, access_capacity=125e6)
    local = [MigrationRequest("a", 0.0, 1e9,
                              src="p0r0h0", dst="p0r0h1")]
    cross = [MigrationRequest("b", 0.0, 1e9,
                              src="p0r0h0", dst="p1r0h0")]
    c_local = cs.plan_cost(local, topo)
    c_cross = cs.plan_cost(cross, topo)
    assert c_local["weighted_bytes"] == pytest.approx(c_local["bytes"])
    assert c_cross["weighted_bytes"] == pytest.approx(
        cs.TIER_WEIGHTS[2] * c_cross["bytes"])
    flat = network.Topology.single_link(125e6)
    c_flat = cs.plan_cost(local, flat)
    assert c_flat["weighted_bytes"] == c_flat["bytes"]


def test_affinity_candidates_keep_moves_off_the_spine():
    """Tier-weighted scoring: when a pod-local repack exists at the same
    host count, the plan must not climb to the spine. Classic FFD would
    funnel pod 0's jobs into pod 1's most-loaded host (3 spine
    transfers); the affinity candidates consolidate rack-locally at the
    same host count and win on weighted bytes."""
    from repro.core import network
    topo = network.Topology.pod_spine(2, 2, hosts_per_rack=2,
                                      access_capacity=125e6,
                                      pod_oversubscription=4.0)
    hosts = {
        "p0r0h0": cs.Host("p0r0h0", 2.0, {"a": 1.0}),
        "p0r0h1": cs.Host("p0r0h1", 2.0, {"b": 1.0}),
        "p1r0h0": cs.Host("p1r0h0", 4.0, {"c": 1.0, "d": 1.0, "e": 1.0}),
    }
    sb = {j: 1e9 for j in "abcde"}
    new_p, plan = cs.consolidate_ffd(cs.Placement(hosts), state_bytes=sb,
                                     topology=topo)
    assert cs.hosts_used(new_p) == 2
    assert plan                         # pod 0 still consolidates a + b
    for req in plan:
        p = topo.path(req.src, req.dst)
        assert not any(l.startswith("spine:") for l in p), (req, p)
