# NOTE: deliberately no XLA_FLAGS here — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 host devices.
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(__file__))   # make _hypothesis_compat importable

from _hypothesis_compat import HAS_HYPOTHESIS, settings

if HAS_HYPOTHESIS:
    settings.register_profile("ci", max_examples=25, deadline=None,
                              derandomize=True)
    settings.load_profile("ci")


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.key(0)
