# NOTE: deliberately no XLA_FLAGS here — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 host devices.
import os

import pytest
from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None,
                          derandomize=True)
settings.load_profile("ci")


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.key(0)
