"""Prediction-guard layer (core/guard.py) and its wiring.

The load-bearing contracts:

* a plane/fleet built with ``guard=None`` (or a guard whose thresholds
  never trip) is BIT-IDENTICAL to a guard-less build — evaluation alone
  must not perturb a single byte;
* the policy ladder: throttling replaces the lane's table with a
  composably scaled ``PiecewiseRate`` (auto-converge), and the abort
  rung settles the lane with ``stop_reason == strunk.STOP_GUARD`` —
  distinct from fault aborts — feeding wasted-bytes accounting and the
  LMCM backoff path;
* lanes without an admission-time expectation (NaN) are structurally
  exempt;
* misprediction feedback: a guard abort decays the job's ``trust``,
  forces its fit stale, and ``confidence x trust`` below the gate turns
  trough pricing off;
* degraded telemetry: blackout faults record NaN AFTER the rng draw
  (stream unchanged), ``window_matrix`` exposes a validity mask,
  low-coverage fits demote to acyclic, and faulted+guarded runs stay
  bit-identical between ``event_skip`` on/off;
* S1: degenerate windows (no spectral mass) fit with confidence 0 and
  per-job confidence is surfaced on ``TickResult``;
* S2: seeded retry jitter de-synchronizes mass-abort backoff
  deterministically.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import cycles, network, strunk
from repro.core.fabric import ShardedPlane
from repro.core.fleetsim import FleetSim, SimJob, table3_traces
from repro.core.guard import MigrationGuard, expectation_of, throttled_spec
from repro.core.orchestrator import LMCM, MigrationRequest
from repro.core.plane import MigrationPlane
from repro.core.rates import PiecewiseRate
from repro.core.telemetry import FleetTelemetry
from repro.scenarios.faults import FaultEvent, FaultPlan

CAP = 125e6


# ---------------------------------------------------------------------------
# MigrationGuard unit surface
# ---------------------------------------------------------------------------
def test_guard_ctor_validates():
    with pytest.raises(ValueError):
        MigrationGuard(throttle_ratio=0.5)
    with pytest.raises(ValueError):
        MigrationGuard(throttle_ratio=4.0, abort_ratio=3.0)
    with pytest.raises(ValueError):
        MigrationGuard(throttle_factor=1.0)
    with pytest.raises(ValueError):
        MigrationGuard(trust_decay=0.0)


def test_divergence_nan_disarms():
    g = MigrationGuard()
    div = g.divergence(np.array([3e9, 3e9]), np.array([50.0, 50.0]),
                       np.array([1e9, np.nan]), np.array([10.0, np.nan]))
    assert div[0] == 5.0                       # max(bytes 3x, time 5x)
    assert np.isnan(div[1])
    # NaN compares False against every rung
    assert not (div[1] >= g.throttle_ratio)
    assert not (div[1] >= g.abort_ratio)


def test_factor_ladder_floors():
    g = MigrationGuard(throttle_factor=0.5, throttle_floor=0.2)
    assert g.factor_for(1) == 0.5
    assert g.factor_for(2) == 0.25
    assert g.factor_for(3) is None             # 0.125 < floor


def test_trust_decay_and_gate():
    g = MigrationGuard(trust_decay=0.5, trust_gate=0.25, trust_floor=0.05)
    t = 1.0
    for expect in (0.5, 0.25, 0.125, 0.0625, 0.05, 0.05):
        t = g.decay_trust(t)
        assert t == expect
    assert g.trusts(0.9, 1.0)
    assert not g.trusts(0.9, 0.1)              # burned trust gates it off
    assert not g.trusts(0.1, 1.0)              # low confidence alone too


def test_expectation_of_reads_stamps():
    req = MigrationRequest("j", 0.0, 1e9)
    assert all(np.isnan(expectation_of(req)))
    req.expected_bytes, req.expected_time = 2e9, 16.0
    assert expectation_of(req) == (2e9, 16.0)


def test_throttled_spec_composes():
    tbl = PiecewiseRate([10.0, 30.0], [40e6, 8e6], offset=3.0)
    half = throttled_spec(tbl, 0.5)
    assert isinstance(half, PiecewiseRate)
    assert np.array_equal(half.ends, tbl.ends)
    assert np.array_equal(np.asarray(half.rates),
                          np.asarray(tbl.rates) * 0.5)
    assert half.offset == tbl.offset
    # constants normalize to 1-entry tables; callables wrap; None passes
    const = throttled_spec(30e6, 0.25)
    assert isinstance(const, PiecewiseRate) and const(5.0) == 7.5e6
    fn = throttled_spec(lambda t: 100.0 + t, 0.1)
    assert fn(10.0) == pytest.approx(11.0)
    assert throttled_spec(None, 0.5) is None


def test_throttled_spec_reprices_bit_identically():
    """The scaled table through ``what_if_cost_batch`` equals a manually
    scaled table bit-for-bit — the repricing consistency the composable
    transform exists for."""
    tbl = PiecewiseRate([20.0, 50.0], [200e6, 30e6])
    man = PiecewiseRate(tbl.ends, np.asarray(tbl.rates) * 0.5)
    a = strunk.what_if_cost_batch([1e9], [CAP], [throttled_spec(tbl, 0.5)],
                                  [7.0], full=True)
    b = strunk.what_if_cost_batch([1e9], [CAP], [man], [7.0], full=True)
    for f in ("total_time", "downtime", "bytes_sent", "rounds"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f


# ---------------------------------------------------------------------------
# plane-level ladder
# ---------------------------------------------------------------------------
def _hostile_lane(guard, *, hot=300e6, v=1.5e9, expect=True, t_end=600.0):
    plane = MigrationPlane(network.Topology.single_link(CAP), guard=guard)
    req = MigrationRequest("h", 0.0, v, src="h0", dst="h1")
    if expect:
        # the optimistic admission price: a cheap lane at full capacity
        out = strunk.what_if_cost_batch(
            [v], CAP, [PiecewiseRate([1e9], [3e6])], [0.0], full=True)
        req.expected_bytes = float(out.bytes_sent[0])
        req.expected_time = float(out.total_time[0])
    plane.launch(req, PiecewiseRate([1e9], [hot]), 0.0)
    done, t = [], 0.0
    while plane.in_flight and t < t_end:
        t += 1.0
        done.extend(plane.advance(t))
    assert len(done) == 1
    return done[0]


def test_guard_that_never_trips_is_bit_identical():
    base_req, base = _hostile_lane(None)
    idle = MigrationGuard(throttle_ratio=1e9, abort_ratio=1e9)
    req, out = _hostile_lane(idle)
    assert idle.n_throttles == 0 and idle.n_aborts == 0
    for f in ("total_time", "downtime", "bytes_sent", "rounds",
              "stop_reason"):
        assert getattr(out, f) == getattr(base, f), f


def test_unstamped_lane_is_exempt():
    g = MigrationGuard(throttle_ratio=1.1, abort_ratio=1.2)
    _, out = _hostile_lane(g, expect=False)
    assert g.n_throttles == 0 and g.n_aborts == 0
    assert out.stop_reason != strunk.STOP_GUARD


def test_throttle_rung_auto_converges():
    """A steep patient ladder drags the hostile lane under the link speed:
    it converges (dirty_low) with fewer bytes and far less downtime than
    the unguarded grind to the Xen stop ladder."""
    _, un = _hostile_lane(None, hot=200e6, v=1e9)
    g = MigrationGuard(throttle_ratio=1.2, abort_ratio=50.0,
                       throttle_factor=0.3)
    _, out = _hostile_lane(g, hot=200e6, v=1e9)
    assert g.n_throttles >= 1 and g.n_aborts == 0
    assert out.bytes_sent < un.bytes_sent
    assert out.downtime < un.downtime
    assert out.stop_reason == strunk.STOP_REASONS[strunk.REASON_DIRTY_LOW]


def test_abort_rung_emits_guard_stop_reason():
    g = MigrationGuard(throttle_ratio=1.3, abort_ratio=2.0)
    req, out = _hostile_lane(g, hot=4e9)
    assert g.n_aborts == 1
    assert out.stop_reason == strunk.STOP_GUARD == "guard_abort"
    assert out.stop_reason != strunk.STOP_ABORTED    # distinct from faults
    assert 0.0 < out.bytes_sent < 3.0 * 1.5e9        # partial, pre-cap
    assert out.downtime == 0.0                       # never reached s&c


def test_sharded_plane_plumbs_one_shared_guard():
    topo = network.Topology.star(["a", "b", "c", "d"], CAP,
                                 core_capacity=4 * CAP)
    g = MigrationGuard(throttle_ratio=1.2, abort_ratio=2.0)
    plane = ShardedPlane(topo, guard=g)
    for i, (s, d) in enumerate((("a", "b"), ("c", "d"))):
        req = MigrationRequest(f"j{i}", 0.0, 1.5e9, src=s, dst=d)
        req.expected_bytes, req.expected_time = 1.6e9, 13.0
        plane.launch(req, PiecewiseRate([1e9], [4e9]), 0.0)
    done, t = [], 0.0
    while plane.in_flight and t < 300.0:
        t += 1.0
        done.extend(plane.advance(t))
    # disjoint domains, one aggregate counter
    assert g.n_aborts == 2
    assert all(o.stop_reason == strunk.STOP_GUARD for _, o in done)


# ---------------------------------------------------------------------------
# FleetSim wiring: parity, feedback, degraded telemetry
# ---------------------------------------------------------------------------
def _sim(policy="alma-plus", **kw):
    traces = table3_traces(10.0)
    jobs = [SimJob(n, tr, v_bytes=1.0e9) for n, tr in traces.items()]
    sim = FleetSim(jobs, policy=policy, warmup_s=400.0, seed=7, **kw)
    plan = [MigrationRequest(j.job_id, created_at=5.0 * i,
                             v_bytes=j.v_bytes, src="h0", dst="h1")
            for i, j in enumerate(jobs)]
    return sim, plan


def test_fleetsim_guard_none_bit_identical():
    s1, p1 = _sim()
    r1 = s1.run_with_plan(p1, horizon_s=1200.0)
    s2, p2 = _sim(guard=None)
    r2 = s2.run_with_plan(p2, horizon_s=1200.0)
    assert r1.total_bytes == r2.total_bytes
    assert r1.total_time == r2.total_time
    assert r1.completed_at == r2.completed_at
    w1, _ = s1.telemetry.window_matrix(512)
    w2, _ = s2.telemetry.window_matrix(512)
    assert np.array_equal(w1, w2)


def test_fleetsim_guard_abort_decays_trust_and_forces_refit():
    g = MigrationGuard(throttle_ratio=1.5, abort_ratio=2.0)
    # immediate launches right after warmup (t=400); the brownout stalls
    # the lane mid-flight two seconds later, so realized time diverges
    # from the stamped expectation until the guard cuts it loose
    plan_fault = FaultPlan.link_brownout(402.0, "migration-net", 1e5,
                                         restore_at=700.0,
                                         restore_capacity=CAP)
    s, p = _sim(policy="immediate", guard=g, fault_plan=plan_fault)
    res = s.run_with_plan(p[:1], horizon_s=1000.0)
    assert g.n_aborts >= 1
    sj = s.lmcm.engine.jobs[p[0].job_id]
    assert sj.trust < 1.0                      # misprediction feedback
    assert res.n_aborts >= 1 and res.aborted_bytes > 0.0
    assert res.n_retries >= 1                  # backoff re-admission
    assert res.completed_at                    # finished after restore


def test_trough_gate_on_burned_trust():
    g = MigrationGuard(trust_gate=0.25)
    s, p = _sim(guard=g, adaptive_concurrency=True, horizon=True)
    req = p[0]
    s._tag_request(req)
    jid = req.job_id
    s.lmcm.engine.refresh_model(jid, force=True)
    sj = s.lmcm.engine.jobs[jid]
    assert sj.model is not None
    before = s._trough_of(req, s.now)
    sj.trust = 0.01                            # as if aborts burned it
    assert s._trough_of(req, s.now) is None
    sj.trust = 1.0
    assert s._trough_of(req, s.now) == before


def _blackout_sim(event_skip, *, with_plan=True, policy="immediate"):
    traces = table3_traces(10.0)
    jobs = [SimJob(n, tr, v_bytes=1.0e9) for n, tr in traces.items()]
    fp = FaultPlan.telemetry_blackout(
        100.0, [jobs[0].job_id, jobs[1].job_id], duration_s=150.0) \
        if with_plan else None
    sim = FleetSim(jobs, policy=policy, warmup_s=400.0, seed=7,
                   fault_plan=fp, event_skip=event_skip)
    plan = [MigrationRequest(j.job_id, created_at=5.0 * i,
                             v_bytes=j.v_bytes, src="h0", dst="h1")
            for i, j in enumerate(jobs)]
    res = sim.run_with_plan(plan, horizon_s=1200.0)
    return sim, res


def test_blackout_event_skip_bit_identity():
    s1, r1 = _blackout_sim(True, policy="alma-plus")
    s2, r2 = _blackout_sim(False, policy="alma-plus")
    assert r1.total_bytes == r2.total_bytes
    assert r1.completed_at == r2.completed_at
    w1, _ = s1.telemetry.window_matrix(2048)
    w2, _ = s2.telemetry.window_matrix(2048)
    assert np.array_equal(w1, w2, equal_nan=True)
    assert np.isnan(w1).any()


def test_blackout_overwrites_after_draw_stream_unchanged():
    """NaN injection must not consume or skip rng draws: every sample of
    every NON-blacked-out job is bit-identical to the fault-free run."""
    s_base, r_base = _blackout_sim(True, with_plan=False)
    s_fault, r_fault = _blackout_sim(True, with_plan=True)
    assert r_base.total_bytes == r_fault.total_bytes   # immediate ignores
    w0, _ = s_base.telemetry.window_matrix(2048)
    w1, _ = s_fault.telemetry.window_matrix(2048)
    blacked = ~np.isfinite(w1).all(axis=(1, 2)) | \
        ~np.isfinite(w0).all(axis=(1, 2))
    assert blacked.sum() == 2
    assert np.array_equal(w0[~blacked], w1[~blacked])
    # blacked rows: NaN exactly inside the episode, real samples outside
    nan_steps = np.isnan(w1[blacked]).all(axis=2)
    assert nan_steps.any() and not nan_steps.all()


def test_low_coverage_demotes_to_acyclic():
    traces = table3_traces(10.0)
    jobs = [SimJob(n, tr, v_bytes=1.0e9) for n, tr in traces.items()]
    victim = jobs[1].job_id                    # vm02_C: strongly cyclic
    fp = FaultPlan.telemetry_blackout(700.0, [victim], duration_s=400.0)
    sim = FleetSim(jobs, policy="alma-paper", warmup_s=600.0, seed=7,
                   fault_plan=fp)
    m0 = sim.lmcm.engine.refresh_model(victim, force=True)
    assert m0 is not None and m0.cyclic        # clean fit first
    sim.run_idle(600.0)                        # blackout covers > half
    m1 = sim.lmcm.engine.refresh_model(victim, force=True)
    assert m1 is not None and m1.period == 0 and not m1.cyclic
    assert m1.confidence == 0.0


def test_window_matrix_mask_default_path_unchanged():
    fleet = FleetTelemetry(2, capacity=64)
    for s in range(8):
        vals = np.full((2, len(fleet.fields)), float(s + 1))
        if s in (3, 4):
            vals[1] = np.nan
        fleet.record_fleet(s, vals)
    w, m = fleet.window_matrix(6)
    assert np.isnan(w[1]).any()                # default: raw, NaN intact
    w2, m2, mask = fleet.window_matrix(6, return_mask=True)
    assert np.array_equal(m, m2)
    assert mask.shape == (2, 6)
    assert mask[0].all()
    assert mask[1].sum() == 4                  # two NaN steps invalid
    assert not np.isnan(w2).any()              # masked gather zero-fills
    assert np.array_equal(w2[0], w[0])


# ---------------------------------------------------------------------------
# S1: degenerate-window confidence
# ---------------------------------------------------------------------------
def test_degenerate_window_confidence_clamps_to_zero():
    const = np.full(256, 3.0, np.float32)
    p, conf = cycles.cycle_length(const)
    assert conf == 0.0
    p, conf = cycles.cycle_length(np.zeros(256, np.float32))
    assert conf == 0.0
    cyc = np.tile(np.r_[np.ones(8), np.zeros(8)], 16).astype(np.float32)
    p, conf = cycles.cycle_length(cyc)
    assert p == 16 and conf > 0.1


def test_fit_cycle_batch_degenerate_rows():
    cyc = np.tile(np.r_[np.ones(8, np.int8), np.zeros(8, np.int8)], 16)
    batch = np.stack([np.ones(256, np.int8), cyc,
                      np.zeros(256, np.int8)])
    models = cycles.fit_cycle_batch(batch)
    assert models[0].confidence == 0.0 and not models[0].cyclic
    assert models[2].confidence == 0.0 and not models[2].cyclic
    assert models[1].cyclic and models[1].confidence > 0.1


def test_tick_result_surfaces_confidence():
    s, _ = _sim(policy="alma-paper")
    tr = s.lmcm.engine.tick(int(s.now / s.dt))
    assert isinstance(tr.confidence, dict) and tr.confidence
    for jid, c in tr.confidence.items():
        assert jid in s.jobs and 0.0 <= c <= 1.0


# ---------------------------------------------------------------------------
# S2: seeded retry jitter
# ---------------------------------------------------------------------------
def _aborted(bytes_sent=1e8):
    return strunk.MigrationOutcome(total_time=5.0, downtime=0.0,
                                   bytes_sent=bytes_sent, rounds=1,
                                   stop_reason=strunk.STOP_ABORTED)


def test_retry_jitter_desynchronizes_mass_aborts():
    lm = LMCM(policy="immediate", retry_backoff_s=4.0, retry_jitter=0.5,
              retry_jitter_seed=3)
    wakes = []
    for i in range(6):
        req = MigrationRequest(f"j{i}", 0.0, 1e9)
        assert lm.fail(req, _aborted(), 0.0)
        wakes.append(req.scheduled_at)
    assert len(set(wakes)) == len(wakes)       # all distinct
    assert all(4.0 <= w <= 6.0 for w in wakes)  # base * [1, 1+jitter)


def test_retry_jitter_seed_reproducible():
    def wakes(seed):
        lm = LMCM(policy="immediate", retry_backoff_s=4.0,
                  retry_jitter=0.5, retry_jitter_seed=seed)
        out = []
        for i in range(4):
            req = MigrationRequest(f"j{i}", 0.0, 1e9)
            lm.fail(req, _aborted(), 0.0)
            out.append(req.scheduled_at)
        return out
    assert wakes(3) == wakes(3)
    assert wakes(3) != wakes(4)


def test_retry_jitter_zero_is_exact_baseline():
    lm = LMCM(policy="immediate", retry_backoff_s=4.0, retry_jitter=0.0)
    req = MigrationRequest("j", 0.0, 1e9)
    now = 0.0
    for expect in (4.0, 8.0, 16.0):
        assert lm.fail(req, _aborted(), now)
        assert req.scheduled_at - now == expect
        now = req.scheduled_at


def test_retry_jitter_scales_per_attempt():
    lm = LMCM(policy="immediate", retry_backoff_s=4.0, retry_jitter=0.5,
              retry_jitter_seed=0, retry_max=3)
    req = MigrationRequest("j", 0.0, 1e9)
    now = 0.0
    for k in range(3):
        assert lm.fail(req, _aborted(), now)
        base = 4.0 * 2.0 ** k
        assert base <= req.scheduled_at - now <= base * 1.5
        now = req.scheduled_at


def test_telemetry_blackout_builder_seeded_subset():
    jobs = [f"j{i}" for i in range(10)]
    p1 = FaultPlan.telemetry_blackout(50.0, jobs, duration_s=30.0,
                                      frac=0.4, seed=5)
    p2 = FaultPlan.telemetry_blackout(50.0, jobs, duration_s=30.0,
                                      frac=0.4, seed=5)
    assert [e.jobs for e in p1] == [e.jobs for e in p2]
    assert len(p1.events[0].jobs) == 4
    assert p1.events[0].jobs == p1.events[1].jobs
    assert p1.events[1].t == 80.0 and p1.events[1].kind \
        == "telemetry_restore"
    p3 = FaultPlan.telemetry_blackout(50.0, jobs, duration_s=30.0,
                                      frac=0.4, seed=6)
    assert p3.events[0].jobs != p1.events[0].jobs
    # shifted() carries the job tuple through
    s = p1.shifted(100.0)
    assert s.events[0].jobs == p1.events[0].jobs
    assert s.events[0].t == 150.0
