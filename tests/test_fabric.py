"""Sharded-fabric invariants: domain isolation, merging, and the
vectorized event loop's parity with the scalar reference plane.

The load-bearing contracts:

  * migrations confined to disjoint access-link domains are BIT-EQUAL to
    running each domain alone (sharding changes nothing but wall-clock);
  * core-link contention with unconstrained access links reproduces the
    single-shared-link results exactly (the paper's testbed is the
    degenerate one-domain fabric);
  * the vectorized plane is bit-equal to the kept scalar reference loop
    on uncontended lanes, and to float summation order under contention;
  * per-link byte conservation holds on every link of a multi-rack sweep.
"""
import numpy as np
import pytest

from repro.core import network, strunk
from repro.core.fabric import ShardedPlane
from repro.core.fleetsim import FleetSim, SimJob, WorkloadTrace
from repro.core.orchestrator import MigrationRequest
from repro.core.plane import MigrationPlane


def _tuples(done):
    return {r.job_id: (o.total_time, o.downtime, o.bytes_sent, o.rounds,
                       o.stop_reason) for r, o in done}


def _trace(seed=0):
    return WorkloadTrace([("MEM", 60), ("CPU", 60)], 120)


def _rack_topo(access=125e6, core=125e6):
    return network.Topology.multi_rack(
        {"r0": ["r0h0", "r0h1"], "r1": ["r1h0", "r1h1"]},
        access, core_capacity=core)


def _intra_rack_reqs(rack, n, rng):
    return [MigrationRequest(f"{rack}j{i}", 0.0,
                             float(rng.uniform(0.5e9, 2e9)),
                             src=f"{rack}h0", dst=f"{rack}h1")
            for i in range(n)]


# ---------------------------------------------------------------------------
# domain isolation
# ---------------------------------------------------------------------------
def test_disjoint_domains_bit_equal_to_isolated_runs():
    """Two racks, only intra-rack migrations: the fabric must produce
    outcomes bit-equal to running each rack's lanes on a fabric of its
    own — and must actually shard them into two domains."""
    topo = _rack_topo()
    tr = _trace()
    rng = np.random.default_rng(1)
    reqs = {r: _intra_rack_reqs(r, 3, rng) for r in ("r0", "r1")}

    both = ShardedPlane(topo)
    for r in ("r0", "r1"):
        for q in reqs[r]:
            both.launch(q, tr.rate_table, 0.0)
    assert both.domain_count == 2
    assert sorted(map(sorted, both.domain_links())) == \
        [["acc:r0"], ["acc:r1"]]
    together = _tuples(both.advance(np.inf))

    for r in ("r0", "r1"):
        alone = ShardedPlane(topo)
        for q in reqs[r]:
            alone.launch(q, tr.rate_table, 0.0)
        solo = _tuples(alone.advance(np.inf))
        for job, tup in solo.items():
            assert together[job] == tup, (job, tup, together[job])


def test_core_contention_reproduces_single_link():
    """Cross-rack lanes with unconstrained access links contend only on
    the core — bit-equal to the same lanes on the paper's single shared
    migration link of the core's capacity."""
    cap = 125e6
    topo = _rack_topo(access=1e18, core=cap)
    tr = _trace()
    rng = np.random.default_rng(2)
    sizes = [float(rng.uniform(0.5e9, 2e9)) for _ in range(6)]

    fabric = ShardedPlane(topo)
    flat = ShardedPlane(network.Topology.single_link(cap))
    for i, v in enumerate(sizes):
        fabric.launch(MigrationRequest(f"x{i}", 0.0, v,
                                       src="r0h0", dst="r1h0"),
                      tr.rate_table, 0.0)
        flat.launch(MigrationRequest(f"x{i}", 0.0, v), tr.rate_table, 0.0)
    assert fabric.domain_count == 1     # the core couples everything
    assert _tuples(fabric.advance(np.inf)) == _tuples(flat.advance(np.inf))
    # the core carried every byte; each (unconstrained) access link too
    lb = fabric.link_bytes
    total = lb["core"]
    assert total == pytest.approx(lb["acc:r0"] + 0.0, rel=1e-12)
    assert total == pytest.approx(flat.link_bytes["migration-net"],
                                  rel=1e-12)


def test_cross_rack_lane_merges_domains():
    topo = _rack_topo()
    tr = _trace()
    rng = np.random.default_rng(3)
    plane = ShardedPlane(topo)
    for r in ("r0", "r1"):
        for q in _intra_rack_reqs(r, 2, rng):
            plane.launch(q, tr.rate_table, 0.0)
    assert plane.domain_count == 2
    plane.advance(5.0)
    # a cross-rack migration bridges both racks through the core
    plane.launch(MigrationRequest("bridge", 0.0, 1e9,
                                  src="r0h1", dst="r1h0"),
                 tr.rate_table, 5.0)
    assert plane.domain_count == 1
    assert plane.merges == 1
    done = _tuples(plane.advance(np.inf))
    assert set(done) == {"r0j0", "r0j1", "r1j0", "r1j1", "bridge"}
    # conservation on every link
    elapsed = plane.now
    for l, b in plane.link_bytes.items():
        assert b <= topo.links[l].capacity * elapsed * (1 + 1e-9), (l, b)


def test_link_bytes_survive_domain_dissolve():
    topo = _rack_topo()
    plane = ShardedPlane(topo)
    plane.launch(MigrationRequest("j", 0.0, 1e9, src="r0h0", dst="r0h1"),
                 2e6, 0.0)
    (req, out), = plane.advance(np.inf)
    assert plane.domain_count == 0      # drained domains dissolve
    assert plane.link_bytes["acc:r0"] == pytest.approx(out.bytes_sent)
    assert plane.in_flight == 0


def test_unlinked_lane_runs_at_fallback_bandwidth():
    """A lane whose path resolves to no links (hosts unknown to a star
    topology) is unconstrained: both plane modes must run it at the
    fallback bandwidth instead of crashing on an empty incidence."""
    topo = network.Topology.star(["h0", "h1"], 125e6)
    ref = strunk.simulate_precopy_reference(1e9, 125e6, 2e6)
    for cls in (ShardedPlane, MigrationPlane):
        plane = cls(topo)
        plane.launch(MigrationRequest("ghost", 0.0, 1e9), 2e6, 0.0)
        (_, out), = plane.advance(np.inf)
        assert (out.total_time, out.bytes_sent) == \
            (ref.total_time, ref.bytes_sent)


def test_probe_is_per_domain():
    """Lanes saturating rack r0 must not dilute the probed share of an
    intra-r1 migration — but a cross-rack probe sees them through the
    shared links it would traverse."""
    cap = 125e6
    topo = _rack_topo(access=cap, core=cap)
    plane = ShardedPlane(topo)
    for i in range(4):
        plane.launch(MigrationRequest(f"j{i}", 0.0, 1e12,
                                      src="r0h0", dst="r0h1"), 1e6, 0.0)
    assert plane.probe_bandwidth("r0h0", "r0h1") == pytest.approx(cap / 5)
    # r1 is an independent domain: full access-link speed
    assert plane.probe_bandwidth("r1h0", "r1h1") == pytest.approx(cap)
    # a cross-rack lane shares acc:r0 with the four in-flight lanes
    assert plane.probe_bandwidth("r0h0", "r1h0") == pytest.approx(cap / 5)


def test_absorb_tolerates_ulp_clock_skew():
    """Regression: the fabric merges domains at a common event time, and
    truncated chunks normally land on ``until`` exactly — but the
    vectorized path's float summation can leave a domain within a few
    ULPs of the target. ``_absorb`` must accept (and snap) clocks equal
    within the documented epsilon, and still reject real skew."""
    topo = _rack_topo()
    tr = _trace()
    plane = ShardedPlane(topo)
    # v/bw chosen so round boundaries land on non-representable times
    for r in ("r0", "r1"):
        plane.launch(MigrationRequest(f"{r}j", 0.0, 1e9 / 3,
                                      src=f"{r}h0", dst=f"{r}h1"),
                     tr.rate_table, 0.0)
    t = 1.0 + 1.0 / 3.0
    plane.advance(t)                       # vectorized advance, both domains
    d0, d1 = plane._domains
    assert d0.now == t and d1.now == t
    # simulate the ULP drift the clamp now prevents from ever compounding
    d1.now = np.nextafter(np.nextafter(t, np.inf), np.inf)
    plane.launch(MigrationRequest("bridge", 0.0, 1e9,
                                  src="r0h1", dst="r1h0"),
                 tr.rate_table, t)
    assert plane.domain_count == 1 and plane.merges == 1
    done = _tuples(plane.advance(np.inf))
    assert set(done) == {"r0j", "r1j", "bridge"}
    # genuine skew (beyond epsilon) must still be rejected
    a = MigrationPlane(topo)
    b = MigrationPlane(topo)
    a.now, b.now = 100.0, 100.1
    with pytest.raises(ValueError):
        a._absorb(b)


def test_merge_after_vectorized_advance_lands_on_target():
    """Truncated vectorized chunks must land the event clock on the
    advance target EXACTLY (the merge precondition), including when
    now + dt would round past it."""
    topo = _rack_topo()
    tr = _trace()
    plane = ShardedPlane(topo)
    rng = np.random.default_rng(13)
    for r in ("r0", "r1"):
        for i in range(3):
            plane.launch(MigrationRequest(
                f"{r}j{i}", 0.0, float(rng.uniform(0.3e9, 1.7e9)) / 3,
                src=f"{r}h0", dst=f"{r}h1"), tr.rate_table, 0.0)
    t = 0.0
    for _ in range(40):                    # many odd-sized steps
        t += 0.1 + 1.0 / 7.0
        plane.advance(t)
        for d in plane._domains:
            assert d.now == t
    plane.launch(MigrationRequest("bridge", 0.0, 1e9,
                                  src="r0h0", dst="r1h1"),
                 tr.rate_table, t)
    assert plane.domain_count == 1
    done = _tuples(plane.advance(np.inf))
    assert "bridge" in done


# ---------------------------------------------------------------------------
# vectorized event loop vs the scalar reference plane
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("v,rate,kw", [
    (1.5e9, 2e6, {}),                       # dirty_low
    (1e9, 0.6 * 125e6, {"max_rounds": 5}),  # max_rounds
    (1e9, 150e6, {}),                       # total_cap
])
def test_vectorized_uncontended_bit_equals_reference(v, rate, kw):
    """The acceptance contract: the vectorized plane's uncontended lane is
    bit-equal to BOTH the scalar-reference plane and the Strunk loop."""
    outs = {}
    for vec in (True, False):
        plane = MigrationPlane(network.Topology.single_link(125e6),
                               vectorized=vec, **kw)
        plane.launch(MigrationRequest("j", 0.0, v), rate, 0.0)
        (_, out), = plane.advance(np.inf)
        outs[vec] = (out.total_time, out.downtime, out.bytes_sent,
                     out.rounds, out.stop_reason)
    ref = strunk.simulate_precopy_reference(v, 125e6, rate, **kw)
    assert outs[True] == outs[False] == \
        (ref.total_time, ref.downtime, ref.bytes_sent, ref.rounds,
         ref.stop_reason)


def test_vectorized_contended_matches_scalar_plane():
    """8 lanes on one shared link, cyclic tables, stepped advances: the
    vectorized loop tracks the per-lane reference loop exactly (one
    contended link involves no cross-link float reassociation)."""
    tr = _trace()
    res = {}
    for vec in (True, False):
        plane = MigrationPlane(network.Topology.single_link(125e6),
                               vectorized=vec)
        rng = np.random.default_rng(7)
        for j in range(8):
            plane.launch(MigrationRequest(f"j{j}", 0.0,
                                          float(rng.uniform(0.5e9, 2e9))),
                         tr.rate_table, float(rng.uniform(0.0, 20.0)))
        done = {}
        t = 20.0
        while plane.in_flight:
            t += 1.0
            done.update(_tuples(plane.advance(t)))
        res[vec] = (done, plane.link_bytes)
    assert res[True][0] == res[False][0]
    for l, b in res[True][1].items():
        assert b == pytest.approx(res[False][1][l], rel=1e-9)


def test_vectorized_multilink_close_to_scalar_plane():
    """Cross-rack contention exercises multi-link fair sharing, where the
    dense and sparse solvers may differ by summation order only."""
    topo = _rack_topo()
    tr = _trace()
    res = {}
    for vec in (True, False):
        plane = MigrationPlane(topo, vectorized=vec)
        rng = np.random.default_rng(11)
        for j in range(6):
            src = f"r{j % 2}h0"
            dst = f"r{(j + 1) % 2}h1"
            plane.launch(MigrationRequest(f"j{j}", 0.0,
                                          float(rng.uniform(0.5e9, 2e9)),
                                          src=src, dst=dst),
                         tr.rate_table, 0.0)
        res[vec] = {j: t for j, t in _tuples(plane.advance(np.inf)).items()}
    for j, tup in res[True].items():
        np.testing.assert_allclose(tup[:3], res[False][j][:3], rtol=1e-9)
        assert tup[3:] == res[False][j][3:]


def test_sharded_equals_monolithic_single_domain():
    """When every lane shares one link there is exactly one domain — the
    fabric must be a transparent wrapper over a single plane."""
    tr = _trace()
    res = {}
    for cls in (ShardedPlane, MigrationPlane):
        plane = cls(network.Topology.single_link(125e6))
        rng = np.random.default_rng(5)
        for j in range(6):
            plane.launch(MigrationRequest(f"j{j}", 0.0,
                                          float(rng.uniform(0.5e9, 2e9))),
                         tr.rate_table, 0.0)
        res[cls.__name__] = _tuples(plane.advance(np.inf))
    assert res["ShardedPlane"] == res["MigrationPlane"]


# ---------------------------------------------------------------------------
# FleetSim on the default star substrate
# ---------------------------------------------------------------------------
def test_fleetsim_star_default_conserves_every_link():
    from repro.core.consolidation import Host, Placement
    from repro.core.fleetsim import table3_traces
    traces = table3_traces(phase_s=60.0)
    jobs = [SimJob(j, tr, 1e9) for j, tr in traces.items()]
    hosts = {f"s{i}": Host(f"s{i}", 1.0, {j.job_id: 1.0})
             for i, j in enumerate(jobs)}
    hosts["sink"] = Host("sink", float(len(jobs)))
    sim = FleetSim(jobs, policy="immediate", warmup_s=60.0,
                   max_concurrent=8, seed=0, placement=Placement(hosts))
    # the default substrate is a star over the placement's hosts
    assert "acc:sink" in sim.topology.links and "core" in sim.topology.links
    plan = [MigrationRequest(j.job_id, sim.now + 2.0, j.v_bytes, dst="sink")
            for j in jobs]
    res = sim.run_with_plan(plan, horizon_s=3000.0)
    assert len(res.per_job) == len(jobs)
    caps = sim.topology.capacities
    for l, b in res.link_bytes.items():
        assert b <= caps[l] * res.makespan * (1 + 1e-9), (l, b)
    # every job's bytes crossed its own access link and the sink's
    assert res.link_bytes["acc:sink"] == pytest.approx(res.total_bytes)
