"""Receding-horizon admission (ISSUE 9): mid-round resumable pre-copy
costs, subset what-if shares, trough-priced deferral, overtake aging, and
the LMCM/FleetSim wake-up plumbing.

The load-bearing contracts:

  * ``strunk.ResumeState.fresh`` threaded through ``what_if_cost_batch``
    is BIT-IDENTICAL to the no-init hot loop (the resume generalization
    must not perturb a single existing prediction);
  * resuming a lane's mid-round snapshot (``plane.lane_state``) conserves
    the bill: charged-so-far + marginal-future equals the full-simulation
    outcome, and elapsed + marginal time equals total time;
  * ``what_if_subset_shares`` rows equal independent fair-share solves of
    the same active sets, base columns aligned 1:1 with ``lane_state``;
  * the subset sweep's winning score can never exceed the best queue-order
    prefix score on the same inputs (queue prefixes are always scenarios);
  * aging counts OVERTAKES (a later-queued candidate launching past a
    deferred one) and promotes at the bound — plain queue-order waiting
    does not age, so ``horizon=True`` on acyclic load stays myopic;
  * horizon-deferred wakes surface in ``LMCM.next_due_time`` so FleetSim
    event-skip stops at re-admission boundaries — skip on/off runs are
    bit-identical with trough-deferred candidates inside idle stretches;
  * ``horizon=False`` leaves selections, request state, and controller
    dicts byte-identical to the myopic PR 8 paths.
"""
import numpy as np
import pytest

from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st
from repro.core import cycles, network, postpone as pp, strunk
from repro.core.controller import AdaptiveConcurrencyController
from repro.core.fabric import ShardedPlane
from repro.core.fleetsim import FleetSim, SimJob, WorkloadTrace
from repro.core.orchestrator import LMCM, MigrationRequest
from repro.core.plane import MigrationPlane
from repro.core.rates import PiecewiseRate
from repro.core.surveillance import SurveilledJob, SurveillanceEngine

CAP = 125e6


def _rand_specs(rng, m):
    specs = []
    for _ in range(m):
        k = int(rng.integers(1, 4))
        bounds = np.cumsum(rng.uniform(10.0, 120.0, k))
        rates = rng.uniform(0.0, 150e6, k)
        specs.append(PiecewiseRate(list(bounds), list(rates),
                                   offset=float(rng.uniform(0, 120))))
    return specs


# ---------------------------------------------------------------------------
# strunk.ResumeState — the resumable pre-copy loop
# ---------------------------------------------------------------------------
def _assert_fresh_init_parity(seed):
    """what_if_cost_batch(init=ResumeState.fresh(v)) must be bitwise
    equal to the no-init hot loop on every outcome field."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 12))
    v = rng.uniform(1e8, 4e9, m)
    bw = rng.uniform(5e6, 2e8, m)
    t0 = rng.uniform(0.0, 500.0, m)
    specs = _rand_specs(rng, m)
    base = strunk.what_if_cost_batch(v, bw, specs, t0, full=True)
    resumed = strunk.what_if_cost_batch(
        v, bw, specs, t0, init=strunk.ResumeState.fresh(v), full=True)
    for f in ("total_time", "downtime", "bytes_sent", "rounds",
              "stop_reason"):
        assert np.array_equal(getattr(base, f), getattr(resumed, f)), f


@pytest.mark.parametrize("seed", range(8))
def test_fresh_init_bit_parity_seeded(seed):
    _assert_fresh_init_parity(seed)


def test_resume_conserves_bytes_and_time_mid_round():
    """Snapshot a lane mid-round and resume it: charged + marginal bytes
    equals the full simulation's bill, elapsed + marginal time equals its
    total time (constant rate keeps every intermediate exact)."""
    rate = PiecewiseRate([60.0], [30e6])
    plane = MigrationPlane(network.Topology.single_link(CAP))
    plane.launch(MigrationRequest("j", 0.0, 1e9), rate, 0.0)
    for t in range(1, 6):
        plane.advance(float(t))
    ls = plane.lane_state()[0]
    assert not ls.stopped and 0.0 < ls.rem < ls.v
    init = strunk.ResumeState(
        rem=np.asarray([ls.rem]), acc=np.asarray([ls.acc]),
        sent=np.asarray([ls.sent]), rounds=np.asarray([ls.rounds]),
        stopped=np.asarray([ls.stopped]),
        reason=np.asarray([ls.reason]))
    marginal = strunk.what_if_cost_batch(
        np.asarray([ls.v]), np.asarray([CAP]), [rate],
        np.asarray([plane.now]), init=init, full=True)
    full = strunk.what_if_cost_batch(
        np.asarray([1e9]), np.asarray([CAP]), [rate],
        np.asarray([0.0]), full=True)
    tight = lambda x: pytest.approx(x, rel=1e-12)
    assert ls.sent + marginal.bytes_sent[0] == tight(full.bytes_sent[0])
    assert plane.now + marginal.total_time[0] == tight(full.total_time[0])
    assert marginal.downtime[0] == tight(full.downtime[0])
    assert ls.rounds + marginal.rounds[0] == full.rounds[0]


def test_resume_stopped_lane_bills_final_copy_only():
    """A lane already in stop-and-copy owes exactly its final-round
    bytes; reason and round count pass through untouched."""
    init = strunk.ResumeState(
        rem=np.asarray([3e7]), acc=np.asarray([0.0]),
        sent=np.asarray([1.2e9]), rounds=np.asarray([7]),
        stopped=np.asarray([True]),
        reason=np.asarray([strunk.REASON_DIRTY_LOW]))
    out = strunk.what_if_cost_batch(
        np.asarray([1e9]), np.asarray([CAP]), [PiecewiseRate([60.0], [5e7])],
        np.asarray([100.0]), init=init, full=True)
    assert out.bytes_sent[0] == 3e7
    assert out.downtime[0] == 3e7 / CAP
    assert out.total_time[0] == pytest.approx(3e7 / CAP, rel=1e-12)
    assert out.rounds[0] == 7
    assert out.stop_reason[0] == strunk.REASON_DIRTY_LOW


# ---------------------------------------------------------------------------
# plane/fabric — lane_state alignment and subset shares
# ---------------------------------------------------------------------------
def _contended_fabric(seed=0):
    topo = network.Topology.multi_rack(3, CAP, core_capacity=3 * CAP / 2.0,
                                       hosts_per_rack=2)
    plane = ShardedPlane(topo)
    rng = np.random.default_rng(seed)
    lanes = [("b0", "r0h0", "r0h1"), ("b1", "r1h0", "r1h1"),
             ("b2", "r1h0", "r2h1")]
    for jid, src, dst in lanes:
        plane.launch(MigrationRequest(jid, 0.0,
                                      float(rng.uniform(0.5e9, 2e9)),
                                      src=src, dst=dst),
                     PiecewiseRate([60.0], [float(rng.uniform(0, 60e6))]),
                     0.0)
    plane.advance(2.0)
    return topo, plane


def test_lane_state_aligns_with_base_path_columns():
    """``lane_state(links)`` must return snapshots in exactly the order
    ``_base_paths(links)`` lists their paths — the controller reprices
    lane j at base column j of the subset solve."""
    topo, plane = _contended_fabric()
    links = set(topo.path("r0h0", "r2h0")) | set(topo.path("r1h0", "r1h1"))
    base = plane._base_paths(iter(links))
    snap = plane.lane_state(links)
    assert len(base) == len(snap) == 3
    assert [tuple(s.path) for s in snap] == [tuple(p) for p in base]
    # and a narrower link set hits only the intersecting domains, both
    # views agreeing on the cut
    links_r0 = set(topo.path("r0h0", "r0h1"))
    base0 = plane._base_paths(iter(links_r0))
    snap0 = plane.lane_state(links_r0)
    assert [tuple(s.path) for s in snap0] == [tuple(p) for p in base0]
    assert {s.job_id for s in snap0} == {"b0"}


def test_subset_shares_rows_match_independent_solves():
    """Every mask row of ``what_if_subset_shares`` equals a fair-share
    solve over exactly that active set (base + fixed + selected), column
    by column; unselected candidate columns are zero."""
    topo, plane = _contended_fabric(seed=3)
    fixed = [topo.path("r0h0", "r1h0")]
    cands = [topo.path("r0h0", "r0h1"), topo.path("r1h0", "r2h0"),
             topo.path("r2h0", "r2h1")]
    rng = np.random.default_rng(7)
    masks = rng.random((6, 3)) < 0.5
    shares = plane.what_if_subset_shares(fixed, cands, masks)
    links = {l for p in [*fixed, *cands] for l in p}
    base = plane._base_paths(iter(links))
    n_b, n_f = len(base), len(fixed)
    assert shares.shape == (6, n_b + n_f + 3)
    for k, mask in enumerate(masks):
        sel = [p for p, on in zip(cands, mask) if on]
        ref = network.fair_share([*base, *fixed, *sel], topo.capacities)
        ref = np.where(np.isfinite(ref), ref, plane._fallback_bw)
        active_cols = (list(range(n_b + n_f))
                       + [n_b + n_f + j for j in range(3) if mask[j]])
        assert np.array_equal(shares[k, active_cols], ref)
        for j in range(3):
            if not mask[j]:
                assert shares[k, n_b + n_f + j] == 0.0


# ---------------------------------------------------------------------------
# surveillance.next_trough — Algorithm 2 as a price
# ---------------------------------------------------------------------------
def test_next_trough_matches_postpone():
    """next_trough is postpone() over the job's CURRENT fit, indexed from
    its origin step; acyclic and unregistered jobs price as None."""
    profile = np.asarray([0, 0, 1, 1, 0, 0], np.int8)
    model = cycles.CycleModel(period=6, confidence=0.9,
                              profile_lm=profile,
                              array_lm=np.flatnonzero(profile))
    engine = SurveillanceEngine()
    engine.jobs["cyc"] = SurveilledJob("cyc", None, None, model=model,
                                       origin_step=10)
    engine.jobs["flat"] = SurveilledJob(
        "flat", None, None, origin_step=0,
        model=cycles.CycleModel(period=0, confidence=0.0,
                                profile_lm=np.zeros(0, np.int8)))
    for now in (10, 13, 15, 27, 40):
        out = engine.next_trough(["cyc", "flat", "ghost"], now)
        assert out["cyc"] == pp.postpone(model, now - 10)
        assert out["flat"] is None and out["ghost"] is None
    # spot values: relative moment 0 -> 2 samples to the LM window,
    # inside the window -> 0, past it -> wrap into the next cycle
    assert engine.next_trough(["cyc"], 10)["cyc"] == 2
    assert engine.next_trough(["cyc"], 13)["cyc"] == 0
    assert engine.next_trough(["cyc"], 15)["cyc"] == 3


# ---------------------------------------------------------------------------
# controller — horizon sweep semantics
# ---------------------------------------------------------------------------
def _single_link_ctl(rate_table, **kw):
    plane = ShardedPlane(network.Topology.single_link(CAP))
    ctl = AdaptiveConcurrencyController(
        plane, rate_of=lambda r: rate_table[r.job_id], **kw)
    return plane, ctl


def test_horizon_false_is_pure_myopic_and_mutation_free():
    """horizon=False must be byte-identical to the PR 8 controller:
    same selections as both sweep engines, no ``defers`` mutation, no
    deferral bookkeeping."""
    rng = np.random.default_rng(11)
    rates = {f"j{i}": PiecewiseRate(
        [60.0, 120.0], [float(rng.uniform(0, 120e6)), 3e6],
        offset=float(rng.uniform(0, 120))) for i in range(6)}
    picks = {}
    for mode in ("stacked", "reference", "horizon_off"):
        plane, ctl = _single_link_ctl(
            rates, sweep="reference" if mode == "reference" else "stacked")
        reqs = [MigrationRequest(f"j{i}", 0.0, 1e9) for i in range(6)]
        picks[mode] = [r.job_id for r in ctl.select(reqs, 0.0)]
        if mode == "horizon_off":
            assert all(r.defers == 0 for r in reqs)
            assert ctl.deferred_until == {}
            assert ctl._deferred_claims == {}
    assert picks["stacked"] == picks["reference"] == picks["horizon_off"]


def _assert_subset_score_le_queue_prefix(seed):
    """Queue-order prefixes are always among the scenarios, listed first,
    so the winning subset score is <= the best queue-prefix score — the
    receding-horizon sweep can only improve on the myopic ladder."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 9))
    rates = {f"j{i}": PiecewiseRate(
        [60.0, 120.0], [float(rng.uniform(0, 150e6)),
                        float(rng.uniform(0, 10e6))],
        offset=float(rng.uniform(0, 120))) for i in range(n)}
    troughs = {f"j{i}": (float(rng.uniform(2.0, 90.0))
                         if rng.random() < 0.5 else None)
               for i in range(n)}
    plane, ctl = _single_link_ctl(
        rates, horizon=True, trough_of=lambda r, now: troughs[r.job_id])
    if rng.random() < 0.5:                      # sometimes mid-flight lanes
        plane.launch(MigrationRequest("bg", 0.0,
                                      float(rng.uniform(0.5e9, 2e9))),
                     PiecewiseRate([60.0], [30e6]), 0.0)
        rates["bg"] = PiecewiseRate([60.0], [30e6])
        plane.advance(2.0)
    reqs = [MigrationRequest(f"j{i}", 0.0,
                             float(rng.uniform(0.3e9, 2e9)))
            for i in range(n)]
    paths = [ctl.path_of(r) for r in reqs]
    subsets, scores, _, _ = ctl._score_subsets(reqs, paths, [], [],
                                               plane.now)
    # the first n+1 scenarios ARE the queue prefixes, in order
    assert subsets[:n + 1] == [tuple(range(k)) for k in range(n + 1)]
    assert min(scores) <= min(scores[:n + 1])


@pytest.mark.parametrize("seed", range(6))
def test_subset_score_never_worse_than_queue_prefix(seed):
    _assert_subset_score_le_queue_prefix(seed)


def test_trough_pricing_defers_and_publishes_wakes():
    """Candidates in an expensive phase with a predicted trough defer to
    it: empty selection on an idle domain (waiting IS the decision), the
    wake published in ``deferred_until``, claims recorded, and the delay
    floored at defer_s."""
    rates = {f"j{i}": PiecewiseRate([60.0, 120.0], [150e6, 0.3e6])
             for i in range(2)}
    plane, ctl = _single_link_ctl(rates, horizon=True, defer_s=1.0,
                                  trough_of=lambda r, now: 50.0)
    reqs = [MigrationRequest(f"j{i}", 0.0, 1e9) for i in range(2)]
    assert ctl.select(reqs, 0.0) == []
    assert sorted(ctl.deferred_until.values()) == [50.0, 50.0]
    assert len(ctl._deferred_claims) == 2
    assert all(w == 50.0 for w, _ in ctl._deferred_claims.values())
    # nobody launched past anybody: waiting does not age
    assert all(r.defers == 0 for r in reqs)
    # a sub-defer_s trough is floored at the re-evaluation delay
    plane2, ctl2 = _single_link_ctl(rates, horizon=True, defer_s=4.0,
                                    trough_of=lambda r, now: 0.5)
    reqs2 = [MigrationRequest(f"j{i}", 0.0, 1e9) for i in range(2)]
    ctl2.select(reqs2, 0.0)
    assert all(w == 4.0 for w in ctl2.deferred_until.values())


def test_idle_domain_releases_head_without_troughs():
    """No trough predictions -> the myopic no-livelock rule holds: an
    idle domain always releases its head-of-line candidate."""
    rates = {"j0": PiecewiseRate([60.0], [150e6])}
    plane, ctl = _single_link_ctl(rates, horizon=True)
    reqs = [MigrationRequest("j0", 0.0, 1e9)]
    assert [r.job_id for r in ctl.select(reqs, 0.0)] == ["j0"]


def test_overtake_aging_promotes_within_bound():
    """A candidate overtaken ``aging_limit`` times (later-queued launches
    passing it while it defers to its trough) is promoted to a forced
    launch — the subset sweep's explicit no-starvation bound."""
    head = MigrationRequest("head", 0.0, 2e9)
    rates = {"head": PiecewiseRate([300.0, 600.0], [150e6, 0.3e6])}
    plane, ctl = _single_link_ctl(rates, horizon=True, aging_limit=3,
                                  trough_of=lambda r, now:
                                  300.0 - now if r.job_id == "head"
                                  else None)
    for i in range(3):
        cheap = MigrationRequest(f"c{i}", 0.0, 2e8)
        rates[f"c{i}"] = PiecewiseRate([60.0], [0.0])
        sel = ctl.select([head, cheap], float(i))
        assert [r.job_id for r in sel] == [f"c{i}"]   # overtaken again
        assert head.defers == i + 1
    cheap = MigrationRequest("c3", 0.0, 2e8)
    rates["c3"] = PiecewiseRate([60.0], [0.0])
    sel = ctl.select([head, cheap], 3.0)
    assert "head" in [r.job_id for r in sel]          # promoted: launches
    assert id(head) not in ctl._deferred_claims


def test_deferred_claims_break_route_ties():
    """Satellite 2: a horizon-deferred candidate's claimed links count as
    live in route tie de-confliction — an exact-score tie routes AWAY
    from the links a deferred lane will take at its wake."""
    plane = ShardedPlane(network.Topology.single_link(CAP))
    ctl = AdaptiveConcurrencyController(plane)
    routes = [(("spine-a", "dst"), ("spine-b", "dst"))]
    ones = np.asarray([1.0, 1.0])
    # clean tie: lowest route index wins
    assert ctl._assign_routes(routes, ones, ones) == [("spine-a", "dst")]
    # claim on spine-a tips the tie to spine-b
    ctl._deferred_claims[999] = (50.0, ("spine-a",))
    assert ctl._assign_routes(routes, ones, ones) == [("spine-b", "dst")]


def test_claims_pruned_at_wake():
    plane = ShardedPlane(network.Topology.single_link(CAP))
    ctl = AdaptiveConcurrencyController(plane, horizon=True)
    ctl._deferred_claims = {1: (5.0, ("a",)), 2: (20.0, ("a",))}
    ctl._prune_claims(10.0)
    assert set(ctl._deferred_claims) == {2}


# ---------------------------------------------------------------------------
# LMCM — trough wakes in the heap (satellite 1)
# ---------------------------------------------------------------------------
def test_defer_wake_honors_controller_and_max_wait():
    import types
    lmcm = LMCM(policy="immediate", sample_period=1.0, max_wait=100.0)
    req = MigrationRequest("j", 0.0, 1e9)
    # no controller: one sampling period, the PR 8 behavior
    assert lmcm._defer_wake(req, 10.0) == 11.0
    # a published trough wake is honored and consumed
    lmcm.controller = types.SimpleNamespace(
        deferred_until={id(req): 40.0})
    assert lmcm._defer_wake(req, 10.0) == 40.0
    assert lmcm.controller.deferred_until == {}
    # and clamped to the request's max-wait wall
    lmcm.controller.deferred_until[id(req)] = 1e9
    assert lmcm._defer_wake(req, 10.0) == 100.0


def test_next_due_time_reflects_trough_wake():
    """due() pushes a horizon-deferred request at its trough wake, so
    ``next_due_time`` — the event-skip boundary — lands exactly there
    instead of one sampling period out."""
    plane = ShardedPlane(network.Topology.single_link(CAP))
    rate = PiecewiseRate([60.0], [30e6])
    lmcm = LMCM(policy="immediate", max_concurrent=8, max_wait=600.0,
                bandwidth=CAP, sample_period=1.0)
    lmcm.controller = AdaptiveConcurrencyController(
        plane, rate_of=lambda r: rate, horizon=True,
        trough_of=lambda r, now: 40.0)
    reqs = [MigrationRequest(f"j{i}", 0.0, 1e9) for i in range(2)]
    for r in reqs:
        lmcm.submit(r, 0.0)
    # every candidate has a predicted trough: waiting IS the decision,
    # and both requests re-enter the heap AT the trough, not one
    # sampling period out
    assert lmcm.due(0.0) == []
    assert lmcm.next_due_time() == 40.0
    assert lmcm.due(1.0) == []                   # nothing due before it
    assert lmcm.next_due_time() == 40.0


def test_force_surveillance_keeps_engine_ticking():
    lmcm = LMCM(policy="immediate")
    assert not lmcm.uses_surveillance
    lmcm.force_surveillance = True
    assert lmcm.uses_surveillance
    assert LMCM(policy="alma-paper").uses_surveillance


# ---------------------------------------------------------------------------
# FleetSim — end to end
# ---------------------------------------------------------------------------
def _cyclic_fleet(horizon, skip=True, n_jobs=6):
    jobs = [SimJob(f"j{i}",
                   WorkloadTrace([("MEM", 60.0), ("IDLE", 60.0)], 3600.0,
                                 offset=15.0 * i), 1e9)
            for i in range(n_jobs)]
    sim = FleetSim(jobs, policy="immediate", warmup_s=500.0,
                   max_concurrent=n_jobs, seed=5,
                   adaptive_concurrency=not horizon, horizon=horizon,
                   event_skip=True)
    sim._event_skip = skip
    plan = [MigrationRequest(j.job_id, sim.now + 5.0, j.v_bytes)
            for j in jobs]
    return sim, plan


def test_horizon_fleet_event_skip_bit_identical():
    """Satellite 1 end-to-end: with trough-deferred candidates inside
    otherwise-idle stretches, the event-skipping run reproduces the
    per-second loop exactly — every wake is a boundary the skip stops
    at (bytes, times, links, clock, telemetry ring, rng stream)."""
    out = {}
    for skip in (True, False):
        sim, plan = _cyclic_fleet(horizon=True, skip=skip)
        res = sim.run_with_plan(plan, horizon_s=2500.0)
        out[skip] = (res, sim)
    r1, s1 = out[True]
    r0, s0 = out[False]
    assert len(r1.per_job) == 6
    assert r1.total_bytes == r0.total_bytes
    assert r1.total_time == r0.total_time
    assert r1.link_bytes == r0.link_bytes
    assert s1.now == s0.now
    assert np.array_equal(s1.telemetry._data, s0.telemetry._data)
    assert np.array_equal(s1.telemetry._steps, s0.telemetry._steps)
    assert s1.rng.bit_generator.state == s0.rng.bit_generator.state


def test_horizon_fleet_beats_myopic_on_cyclic_load():
    """The paper's premise, unified into admission: on cyclic MEM/IDLE
    load the receding-horizon arm moves fewer bytes than the myopic
    controller and fires more launches inside true LM phases."""
    res = {}
    for horizon in (False, True):
        sim, plan = _cyclic_fleet(horizon)
        res[horizon] = sim.run_with_plan(plan, horizon_s=2500.0)
    assert len(res[True].per_job) == len(res[False].per_job) == 6
    assert res[True].total_bytes <= res[False].total_bytes
    assert res[True].lm_hit_rate >= res[False].lm_hit_rate


# ---------------------------------------------------------------------------
# hypothesis search (skipped cleanly when the package is absent)
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_fresh_init_bit_parity_hypothesis(seed):
    _assert_fresh_init_parity(seed)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_subset_score_vs_prefix_hypothesis(seed):
    _assert_subset_score_le_queue_prefix(seed)
