"""One-solve control plane: stacked defer-k sweep parity, union-find
domain maintenance parity, and the event-skipping FleetSim's bit-identity
with the per-second loop.

The load-bearing contracts (ISSUE 5):

  * the stacked prefix sweep (one masked fair-share solve + one flattened
    pre-copy batch) selects the SAME k with the SAME (bytes, time, -k)
    score tuple as the kept per-k reference loop, over random topologies,
    queue orders, and forced/max-wait mixes;
  * ``fair_share_masked`` rows obey the same max-min invariants as the
    sparse solver, scenario by scenario, and ``what_if_shares_sweep``
    row k equals ``what_if_shares`` of the k-prefix;
  * union-find domain bookkeeping (launch/merge/drain) produces the same
    domain partitions and the same ``probe_bandwidth`` answers as the
    PR 4 connected-components scan it replaced — including the
    partially-drained-domain case where a link's last live lane completed
    but its domain still runs (the link must NOT match new launches);
  * ``run_idle`` and ``run_with_plan`` with event skipping are
    bit-identical to the per-second loop: telemetry ring, rng stream,
    clock, fits, and every migration outcome.

Hypothesis drives the search when installed; the ``_seeded`` variants run
the same invariants over fixed random sweeps so clean containers still
execute them.
"""
import numpy as np
import pytest

from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

from repro.core import network
from repro.core.controller import AdaptiveConcurrencyController
from repro.core.fabric import ShardedPlane
from repro.core.fleetsim import FleetSim, SimJob, WorkloadTrace, \
    table3_traces
from repro.core.orchestrator import MigrationRequest
from repro.core.rates import PiecewiseRate

CAP = 125e6


# ---------------------------------------------------------------------------
# stacked sweep vs per-k reference
# ---------------------------------------------------------------------------
def _sweep_case(seed: int):
    """A random decision point: topology, background lanes, candidates,
    forced launches, and rates."""
    rng = np.random.default_rng(seed)
    racks = int(rng.integers(1, 5))
    oversub = float(rng.choice([1.0, 2.0, 4.0]))
    topo = network.Topology.multi_rack(
        racks, CAP, core_capacity=racks * CAP / oversub, hosts_per_rack=2)
    plane = ShardedPlane(topo)
    rates = {}

    def req(tag, i):
        r = MigrationRequest(
            f"{tag}{i}", 0.0, float(rng.uniform(0.2e9, 2e9)),
            src=f"r{int(rng.integers(racks))}h0",
            dst=f"r{int(rng.integers(racks))}h1")
        rates[r.job_id] = PiecewiseRate(
            [60.0, 120.0], [float(rng.uniform(0, 160e6)),
                            float(rng.uniform(0, 20e6))],
            offset=float(rng.uniform(0, 120)))
        return r

    for i in range(int(rng.integers(0, 4))):
        r = req("bg", i)
        plane.launch(r, rates[r.job_id], 0.0)
    plane.advance(float(rng.uniform(0, 5)))
    cands = [req("c", i) for i in range(int(rng.integers(1, 9)))]
    forced = [req("f", i) for i in range(int(rng.integers(0, 3)))]
    ctl = AdaptiveConcurrencyController(
        plane, rate_of=lambda q: rates[q.job_id])
    return ctl, cands, forced, plane.now


def _assert_sweep_parity(seed: int):
    ctl, cands, forced, now = _sweep_case(seed)
    cp = [ctl.path_of(r) for r in cands]
    fp = [ctl.path_of(r) for r in forced]
    for idxs, busy, f_idx in ctl._components(cp, fp):
        g = [cands[i] for i in idxs]
        gp = [cp[i] for i in idxs]
        gf = [forced[i] for i in f_idx]
        gfp = [fp[i] for i in f_idx]
        k_s, score_s = ctl._sweep_stacked(g, gp, gf, gfp, now)
        k_r, score_r = ctl._sweep_reference(g, gp, gf, gfp, now)
        assert k_s == k_r, (seed, k_s, k_r)
        assert score_s == score_r, (seed, score_s, score_r)


@pytest.mark.parametrize("seed", range(6))
def test_stacked_sweep_matches_reference_seeded(seed):
    for trial in range(20):
        _assert_sweep_parity(seed * 1000 + trial)


def test_select_identical_across_sweep_engines():
    """End-to-end select(): same launches in the same order."""
    for seed in range(25):
        ctl, cands, forced, now = _sweep_case(seed + 7_000)
        sel = {}
        for mode in ("stacked", "reference"):
            ctl.sweep = mode
            sel[mode] = [r.job_id
                         for r in ctl.select(cands, now, forced=forced)]
        assert sel["stacked"] == sel["reference"], seed


# ---------------------------------------------------------------------------
# the masked share solver and the sweep surface
# ---------------------------------------------------------------------------
LINKS = [f"L{i}" for i in range(5)]


def _masked_case(rng):
    caps = {l: float(rng.uniform(0.5, 50.0)) for l in LINKS}
    n = int(rng.integers(1, 10))
    paths = [tuple(rng.choice(LINKS, size=rng.integers(1, 4), replace=False))
             for _ in range(n)]
    if rng.random() < 0.2:
        paths.append(())
    active = rng.random((int(rng.integers(1, 6)), len(paths))) < 0.7
    return paths, caps, active


@pytest.mark.parametrize("seed", range(4))
def test_masked_solver_rows_match_sparse_scenarios(seed):
    """Each active row of ``fair_share_masked`` is the max-min allocation
    of exactly that lane subset."""
    rng = np.random.default_rng(seed)
    for _ in range(25):
        paths, caps, active = _masked_case(rng)
        order = sorted({l for p in paths for l in p})
        inc = np.zeros((len(order), len(paths)))
        for i, p in enumerate(paths):
            for l in p:
                inc[order.index(l), i] = 1.0
        rates = network.fair_share_masked(
            inc, np.asarray([caps[l] for l in order]), active)
        for k in range(active.shape[0]):
            sub = [i for i in range(len(paths)) if active[k, i]]
            ref = network.fair_share([paths[i] for i in sub], caps)
            np.testing.assert_allclose(rates[k, sub], ref, rtol=1e-9)
            assert not rates[k, [i for i in range(len(paths))
                                 if not active[k, i]]].any()


def test_what_if_prefix_shares_equals_per_k_calls():
    """Row k of the sweep == what_if_shares(forced + cands[:k]), exactly
    (the stacked solver's per-link arithmetic is local, so extra
    scenarios and inactive columns change nothing)."""
    for seed in range(20):
        ctl, cands, forced, now = _sweep_case(seed + 11_000)
        plane = ctl.plane
        fp = [ctl.path_of(r) for r in forced]
        cp = [ctl.path_of(r) for r in cands]
        stacked = plane.what_if_shares_sweep(fp, cp)
        assert stacked.shape == (len(cands) + 1, len(forced) + len(cands))
        for k in range(len(cands) + 1):
            ref = plane.what_if_shares(fp + cp[:k])
            assert np.array_equal(stacked[k, :len(forced) + k], ref), \
                (seed, k)
            assert not stacked[k, len(forced) + k:].any()


# ---------------------------------------------------------------------------
# union-find domain maintenance vs the connected-components scan
# ---------------------------------------------------------------------------
class _RefDomains:
    """PR 4's scan-based domain bookkeeping, tracked symbolically: each
    domain is an ordered list of live (job_id, path) lanes; a launch
    matches any domain whose LIVE link set intersects its path (the
    coarser never-split semantics of the fabric: merged domains stay
    merged until they drain)."""

    def __init__(self):
        self.domains = []                 # list of list[(job, path)]

    @staticmethod
    def _links(dom):
        return {l for _, p in dom for l in p}

    def launch(self, job, path):
        pset = frozenset(path)
        if pset:
            hits = [d for d in self.domains if pset & self._links(d)]
        else:
            hits = [d for d in self.domains if not self._links(d)]
        if not hits:
            target = []
            self.domains.append(target)
        else:
            target = hits[0]
            for other in hits[1:]:
                target.extend(other)
                self.domains.remove(other)
        target.append((job, tuple(path)))

    def finish(self, job):
        for d in self.domains:
            for entry in d:
                if entry[0] == job:
                    d.remove(entry)
                    if not d:
                        self.domains.remove(d)
                    return

    def partition(self):
        return sorted(sorted(j for j, _ in d) for d in self.domains)

    def probe(self, path, caps, fallback):
        pset = frozenset(path)
        base = [p for d in self.domains if pset & self._links(d)
                for _, p in d]
        share = float(network.fair_share(base + [tuple(path)], caps)[-1])
        return share if np.isfinite(share) else fallback


def _run_uf_parity(seed: int):
    rng = np.random.default_rng(seed)
    racks = int(rng.integers(2, 5))
    topo = network.Topology.multi_rack(
        racks, CAP, core_capacity=racks * CAP / 2.0, hosts_per_rack=2)
    plane = ShardedPlane(topo)
    ref = _RefDomains()
    tr = PiecewiseRate([60.0, 120.0], [40e6, 2e6])
    now, n = 0.0, 0
    for step in range(30):
        op = rng.random()
        if op < 0.6:                       # launch (sometimes unlinked)
            if rng.random() < 0.1:
                req = MigrationRequest(f"g{n}", 0.0,
                                       float(rng.uniform(0.2e9, 1e9)))
                req.src = req.dst = f"ghost{n}"   # unknown hosts: no links
            else:
                req = MigrationRequest(
                    f"j{n}", 0.0, float(rng.uniform(0.2e9, 1.5e9)),
                    src=f"r{int(rng.integers(racks))}h0",
                    dst=f"r{int(rng.integers(racks))}h1")
            n += 1
            path = topo.path(req.src, req.dst)
            plane.launch(req, tr, now, path=path)
            ref.launch(req.job_id, path)
        else:                              # advance: drain some lanes
            now += float(rng.uniform(1.0, 40.0))
            for req, _ in plane.advance(now):
                ref.finish(req.job_id)
        got = sorted(sorted(d.jobs_in_flight()) for d in plane._domains)
        assert got == ref.partition(), (seed, step, got, ref.partition())
        # probes agree exactly (same base-path order per domain)
        for _ in range(3):
            src = f"r{int(rng.integers(racks))}h0"
            dst = f"r{int(rng.integers(racks))}h1"
            assert plane.probe_bandwidth(src, dst) == ref.probe(
                topo.path(src, dst), topo.capacities, plane._fallback_bw)
    for req, _ in plane.advance(np.inf):
        ref.finish(req.job_id)
    assert plane.domain_count == 0 and ref.partition() == []
    assert not plane._link_key and not plane._live     # all reaped


@pytest.mark.parametrize("seed", range(8))
def test_union_find_domains_match_components_rebuild_seeded(seed):
    _run_uf_parity(seed)


def test_drained_link_does_not_match_new_launches():
    """A domain whose cross-rack lane completed keeps running its
    intra-rack lanes; a NEW lane on the drained link must form its own
    domain (live-link semantics), not join the old one."""
    topo = network.Topology.multi_rack(2, CAP, core_capacity=2 * CAP,
                                       hosts_per_rack=2)
    plane = ShardedPlane(topo)
    slow = PiecewiseRate([1.0], [80e6])
    plane.launch(MigrationRequest("long", 0.0, 30e9,
                                  src="r0h0", dst="r0h1"), slow, 0.0)
    plane.launch(MigrationRequest("cross", 0.0, 1e9,
                                  src="r0h0", dst="r1h0"), 0.0, 0.0)
    assert plane.domain_count == 1         # coupled through acc:r0
    # drain the cross lane only (rate 0 -> two rounds at fair share)
    t = 0.0
    while "cross" in plane.jobs_in_flight():
        t += 1.0
        plane.advance(t)
    assert plane.jobs_in_flight() == ["long"]
    # a NEW intra-r1 lane touches only the drained acc:r1/core links of
    # the old domain: it must NOT join it
    plane.launch(MigrationRequest("fresh", 0.0, 1e9,
                                  src="r1h0", dst="r1h1"), slow, t)
    assert plane.domain_count == 2
    assert sorted(map(sorted, (d.jobs_in_flight()
                               for d in plane._domains))) == \
        [["fresh"], ["long"]]


# ---------------------------------------------------------------------------
# event-skipping FleetSim
# ---------------------------------------------------------------------------
def _mini_fleet(policy, skip, J=6, seed=11):
    jobs = [SimJob(f"j{i}",
                   WorkloadTrace([("IO", 60), ("CPU", 120), ("MEM", 60)],
                                 total_s=7200, offset=13.0 * i), 1e9)
            for i in range(J)]
    return FleetSim(jobs, policy=policy, warmup_s=400.0, max_concurrent=4,
                    seed=seed, event_skip=skip)


@pytest.mark.parametrize("policy", ["immediate", "alma-paper"])
def test_event_skip_bit_identical_to_per_second_loop(policy):
    """Full-state parity: results, telemetry ring, rng stream, clock, and
    (for surveillance policies) every fit's epoch."""
    runs = {}
    for skip in (False, True):
        sim = _mini_fleet(policy, skip)
        plan = [MigrationRequest(f"j{i}", sim.now + 30.0 + 200.0 * k, 1e9)
                for k, i in enumerate((0, 2, 4))]
        runs[skip] = (sim, sim.run_with_plan(plan, horizon_s=1200.0))
    (s0, r0), (s1, r1) = runs[False], runs[True]
    assert len(r1.per_job) == 3
    assert r1.total_bytes == r0.total_bytes
    assert r1.total_time == r0.total_time
    assert r1.mean_downtime == r0.mean_downtime
    assert r1.makespan == r0.makespan
    assert r1.lm_hit_rate == r0.lm_hit_rate
    assert r1.link_bytes == r0.link_bytes
    assert s1.now == s0.now
    assert np.array_equal(s1.telemetry._data, s0.telemetry._data)
    assert np.array_equal(s1.telemetry._steps, s0.telemetry._steps)
    assert np.array_equal(s1.telemetry._n, s0.telemetry._n)
    assert s1.rng.bit_generator.state == s0.rng.bit_generator.state
    for job_id, job in s0.lmcm.jobs.items():
        other = s1.lmcm.jobs[job_id]
        assert other.fitted_step == job.fitted_step, job_id
        assert other.origin_step == job.origin_step, job_id


def test_event_skip_cold_fleet_first_fit_parity():
    """Regression: a COLD fleet (no warmup, no samples) under a
    surveillance policy must fit its first cycle at the same step with
    the same window in both modes — `next_refresh_step`'s no-samples
    branch counts the about-to-be-recorded step as the first sample."""
    runs = {}
    for skip in (False, True):
        sim2 = FleetSim([SimJob(f"j{i}",
                                WorkloadTrace([("IO", 60), ("CPU", 120),
                                               ("MEM", 60)],
                                              total_s=7200,
                                              offset=13.0 * i), 1e9)
                         for i in range(6)],
                        policy="alma-paper", warmup_s=0.0,
                        max_concurrent=4, seed=11, event_skip=skip)
        plan = [MigrationRequest("j0", 700.0, 1e9)]
        runs[skip] = (sim2, sim2.run_with_plan(plan, horizon_s=1500.0))
    (s0, r0), (s1, r1) = runs[False], runs[True]
    assert r1.total_bytes == r0.total_bytes
    assert np.array_equal(s1.telemetry._data, s0.telemetry._data)
    assert s1.rng.bit_generator.state == s0.rng.bit_generator.state
    for job_id, job in s0.lmcm.jobs.items():
        assert s1.lmcm.jobs[job_id].fitted_step == job.fitted_step
        assert s1.lmcm.jobs[job_id].origin_step == job.origin_step


def test_run_idle_bulk_matches_per_step_loop():
    """The run_idle fast path: identical ring, rng stream, and clock to
    the per-second loop (forced via the fallback flag)."""
    fast = _mini_fleet("immediate", True)
    slow = _mini_fleet("immediate", True)
    slow._bulk_ok = False                 # force the per-step loop
    fast.run_idle(333.0)
    slow.run_idle(333.0)
    assert fast.now == slow.now
    assert np.array_equal(fast.telemetry._data, slow.telemetry._data)
    assert np.array_equal(fast.telemetry._steps, slow.telemetry._steps)
    assert fast.rng.bit_generator.state == slow.rng.bit_generator.state


def test_run_idle_wraps_ring_like_the_loop():
    """Bulk appends past the ring capacity keep the surviving tail and
    the full sample count."""
    jobs = [SimJob("a", WorkloadTrace([("CPU", 30), ("IO", 30)], 3600),
                   1e9)]
    fast = FleetSim(jobs, policy="immediate", seed=5)
    slow = FleetSim([SimJob("a", WorkloadTrace([("CPU", 30), ("IO", 30)],
                                               3600), 1e9)],
                    policy="immediate", seed=5)
    slow._bulk_ok = False
    cap = fast.telemetry.capacity
    fast.run_idle(cap + 500.0)
    slow.run_idle(cap + 500.0)
    assert np.array_equal(fast.telemetry._data, slow.telemetry._data)
    assert np.array_equal(fast.telemetry._steps, slow.telemetry._steps)
    assert np.array_equal(fast.telemetry._n, slow.telemetry._n)


def test_empty_fleet_constructs_and_runs():
    """Regression: the bulk-recorder precomputation must not choke on a
    fleet with no jobs (max() over zero traces)."""
    sim = FleetSim([], policy="immediate", seed=0)
    sim.run_idle(30.0)
    res = sim.run_with_plan([], horizon_s=10.0)
    assert res.total_bytes == 0.0 and res.per_job == {}


def test_adaptive_controller_rides_event_skip():
    """The adaptive-concurrency fleet with event skipping reproduces the
    per-second loop exactly (controller decisions included)."""
    results = {}
    for skip in (False, True):
        traces = table3_traces(phase_s=60.0)
        jobs = [SimJob(j, tr, 1e9) for j, tr in traces.items()]
        sim = FleetSim(jobs, policy="immediate", warmup_s=60.0,
                       max_concurrent=8, seed=5,
                       adaptive_concurrency=True, event_skip=skip)
        plan = [MigrationRequest(j.job_id, sim.now + 5.0 + 120.0 * i,
                                 j.v_bytes)
                for i, j in enumerate(jobs)]
        results[skip] = sim.run_with_plan(plan, horizon_s=3000.0)
    assert results[True].total_bytes == results[False].total_bytes
    assert results[True].total_time == results[False].total_time
    assert results[True].link_bytes == results[False].link_bytes


# ---------------------------------------------------------------------------
# hypothesis search (skipped cleanly when the package is absent)
# ---------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_stacked_sweep_matches_reference_hypothesis(seed):
    _assert_sweep_parity(seed)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_union_find_domains_match_components_rebuild_hypothesis(seed):
    _run_uf_parity(seed)
