"""Chunked GLA core: the chunked decomposition must match the exact
per-token recurrence for any decay pattern (hypothesis), and decode must
continue a prefill bit-compatibly."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.kernels.ref import ssm_scan_ref
from repro.models import gla

RNG = np.random.default_rng(3)


def _inputs(B, H, S, Dk, Dv, decay_scale):
    q = jnp.asarray(RNG.standard_normal((B, H, S, Dk)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, H, S, Dk)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, H, S, Dv)), jnp.float32)
    lw = -jnp.abs(jnp.asarray(RNG.standard_normal((B, H, S, Dk)),
                              jnp.float32)) * decay_scale
    return q, k, v, lw


@settings(max_examples=15)
@given(s=st.integers(5, 90), dk=st.sampled_from([4, 16]),
       dv=st.sampled_from([4, 8]), decay=st.floats(0.01, 3.0),
       ssd=st.booleans())
def test_chunked_matches_exact_recurrence(s, dk, dv, decay, ssd):
    q, k, v, lw = _inputs(1, 2, s, dk, dv, decay)
    u = jnp.asarray(RNG.standard_normal((2, dk)), jnp.float32)
    y_c, st_c = gla.gla_chunked(q, k, v, lw, bonus=None if ssd else u)
    y_r, st_r = ssm_scan_ref(q, k, v, lw, bonus=u, ssd=ssd)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_r),
                               rtol=2e-4, atol=2e-4)


def test_decode_continues_prefill():
    """chunked(S) state + N decode steps == chunked(S+N) exactly."""
    B, H, S, N, Dk, Dv = 1, 2, 64, 5, 8, 8
    q, k, v, lw = _inputs(B, H, S + N, Dk, Dv, 0.4)
    y_full, st_full = gla.gla_chunked(q, k, v, lw)
    y_pre, st_pre = gla.gla_chunked(q[:, :, :S], k[:, :, :S], v[:, :, :S],
                                    lw[:, :, :S])
    st = st_pre
    ys = []
    for t in range(S, S + N):
        y_t, st = gla.gla_decode_step(q[:, :, t], k[:, :, t], v[:, :, t],
                                      lw[:, :, t], st)
        ys.append(y_t)
    y_dec = jnp.stack(ys, axis=2)
    np.testing.assert_allclose(np.asarray(y_dec),
                               np.asarray(y_full[:, :, S:]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_full),
                               rtol=1e-4, atol=1e-4)


def test_zero_pad_exactness():
    """Padding contract: non-multiple-of-chunk S gives identical results."""
    q, k, v, lw = _inputs(1, 1, 45, 8, 8, 0.5)
    y_a, st_a = gla.gla_chunked(q, k, v, lw, chunk=32)
    y_b, st_b = gla.gla_chunked(q, k, v, lw, chunk=45)
    np.testing.assert_allclose(np.asarray(y_a), np.asarray(y_b),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_a), np.asarray(st_b),
                               rtol=2e-4, atol=2e-4)
