"""Route-aware one-solve admission on pod/spine fabrics (ISSUE 8).

The load-bearing contracts:

  * ``Topology.pod_spine`` builds the 3-tier access -> pod -> spine
    fabric the module docstring draws: per-tier oversubscription shrinks
    the capacities exactly as documented, every distinct-rack pair
    exposes one candidate route per spine plane, route 0 is the
    canonical ``path()``, and same-rack pairs stay single-route;
  * the precomputed link-id tables (``ids_of`` / ``fair_share_ids``) are
    bit-parity mirrors of the dict-walk oracle — same progressive
    filling, same member order, same summation;
  * ``what_if_pair_shares`` (ONE stacked masked solve over the flattened
    (lane, route) axis) returns exactly what the per-pair reference loop
    computes, on the raw network function and through both planes;
  * the sparse masked solver agrees with the dense path and — when a
    scenario's active columns form a prefix — with the python
    ``fair_share`` summation exactly;
  * the controller's defer-k x route sweep selects identical launch sets
    AND stamps identical routes under ``sweep="stacked"`` and
    ``sweep="reference"`` over seeded random pod/spine decision points.

Hypothesis widens the search when installed (``_hypothesis_compat``
degrades the ``@given`` tests to skips otherwise); the seeded variants
run the same invariants regardless.
"""
import numpy as np
import pytest

from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

from repro.core import network
from repro.core.controller import AdaptiveConcurrencyController
from repro.core.fabric import ShardedPlane
from repro.core.orchestrator import MigrationRequest
from repro.core.plane import MigrationPlane
from repro.core.rates import PiecewiseRate

CAP = 125e6


def _fabric(pods=2, racks=2, *, pod_over=2.0, spine_over=2.0, n_spines=2):
    return network.Topology.pod_spine(
        pods, racks, access_capacity=CAP,
        pod_oversubscription=pod_over, spine_oversubscription=spine_over,
        n_spines=n_spines)


# ---------------------------------------------------------------------------
# pod_spine structure
# ---------------------------------------------------------------------------
def test_pod_spine_tiers_and_capacities():
    topo = _fabric(pods=3, racks=2, pod_over=4.0, spine_over=2.0,
                   n_spines=2)
    uplink = 2 * CAP / (4.0 * 2)           # racks * access / (over * spines)
    spine = 3 * uplink / 2.0               # pods * uplink / over
    for p in range(3):
        for r in range(2):
            l = f"acc:p{p}r{r}"
            assert topo.capacities[l] == CAP and topo.tier_of(l) == 0
        for m in range(2):
            l = f"pod:p{p}s{m}"
            assert topo.capacities[l] == pytest.approx(uplink)
            assert topo.tier_of(l) == 1
    for m in range(2):
        assert topo.capacities[f"spine:s{m}"] == pytest.approx(spine)
        assert topo.tier_of(f"spine:s{m}") == 2
    assert topo.pod_of("p2r1h0") == "p2"
    assert topo.pod_of("nonexistent") is None


def test_pod_spine_routes():
    topo = _fabric(pods=2, racks=2, n_spines=3)
    assert topo.n_routes() == 3
    # same rack: one route, no shared fabric links
    assert topo.routes("p0r0h0", "p0r0h1") == (("acc:p0r0",),)
    # cross-rack same-pod: one route per spine plane, through that
    # plane's pod uplink only (no spine hop needed inside a pod)
    rs = topo.routes("p0r0h0", "p0r1h0")
    assert len(rs) == 3
    for m, p in enumerate(rs):
        assert f"pod:p0s{m}" in p and not any("spine" in l for l in p)
    # cross-pod: each route rides plane m end to end
    rs = topo.routes("p0r0h0", "p1r1h0")
    assert len(rs) == 3
    for m, p in enumerate(rs):
        assert f"pod:p0s{m}" in p and f"spine:s{m}" in p \
            and f"pod:p1s{m}" in p
    # route 0 IS the canonical fixed-shortest path
    assert rs[0] == topo.path("p0r0h0", "p1r1h0")


def test_route_ids_mirror_routes():
    topo = _fabric()
    for pair in [("p0r0h0", "p1r1h1"), ("p0r0h0", "p0r1h0")]:
        for p, ids in zip(topo.routes(*pair), topo.route_ids(*pair)):
            assert ids is not None
            assert [topo.link_ids[l] for l in p] == list(ids)


# ---------------------------------------------------------------------------
# satellite 1: link-id tables vs the dict-walk oracle
# ---------------------------------------------------------------------------
def _random_fabric_paths(rng, topo, n):
    hosts = sorted(topo.host_links)
    paths = []
    for _ in range(n):
        src, dst = rng.choice(hosts, size=2, replace=False)
        rs = topo.routes(src, dst)
        paths.append(rs[int(rng.integers(len(rs)))])
    return paths


@pytest.mark.parametrize("seed", range(6))
def test_fair_share_ids_bit_parity(seed):
    rng = np.random.default_rng(seed)
    topo = _fabric(pods=int(rng.integers(2, 4)),
                   racks=int(rng.integers(2, 4)),
                   pod_over=float(rng.choice([1.0, 2.0, 4.0])))
    paths = _random_fabric_paths(rng, topo, int(rng.integers(1, 12)))
    oracle = network.fair_share(paths, topo.capacities)
    ids = network.fair_share_ids([topo.ids_of(p) for p in paths],
                                 topo.caps_vector())
    assert np.array_equal(oracle, ids)      # bit-exact, not allclose


def test_ids_of_unknown_link_falls_back():
    topo = _fabric()
    assert topo.ids_of(("acc:p0r0", "no-such-link")) is None
    # None ids -> unconstrained in fair_share_ids, like an empty path
    out = network.fair_share_ids([None], topo.caps_vector())
    assert np.isinf(out[0])


def test_caps_vector_tracks_set_capacity():
    topo = _fabric()
    idx = topo.link_ids["pod:p0s0"]
    topo.set_capacity("pod:p0s0", 7.0)
    assert topo.caps_vector()[idx] == 7.0
    assert topo.capacities["pod:p0s0"] == 7.0


# ---------------------------------------------------------------------------
# stacked pair pricing vs the per-pair reference
# ---------------------------------------------------------------------------
def test_pair_active_mask_one_route_per_lane():
    m = network.pair_active_mask(2, 1, 4)
    assert m.shape == (4, 7)
    assert m[:, :3].all()                   # base + fixed always active
    assert np.array_equal(m[:, 3:], np.eye(4, dtype=bool))
    for row in m:                           # exactly one pair column per row
        assert row[3:].sum() == 1


@pytest.mark.parametrize("seed", range(6))
def test_what_if_pair_shares_matches_per_pair(seed):
    rng = np.random.default_rng(100 + seed)
    topo = _fabric(pods=2, racks=2,
                   pod_over=float(rng.choice([1.0, 2.0, 4.0])))
    base = _random_fabric_paths(rng, topo, int(rng.integers(0, 4)))
    fixed = _random_fabric_paths(rng, topo, int(rng.integers(0, 3)))
    pairs = _random_fabric_paths(rng, topo, int(rng.integers(1, 10)))
    fb = max(topo.capacities.values())
    stacked = network.what_if_pair_shares(base, fixed, pairs,
                                          topo.capacities, fb)
    for j, p in enumerate(pairs):
        alone = network.fair_share(base + fixed + [p], topo.capacities)
        want = alone[-1] if np.isfinite(alone[-1]) else fb
        assert stacked[j] == want, (seed, j)


def test_what_if_pair_shares_empty():
    topo = _fabric()
    out = network.what_if_pair_shares([], [], [], topo.capacities, CAP)
    assert out.shape == (0,)


@pytest.mark.parametrize("plane_cls", [MigrationPlane, ShardedPlane])
def test_plane_pair_shares_match_reference(plane_cls):
    topo = _fabric(pod_over=4.0)
    plane = plane_cls(topo)
    rate = PiecewiseRate([60.0, 120.0], [40e6, 1e6])
    for i in range(3):
        plane.launch(MigrationRequest(f"bg{i}", 0.0, 2e9,
                                      src="p0r0h0", dst="p1r0h0"),
                     rate, 0.0)
    pairs = [p for pair in [("p0r0h1", "p1r1h0"), ("p0r1h0", "p0r0h1")]
             for p in topo.routes(*pair)]
    stacked = plane.what_if_pair_shares([], pairs)
    for j, p in enumerate(pairs):
        assert stacked[j] == plane.what_if_shares([p])[0], j


# ---------------------------------------------------------------------------
# sparse masked solver
# ---------------------------------------------------------------------------
def _masked_case(rng, n_links=8):
    links = [f"L{i}" for i in range(n_links)]
    caps = {l: float(rng.uniform(0.5, 50.0)) for l in links}
    n = int(rng.integers(1, 12))
    paths = [tuple(rng.choice(links, size=rng.integers(1, 4),
                              replace=False)) for _ in range(n)]
    inc = np.zeros((n_links, n))
    for i, p in enumerate(paths):
        for l in p:
            inc[links.index(l), i] = 1.0
    active = rng.random((int(rng.integers(1, 6)), n)) < 0.7
    return paths, caps, inc, np.asarray([caps[l] for l in links]), active


@pytest.mark.parametrize("seed", range(6))
def test_sparse_masked_matches_dense(seed):
    rng = np.random.default_rng(200 + seed)
    _, _, inc, caps, active = _masked_case(rng)
    dense = network.fair_share_masked(inc, caps, active, sparse=False)
    sparse = network.fair_share_masked(inc, caps, active, sparse=True)
    np.testing.assert_allclose(sparse, dense, rtol=1e-12)


@pytest.mark.parametrize("seed", range(6))
def test_sparse_masked_prefix_exact_vs_python(seed):
    """Prefix-active scenarios sum over ascending member columns — the
    same order as the python oracle, so equality is exact."""
    rng = np.random.default_rng(300 + seed)
    paths, caps, inc, caps_vec, _ = _masked_case(rng)
    n = len(paths)
    active = np.zeros((n, n), bool)
    for k in range(n):
        active[k, :k + 1] = True
    sparse = network.fair_share_masked(inc, caps_vec, active, sparse=True)
    for k in range(n):
        oracle = network.fair_share(paths[:k + 1], caps)
        oracle = np.where(np.isfinite(oracle), oracle, np.inf)
        assert np.array_equal(sparse[k, :k + 1], oracle), (seed, k)
        assert not sparse[k, k + 1:].any()


def test_sparse_auto_threshold_keeps_small_cases_dense():
    """Below the cell/link thresholds the dispatcher must stay on the
    dense engine — the bit-for-bit contract of every existing caller."""
    rng = np.random.default_rng(7)
    _, _, inc, caps, active = _masked_case(rng, n_links=4)
    auto = network.fair_share_masked(inc, caps, active)
    dense = network.fair_share_masked(inc, caps, active, sparse=False)
    assert np.array_equal(auto, dense)


# ---------------------------------------------------------------------------
# controller: defer-k x route, stacked vs reference
# ---------------------------------------------------------------------------
def _route_case(seed):
    """A random pod/spine decision point. Rebuilt per engine — select()
    stamps routes on launching requests, so parity runs need twins."""
    rng = np.random.default_rng(seed)
    pods = int(rng.integers(2, 4))
    racks = int(rng.integers(2, 4))
    topo = network.Topology.pod_spine(
        pods, racks, access_capacity=CAP,
        pod_oversubscription=float(rng.choice([1.0, 2.0, 4.0])),
        spine_oversubscription=float(rng.choice([1.0, 2.0])),
        n_spines=int(rng.integers(2, 4)))
    plane = ShardedPlane(topo)
    rates = {}

    def req(tag, i):
        p, r = int(rng.integers(pods)), int(rng.integers(racks))
        q, s = int(rng.integers(pods)), int(rng.integers(racks))
        r_ = MigrationRequest(
            f"{tag}{i}", 0.0, float(rng.uniform(0.2e9, 2e9)),
            src=f"p{p}r{r}h0", dst=f"p{q}r{s}h1")
        rates[r_.job_id] = PiecewiseRate(
            [60.0, 120.0], [float(rng.uniform(0, 160e6)),
                            float(rng.uniform(0, 20e6))],
            offset=float(rng.uniform(0, 120)))
        return r_

    for i in range(int(rng.integers(0, 4))):
        r = req("bg", i)
        plane.launch(r, rates[r.job_id], 0.0)
    plane.advance(float(rng.uniform(0, 5)))
    cands = [req("c", i) for i in range(int(rng.integers(1, 9)))]
    forced = [req("f", i) for i in range(int(rng.integers(0, 3)))]
    return plane, rates, cands, forced, plane.now


@pytest.mark.parametrize("seed", range(6))
def test_route_selection_parity_seeded(seed):
    """Identical (k, route) decisions: same launched job ids in the same
    order, and bit-identical stamped routes on forced + launched."""
    for trial in range(12):
        s = seed * 1000 + trial
        out = {}
        for mode in ("stacked", "reference"):
            plane, rates, cands, forced, now = _route_case(s)
            ctl = AdaptiveConcurrencyController(
                plane, rate_of=lambda q: rates[q.job_id], sweep=mode)
            sel = ctl.select(cands, now, forced=forced)
            out[mode] = ([r.job_id for r in sel],
                         [tuple(r.path or ()) for r in sel],
                         [tuple(r.path or ()) for r in forced])
        assert out["stacked"] == out["reference"], s


def test_routes_stamped_only_on_launching():
    """Deferred candidates must come back route-unpinned so the next
    boundary can re-route them."""
    topo = _fabric(pod_over=4.0)
    plane = ShardedPlane(topo)
    rate = PiecewiseRate([60.0, 120.0], [40e6, 1e6])
    cands = [MigrationRequest(f"c{i}", 0.0, 4e9,
                              src="p0r0h0", dst="p1r0h0")
             for i in range(6)]
    ctl = AdaptiveConcurrencyController(plane, rate_of=lambda q: rate)
    sel = ctl.select(cands, 0.0)
    assert sel                               # idle domain releases >= 1
    chosen = {r.job_id for r in sel}
    routes = set(topo.routes("p0r0h0", "p1r0h0"))
    for r in cands:
        if r.job_id in chosen:
            assert tuple(r.path) in routes
        else:
            assert not getattr(r, "path", None)


def test_route_stage_spreads_identical_lanes():
    """Two equal lanes between the same racks must land on different
    spine planes (tie de-confliction toward less-claimed links)."""
    topo = _fabric(pod_over=1.0, spine_over=1.0)
    plane = ShardedPlane(topo)
    rate = PiecewiseRate([60.0, 120.0], [1e6, 1e6])
    cands = [MigrationRequest(f"j{i}", 0.0, 1e9,
                              src="p0r0h0", dst="p0r1h0")
             for i in range(2)]
    ctl = AdaptiveConcurrencyController(plane, rate_of=lambda q: rate)
    sel = ctl.select(cands, 0.0)
    if len(sel) == 2:
        assert tuple(sel[0].path) != tuple(sel[1].path)


def test_custom_path_pins_single_route():
    """A stamped path OUTSIDE the topology's route set is honored as a
    fixed single route (operator-pinned lanes must not be re-routed)."""
    topo = _fabric()
    plane = ShardedPlane(topo)
    pinned = ("acc:p0r0", "acc:p1r0")       # not a fabric route
    r = MigrationRequest("pin", 0.0, 1e9, src="p0r0h0", dst="p1r0h0")
    r.path = pinned
    ctl = AdaptiveConcurrencyController(plane)
    assert ctl.routes_of(r) == (pinned,)
    sel = ctl.select([r], 0.0)
    assert sel and tuple(r.path) == pinned


# ---------------------------------------------------------------------------
# satellite 3: hypothesis search over the route-expanded masked solver
# ---------------------------------------------------------------------------
LINKS = [f"L{i}" for i in range(6)]

if HAS_HYPOTHESIS:
    route_set = st.lists(                    # one lane's candidate routes
        st.lists(st.sampled_from(LINKS), min_size=1, max_size=3,
                 unique=True).map(tuple),
        min_size=1, max_size=3)
    lanes_strategy = st.lists(route_set, min_size=1, max_size=6)
    caps_strategy = st.fixed_dictionaries(
        {l: st.floats(min_value=0.5, max_value=50.0) for l in LINKS})
else:
    lanes_strategy = caps_strategy = None


def _pair_layout(lanes):
    pair_paths = [p for rs in lanes for p in rs]
    pair_lane = [i for i, rs in enumerate(lanes) for _ in rs]
    return pair_paths, pair_lane


def _check_pair_invariants(lanes, caps):
    """Stacked pair pricing == per-pair oracle, and every scenario row of
    the underlying mask solve is feasible (per-link <= capacity)."""
    pair_paths, _ = _pair_layout(lanes)
    fb = max(caps.values())
    stacked = network.what_if_pair_shares([], [], pair_paths, caps, fb)
    for j, p in enumerate(pair_paths):
        alone = network.fair_share([p], caps)
        want = alone[0] if np.isfinite(alone[0]) else fb
        assert stacked[j] == want
        assert stacked[j] <= min(caps[l] for l in p) * (1 + 1e-9)


@settings(max_examples=100, deadline=None)
@given(lanes=lanes_strategy, caps=caps_strategy)
def test_pair_shares_oracle_equality(lanes, caps):
    _check_pair_invariants(lanes, caps)


@settings(max_examples=100, deadline=None)
@given(lanes=lanes_strategy, caps=caps_strategy)
def test_pair_mask_validity(lanes, caps):
    pair_paths, pair_lane = _pair_layout(lanes)
    m = network.pair_active_mask(0, 0, len(pair_paths))
    for row in m:
        on = np.flatnonzero(row)
        assert len(on) == 1                  # one (lane, route) per scenario
        int(pair_lane[on[0]])                # indexes a real lane


@settings(max_examples=80, deadline=None)
@given(lanes=lanes_strategy, caps=caps_strategy,
       base=lanes_strategy)
def test_pair_shares_with_base_lanes(lanes, caps, base):
    """With in-flight lanes the stacked diagonal still equals the
    per-pair fair_share(base + [pair]) oracle."""
    base_paths = [rs[0] for rs in base]
    pair_paths, _ = _pair_layout(lanes)
    fb = max(caps.values())
    stacked = network.what_if_pair_shares(base_paths, [], pair_paths,
                                          caps, fb)
    for j, p in enumerate(pair_paths):
        alone = network.fair_share(base_paths + [p], caps)
        want = alone[-1] if np.isfinite(alone[-1]) else fb
        assert stacked[j] == want
