"""Table 5 — Naive Bayes characterization of benchmark/application workloads.

The paper runs SPEC / LAME / OpenModeller under four VM configs (C1-C4) and
reports the primary/secondary NB classes. We reproduce the setup with the
fleet simulator's phase-calibrated workload generators: each "benchmark" is
a characteristic phase mixture, each "VM config" scales the compute
availability (1 vs 2 VCPUs halves per-phase CPU utilization, exactly the
effect the paper observes flipping CPU-primary to IO-primary), and the NB
classifier — trained once on labeled phases, as in the paper — labels each
15-sample window. Derived metric: classification accuracy against the true
phase labels.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import characterize
from repro.core.fleetsim import WorkloadTrace, make_training_nb

# benchmark analogues: phase mixtures per §6.2's observed behavior
BENCHMARKS = {
    "SPEC": [("CPU", 30), ("MEM", 4), ("IO", 2)],
    "LAME": [("CPU", 24), ("IO", 10)],
    "OpenModeller": [("IO", 3), ("CPU", 40), ("IO", 4)],
}
CONFIGS = {"C1": (1, 1.0), "C2": (1, 2.0), "C3": (2, 1.0), "C4": (2, 2.0)}


def _sample(trace: WorkloadTrace, vcpus: int, rng) -> tuple:
    feats, labels = [], []
    for t in np.arange(0, trace.cycle_s * 10, 1.0):
        s = trace.sample_indexes(t, rng)
        if vcpus == 2:           # second VCPU halves apparent CPU pressure
            s["compute_util"] *= 0.52
            s["step_time"] *= 0.55
        feats.append([s[f] for f in ("step_time", "dirty_bytes",
                                     "dirty_fraction", "collective_bytes",
                                     "compute_util", "hbm_util")])
        labels.append(trace.label_at(t))
    return np.asarray(feats, np.float32), np.asarray(labels)


def run() -> List[Dict]:
    nb = make_training_nb()
    rng = np.random.default_rng(7)
    rows = []
    t0 = time.perf_counter()
    n_pred = 0
    for bench, phases in BENCHMARKS.items():
        trace = WorkloadTrace(phases, total_s=3600)
        for cname, (vcpus, memgb) in CONFIGS.items():
            feats, labels = _sample(trace, vcpus, rng)
            cls, lm, post = characterize.classify_series(nb, feats)
            n_pred += len(cls)
            prim, sec = characterize.primary_secondary(cls)
            acc = float(np.mean(cls == labels)) if vcpus == 1 else None
            rows.append({
                "benchmark": bench, "config": cname,
                "primary": characterize.CLASSES[prim],
                "secondary": characterize.CLASSES[sec] if sec is not None
                else "-",
                "accuracy_vs_truth": round(acc, 3) if acc is not None else "",
                "lm_fraction": round(float(np.mean(lm)), 3),
            })
    dt = time.perf_counter() - t0
    us_per_call = dt / max(n_pred, 1) * 1e6
    return [{"name": "table5_nb", "us_per_call": round(us_per_call, 2),
             "derived": f"rows={len(rows)}"}], rows
