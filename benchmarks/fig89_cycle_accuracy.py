"""Figures 8-9 — cycle accuracy identification diagrams.

For each job: when was the migration *requested* (red dashed line in the
paper) vs when did ALMA actually *trigger* it (black line), against the
ground-truth phase timeline. Accuracy = fraction of triggers that landed in
a migration-suitable (non-MEM) phase; the paper's diagrams show every ALMA
trigger on a peak. Also emits an ASCII timeline per job.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.fleetsim import (FleetSim, SimJob, WorkloadTrace,
                                 application_traces, table3_traces)
from repro.core.orchestrator import MigrationRequest

VMEM = 1024e6


def _ascii_timeline(trace: WorkloadTrace, req_t: float, fire_t: float,
                    t0: float, horizon: float, width: int = 72) -> str:
    chars = []
    for i in range(width):
        t = t0 + horizon * i / width
        ph = trace.phase_at(t)
        c = {"MEM": "_", "CPU": "^", "IO": "~", "IDLE": "-"}[ph]
        chars.append(c)
    for t, sym in ((req_t, "R"), (fire_t, "F")):
        i = int((t - t0) / horizon * width)
        if 0 <= i < width:
            chars[i] = sym
    return "".join(chars)


def run(seeds: int = 3):
    t0c = time.perf_counter()
    rows: List[Dict] = []
    hits = {"alma-paper": [], "alma-plus": [], "immediate": []}
    for which, traces in (("bench", table3_traces()),
                          ("apps", application_traces())):
        for policy in ("immediate", "alma-paper", "alma-plus"):
            for seed in range(seeds):
                jobs = [SimJob(j, tr, VMEM) for j, tr in traces.items()]
                sim = FleetSim(jobs, policy=policy, warmup_s=1500.0,
                               max_wait=900.0, seed=seed)
                rng = np.random.default_rng(seed)
                start = sim.now
                plan = [MigrationRequest(job_id=j.job_id,
                                         created_at=start + float(
                                             rng.uniform(0, j.trace.cycle_s)),
                                         v_bytes=j.v_bytes) for j in jobs]
                res = sim.run_with_plan(plan, horizon_s=5000.0)
                hits[policy].append(res.lm_hit_rate)
                if seed == 0 and policy != "immediate":
                    for req in res.migrations:
                        tr = traces[req.job_id]
                        rows.append({
                            "set": which, "policy": policy, "vm": req.job_id,
                            "requested_at": round(req.created_at - start, 1),
                            "fired_at": round(req.scheduled_at - start, 1),
                            "fired_phase": tr.phase_at(req.scheduled_at),
                            "timeline": _ascii_timeline(
                                tr, req.created_at, req.scheduled_at,
                                start, 3000.0),
                        })
    derived = {p: round(float(np.mean(v)), 3) for p, v in hits.items()}
    dt = time.perf_counter() - t0c
    return [{"name": "fig89_cycle_accuracy",
             "us_per_call": round(dt * 1e6 / max(len(rows), 1), 1),
             "derived": (f"hit_imm={derived['immediate']}"
                         f" hit_paper={derived['alma-paper']}"
                         f" hit_plus={derived['alma-plus']}")}], rows
