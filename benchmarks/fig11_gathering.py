"""Figure 11 — in-job telemetry gathering overhead.

The paper measures SNMP index collection overhead inside VMs (~0.75% with
one VCPU, ~0.5% with two, flat in memory size). Our collection is an
in-process ring-buffer record per step; we measure the training-step
overhead with telemetry on vs off on a real (reduced) model training step,
across 'VM configurations' = model widths, mirroring the memory sweep.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.telemetry import TelemetryBuffer
from repro.data import make_batch
from repro.train import init_train_state, make_train_step

CONFIGS = {"256MB": dict(d_model=128, d_ff=256),
           "512MB": dict(d_model=192, d_ff=384),
           "1080MB": dict(d_model=256, d_ff=512)}


def _steps_per_sec(cfg, telemetry: bool, n: int = 8) -> float:
    state = init_train_state(cfg, jax.random.key(0))
    step = jax.jit(make_train_step(cfg, telemetry=telemetry))
    batch = make_batch(cfg, 2, 64)
    buf = TelemetryBuffer()
    state, m = step(state, batch)
    jax.block_until_ready(m)             # compile
    t0 = time.perf_counter()
    for i in range(n):
        state, m = step(state, batch)
        if telemetry:
            jax.block_until_ready(m)
            buf.record(i, dirty_bytes=float(m["dirty_bytes"]),
                       dirty_fraction=float(m["dirty_fraction"]),
                       step_time=0.0)
    jax.block_until_ready(m)
    return n / (time.perf_counter() - t0)


def run():
    rows: List[Dict] = []
    overheads = []
    for name, tweak in CONFIGS.items():
        cfg = get_config("internlm2_1p8b").smoke().replace(**tweak)
        base = _steps_per_sec(cfg, telemetry=False)
        tele = _steps_per_sec(cfg, telemetry=True)
        ovh = (base / tele - 1.0) * 100
        overheads.append(ovh)
        rows.append({"config": name, "steps_per_s_base": round(base, 2),
                     "steps_per_s_telemetry": round(tele, 2),
                     "overhead_pct": round(ovh, 2)})
    import numpy as np
    return [{"name": "fig11_gathering",
             "us_per_call": round(1e6 / max(rows[0]['steps_per_s_base'], 1e-9), 1),
             "derived": f"mean_overhead={np.mean(overheads):.2f}%"}], rows
