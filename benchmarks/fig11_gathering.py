"""Figure 11 — in-job telemetry gathering + orchestration overhead.

The paper measures SNMP index collection overhead inside VMs (~0.75% with
one VCPU, ~0.5% with two, flat in memory size). Our collection is an
in-process ring-buffer record per step; we measure the training-step
overhead with telemetry on vs off on a real (reduced) model training step,
across 'VM configurations' = model widths, mirroring the memory sweep.

The migration plane adds a second overhead source the paper does not have:
advancing every in-flight contended transfer once per sampling period
(fair-share recompute + dirty accrual at event boundaries). The
``plane_*`` rows report that cost per 1 s simulation step at increasing
in-flight counts — it must stay far below the 1 s budget for the
orchestrator to run in real time — for both the vectorized event loop
(PiecewiseRate-registered lanes, batched accrual) and the kept per-lane
scalar reference it is measured against.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import network
from repro.core.fleetsim import PAPER_BANDWIDTH, WorkloadTrace
from repro.core.orchestrator import MigrationRequest
from repro.core.plane import MigrationPlane

CONFIGS = {"256MB": dict(d_model=128, d_ff=256),
           "512MB": dict(d_model=192, d_ff=384),
           "1080MB": dict(d_model=256, d_ff=512)}


def _steps_per_sec(cfg, telemetry: bool, n: int = 8) -> float:
    import jax
    from repro.core.telemetry import TelemetryBuffer
    from repro.data import make_batch
    from repro.train import init_train_state, make_train_step

    state = init_train_state(cfg, jax.random.key(0))
    step = jax.jit(make_train_step(cfg, telemetry=telemetry))
    batch = make_batch(cfg, 2, 64)
    buf = TelemetryBuffer()
    state, m = step(state, batch)
    jax.block_until_ready(m)             # compile
    t0 = time.perf_counter()
    for i in range(n):
        state, m = step(state, batch)
        if telemetry:
            jax.block_until_ready(m)
            buf.record(i, dirty_bytes=float(m["dirty_bytes"]),
                       dirty_fraction=float(m["dirty_fraction"]),
                       step_time=0.0)
    jax.block_until_ready(m)
    return n / (time.perf_counter() - t0)


def _plane_step_cost(n_lanes: int, n_steps: int = 64, *,
                     vectorized: bool = True) -> float:
    """Mean wall-clock microseconds to advance the migration plane by one
    1 s sampling period with ``n_lanes`` transfers contending one link.
    ``vectorized=False`` times the kept per-lane reference loop — the
    baseline for the vectorized event loop's speedup."""
    plane = MigrationPlane(network.Topology.single_link(PAPER_BANDWIDTH),
                           vectorized=vectorized)
    tr = WorkloadTrace([("MEM", 60), ("CPU", 60)], 120)
    for i in range(n_lanes):
        # state large enough that every lane stays in flight all benchmark;
        # lanes register their PiecewiseRate table (the vectorized loop's
        # batched dirty lookup; the scalar loop calls it per lane)
        plane.launch(MigrationRequest(f"j{i}", 0.0, 1e12), tr.rate_table,
                     0.0)
    plane.advance(1.0)                   # settle the first event layout
    t0 = time.perf_counter()
    now = plane.now
    for _ in range(n_steps):
        now += 1.0
        plane.advance(now)
    return (time.perf_counter() - t0) / n_steps * 1e6


def run():
    from repro.configs import get_config

    rows: List[Dict] = []
    overheads = []
    for name, tweak in CONFIGS.items():
        cfg = get_config("internlm2_1p8b").smoke().replace(**tweak)
        base = _steps_per_sec(cfg, telemetry=False)
        tele = _steps_per_sec(cfg, telemetry=True)
        ovh = (base / tele - 1.0) * 100
        overheads.append(ovh)
        rows.append({"config": name, "steps_per_s_base": round(base, 2),
                     "steps_per_s_telemetry": round(tele, 2),
                     "overhead_pct": round(ovh, 2)})
    plane_us = {}
    for n_lanes in (8, 64):
        us = _plane_step_cost(n_lanes)
        scalar_us = _plane_step_cost(n_lanes, vectorized=False)
        plane_us[n_lanes] = us
        rows.append({"config": f"plane_{n_lanes}_lanes",
                     "plane_us_per_step": round(us, 1),
                     "plane_scalar_us_per_step": round(scalar_us, 1),
                     "vectorized_speedup": round(scalar_us / max(us, 1e-9),
                                                 2),
                     "realtime_budget_pct": round(us / 1e6 * 100, 4)})
    sp64 = rows[-1]["vectorized_speedup"]
    return [{"name": "fig11_gathering",
             "us_per_call": round(1e6 / max(rows[0]['steps_per_s_base'], 1e-9), 1),
             "derived": (f"mean_overhead={np.mean(overheads):.2f}% "
                         f"plane_us_per_step@64={plane_us[64]:.0f} "
                         f"plane_vec_speedup@64={sp64}x")}], rows
