"""Prediction-guard acceptance bench — guarded vs unguarded execution
under drifting loads where the admission-time fit is wrong BY
CONSTRUCTION.

Every lane is priced from a *stale* predicted dirty-rate table (the flat
cool profile the fit saw before the drift), then executed against a true
table that drifts into a hostile MEM episode right after launch. The
unguarded arm trusts the price unconditionally: hostile lanes grind
through Xen's ``max_rounds``/``total_cap`` stop ladder at up to
``stop_total_factor`` x the priced bytes and settle with whatever dirty
remainder the episode left — a large stop-and-copy downtime. The guarded
arm runs the same fleet through :class:`repro.core.guard.MigrationGuard`:

  * **auto-converge cells** — the hostile rate is within reach of the
    progressive throttle ladder (``throttle_factor ** step``), so the
    guard drags the lane back under the link speed and it converges with
    a live-migration-grade downtime;
  * **never-converge cells** — the hostile rate outruns even the floored
    throttle, so the guard aborts the lane (``stop_reason ==
    "guard_abort"``), the driver reprices the retry from the *refit*
    (true) table, defers it past the episode (the trough-deferral path
    FleetSim wires through ``SurveillanceEngine.next_trough``), and the
    lane completes cheaply once the drift has passed.

Acceptance contract (gated by ``benchmarks.run --quick``):

  * guarded wastes STRICTLY fewer bytes than unguarded on the drifting
    (aborted / never-converging) lanes of every cell;
  * guarded meets at least as many SLAs (completed, downtime <=
    ``SLA_DOWNTIME_S``, finish within ``DEADLINE_S`` of first launch);
  * guarded recovery p95 (first launch -> final completion of drifting
    lanes) stays finite and bounded by the horizon.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import network, strunk
from repro.core.fabric import ShardedPlane
from repro.core.guard import MigrationGuard
from repro.core.orchestrator import MigrationRequest
from repro.core.rates import PiecewiseRate

BW = 125e6                   # the paper's 1 Gbit/s migration network
DT = 1.0                     # driver sampling period, seconds
COOL_RATE = 3e6              # the rate the stale fit predicts everywhere
SLA_DOWNTIME_S = 0.5         # live-migration downtime SLA
DEADLINE_S = 900.0           # per-lane completion SLA from first launch
RETRY_BACKOFF_S = 8.0
RETRY_MAX = 5

# cell -> (hostile dirty rate, hostile episode length, guard kwargs):
# auto_converge is reachable by a steep throttle ladder (250e6 * 0.3 =
# 75e6 < BW) and its guard is patient (high abort_ratio), so ONLY the
# throttle rung fires; never_converge outruns even the floored ladder
# (4e9 * 0.0625 >> BW) and its guard aborts at 2x, so the
# abort -> refit -> deferred-retry rung is what completes the lane
CELLS: Dict[str, Tuple[float, float, dict]] = {
    "auto_converge": (250e6, 240.0,
                      dict(throttle_ratio=1.2, abort_ratio=12.0,
                           throttle_factor=0.3)),
    "never_converge": (4e9, 300.0,
                       dict(throttle_ratio=1.3, abort_ratio=2.0)),
}


def drifting_table(hot_rate: float, t0: float, t1: float,
                   horizon: float) -> PiecewiseRate:
    """True dirty rate: cool everywhere except the hostile [t0, t1)
    episode (cycle = horizon, so one-shot within a run)."""
    return PiecewiseRate([t0, t1, horizon],
                         [COOL_RATE, hot_rate, COOL_RATE])


def stale_table(horizon: float) -> PiecewiseRate:
    """The fit the admission price is built from — flat cool, blind to
    the drift (wrong by construction)."""
    return PiecewiseRate([horizon], [COOL_RATE])


def _price(v: float, bw: float, table, t0: float) -> Tuple[float, float]:
    out = strunk.what_if_cost_batch([v], bw, [table], [t0], full=True)
    return float(out.bytes_sent[0]), float(out.total_time[0])


def make_lanes(cell: str, *, n_drift: int = 2, n_clean: int = 2,
               horizon: float = 1600.0) -> List[dict]:
    """``n_drift`` staggered drifting lanes (episodes non-overlapping so
    each is individually attributable) plus ``n_clean`` well-predicted
    background lanes sharing the link."""
    hot, ep, _ = CELLS[cell]
    lanes = []
    for i in range(n_drift):
        t = 600.0 * i
        lanes.append(dict(
            job_id=f"{cell}-drift{i}", v=1.5e9, t=t, drift=True,
            true=drifting_table(hot, t + 10.0, t + 10.0 + ep, horizon),
            pred=stale_table(horizon)))
    for i in range(n_clean):
        tbl = stale_table(horizon)
        lanes.append(dict(job_id=f"{cell}-clean{i}", v=0.25e9,
                          t=50.0 + 600.0 * (i % n_drift), drift=False,
                          true=tbl, pred=tbl))
    return lanes


def run_arm(lanes: List[dict], guard: Optional[MigrationGuard], *,
            horizon: float = 1600.0) -> dict:
    """Drive one arm's fleet on a shared-link fabric to completion (or
    the horizon), with the guarded arm's aborted lanes repriced from the
    refit (true) table and deferred past the hostile episode."""
    plane = ShardedPlane(network.Topology.single_link(BW), guard=guard)
    queue = sorted((dict(l) for l in lanes), key=lambda l: l["t"])
    retries: List[dict] = []
    by_req: Dict[int, dict] = {}
    first_launch: Dict[str, float] = {}
    bytes_by_job: Dict[str, float] = {}
    finish: Dict[str, float] = {}
    downtime: Dict[str, float] = {}
    n_aborts = 0
    now = 0.0
    while now < horizon and (queue or retries or plane.in_flight):
        due = [l for l in retries if l["t"] <= now]
        retries = [l for l in retries if l["t"] > now]
        while queue and queue[0]["t"] <= now:
            due.append(queue.pop(0))
        for l in due:
            req = MigrationRequest(l["job_id"], created_at=now,
                                   v_bytes=l["v"], src="h0", dst="h1")
            share = plane.probe_bandwidth("h0", "h1", 1)
            req.expected_bytes, req.expected_time = \
                _price(l["v"], share, l["pred"], now)
            first_launch.setdefault(l["job_id"], now)
            by_req[id(req)] = l
            plane.launch(req, l["true"], now)
        now += DT
        for req, outcome in plane.advance(now):
            l = by_req.pop(id(req))
            jid = l["job_id"]
            bytes_by_job[jid] = bytes_by_job.get(jid, 0.0) \
                + outcome.bytes_sent
            if outcome.stop_reason == strunk.STOP_GUARD:
                n_aborts += 1
                l["retries"] = l.get("retries", 0) + 1
                if l["retries"] > RETRY_MAX:
                    continue
                # misprediction feedback: the refit sees the true table,
                # so the retry is priced honestly AND deferred to the
                # next trough (first boundary where the drift has cooled)
                t = now + RETRY_BACKOFF_S * 2.0 ** (l["retries"] - 1)
                while t < horizon and l["true"](t) > BW / 2.0:
                    t += DT
                l["t"], l["pred"] = t, l["true"]
                retries.append(l)
            else:
                finish[jid] = now
                downtime[jid] = outcome.downtime
    drift_ids = [l["job_id"] for l in lanes if l["drift"]]
    v_of = {l["job_id"]: l["v"] for l in lanes}
    wasted = sum(bytes_by_job.get(j, 0.0)
                 - (v_of[j] if j in finish else 0.0) for j in drift_ids)
    sla = sum(1 for l in lanes
              if l["job_id"] in finish
              and downtime[l["job_id"]] <= SLA_DOWNTIME_S
              and finish[l["job_id"]] - first_launch[l["job_id"]]
              <= DEADLINE_S)
    recov = [finish[j] - first_launch[j] for j in drift_ids if j in finish]
    return {
        "completed": len(finish),
        "n_lanes": len(lanes),
        "total_bytes": float(sum(bytes_by_job.values())),
        "wasted_drift_bytes": float(wasted),
        "sla_met": int(sla),
        "n_guard_aborts": n_aborts,
        "n_throttles": guard.n_throttles if guard is not None else 0,
        "recovery_p95_s": (float(np.percentile(recov, 95.0))
                           if recov else float("inf")),
        "worst_downtime_s": float(max(downtime.values(), default=0.0)),
    }


def sweep(cells=tuple(CELLS), *, horizon: float = 1600.0) -> List[dict]:
    """Guarded-vs-unguarded pairs, one row per cell. Each cell's guard
    runs at drift-hunting thresholds (tighter than the library defaults
    — these loads are hostile by construction and the bench measures the
    ladder, not its patience), tuned so the two cells exercise the two
    rungs separately: see ``CELLS``."""
    rows = []
    for cell in cells:
        lanes = make_lanes(cell, horizon=horizon)
        un = run_arm(lanes, None, horizon=horizon)
        g = MigrationGuard(**CELLS[cell][2])
        gu = run_arm(lanes, g, horizon=horizon)
        rows.append({
            "cell": cell,
            "unguarded": un,
            "guarded": gu,
            "bytes_saved": un["wasted_drift_bytes"]
            - gu["wasted_drift_bytes"],
        })
    return rows


def check(rows: List[dict]) -> Dict[str, bool]:
    """The acceptance booleans ``benchmarks.run --quick`` gates on."""
    return {
        "guarded_fewer_wasted_bytes": all(
            r["guarded"]["wasted_drift_bytes"]
            < r["unguarded"]["wasted_drift_bytes"] for r in rows),
        "guarded_sla_no_worse": all(
            r["guarded"]["sla_met"] >= r["unguarded"]["sla_met"]
            for r in rows),
        "guarded_sla_wins_somewhere": any(
            r["guarded"]["sla_met"] > r["unguarded"]["sla_met"]
            for r in rows),
        "recovery_bounded": all(
            np.isfinite(r["guarded"]["recovery_p95_s"]) for r in rows),
        "all_guarded_completed": all(
            r["guarded"]["completed"] == r["guarded"]["n_lanes"]
            for r in rows),
    }


def run(**kw):
    """Harness entry (``python -m benchmarks.run guard_suite``)."""
    rows = sweep(**kw)
    crit = check(rows)
    summary = [{
        "name": f"guard_suite_{r['cell']}",
        "us_per_call": 0,
        "derived": (f"saved={r['bytes_saved'] / 1e9:.2f}GB "
                    f"sla={r['guarded']['sla_met']}"
                    f"vs{r['unguarded']['sla_met']} "
                    f"aborts={r['guarded']['n_guard_aborts']} "
                    f"throttles={r['guarded']['n_throttles']}"),
    } for r in rows]
    return summary, {"rows": rows, "criteria": crit}
