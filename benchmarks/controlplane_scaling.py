"""Control-plane scaling sweep — decision latency and event-skipped time.

ISSUE 5's acceptance bench, two measurements:

1. **Defer-k decision latency** (16 -> 256 candidates x 2 -> 8 racks):
   one ``AdaptiveConcurrencyController.select`` over a simultaneous
   candidate burst, stacked one-solve sweep vs the kept per-k reference
   loop. The reference solves fair shares and prices a pre-copy batch
   once PER prefix (O(n) solves + O(n) simulations per component); the
   stacked sweep answers every prefix with ONE masked share solve
   (``network.fair_share_masked``) and ONE flattened
   ``strunk.what_if_cost_batch``. Selections must be bit-identical.

2. **Event-skipping FleetSim** (sparse 1-hour plans): ``run_with_plan``
   with ``event_skip`` on vs off on an idle-dominated fleet — a handful
   of migrations spread over an hour, long workload cycles. With
   ``policy="immediate"`` (the paper's no-surveillance baseline; the
   simulator no longer burns surveillance ticks it never reads) the skip
   path jumps straight between arrivals/releases; with ``alma-paper``
   jumps stop at every surveillance staleness boundary so the refresh
   schedule — and therefore every fit and decision — is bit-identical.
   Results (bytes, times, telemetry ring, rng stream) must match the
   per-second loop exactly.

``benchmarks.run --quick`` runs a reduced grid and asserts: sweep
speedup >= 5x at 64 candidates, selections bit-equal everywhere, and
>= 10x end-to-end wall time on the immediate sparse plan.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import numpy as np

from repro.core import network
from repro.core.controller import AdaptiveConcurrencyController
from repro.core.fabric import ShardedPlane
from repro.core.fleetsim import (FleetSim, PAPER_BANDWIDTH, SimJob,
                                 WorkloadTrace)
from repro.core.orchestrator import MigrationRequest
from repro.core.rates import PiecewiseRate

ACCESS = PAPER_BANDWIDTH                  # 1 Gbit/s ToR links


def _controller_case(n_cands: int, racks: int, seed: int):
    """A contended decision point: some lanes already in flight plus a
    simultaneous burst of intra- and cross-rack candidates."""
    topo = network.Topology.multi_rack(
        racks, ACCESS, core_capacity=racks * ACCESS / 2.0, hosts_per_rack=2)
    plane = ShardedPlane(topo)
    rng = np.random.default_rng(seed)
    rates: Dict[str, PiecewiseRate] = {}

    def lane(tag: str, i: int) -> MigrationRequest:
        src, dst = int(rng.integers(racks)), int(rng.integers(racks))
        req = MigrationRequest(f"{tag}{i}", 0.0,
                               float(rng.uniform(0.3e9, 2e9)),
                               src=f"r{src}h0", dst=f"r{dst}h1")
        rates[req.job_id] = PiecewiseRate(
            [60.0, 120.0], [float(rng.uniform(0, 150e6)), 3e6],
            offset=float(rng.uniform(0, 120)))
        return req

    for i in range(racks):                 # background in-flight lanes
        plane.launch(lane("bg", i), rates[f"bg{i}"], 0.0)
    plane.advance(1.0)
    cands = [lane("c", i) for i in range(n_cands)]
    return plane, cands, rates


def sweep_cell(n_cands: int, racks: int, seed: int = 0, reps: int = 3
               ) -> Dict:
    """Time one select() under both sweep engines; assert identical
    selections."""
    row = {"n_candidates": n_cands, "racks": racks}
    picks = {}
    for mode in ("stacked", "reference"):
        plane, cands, rates = _controller_case(n_cands, racks, seed)
        ctl = AdaptiveConcurrencyController(
            plane, rate_of=lambda r: rates[r.job_id], sweep=mode)
        best = np.inf
        for _ in range(reps):
            t0 = time.perf_counter()
            picks[mode] = [r.job_id for r in ctl.select(cands, plane.now)]
            best = min(best, time.perf_counter() - t0)
        row[f"{mode}_ms"] = round(best * 1e3, 3)
    row["speedup"] = round(row["reference_ms"] / max(row["stacked_ms"],
                                                     1e-9), 2)
    row["selection_equal"] = picks["stacked"] == picks["reference"]
    row["launched"] = len(picks["stacked"])
    return row


def sweep(n_list: Sequence[int] = (16, 64, 256),
          racks_list: Sequence[int] = (2, 4, 8), seed: int = 0
          ) -> List[Dict]:
    return [sweep_cell(n, racks, seed)
            for n in n_list for racks in racks_list]


def _sparse_fleet(policy: str, n_jobs: int, event_skip: bool,
                  seed: int = 3):
    """An idle-dominated fleet: long (2040 s) workload cycles, warmup
    long enough for confident cycle fits, four migrations spread over the
    hour. Warmup always runs event-skipped (its bulk path is bit-equal
    and tested separately); ``event_skip`` governs only the measured
    ``run_with_plan``."""
    jobs = [SimJob(f"j{i}",
                   WorkloadTrace([("IO", 340.0), ("CPU", 680.0),
                                  ("MEM", 340.0), ("CPU", 680.0)],
                                 total_s=28800, offset=23.0 * i), 1e9)
            for i in range(n_jobs)]
    sim = FleetSim(jobs, policy=policy, warmup_s=8200.0, max_concurrent=8,
                   seed=seed, event_skip=True)
    sim._event_skip = event_skip
    return sim


def fleetsim_cell(policy: str, n_jobs: int, horizon_s: float = 3600.0
                  ) -> Dict:
    """run_with_plan with event skipping on vs off: identical results
    (bytes, summed time, telemetry ring, rng stream), wall-clock ratio."""
    out = {}
    for skip in (True, False):
        sim = _sparse_fleet(policy, n_jobs, skip)
        plan = [MigrationRequest(f"j{i}", sim.now + 300.0 + 900.0 * k, 1e9)
                for k, i in enumerate((0, 5, 11, 17))]
        t0 = time.perf_counter()
        res = sim.run_with_plan(plan, horizon_s=horizon_s)
        out[skip] = (time.perf_counter() - t0, res, sim)
    (w1, r1, s1), (w0, r0, s0) = out[True], out[False]
    identical = (r1.total_bytes == r0.total_bytes
                 and r1.total_time == r0.total_time
                 and r1.link_bytes == r0.link_bytes
                 and s1.now == s0.now
                 and np.array_equal(s1.telemetry._data, s0.telemetry._data)
                 and np.array_equal(s1.telemetry._steps, s0.telemetry._steps)
                 and s1.rng.bit_generator.state == s0.rng.bit_generator.state)
    return {"policy": policy, "n_jobs": n_jobs, "horizon_s": horizon_s,
            "completed": len(r1.per_job),
            "skip_wall_s": round(w1, 3), "loop_wall_s": round(w0, 3),
            "speedup": round(w0 / max(w1, 1e-9), 2),
            "identical": bool(identical)}


def fleetsim_cells(n_jobs: int = 96) -> List[Dict]:
    # warm jax shape buckets outside the timed runs (the surveillance
    # pipeline jit-compiles per power-of-two batch bucket)
    fleetsim_cell("alma-paper", n_jobs, horizon_s=60.0)
    return [fleetsim_cell("immediate", n_jobs),
            fleetsim_cell("alma-paper", n_jobs)]


def check(sweep_rows: Sequence[Dict], sim_rows: Sequence[Dict]
          ) -> Dict[str, bool]:
    """The acceptance booleans (--quick criteria)."""
    at64 = [r for r in sweep_rows if r["n_candidates"] == 64]
    imm = [r for r in sim_rows if r["policy"] == "immediate"]
    return {
        "sweep_5x_at_64": bool(at64) and all(r["speedup"] >= 5.0
                                             for r in at64),
        "selections_bit_equal": all(r["selection_equal"]
                                    for r in sweep_rows),
        "run_with_plan_10x": bool(imm) and all(r["speedup"] >= 10.0
                                               for r in imm),
        "run_with_plan_identical": all(r["identical"] for r in sim_rows),
    }


def run():
    t0 = time.perf_counter()
    sweep_rows = sweep()
    sim_rows = fleetsim_cells()
    dt = time.perf_counter() - t0
    crit = check(sweep_rows, sim_rows)
    at64 = max(r["speedup"] for r in sweep_rows if r["n_candidates"] == 64)
    skip = max(r["speedup"] for r in sim_rows if r["policy"] == "immediate")
    rows = sweep_rows + sim_rows + [{"criteria": crit}]
    return [{"name": "controlplane_scaling",
             "us_per_call": round(dt * 1e6 / max(len(rows), 1), 1),
             "derived": (f"sweep@64={at64}x skip={skip}x "
                         f"parity={crit['selections_bit_equal']}"
                         f"&{crit['run_with_plan_identical']}")}], rows


if __name__ == "__main__":
    summary, rows = run()
    for r in rows:
        print(r)
    print(summary)
