"""Sharded-fabric sweep — racks x lanes under core oversubscription.

The multi-rack substrate (``network.Topology.multi_rack``): per-rack ToR
access links at the paper's 1 Gbit/s, joined by a core sized at
``racks x access / oversubscription`` (1:1 = non-blocking spine, 1:4 =
heavily oversubscribed). Each configuration launches an intra-rack lane
burst per rack plus a ring of cross-rack lanes through the core, drains
the fabric, and records:

  * per-link byte conservation (bytes <= capacity x elapsed) on EVERY
    link — the fabric's correctness invariant under arbitrary sharing;
  * how the core's oversubscription shifts bytes/time (the cross-rack
    lanes are the ones that pay);
  * domain statistics (shard count, merges) proving the fleet is NOT one
    flat migration domain;
  * steady-state event-loop cost per 1 s step: sharded vs monolithic
    plane, vectorized vs the scalar reference loop — the fig11-style
    overhead numbers at fabric scale.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import numpy as np

from repro.core import network
from repro.core.fabric import ShardedPlane
from repro.core.fleetsim import PAPER_BANDWIDTH, WorkloadTrace
from repro.core.orchestrator import MigrationRequest
from repro.core.plane import MigrationPlane

ACCESS = PAPER_BANDWIDTH                  # 1 Gbit/s ToR links


def _topology(racks: int, oversub: float) -> network.Topology:
    return network.Topology.multi_rack(
        racks, ACCESS, core_capacity=racks * ACCESS / oversub,
        hosts_per_rack=2)


def _launch_burst(plane, racks: int, lanes_per_rack: int, *,
                  cross_lanes: int, rng: np.random.Generator,
                  v_scale: float = 1.0) -> int:
    tr = WorkloadTrace([("MEM", 60), ("CPU", 60)], 120)
    n = 0
    for r in range(racks):
        for i in range(lanes_per_rack):
            plane.launch(
                MigrationRequest(f"r{r}j{i}", 0.0,
                                 v_scale * float(rng.uniform(0.5e9, 1.5e9)),
                                 src=f"r{r}h0", dst=f"r{r}h1"),
                tr.rate_table, 0.0)
            n += 1
    for c in range(cross_lanes):
        r = c % racks
        plane.launch(
            MigrationRequest(f"x{c}", 0.0,
                             v_scale * float(rng.uniform(0.5e9, 1.5e9)),
                             src=f"r{r}h0", dst=f"r{(r + 1) % racks}h0"),
            tr.rate_table, 0.0)
        n += 1
    return n


def run_config(racks: int, lanes_per_rack: int, oversub: float,
               seed: int = 0) -> Dict:
    """Drain one burst; verify conservation on every link."""
    topo = _topology(racks, oversub)
    plane = ShardedPlane(topo)
    rng = np.random.default_rng(seed)
    n = _launch_burst(plane, racks, lanes_per_rack,
                      cross_lanes=racks, rng=rng)
    domains_at_burst = plane.domain_count
    done = plane.advance(np.inf)
    elapsed = plane.now
    caps = topo.capacities
    conservation = {
        l: b <= caps[l] * elapsed * (1 + 1e-9)
        for l, b in plane.link_bytes.items()
    }
    outs = [o for _, o in done]
    return {
        "racks": racks,
        "lanes_per_rack": lanes_per_rack,
        "core_oversubscription": oversub,
        "lanes": n,
        "completed": len(outs),
        "domains_at_burst": domains_at_burst,
        "domain_merges": plane.merges,
        "makespan_s": round(elapsed, 2),
        "total_bytes_GB": round(sum(o.bytes_sent for o in outs) / 1e9, 3),
        "sum_time_s": round(sum(o.total_time for o in outs), 2),
        "links_checked": len(conservation),
        "conservation_ok": all(conservation.values()),
        "core_utilization": round(
            plane.link_bytes.get("core", 0.0)
            / (caps.get("core", np.inf) * elapsed), 3),
    }


def step_cost(racks: int, lanes_per_rack: int, *, mode: str,
              n_steps: int = 64, seed: int = 0) -> float:
    """Steady-state wall-clock microseconds per 1 s fabric step with every
    lane still in flight. Modes: sharded / monolithic / scalar (the
    monolithic per-lane reference loop)."""
    topo = _topology(racks, 1.0)
    if mode == "sharded":
        plane = ShardedPlane(topo)
    else:
        plane = MigrationPlane(topo, vectorized=(mode == "monolithic"))
    rng = np.random.default_rng(seed)
    # state large enough that no lane completes inside the measurement
    _launch_burst(plane, racks, lanes_per_rack, cross_lanes=racks,
                  rng=rng, v_scale=1e3)
    plane.advance(1.0)
    now = plane.now
    t0 = time.perf_counter()
    for _ in range(n_steps):
        now += 1.0
        plane.advance(now)
    return (time.perf_counter() - t0) / n_steps * 1e6


def sweep(racks_list: Sequence[int] = (2, 4, 8),
          lanes_list: Sequence[int] = (2, 8),
          oversubs: Sequence[float] = (1.0, 2.0, 4.0)) -> List[Dict]:
    rows = [run_config(r, lpr, ov)
            for r in racks_list for lpr in lanes_list for ov in oversubs]
    # step-cost rows at the smallest and largest requested configs (the
    # quick smoke passes a reduced sweep; don't time beyond it)
    step_configs = {(min(racks_list), min(lanes_list)),
                    (max(racks_list), max(lanes_list))}
    for racks, lpr in sorted(step_configs):
        costs = {m: min(step_cost(racks, lpr, mode=m) for _ in range(3))
                 for m in ("sharded", "monolithic", "scalar")}
        rows.append({
            "step_cost": True, "racks": racks, "lanes_per_rack": lpr,
            "lanes": racks * (lpr + 1),
            "sharded_us_per_step": round(costs["sharded"], 1),
            "monolithic_us_per_step": round(costs["monolithic"], 1),
            "scalar_us_per_step": round(costs["scalar"], 1),
            "vectorized_speedup": round(
                costs["scalar"] / max(costs["monolithic"], 1e-9), 2),
            "sharded_speedup_vs_scalar": round(
                costs["scalar"] / max(costs["sharded"], 1e-9), 2),
        })
    return rows


def run():
    t0 = time.perf_counter()
    rows = sweep()
    dt = time.perf_counter() - t0
    ok = all(r["conservation_ok"] for r in rows if "conservation_ok" in r)
    sc = max((r for r in rows if r.get("step_cost")),
             key=lambda r: r["racks"])
    return [{"name": "fabric_sweep",
             "us_per_call": round(dt * 1e6 / max(len(rows), 1), 1),
             "derived": (f"conservation_ok={ok} "
                         f"vec_speedup@{sc['lanes']}lanes="
                         f"{sc['vectorized_speedup']}x "
                         f"sharded_speedup={sc['sharded_speedup_vs_scalar']}x")
             }], rows


if __name__ == "__main__":
    print(run())
