"""Sharded-fabric sweep — racks x lanes under core oversubscription.

The multi-rack substrate (``network.Topology.multi_rack``): per-rack ToR
access links at the paper's 1 Gbit/s, joined by a core sized at
``racks x access / oversubscription`` (1:1 = non-blocking spine, 1:4 =
heavily oversubscribed). Each configuration launches an intra-rack lane
burst per rack plus a ring of cross-rack lanes through the core, drains
the fabric, and records:

  * per-link byte conservation (bytes <= capacity x elapsed) on EVERY
    link — the fabric's correctness invariant under arbitrary sharing;
  * how the core's oversubscription shifts bytes/time (the cross-rack
    lanes are the ones that pay);
  * domain statistics (shard count, merges) proving the fleet is NOT one
    flat migration domain;
  * steady-state event-loop cost per 1 s step: sharded vs monolithic
    plane, vectorized vs the scalar reference loop — the fig11-style
    overhead numbers at fabric scale.

Route-aware rows (ISSUE 8): the same burst on a 3-tier pod/spine fabric
(``Topology.pod_spine``, pods x racks x pod-tier oversubscription 1:1 ->
1:4), once with every lane pinned to route 0 (the fixed-shortest-path
baseline) and once routed by ``pick_route`` across the spine planes.
Route-aware must move no more contended bytes than fixed-path on every
cell and strictly fewer on at least one oversubscribed cell;
``route_latency`` proves the stacked defer-k x route controller sweep at
64 candidates x 4 routes stays within ~2x of the flat-fabric sweep, and
``route_parity`` asserts stacked-vs-reference (k, route) selections are
bit-equal.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import numpy as np

from repro.core import network
from repro.core.fabric import ShardedPlane
from repro.core.fleetsim import PAPER_BANDWIDTH, WorkloadTrace
from repro.core.orchestrator import MigrationRequest
from repro.core.plane import MigrationPlane

ACCESS = PAPER_BANDWIDTH                  # 1 Gbit/s ToR links


def _topology(racks: int, oversub: float) -> network.Topology:
    return network.Topology.multi_rack(
        racks, ACCESS, core_capacity=racks * ACCESS / oversub,
        hosts_per_rack=2)


def _launch_burst(plane, racks: int, lanes_per_rack: int, *,
                  cross_lanes: int, rng: np.random.Generator,
                  v_scale: float = 1.0) -> int:
    tr = WorkloadTrace([("MEM", 60), ("CPU", 60)], 120)
    n = 0
    for r in range(racks):
        for i in range(lanes_per_rack):
            plane.launch(
                MigrationRequest(f"r{r}j{i}", 0.0,
                                 v_scale * float(rng.uniform(0.5e9, 1.5e9)),
                                 src=f"r{r}h0", dst=f"r{r}h1"),
                tr.rate_table, 0.0)
            n += 1
    for c in range(cross_lanes):
        r = c % racks
        plane.launch(
            MigrationRequest(f"x{c}", 0.0,
                             v_scale * float(rng.uniform(0.5e9, 1.5e9)),
                             src=f"r{r}h0", dst=f"r{(r + 1) % racks}h0"),
            tr.rate_table, 0.0)
        n += 1
    return n


def run_config(racks: int, lanes_per_rack: int, oversub: float,
               seed: int = 0) -> Dict:
    """Drain one burst; verify conservation on every link."""
    topo = _topology(racks, oversub)
    plane = ShardedPlane(topo)
    rng = np.random.default_rng(seed)
    n = _launch_burst(plane, racks, lanes_per_rack,
                      cross_lanes=racks, rng=rng)
    domains_at_burst = plane.domain_count
    done = plane.advance(np.inf)
    elapsed = plane.now
    caps = topo.capacities
    conservation = {
        l: b <= caps[l] * elapsed * (1 + 1e-9)
        for l, b in plane.link_bytes.items()
    }
    outs = [o for _, o in done]
    return {
        "racks": racks,
        "lanes_per_rack": lanes_per_rack,
        "core_oversubscription": oversub,
        "lanes": n,
        "completed": len(outs),
        "domains_at_burst": domains_at_burst,
        "domain_merges": plane.merges,
        "makespan_s": round(elapsed, 2),
        "total_bytes_GB": round(sum(o.bytes_sent for o in outs) / 1e9, 3),
        "sum_time_s": round(sum(o.total_time for o in outs), 2),
        "links_checked": len(conservation),
        "conservation_ok": all(conservation.values()),
        "core_utilization": round(
            plane.link_bytes.get("core", 0.0)
            / (caps.get("core", np.inf) * elapsed), 3),
    }


def step_cost(racks: int, lanes_per_rack: int, *, mode: str,
              n_steps: int = 64, seed: int = 0) -> float:
    """Steady-state wall-clock microseconds per 1 s fabric step with every
    lane still in flight. Modes: sharded / monolithic / scalar (the
    monolithic per-lane reference loop)."""
    topo = _topology(racks, 1.0)
    if mode == "sharded":
        plane = ShardedPlane(topo)
    else:
        plane = MigrationPlane(topo, vectorized=(mode == "monolithic"))
    rng = np.random.default_rng(seed)
    # state large enough that no lane completes inside the measurement
    _launch_burst(plane, racks, lanes_per_rack, cross_lanes=racks,
                  rng=rng, v_scale=1e3)
    plane.advance(1.0)
    now = plane.now
    t0 = time.perf_counter()
    for _ in range(n_steps):
        now += 1.0
        plane.advance(now)
    return (time.perf_counter() - t0) / n_steps * 1e6


def sweep(racks_list: Sequence[int] = (2, 4, 8),
          lanes_list: Sequence[int] = (2, 8),
          oversubs: Sequence[float] = (1.0, 2.0, 4.0)) -> List[Dict]:
    rows = [run_config(r, lpr, ov)
            for r in racks_list for lpr in lanes_list for ov in oversubs]
    # step-cost rows at the smallest and largest requested configs (the
    # quick smoke passes a reduced sweep; don't time beyond it)
    step_configs = {(min(racks_list), min(lanes_list)),
                    (max(racks_list), max(lanes_list))}
    for racks, lpr in sorted(step_configs):
        costs = {m: min(step_cost(racks, lpr, mode=m) for _ in range(3))
                 for m in ("sharded", "monolithic", "scalar")}
        rows.append({
            "step_cost": True, "racks": racks, "lanes_per_rack": lpr,
            "lanes": racks * (lpr + 1),
            "sharded_us_per_step": round(costs["sharded"], 1),
            "monolithic_us_per_step": round(costs["monolithic"], 1),
            "scalar_us_per_step": round(costs["scalar"], 1),
            "vectorized_speedup": round(
                costs["scalar"] / max(costs["monolithic"], 1e-9), 2),
            "sharded_speedup_vs_scalar": round(
                costs["scalar"] / max(costs["sharded"], 1e-9), 2),
        })
    return rows


# ---------------------------------------------------------------------------
# route-aware pod/spine rows (ISSUE 8)
# ---------------------------------------------------------------------------
def _pod_topology(pods: int, racks: int, oversub: float,
                  n_spines: int = 2) -> network.Topology:
    return network.Topology.pod_spine(
        pods, racks, access_capacity=ACCESS,
        pod_oversubscription=oversub, n_spines=n_spines)


def _pod_burst(pods: int, racks: int, lanes: int,
               rng: np.random.Generator) -> List[MigrationRequest]:
    """A cross-rack lane ring: half the lanes stay inside their pod,
    half cross pods — the traffic that actually exercises routing."""
    reqs = []
    for i in range(lanes):
        p, r = i % pods, i % racks
        if i % 2:
            dst = f"p{p}r{(r + 1) % racks}h1"          # intra-pod
        else:
            dst = f"p{(p + 1) % pods}r{r}h1"           # cross-pod
        reqs.append(MigrationRequest(
            f"l{i}", 0.0, float(rng.uniform(0.5e9, 1.5e9)),
            src=f"p{p}r{r}h0", dst=dst))
    return reqs


def route_config(pods: int, racks: int, lanes: int, oversub: float, *,
                 mode: str, seed: int = 0) -> Dict:
    """Drain one pod/spine burst with lanes routed by ``pick_route``
    (``mode="route_aware"``) or pinned to route 0 (``mode="fixed"``)."""
    assert mode in ("route_aware", "fixed")
    topo = _pod_topology(pods, racks, oversub)
    plane = ShardedPlane(topo)
    tr = WorkloadTrace([("MEM", 60), ("CPU", 60)], 120)
    rng = np.random.default_rng(seed)
    for req in _pod_burst(pods, racks, lanes, rng):
        path = plane.pick_route(req.src, req.dst) if mode == "route_aware" \
            else topo.routes(req.src, req.dst)[0]
        plane.launch(req, tr.rate_table, 0.0, path=path)
    done = plane.advance(np.inf)
    elapsed = plane.now
    caps = topo.capacities
    conservation = all(b <= caps[l] * elapsed * (1 + 1e-9)
                       for l, b in plane.link_bytes.items())
    outs = [o for _, o in done]
    return {
        "pods": pods, "racks_per_pod": racks, "lanes": lanes,
        "pod_oversubscription": oversub, "mode": mode,
        "completed": len(outs),
        "makespan_s": round(elapsed, 2),
        "total_bytes_GB": round(sum(o.bytes_sent for o in outs) / 1e9, 3),
        "conservation_ok": conservation,
    }


def route_sweep(pods_list: Sequence[int] = (2, 3),
                racks_list: Sequence[int] = (2,),
                lanes_list: Sequence[int] = (8, 16),
                oversubs: Sequence[float] = (1.0, 2.0, 4.0)
                ) -> List[Dict]:
    """Route-aware vs fixed-shortest-path, cell by cell. Each cell row
    carries both modes' bytes/makespan plus the <= comparison."""
    rows = []
    for pods in pods_list:
        for racks in racks_list:
            for lanes in lanes_list:
                for ov in oversubs:
                    ra = route_config(pods, racks, lanes, ov,
                                      mode="route_aware")
                    fx = route_config(pods, racks, lanes, ov, mode="fixed")
                    rows.append({
                        "pods": pods, "racks_per_pod": racks,
                        "lanes": lanes, "pod_oversubscription": ov,
                        "route_aware_bytes_GB": ra["total_bytes_GB"],
                        "fixed_bytes_GB": fx["total_bytes_GB"],
                        "route_aware_makespan_s": ra["makespan_s"],
                        "fixed_makespan_s": fx["makespan_s"],
                        "conservation_ok": (ra["conservation_ok"]
                                            and fx["conservation_ok"]),
                        "route_le_fixed": (ra["total_bytes_GB"]
                                           <= fx["total_bytes_GB"]),
                        "route_lt_fixed": (ra["total_bytes_GB"]
                                           < fx["total_bytes_GB"]),
                    })
    return rows


def _latency_case(kind: str, n_cands: int, n_routes: int, seed: int = 0):
    """One controller decision point: ``kind="pod"`` is the routed
    pod/spine fabric, ``kind="flat"`` the multi_rack baseline with a
    comparable candidate load."""
    from repro.core.controller import AdaptiveConcurrencyController
    from repro.core.rates import PiecewiseRate
    rng = np.random.default_rng(seed)
    if kind == "pod":
        topo = _pod_topology(4, 2, 4.0, n_spines=n_routes)
        plane = ShardedPlane(topo)
        cands = _pod_burst(4, 2, n_cands, rng)
    else:
        topo = _topology(4, 4.0)
        plane = ShardedPlane(topo)
        cands = [MigrationRequest(
            f"l{i}", 0.0, float(rng.uniform(0.5e9, 1.5e9)),
            src=f"r{i % 4}h0", dst=f"r{(i + 1) % 4}h0")
            for i in range(n_cands)]
    rate = PiecewiseRate([60.0, 120.0], [40e6, 1e6])
    ctl = AdaptiveConcurrencyController(plane, rate_of=lambda q: rate)
    return ctl, cands


def route_latency(n_cands: int = 64, n_routes: int = 4,
                  reps: int = 5) -> Dict:
    """Wall-clock of one stacked ``select()`` over ``n_cands``
    candidates: defer-k x route on the pod fabric (x ``n_routes``
    candidate routes per lane) vs plain defer-k on the flat fabric.
    The acceptance bar is ~2x — the route stage adds one stacked pair
    solve and one flattened cost batch on top of the common prefix
    sweep."""
    times = {}
    for kind in ("pod", "flat"):
        best = np.inf
        for rep in range(reps):
            ctl, cands = _latency_case(kind, n_cands, n_routes, seed=rep)
            for r in cands:               # route stamps from prior reps
                r.path = None             # must not pin the next run
            t0 = time.perf_counter()
            ctl.select(cands, 0.0)
            best = min(best, time.perf_counter() - t0)
        times[kind] = best
    return {
        "n_candidates": n_cands, "n_routes": n_routes,
        "pod_select_ms": round(times["pod"] * 1e3, 3),
        "flat_select_ms": round(times["flat"] * 1e3, 3),
        "ratio": round(times["pod"] / max(times["flat"], 1e-12), 2),
        "within_2x": times["pod"] <= 2.0 * times["flat"],
    }


def route_parity(seeds: Sequence[int] = range(8)) -> Dict:
    """Stacked vs reference defer-k x route: identical launch sets and
    identical stamped routes on every seeded decision point."""
    from repro.core.controller import AdaptiveConcurrencyController
    from repro.core.rates import PiecewiseRate
    checked, ok = 0, True
    for seed in seeds:
        out = {}
        for mode in ("stacked", "reference"):
            rng = np.random.default_rng(seed)
            topo = _pod_topology(int(rng.integers(2, 4)), 2,
                                 float(rng.choice([1.0, 2.0, 4.0])))
            plane = ShardedPlane(topo)
            pods = len({topo.pod_of(h) for h in topo.host_links})
            cands = _pod_burst(pods, 2, int(rng.integers(2, 12)), rng)
            rate = PiecewiseRate(
                [60.0, 120.0], [float(rng.uniform(0, 160e6)),
                                float(rng.uniform(0, 20e6))])
            ctl = AdaptiveConcurrencyController(
                plane, rate_of=lambda q: rate, sweep=mode)
            sel = ctl.select(cands, 0.0)
            out[mode] = [(r.job_id, tuple(r.path or ())) for r in sel]
        checked += 1
        ok = ok and out["stacked"] == out["reference"]
    return {"cases": checked, "selections_bit_equal": ok}


def run():
    t0 = time.perf_counter()
    rows = sweep()
    route_rows = route_sweep()
    lat = route_latency()
    parity = route_parity()
    rows += [dict(r, route_sweep=True) for r in route_rows]
    rows.append(dict(lat, route_latency=True))
    rows.append(dict(parity, route_parity=True))
    dt = time.perf_counter() - t0
    ok = all(r["conservation_ok"] for r in rows if "conservation_ok" in r)
    r_le = all(r["route_le_fixed"] for r in route_rows)
    r_lt = any(r["route_lt_fixed"] for r in route_rows
               if r["pod_oversubscription"] > 1.0)
    sc = max((r for r in rows if r.get("step_cost")),
             key=lambda r: r["racks"])
    return [{"name": "fabric_sweep",
             "us_per_call": round(dt * 1e6 / max(len(rows), 1), 1),
             "derived": (f"conservation_ok={ok} "
                         f"vec_speedup@{sc['lanes']}lanes="
                         f"{sc['vectorized_speedup']}x "
                         f"sharded_speedup={sc['sharded_speedup_vs_scalar']}x "
                         f"route_le_fixed={r_le} route_win={r_lt} "
                         f"route_latency={lat['ratio']}x "
                         f"route_parity={parity['selections_bit_equal']}")
             }], rows


if __name__ == "__main__":
    print(run())
