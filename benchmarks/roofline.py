"""Roofline analysis (deliverable g) — three terms per (arch x shape x mesh)
from the compiled dry-run artifacts in experiments/dryrun/.

  compute    = HLO_FLOPs_per_device / peak_FLOPs         (197 TF/s bf16, v5e)
  memory     = HLO_bytes_per_device / HBM_bw             (819 GB/s)
  collective = collective_bytes_per_device / link_bw     (~50 GB/s ICI)

All three in seconds; the max is the bound, its share is the bottleneck.
MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) — per device — and the
ratio MODEL_FLOPS / HLO_FLOPs shows how much compiled compute is useful
(remat/dispatch waste shows up here).

Caveat (documented in EXPERIMENTS.md): HLO comes from the CPU-backend SPMD
compile; TPU fusion would reduce hbm_bytes, so the memory term is an upper
bound. hbm_write_bytes (results only) is reported as the lower bound.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config
from repro.models import lm as lm_mod

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

_param_cache: Dict[str, Dict[str, float]] = {}


def model_flops_per_device(arch: str, shape_name: str, devices: int) -> float:
    if arch not in _param_cache:
        cfg = get_config(arch)
        _param_cache[arch] = {
            "total": lm_mod.param_count(cfg),
            "active": cfg.active_param_count(),
        }
    cfg = get_config(arch)
    n = _param_cache[arch]["active" if cfg.moe else "total"]
    shape = SHAPES[shape_name]
    if shape.mode == "train":
        tokens = shape.seq_len * shape.global_batch
        mult = 6.0
    elif shape.mode == "prefill":
        tokens = shape.seq_len * shape.global_batch
        mult = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mult = 2.0
    return mult * n * tokens / devices


def analyze_record(rec: dict) -> Optional[dict]:
    if not rec.get("ok"):
        return None
    flops_t = rec["flops"] / PEAK_FLOPS
    mem_t = rec["hbm_bytes"] / HBM_BW
    mem_lo_t = rec.get("hbm_write_bytes", 0.0) / HBM_BW
    coll_t = rec["collectives"].get("total", 0.0) / LINK_BW
    terms = {"compute": flops_t, "memory": mem_t, "collective": coll_t}
    dominant = max(terms, key=terms.get)
    bound = terms[dominant]
    mf = model_flops_per_device(rec["arch"], rec["shape"], rec["devices"])
    useful = mf / rec["flops"] if rec["flops"] else 0.0
    # roofline fraction: useful-compute time over the dominant bound
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "devices": rec["devices"],
        "compute_s": round(flops_t, 6),
        "memory_s": round(mem_t, 6),
        "memory_lo_s": round(mem_lo_t, 6),
        "collective_s": round(coll_t, 6),
        "dominant": dominant,
        "model_flops_ratio": round(useful, 4),
        "roofline_fraction": round(frac, 4),
        "hbm_gib": round(rec["memory"]["argument_size_in_bytes"] / 2 ** 30
                         + rec["memory"]["temp_size_in_bytes"] / 2 ** 30, 2),
    }


def run():
    rows: List[dict] = []
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("tag"):
            continue                    # perf-iteration variants: §Perf only
        r = analyze_record(rec)
        if r:
            rows.append(r)
    n_ok = len(rows)
    worst = min(rows, key=lambda r: r["roofline_fraction"]) if rows else None
    derived = (f"cells={n_ok}"
               + (f" worst={worst['arch']}/{worst['shape']}"
                  f"@{worst['roofline_fraction']}" if worst else ""))
    return [{"name": "roofline", "us_per_call": 0.0, "derived": derived}], rows
