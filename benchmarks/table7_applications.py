"""Table 7 — orchestration with application workloads (OpenModeller, BRAMS,
Hadoop/TeraSort analogues).

Long irregular phases and complex cycles (the paper's §6.3.2: behavior not
known a priori, sensitive to inputs). Hadoop-like shuffle traces are the
MEM/IO-heavy ones the paper found benefited most (67% time, 62% traffic).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.fleetsim import FleetSim, SimJob, application_traces
from repro.core.orchestrator import MigrationRequest

VMEM = {"vm03_A_openmodeller": 768e6, "vm02_C_brams": 2048e6,
        "vm01_C_hadoop": 1024e6, "vm02_A_hadoop": 768e6}


def _run_policy(policy: str, seed: int) -> Dict:
    traces = application_traces(phase_s=45.0)
    jobs = [SimJob(j, traces[j], VMEM[j]) for j in traces]
    sim = FleetSim(jobs, policy=policy, warmup_s=1800.0,
                   max_wait=900.0, max_concurrent=2, seed=seed)
    rng = np.random.default_rng(seed + 11)
    plan = [MigrationRequest(job_id=j.job_id, created_at=sim.now
                             + float(rng.uniform(0, j.trace.cycle_s)),
                             v_bytes=j.v_bytes) for j in jobs]
    res = sim.run_with_plan(plan, horizon_s=6000.0)
    return {"per_job_time": {j: o.total_time for j, o in res.per_job.items()},
            "per_job_down": {j: o.downtime for j, o in res.per_job.items()},
            "traffic": res.total_bytes, "lm_hit_rate": res.lm_hit_rate}


def run(n_seeds: int = 5):
    t0 = time.perf_counter()
    rows: List[Dict] = []
    agg = {"tt": [], "at": [], "trf_t": [], "trf_a": [], "hit": []}
    for seed in range(n_seeds):
        trad = _run_policy("immediate", seed)
        alma = _run_policy("alma-paper", seed)
        agg["trf_t"].append(trad["traffic"])
        agg["trf_a"].append(alma["traffic"])
        agg["hit"].append(alma["lm_hit_rate"])
        for j in trad["per_job_time"]:
            agg["tt"].append(trad["per_job_time"][j])
            agg["at"].append(alma["per_job_time"][j])
            if seed == 0:
                red = (1 - alma["per_job_time"][j]
                       / max(trad["per_job_time"][j], 1e-9)) * 100
                rows.append({"vm": j,
                             "trad_time_s": round(trad["per_job_time"][j], 2),
                             "alma_time_s": round(alma["per_job_time"][j], 2),
                             "time_reduction_pct": round(red, 1),
                             "trad_down_s": round(trad["per_job_down"][j], 2),
                             "alma_down_s": round(alma["per_job_down"][j], 2)})
    traffic_red = (1 - np.mean(agg["trf_a"]) / np.mean(agg["trf_t"])) * 100
    traffic_red_best = (1 - np.asarray(agg["trf_a"])
                        / np.asarray(agg["trf_t"])).max() * 100
    time_red_max = (1 - np.asarray(agg["at"])
                    / np.maximum(np.asarray(agg["tt"]), 1e-9)).max() * 100
    rows.append({"vm": "TOTAL",
                 "trad_traffic_MB": round(np.mean(agg["trf_t"]) / 1e6, 1),
                 "alma_traffic_MB": round(np.mean(agg["trf_a"]) / 1e6, 1),
                 "traffic_reduction_pct": round(traffic_red, 1),
                 "traffic_reduction_best_seed_pct": round(traffic_red_best, 1),
                 "max_time_reduction_pct": round(time_red_max, 1),
                 "lm_hit_rate": round(float(np.mean(agg["hit"])), 3)})
    dt = time.perf_counter() - t0
    return [{"name": "table7_applications",
             "us_per_call": round(dt / n_seeds * 1e6, 1),
             "derived": (f"max_time_red={time_red_max:.0f}%"
                         f" traffic_red={traffic_red:.0f}%"
                         f" (best seed {traffic_red_best:.0f}%)")}], rows
