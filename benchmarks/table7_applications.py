"""Table 7 — orchestration with application workloads (OpenModeller, BRAMS,
Hadoop/TeraSort analogues) on the contention-aware migration plane.

Long irregular phases and complex cycles (the paper's §6.3.2: behavior not
known a priori, sensitive to inputs). Hadoop-like shuffle traces are the
MEM/IO-heavy ones the paper found benefited most (67% time, 62% traffic) —
and they are also the ones that hurt the most when fired simultaneously:
two replicas of each application share one 1 Gbit/s migration link, so a
burst of concurrent requests stretches every pre-copy round. ALMA's
postponement staggers the transfers into each workload's LM windows.
"""
from __future__ import annotations

import time
from typing import Dict

from benchmarks.contended_fleet import run_contended, summarize
from repro.core.fleetsim import application_traces

VMEM = {"vm03_A_openmodeller": 768e6, "vm02_C_brams": 2048e6,
        "vm01_C_hadoop": 1024e6, "vm02_A_hadoop": 768e6}


def _run_policy(policy: str, seed: int, *, replicas: int = 2,
                max_concurrent: int = 8) -> Dict:
    return run_contended(
        application_traces(phase_s=45.0, replicas=replicas),
        lambda j: VMEM[j.split(".")[0]], policy, seed,
        warmup_s=1800.0, max_wait=900.0, event_span=405.0, rng_salt=11,
        max_concurrent=max_concurrent, horizon_s=6000.0)


def run(n_seeds: int = 5):
    t0 = time.perf_counter()
    rows, total = summarize(_run_policy, n_seeds)
    dt = time.perf_counter() - t0
    return [{"name": "table7_applications",
             "us_per_call": round(dt / n_seeds * 1e6, 1),
             "derived": (f"max_time_red={total['max_time_reduction_pct']:.0f}%"
                         f" traffic_red={total['traffic_reduction_pct']:.0f}%"
                         f" total_time_red="
                         f"{total['total_time_reduction_pct']:.0f}%")}], rows
