"""Receding-horizon admission sweep — horizon vs myopic controller.

ISSUE 9's acceptance bench. Both arms run the SAME fleet, plan, seeds,
and adaptive concurrency controller; the only difference is the
``horizon`` knob:

  * ``myopic``  — the PR 8 controller: queue-order defer-k prefix sweep
    per migration domain, deferrals wake one sampling period out;
  * ``horizon`` — receding-horizon admission: subset selection over
    queue-order AND benefit-order prefixes, in-flight lanes repriced
    mid-round (``lane_state`` -> ``strunk.ResumeState``), and deferred
    candidates priced/woken at their predicted workload-cycle trough
    (Alg. 2 RemainTime read through ``SurveillanceEngine.next_trough``).

Cells are load x fabric: cyclic loads (the paper's table-3 MEM/IDLE
alternation and a slower diurnal profile) are where trough timing pays;
the flat acyclic load has no trough to wait for, so horizon must fall
back to myopic behavior and never regress. The acceptance contract:

  * horizon's measured contended bytes <= myopic's on EVERY cell;
  * strictly lower on at least one cyclic-load cell;
  * one horizon ``select()`` at 64 candidates costs <= 2x the myopic
    stacked sweep (the subset search adds one benefit-order ladder and
    an in-flight repricing batch, not a combinatorial blowup);
  * with ``horizon=False`` the stacked and per-k reference sweeps pick
    bit-identically (the PR 8 parity contract survives the refactor).

``benchmarks.run --quick`` runs a reduced grid and asserts all four.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import network
from repro.core.consolidation import Host, Placement
from repro.core.controller import AdaptiveConcurrencyController
from repro.core.fabric import ShardedPlane
from repro.core.fleetsim import (FleetSim, PAPER_BANDWIDTH, SimJob,
                                 WorkloadTrace)
from repro.core.orchestrator import MigrationRequest
from repro.core.rates import PiecewiseRate

ACCESS = PAPER_BANDWIDTH                  # 1 Gbit/s access links

# load name -> (phases, total_s, warmup_s, horizon_s, cyclic?)
LOADS: Dict[str, Tuple[list, float, float, float, bool]] = {
    "table3_cyclic": ([("MEM", 60.0), ("IDLE", 60.0)],
                      3600.0, 500.0, 4000.0, True),
    "diurnal_cyclic": ([("CPU", 90.0), ("MEM", 90.0),
                        ("IO", 90.0), ("IDLE", 90.0)],
                       7200.0, 1500.0, 6000.0, True),
    "flat_acyclic": ([("CPU", 60.0)], 3600.0, 200.0, 4000.0, False),
}
FABRICS = ("shared_link", "star")


def _fleet(load: str, fabric: str, horizon: bool, n_jobs: int, seed: int,
           event_skip: bool = True):
    """One fleet + its migration plan. Both arms get byte-identical
    inputs; jobs are de-phased so their troughs disagree (subset
    selection has real timing choices to make)."""
    phases, total_s, warmup_s, horizon_s, _ = LOADS[load]
    jobs = [SimJob(f"j{i}",
                   WorkloadTrace(phases, total_s, offset=15.0 * i), 1e9)
            for i in range(n_jobs)]
    placement = None
    if fabric == "star":
        hosts = {f"s{i}": Host(f"s{i}", 1.0, {j.job_id: 1.0})
                 for i, j in enumerate(jobs)}
        hosts["sink"] = Host("sink", float(n_jobs))
        placement = Placement(hosts)
    sim = FleetSim(jobs, policy="immediate", warmup_s=warmup_s,
                   max_concurrent=n_jobs, seed=seed, placement=placement,
                   adaptive_concurrency=not horizon, horizon=horizon,
                   event_skip=event_skip)
    plan = [MigrationRequest(j.job_id, sim.now + 5.0, j.v_bytes,
                             dst="sink" if fabric == "star" else "")
            for j in jobs]
    return sim, plan, horizon_s


def run_cell(load: str, fabric: str, horizon: bool, *, n_jobs: int = 8,
             seed: int = 5, event_skip: bool = True) -> Dict:
    sim, plan, horizon_s = _fleet(load, fabric, horizon, n_jobs, seed,
                                  event_skip)
    res = sim.run_with_plan(plan, horizon_s=horizon_s)
    return {
        "load": load, "fabric": fabric,
        "arm": "horizon" if horizon else "myopic",
        "completed": len(res.per_job), "requested": len(plan),
        "total_bytes_GB": round(res.total_bytes / 1e9, 4),
        "sum_time_s": round(res.total_time, 2),
        "makespan_s": round(res.makespan, 1),
    }


def sweep(loads: Sequence[str] = tuple(LOADS), fabrics: Sequence[str]
          = FABRICS, n_jobs: int = 8, seed: int = 5) -> List[Dict]:
    """The load x fabric grid, one merged row per cell."""
    rows: List[Dict] = []
    for load in loads:
        for fabric in fabrics:
            arm = {h: run_cell(load, fabric, h, n_jobs=n_jobs, seed=seed)
                   for h in (False, True)}
            rows.append({
                "load": load, "fabric": fabric,
                "cyclic": LOADS[load][4],
                "myopic_bytes_GB": arm[False]["total_bytes_GB"],
                "horizon_bytes_GB": arm[True]["total_bytes_GB"],
                "myopic_sum_time_s": arm[False]["sum_time_s"],
                "horizon_sum_time_s": arm[True]["sum_time_s"],
                "myopic_makespan_s": arm[False]["makespan_s"],
                "horizon_makespan_s": arm[True]["makespan_s"],
                "all_completed": all(
                    a["completed"] == a["requested"] for a in arm.values()),
                "horizon_le_myopic": (arm[True]["total_bytes_GB"]
                                      <= arm[False]["total_bytes_GB"]),
                "horizon_lt_myopic": (arm[True]["total_bytes_GB"]
                                      < arm[False]["total_bytes_GB"]),
            })
    return rows


# -- decision latency & parity (one decision point, not a whole sim) -------
def _decision_case(n_cands: int, racks: int, seed: int):
    """A contended decision point with lanes already mid-flight — the
    in-flight repricing path is exercised, not just the cold sweep."""
    topo = network.Topology.multi_rack(
        racks, ACCESS, core_capacity=racks * ACCESS / 2.0, hosts_per_rack=2)
    plane = ShardedPlane(topo)
    rng = np.random.default_rng(seed)
    rates: Dict[str, PiecewiseRate] = {}

    def lane(tag: str, i: int) -> MigrationRequest:
        src, dst = int(rng.integers(racks)), int(rng.integers(racks))
        req = MigrationRequest(f"{tag}{i}", 0.0,
                               float(rng.uniform(0.3e9, 2e9)),
                               src=f"r{src}h0", dst=f"r{dst}h1")
        rates[req.job_id] = PiecewiseRate(
            [60.0, 120.0], [float(rng.uniform(0, 150e6)), 3e6],
            offset=float(rng.uniform(0, 120)))
        return req

    for i in range(racks):
        plane.launch(lane("bg", i), rates[f"bg{i}"], 0.0)
    plane.advance(1.0)
    cands = [lane("c", i) for i in range(n_cands)]
    return plane, cands, rates


def _trough_table(cands: Sequence[MigrationRequest], seed: int):
    """Synthetic per-candidate troughs (half the burst is cyclic)."""
    rng = np.random.default_rng(seed + 1)
    table = {r.job_id: (float(rng.uniform(5.0, 120.0))
                        if rng.random() < 0.5 else None)
             for r in cands}
    return lambda req, now: table[req.job_id]


def latency_cell(n_cands: int = 64, racks: int = 4, seed: int = 0,
                 reps: int = 3) -> Dict:
    """One select() at ``n_cands`` candidates: horizon subset sweep vs
    the myopic stacked prefix sweep. The acceptance bar is <= 2x."""
    row: Dict = {"n_candidates": n_cands, "racks": racks}
    for mode in ("myopic", "horizon"):
        plane, cands, rates = _decision_case(n_cands, racks, seed)
        ctl = AdaptiveConcurrencyController(
            plane, rate_of=lambda r: rates[r.job_id],
            horizon=(mode == "horizon"),
            trough_of=_trough_table(cands, seed)
            if mode == "horizon" else None)
        best = np.inf
        for _ in range(reps):
            t0 = time.perf_counter()
            ctl.select(list(cands), plane.now)
            best = min(best, time.perf_counter() - t0)
        row[f"{mode}_ms"] = round(best * 1e3, 3)
    row["ratio"] = round(row["horizon_ms"] / max(row["myopic_ms"], 1e-9), 2)
    row["within_2x"] = row["ratio"] <= 2.0
    return row


def parity_cell(seeds: Sequence[int] = range(6), n_cands: int = 24,
                racks: int = 3) -> Dict:
    """``horizon=False`` selections, stacked vs per-k reference — the
    PR 8 bit-parity contract must survive the subset-sweep refactor."""
    equal = []
    for seed in seeds:
        picks = {}
        for mode in ("stacked", "reference"):
            plane, cands, rates = _decision_case(n_cands, racks, seed)
            ctl = AdaptiveConcurrencyController(
                plane, rate_of=lambda r: rates[r.job_id], sweep=mode)
            picks[mode] = [(r.job_id, r.path)
                           for r in ctl.select(cands, plane.now)]
        equal.append(picks["stacked"] == picks["reference"])
    return {"seeds": len(list(seeds)), "n_candidates": n_cands,
            "selections_bit_equal": all(equal)}


def check(rows: Sequence[Dict], lat: Dict, par: Dict) -> Dict[str, bool]:
    """The acceptance booleans (--quick criteria)."""
    cyc = [r for r in rows if r["cyclic"]]
    return {
        "all_completed": all(r["all_completed"] for r in rows),
        "horizon_le_myopic_everywhere": all(r["horizon_le_myopic"]
                                            for r in rows),
        "horizon_wins_cyclic": any(r["horizon_lt_myopic"] for r in cyc),
        "horizon_latency_within_2x": bool(lat["within_2x"]),
        "myopic_selection_parity": bool(par["selections_bit_equal"]),
    }


def run():
    t0 = time.perf_counter()
    rows = sweep()
    lat = latency_cell()
    par = parity_cell()
    dt = time.perf_counter() - t0
    crit = check(rows, lat, par)
    gain = max((1 - r["horizon_bytes_GB"] / max(r["myopic_bytes_GB"], 1e-9))
               for r in rows if r["cyclic"]) * 100
    all_rows = list(rows) + [lat, par, {"criteria": crit}]
    return [{"name": "horizon_sweep",
             "us_per_call": round(dt * 1e6 / max(len(all_rows), 1), 1),
             "derived": (f"le_everywhere={crit['horizon_le_myopic_everywhere']} "
                         f"wins_cyclic={crit['horizon_wins_cyclic']} "
                         f"best_cyclic_gain={gain:.1f}% "
                         f"latency={lat['ratio']}x")}], all_rows


if __name__ == "__main__":
    summary, rows = run()
    for r in rows:
        print(r)
    print(summary)
