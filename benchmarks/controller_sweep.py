"""Adaptive-concurrency sweep — static gate vs controller vs immediate.

The PR 2/3 sweeps showed the saturation regime (>= 16 concurrent lanes on
a shared link): the link, not the migration moment, becomes the bound.
This benchmark measures what the adaptive concurrency controller
(``core/controller.py``) buys there: on a multi-rack fabric with an
oversubscribed core (1:2 -> 1:4), a single simultaneous burst of 8+
migration requests (per-rack intra-rack lanes plus a ring of cross-rack
lanes) is released through three concurrency policies —

  * ``immediate``  — every request launches the moment it is due
    (``min_share_frac = 0``, no controller): the fire-and-forget baseline;
  * ``static``     — the ``min_share_frac`` share-floor gate (the PR 2
    fallback policy, cumulative within a tick);
  * ``adaptive``   — the defer-k controller minimizing predicted total
    contended bytes per migration domain.

Each cell drains the burst to completion and records measured total
transferred bytes, summed migration time, and makespan. The acceptance
contract (ISSUE 4): adaptive's measured bytes <= static's on every cell,
strictly lower on the saturation cells (>= 16 lanes). ``benchmarks.run
--quick`` asserts that on a reduced grid.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import numpy as np

from repro.core import network
from repro.core.controller import AdaptiveConcurrencyController
from repro.core.fabric import ShardedPlane
from repro.core.fleetsim import PAPER_BANDWIDTH
from repro.core.orchestrator import LMCM, MigrationRequest
from repro.core.rates import PiecewiseRate

ACCESS = PAPER_BANDWIDTH                  # 1 Gbit/s ToR links
MODES = ("immediate", "static", "adaptive")


def _topology(racks: int, oversub: float) -> network.Topology:
    return network.Topology.multi_rack(
        racks, ACCESS, core_capacity=racks * ACCESS / oversub,
        hosts_per_rack=2)


def _burst(racks: int, lanes_per_rack: int, rng: np.random.Generator
           ) -> tuple:
    """One simultaneous consolidation-style burst: ``lanes_per_rack``
    intra-rack requests per rack plus one cross-rack lane per rack, all
    created at t=0, with cyclic IO/CPU dirty-rate tables de-phased across
    the fleet (the contended-fleet scenario of Tables 6/7)."""
    reqs: List[MigrationRequest] = []
    rates: Dict[str, PiecewiseRate] = {}
    for r in range(racks):
        for i in range(lanes_per_rack):
            reqs.append(MigrationRequest(
                f"r{r}j{i}", 0.0, float(rng.uniform(0.5e9, 1.5e9)),
                src=f"r{r}h0", dst=f"r{r}h1"))
    for c in range(racks):
        reqs.append(MigrationRequest(
            f"x{c}", 0.0, float(rng.uniform(0.5e9, 1.5e9)),
            src=f"r{c}h0", dst=f"r{(c + 1) % racks}h0"))
    for i, req in enumerate(reqs):
        rates[req.job_id] = PiecewiseRate(
            [60.0, 120.0], [12e6, 3e6], offset=120.0 * i / len(reqs))
    return reqs, rates


def run_cell(racks: int, lanes_per_rack: int, oversub: float, mode: str,
             seed: int = 0, *, max_wait: float = 3600.0,
             horizon_s: float = 4000.0) -> Dict:
    """Drain one burst under one concurrency policy; measure the bill."""
    assert mode in MODES
    topo = _topology(racks, oversub)
    plane = ShardedPlane(topo)
    reqs, rates = _burst(racks, lanes_per_rack,
                         np.random.default_rng(seed))
    lmcm = LMCM(policy="immediate", max_wait=max_wait,
                max_concurrent=len(reqs) + 1, bandwidth=ACCESS,
                sample_period=1.0,
                min_share_frac=0.5 if mode == "static" else 0.0)
    lmcm.bandwidth_probe = lambda req, extra=0, pending=(): \
        plane.probe_bandwidth(req.src, req.dst, extra, pending=pending)
    lmcm.path_capacity = lambda req: \
        plane.path_capacity(req.src, req.dst)
    if mode == "adaptive":
        lmcm.controller = AdaptiveConcurrencyController(
            plane, rate_of=lambda r: rates[r.job_id], defer_s=1.0)
    for req in reqs:
        req.path = topo.path(req.src, req.dst)
        lmcm.submit(req, 0.0)
    now, outs = 0.0, []
    t0 = time.perf_counter()
    while (lmcm.queue or lmcm.running or plane.in_flight) \
            and now < horizon_s:
        for req in lmcm.due(now):
            plane.launch(req, rates[req.job_id], now, path=req.path)
        now += 1.0
        for req, out in plane.advance(now):
            lmcm.finish(req, out)
            outs.append(out)
    wall = time.perf_counter() - t0
    caps = topo.capacities
    return {
        "racks": racks,
        "lanes_per_rack": lanes_per_rack,
        "core_oversubscription": oversub,
        "lanes": len(reqs),
        "mode": mode,
        "completed": len(outs),
        "total_bytes_GB": round(sum(o.bytes_sent for o in outs) / 1e9, 4),
        "sum_time_s": round(sum(o.total_time for o in outs), 2),
        "makespan_s": round(now, 1),
        "conservation_ok": all(
            b <= caps[l] * now * (1 + 1e-9)
            for l, b in plane.link_bytes.items()),
        "wall_s": round(wall, 3),
    }


def sweep(racks_list: Sequence[int] = (2, 4),
          lanes_list: Sequence[int] = (4, 8),
          oversubs: Sequence[float] = (2.0, 4.0),
          seed: int = 0) -> List[Dict]:
    """The contended grid: every cell is 8+ simultaneous requests; cells
    with >= 16 lanes are the saturation regime of the PR 2/3 sweeps."""
    rows: List[Dict] = []
    for racks in racks_list:
        for lpr in lanes_list:
            for ov in oversubs:
                cell = {m: run_cell(racks, lpr, ov, m, seed) for m in MODES}
                merged = {k: cell["immediate"][k]
                          for k in ("racks", "lanes_per_rack",
                                    "core_oversubscription", "lanes")}
                for m in MODES:
                    merged[f"{m}_bytes_GB"] = cell[m]["total_bytes_GB"]
                    merged[f"{m}_sum_time_s"] = cell[m]["sum_time_s"]
                    merged[f"{m}_makespan_s"] = cell[m]["makespan_s"]
                    merged[f"{m}_completed"] = cell[m]["completed"]
                merged["conservation_ok"] = all(
                    cell[m]["conservation_ok"] for m in MODES)
                merged["all_completed"] = all(
                    cell[m]["completed"] == cell[m]["lanes"] for m in MODES)
                merged["adaptive_le_static"] = (
                    merged["adaptive_bytes_GB"] <= merged["static_bytes_GB"])
                merged["adaptive_lt_static"] = (
                    merged["adaptive_bytes_GB"] < merged["static_bytes_GB"])
                merged["saturation"] = merged["lanes"] >= 16
                rows.append(merged)
    return rows


def check(rows: Sequence[Dict]) -> Dict[str, bool]:
    """The acceptance booleans over a sweep's rows."""
    sat = [r for r in rows if r["saturation"]]
    return {
        "all_completed": all(r["all_completed"] for r in rows),
        "conservation_ok": all(r["conservation_ok"] for r in rows),
        "adaptive_le_static_everywhere": all(
            r["adaptive_le_static"] for r in rows),
        "adaptive_lt_static_at_saturation": (
            bool(sat) and all(r["adaptive_lt_static"] for r in sat)),
    }


def run():
    t0 = time.perf_counter()
    rows = sweep()
    dt = time.perf_counter() - t0
    crit = check(rows)
    gain = max((1 - r["adaptive_bytes_GB"] / max(r["static_bytes_GB"], 1e-9))
               for r in rows if r["saturation"]) * 100
    return [{"name": "controller_sweep",
             "us_per_call": round(dt * 1e6 / max(len(rows), 1), 1),
             "derived": (f"adaptive_le_static={crit['adaptive_le_static_everywhere']} "
                         f"lt_at_saturation={crit['adaptive_lt_static_at_saturation']} "
                         f"best_saturation_gain={gain:.1f}%")
             }], rows


if __name__ == "__main__":
    summary, rows = run()
    for r in rows:
        print(r)
    print(summary)
